"""L1 — Pallas kernels for the Q-learning accelerator hot path.

Two kernels per configuration, mirroring the paper's two hardware blocks:

* `forward` — the feed-forward step (Fig. 4 / Fig. 9): Q-values for all A
  actions of one state. Used on the action-selection path.
* `qupdate` — the fused full Q-update (Fig. 6-8, 10): both feed-forward
  sweeps (current + next state), error capture (Eq. 8), and backpropagation
  with the delta / delta-W generators (Eq. 7, 9-14), in ONE kernel launch.
  One launch == one paper "Q-update", the unit all the paper's tables are
  expressed in.

Hardware adaptation (DESIGN.md section 8): the paper streams one state-action
vector at a time through a MAC + sigmoid-ROM pipeline with all weights
resident in BRAM/FF. On TPU the analogue is: the whole parameter set and the
(A, D) activation tile are VMEM-resident for the duration of the kernel
(BlockSpecs map full arrays, no grid), the A serial dot products become one
(A, D) @ (D, H) MXU matmul, the sigmoid ROM becomes a VMEM-resident gather
table (passed to the kernel as an input operand — the Pallas analogue of
BRAM init data), and the paper's "separate resources" for delta and delta-W
generation become a fused epilogue (outer products on the MXU).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO, which is exactly what the
rust runtime loads. Real-TPU performance is estimated analytically in
DESIGN.md section 9 / EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from ..configs import FixedSpec, Hyper, LutSpec, NetConfig
from . import fixed_point as fxp
from . import sigmoid as sg


def _quant_fn(fixed: Optional[FixedSpec]):
    if fixed is None:
        return lambda x: x
    return lambda x: fxp.quantize(x, fixed)


class _Activation:
    """Activation plumbing for kernel bodies.

    Pallas kernels may not capture array constants, so the sigmoid /
    derivative ROMs are threaded through the kernel as *input operands*
    (`extra_inputs`, appended after the regular inputs). `bind` consumes the
    corresponding refs inside the kernel body and returns (f, fprime)
    callables over loaded VMEM values.
    """

    def __init__(self, lut: Optional[LutSpec], fixed: Optional[FixedSpec],
                 need_deriv: bool):
        self.lut = lut
        self.qz = _quant_fn(fixed)
        self.need_deriv = need_deriv
        if lut is None:
            self.extra_inputs: tuple = ()
        else:
            tabs = [self.qz(jnp.asarray(sg.build_sigmoid_table(lut)))]
            if need_deriv:
                tabs.append(self.qz(jnp.asarray(sg.build_deriv_table(lut))))
            self.extra_inputs = tuple(tabs)

    @property
    def n_extra(self) -> int:
        return len(self.extra_inputs)

    def bind(self, table_refs):
        qz, lut = self.qz, self.lut
        if lut is None:
            f = lambda x: qz(sg.sigmoid_exact(x))
            fp = lambda x: qz(sg.sigmoid_deriv_exact(x))
            return f, fp
        table = table_refs[0][...]
        f = lambda x: sg.lut_lookup(table, x, lut)
        if not self.need_deriv:
            return f, None
        dtable = table_refs[1][...]
        fp = lambda x: sg.lut_lookup(dtable, x, lut)
        return f, fp


# ---------------------------------------------------------------------------
# Feed-forward kernels
# ---------------------------------------------------------------------------

def make_forward(cfg: NetConfig,
                 fixed: Optional[FixedSpec] = None,
                 lut: Optional[LutSpec] = None,
                 a: Optional[int] = None):
    """Build the feed-forward pallas_call: (params, sa) -> q (A,).

    `a` overrides the action-batch size (defaults to cfg.a) so tests can
    sweep shapes.
    """
    a = cfg.a if a is None else a
    qz = _quant_fn(fixed)
    act = _Activation(lut, fixed, need_deriv=False)
    out = jax.ShapeDtypeStruct((a,), jnp.float32)

    if cfg.arch == "perceptron":
        def body(sa_ref, w_ref, b_ref, *rest):
            (*tabs, q_ref) = rest
            f, _ = act.bind(tabs)
            # Eq. 5/6 over the whole action batch: one (A,D)@(D,1) MXU tile.
            sa, w, b = qz(sa_ref[...]), qz(w_ref[...]), qz(b_ref[...])
            pre = qz(jnp.matmul(sa, w)[:, 0] + b[0])  # MAC array + bias
            q_ref[...] = f(pre)                       # sigmoid ROM read
    else:
        def body(sa_ref, w1_ref, b1_ref, w2_ref, b2_ref, *rest):
            (*tabs, q_ref) = rest
            f, _ = act.bind(tabs)
            # Fig. 9: two MAC stages with a sigmoid ROM between and after.
            sa = qz(sa_ref[...])
            w1, b1 = qz(w1_ref[...]), qz(b1_ref[...])
            w2, b2 = qz(w2_ref[...]), qz(b2_ref[...])
            pre1 = qz(jnp.matmul(sa, w1) + b1)        # (A, H) hidden MACs
            hid = f(pre1)
            pre2 = qz(jnp.matmul(hid, w2)[:, 0] + b2[0])
            q_ref[...] = f(pre2)

    call = pl.pallas_call(body, out_shape=out, interpret=True)

    def forward(params, sa):
        return call(sa, *params, *act.extra_inputs)

    return forward


# ---------------------------------------------------------------------------
# Fused Q-update kernels
# ---------------------------------------------------------------------------

def make_qupdate(cfg: NetConfig,
                 hyper: Hyper,
                 fixed: Optional[FixedSpec] = None,
                 lut: Optional[LutSpec] = None,
                 a: Optional[int] = None):
    """Build the fused Q-update pallas_call.

    Returns `update(params, sa_cur, sa_next, action, reward)` ->
    `(new_params, q_cur, q_next, q_err)` where action is an int32 scalar and
    reward a float32 scalar (shape-() or (1,) accepted).
    """
    a = cfg.a if a is None else a
    qz = _quant_fn(fixed)
    act = _Activation(lut, fixed, need_deriv=True)

    if cfg.arch == "perceptron":
        out = (
            jax.ShapeDtypeStruct((cfg.d, 1), jnp.float32),  # w'
            jax.ShapeDtypeStruct((1,), jnp.float32),        # b'
            jax.ShapeDtypeStruct((a,), jnp.float32),        # q_cur
            jax.ShapeDtypeStruct((a,), jnp.float32),        # q_next
            jax.ShapeDtypeStruct((1,), jnp.float32),        # q_err
        )

        def body(sa_cur_ref, sa_next_ref, action_ref, reward_ref,
                 w_ref, b_ref, *rest):
            (*tabs, wo_ref, bo_ref, qcur_ref, qnext_ref, qerr_ref) = rest
            f, fp = act.bind(tabs)
            sa_cur, sa_next = qz(sa_cur_ref[...]), qz(sa_next_ref[...])
            w, b = qz(w_ref[...]), qz(b_ref[...])
            a_idx = action_ref[0]
            reward = reward_ref[0]

            # Feed-forward sweep 1 (current state) — Fig. 4, filling the
            # "current state" FIFO of Fig. 6.
            pre_c = qz(jnp.matmul(sa_cur, w)[:, 0] + b[0])
            q_cur = f(pre_c)
            # Sweep 2 (next state) — the "next state" FIFO.
            pre_n = qz(jnp.matmul(sa_next, w)[:, 0] + b[0])
            q_next = f(pre_n)

            # Error capture block (Fig. 5, Eq. 8).
            q_sa = jnp.take(q_cur, a_idx)
            target = qz(reward + qz(hyper.gamma * jnp.max(q_next)))
            err = qz(hyper.alpha * qz(target - q_sa))

            # Backprop block (Eq. 7, 9, 10).
            delta = qz(fp(jnp.take(pre_c, a_idx)) * err)
            x = jnp.take(sa_cur, a_idx, axis=0)
            dw = qz(hyper.lr * qz(x * delta))
            db = qz(hyper.lr * delta)

            wo_ref[...] = qz(w + dw[:, None])
            bo_ref[...] = qz(b + db[None])
            qcur_ref[...] = q_cur
            qnext_ref[...] = q_next
            qerr_ref[...] = err[None]

        n_params = 2
    else:
        out = (
            jax.ShapeDtypeStruct((cfg.d, cfg.h), jnp.float32),  # w1'
            jax.ShapeDtypeStruct((cfg.h,), jnp.float32),        # b1'
            jax.ShapeDtypeStruct((cfg.h, 1), jnp.float32),      # w2'
            jax.ShapeDtypeStruct((1,), jnp.float32),            # b2'
            jax.ShapeDtypeStruct((a,), jnp.float32),            # q_cur
            jax.ShapeDtypeStruct((a,), jnp.float32),            # q_next
            jax.ShapeDtypeStruct((1,), jnp.float32),            # q_err
        )

        def body(sa_cur_ref, sa_next_ref, action_ref, reward_ref,
                 w1_ref, b1_ref, w2_ref, b2_ref, *rest):
            (*tabs, w1o_ref, b1o_ref, w2o_ref, b2o_ref,
             qcur_ref, qnext_ref, qerr_ref) = rest
            f, fp = act.bind(tabs)
            sa_cur, sa_next = qz(sa_cur_ref[...]), qz(sa_next_ref[...])
            w1, b1 = qz(w1_ref[...]), qz(b1_ref[...])
            w2, b2 = qz(w2_ref[...]), qz(b2_ref[...])
            a_idx = action_ref[0]
            reward = reward_ref[0]

            # Sweep 1: current state (internals kept for backprop).
            pre1_c = qz(jnp.matmul(sa_cur, w1) + b1)      # (A, H)
            hid_c = f(pre1_c)
            pre2_c = qz(jnp.matmul(hid_c, w2)[:, 0] + b2[0])
            q_cur = f(pre2_c)
            # Sweep 2: next state.
            pre1_n = qz(jnp.matmul(sa_next, w1) + b1)
            hid_n = f(pre1_n)
            pre2_n = qz(jnp.matmul(hid_n, w2)[:, 0] + b2[0])
            q_next = f(pre2_n)

            # Error capture (Eq. 8).
            q_sa = jnp.take(q_cur, a_idx)
            target = qz(reward + qz(hyper.gamma * jnp.max(q_next)))
            err = qz(hyper.alpha * qz(target - q_sa))

            # Backprop (Eq. 11-14) — delta generator then delta-W generator,
            # the "separate resources" of Fig. 10 fused as one epilogue.
            s2 = jnp.take(pre2_c, a_idx)                # output pre-activation
            o1 = jnp.take(hid_c, a_idx, axis=0)         # (H,)
            s1 = jnp.take(pre1_c, a_idx, axis=0)        # (H,)
            x = jnp.take(sa_cur, a_idx, axis=0)         # (D,)

            d2 = qz(fp(s2) * err)                       # Eq. 11
            d1 = qz(fp(s1) * qz(d2 * w2[:, 0]))         # Eq. 12
            dw2 = qz(hyper.lr * qz(o1 * d2))            # Eq. 13 (hidden->out)
            db2 = qz(hyper.lr * d2)
            dw1 = qz(hyper.lr * qz(x[:, None] * d1[None, :]))  # outer product
            db1 = qz(hyper.lr * d1)

            w1o_ref[...] = qz(w1 + dw1)                 # Eq. 14
            b1o_ref[...] = qz(b1 + db1)
            w2o_ref[...] = qz(w2 + dw2[:, None])
            b2o_ref[...] = qz(b2 + db2[None])
            qcur_ref[...] = q_cur
            qnext_ref[...] = q_next
            qerr_ref[...] = err[None]

        n_params = 4

    call = pl.pallas_call(body, out_shape=out, interpret=True)

    def update(params, sa_cur, sa_next, action, reward):
        action = jnp.asarray(action, jnp.int32).reshape((1,))
        reward = jnp.asarray(reward, jnp.float32).reshape((1,))
        res = call(sa_cur, sa_next, action, reward, *params,
                   *act.extra_inputs)
        new_params = tuple(res[:n_params])
        q_cur, q_next, q_err = res[n_params], res[n_params + 1], res[n_params + 2]
        return new_params, q_cur, q_next, q_err[0]

    return update
