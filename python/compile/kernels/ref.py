"""Pure-jnp reference ("oracle") for the Q-learning accelerator math.

Implements the paper's equations directly, with no Pallas:

* Eq. 5/6 — perceptron feed-forward (weighted sum + sigmoid),
* Eq. 7/8 — Q-error capture and output delta,
* Eq. 9/10 — perceptron weight update,
* Eq. 11-14 — MLP backpropagation (output delta, hidden deltas, weight
  updates via the delta / delta-W generators of Fig. 10).

Every Pallas kernel in qnet.py is tested against these functions
(python/tests/), and the rust CPU baseline (rust/src/nn/) and the FPGA
datapath simulator (rust/src/fpga/) reproduce the same chain of operations —
see rust integration test `backend_equiv`.

Conventions
-----------
* `sa` is the (A, D) matrix of state-action encodings: row i is the input
  vector for evaluating action i in the given state. The paper runs the
  feed-forward block A times serially; evaluating the A rows as one batch is
  the same math (DESIGN.md section 7.5).
* Perceptron params: (w (D,1), b (1,)). MLP params: (w1 (D,H), b1 (H,),
  w2 (H,1), b2 (1,)).
* `fixed=None` -> float32 datapath; `fixed=FixedSpec` -> every register
  value is fake-quantized to the Q(word,frac) grid (see fixed_point.py).
* `lut=None` -> exact sigmoid; `lut=LutSpec` -> ROM lookup for both the
  activation and its derivative, as in the paper.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import FixedSpec, Hyper, LutSpec, NetConfig
from . import fixed_point as fxp
from . import sigmoid as sg


# ---------------------------------------------------------------------------
# Activation plumbing
# ---------------------------------------------------------------------------

def make_activation(lut: Optional[LutSpec], fixed: Optional[FixedSpec]):
    """Return (f, fprime) callables matching the configured datapath.

    With a LUT the table entries themselves are quantized when the datapath
    is fixed point — the ROM stores Q(word,frac) words on the FPGA.
    """
    if lut is None:
        f, fp = sg.sigmoid_exact, sg.sigmoid_deriv_exact
        if fixed is None:
            return f, fp
        return (lambda x: fxp.quantize(f(x), fixed),
                lambda x: fxp.quantize(fp(x), fixed))

    table = jnp.asarray(sg.build_sigmoid_table(lut))
    dtable = jnp.asarray(sg.build_deriv_table(lut))
    if fixed is not None:
        table = fxp.quantize(table, fixed)
        dtable = fxp.quantize(dtable, fixed)
    return (lambda x: sg.lut_lookup(table, x, lut),
            lambda x: sg.lut_lookup(dtable, x, lut))


def _q(x, fixed):
    return x if fixed is None else fxp.quantize(x, fixed)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: NetConfig, key: jax.Array, scale: float = 0.5):
    """Small random weights; biases zero (paper does not specify init)."""
    if cfg.arch == "perceptron":
        return (
            scale * jax.random.normal(key, (cfg.d, 1), jnp.float32),
            jnp.zeros((1,), jnp.float32),
        )
    k1, k2 = jax.random.split(key)
    return (
        scale * jax.random.normal(k1, (cfg.d, cfg.h), jnp.float32),
        jnp.zeros((cfg.h,), jnp.float32),
        scale * jax.random.normal(k2, (cfg.h, 1), jnp.float32),
        jnp.zeros((1,), jnp.float32),
    )


def param_shapes(cfg: NetConfig):
    if cfg.arch == "perceptron":
        return ((cfg.d, 1), (1,))
    return ((cfg.d, cfg.h), (cfg.h,), (cfg.h, 1), (1,))


# ---------------------------------------------------------------------------
# Feed-forward (Eq. 5, 6 / Fig. 4, 9)
# ---------------------------------------------------------------------------

def forward_full(cfg: NetConfig, params, sa,
                 fixed: Optional[FixedSpec] = None,
                 lut: Optional[LutSpec] = None):
    """Feed-forward returning internals needed by backprop.

    Returns a dict with:
      q     (A,)   — Q-values (post-sigmoid output)
      pre2  (A,)   — output-layer pre-activations (sigma)
      hid   (A, H) — hidden activations (MLP only)
      pre1  (A, H) — hidden pre-activations (MLP only)
    """
    f, _ = make_activation(lut, fixed)
    sa = _q(sa, fixed)
    if cfg.arch == "perceptron":
        w, b = (_q(p, fixed) for p in params)
        pre = _q(jnp.matmul(sa, w)[:, 0] + b[0], fixed)
        return {"q": f(pre), "pre2": pre}
    w1, b1, w2, b2 = (_q(p, fixed) for p in params)
    pre1 = _q(jnp.matmul(sa, w1) + b1, fixed)
    hid = f(pre1)
    pre2 = _q(jnp.matmul(hid, w2)[:, 0] + b2[0], fixed)
    return {"q": f(pre2), "pre2": pre2, "hid": hid, "pre1": pre1}


def forward(cfg: NetConfig, params, sa,
            fixed: Optional[FixedSpec] = None,
            lut: Optional[LutSpec] = None):
    """Q-values for all A actions: the paper's feed-forward step run A times."""
    return forward_full(cfg, params, sa, fixed, lut)["q"]


# ---------------------------------------------------------------------------
# Q-update (Eq. 4, 7-14 / Fig. 5-7, 10)
# ---------------------------------------------------------------------------

def q_error(q_cur_a, q_next_max, reward, hyper: Hyper,
            fixed: Optional[FixedSpec] = None):
    """Eq. 8: Q_error = alpha * (r + gamma * opt Q(t+1) - Q(s,a))."""
    target = _q(reward + _q(hyper.gamma * q_next_max, fixed), fixed)
    return _q(hyper.alpha * _q(target - q_cur_a, fixed), fixed)


def qupdate(cfg: NetConfig, params, sa_cur, sa_next, action, reward,
            hyper: Hyper,
            fixed: Optional[FixedSpec] = None,
            lut: Optional[LutSpec] = None):
    """One full paper Q-update: two feed-forward sweeps, error, backprop.

    `action` is the index (int32 scalar) of the action taken in the current
    state; `reward` a float scalar. Returns (new_params, aux) with aux
    carrying q_cur (A,), q_next (A,), q_err ().
    """
    _, fprime = make_activation(lut, fixed)

    cur = forward_full(cfg, params, sa_cur, fixed, lut)
    nxt = forward_full(cfg, params, sa_next, fixed, lut)
    q_cur, q_next = cur["q"], nxt["q"]

    err = q_error(q_cur[action], jnp.max(q_next), reward, hyper, fixed)

    x = _q(sa_cur, fixed)[action]  # (D,) input row of the taken action
    lr = hyper.lr

    if cfg.arch == "perceptron":
        w, b = (_q(p, fixed) for p in params)
        # Eq. 7: delta = f'(sigma) * Q_error
        delta = _q(fprime(cur["pre2"][action]) * err, fixed)
        # Eq. 9/10: dW = C * O * delta (O here is the input x_i), W += dW
        dw = _q(lr * _q(x * delta, fixed), fixed)
        db = _q(lr * delta, fixed)
        new = (_q(w + dw[:, None], fixed), _q(b + db[None], fixed))
        aux = {"q_cur": q_cur, "q_next": q_next, "q_err": err}
        return new, aux

    w1, b1, w2, b2 = (_q(p, fixed) for p in params)
    o1 = cur["hid"][action]          # (H,) hidden activations for taken action
    s1 = cur["pre1"][action]         # (H,) hidden pre-activations
    s2 = cur["pre2"][action]         # ()  output pre-activation

    # Eq. 11: output delta
    d2 = _q(fprime(s2) * err, fixed)
    # Eq. 12: hidden deltas — delta_i = f'(sigma_i) * sum_j delta_j W_ij
    d1 = _q(fprime(s1) * _q(d2 * w2[:, 0], fixed), fixed)
    # Eq. 13/14: delta-W generators + update
    dw2 = _q(lr * _q(o1 * d2, fixed), fixed)           # (H,)
    db2 = _q(lr * d2, fixed)                           # ()
    dw1 = _q(lr * _q(jnp.outer(x, d1), fixed), fixed)  # (D, H)
    db1 = _q(lr * d1, fixed)                           # (H,)

    new = (
        _q(w1 + dw1, fixed),
        _q(b1 + db1, fixed),
        _q(w2 + dw2[:, None], fixed),
        _q(b2 + db2[None], fixed),
    )
    aux = {"q_cur": q_cur, "q_next": q_next, "q_err": err}
    return new, aux


# ---------------------------------------------------------------------------
# Convenience: numpy transition generator for tests
# ---------------------------------------------------------------------------

def random_transition(cfg: NetConfig, rng: np.random.Generator):
    """A random (sa_cur, sa_next, action, reward) tuple with paper shapes."""
    sa_cur = rng.uniform(-1, 1, (cfg.a, cfg.d)).astype(np.float32)
    sa_next = rng.uniform(-1, 1, (cfg.a, cfg.d)).astype(np.float32)
    action = np.int32(rng.integers(0, cfg.a))
    reward = np.float32(rng.uniform(-1, 1))
    return sa_cur, sa_next, action, reward
