"""Qm.n fixed-point emulation used by the fixed-precision kernels.

The paper's fixed-point datapath (Section 3, Tables 1-8) is modelled as
fake-quantization: every value that would live in an 18-bit register on the
FPGA is rounded to the Q(word, frac) grid and saturated. Arithmetic between
quantizations is exact (float32 holds the <= 2*frac-bit products of the tiny
nets here), so the sequence

    q(q(a) * q(b))         ==  DSP48 multiply + round
    q(sum_i q(a_i * b_i))  ==  wide accumulator + single round

matches the integer datapath in rust/src/fixed/ to <= 1 LSB (the rust side
uses the same round-half-even convention; see tests/test_fixed_vs_ref.py and
rust tests `fixed::tests::matches_python_convention`).

All helpers are jnp-traceable and run inside Pallas interpret-mode kernels.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..configs import FixedSpec


def quantize(x: jnp.ndarray, spec: FixedSpec) -> jnp.ndarray:
    """Round `x` to the Q(word, frac) grid with saturation.

    jnp.round implements round-half-even, matching the rust implementation
    (`Fixed::from_f64`). Result stays float32 but only takes representable
    values k / 2^frac with qmin <= k <= qmax.
    """
    scaled = jnp.round(x * spec.scale)
    scaled = jnp.clip(scaled, float(spec.qmin), float(spec.qmax))
    return scaled / spec.scale


def qmul(a: jnp.ndarray, b: jnp.ndarray, spec: FixedSpec) -> jnp.ndarray:
    """Fixed-point multiply: exact product, single rounding (DSP48 semantics)."""
    return quantize(a * b, spec)


def qdot(x: jnp.ndarray, w: jnp.ndarray, spec: FixedSpec) -> jnp.ndarray:
    """MAC chain x @ w with a wide accumulator and one final rounding.

    Matches the paper's multiplier+accumulator block (Fig. 4): products are
    kept at full 2*frac precision in the accumulator; only the accumulator
    output is rounded back to Q(word, frac).
    """
    return quantize(jnp.matmul(x, w), spec)


def qadd(a: jnp.ndarray, b: jnp.ndarray, spec: FixedSpec) -> jnp.ndarray:
    return quantize(a + b, spec)
