"""Sigmoid activation and its derivative, both exact and ROM/LUT forms.

The paper (Section 3) implements the activation with a look-up table of
pre-calculated sigmoid values stored in ROM, and a second LUT for the
derivative used during backpropagation ("The derivative of the sigmoid is
also implemented using a Look-up Table (ROM)"). We mirror that: a `size`-entry
table sampled uniformly over [-xmax, xmax], nearest-entry lookup, inputs
clipped to the table range.

Tables are built once at trace time and become HLO constants, i.e. the ROM
contents are baked into the artifact exactly like FPGA block-RAM init data.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..configs import LutSpec


def sigmoid_exact(x: jnp.ndarray) -> jnp.ndarray:
    return 1.0 / (1.0 + jnp.exp(-x))


def sigmoid_deriv_exact(x: jnp.ndarray) -> jnp.ndarray:
    s = sigmoid_exact(x)
    return s * (1.0 - s)


def build_sigmoid_table(lut: LutSpec) -> np.ndarray:
    """ROM contents: sigmoid sampled at `size` points over [-xmax, xmax]."""
    grid = np.linspace(-lut.xmax, lut.xmax, lut.size, dtype=np.float64)
    return (1.0 / (1.0 + np.exp(-grid))).astype(np.float32)


def build_deriv_table(lut: LutSpec) -> np.ndarray:
    """ROM contents for f'(sigma), indexed by pre-activation sigma."""
    grid = np.linspace(-lut.xmax, lut.xmax, lut.size, dtype=np.float64)
    s = 1.0 / (1.0 + np.exp(-grid))
    return (s * (1.0 - s)).astype(np.float32)


def lut_index(x: jnp.ndarray, lut: LutSpec) -> jnp.ndarray:
    """Address generator: clip to table range, map to nearest entry."""
    xc = jnp.clip(x, -lut.xmax, lut.xmax)
    idx = jnp.round((xc + lut.xmax) / (2.0 * lut.xmax) * (lut.size - 1))
    return idx.astype(jnp.int32)


def lut_lookup(table: jnp.ndarray, x: jnp.ndarray, lut: LutSpec) -> jnp.ndarray:
    """ROM read: one BRAM access per element on the FPGA."""
    return jnp.take(table, lut_index(x, lut), axis=0)
