"""L2 — JAX compute graphs for every AOT artifact.

Each paper configuration (perceptron/MLP x simple/complex x float/fixed)
gets three graphs, all calling the L1 Pallas kernels (kernels/qnet.py):

* `forward`     — action-selection path: Q-values for all A actions.
* `qupdate`     — one full Q-update (the unit of Tables 1-6).
* `train_batch` — `SCAN_BATCH` sequential Q-updates under one `lax.scan`,
  so the rust hot loop can amortize PJRT dispatch overhead across a whole
  mini-trajectory (DESIGN.md section 9, L2 perf item).

Argument and result conventions (the contract with rust/src/runtime/ —
recorded machine-readably in artifacts/manifest.json):

* parameters first, then data inputs; scalars travel as shape-(1,) arrays;
* results are emitted as a tuple (lowered with return_tuple=True), updated
  parameters first.

Hyper-parameters (alpha, gamma, lr) and the activation ROM contents are
baked into the artifact as constants, exactly like block-RAM init data in
the paper's bitstream.
"""

from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from .configs import ArtifactSpec
from .kernels import qnet, ref


def _n_params(spec: ArtifactSpec) -> int:
    return 2 if spec.net.arch == "perceptron" else 4


def param_specs(spec: ArtifactSpec):
    return [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in ref.param_shapes(spec.net)]


def input_specs(spec: ArtifactSpec) -> Sequence[jax.ShapeDtypeStruct]:
    """Example-argument shapes used for AOT lowering, in call order."""
    cfg, b = spec.net, spec.batch
    ps = param_specs(spec)
    sa = (cfg.a, cfg.d)
    f32, i32 = jnp.float32, jnp.int32
    if spec.kind == "forward":
        return [*ps, jax.ShapeDtypeStruct(sa, f32)]
    if spec.kind == "qupdate":
        return [*ps,
                jax.ShapeDtypeStruct(sa, f32),
                jax.ShapeDtypeStruct(sa, f32),
                jax.ShapeDtypeStruct((1,), i32),
                jax.ShapeDtypeStruct((1,), f32)]
    if spec.kind == "train_batch":
        return [*ps,
                jax.ShapeDtypeStruct((b, *sa), f32),
                jax.ShapeDtypeStruct((b, *sa), f32),
                jax.ShapeDtypeStruct((b,), i32),
                jax.ShapeDtypeStruct((b,), f32)]
    raise ValueError(f"unknown kind {spec.kind}")


def output_names(spec: ArtifactSpec) -> Sequence[str]:
    pn = (["w", "b"] if spec.net.arch == "perceptron"
          else ["w1", "b1", "w2", "b2"])
    if spec.kind == "forward":
        return ["q"]
    if spec.kind == "qupdate":
        return [*pn, "q_cur", "q_next", "q_err"]
    return [*pn, "q_err_batch"]


def input_names(spec: ArtifactSpec) -> Sequence[str]:
    pn = (["w", "b"] if spec.net.arch == "perceptron"
          else ["w1", "b1", "w2", "b2"])
    if spec.kind == "forward":
        return [*pn, "sa"]
    if spec.kind == "qupdate":
        return [*pn, "sa_cur", "sa_next", "action", "reward"]
    return [*pn, "sa_cur", "sa_next", "actions", "rewards"]


def build_fn(spec: ArtifactSpec) -> Callable[..., Tuple[jnp.ndarray, ...]]:
    """The traceable python function for one artifact."""
    cfg, fixed, lut, hyper = spec.net, spec.fixed, spec.lut, spec.hyper
    n = _n_params(spec)

    if spec.kind == "forward":
        fwd = qnet.make_forward(cfg, fixed=fixed, lut=lut)

        def forward_fn(*args):
            params, sa = args[:n], args[n]
            return (fwd(params, sa),)

        return forward_fn

    upd = qnet.make_qupdate(cfg, hyper, fixed=fixed, lut=lut)

    if spec.kind == "qupdate":
        def qupdate_fn(*args):
            params = args[:n]
            sa_cur, sa_next, action, reward = args[n:]
            new_params, q_cur, q_next, q_err = upd(
                params, sa_cur, sa_next, action[0], reward[0])
            return (*new_params, q_cur, q_next, q_err[None])

        return qupdate_fn

    def train_batch_fn(*args):
        params = args[:n]
        sa_cur, sa_next, actions, rewards = args[n:]

        def step(p, xs):
            sc, sn, a, r = xs
            new_p, _, _, q_err = upd(p, sc, sn, a, r)
            return new_p, q_err

        new_params, q_errs = jax.lax.scan(
            step, params, (sa_cur, sa_next, actions, rewards))
        return (*new_params, q_errs)

    return train_batch_fn


def reference_fn(spec: ArtifactSpec) -> Callable[..., Tuple[jnp.ndarray, ...]]:
    """Same contract as build_fn but implemented with the pure-jnp oracle —
    used by tests to validate whole artifacts, not just kernels."""
    cfg, fixed, lut, hyper = spec.net, spec.fixed, spec.lut, spec.hyper
    n = _n_params(spec)

    if spec.kind == "forward":
        def fwd(*args):
            return (ref.forward(cfg, args[:n], args[n], fixed=fixed, lut=lut),)
        return fwd

    def one(params, sa_cur, sa_next, action, reward):
        return ref.qupdate(cfg, params, sa_cur, sa_next, action, reward,
                           hyper, fixed=fixed, lut=lut)

    if spec.kind == "qupdate":
        def qupd(*args):
            params = args[:n]
            sa_cur, sa_next, action, reward = args[n:]
            new_params, aux = one(params, sa_cur, sa_next, action[0], reward[0])
            return (*new_params, aux["q_cur"], aux["q_next"], aux["q_err"][None])
        return qupd

    def batch(*args):
        params = args[:n]
        sa_cur, sa_next, actions, rewards = args[n:]

        def step(p, xs):
            sc, sn, a, r = xs
            new_p, aux = one(p, sc, sn, a, r)
            return new_p, aux["q_err"]

        new_params, q_errs = jax.lax.scan(
            step, params, (sa_cur, sa_next, actions, rewards))
        return (*new_params, q_errs)

    return batch
