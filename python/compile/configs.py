"""Experiment configurations shared by L1 kernels, L2 models and aot.py.

Mirrors the paper's four architecture/environment combinations (Section 5):

* simple environment  — state+action vector D = 6 (4 state dims + 2 action
  dims), A = 6 actions per state.
* complex environment — D = 20, A = 40, |S| = 1800.
* perceptron — single neuron (D -> 1).
* MLP        — one hidden layer of 4 neurons (D -> 4 -> 1); 11 total "neurons"
  simple (6+4+1), 25 complex (20+4+1), matching the paper's counts.

The rust side (rust/src/config.rs) carries the same presets; the AOT manifest
(artifacts/manifest.json) is the contract between the two.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

HIDDEN = 4  # paper: "4 hidden layer neurons"


@dataclasses.dataclass(frozen=True)
class FixedSpec:
    """Qm.n fixed point: `word` total bits (incl. sign), `frac` fraction bits.

    Default Q(18,12): 18-bit words feed the DSP48E1 18x25 multiplier directly
    (see DESIGN.md section 7.2); 12 fraction bits keep sigmoid-LUT quantization
    below the LSB of the table.
    """

    word: int = 18
    frac: int = 12

    @property
    def qmax(self) -> int:
        return (1 << (self.word - 1)) - 1

    @property
    def qmin(self) -> int:
        return -(1 << (self.word - 1))

    @property
    def scale(self) -> float:
        return float(1 << self.frac)


@dataclasses.dataclass(frozen=True)
class LutSpec:
    """Sigmoid ROM: `size` entries sampled uniformly over [-xmax, xmax].

    Mirrors the paper's look-up-table activation (Section 3): inputs are
    clipped to the table range and mapped to the nearest entry.
    """

    size: int = 1024
    xmax: float = 8.0


@dataclasses.dataclass(frozen=True)
class Hyper:
    """Q-learning hyper-parameters (paper Eq. 4, 8, 9)."""

    alpha: float = 0.5  # Q-error scaling (Eq. 8)
    gamma: float = 0.9  # discount
    lr: float = 0.25    # C, the backprop learning factor (Eq. 9/13)


@dataclasses.dataclass(frozen=True)
class NetConfig:
    """One paper configuration: architecture x environment."""

    name: str
    arch: str  # "perceptron" | "mlp"
    env: str   # "simple" | "complex"
    d: int     # state+action vector width
    h: int     # hidden neurons (0 for perceptron)
    a: int     # actions per state

    @property
    def n_params(self) -> int:
        if self.arch == "perceptron":
            return self.d + 1
        return self.d * self.h + self.h + self.h + 1


SIMPLE = dict(env="simple", d=6, a=6)
COMPLEX = dict(env="complex", d=20, a=40)

CONFIGS = {
    "perceptron_simple": NetConfig(name="perceptron_simple", arch="perceptron", h=0, **SIMPLE),
    "perceptron_complex": NetConfig(name="perceptron_complex", arch="perceptron", h=0, **COMPLEX),
    "mlp_simple": NetConfig(name="mlp_simple", arch="mlp", h=HIDDEN, **SIMPLE),
    "mlp_complex": NetConfig(name="mlp_complex", arch="mlp", h=HIDDEN, **COMPLEX),
}

PRECISIONS = ("float", "fixed")

DEFAULT_FIXED = FixedSpec()
DEFAULT_LUT = LutSpec()
DEFAULT_HYPER = Hyper()

# Batched-training artifact: one XLA call applies this many sequential
# Q-updates (lax.scan) — amortizes PJRT dispatch on the rust hot path.
SCAN_BATCH = 16


@dataclasses.dataclass(frozen=True)
class ArtifactSpec:
    """Fully-resolved description of one AOT artifact."""

    net: NetConfig
    precision: str                 # "float" | "fixed"
    kind: str                      # "forward" | "qupdate" | "train_batch"
    fixed: Optional[FixedSpec]
    lut: LutSpec
    hyper: Hyper
    batch: int = 1

    @property
    def name(self) -> str:
        return f"{self.net.name}_{self.precision}_{self.kind}"


def all_artifacts(kinds=("forward", "qupdate", "train_batch")) -> list[ArtifactSpec]:
    specs = []
    for net in CONFIGS.values():
        for prec in PRECISIONS:
            fixed = DEFAULT_FIXED if prec == "fixed" else None
            for kind in kinds:
                specs.append(
                    ArtifactSpec(
                        net=net,
                        precision=prec,
                        kind=kind,
                        fixed=fixed,
                        lut=DEFAULT_LUT,
                        hyper=DEFAULT_HYPER,
                        batch=SCAN_BATCH if kind == "train_batch" else 1,
                    )
                )
    return specs
