"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text parser on the
rust side (HloModuleProto::from_text_file) reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --outdir ../artifacts

Writes one `<name>.hlo.txt` per artifact plus `manifest.json`, the contract
consumed by rust/src/runtime/artifact.rs (shapes, dtypes, argument order,
hyper-parameters, fixed-point format, LUT spec).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import jax

from jax._src.lib import xla_client as xc

from . import model
from .configs import ArtifactSpec, all_artifacts


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unpacks a tuple, even for single results).

    Two printer options are load-bearing for the xla_extension 0.5.1 parser
    on the rust side:

    * ``print_large_constants=True`` — the default printer elides arrays
      above a size threshold as ``constant({...})``, and the old parser
      silently fills such constants with garbage. Our sigmoid/derivative
      ROMs are 1024-entry constants, so they MUST be printed in full.
    * ``print_metadata=False`` — jax >= 0.8 emits ``source_end_line`` etc.
      in op metadata, attributes the 0.5.1 text parser rejects outright.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _shape_entry(name: str, s: jax.ShapeDtypeStruct) -> dict:
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


def lower_artifact(spec: ArtifactSpec, outdir: pathlib.Path) -> dict:
    fn = model.build_fn(spec)
    in_specs = model.input_specs(spec)
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    fname = f"{spec.name}.hlo.txt"
    (outdir / fname).write_text(text)

    out_shapes = jax.eval_shape(fn, *in_specs)
    entry = {
        "file": fname,
        "kind": spec.kind,
        "arch": spec.net.arch,
        "env": spec.net.env,
        "precision": spec.precision,
        "d": spec.net.d,
        "h": spec.net.h,
        "a": spec.net.a,
        "batch": spec.batch,
        "hyper": dataclasses.asdict(spec.hyper),
        "fixed": dataclasses.asdict(spec.fixed) if spec.fixed else None,
        "lut": dataclasses.asdict(spec.lut),
        "inputs": [_shape_entry(n, s)
                   for n, s in zip(model.input_names(spec), in_specs)],
        "outputs": [_shape_entry(n, s)
                    for n, s in zip(model.output_names(spec), out_shapes)],
    }
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts",
                    help="directory for *.hlo.txt + manifest.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name substrings to build")
    args = ap.parse_args()

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    specs = all_artifacts()
    if args.only:
        keys = args.only.split(",")
        specs = [s for s in specs if any(k in s.name for k in keys)]

    manifest = {"version": 1, "artifacts": {}}
    for spec in specs:
        entry = lower_artifact(spec, outdir)
        manifest["artifacts"][spec.name] = entry
        print(f"  wrote {entry['file']:45s} "
              f"({len(entry['inputs'])} in / {len(entry['outputs'])} out)")

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts "
          f"to {outdir / 'manifest.json'}")


if __name__ == "__main__":
    main()
