# pytest: kernel vs ref allclose — the CORE correctness signal.
"""Hypothesis sweeps: the Pallas kernels must match ref.py for *arbitrary*
shapes (A, D, H), precisions and transitions, not just the four paper
configurations."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property sweeps need hypothesis; offline images skip
from hypothesis import given, settings, strategies as st

from compile.configs import (
    DEFAULT_FIXED,
    DEFAULT_HYPER,
    DEFAULT_LUT,
    FixedSpec,
    Hyper,
    LutSpec,
    NetConfig,
)
from compile.kernels import qnet, ref

ATOL = 1e-6


def _cfg(arch, d, h, a):
    return NetConfig(name=f"hyp_{arch}_{d}_{h}_{a}", arch=arch,
                     env="hyp", d=d, h=h, a=a)


def _rand_params(cfg, rng):
    if cfg.arch == "perceptron":
        return (rng.uniform(-1, 1, (cfg.d, 1)).astype(np.float32),
                rng.uniform(-1, 1, (1,)).astype(np.float32))
    return (rng.uniform(-1, 1, (cfg.d, cfg.h)).astype(np.float32),
            rng.uniform(-1, 1, (cfg.h,)).astype(np.float32),
            rng.uniform(-1, 1, (cfg.h, 1)).astype(np.float32),
            rng.uniform(-1, 1, (1,)).astype(np.float32))


arch_st = st.sampled_from(["perceptron", "mlp"])
dim_st = st.integers(min_value=1, max_value=32)
hid_st = st.integers(min_value=1, max_value=8)
act_st = st.integers(min_value=1, max_value=48)
seed_st = st.integers(min_value=0, max_value=2**31 - 1)
prec_st = st.sampled_from([None, DEFAULT_FIXED, FixedSpec(word=16, frac=8)])
lut_st = st.sampled_from([None, DEFAULT_LUT, LutSpec(size=128, xmax=4.0)])


@given(arch=arch_st, d=dim_st, h=hid_st, a=act_st, seed=seed_st,
       fixed=prec_st, lut=lut_st)
@settings(max_examples=40, deadline=None)
def test_forward_shape_sweep(arch, d, h, a, seed, fixed, lut):
    cfg = _cfg(arch, d, h, a)
    rng = np.random.default_rng(seed)
    params = _rand_params(cfg, rng)
    sa = rng.uniform(-2, 2, (a, d)).astype(np.float32)

    fwd = qnet.make_forward(cfg, fixed=fixed, lut=lut, a=a)
    got = np.asarray(fwd(params, sa))
    want = np.asarray(ref.forward(cfg, params, sa, fixed=fixed, lut=lut))
    assert got.shape == (a,)
    np.testing.assert_allclose(got, want, atol=ATOL)


@given(arch=arch_st, d=dim_st, h=hid_st, a=act_st, seed=seed_st,
       fixed=prec_st,
       alpha=st.floats(0.0, 1.0), gamma=st.floats(0.0, 1.0),
       lr=st.floats(0.0, 1.0))
@settings(max_examples=40, deadline=None)
def test_qupdate_shape_and_hyper_sweep(arch, d, h, a, seed, fixed,
                                       alpha, gamma, lr):
    cfg = _cfg(arch, d, h, a)
    hyper = Hyper(alpha=np.float32(alpha), gamma=np.float32(gamma),
                  lr=np.float32(lr))
    rng = np.random.default_rng(seed)
    params = _rand_params(cfg, rng)
    sa_cur = rng.uniform(-2, 2, (a, d)).astype(np.float32)
    sa_next = rng.uniform(-2, 2, (a, d)).astype(np.float32)
    action = np.int32(rng.integers(0, a))
    reward = np.float32(rng.uniform(-2, 2))

    upd = qnet.make_qupdate(cfg, hyper, fixed=fixed, lut=DEFAULT_LUT, a=a)
    new_p, q_cur, q_next, q_err = upd(params, sa_cur, sa_next, action, reward)
    want_p, aux = ref.qupdate(cfg, params, sa_cur, sa_next, action, reward,
                              hyper, fixed=fixed, lut=DEFAULT_LUT)

    for got_w, want_w in zip(new_p, want_p):
        np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                                   atol=ATOL)
    np.testing.assert_allclose(np.asarray(q_cur), np.asarray(aux["q_cur"]),
                               atol=ATOL)
    np.testing.assert_allclose(np.asarray(q_next), np.asarray(aux["q_next"]),
                               atol=ATOL)
    np.testing.assert_allclose(float(q_err), float(aux["q_err"]), atol=ATOL)


@given(arch=arch_st, seed=seed_st)
@settings(max_examples=10, deadline=None)
def test_qupdate_is_pure(arch, seed):
    """Two invocations with identical inputs give identical outputs — no
    hidden state in the kernel wrapper."""
    cfg = _cfg(arch, 6, 4, 6)
    rng = np.random.default_rng(seed)
    params = _rand_params(cfg, rng)
    t = ref.random_transition(cfg, rng)
    upd = qnet.make_qupdate(cfg, DEFAULT_HYPER)
    p1, _, _, e1 = upd(params, *t)
    p2, _, _, e2 = upd(params, *t)
    assert float(e1) == float(e2)
    for a_, b_ in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(a_), np.asarray(b_))
