"""L2 artifact-graph tests: build_fn vs reference_fn for every artifact,
manifest consistency, and scan-batch semantics (a train_batch call must equal
`batch` sequential qupdate calls)."""

import json
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.configs import SCAN_BATCH, all_artifacts
from compile.kernels import ref

ATOL = 1e-6

SPECS = all_artifacts()
SPEC_IDS = [s.name for s in SPECS]


def _example_inputs(spec, rng, key):
    cfg = spec.net
    params = [np.asarray(p) for p in ref.init_params(cfg, key)]
    if spec.kind == "forward":
        sa = rng.uniform(-1, 1, (cfg.a, cfg.d)).astype(np.float32)
        return [*params, sa]
    if spec.kind == "qupdate":
        sa_cur, sa_next, action, reward = ref.random_transition(cfg, rng)
        return [*params, sa_cur, sa_next,
                np.asarray([action], np.int32),
                np.asarray([reward], np.float32)]
    b = spec.batch
    sa_cur = rng.uniform(-1, 1, (b, cfg.a, cfg.d)).astype(np.float32)
    sa_next = rng.uniform(-1, 1, (b, cfg.a, cfg.d)).astype(np.float32)
    actions = rng.integers(0, cfg.a, (b,)).astype(np.int32)
    rewards = rng.uniform(-1, 1, (b,)).astype(np.float32)
    return [*params, sa_cur, sa_next, actions, rewards]


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_build_fn_matches_reference_fn(spec, rng, key):
    inputs = _example_inputs(spec, rng, key)
    got = model.build_fn(spec)(*inputs)
    want = model.reference_fn(spec)(*inputs)
    assert len(got) == len(want)
    for name, g, w in zip(model.output_names(spec), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=ATOL, err_msg=f"{spec.name}:{name}")


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_shapes_match_declared_specs(spec, rng, key):
    inputs = _example_inputs(spec, rng, key)
    declared_in = model.input_specs(spec)
    assert len(inputs) == len(declared_in)
    for x, s in zip(inputs, declared_in):
        assert tuple(x.shape) == tuple(s.shape)
        assert x.dtype == s.dtype
    outs = model.build_fn(spec)(*inputs)
    declared_out = jax.eval_shape(model.build_fn(spec), *declared_in)
    for o, s in zip(outs, declared_out):
        assert tuple(np.asarray(o).shape) == tuple(s.shape)


@pytest.mark.parametrize(
    "spec",
    [s for s in SPECS if s.kind == "train_batch" and s.net.env == "simple"],
    ids=lambda s: s.name)
def test_train_batch_equals_sequential_qupdates(spec, rng, key):
    """lax.scan over the fused kernel == driving qupdate in a python loop."""
    from compile.kernels import qnet
    inputs = _example_inputs(spec, rng, key)
    n = 2 if spec.net.arch == "perceptron" else 4
    params = tuple(inputs[:n])
    sa_cur, sa_next, actions, rewards = inputs[n:]

    batch_out = model.build_fn(spec)(*inputs)
    batch_params, q_errs = batch_out[:n], batch_out[n]

    upd = qnet.make_qupdate(spec.net, spec.hyper, fixed=spec.fixed,
                            lut=spec.lut)
    p = params
    seq_errs = []
    for i in range(spec.batch):
        p, _, _, e = upd(p, sa_cur[i], sa_next[i], actions[i], rewards[i])
        seq_errs.append(float(e))

    for g, w in zip(batch_params, p):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=ATOL)
    np.testing.assert_allclose(np.asarray(q_errs), seq_errs, atol=ATOL)


class TestManifest:
    """artifacts/manifest.json is the rust contract — validate it whenever
    the artifacts have been built (make artifacts)."""

    @pytest.fixture(scope="class")
    def manifest(self):
        path = pathlib.Path(__file__).parents[2] / "artifacts" / "manifest.json"
        if not path.exists():
            pytest.skip("artifacts not built (run `make artifacts`)")
        return json.loads(path.read_text())

    def test_all_specs_present(self, manifest):
        names = set(manifest["artifacts"])
        assert {s.name for s in SPECS} <= names

    def test_entries_consistent(self, manifest):
        for spec in SPECS:
            e = manifest["artifacts"][spec.name]
            assert e["kind"] == spec.kind
            assert e["a"] == spec.net.a
            assert e["d"] == spec.net.d
            assert [i["name"] for i in e["inputs"]] == \
                list(model.input_names(spec))
            assert [o["name"] for o in e["outputs"]] == \
                list(model.output_names(spec))
            if spec.kind == "train_batch":
                assert e["batch"] == SCAN_BATCH

    def test_hlo_files_exist_and_parse_shapes(self, manifest):
        root = pathlib.Path(__file__).parents[2] / "artifacts"
        for spec in SPECS:
            e = manifest["artifacts"][spec.name]
            text = (root / e["file"]).read_text()
            assert "ENTRY" in text and "HloModule" in text
