"""Shared fixtures for the kernel/model test-suite."""

import sys
from pathlib import Path

import numpy as np
import pytest

import jax

# make `compile.*` importable when pytest is invoked from the repo root
# (CI runs `pytest python/tests`), not just from python/
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from compile.configs import (
    CONFIGS,
    DEFAULT_FIXED,
    DEFAULT_HYPER,
    DEFAULT_LUT,
    FixedSpec,
    LutSpec,
    NetConfig,
)


@pytest.fixture(params=list(CONFIGS.keys()))
def net_cfg(request) -> NetConfig:
    return CONFIGS[request.param]


@pytest.fixture(params=["float", "fixed"])
def precision(request) -> str:
    return request.param


@pytest.fixture
def fixed_spec(precision) -> FixedSpec | None:
    return DEFAULT_FIXED if precision == "fixed" else None


@pytest.fixture(params=["lut", "exact"])
def lut_spec(request) -> LutSpec | None:
    return DEFAULT_LUT if request.param == "lut" else None


@pytest.fixture
def hyper():
    return DEFAULT_HYPER


@pytest.fixture
def rng() -> np.random.Generator:
    return np.random.default_rng(0xC0FFEE)


@pytest.fixture
def key() -> jax.Array:
    return jax.random.PRNGKey(7)
