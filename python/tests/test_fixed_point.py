"""Unit + hypothesis tests for the Qm.n fake-quantization helpers.

The rust implementation (rust/src/fixed/) must follow exactly these
conventions; rust test `fixed::tests::matches_python_convention` pins the
same vectors from VECTORS below.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property sweeps need hypothesis; offline images skip
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.configs import FixedSpec
from compile.kernels import fixed_point as fxp

Q18_12 = FixedSpec(word=18, frac=12)
Q16_8 = FixedSpec(word=16, frac=8)

# Shared convention vectors: (spec, input, expected) — mirrored in rust.
VECTORS = [
    (Q18_12, 0.0, 0.0),
    (Q18_12, 1.0, 1.0),
    (Q18_12, -1.0, -1.0),
    (Q18_12, 0.5, 0.5),
    # round-half-even: 0.5 * 2^12 + 0.5 -> 2048.5 rounds to 2048 (even)
    (Q18_12, (2048.5 / 4096.0), 2048.0 / 4096.0),
    (Q18_12, (2049.5 / 4096.0), 2050.0 / 4096.0),
    # saturation: Q(18,12) max = (2^17 - 1) / 2^12
    (Q18_12, 100.0, (2**17 - 1) / 4096.0),
    (Q18_12, -100.0, -(2**17) / 4096.0),
]


class TestQuantize:
    @pytest.mark.parametrize("spec,x,want", VECTORS)
    def test_vectors(self, spec, x, want):
        got = float(fxp.quantize(jnp.float32(x), spec))
        assert got == pytest.approx(want, abs=1e-9)

    def test_idempotent(self):
        x = jnp.linspace(-3, 3, 101)
        q1 = fxp.quantize(x, Q18_12)
        q2 = fxp.quantize(q1, Q18_12)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))

    def test_grid_membership(self):
        x = jnp.asarray(np.random.default_rng(1).uniform(-20, 20, 1000),
                        dtype=jnp.float32)
        q = np.asarray(fxp.quantize(x, Q18_12))
        scaled = q * Q18_12.scale
        np.testing.assert_array_equal(scaled, np.round(scaled))
        assert scaled.max() <= Q18_12.qmax
        assert scaled.min() >= Q18_12.qmin

    @given(st.floats(min_value=-1e6, max_value=1e6,
                     allow_nan=False, allow_infinity=False))
    @settings(max_examples=200, deadline=None)
    def test_error_bound_or_saturated(self, x):
        spec = Q18_12
        q = float(fxp.quantize(jnp.float32(x), spec))
        lsb = 1.0 / spec.scale
        xf = float(jnp.float32(x))
        if spec.qmin / spec.scale <= xf <= spec.qmax / spec.scale:
            assert abs(q - xf) <= 0.5 * lsb + abs(xf) * 1e-6
        else:
            assert q in (spec.qmin / spec.scale, spec.qmax / spec.scale)

    @given(st.integers(min_value=-(2**17), max_value=2**17 - 1))
    @settings(max_examples=200, deadline=None)
    def test_representable_values_are_fixpoints(self, k):
        x = jnp.float32(k / Q18_12.scale)
        q = float(fxp.quantize(x, Q18_12))
        assert q == float(x)


class TestOps:
    def test_qmul_single_rounding(self):
        a = fxp.quantize(jnp.float32(0.3), Q18_12)
        b = fxp.quantize(jnp.float32(0.7), Q18_12)
        got = float(fxp.qmul(a, b, Q18_12))
        want = float(fxp.quantize(a * b, Q18_12))
        assert got == want

    def test_qdot_wide_accumulator(self):
        """qdot rounds once at the end (DSP48 accumulator), which differs
        from rounding every partial sum."""
        rng = np.random.default_rng(3)
        x = fxp.quantize(jnp.asarray(rng.uniform(-1, 1, (1, 16)), jnp.float32),
                         Q18_12)
        w = fxp.quantize(jnp.asarray(rng.uniform(-1, 1, (16, 1)), jnp.float32),
                         Q18_12)
        got = float(fxp.qdot(x, w, Q18_12)[0, 0])
        want = float(fxp.quantize(jnp.matmul(x, w), Q18_12)[0, 0])
        assert got == want

    @given(st.lists(st.floats(-2, 2), min_size=1, max_size=32))
    @settings(max_examples=100, deadline=None)
    def test_qadd_commutative(self, xs):
        a = fxp.quantize(jnp.asarray(xs, jnp.float32), Q18_12)
        b = fxp.quantize(jnp.asarray(xs[::-1], jnp.float32), Q18_12)
        ab = np.asarray(fxp.qadd(a, b, Q18_12))
        ba = np.asarray(fxp.qadd(b, a, Q18_12))
        np.testing.assert_array_equal(ab, ba)


class TestSpecProperties:
    def test_qmax_qmin(self):
        assert Q18_12.qmax == 131071
        assert Q18_12.qmin == -131072
        assert Q18_12.scale == 4096.0

    @pytest.mark.parametrize("word,frac", [(8, 4), (16, 8), (18, 12),
                                           (24, 16), (32, 24)])
    def test_range_monotone_in_word(self, word, frac):
        s = FixedSpec(word=word, frac=frac)
        assert s.qmax / s.scale > 0
        assert s.qmin / s.scale < 0
        assert s.qmax == -s.qmin - 1
