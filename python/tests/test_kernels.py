"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Every (architecture, environment, precision, activation) combination is
checked: feed-forward and the fused Q-update must match ref.py exactly
(same op chain -> bitwise-identical float32 in interpret mode; we assert to
1e-6 to stay robust against benign reassociation).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.configs import DEFAULT_HYPER
from compile.kernels import qnet, ref

ATOL = 1e-6


def _params(net_cfg, key):
    return ref.init_params(net_cfg, key)


class TestForward:
    def test_matches_ref(self, net_cfg, fixed_spec, lut_spec, key, rng):
        params = _params(net_cfg, key)
        sa = rng.uniform(-1, 1, (net_cfg.a, net_cfg.d)).astype(np.float32)

        fwd = qnet.make_forward(net_cfg, fixed=fixed_spec, lut=lut_spec)
        got = np.asarray(fwd(params, sa))
        want = np.asarray(ref.forward(net_cfg, params, sa,
                                      fixed=fixed_spec, lut=lut_spec))
        np.testing.assert_allclose(got, want, atol=ATOL)

    def test_output_range_is_sigmoid(self, net_cfg, fixed_spec, lut_spec,
                                     key, rng):
        params = _params(net_cfg, key)
        sa = rng.uniform(-4, 4, (net_cfg.a, net_cfg.d)).astype(np.float32)
        fwd = qnet.make_forward(net_cfg, fixed=fixed_spec, lut=lut_spec)
        q = np.asarray(fwd(params, sa))
        assert q.shape == (net_cfg.a,)
        assert np.all(q >= 0.0) and np.all(q <= 1.0)

    def test_jit_compatible(self, net_cfg, key, rng):
        params = _params(net_cfg, key)
        sa = rng.uniform(-1, 1, (net_cfg.a, net_cfg.d)).astype(np.float32)
        fwd = jax.jit(qnet.make_forward(net_cfg))
        got = np.asarray(fwd(params, jnp.asarray(sa)))
        want = np.asarray(ref.forward(net_cfg, params, sa))
        np.testing.assert_allclose(got, want, atol=ATOL)


class TestQUpdate:
    def test_matches_ref(self, net_cfg, fixed_spec, lut_spec, key, rng):
        params = _params(net_cfg, key)
        sa_cur, sa_next, action, reward = ref.random_transition(net_cfg, rng)

        upd = qnet.make_qupdate(net_cfg, DEFAULT_HYPER,
                                fixed=fixed_spec, lut=lut_spec)
        new_p, q_cur, q_next, q_err = upd(params, sa_cur, sa_next,
                                          action, reward)
        want_p, aux = ref.qupdate(net_cfg, params, sa_cur, sa_next,
                                  action, reward, DEFAULT_HYPER,
                                  fixed=fixed_spec, lut=lut_spec)

        for got_w, want_w in zip(new_p, want_p):
            np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                                       atol=ATOL)
        np.testing.assert_allclose(np.asarray(q_cur), np.asarray(aux["q_cur"]),
                                   atol=ATOL)
        np.testing.assert_allclose(np.asarray(q_next), np.asarray(aux["q_next"]),
                                   atol=ATOL)
        np.testing.assert_allclose(float(q_err), float(aux["q_err"]), atol=ATOL)

    def test_only_taken_action_row_changes_perceptron_sign(self, key, rng):
        """Weight update direction must follow the Q-error sign (Eq. 9/10)."""
        from compile.configs import CONFIGS
        cfg = CONFIGS["perceptron_simple"]
        params = _params(cfg, key)
        sa_cur, sa_next, action, _ = ref.random_transition(cfg, rng)
        sa_cur = np.abs(sa_cur)  # positive inputs -> dW sign == delta sign

        upd = qnet.make_qupdate(cfg, DEFAULT_HYPER)
        # Large positive reward -> positive error -> weights move up.
        _, _, _, e_pos = upd(params, sa_cur, sa_next, action, np.float32(5.0))
        new_p, _, _, e_neg = upd(params, sa_cur, sa_next, action,
                                 np.float32(-5.0))
        assert float(e_pos) > 0
        assert float(e_neg) < 0
        w_new = np.asarray(new_p[0])[:, 0]
        w_old = np.asarray(params[0])[:, 0]
        # negative error with positive inputs moves weights down
        assert np.all(w_new <= w_old + ATOL)

    def test_repeated_updates_reduce_qerror(self, net_cfg, key, rng):
        """Driving the same transition repeatedly must shrink |Q_error| —
        the learning loop actually learns (paper Section 2 state-flow).

        gamma=0 makes the target stationary (pure r), and a small init keeps
        the sigmoid out of its saturated tails so the perceptron can move."""
        from compile.configs import Hyper
        params = _params(net_cfg, key)
        params = tuple(0.2 * np.asarray(p) for p in params)
        sa_cur, sa_next, action, _ = ref.random_transition(net_cfg, rng)
        reward = np.float32(0.8)
        hyper = Hyper(alpha=1.0, gamma=0.0, lr=0.5)
        upd = jax.jit(qnet.make_qupdate(net_cfg, hyper))

        errs = []
        for _ in range(150):
            params, _, _, q_err = upd(params, sa_cur, sa_next, action, reward)
            errs.append(abs(float(q_err)))
        assert errs[-1] < errs[0] * 0.5, errs[:5] + errs[-5:]

    def test_zero_alpha_freezes_learning(self, net_cfg, key, rng):
        """alpha = 0 -> Q never updates (paper Section 2 remark)."""
        from compile.configs import Hyper
        params = _params(net_cfg, key)
        sa_cur, sa_next, action, reward = ref.random_transition(net_cfg, rng)
        upd = qnet.make_qupdate(net_cfg, Hyper(alpha=0.0, gamma=0.9, lr=0.25))
        new_p, _, _, q_err = upd(params, sa_cur, sa_next, action, reward)
        assert float(q_err) == 0.0
        for got_w, old_w in zip(new_p, params):
            np.testing.assert_array_equal(np.asarray(got_w), np.asarray(old_w))


class TestFixedVsFloat:
    def test_fixed_tracks_float_within_lsb_budget(self, net_cfg, key, rng):
        """Q(18,12) forward must track float within a small multiple of the
        LSB for these tiny nets (paper Section 5: word length trades accuracy
        for power)."""
        from compile.configs import DEFAULT_FIXED
        params = _params(net_cfg, key)
        sa = rng.uniform(-1, 1, (net_cfg.a, net_cfg.d)).astype(np.float32)
        f = qnet.make_forward(net_cfg)
        g = qnet.make_forward(net_cfg, fixed=DEFAULT_FIXED)
        qf = np.asarray(f(params, sa))
        qx = np.asarray(g(params, sa))
        lsb = 1.0 / DEFAULT_FIXED.scale
        # error accumulates over D MACs + 2 activations; budget is generous
        assert np.max(np.abs(qf - qx)) < 64 * lsb
