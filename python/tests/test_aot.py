"""AOT lowering tests: the HLO-text emitter and manifest writer.

These guard the rust↔python contract at the source: the two
xla_extension-0.5.1 parser hazards (elided large constants, new metadata
attributes) and the manifest schema.
"""

import json
import pathlib
import subprocess
import sys

import jax
import pytest

from compile import aot, model
from compile.configs import all_artifacts


@pytest.fixture(scope="module")
def lowered_entry(tmp_path_factory):
    outdir = tmp_path_factory.mktemp("artifacts")
    spec = next(s for s in all_artifacts()
                if s.name == "perceptron_simple_float_forward")
    entry = aot.lower_artifact(spec, outdir)
    return outdir, spec, entry


class TestHloText:
    def test_no_elided_constants(self, lowered_entry):
        outdir, _, entry = lowered_entry
        text = (outdir / entry["file"]).read_text()
        assert "constant({...})" not in text, \
            "elided constants execute as garbage under xla_extension 0.5.1"

    def test_no_new_metadata_attributes(self, lowered_entry):
        outdir, _, entry = lowered_entry
        text = (outdir / entry["file"]).read_text()
        assert "source_end_line" not in text, \
            "jax>=0.8 metadata breaks the 0.5.1 text parser"

    def test_entry_computation_present(self, lowered_entry):
        outdir, _, entry = lowered_entry
        text = (outdir / entry["file"]).read_text()
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_rom_constant_printed_in_full(self, lowered_entry):
        # the sigmoid ROM is a 1024-entry f32 constant; it must appear with
        # its values, i.e. at least ~1000 commas inside a constant(...)
        outdir, _, entry = lowered_entry
        text = (outdir / entry["file"]).read_text()
        line = next(l for l in text.splitlines() if "f32[1024]" in l and "constant" in l)
        assert line.count(",") > 1000


class TestManifestEntry:
    def test_entry_schema(self, lowered_entry):
        _, spec, entry = lowered_entry
        assert entry["kind"] == "forward"
        assert entry["arch"] == "perceptron"
        assert entry["precision"] == "float"
        assert entry["a"] == spec.net.a and entry["d"] == spec.net.d
        assert [i["name"] for i in entry["inputs"]] == ["w", "b", "sa"]
        assert entry["inputs"][2]["shape"] == [spec.net.a, spec.net.d]
        assert entry["outputs"][0]["name"] == "q"
        assert entry["hyper"]["gamma"] == pytest.approx(0.9)

    def test_all_specs_enumerate_24(self):
        specs = all_artifacts()
        assert len(specs) == 24
        names = {s.name for s in specs}
        assert len(names) == 24  # unique

    def test_input_specs_match_build_fn_arity(self):
        for spec in all_artifacts():
            fn = model.build_fn(spec)
            ins = model.input_specs(spec)
            # eval_shape both validates arity and avoids running the kernel
            outs = jax.eval_shape(fn, *ins)
            assert len(outs) == len(model.output_names(spec)), spec.name


class TestCliEndToEnd:
    def test_only_filter_builds_subset(self, tmp_path):
        subprocess.run(
            [sys.executable, "-m", "compile.aot",
             "--outdir", str(tmp_path), "--only", "perceptron_simple_fixed_forward"],
            check=True,
            cwd=pathlib.Path(__file__).parents[1],
        )
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert list(manifest["artifacts"]) == ["perceptron_simple_fixed_forward"]
        entry = manifest["artifacts"]["perceptron_simple_fixed_forward"]
        assert (tmp_path / entry["file"]).exists()
        assert entry["fixed"] == {"word": 18, "frac": 12}
