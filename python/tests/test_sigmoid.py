"""Sigmoid ROM (LUT) tests — accuracy vs table size, the paper's Section 3
remark: "The size of ROM plays a major role in the accuracy of the output
value."  The X2 ablation (EXPERIMENTS.md) uses the same sweep.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property sweeps need hypothesis; offline images skip
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.configs import LutSpec
from compile.kernels import sigmoid as sg


class TestTables:
    def test_table_endpoints(self):
        lut = LutSpec(size=1024, xmax=8.0)
        t = sg.build_sigmoid_table(lut)
        assert t.shape == (1024,)
        assert t[0] == pytest.approx(1 / (1 + np.exp(8.0)), abs=1e-6)
        assert t[-1] == pytest.approx(1 / (1 + np.exp(-8.0)), abs=1e-6)

    def test_table_monotone(self):
        t = sg.build_sigmoid_table(LutSpec(size=512, xmax=6.0))
        assert np.all(np.diff(t) > 0)

    def test_deriv_table_peak_at_center(self):
        lut = LutSpec(size=1025, xmax=8.0)  # odd -> exact center sample
        d = sg.build_deriv_table(lut)
        assert np.argmax(d) == 512
        assert d[512] == pytest.approx(0.25, abs=1e-6)

    def test_deriv_symmetric(self):
        d = sg.build_deriv_table(LutSpec(size=1024, xmax=8.0))
        np.testing.assert_allclose(d, d[::-1], atol=1e-7)


class TestLookup:
    def test_exact_at_grid_points(self):
        lut = LutSpec(size=257, xmax=4.0)
        t = jnp.asarray(sg.build_sigmoid_table(lut))
        grid = jnp.linspace(-4.0, 4.0, 257)
        got = np.asarray(sg.lut_lookup(t, grid, lut))
        np.testing.assert_allclose(got, np.asarray(t), atol=1e-7)

    def test_clipping_beyond_range(self):
        lut = LutSpec(size=64, xmax=2.0)
        t = jnp.asarray(sg.build_sigmoid_table(lut))
        lo = float(sg.lut_lookup(t, jnp.float32(-100.0), lut))
        hi = float(sg.lut_lookup(t, jnp.float32(100.0), lut))
        assert lo == pytest.approx(float(t[0]))
        assert hi == pytest.approx(float(t[-1]))

    @pytest.mark.parametrize("size,budget", [(64, 0.07), (256, 0.02),
                                             (1024, 0.006), (4096, 0.0025)])
    def test_accuracy_improves_with_rom_size(self, size, budget):
        """X2 ablation shape: max |LUT - exact| shrinks as ROM grows."""
        lut = LutSpec(size=size, xmax=8.0)
        t = jnp.asarray(sg.build_sigmoid_table(lut))
        x = jnp.linspace(-8.0, 8.0, 10_001)
        approx = np.asarray(sg.lut_lookup(t, x, lut))
        exact = np.asarray(sg.sigmoid_exact(x))
        assert np.max(np.abs(approx - exact)) < budget

    @given(st.floats(min_value=-50, max_value=50,
                     allow_nan=False, allow_infinity=False))
    @settings(max_examples=200, deadline=None)
    def test_lookup_within_half_step(self, x):
        """Nearest-entry lookup error <= sigmoid'(x)*step/2 + table quant."""
        lut = LutSpec(size=2048, xmax=8.0)
        t = jnp.asarray(sg.build_sigmoid_table(lut))
        got = float(sg.lut_lookup(t, jnp.float32(x), lut))
        xc = float(np.clip(x, -8.0, 8.0))
        step = 16.0 / 2047
        # worst-case slope of sigmoid is 1/4
        assert abs(got - 1 / (1 + np.exp(-xc))) <= 0.25 * step / 2 + 1e-5

    @given(st.floats(-8, 8), st.floats(-8, 8))
    @settings(max_examples=100, deadline=None)
    def test_lookup_monotone(self, a, b):
        lut = LutSpec(size=512, xmax=8.0)
        t = jnp.asarray(sg.build_sigmoid_table(lut))
        fa = float(sg.lut_lookup(t, jnp.float32(a), lut))
        fb = float(sg.lut_lookup(t, jnp.float32(b), lut))
        if a <= b:
            assert fa <= fb + 1e-9
        else:
            assert fb <= fa + 1e-9

    def test_index_int32(self):
        lut = LutSpec(size=1024, xmax=8.0)
        idx = sg.lut_index(jnp.asarray([-9.0, 0.0, 9.0]), lut)
        assert idx.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(idx), [0, 512, 1023])
