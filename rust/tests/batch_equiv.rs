//! Batched-update conformance suite: for every backend and precision, the
//! `update_batch` fast path must agree with the step-by-step path on
//! identical seeded transition streams — **bit-exact in fixed point, within
//! 1e-5 in float** — and the CPU and FPGA-sim batch paths must agree with
//! each other within the established cross-engine budgets.
//!
//! This is the contract that makes the batched throughput numbers honest:
//! batching amortizes overhead, it must never change the learning
//! trajectory. XLA-backed checks live at the end and skip silently when
//! `artifacts/` has not been built (run `make artifacts` for full coverage).

use qfpga::config::{NetConfig, Precision};
use qfpga::coordinator::sweep::Workload;
use qfpga::experiment::{AnyBackend, BackendFactory, BackendSpec};
use qfpga::fixed::FixedSpec;
use qfpga::nn::params::QNetParams;
use qfpga::qlearn::backend::QBackend;
use qfpga::runtime::Runtime;
use qfpga::util::Rng;

/// All backends are built through the factory — the only construction path
/// since the experiment-API redesign.
fn cpu(net: NetConfig, prec: Precision, params: QNetParams) -> AnyBackend {
    BackendFactory::offline()
        .build(&BackendSpec::cpu(net, prec), params)
        .expect("cpu backend")
}

fn sim(net: NetConfig, prec: Precision, params: QNetParams) -> AnyBackend {
    BackendFactory::offline()
        .build(&BackendSpec::fpga_sim(net, prec), params)
        .expect("fpga-sim backend")
}

/// Batch-vs-stepwise tolerance per precision: the fixed, int8 and binary
/// datapaths are fully deterministic integer/fake-quant math, so the batch
/// path must reproduce them to the bit; float gets the conventional 1e-5
/// budget.
fn batch_tol(prec: Precision) -> f32 {
    match prec {
        Precision::Fixed | Precision::Int8 | Precision::Binary => 0.0,
        Precision::Float => 1e-5,
    }
}

fn seeded_stream(net: NetConfig, n: usize, seed: u64) -> (QNetParams, Workload) {
    let mut rng = Rng::seeded(seed);
    let params = QNetParams::init(&net, 0.35, &mut rng);
    (params, Workload::synthetic(net, n, seed ^ 0x5EED))
}

/// Drive `backend` stepwise through the first `n` workload transitions.
fn stepwise_errs<B: QBackend>(backend: &mut B, w: &Workload, n: usize) -> Vec<f32> {
    let step = w.net.a * w.net.d;
    (0..n)
        .map(|i| {
            backend
                .update(
                    &w.sa_cur[i * step..(i + 1) * step],
                    &w.sa_next[i * step..(i + 1) * step],
                    w.actions[i],
                    w.rewards[i],
                )
                .expect("stepwise update")
        })
        .collect()
}

fn assert_stream_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol,
            "{ctx}: q_err[{i}] {g} vs {w} (tol {tol})"
        );
    }
}

// ------------------------------------------------- batch == stepwise, CPU

#[test]
fn cpu_batch_equals_stepwise_all_configs_and_precisions() {
    let n = 24;
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let (params, w) = seeded_stream(net, n, 1001);
            let mut stepwise = cpu(net, prec, params.clone());
            let mut batched = cpu(net, prec, params);

            let want = stepwise_errs(&mut stepwise, &w, n);
            let got = batched.update_batch(&w.flat_batch(0, n)).unwrap();

            let ctx = format!("cpu {}/{}", net.name(), prec.as_str());
            assert_stream_close(&got, &want, batch_tol(prec), &ctx);
            assert!(
                batched.params().max_abs_diff(&stepwise.params()) <= batch_tol(prec),
                "{ctx}: params diverged by {}",
                batched.params().max_abs_diff(&stepwise.params())
            );
        }
    }
}

// -------------------------------------------- batch == stepwise, FPGA sim

#[test]
fn fpga_sim_batch_equals_stepwise_all_configs_and_precisions() {
    let n = 16;
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let (params, w) = seeded_stream(net, n, 2002);
            let mut stepwise = sim(net, prec, params.clone());
            let mut batched = sim(net, prec, params);

            let want = stepwise_errs(&mut stepwise, &w, n);
            let got = batched.update_batch(&w.flat_batch(0, n)).unwrap();

            let ctx = format!("fpga-sim {}/{}", net.name(), prec.as_str());
            // same engine underneath: exact in both precisions
            assert_stream_close(&got, &want, 0.0, &ctx);
            assert_eq!(
                batched.params().max_abs_diff(&stepwise.params()),
                0.0,
                "{ctx}: params diverged"
            );
        }
    }
}

// ------------------------------------------------- cross-engine agreement

/// CPU fake-quant vs FPGA integer datapath, both through their *batch*
/// paths, over a stream. Float and binary delegate to the identical nn op
/// chain on both engines (equal to the bit — binary asserted at exactly 0,
/// float at 1e-5 per the contract); fixed and int8 diverge by a bounded
/// number of LSBs of their respective grids per step (integer accumulators
/// round once where the fake-quant path rounds in f32), so those budgets
/// grow linearly with the stream position.
#[test]
fn cpu_and_fpga_sim_batch_paths_agree() {
    let n = 12;
    let lsb = FixedSpec::default().lsb() as f32;
    let lsb8 = FixedSpec::int8().lsb() as f32;
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let (params, w) = seeded_stream(net, n, 3003);
            let mut cpu = cpu(net, prec, params.clone());
            let mut sim = sim(net, prec, params);

            let e_cpu = cpu.update_batch(&w.flat_batch(0, n)).unwrap();
            let e_sim = sim.update_batch(&w.flat_batch(0, n)).unwrap();

            let ctx = format!("cpu-vs-sim {}/{}", net.name(), prec.as_str());
            for i in 0..n {
                let tol = match prec {
                    Precision::Float => 1e-5,
                    Precision::Binary => 0.0,
                    Precision::Fixed => 4.0 * lsb * (i as f32 + 1.0),
                    Precision::Int8 => 4.0 * lsb8 * (i as f32 + 1.0),
                };
                assert!(
                    (e_cpu[i] - e_sim[i]).abs() <= tol,
                    "{ctx}: q_err[{i}] {} vs {} (tol {tol})",
                    e_cpu[i],
                    e_sim[i]
                );
            }
            let param_tol = match prec {
                Precision::Float => 1e-5,
                Precision::Binary => 0.0,
                Precision::Fixed => 4.0 * lsb * n as f32,
                Precision::Int8 => 4.0 * lsb8 * n as f32,
            };
            assert!(
                cpu.params().max_abs_diff(&sim.params()) <= param_tol,
                "{ctx}: params diverged by {}",
                cpu.params().max_abs_diff(&sim.params())
            );
        }
    }
}

// --------------------------------------------------- flush-shape coverage

/// Chunked flushes (ragged tails included) must equal one long stepwise
/// stream — the exact shape the learner's episode-end flush produces.
#[test]
fn chunked_flushes_equal_stepwise_stream() {
    let n = 11; // deliberately not a multiple of any chunk size
    for chunk in [1usize, 3, 4, 11] {
        for net in NetConfig::all() {
            for prec in Precision::all() {
                let (params, w) = seeded_stream(net, n, 4004);
                let mut stepwise = cpu(net, prec, params.clone());
                let mut batched = cpu(net, prec, params);

                let want = stepwise_errs(&mut stepwise, &w, n);
                let mut got = Vec::new();
                let mut lo = 0;
                while lo < n {
                    let b = w.flat_batch(lo, chunk);
                    got.extend(batched.update_batch(&b).unwrap());
                    lo += b.len();
                }

                let ctx = format!("chunk={chunk} {}/{}", net.name(), prec.as_str());
                assert_stream_close(&got, &want, batch_tol(prec), &ctx);
                assert!(
                    batched.params().max_abs_diff(&stepwise.params()) <= batch_tol(prec),
                    "{ctx}: params diverged"
                );
            }
        }
    }
}

/// A batch of one must equal a single `update` on every backend.
#[test]
fn batch_of_one_equals_single_update() {
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let (params, w) = seeded_stream(net, 1, 5005);
            let step = net.a * net.d;

            let mut cpu_a = cpu(net, prec, params.clone());
            let mut cpu_b = cpu(net, prec, params.clone());
            let e_single = cpu_a
                .update(&w.sa_cur[..step], &w.sa_next[..step], w.actions[0], w.rewards[0])
                .unwrap();
            let e_batch = cpu_b.update_batch(&w.flat_batch(0, 1)).unwrap();
            assert_eq!(e_batch.len(), 1);
            assert!((e_batch[0] - e_single).abs() <= batch_tol(prec));
            assert!(cpu_b.params().max_abs_diff(&cpu_a.params()) <= batch_tol(prec));

            let mut sim_a = sim(net, prec, params.clone());
            let mut sim_b = sim(net, prec, params);
            let s_single = sim_a
                .update(&w.sa_cur[..step], &w.sa_next[..step], w.actions[0], w.rewards[0])
                .unwrap();
            let s_batch = sim_b.update_batch(&w.flat_batch(0, 1)).unwrap();
            assert_eq!(s_batch[0], s_single);
        }
    }
}

/// Determinism: the same seeded stream through the batch path twice gives
/// identical bits (scratch-buffer reuse must not leak state).
#[test]
fn batch_path_is_deterministic() {
    let n = 10;
    for net in NetConfig::all() {
        let (params, w) = seeded_stream(net, n, 6006);
        let batch = w.flat_batch(0, n);

        let mut a = cpu(net, Precision::Fixed, params.clone());
        let mut b = cpu(net, Precision::Fixed, params);
        // dirty b's scratch with a warm-up flush; a2 gets a fresh scratch at
        // the same parameter state — both then apply the identical batch
        let half = w.flat_batch(0, n / 2);
        a.update_batch(&half).unwrap();
        let mut a2 = cpu(net, Precision::Fixed, a.params());
        let e1 = a2.update_batch(&batch).unwrap();
        b.update_batch(&half).unwrap();
        let e2 = b.update_batch(&batch).unwrap();
        assert_eq!(e1, e2, "{}", net.name());
        assert_eq!(a2.params(), b.params(), "{}", net.name());
    }
}

// ------------------------------------------------------------ XLA backend

fn runtime() -> Option<Runtime> {
    let dir = qfpga::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

/// XLA `update_batch` (scan-chained artifact at its native size, per-step
/// fallback elsewhere) vs the CPU stepwise oracle.
#[test]
fn xla_batch_matches_cpu_stepwise() {
    let Some(rt) = runtime() else { return };
    let factory = BackendFactory::with_runtime(rt);
    for net in NetConfig::all() {
        let prec = Precision::Float;
        let (params, _) = seeded_stream(net, 1, 7007);
        let mut xla = factory
            .build(&BackendSpec::xla(net, prec), params.clone())
            .expect("backend");
        let b = xla.preferred_batch();
        let w = Workload::synthetic(net, b, 7007 ^ 0x5EED);
        let mut cpu = factory
            .build(&BackendSpec::cpu(net, prec).with_hyper(xla.hyper()), params)
            .expect("cpu backend");

        let want = stepwise_errs(&mut cpu, &w, b);
        let got = xla.update_batch(&w.flat_batch(0, b)).unwrap();

        assert_stream_close(&got, &want, 1e-5, &format!("xla {}", net.name()));
        assert!(
            xla.params().max_abs_diff(&cpu.params()) <= 1e-5,
            "{}: params diverged",
            net.name()
        );
    }
}
