//! Fleet worker-pool contract: pool width never changes results, rovers
//! scale past the worker count with ordering and seeds intact, and
//! mid-mission checkpoint/restore reproduces the uninterrupted run
//! bit-exactly (the resumable-`MissionRun` side of the same scheduler).

use qfpga::config::{EnvKind, Precision};
use qfpga::coordinator::{
    run_fleet_with_workers, FleetReport, MissionCheckpoint, MissionConfig, MissionRun,
};
use qfpga::experiment::{BackendFactory, Experiment};
use qfpga::fault::{FaultPlan, Mitigation};
use qfpga::qlearn::backend::BackendKind;
use qfpga::util::Json;

fn quick_cfg() -> MissionConfig {
    MissionConfig {
        episodes: 8,
        max_steps: 40,
        backend: BackendKind::Cpu,
        precision: Precision::Float,
        ..Default::default()
    }
}

/// Per-rover fingerprint strict enough to catch any trajectory change:
/// every episode's (steps, reward bits, ε bits) plus the update count.
fn fingerprint(r: &FleetReport) -> Vec<(String, u64, Vec<(usize, u32, u32)>)> {
    r.rovers
        .iter()
        .map(|m| {
            (
                m.config_desc.clone(),
                m.train.total_updates,
                m.train
                    .episodes
                    .iter()
                    .map(|e| (e.steps, e.total_reward.to_bits(), e.epsilon.to_bits()))
                    .collect(),
            )
        })
        .collect()
}

/// The acceptance contract: 9 rovers on a 2-worker pool reproduce the
/// thread-per-rover output (workers == rovers) and the fully serial pool,
/// with per-rover ordering and seeds identical at every width.
#[test]
fn pool_width_never_changes_fleet_results() {
    let cfg = quick_cfg();
    let n = 9;
    let per_rover = run_fleet_with_workers(&cfg, n, n).unwrap(); // thread-per-rover
    let pooled = run_fleet_with_workers(&cfg, n, 2).unwrap(); // rovers >> workers
    let serial = run_fleet_with_workers(&cfg, n, 1).unwrap();
    let auto = run_fleet_with_workers(&cfg, n, 0).unwrap(); // min(cores, rovers)

    assert_eq!(per_rover.rovers.len(), n);
    assert_eq!(pooled.workers, 2);
    assert!(auto.workers >= 1 && auto.workers <= n);

    let want = fingerprint(&per_rover);
    assert_eq!(fingerprint(&pooled), want, "2-worker pool diverged");
    assert_eq!(fingerprint(&serial), want, "serial pool diverged");
    assert_eq!(fingerprint(&auto), want, "auto pool diverged");

    // seeds really differ per rover: descriptions embed `seed base + i`
    // and trajectories are pairwise distinct
    for i in 0..n {
        assert!(
            want[i].0.contains(&format!("seed {}", cfg.seed + i as u64)),
            "rover {i} seed missing from `{}`",
            want[i].0
        );
    }
    for i in 1..n {
        assert_ne!(want[0].2, want[i].2, "rovers 0 and {i} share a trajectory");
    }
}

#[test]
fn explicit_workers_ride_through_the_builder() {
    let r = Experiment::from_mission(&quick_cfg())
        .rovers(5)
        .workers(3)
        .run()
        .unwrap();
    assert_eq!(r.workers, 3);
    assert_eq!(r.rovers.len(), 5);
    let j = Json::parse(&qfpga::Report::to_json(&r).to_string()).unwrap();
    assert_eq!(j.req_f64("workers").unwrap(), 3.0);
}

/// Mid-mission checkpoint/restore reproduces the uninterrupted run
/// bit-exactly — through the serialized JSON form, on a stochastic
/// scenario environment and on the cycle-accounting FPGA backend.
#[test]
fn checkpoint_restore_reproduces_the_uninterrupted_run() {
    for (backend, precision, env, batch) in [
        (BackendKind::Cpu, Precision::Float, EnvKind::Slip, 1usize),
        (BackendKind::Cpu, Precision::Fixed, EnvKind::Simple, 4),
        (BackendKind::FpgaSim, Precision::Fixed, EnvKind::Simple, 1),
        // the sub-8-bit kernel arms: same bit-exact resume contract
        (BackendKind::Cpu, Precision::Int8, EnvKind::Simple, 1),
        (BackendKind::FpgaSim, Precision::Binary, EnvKind::Simple, 4),
    ] {
        let cfg = MissionConfig {
            episodes: 10,
            max_steps: 30,
            backend,
            precision,
            env,
            batch,
            ..Default::default()
        };
        let factory = BackendFactory::for_kind(cfg.backend).unwrap();

        // uninterrupted reference
        let mut full = MissionRun::new(&cfg, &factory).unwrap();
        full.run_episodes(cfg.episodes, &mut |_| {}).unwrap();
        let want = full.finish().unwrap();

        // interrupted at episode 4, round-tripped through JSON text
        let mut head = MissionRun::new(&cfg, &factory).unwrap();
        head.run_episodes(4, &mut |_| {}).unwrap();
        let ckpt = head.checkpoint().unwrap();
        drop(head);
        let text = ckpt.to_json().to_string();
        let restored =
            MissionCheckpoint::from_json(&cfg.net(), &Json::parse(&text).unwrap()).unwrap();
        let mut tail = MissionRun::restore(&cfg, &factory, restored).unwrap();
        assert_eq!(tail.episodes_done(), 4);
        tail.run_episodes(cfg.episodes, &mut |_| {}).unwrap();
        let got = tail.finish().unwrap();

        let ctx = format!("{backend:?}/{precision:?}/{env:?}/batch={batch}");
        assert_eq!(got.train.episodes.len(), want.train.episodes.len(), "{ctx}");
        for (g, w) in got.train.episodes.iter().zip(&want.train.episodes) {
            assert_eq!(g.steps, w.steps, "{ctx}: steps");
            assert_eq!(g.total_reward.to_bits(), w.total_reward.to_bits(), "{ctx}: reward");
            assert_eq!(
                g.mean_abs_q_err.to_bits(),
                w.mean_abs_q_err.to_bits(),
                "{ctx}: q_err"
            );
            assert_eq!(g.epsilon.to_bits(), w.epsilon.to_bits(), "{ctx}: epsilon");
        }
        assert_eq!(got.train.total_updates, want.train.total_updates, "{ctx}");
        assert_eq!(got.fpga_cycles, want.fpga_cycles, "{ctx}: modeled cycles");
    }
}

/// Checkpoint files round-trip through disk, and a fleet with a
/// pre-existing checkpoint resumes that rover to the same result a clean
/// fleet produces (then clears the file on completion).
#[test]
fn fleet_resumes_rovers_from_checkpoint_files() {
    let cfg = quick_cfg();
    let n = 3;
    let dir = std::env::temp_dir().join("qfpga_fleet_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // clean reference fleet
    let want = run_fleet_with_workers(&cfg, n, 2).unwrap();

    // pre-seed a mid-mission checkpoint for rover 1 (seed base + 1)
    let factory = BackendFactory::for_kind(cfg.backend).unwrap();
    let mut rover1_cfg = cfg.clone();
    rover1_cfg.seed = cfg.seed + 1;
    let mut head = MissionRun::new(&rover1_cfg, &factory).unwrap();
    head.run_episodes(3, &mut |_| {}).unwrap();
    head.checkpoint().unwrap().save(&dir.join("rover-1.json")).unwrap();

    let got = Experiment::from_mission(&cfg)
        .rovers(n)
        .workers(2)
        .checkpoint(&dir, 100) // cadence larger than the mission: resume-only
        .run()
        .unwrap();

    assert_eq!(fingerprint(&got), fingerprint(&want));
    // completed rovers clear their resume state
    for i in 0..n {
        assert!(
            !dir.join(format!("rover-{i}.json")).exists(),
            "rover-{i} checkpoint not cleaned up"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A checkpoint taken under one configuration refuses to resume another:
/// the fingerprint covers everything that shapes the trajectory (seed,
/// env, episode budget, batching, word format).
#[test]
fn stale_checkpoints_are_rejected_not_silently_resumed() {
    let cfg = quick_cfg();
    let factory = BackendFactory::for_kind(cfg.backend).unwrap();
    let mut head = MissionRun::new(&cfg, &factory).unwrap();
    head.run_episodes(3, &mut |_| {}).unwrap();
    let ckpt = head.checkpoint().unwrap();

    for other in [
        MissionConfig { seed: cfg.seed + 1, ..cfg.clone() },
        MissionConfig { max_steps: cfg.max_steps + 1, ..cfg.clone() },
        MissionConfig { batch: 4, ..cfg.clone() },
    ] {
        let err = MissionRun::restore(&other, &factory, ckpt.clone()).unwrap_err();
        assert!(err.to_string().contains("different mission configuration"), "{err}");
    }
    // the matching configuration still resumes
    assert!(MissionRun::restore(&cfg, &factory, ckpt).is_ok());
}

/// Faults × checkpointing is rejected up front by the builder, before any
/// episode runs — not at the first mid-run snapshot.
#[test]
fn builder_rejects_faulted_checkpointing_up_front() {
    let dir = std::env::temp_dir().join("qfpga_fleet_fault_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    let err = Experiment::from_mission(&MissionConfig {
        episodes: 50,
        precision: Precision::Fixed,
        fault: Some(FaultPlan::constant(1e-4, Mitigation::None)),
        ..quick_cfg()
    })
    .rovers(2)
    .checkpoint(&dir, 100) // cadence past the mission: must still fail fast
    .run()
    .unwrap_err();
    assert!(err.to_string().contains("SEU"), "{err}");
    assert!(!dir.exists(), "checkpoint dir created despite the rejection");
}

/// Missions under SEU injection refuse to checkpoint (the injection
/// stream's state is not serializable) instead of resuming wrongly.
#[test]
fn faulted_missions_refuse_checkpoints() {
    let cfg = MissionConfig {
        episodes: 4,
        max_steps: 20,
        precision: Precision::Fixed,
        fault: Some(FaultPlan::constant(1e-4, Mitigation::None)),
        ..Default::default()
    };
    let factory = BackendFactory::for_kind(cfg.backend).unwrap();
    let mut run = MissionRun::new(&cfg, &factory).unwrap();
    run.run_episodes(2, &mut |_| {}).unwrap();
    let err = run.checkpoint().unwrap_err();
    assert!(err.to_string().contains("SEU"), "{err}");
}

/// A shared fleet drained at a round boundary that lies *between* the
/// exchange rounds (the round length is the gcd of the cadences, so not
/// every boundary fires a transform) resumes from its rover checkpoints to
/// the uninterrupted run's report hash — the cadences count absolute
/// episodes, so the resumed fleet lands on exactly the boundaries the
/// uninterrupted run hits.
#[test]
fn shared_fleet_resumed_between_exchange_rounds_matches_uninterrupted() {
    use qfpga::obs::manifest::report_sha256;
    use qfpga::qlearn::SharePlan;
    use qfpga::util::shutdown;

    let cfg = quick_cfg(); // 8 episodes
    // round length gcd(4, 6) = 2: the first boundary (episode 2) fires
    // neither transform — the drain lands between exchange rounds
    let plan = SharePlan { exchange_every: 4, avg_every: 6, pool_cap: 3 };
    let dir = std::env::temp_dir()
        .join(format!("qfpga-pool-share-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let want = Experiment::from_mission(&cfg)
        .rovers(3)
        .workers(2)
        .share(plan)
        .run()
        .unwrap();

    shutdown::request(); // lands before the first 2-episode round finishes
    let partial = Experiment::from_mission(&cfg)
        .rovers(3)
        .workers(2)
        .share(plan)
        .checkpoint(&dir, 100)
        .drain_on_signal(true)
        .run()
        .unwrap();
    shutdown::reset();
    assert!(partial.interrupted);
    let done = partial.rovers[0].train.episodes.len();
    assert!(done > 0 && done < plan.exchange_every, "drained after {done}, not between rounds");

    let got = Experiment::from_mission(&cfg)
        .rovers(3)
        .workers(2)
        .share(plan)
        .checkpoint(&dir, 100)
        .run()
        .unwrap();
    assert_eq!(fingerprint(&got), fingerprint(&want));
    assert_eq!(
        report_sha256(&qfpga::Report::to_json(&got)),
        report_sha256(&qfpga::Report::to_json(&want))
    );
    assert_eq!(got.share, want.share);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Progress streaming: every rover reports every episode, in episode order
/// per rover, and the stream carries the same rewards the report does.
#[test]
fn progress_stream_covers_every_rover_episode() {
    use std::sync::Mutex;
    let cfg = quick_cfg();
    let n = 4;
    let events = Mutex::new(Vec::new());
    let report = Experiment::from_mission(&cfg)
        .rovers(n)
        .workers(2)
        .run_with_progress(&|p| events.lock().unwrap().push(p))
        .unwrap();

    let events = events.into_inner().unwrap();
    assert_eq!(events.len(), n * cfg.episodes);
    for rover in 0..n {
        let mine: Vec<_> = events.iter().filter(|p| p.rover == rover).collect();
        assert_eq!(mine.len(), cfg.episodes, "rover {rover}");
        for (i, p) in mine.iter().enumerate() {
            assert_eq!(p.episode, i, "rover {rover} out of order");
            assert_eq!(p.episodes, cfg.episodes);
            assert_eq!(
                p.reward.to_bits(),
                report.rovers[rover].train.episodes[i].total_reward.to_bits()
            );
        }
    }
}
