//! Property-based tests over the substrate invariants.
//!
//! The proptest crate is not vendored in this offline image, so properties
//! are driven by a seeded random sweep (`qfpga::util::Rng`) with enough
//! cases to give the same practical coverage; every failure reports the
//! case seed for deterministic reproduction.

use qfpga::config::{Arch, EnvKind, NetConfig, Precision};
use qfpga::coordinator::{run_fleet, MissionConfig};
use qfpga::env::make_env;
use qfpga::experiment::{BackendFactory, BackendSpec};
use qfpga::fixed::{tensor, Acc, Fixed, FixedSpec};
use qfpga::fpga::fifo::Fifo;
use qfpga::fpga::{TimingModel, Virtex7};
use qfpga::nn::activation::{LutSpec, SigmoidLut};
use qfpga::nn::params::QNetParams;
use qfpga::qlearn::backend::{BackendKind, QBackend};
use qfpga::qlearn::replay::{StoredTransition, TransitionBuffer};
use qfpga::util::{Json, Rng};

const CASES: usize = 300;

// ------------------------------------------------------------- fixed point

#[test]
fn prop_quantize_is_idempotent_and_bounded() {
    let mut rng = Rng::seeded(9001);
    for case in 0..CASES {
        let word = rng.range(4, 32) as u32;
        let frac = rng.range(1, word as usize) as u32;
        let spec = FixedSpec::new(word, frac);
        let x = rng.f32_range(-1e5, 1e5) as f64;
        let q = Fixed::from_f64(x, spec);
        // idempotent
        assert_eq!(Fixed::from_f64(q.to_f64(), spec), q, "case {case}: {spec:?} {x}");
        // bounded
        assert!(q.to_f64() <= spec.max_value() && q.to_f64() >= spec.min_value());
        // error bound when in range
        if x <= spec.max_value() && x >= spec.min_value() {
            assert!(
                (q.to_f64() - x).abs() <= spec.lsb() / 2.0 + 1e-12,
                "case {case}: {spec:?} {x} -> {}",
                q.to_f64()
            );
        }
    }
}

#[test]
fn prop_wide_accumulator_equals_exact_dot_rounded_once() {
    let spec = FixedSpec::default();
    let mut rng = Rng::seeded(9002);
    for case in 0..CASES {
        let n = rng.range(1, 64);
        let xs = tensor::quantize_slice(&rng.vec_f32(n, -2.0, 2.0), spec);
        let ws = tensor::quantize_slice(&rng.vec_f32(n, -2.0, 2.0), spec);
        let mut acc = Acc::new(spec);
        let mut exact = 0f64;
        for (x, w) in xs.iter().zip(&ws) {
            acc.mac(*x, *w);
            exact += x.to_f64() * w.to_f64();
        }
        assert_eq!(
            acc.finish(),
            Fixed::from_f64(exact, spec),
            "case {case}, n = {n}"
        );
    }
}

#[test]
fn prop_fixed_mul_commutative_and_single_rounded() {
    let spec = FixedSpec::default();
    let mut rng = Rng::seeded(9003);
    for case in 0..CASES {
        let a = Fixed::from_f64(rng.f32_range(-4.0, 4.0) as f64, spec);
        let b = Fixed::from_f64(rng.f32_range(-4.0, 4.0) as f64, spec);
        assert_eq!(a.mul(b), b.mul(a), "case {case}");
        assert_eq!(a.mul(b), Fixed::from_f64(a.to_f64() * b.to_f64(), spec), "case {case}");
    }
}

// ------------------------------------------------------------------- fifo

#[test]
fn prop_fifo_behaves_like_vecdeque() {
    let mut rng = Rng::seeded(9004);
    for case in 0..100 {
        let cap = rng.range(1, 64);
        let mut fifo: Fifo<u64> = Fifo::new(cap);
        let mut model = std::collections::VecDeque::new();
        for _ in 0..500 {
            if rng.chance(0.55) {
                let v = rng.next_u64();
                let ok = fifo.push(v).is_ok();
                assert_eq!(ok, model.len() < cap, "case {case}: push admissibility");
                if ok {
                    model.push_back(v);
                }
            } else {
                let got = fifo.pop().ok();
                assert_eq!(got, model.pop_front(), "case {case}: pop value");
            }
            assert_eq!(fifo.len(), model.len());
        }
    }
}

// ------------------------------------------------------------ environments

#[test]
fn prop_environment_contract() {
    // For any env kind (paper benchmarks and scenario library alike), any
    // action sequence: encodings bounded, state ids within |S|, episodes
    // terminate, rewards finite.
    let mut rng = Rng::seeded(9005);
    for case in 0..40 {
        let kinds = EnvKind::all();
        let kind = kinds[rng.below(kinds.len())];
        let mut env = make_env(kind, rng.next_u64());
        let a_n = env.n_actions();
        let d = env.d();
        let mut enc = vec![0f32; a_n * d];
        let mut steps = 0usize;
        while !env.is_done() {
            assert!(env.state_id() < env.state_space(), "case {case}");
            env.encode_all(&mut enc);
            for &v in &enc {
                assert!(v.is_finite() && (-1.0..=1.0).contains(&v), "case {case}: {v}");
            }
            let r = env.step(rng.below(a_n));
            assert!(r.reward.is_finite() && r.reward.abs() < 10.0, "case {case}: {}", r.reward);
            steps += 1;
            assert!(steps <= 500, "case {case}: episode failed to terminate");
        }
        env.reset();
        assert!(!env.is_done(), "case {case}: reset must clear terminal");
    }
}

#[test]
fn prop_scenario_envs_deterministic_and_bounded() {
    // Seed-determinism contract for the scenario library: same constructor
    // seed + same action sequence ⇒ bit-identical encodings, rewards and
    // state ids — including the slip environment, whose stochastic
    // dynamics must derive entirely from the seed. Encodings stay inside
    // the Q(18,12) no-saturation range [−1, 1] along every trajectory.
    let mut rng = Rng::seeded(9022);
    for case in 0..25 {
        for kind in [EnvKind::Crater, EnvKind::Slip, EnvKind::Energy] {
            let seed = rng.next_u64();
            let mut a = make_env(kind, seed);
            let mut b = make_env(kind, seed);
            let (a_n, d) = (a.n_actions(), a.d());
            let mut enc_a = vec![0f32; a_n * d];
            let mut enc_b = vec![0f32; a_n * d];
            for _ in 0..120 {
                if a.is_done() {
                    a.reset();
                    b.reset();
                }
                a.encode_all(&mut enc_a);
                b.encode_all(&mut enc_b);
                assert_eq!(enc_a, enc_b, "case {case} {kind:?}: encodings diverged");
                for &v in &enc_a {
                    assert!(
                        v.is_finite() && (-1.0..=1.0).contains(&v),
                        "case {case} {kind:?}: encoding {v} outside [−1, 1]"
                    );
                }
                let action = rng.below(a_n);
                let ra = a.step(action);
                let rb = b.step(action);
                assert_eq!(ra, rb, "case {case} {kind:?}: step results diverged");
                assert_eq!(a.state_id(), b.state_id(), "case {case} {kind:?}");
                assert!(a.state_id() < a.state_space(), "case {case} {kind:?}");
            }
        }
    }
}

// ------------------------------------------------------------- Q-learning

#[test]
fn prop_qupdate_direction_matches_error_sign() {
    // After one update on (s, a), re-evaluating Q(s, a) moves toward the
    // target (or stays, under saturation): sign(Q' − Q) == sign(q_err) or 0.
    let mut rng = Rng::seeded(9006);
    for case in 0..150 {
        let arch = if rng.chance(0.5) { Arch::Perceptron } else { Arch::Mlp };
        let net = NetConfig::new(arch, EnvKind::Simple);
        let params = QNetParams::init(&net, 0.3, &mut rng);
        let mut backend = BackendFactory::offline()
            .build(&BackendSpec::cpu(net, Precision::Float), params)
            .unwrap();
        let sa_cur = rng.vec_f32(net.a * net.d, -1.0, 1.0);
        let sa_next = rng.vec_f32(net.a * net.d, -1.0, 1.0);
        let action = rng.below(net.a);
        let reward = rng.f32_range(-1.0, 1.0);

        let q_before = backend.q_values(&sa_cur).unwrap()[action];
        let err = backend.update(&sa_cur, &sa_next, action, reward).unwrap();
        let q_after = backend.q_values(&sa_cur).unwrap()[action];
        let dq = q_after - q_before;
        if err.abs() > 1e-4 && dq.abs() > 1e-6 {
            assert_eq!(
                dq.signum(),
                err.signum(),
                "case {case}: q moved {dq} against error {err}"
            );
        }
    }
}

#[test]
fn prop_timing_model_monotone_in_a_and_d() {
    // More actions or wider inputs never make an update cheaper.
    let t = TimingModel::default();
    let mut rng = Rng::seeded(9007);
    for case in 0..CASES {
        let arch = if rng.chance(0.5) { Arch::Perceptron } else { Arch::Mlp };
        let mut small = NetConfig::new(arch, EnvKind::Simple);
        small.a = rng.range(1, 32);
        small.d = rng.range(1, 32);
        let mut big = small;
        big.a = small.a + rng.range(1, 16);
        big.d = small.d + rng.range(1, 16);
        for prec in Precision::all() {
            assert!(
                t.qupdate(&big, prec).total() >= t.qupdate(&small, prec).total(),
                "case {case}: {arch:?}/{prec:?}"
            );
        }
    }
}

#[test]
fn prop_throughput_inverse_of_completion() {
    let t = TimingModel::default();
    let dev = Virtex7::default();
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let us = t.completion_us(&net, prec, &dev);
            let kq = t.throughput_kq_s(&net, prec, &dev);
            assert!((kq * us / 1e3 - 1.0).abs() < 1e-9, "{net:?}/{prec:?}");
        }
    }
}

// --------------------------------------------------------- backend naming

/// Parse↔print property: every backend kind round-trips through its
/// canonical string, the `"fpga"` alias maps onto `"fpga-sim"`, and random
/// junk never parses.
#[test]
fn prop_backend_kind_parse_print_roundtrip() {
    for kind in BackendKind::all() {
        assert_eq!(kind.as_str().parse::<BackendKind>().unwrap(), kind);
    }
    assert_eq!(
        "fpga".parse::<BackendKind>().unwrap(),
        BackendKind::FpgaSim
    );
    let mut rng = Rng::seeded(9020);
    let alphabet: Vec<char> = "abcdefghijklmnopqrstuvwxyz-".chars().collect();
    let known = ["xla", "cpu", "fpga-sim", "fpga"];
    for _ in 0..200 {
        let len = rng.range(1, 10);
        let s: String = (0..len).map(|_| alphabet[rng.below(alphabet.len())]).collect();
        let parsed = s.parse::<BackendKind>();
        if known.contains(&s.as_str()) {
            // accepted spellings must round-trip back to a known kind
            assert!(known.contains(&parsed.unwrap().as_str()));
        } else {
            assert!(parsed.is_err(), "junk `{s}` parsed");
        }
    }
}

/// Parse↔print property: every env kind round-trips through its canonical
/// string, the long-form aliases map onto the canonical kinds, random junk
/// never parses, and the parse error lists the valid spellings.
#[test]
fn prop_env_kind_parse_print_roundtrip() {
    for kind in EnvKind::all() {
        assert_eq!(kind.as_str().parse::<EnvKind>().unwrap(), kind);
    }
    for (alias, kind) in [
        ("crater-field", EnvKind::Crater),
        ("slip-slope", EnvKind::Slip),
        ("energy-budget", EnvKind::Energy),
    ] {
        assert_eq!(alias.parse::<EnvKind>().unwrap(), kind);
    }
    // the error message must list every valid spelling (not fail opaquely)
    let err = "medium".parse::<EnvKind>().unwrap_err().to_string();
    for spelling in ["simple", "complex", "crater", "slip", "energy"] {
        assert!(err.contains(spelling), "error must list `{spelling}`: {err}");
    }

    let mut rng = Rng::seeded(9021);
    let alphabet: Vec<char> = "abcdefghijklmnopqrstuvwxyz-".chars().collect();
    let known = [
        "simple",
        "complex",
        "crater",
        "crater-field",
        "slip",
        "slip-slope",
        "energy",
        "energy-budget",
    ];
    for _ in 0..200 {
        let len = rng.range(1, 14);
        let s: String = (0..len).map(|_| alphabet[rng.below(alphabet.len())]).collect();
        let parsed = s.parse::<EnvKind>();
        if known.contains(&s.as_str()) {
            // accepted spellings must round-trip back to a known kind
            assert!(known.contains(&parsed.unwrap().as_str()));
        } else {
            assert!(parsed.is_err(), "junk `{s}` parsed");
        }
    }
}

/// Parse↔print property: every precision arm round-trips through its
/// canonical string, the long-form aliases map onto the canonical arms,
/// random junk never parses, and the parse error lists the valid
/// spellings.
#[test]
fn prop_precision_parse_print_roundtrip() {
    for prec in Precision::all() {
        assert_eq!(prec.as_str().parse::<Precision>().unwrap(), prec);
    }
    for (alias, prec) in [("floating", Precision::Float), ("bnn", Precision::Binary)] {
        assert_eq!(alias.parse::<Precision>().unwrap(), prec);
    }
    // the error message must list every valid spelling (not fail opaquely)
    let err = "int4".parse::<Precision>().unwrap_err().to_string();
    for spelling in ["fixed", "float", "int8", "binary", "floating", "bnn"] {
        assert!(err.contains(spelling), "error must list `{spelling}`: {err}");
    }

    let mut rng = Rng::seeded(9023);
    let alphabet: Vec<char> = "abcdefghijklmnopqrstuvwxyz0123456789-".chars().collect();
    let known = ["fixed", "float", "floating", "int8", "binary", "bnn"];
    for _ in 0..200 {
        let len = rng.range(1, 10);
        let s: String = (0..len).map(|_| alphabet[rng.below(alphabet.len())]).collect();
        let parsed = s.parse::<Precision>();
        if known.contains(&s.as_str()) {
            // accepted spellings must round-trip back to a known arm
            assert!(known.contains(&parsed.unwrap().as_str()));
        } else {
            assert!(parsed.is_err(), "junk `{s}` parsed");
        }
    }
}

// ------------------------------------------------------ transition buffer

#[test]
fn prop_drain_flat_contract() {
    // Arbitrary push/drain interleavings: FIFO order, flat layout, clamped
    // partial drains, clean errors on malformed transitions.
    let net = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
    let step = net.a * net.d;
    let mut rng = Rng::seeded(9010);
    for case in 0..150 {
        let mut buf = TransitionBuffer::new();
        let mut model: std::collections::VecDeque<(usize, f32, f32)> = Default::default();
        let n_push = rng.below(12);
        for k in 0..n_push {
            let action = rng.below(net.a);
            let reward = rng.f32_range(-1.0, 1.0);
            let fill = k as f32 * 0.5 - 1.0;
            buf.push(StoredTransition {
                sa_cur: vec![fill; step],
                sa_next: vec![-fill; step],
                action,
                reward,
            });
            model.push_back((action, reward, fill));
        }
        while !buf.is_empty() {
            let take = rng.range(1, 6);
            let before = buf.len();
            let batch = buf.drain_flat(take, &net).unwrap();
            assert_eq!(batch.len(), take.min(before), "case {case}");
            assert_eq!(buf.len(), before - batch.len(), "case {case}");
            assert_eq!(batch.sa_cur.len(), batch.len() * step, "case {case}");
            assert!(batch.validate(&net).is_ok(), "case {case}");
            for i in 0..batch.len() {
                let (action, reward, fill) = model.pop_front().unwrap();
                assert_eq!(batch.actions[i], action, "case {case}");
                assert_eq!(batch.rewards[i], reward, "case {case}");
                assert_eq!(batch.sa_cur[i * step], fill, "case {case}: layout");
                assert_eq!(batch.sa_next[i * step], -fill, "case {case}: layout");
            }
        }
        assert!(model.is_empty(), "case {case}: drained counts disagree");
        // draining an empty buffer yields an empty, valid batch
        let empty = buf.drain_flat(4, &net).unwrap();
        assert!(empty.is_empty() && empty.validate(&net).is_ok(), "case {case}");
        // a dimension-mismatched transition is rejected, not silently packed
        buf.push(StoredTransition {
            sa_cur: vec![0.0; step.saturating_sub(1)],
            sa_next: vec![0.0; step],
            action: 0,
            reward: 0.0,
        });
        assert!(buf.drain_flat(1, &net).is_err(), "case {case}");
    }
}

// --------------------------------------------------------- rover progress

#[test]
fn prop_rover_progress_json_roundtrip() {
    // Any reachable progress sample survives the JSON text round-trip
    // bit-exactly: f32 rewards/epsilons widen losslessly to f64 and the
    // writer's shortest-round-trip float formatting preserves them.
    use qfpga::coordinator::RoverProgress;
    let mut rng = Rng::seeded(9030);
    for case in 0..CASES {
        let p = RoverProgress {
            rover: rng.below(64),
            episode: rng.below(100_000),
            episodes: rng.range(1, 100_000),
            reward: rng.f32_range(-1e4, 1e4),
            epsilon: rng.f32_range(0.0, 1.0),
        };
        let text = p.to_json().to_string();
        let back = RoverProgress::from_json(&Json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, p, "case {case}: {text}");
        assert_eq!(back.reward.to_bits(), p.reward.to_bits(), "case {case}");
        assert_eq!(back.epsilon.to_bits(), p.epsilon.to_bits(), "case {case}");
    }
}

// -------------------------------------------------------- fleet + batching

#[test]
fn prop_run_fleet_deterministic_with_batching() {
    // For random seeds and batch sizes, a batched fleet must replay
    // bit-identically and learn from every environment step.
    let mut rng = Rng::seeded(9011);
    for (case, &batch) in [2usize, 5, 8].iter().enumerate() {
        let cfg = MissionConfig {
            episodes: 4,
            max_steps: 30,
            backend: BackendKind::Cpu,
            precision: Precision::Float,
            batch,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let a = run_fleet(&cfg, 2).unwrap();
        let b = run_fleet(&cfg, 2).unwrap();
        assert_eq!(a.rovers.len(), b.rovers.len());
        for (x, y) in a.rovers.iter().zip(&b.rovers) {
            assert_eq!(x.train.total_updates, y.train.total_updates, "case {case}");
            assert_eq!(x.train.total_steps, y.train.total_steps, "case {case}");
            for (ex, ey) in x.train.episodes.iter().zip(&y.train.episodes) {
                assert_eq!(ex.total_reward, ey.total_reward, "case {case}");
                assert_eq!(ex.steps, ey.steps, "case {case}");
            }
        }
        for r in &a.rovers {
            assert_eq!(
                r.train.total_updates as usize, r.train.total_steps,
                "case {case}: a batched rover must still learn from every step"
            );
        }
    }
}

// -------------------------------------------------------------- sigmoid LUT

#[test]
fn prop_lut_monotone_any_size() {
    let mut rng = Rng::seeded(9008);
    for case in 0..60 {
        let size = rng.range(16, 4096);
        let xmax = rng.f32_range(2.0, 16.0);
        let lut = SigmoidLut::build(LutSpec { size, xmax }, None);
        let mut xs = rng.vec_f32(64, -20.0, 20.0);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = -1.0f32;
        for &x in &xs {
            let v = lut.lookup(x);
            assert!(v >= prev - 1e-7, "case {case}: size {size}, x {x}");
            prev = v;
        }
    }
}

// --------------------------------------------------------------------- json

#[test]
fn prop_json_roundtrip_random_trees() {
    let mut rng = Rng::seeded(9009);
    for case in 0..200 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num((rng.f64() * 2e6 - 1e6).round() / 8.0),
        3 => {
            let n = rng.below(12);
            Json::Str((0..n).map(|_| random_char(rng)).collect())
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}_{}", rng.below(100)), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

fn random_char(rng: &mut Rng) -> char {
    const POOL: &[char] = &['a', 'Z', '0', ' ', '"', '\\', '\n', '\t', 'é', '✓', '{', '}'];
    POOL[rng.below(POOL.len())]
}

// --------------------------------------------------------- fleet averaging

/// Shared-fleet parameter averaging is exactly permutation-invariant
/// across rover order (the fleet mean may not depend on which worker
/// finished first) and exactly idempotent on a fleet that already agrees
/// on on-grid parameters (an averaging round over identical inputs is the
/// identity, bit for bit) — over random shapes from the mission grid and
/// both the float and Q(18,12) datapaths.
#[test]
fn prop_fleet_averaging_permutation_invariant_and_idempotent() {
    use qfpga::nn::Datapath;
    use qfpga::qlearn::share::average_params;

    let mut rng = Rng::seeded(9102);
    let grid = NetConfig::grid();
    for case in 0..60 {
        let net = grid[rng.below(grid.len())];
        let fixed = rng.chance(0.5);
        let dp = if fixed {
            Datapath::for_precision_spec(Precision::Fixed, FixedSpec::default())
        } else {
            Datapath::for_precision(Precision::Float)
        };
        let ctx = format!("case {case} ({}, fixed={fixed})", net.name());
        let n = rng.range(2, 6);
        let sets: Vec<QNetParams> = (0..n)
            .map(|_| QNetParams::init(&net, rng.f32_range(0.1, 0.6), &mut rng))
            .collect();
        let want = average_params(&sets, &net, &dp).unwrap();

        // permutation invariance: a random shuffle of the rover order
        // produces the bit-identical mean
        let mut shuffled = sets.clone();
        for i in (1..shuffled.len()).rev() {
            shuffled.swap(i, rng.below(i + 1));
        }
        let got = average_params(&shuffled, &net, &dp).unwrap();
        let (wt, gt) = (want.to_tensors(), got.to_tensors());
        for (t, (wv, gv)) in wt.iter().zip(&gt).enumerate() {
            for (e, (w, g)) in wv.iter().zip(gv).enumerate() {
                assert_eq!(w.to_bits(), g.to_bits(), "{ctx}: tensor {t} elem {e}");
            }
        }

        // idempotence on an agreeing fleet: averaging n copies of on-grid
        // parameters returns them unchanged (n·x / n is exact in f64 and
        // the grid pass is a fixpoint on on-grid values)
        let on_grid = average_params(&sets[..1], &net, &dp).unwrap();
        let again = average_params(&vec![on_grid.clone(); n], &net, &dp).unwrap();
        assert_eq!(again.max_abs_diff(&on_grid), 0.0, "{ctx}: averaging drifted");
    }
}

// ------------------------------------------------------------- PreparedNet

/// Cache-invalidation soundness: any interleaving of parameter loads,
/// stepwise updates, forwards and batched flushes through a `PreparedNet`
/// matches the cache-free reference implementation (`nn::qupdate` /
/// `nn::forward` threading raw parameters) bit for bit — the cache may
/// never serve stale or raw weights.
#[test]
fn prop_prepared_net_interleavings_match_cache_free_reference() {
    use qfpga::config::Hyper;
    use qfpga::nn::{forward, qupdate, Datapath, PreparedNet};

    let mut rng = Rng::seeded(9101);
    for case in 0..40 {
        let net = NetConfig::all()[rng.below(4)];
        let fixed = rng.chance(0.5);
        let dp = Datapath::paper(fixed.then(FixedSpec::default));
        let hyper = Hyper::default();
        let step = net.a * net.d;

        let init = QNetParams::init(&net, 0.4, &mut rng);
        let mut reference = init.clone();
        let mut prepared = PreparedNet::new(init);
        let mut q_buf = Vec::new();
        let ctx = |op: usize| format!("case {case} ({}, fixed={fixed}), op {op}", net.name());

        for op in 0..30 {
            match rng.below(4) {
                // invalidate: swap fresh (off-grid) parameters into both
                0 => {
                    let fresh = QNetParams::init(&net, rng.f32_range(0.1, 0.6), &mut rng);
                    prepared.load(&fresh);
                    reference = fresh;
                }
                // stepwise update
                1 => {
                    let sc = rng.vec_f32(step, -1.0, 1.0);
                    let sn = rng.vec_f32(step, -1.0, 1.0);
                    let (a, r) = (rng.below(net.a), rng.f32_range(-1.0, 1.0));
                    let want = qupdate(&net, &reference, &sc, &sn, a, r, &hyper, &dp).unwrap();
                    reference = want.params;
                    let got = prepared.update(&net, &sc, &sn, a, r, &hyper, &dp).unwrap();
                    assert_eq!(got.to_bits(), want.q_err.to_bits(), "{}", ctx(op));
                }
                // action-selection forward
                2 => {
                    let sa = rng.vec_f32(step, -1.0, 1.0);
                    let want = forward(&net, &reference, &sa, &dp).unwrap();
                    prepared.forward_into(&net, &sa, &dp, &mut q_buf).unwrap();
                    assert_eq!(q_buf, want, "{}", ctx(op));
                }
                // batched flush of 1..=4 transitions
                _ => {
                    let b = rng.range(1, 5);
                    let sc = rng.vec_f32(b * step, -1.0, 1.0);
                    let sn = rng.vec_f32(b * step, -1.0, 1.0);
                    let actions: Vec<usize> = (0..b).map(|_| rng.below(net.a)).collect();
                    let rewards = rng.vec_f32(b, -1.0, 1.0);
                    let mut want = Vec::new();
                    for i in 0..b {
                        let out = qupdate(
                            &net,
                            &reference,
                            &sc[i * step..(i + 1) * step],
                            &sn[i * step..(i + 1) * step],
                            actions[i],
                            rewards[i],
                            &hyper,
                            &dp,
                        )
                        .unwrap();
                        reference = out.params;
                        want.push(out.q_err);
                    }
                    let mut got = Vec::new();
                    prepared
                        .update_batch(&net, &sc, &sn, &actions, &rewards, &hyper, &dp, &mut got)
                        .unwrap();
                    assert_eq!(got, want, "{}", ctx(op));
                }
            }
        }
        // after the interleaving, one more update puts both on-grid and the
        // full parameter state must agree to the bit
        let sc = rng.vec_f32(step, -1.0, 1.0);
        let sn = rng.vec_f32(step, -1.0, 1.0);
        let out = qupdate(&net, &reference, &sc, &sn, 0, 0.1, &hyper, &dp).unwrap();
        prepared.update(&net, &sc, &sn, 0, 0.1, &hyper, &dp).unwrap();
        assert_eq!(
            prepared.params().max_abs_diff(&out.params),
            0.0,
            "case {case}: final params diverged"
        );
    }
}
