//! Graceful-drain integration: a signal (simulated by raising the
//! process-global shutdown flag) cuts a checkpointed train run at a chunk
//! boundary with a resumable snapshot on disk, the resumed run reproduces
//! the uninterrupted report bit-exactly, the fleet pool stops claiming
//! rovers, and the scenario campaign returns a partial table that says so.
//!
//! The flag is process-global, so every test here serializes on one mutex
//! and resets the flag on entry and exit.

use std::sync::Mutex;

use qfpga::config::EnvKind;
use qfpga::coordinator::{scenario_table_with_drain, MissionConfig, ScenarioSpec};
use qfpga::experiment::Experiment;
use qfpga::obs::manifest::report_sha256;
use qfpga::util::shutdown;
use qfpga::Report;

static SERIAL: Mutex<()> = Mutex::new(());

fn base_cfg(seed: u64) -> MissionConfig {
    MissionConfig { episodes: 8, max_steps: 20, seed, ..Default::default() }
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("qfpga-drain-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn signal_drain_checkpoints_then_resume_matches_uninterrupted() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    shutdown::reset();
    let cfg = base_cfg(41);
    let baseline = report_sha256(&Experiment::from_mission(&cfg).run().unwrap().to_json());

    let dir = temp_dir("train");
    let ckpt = dir.join("rover-0.json");
    std::fs::remove_file(&ckpt).ok();
    shutdown::request(); // the signal lands before the first chunk finishes
    let drained = Experiment::from_mission(&cfg)
        .checkpoint(&dir, 2)
        .drain_on_signal(true)
        .run()
        .unwrap();
    assert!(drained.interrupted);
    let done = drained.rovers[0].train.episodes.len();
    assert!(done >= 1 && done < cfg.episodes, "drained after {done}/{}", cfg.episodes);
    assert!(ckpt.exists(), "no resumable checkpoint written on drain");

    shutdown::reset();
    let resumed = Experiment::from_mission(&cfg)
        .checkpoint(&dir, 2)
        .drain_on_signal(true)
        .run()
        .unwrap();
    assert!(!resumed.interrupted);
    assert_eq!(resumed.rovers[0].train.episodes.len(), cfg.episodes);
    // drain + resume reproduces the uninterrupted run bit-exactly
    assert_eq!(report_sha256(&resumed.to_json()), baseline);
    // completion clears the resume state so a rerun starts fresh
    assert!(!ckpt.exists());
}

#[test]
fn fleet_pool_stops_claiming_rovers_on_drain() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    shutdown::reset();
    shutdown::request();
    let report = Experiment::from_mission(&base_cfg(42))
        .rovers(3)
        .workers(2)
        .drain_on_signal(true)
        .run()
        .unwrap();
    // draining returns cleanly with whatever subset ran, flagged
    assert!(report.interrupted);
    assert!(report.rovers.len() <= 3);
    shutdown::reset();
}

#[test]
fn scenario_campaign_drains_into_a_partial_table() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    shutdown::reset();
    let spec = ScenarioSpec {
        envs: vec![EnvKind::Simple, EnvKind::Crater],
        episodes: 3,
        max_steps: 10,
        ..Default::default()
    };
    shutdown::request();
    let table = scenario_table_with_drain(&spec, true).unwrap();
    let rendered = format!("{table}");
    assert!(rendered.contains("DRAINED"), "missing drain note:\n{rendered}");
    shutdown::reset();

    // without the drain flag the same campaign runs to completion even
    // with the shutdown flag raised (replay/daemon semantics)
    shutdown::request();
    let full = scenario_table_with_drain(&spec, false).unwrap();
    assert!(!format!("{full}").contains("DRAINED"));
    shutdown::reset();
}
