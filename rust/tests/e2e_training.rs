//! End-to-end integration: full missions through the coordinator, on every
//! backend, on both environments — learning happens, determinism holds, and
//! the FPGA model accounting is consistent.

use qfpga::config::{Arch, EnvKind, Precision};
use qfpga::coordinator::{run_fleet, run_mission, MissionConfig};
use qfpga::fpga::{TimingModel, Virtex7};
use qfpga::qlearn::backend::BackendKind;

fn base_cfg() -> MissionConfig {
    MissionConfig {
        arch: Arch::Mlp,
        env: EnvKind::Simple,
        precision: Precision::Fixed,
        backend: BackendKind::Cpu,
        episodes: 120,
        max_steps: 120,
        seed: 2017,
        ..Default::default()
    }
}

fn have_artifacts() -> bool {
    qfpga::runtime::default_artifact_dir().join("manifest.json").exists()
}

#[test]
fn cpu_mission_learns_on_simple_env() {
    let cfg = MissionConfig { precision: Precision::Float, ..base_cfg() };
    let r = run_mission(&cfg).unwrap();
    let (first, last) = r.train.first_last_mean_reward(25);
    assert!(
        last > first,
        "no learning: first-25 {first} -> last-25 {last}"
    );
}

#[test]
fn fpga_sim_mission_learns_and_accounts_cycles() {
    let cfg = MissionConfig { backend: BackendKind::FpgaSim, episodes: 60, ..base_cfg() };
    let r = run_mission(&cfg).unwrap();
    // cycle accounting: every update costs 13A+3 = 81 (fixed simple MLP),
    // every action-selection forward sweep costs 6A = 36
    let t = TimingModel::default();
    let net = cfg.net();
    let per_update = t.qupdate(&net, Precision::Fixed).total();
    let per_forward = t.forward_cycles(&net, Precision::Fixed);
    let updates = r.train.total_updates;
    let forwards = r.train.total_steps as u64; // one sweep per step
    let expected = updates * per_update + forwards * per_forward;
    assert_eq!(r.fpga_cycles.unwrap(), expected);
    // modeled time consistent with the device clock
    let us = Virtex7::default().cycles_to_us(expected);
    assert!((r.fpga_modeled_us.unwrap() - us).abs() < 1e-6);
}

#[test]
fn xla_mission_runs_e2e() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = MissionConfig {
        backend: BackendKind::Xla,
        episodes: 25,
        max_steps: 60,
        ..base_cfg()
    };
    let r = run_mission(&cfg).unwrap();
    assert_eq!(r.train.episodes.len(), 25);
    assert!(r.train.total_updates > 0);
}

#[test]
fn xla_microbatch_mission_matches_update_count() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = MissionConfig {
        backend: BackendKind::Xla,
        microbatch: true,
        episodes: 12,
        max_steps: 60,
        ..base_cfg()
    };
    let r = run_mission(&cfg).unwrap();
    // every environment step must eventually be learned from (flush at
    // episode end), so updates == steps even in microbatch mode
    assert_eq!(r.train.total_updates as usize, r.train.total_steps);
}

#[test]
fn complex_env_mission_runs_on_all_local_backends() {
    for backend in [BackendKind::Cpu, BackendKind::FpgaSim] {
        let cfg = MissionConfig {
            env: EnvKind::Complex,
            backend,
            episodes: 6,
            max_steps: 80,
            ..base_cfg()
        };
        let r = run_mission(&cfg).unwrap();
        assert_eq!(r.train.episodes.len(), 6, "{backend:?}");
    }
}

#[test]
fn fleet_of_rovers_is_deterministic_and_parallel() {
    let cfg = MissionConfig { episodes: 10, max_steps: 60, ..base_cfg() };
    let a = run_fleet(&cfg, 3).unwrap();
    let b = run_fleet(&cfg, 3).unwrap();
    assert_eq!(a.rovers.len(), 3);
    for (x, y) in a.rovers.iter().zip(&b.rovers) {
        assert_eq!(
            x.train.episodes.last().unwrap().total_reward,
            y.train.episodes.last().unwrap().total_reward
        );
    }
}

#[test]
fn precision_comparison_fixed_tracks_float_learning() {
    // The paper's core claim is that fixed point is a viable substitute:
    // trained on the same seed, the fixed-point learner must reach a
    // similar reward level to the float learner.
    let float_cfg = MissionConfig { precision: Precision::Float, ..base_cfg() };
    let fixed_cfg = MissionConfig { precision: Precision::Fixed, ..base_cfg() };
    let rf = run_mission(&float_cfg).unwrap();
    let rx = run_mission(&fixed_cfg).unwrap();
    let (_, last_f) = rf.train.first_last_mean_reward(25);
    let (_, last_x) = rx.train.first_last_mean_reward(25);
    assert!(
        (last_f - last_x).abs() < 1.5,
        "fixed {last_x} vs float {last_f}: quantization destroyed learning"
    );
}
