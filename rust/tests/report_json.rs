//! Typed-JSON surface tests: every [`Report`] impl's `to_json` must be
//! stable (parse ↔ print fixed point, required keys present), `qfpga diff`
//! must flag injected ratio regressions, and the committed CI golden
//! (`ci/golden_report.json`) must stay structurally in sync with the
//! generated tables (its ids and row labels all exist in a fresh
//! `report --all --no-measure` run — the *numeric* gate runs in CI via
//! `qfpga diff`).

use qfpga::config::{Arch, EnvKind, NetConfig, Precision};
use qfpga::coordinator::{MissionConfig, SweepReport};
use qfpga::experiment::{BackendSpec, Experiment};
use qfpga::fault::{run_campaign, CampaignSpec, Mitigation};
use qfpga::qlearn::backend::BackendKind;
use qfpga::report::{self, diff_json, set_to_json, PaperTable, Report};
use qfpga::util::Json;

/// Every paper table, generated without host measurement (model rows only,
/// exactly what the CI `report-json` job produces) — the canonical list
/// comes from `report::all_tables`, the same helper `report --all` uses.
fn all_tables() -> Vec<PaperTable> {
    report::all_tables(
        |arch, env| {
            Ok(report::table_completion(
                arch,
                env,
                report::CompletionInputs { measured_cpu_us: None },
            ))
        },
        16,
    )
    .expect("model tables never fail")
}

#[test]
fn every_paper_table_json_is_a_parse_print_fixed_point() {
    for t in all_tables() {
        let j = Report::to_json(&t);
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("{}: {e}", t.id));
        assert_eq!(parsed, j, "{}: reparse changed the value", t.id);
        assert_eq!(parsed.req_str("id").unwrap(), Report::id(&t));
        let rows = parsed.req_arr("rows").unwrap();
        assert_eq!(rows.len(), t.rows.len(), "{}", t.id);
        for (row, json_row) in t.rows.iter().zip(rows) {
            assert_eq!(json_row.req_str("label").unwrap(), row.label, "{}", t.id);
            assert_eq!(json_row.req_f64("ours").unwrap(), row.ours, "{}", t.id);
        }
    }
}

#[test]
fn report_set_wraps_every_table_once() {
    let tables = all_tables();
    let doc = set_to_json(&tables);
    let arr = doc.req_arr("tables").unwrap();
    assert_eq!(arr.len(), tables.len());
    for (t, j) in tables.iter().zip(arr) {
        assert_eq!(j.req_str("id").unwrap(), t.id);
    }
    // the wrapper itself round-trips
    assert_eq!(Json::parse(&doc.to_string()).unwrap(), doc);
}

#[test]
fn diff_passes_on_identical_reports_and_flags_injected_regression() {
    let doc = set_to_json(&all_tables());
    let clean = diff_json(&doc, &doc, 0.01);
    assert!(clean.ok(), "{:?}", clean.problems);
    assert!(clean.compared > 50, "only {} values compared", clean.compared);

    // inject a 3× paper-ratio regression into the headline table
    let mut drifted_tables = all_tables();
    for t in &mut drifted_tables {
        if t.id == "H1" {
            t.rows[0].ours *= 3.0;
        }
    }
    let drifted = set_to_json(&drifted_tables);
    let d = diff_json(&drifted, &doc, 0.05);
    assert!(!d.ok(), "3× ratio drift not flagged");
    assert!(
        d.problems.iter().any(|p| p.contains("H1")),
        "{:?}",
        d.problems
    );
}

#[test]
fn golden_report_structurally_matches_generated_tables() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../ci/golden_report.json");
    let text = std::fs::read_to_string(path).expect("ci/golden_report.json present");
    let golden = Json::parse(&text).expect("golden parses");
    let generated = set_to_json(&all_tables());
    let gen_tables = generated.req_arr("tables").unwrap();

    for gtable in golden.req_arr("tables").unwrap() {
        let id = gtable.req_str("id").unwrap();
        let table = gen_tables
            .iter()
            .find(|t| t.get("id").and_then(Json::as_str) == Some(id))
            .unwrap_or_else(|| panic!("golden table {id} not generated"));
        let labels: Vec<&str> = table
            .req_arr("rows")
            .unwrap()
            .iter()
            .map(|r| r.req_str("label").unwrap())
            .collect();
        for grow in gtable.req_arr("rows").unwrap() {
            let label = grow.req_str("label").unwrap();
            assert!(
                labels.contains(&label),
                "golden {id} row `{label}` missing from generated table (have {labels:?})"
            );
        }
    }
}

#[test]
fn campaign_json_diffs_against_itself_and_flags_degradation_drift() {
    let spec = CampaignSpec {
        base: MissionConfig {
            episodes: 4,
            max_steps: 25,
            precision: Precision::Fixed,
            seed: 5,
            ..Default::default()
        },
        backends: vec![BackendKind::Cpu],
        rates: vec![1e-4],
        mitigations: vec![Mitigation::None],
        rovers: 1,
        schedule: None,
    };
    let r = run_campaign(&spec).unwrap();
    let j = Report::to_json(&r);
    assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    let clean = diff_json(&j, &j, 0.01);
    assert!(clean.ok(), "{:?}", clean.problems);
    assert!(clean.compared > 0);

    // rerun with a different seed: the cells still pair up by key, and the
    // upset counters almost surely differ
    let mut other_spec = spec;
    other_spec.base.seed = 999;
    let other = run_campaign(&other_spec).unwrap();
    let d = diff_json(&Report::to_json(&other), &j, 1e-9);
    assert!(d.compared > 0, "cells failed to pair: {:?}", d.problems);
    assert!(
        d.problems.iter().all(|p| !p.contains("missing")),
        "cells failed to pair: {:?}",
        d.problems
    );
}

#[test]
fn experiment_and_sweep_reports_serialize_stably() {
    let exp = Experiment::train(BackendSpec::cpu(
        NetConfig::new(Arch::Mlp, EnvKind::Simple),
        Precision::Float,
    ))
    .episodes(3)
    .max_steps(25)
    .run()
    .unwrap();
    let j = exp.to_json();
    let parsed = Json::parse(&j.to_string()).unwrap();
    assert_eq!(parsed, j);
    assert_eq!(parsed.req_str("id").unwrap(), "EXP");
    assert_eq!(parsed.req_arr("reports").unwrap().len(), 1);
    let rover = &parsed.req_arr("reports").unwrap()[0];
    assert!(rover.req("train").unwrap().get("episodes").is_some());

    let sweep = SweepReport { updates: 0, batch: 0, rows: vec![] };
    let sj = sweep.to_json();
    assert_eq!(Json::parse(&sj.to_string()).unwrap(), sj);
    // the latency sweep moved to L1 when S1 became the scenario table
    assert_eq!(sj.req_str("id").unwrap(), "L1");
}

#[test]
fn scenario_table_s1_roundtrips_and_diffs_cleanly() {
    use qfpga::coordinator::{scenario_table, ScenarioSpec};

    // every env kind, tiny budget: the table must build on cpu + fpga-sim,
    // serialize to a parse↔print fixed point, and self-diff clean
    let spec = ScenarioSpec {
        episodes: 4,
        max_steps: 20,
        precision: Precision::Float,
        ..Default::default()
    };
    let t = scenario_table(&spec).unwrap();
    assert_eq!(Report::id(&t), "S1");
    // five rows per scenario: convergence, final reward, two Δrewards,
    // fpga advantage
    assert_eq!(t.rows.len(), 5 * EnvKind::all().len());
    for env in EnvKind::all() {
        assert!(
            t.rows.iter().any(|r| r.label.starts_with(env.as_str())),
            "no rows for `{}`",
            env.as_str()
        );
    }

    let j = Report::to_json(&t);
    assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    let d = diff_json(&j, &j, 0.01);
    assert!(d.ok(), "{:?}", d.problems);
    assert!(d.compared >= t.rows.len(), "only {} values compared", d.compared);
}
