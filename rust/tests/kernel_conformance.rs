//! Differential kernel conformance: the SIMD-shaped and scalar kernels,
//! and every precision arm they serve, must be indistinguishable through
//! the public surfaces.
//!
//! Two layers of evidence:
//!
//! * **kernel vs kernel** — a `PreparedNet` driven through random
//!   interleavings of parameter loads, stepwise updates, action-selection
//!   forwards and batched flushes must produce the same bits whether the
//!   datapath is pinned to [`KernelPath::Scalar`] or [`KernelPath::Simd`]
//!   (the chunked kernels keep each output's accumulation order, so even
//!   float is expected exact; the contract asserted here is bit-exact for
//!   the quantized arms and 1e-5 for float).
//! * **engine vs engine** — the factory-built CPU backend (fake-quant nn
//!   kernels) and FPGA-sim backend (integer datapath for fixed/int8, nn
//!   delegation for float/binary) must agree within the established
//!   cross-engine budgets under the same random interleavings.
//!
//! The CI `kernel-conformance` job runs this suite twice — once with
//! `QFPGA_KERNEL=scalar` and once without — so both dispatch targets see
//! the full interleaving space; `kernel_dispatch_reflects_the_environment`
//! pins the env wiring itself in whichever mode the suite runs.

use qfpga::config::{Hyper, NetConfig, Precision};
use qfpga::coordinator::sweep::Workload;
use qfpga::experiment::{AnyBackend, BackendFactory, BackendSpec};
use qfpga::fixed::FixedSpec;
use qfpga::fpga::{TimingModel, Virtex7};
use qfpga::nn::params::QNetParams;
use qfpga::nn::{Datapath, KernelPath, PreparedNet};
use qfpga::qlearn::backend::QBackend;
use qfpga::util::Rng;

fn cpu(net: NetConfig, prec: Precision, params: QNetParams) -> AnyBackend {
    BackendFactory::offline()
        .build(&BackendSpec::cpu(net, prec), params)
        .expect("cpu backend")
}

fn sim(net: NetConfig, prec: Precision, params: QNetParams) -> AnyBackend {
    BackendFactory::offline()
        .build(&BackendSpec::fpga_sim(net, prec), params)
        .expect("fpga-sim backend")
}

/// Grid step of the quantized arms (0 for the arms without a fixed grid).
fn grid_lsb(prec: Precision) -> f32 {
    match prec {
        Precision::Fixed => FixedSpec::default().lsb() as f32,
        Precision::Int8 => FixedSpec::int8().lsb() as f32,
        Precision::Float | Precision::Binary => 0.0,
    }
}

/// Scalar-vs-SIMD budget: bit-exact on the quantized grids, 1e-5 float.
fn kernel_tol(prec: Precision) -> f32 {
    match prec {
        Precision::Fixed | Precision::Int8 | Precision::Binary => 0.0,
        Precision::Float => 1e-5,
    }
}

/// Cross-engine budget for the `k`-th update of a stream: float and binary
/// ride the identical nn op chain on both engines; fixed and int8 diverge
/// by a bounded number of LSBs of their grids per step (the integer
/// engine's wide accumulators round once where fake-quant rounds in f32).
fn engine_tol(prec: Precision, k: usize) -> f32 {
    match prec {
        Precision::Float => 1e-5,
        Precision::Binary => 0.0,
        Precision::Fixed | Precision::Int8 => 4.0 * grid_lsb(prec) * (k as f32 + 1.0),
    }
}

// -------------------------------------------------------------- dispatch

/// The runtime dispatch must mirror `QFPGA_KERNEL` exactly — whichever
/// mode this suite runs under — and the in-process override must win over
/// the environment in both directions.
#[test]
fn kernel_dispatch_reflects_the_environment() {
    let want = match std::env::var("QFPGA_KERNEL") {
        Ok(v) if v == "scalar" => KernelPath::Scalar,
        _ => KernelPath::Simd,
    };
    assert_eq!(KernelPath::from_env(), want);
    for prec in Precision::all() {
        assert_eq!(Datapath::for_precision(prec).kernel(), want, "{prec:?}");
        for forced in [KernelPath::Scalar, KernelPath::Simd] {
            assert_eq!(
                Datapath::for_precision(prec).with_kernel(forced).kernel(),
                forced,
                "{prec:?}: with_kernel must beat the environment"
            );
        }
    }
}

// ------------------------------------------------------ kernel vs kernel

/// Random load/update/forward/batch interleavings through a `PreparedNet`:
/// the scalar and SIMD kernels must stay in lockstep at every observable
/// point, for every architecture and precision arm.
#[test]
fn scalar_and_simd_kernels_agree_under_random_interleavings() {
    let hyper = Hyper::default();
    let mut rng = Rng::seeded(0x51D);
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let dp_s = Datapath::for_precision(prec).with_kernel(KernelPath::Scalar);
            let dp_v = Datapath::for_precision(prec).with_kernel(KernelPath::Simd);
            let tol = kernel_tol(prec);
            let step = net.a * net.d;
            for case in 0..3 {
                let init = QNetParams::init(&net, 0.4, &mut rng);
                let mut p_s = PreparedNet::new(init.clone());
                let mut p_v = PreparedNet::new(init);
                let (mut qs, mut qv) = (Vec::new(), Vec::new());
                let ctx =
                    |op: usize| format!("{}/{} case {case} op {op}", net.name(), prec.as_str());
                for op in 0..24 {
                    match rng.below(4) {
                        // swap fresh (off-grid) parameters into both
                        0 => {
                            let fresh =
                                QNetParams::init(&net, rng.f32_range(0.1, 0.6), &mut rng);
                            p_s.load(&fresh);
                            p_v.load(&fresh);
                        }
                        // stepwise update
                        1 => {
                            let sc = rng.vec_f32(step, -1.0, 1.0);
                            let sn = rng.vec_f32(step, -1.0, 1.0);
                            let (a, r) = (rng.below(net.a), rng.f32_range(-1.0, 1.0));
                            let es =
                                p_s.update(&net, &sc, &sn, a, r, &hyper, &dp_s).unwrap();
                            let ev =
                                p_v.update(&net, &sc, &sn, a, r, &hyper, &dp_v).unwrap();
                            assert!(
                                (es - ev).abs() <= tol,
                                "{}: q_err {es} vs {ev}",
                                ctx(op)
                            );
                        }
                        // action-selection forward
                        2 => {
                            let sa = rng.vec_f32(step, -1.0, 1.0);
                            p_s.forward_into(&net, &sa, &dp_s, &mut qs).unwrap();
                            p_v.forward_into(&net, &sa, &dp_v, &mut qv).unwrap();
                            for (i, (s, v)) in qs.iter().zip(&qv).enumerate() {
                                assert!(
                                    (s - v).abs() <= tol,
                                    "{}: q[{i}] {s} vs {v}",
                                    ctx(op)
                                );
                            }
                        }
                        // batched flush of 1..=4 transitions
                        _ => {
                            let b = rng.range(1, 5);
                            let sc = rng.vec_f32(b * step, -1.0, 1.0);
                            let sn = rng.vec_f32(b * step, -1.0, 1.0);
                            let actions: Vec<usize> =
                                (0..b).map(|_| rng.below(net.a)).collect();
                            let rewards = rng.vec_f32(b, -1.0, 1.0);
                            let (mut es, mut ev) = (Vec::new(), Vec::new());
                            p_s.update_batch(
                                &net, &sc, &sn, &actions, &rewards, &hyper, &dp_s, &mut es,
                            )
                            .unwrap();
                            p_v.update_batch(
                                &net, &sc, &sn, &actions, &rewards, &hyper, &dp_v, &mut ev,
                            )
                            .unwrap();
                            for (i, (s, v)) in es.iter().zip(&ev).enumerate() {
                                assert!(
                                    (s - v).abs() <= tol,
                                    "{}: batch q_err[{i}] {s} vs {v}",
                                    ctx(op)
                                );
                            }
                        }
                    }
                }
                let drift = p_s.params().max_abs_diff(p_v.params());
                assert!(
                    drift <= tol,
                    "{}/{} case {case}: params diverged by {drift}",
                    net.name(),
                    prec.as_str()
                );
            }
        }
    }
}

// ------------------------------------------------------ engine vs engine

/// The factory-built CPU and FPGA-sim backends driven through the same
/// random interleavings of stepwise updates, Q-value reads and batched
/// flushes: agreement within the cross-engine budgets at every step, for
/// every backend pair × precision arm. (Q-value reads are compared
/// directly where both engines share the nn op chain — float and binary;
/// for the integer arms forward agreement is implied transitively by the
/// q_err stream, which embeds both engines' forward results.)
#[test]
fn cpu_and_fpga_sim_backends_agree_under_random_interleavings() {
    let n = 24;
    let mut rng = Rng::seeded(0xC0F0);
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let mut seed_rng = Rng::seeded(8008 ^ net.a as u64);
            let params = QNetParams::init(&net, 0.35, &mut seed_rng);
            let w = Workload::synthetic(net, n, 8008 ^ 0x5EED);
            let mut cpu = cpu(net, prec, params.clone());
            let mut sim = sim(net, prec, params);
            let step = net.a * net.d;
            let ctx = format!("cpu-vs-sim {}/{}", net.name(), prec.as_str());

            let mut k = 0usize; // transitions consumed so far
            while k < n {
                match rng.below(3) {
                    // stepwise update off the shared workload
                    0 => {
                        let sc = &w.sa_cur[k * step..(k + 1) * step];
                        let sn = &w.sa_next[k * step..(k + 1) * step];
                        let ec = cpu.update(sc, sn, w.actions[k], w.rewards[k]).unwrap();
                        let es = sim.update(sc, sn, w.actions[k], w.rewards[k]).unwrap();
                        let tol = engine_tol(prec, k);
                        assert!(
                            (ec - es).abs() <= tol,
                            "{ctx}: q_err[{k}] {ec} vs {es} (tol {tol})"
                        );
                        k += 1;
                    }
                    // action-selection read on fresh state
                    1 => {
                        let sa = rng.vec_f32(step, -1.0, 1.0);
                        let qc = cpu.q_values(&sa).unwrap();
                        let qs = sim.q_values(&sa).unwrap();
                        assert_eq!(qc.len(), qs.len(), "{ctx}");
                        for (i, (c, s)) in qc.iter().zip(&qs).enumerate() {
                            assert!(c.is_finite() && s.is_finite(), "{ctx}: q[{i}]");
                            if matches!(prec, Precision::Float | Precision::Binary) {
                                assert!(
                                    (c - s).abs() <= engine_tol(prec, 0),
                                    "{ctx}: q[{i}] {c} vs {s}"
                                );
                            }
                        }
                    }
                    // batched flush of 1..=4 transitions
                    _ => {
                        let b = rng.range(1, 5).min(n - k);
                        let batch = w.flat_batch(k, b);
                        let ec = cpu.update_batch(&batch).unwrap();
                        let es = sim.update_batch(&batch).unwrap();
                        for i in 0..b {
                            let tol = engine_tol(prec, k + i);
                            assert!(
                                (ec[i] - es[i]).abs() <= tol,
                                "{ctx}: batch q_err[{}] {} vs {} (tol {tol})",
                                k + i,
                                ec[i],
                                es[i]
                            );
                        }
                        k += b;
                    }
                }
            }
            let param_tol = match prec {
                Precision::Float => 1e-5,
                Precision::Binary => 0.0,
                Precision::Fixed | Precision::Int8 => 4.0 * grid_lsb(prec) * n as f32,
            };
            let drift = cpu.params().max_abs_diff(&sim.params());
            assert!(drift <= param_tol, "{ctx}: params diverged by {drift}");
        }
    }
}

// --------------------------------------------------- BM1 float anomaly

/// BM1's float rows show *no* batched gain — stepwise and batched
/// throughput coincide. That is the model's design, not a bug: the serial
/// LogiCORE MAC chains leave no action-level overlap to exploit, so
/// batched cycles are exactly `b ×` the stepwise cost (see
/// [`TimingModel::qupdate_batch_cycles`]). This regression pins the two
/// sides of the anomaly: float batched per-update throughput never falls
/// *below* stepwise (it is exactly equal), while every other arm gains
/// strictly from `b ≥ 2`.
#[test]
fn bm1_float_batching_never_regresses_per_update_throughput() {
    let dev = Virtex7::default();
    for t in [TimingModel::default(), TimingModel::pipelined()] {
        for net in NetConfig::all() {
            let stepwise_fp = t.qupdate(&net, Precision::Float).total();
            for b in [1usize, 2, 8, 32] {
                // float: cycles are exactly b × stepwise ⇒ per-update
                // throughput equal, never worse
                assert_eq!(
                    t.qupdate_batch_cycles(&net, Precision::Float, b),
                    b as u64 * stepwise_fp,
                    "{}: float batched diverged from b × stepwise",
                    net.name()
                );
                assert!(
                    t.batch_throughput_kq_s(&net, Precision::Float, b, &dev)
                        >= t.throughput_kq_s(&net, Precision::Float, &dev) - 1e-9,
                    "{}: float batched throughput regressed at b={b}",
                    net.name()
                );
                // the quantized arms strictly gain from batching
                for prec in [Precision::Fixed, Precision::Int8, Precision::Binary] {
                    if b >= 2 {
                        assert!(
                            t.qupdate_batch_cycles(&net, prec, b)
                                < b as u64 * t.qupdate(&net, prec).total(),
                            "{}/{prec:?}: no batched gain at b={b}",
                            net.name()
                        );
                    }
                }
            }
        }
    }
}
