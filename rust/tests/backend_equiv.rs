//! X4 cross-backend equivalence: the three compute backends must agree.
//!
//! * XLA artifact (Pallas kernel lowered to HLO, executed via PJRT)
//! * pure-Rust NN (`qfpga::nn`, the CPU baseline)
//! * FPGA datapath simulator (`qfpga::fpga`)
//!
//! Float paths must agree to f32 round-off; fixed paths to a small LSB
//! budget (the integer datapath accumulates exactly where the f32
//! fake-quant path rounds; see fpga module docs).
//!
//! These tests skip silently when `artifacts/` has not been built — run
//! `make artifacts` first for full coverage (CI always does).

use qfpga::config::{Hyper, NetConfig, Precision};
use qfpga::fixed::FixedSpec;
use qfpga::fpga::datapath::Transition;
use qfpga::fpga::FpgaAccelerator;
use qfpga::nn::params::QNetParams;
use qfpga::nn::qupdate::{self, Datapath};
use qfpga::runtime::{ArtifactKind, Runtime};
use qfpga::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = qfpga::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn dp(prec: Precision) -> Datapath {
    Datapath::for_precision(prec)
}

fn tolerance(prec: Precision) -> f32 {
    match prec {
        // fixed: python fake-quant (f32) vs rust fake-quant (f64 rounding)
        // can differ by one grid step at rounding boundaries
        Precision::Fixed => 2.0 * FixedSpec::default().lsb() as f32,
        Precision::Int8 => 2.0 * FixedSpec::int8().lsb() as f32,
        // no XLA artifacts exist for the binary arm (see
        // experiment::spec), but the budget is well defined: the sign
        // grid is exact
        Precision::Binary => 0.0,
        Precision::Float => 2e-6,
    }
}

#[test]
fn xla_forward_matches_rust_nn() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seeded(100);
    for net in NetConfig::all() {
        for prec in [Precision::Float, Precision::Fixed] {
            let exe = rt.select(&net, prec, ArtifactKind::Forward).unwrap();
            let params = QNetParams::init(&net, 0.4, &mut rng);
            let sa = rng.vec_f32(net.a * net.d, -1.0, 1.0);

            let got = exe.run_forward(&params, &sa).unwrap();
            let want = qupdate::forward(&net, &params, &sa, &dp(prec)).unwrap();

            assert_eq!(got.len(), net.a);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= tolerance(prec),
                    "{}/{prec:?} q[{i}]: xla {g} vs nn {w}",
                    net.name()
                );
            }
        }
    }
}

#[test]
fn xla_qupdate_matches_rust_nn() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seeded(101);
    for net in NetConfig::all() {
        for prec in [Precision::Float, Precision::Fixed] {
            let exe = rt.select(&net, prec, ArtifactKind::QUpdate).unwrap();
            let params = QNetParams::init(&net, 0.4, &mut rng);
            let sa_cur = rng.vec_f32(net.a * net.d, -1.0, 1.0);
            let sa_next = rng.vec_f32(net.a * net.d, -1.0, 1.0);
            let action = rng.below(net.a);
            let reward = rng.f32_range(-1.0, 1.0);

            let got = exe
                .run_qupdate(&params, &sa_cur, &sa_next, action, reward)
                .unwrap();
            let want = qupdate::qupdate(
                &net, &params, &sa_cur, &sa_next, action, reward,
                &Hyper::default(), &dp(prec),
            )
            .unwrap();

            let tol = tolerance(prec);
            assert!(
                (got.q_err - want.q_err).abs() <= tol,
                "{}/{prec:?} q_err: {} vs {}",
                net.name(),
                got.q_err,
                want.q_err
            );
            assert!(
                got.params.max_abs_diff(&want.params) <= tol,
                "{}/{prec:?}: params diverged by {}",
                net.name(),
                got.params.max_abs_diff(&want.params)
            );
        }
    }
}

#[test]
fn xla_train_batch_matches_sequential_qupdates() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seeded(102);
    for net in NetConfig::all() {
        let prec = Precision::Float;
        let batch_exe = rt.select(&net, prec, ArtifactKind::TrainBatch).unwrap();
        let b = batch_exe.meta().batch;
        let params = QNetParams::init(&net, 0.4, &mut rng);
        let sa_cur = rng.vec_f32(b * net.a * net.d, -1.0, 1.0);
        let sa_next = rng.vec_f32(b * net.a * net.d, -1.0, 1.0);
        let actions: Vec<i32> = (0..b).map(|_| rng.below(net.a) as i32).collect();
        let rewards = rng.vec_f32(b, -1.0, 1.0);

        let (batch_params, q_errs) = batch_exe
            .run_train_batch(&params, &sa_cur, &sa_next, &actions, &rewards)
            .unwrap();

        // sequential oracle
        let mut p = params;
        let step = net.a * net.d;
        let mut want_errs = Vec::with_capacity(b);
        for i in 0..b {
            let out = qupdate::qupdate(
                &net,
                &p,
                &sa_cur[i * step..(i + 1) * step],
                &sa_next[i * step..(i + 1) * step],
                actions[i] as usize,
                rewards[i],
                &Hyper::default(),
                &dp(prec),
            )
            .unwrap();
            p = out.params;
            want_errs.push(out.q_err);
        }

        assert_eq!(q_errs.len(), b);
        for (i, (g, w)) in q_errs.iter().zip(&want_errs).enumerate() {
            assert!((g - w).abs() <= 1e-5, "{} err[{i}]: {g} vs {w}", net.name());
        }
        assert!(
            batch_params.max_abs_diff(&p) <= 1e-5,
            "{}: batch params diverged",
            net.name()
        );
    }
}

#[test]
fn fpga_sim_matches_xla_within_lsb_budget() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seeded(103);
    for net in NetConfig::all() {
        for prec in [Precision::Float, Precision::Fixed] {
            let exe = rt.select(&net, prec, ArtifactKind::QUpdate).unwrap();
            let params = QNetParams::init(&net, 0.4, &mut rng);
            let sa_cur = rng.vec_f32(net.a * net.d, -1.0, 1.0);
            let sa_next = rng.vec_f32(net.a * net.d, -1.0, 1.0);
            let action = rng.below(net.a);
            let reward = rng.f32_range(-1.0, 1.0);

            let xla_out = exe
                .run_qupdate(&params, &sa_cur, &sa_next, action, reward)
                .unwrap();

            let mut acc = FpgaAccelerator::paper(net, prec, &params, Hyper::default());
            let (sim_out, _) = acc
                .qupdate(&Transition {
                    sa_cur: &sa_cur,
                    sa_next: &sa_next,
                    action,
                    reward,
                })
                .unwrap();

            // integer datapath vs float32 fake-quant: budget a few LSB
            let tol = match prec {
                Precision::Fixed => 4.0 * FixedSpec::default().lsb() as f32,
                Precision::Int8 => 4.0 * FixedSpec::int8().lsb() as f32,
                Precision::Float | Precision::Binary => 2e-6,
            };
            assert!(
                (sim_out.q_err - xla_out.q_err).abs() <= tol,
                "{}/{prec:?} q_err: sim {} vs xla {}",
                net.name(),
                sim_out.q_err,
                xla_out.q_err
            );
            assert!(
                sim_out.params.max_abs_diff(&xla_out.params) <= tol,
                "{}/{prec:?} params diverged",
                net.name()
            );
        }
    }
}

#[test]
fn executor_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let net = NetConfig::all()[0];
    let exe = rt.select(&net, Precision::Float, ArtifactKind::Forward).unwrap();
    let params = QNetParams::zeros(&net);
    let bad_sa = vec![0f32; 3];
    assert!(exe.run_forward(&params, &bad_sa).is_err());
    // wrong kind
    assert!(exe.run_qupdate(&params, &bad_sa, &bad_sa, 0, 0.0).is_err());
}

#[test]
fn runtime_caches_compiled_executors() {
    let Some(rt) = runtime() else { return };
    let net = NetConfig::all()[0];
    assert_eq!(rt.compiled_count(), 0);
    let _a = rt.select(&net, Precision::Float, ArtifactKind::Forward).unwrap();
    let _b = rt.select(&net, Precision::Float, ArtifactKind::Forward).unwrap();
    assert_eq!(rt.compiled_count(), 1);
}
