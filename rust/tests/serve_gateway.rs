//! Gateway end-to-end over a real unix socket: submit → progress →
//! result, resubmission answered bit-identically from the cache, results
//! invariant across worker widths, backpressure rejects when the queue is
//! full, drain completes every accepted job, and a high-priority
//! submission preempts a running mission without losing work.
//!
//! Integration tests run in their own process, so the process-global
//! shutdown flag is reset defensively at the top of each test; the serve
//! tests never raise it (drains go through `GatewayHandle::drain`).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use qfpga::coordinator::MissionConfig;
use qfpga::obs::manifest::report_sha256;
use qfpga::serve::{
    job_mix, Client, GatewayHandle, JobSpec, Request, Response, ServeConfig,
};
use qfpga::util::shutdown;

fn sock(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("qfpga-e2e-{}-{tag}.sock", std::process::id()))
}

fn tiny_train(seed: u64) -> JobSpec {
    JobSpec::Train(MissionConfig { episodes: 4, max_steps: 12, seed, ..Default::default() })
}

#[test]
fn submit_streams_progress_then_result() {
    shutdown::reset();
    let handle = GatewayHandle::spawn(ServeConfig::new(sock("stream"))).unwrap();
    let mut client = Client::connect(&handle.socket()).unwrap();
    let mut episodes = Vec::new();
    let out = client
        .submit_and_wait(&tiny_train(31), 1, true, &mut |resp| {
            if let Response::Progress { sample, .. } = resp {
                episodes.push(sample.episode);
            }
        })
        .unwrap();
    assert!(out.ok, "{:?}", out.error);
    assert!(!out.cache_hit);
    assert_eq!(out.report_id, "EXP");
    // the progress throttle must always include the final episode
    assert_eq!(episodes.last(), Some(&3));
    // the advertised hash is the deterministic projection of the document
    assert_eq!(out.report_sha256, report_sha256(&out.report));
    handle.drain();
    let stats = handle.join().unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.cache_hits, 0);
}

#[test]
fn resubmission_hits_the_cache_bit_identically() {
    shutdown::reset();
    let handle = GatewayHandle::spawn(ServeConfig::new(sock("cache"))).unwrap();
    let mut client = Client::connect(&handle.socket()).unwrap();
    let job = tiny_train(32);
    let first = client.submit_and_wait(&job, 1, false, &mut |_| {}).unwrap();
    let again = client.submit_and_wait(&job, 1, false, &mut |_| {}).unwrap();
    assert!(first.ok && !first.cache_hit);
    assert!(again.ok && again.cache_hit);
    // byte-identical document, not just an equal hash
    assert_eq!(first.report.to_string(), again.report.to_string());
    assert_eq!(first.report_sha256, again.report_sha256);
    // and the gateway's answer is exactly what a local run produces
    let local = job.run(&|_| {}).unwrap();
    assert_eq!(report_sha256(&local), first.report_sha256);
    handle.drain();
    let stats = handle.join().unwrap();
    assert_eq!(stats.submitted, 2);
    assert_eq!(stats.cache_hits, 1);
}

#[test]
fn results_are_invariant_across_worker_widths() {
    shutdown::reset();
    let jobs = job_mix(5, 2, 10, 900);
    let mut by_width: Vec<BTreeMap<String, String>> = Vec::new();
    for (i, &w) in [1usize, 3].iter().enumerate() {
        let mut cfg = ServeConfig::new(sock(&format!("width{i}")));
        cfg.workers = w;
        let handle = GatewayHandle::spawn(cfg).unwrap();
        let socket = handle.socket();
        // all five jobs in flight at once, each on its own connection
        let hashes: BTreeMap<String, String> = std::thread::scope(|s| {
            let workers: Vec<_> = jobs
                .iter()
                .map(|job| {
                    let socket = socket.clone();
                    s.spawn(move || {
                        let mut client = Client::connect(&socket).unwrap();
                        let out =
                            client.submit_and_wait(job, 1, false, &mut |_| {}).unwrap();
                        assert!(out.ok, "{:?}", out.error);
                        (job.key(), out.report_sha256)
                    })
                })
                .collect();
            workers.into_iter().map(|h| h.join().unwrap()).collect()
        });
        handle.drain();
        handle.join().unwrap();
        by_width.push(hashes);
    }
    assert_eq!(by_width[0], by_width[1], "reports depend on worker width");
}

#[test]
fn full_queue_rejects_with_a_retry_hint() {
    shutdown::reset();
    let mut cfg = ServeConfig::new(sock("full"));
    cfg.workers = 1;
    cfg.queue_capacity = 1;
    let handle = GatewayHandle::spawn(cfg).unwrap();
    let busy = |seed: u64| {
        JobSpec::Train(MissionConfig {
            episodes: 400,
            max_steps: 80,
            seed,
            ..Default::default()
        })
    };
    // first job occupies the single worker...
    let mut first = Client::connect(&handle.socket()).unwrap();
    let accepted = first
        .request(&Request::Submit { job: busy(50), priority: 1, stream: false })
        .unwrap();
    assert!(matches!(accepted, Response::Accepted { .. }), "{}", accepted.to_json());
    let mut health = Client::connect(&handle.socket()).unwrap();
    loop {
        match health.request(&Request::Healthz).unwrap() {
            Response::Health { in_flight: 1.., .. } => break,
            _ => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    // ...the second fills the queue...
    let mut second = Client::connect(&handle.socket()).unwrap();
    let queued = second
        .request(&Request::Submit { job: busy(51), priority: 1, stream: false })
        .unwrap();
    assert!(matches!(queued, Response::Accepted { .. }), "{}", queued.to_json());
    loop {
        match health.request(&Request::Healthz).unwrap() {
            Response::Health { queue_depth: 1.., .. } => break,
            _ => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    // ...so the third must bounce with backpressure, not block or drop
    let mut third = Client::connect(&handle.socket()).unwrap();
    match third
        .request(&Request::Submit { job: busy(52), priority: 1, stream: false })
        .unwrap()
    {
        Response::Rejected { reason, retry_after_ms } => {
            assert!(reason.contains("queue full"), "{reason}");
            assert!(retry_after_ms >= 100);
        }
        other => panic!("expected rejected, got {}", other.to_json()),
    }
    handle.drain();
    let stats = handle.join().unwrap();
    assert_eq!(stats.submitted, 3);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.rejected, 1);
}

#[test]
fn drain_completes_every_accepted_job() {
    shutdown::reset();
    let mut cfg = ServeConfig::new(sock("drain"));
    cfg.workers = 2;
    let handle = GatewayHandle::spawn(cfg).unwrap();
    // accept four unique jobs without waiting for their results, keeping
    // each connection open so the daemon still owes a terminal frame
    let mut clients = Vec::new();
    for i in 0..4u64 {
        let mut c = Client::connect(&handle.socket()).unwrap();
        let resp = c
            .request(&Request::Submit { job: tiny_train(700 + i), priority: 1, stream: false })
            .unwrap();
        assert!(matches!(resp, Response::Accepted { .. }), "{}", resp.to_json());
        clients.push(c);
    }
    handle.drain();
    let stats = handle.join().unwrap();
    // a drain may not strand accepted work: every job ran to completion
    assert_eq!(stats.submitted, 4);
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.rejected, 0);
    drop(clients);
}

#[test]
fn high_priority_submission_preempts_without_losing_work() {
    shutdown::reset();
    let mut cfg = ServeConfig::new(sock("preempt"));
    cfg.workers = 1;
    cfg.chunk = 2;
    let handle = GatewayHandle::spawn(cfg).unwrap();
    let long = JobSpec::Train(MissionConfig {
        episodes: 300,
        max_steps: 100,
        seed: 77,
        ..Default::default()
    });
    let expected = report_sha256(&long.run(&|_| {}).unwrap());
    let socket = handle.socket();
    let (long_out, quick_out) = std::thread::scope(|s| {
        let long_job = &long;
        let socket_a = socket.clone();
        let waiter = s.spawn(move || {
            Client::connect(&socket_a)
                .unwrap()
                .submit_and_wait(long_job, 1, false, &mut |_| {})
                .unwrap()
        });
        // wait until the long mission owns the single worker
        let mut health = Client::connect(&socket).unwrap();
        loop {
            match health.request(&Request::Healthz).unwrap() {
                Response::Health { in_flight: 1.., .. } => break,
                _ => std::thread::sleep(Duration::from_millis(2)),
            }
        }
        let quick = tiny_train(78);
        let quick_out = Client::connect(&socket)
            .unwrap()
            .submit_and_wait(&quick, 9, false, &mut |_| {})
            .unwrap();
        (waiter.join().unwrap(), quick_out)
    });
    assert!(quick_out.ok, "{:?}", quick_out.error);
    assert!(long_out.ok, "{:?}", long_out.error);
    handle.drain();
    let stats = handle.join().unwrap();
    assert!(stats.preemptions >= 1, "long mission was never preempted ({stats:?})");
    assert_eq!(long_out.preemptions, stats.preemptions);
    assert_eq!(quick_out.preemptions, 0);
    // the checkpoint/resume cycle must not change a single bit
    assert_eq!(long_out.report_sha256, expected, "preempted+resumed run diverged");
}
