//! Shared-fleet contract: fleet learning (transition exchange + parameter
//! averaging) is bit-identical at every worker-pool width, the averaging
//! round is the hand-computable order-invariant mean on every precision
//! arm, a schedule that never fires leaves the isolated trajectory
//! untouched (the regression pin for the isolated pool), and a shared
//! fleet drained at a round boundary resumes to the uninterrupted run's
//! report hash.

use qfpga::config::{Arch, EnvKind, NetConfig, Precision};
use qfpga::coordinator::MissionConfig;
use qfpga::experiment::{Experiment, ExperimentReport};
use qfpga::nn::params::QNetParams;
use qfpga::nn::Datapath;
use qfpga::obs::manifest::report_sha256;
use qfpga::qlearn::backend::BackendKind;
use qfpga::qlearn::share::average_params;
use qfpga::qlearn::SharePlan;
use qfpga::util::{shutdown, Rng};
use qfpga::Report;

fn quick_cfg() -> MissionConfig {
    MissionConfig {
        episodes: 8,
        max_steps: 40,
        backend: BackendKind::Cpu,
        precision: Precision::Float,
        ..Default::default()
    }
}

fn plan() -> SharePlan {
    SharePlan { exchange_every: 2, avg_every: 4, pool_cap: 4 }
}

/// Per-rover fingerprint strict enough to catch any trajectory change:
/// every episode's (steps, reward bits, ε bits) plus the update count.
fn fingerprint(r: &ExperimentReport) -> Vec<(String, u64, Vec<(usize, u32, u32)>)> {
    r.rovers
        .iter()
        .map(|m| {
            (
                m.config_desc.clone(),
                m.train.total_updates,
                m.train
                    .episodes
                    .iter()
                    .map(|e| (e.steps, e.total_reward.to_bits(), e.epsilon.to_bits()))
                    .collect(),
            )
        })
        .collect()
}

fn shared(cfg: &MissionConfig, rovers: usize, workers: usize, p: SharePlan) -> ExperimentReport {
    Experiment::from_mission(cfg)
        .rovers(rovers)
        .workers(workers)
        .share(p)
        .run()
        .unwrap()
}

/// The tentpole acceptance contract: a shared fleet reproduces itself
/// bit-exactly at every `--workers` width, including the single-worker
/// reference — exchange and averaging happen at episode-counted round
/// boundaries in rover-id order, never thread-arrival order.
#[test]
fn shared_fleet_is_bit_identical_at_every_worker_width() {
    let cfg = quick_cfg();
    let want = shared(&cfg, 4, 1, plan()); // fully serial reference
    assert_eq!(want.rovers.len(), 4);
    let summary = want.share.expect("shared run must report its schedule");
    assert_eq!(summary.exchanges, 3); // episodes 2, 4, 6 (not the final 8)
    assert_eq!(summary.avg_rounds, 1); // episode 4 only

    for workers in [2usize, 4] {
        let got = shared(&cfg, 4, workers, plan());
        assert_eq!(
            fingerprint(&got),
            fingerprint(&want),
            "{workers}-worker shared fleet diverged from the serial reference"
        );
        assert_eq!(got.share, Some(summary), "{workers}-worker schedule drifted");
    }

    // sharing changes the trajectory: rovers really learn from each other
    let isolated = Experiment::from_mission(&cfg).rovers(4).run().unwrap();
    assert_ne!(
        fingerprint(&want),
        fingerprint(&isolated),
        "the share schedule fired {} exchange(s) yet left trajectories untouched",
        summary.exchanges
    );
}

/// One averaging round equals the hand-computed mean — per element: sort
/// the contributions by total order, sum in f64, divide, round to f32 and
/// re-quantize onto the datapath grid — on every precision arm.
#[test]
fn averaging_round_matches_the_hand_mean_on_every_precision_arm() {
    let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
    for prec in Precision::all() {
        let dp = Datapath::for_precision(prec);
        let mut rng = Rng::seeded(9102);
        let sets: Vec<QNetParams> =
            (0..3).map(|_| QNetParams::init(&net, 0.3, &mut rng)).collect();
        let avg = average_params(&sets, &net, &dp).unwrap();

        let tensors: Vec<Vec<Vec<f32>>> = sets.iter().map(QNetParams::to_tensors).collect();
        let got = avg.to_tensors();
        for t in 0..got.len() {
            for e in 0..got[t].len() {
                let mut vals: Vec<f32> = tensors.iter().map(|ts| ts[t][e]).collect();
                vals.sort_by(f32::total_cmp);
                let mean = (vals.iter().map(|&v| v as f64).sum::<f64>() / 3.0) as f32;
                assert_eq!(
                    got[t][e].to_bits(),
                    dp.q(mean).to_bits(),
                    "{prec:?}: tensor {t} elem {e}"
                );
            }
        }
    }
}

/// A share schedule whose exchange cadence never lands inside the mission
/// (and with averaging off) must leave the fleet bit-identical to the
/// plain isolated pool — the outbox tap may never perturb a trajectory.
/// This is the regression pin for every pre-sharing fleet user.
#[test]
fn never_firing_schedule_is_bit_identical_to_the_isolated_fleet() {
    let cfg = quick_cfg();
    let never = SharePlan {
        exchange_every: cfg.episodes * 10, // far past the mission
        avg_every: 0,
        pool_cap: 4,
    };
    let isolated = Experiment::from_mission(&cfg).rovers(3).workers(2).run().unwrap();
    let inert = shared(&cfg, 3, 2, never);
    assert_eq!(fingerprint(&inert), fingerprint(&isolated));
    let summary = inert.share.unwrap();
    assert_eq!((summary.exchanges, summary.avg_rounds), (0, 0));
    assert!(isolated.share.is_none());
}

/// A shared fleet of one has nobody to exchange with and averages only
/// itself: its rover must be bit-identical to the isolated single-rover
/// reference even though every round boundary still fires.
#[test]
fn shared_fleet_of_one_matches_the_isolated_single_rover() {
    let cfg = quick_cfg();
    let alone = shared(&cfg, 1, 1, plan());
    let reference = Experiment::from_mission(&cfg).rovers(1).run().unwrap();
    assert_eq!(fingerprint(&alone), fingerprint(&reference));
    // the schedule still ran (and is reported) — it just had no effect
    assert_eq!(alone.share.unwrap().exchanges, 3);
}

/// Drain a shared fleet at its first round boundary, then resume from the
/// on-disk rover checkpoints: the completed run must hash identically to
/// the uninterrupted one, and checkpoints from a shared fleet must refuse
/// to resume under a different schedule or into an isolated fleet.
#[test]
fn drained_shared_fleet_resumes_to_the_uninterrupted_hash() {
    let cfg = quick_cfg();
    let p = plan();
    let dir = std::env::temp_dir()
        .join(format!("qfpga-share-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let want = shared(&cfg, 3, 2, p);

    shutdown::request(); // the signal lands before the first round finishes
    let partial = Experiment::from_mission(&cfg)
        .rovers(3)
        .workers(2)
        .share(p)
        .checkpoint(&dir, 100) // shared fleets save at round boundaries
        .drain_on_signal(true)
        .run()
        .unwrap();
    shutdown::reset();
    assert!(partial.interrupted);
    let done = partial.rovers[0].train.episodes.len();
    assert!(done >= 1 && done < cfg.episodes, "drained after {done}/{}", cfg.episodes);
    for i in 0..3 {
        assert!(dir.join(format!("rover-{i}.json")).exists(), "rover-{i} not checkpointed");
    }

    // a different schedule or an isolated resume must be rejected, not
    // silently blended into a different trajectory
    let other = SharePlan { exchange_every: 4, ..p };
    let err = Experiment::from_mission(&cfg)
        .rovers(3)
        .share(other)
        .checkpoint(&dir, 100)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("configuration"), "{err}");
    let err = Experiment::from_mission(&cfg)
        .rovers(3)
        .checkpoint(&dir, 100)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("configuration"), "{err}");

    let got = Experiment::from_mission(&cfg)
        .rovers(3)
        .workers(2)
        .share(p)
        .checkpoint(&dir, 100)
        .run()
        .unwrap();
    assert!(!got.interrupted);
    assert_eq!(fingerprint(&got), fingerprint(&want));
    assert_eq!(report_sha256(&got.to_json()), report_sha256(&want.to_json()));
    // completion clears the resume state
    for i in 0..3 {
        assert!(!dir.join(format!("rover-{i}.json")).exists(), "rover-{i} left behind");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
