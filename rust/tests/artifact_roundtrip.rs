//! Artifact pipeline integrity: manifest ↔ HLO files ↔ executors.
//!
//! Complements `backend_equiv` (numerics) with structural checks on the
//! build pipeline itself: every declared artifact exists, parses, compiles,
//! and honors its declared interface; hyper-parameters baked into the
//! artifacts match the rust defaults; failure modes are clean errors.

use qfpga::config::{Hyper, NetConfig, Precision};
use qfpga::nn::params::QNetParams;
use qfpga::runtime::{default_artifact_dir, ArtifactKind, Manifest, Runtime};
use qfpga::util::{Json, Rng};

fn manifest() -> Option<Manifest> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Manifest::load(&dir).unwrap())
}

#[test]
fn every_config_has_all_three_kinds() {
    let Some(m) = manifest() else { return };
    for net in NetConfig::all() {
        for prec in [Precision::Fixed, Precision::Float] {
            for kind in [ArtifactKind::Forward, ArtifactKind::QUpdate, ArtifactKind::TrainBatch] {
                let meta = m.select(&net, prec, kind).unwrap();
                assert!(meta.file.exists());
                assert_eq!(meta.precision, prec);
            }
        }
    }
}

#[test]
fn hlo_files_have_full_constants_and_no_metadata() {
    // regression for the two xla_extension-0.5.1 parser hazards (aot.py):
    // elided large constants (`constant({...})`) execute as garbage, and
    // `source_end_line` metadata fails to parse at all.
    let Some(m) = manifest() else { return };
    for meta in m.artifacts.values() {
        let text = std::fs::read_to_string(&meta.file).unwrap();
        assert!(
            !text.contains("constant({...})"),
            "{}: elided constant would mis-execute under xla_extension 0.5.1",
            meta.name
        );
        assert!(
            !text.contains("source_end_line"),
            "{}: jax>=0.8 metadata breaks the 0.5.1 text parser",
            meta.name
        );
    }
}

#[test]
fn baked_hyper_matches_rust_default() {
    let Some(m) = manifest() else { return };
    let default = Hyper::default();
    for meta in m.artifacts.values() {
        assert_eq!(meta.hyper, default, "{}", meta.name);
    }
}

#[test]
fn declared_shapes_are_consistent() {
    let Some(m) = manifest() else { return };
    for meta in m.artifacts.values() {
        let net = meta.net;
        let n = meta.n_param_tensors();
        // parameter tensors lead, then the data inputs
        assert!(meta.inputs.len() > n, "{}", meta.name);
        // every qupdate output set starts with the updated parameters
        if meta.kind == ArtifactKind::QUpdate {
            assert_eq!(meta.outputs.len(), n + 3, "{}", meta.name);
            let q_cur = &meta.outputs[n];
            assert_eq!(q_cur.shape, vec![net.a], "{}", meta.name);
        }
        if meta.kind == ArtifactKind::TrainBatch {
            assert_eq!(meta.outputs.len(), n + 1, "{}", meta.name);
            assert_eq!(meta.outputs[n].shape, vec![meta.batch], "{}", meta.name);
        }
    }
}

#[test]
fn executors_compile_and_run_for_every_artifact() {
    let Some(_) = manifest() else { return };
    let rt = Runtime::from_default_dir().unwrap();
    let n = rt.warm_up().unwrap();
    assert!(n >= 24);
    // run one forward per config to prove the compiled modules execute
    let mut rng = Rng::seeded(71);
    for net in NetConfig::all() {
        for prec in [Precision::Fixed, Precision::Float] {
            let exe = rt.select(&net, prec, ArtifactKind::Forward).unwrap();
            let params = QNetParams::init(&net, 0.2, &mut rng);
            let sa = rng.vec_f32(net.a * net.d, -1.0, 1.0);
            let q = exe.run_forward(&params, &sa).unwrap();
            assert_eq!(q.len(), net.a);
            assert!(q.iter().all(|v| v.is_finite() && (0.0..=1.0).contains(v)));
        }
    }
}

#[test]
fn corrupt_manifest_is_rejected_cleanly() {
    let dir = std::env::temp_dir().join("qfpga_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("json parse error"), "{err}");

    // valid json, wrong version
    std::fs::write(dir.join("manifest.json"), r#"{"version": 99, "artifacts": {}}"#).unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_rejects_missing_hlo_file() {
    let Some(m) = manifest() else { return };
    // copy the manifest into a temp dir without the HLO files
    let dir = std::env::temp_dir().join("qfpga_missing_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let src = default_artifact_dir().join("manifest.json");
    std::fs::copy(src, dir.join("manifest.json")).unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("missing HLO file"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
    drop(m);
}

#[test]
fn manifest_json_is_valid_and_versioned() {
    let dir = default_artifact_dir();
    let Ok(text) = std::fs::read_to_string(dir.join("manifest.json")) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.req_usize("version").unwrap(), 1);
}
