//! Fault-subsystem determinism and masking guarantees:
//!
//! * same seed + rate + mitigation ⇒ bit-identical injected weights and
//!   identical campaign reports, across runs and across the fleet
//!   scheduler;
//! * same seed + rate schedule ⇒ bit-identical CRAM strike/repair logs,
//!   across runs and across fleet widths;
//! * TMR and SECDED fully mask single-bit flips on `Fixed` words at every
//!   `FixedSpec` the repo uses, and continuous configuration scrubbing
//!   masks every single-frame CRAM upset (seeded-random property sweeps,
//!   same style as `tests/proptests.rs`).

use qfpga::config::{Arch, EnvKind, NetConfig, Precision};
use qfpga::coordinator::sweep::{resilience, Workload};
use qfpga::coordinator::{run_fleet, MissionConfig};
use qfpga::experiment::{AnyBackend, BackendFactory, BackendSpec};
use qfpga::fault::{
    CramPlan, CramState, FaultModel, FaultPlan, FaultStats, FaultyBackend, FrameMap,
    Mitigation, ProtectedStore, RateSchedule, Secded, WordCodec,
};
use qfpga::fixed::{Fixed, FixedSpec};
use qfpga::nn::params::QNetParams;
use qfpga::qlearn::backend::{BackendKind, QBackend};
use qfpga::util::Rng;

/// Backends come from the factory — the only construction path.
fn build(kind: BackendKind, net: NetConfig, prec: Precision, seed: u64) -> AnyBackend {
    let mut rng = Rng::seeded(seed);
    let params = QNetParams::init(&net, 0.3, &mut rng);
    BackendFactory::offline()
        .build(&BackendSpec::new(kind, net, prec), params)
        .expect("backend")
}

const CASES: usize = 200;

/// Every fixed-point format the repo exercises: the DSP48 default plus the
/// X3 word-length ablation sweep.
fn specs_in_use() -> [FixedSpec; 6] {
    [
        FixedSpec::new(8, 4),
        FixedSpec::new(12, 8),
        FixedSpec::new(16, 8),
        FixedSpec::new(18, 12),
        FixedSpec::new(24, 16),
        FixedSpec::new(32, 24),
    ]
}

// ------------------------------------------------------------- determinism

fn drive_workload<B: QBackend>(backend: &mut B, net: &NetConfig, n: usize) -> Vec<f32> {
    let w = Workload::synthetic(*net, n, 501);
    let step = net.a * net.d;
    (0..n)
        .map(|i| {
            backend
                .update(
                    &w.sa_cur[i * step..(i + 1) * step],
                    &w.sa_next[i * step..(i + 1) * step],
                    w.actions[i],
                    w.rewards[i],
                )
                .unwrap()
        })
        .collect()
}

/// Same seed + rate + mitigation ⇒ bit-identical injected weights, for
/// both wrapped backends and both precisions.
#[test]
fn injected_weights_are_bit_identical_across_runs() {
    let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
    for prec in [Precision::Fixed, Precision::Float] {
        for mitigation in Mitigation::all() {
            let run_cpu = || {
                let inner = build(BackendKind::Cpu, net, prec, 9);
                let mut b = FaultyBackend::new(
                    inner,
                    prec,
                    mitigation,
                    FaultModel::new(1234, 1e-3),
                );
                drive_workload(&mut b, &net, 50);
                (b.params(), b.stats())
            };
            let run_sim = || {
                let inner = build(BackendKind::FpgaSim, net, prec, 9);
                let mut b = FaultyBackend::new(
                    inner,
                    prec,
                    mitigation,
                    FaultModel::new(1234, 1e-3),
                );
                drive_workload(&mut b, &net, 50);
                (b.params(), b.stats())
            };
            let (p1, s1) = run_cpu();
            let (p2, s2) = run_cpu();
            assert_eq!(p1, p2, "cpu {prec:?}/{}", mitigation.label());
            assert_eq!(s1, s2, "cpu {prec:?}/{}", mitigation.label());
            let (q1, t1) = run_sim();
            let (q2, t2) = run_sim();
            assert_eq!(q1, q2, "fpga-sim {prec:?}/{}", mitigation.label());
            assert_eq!(t1, t2, "fpga-sim {prec:?}/{}", mitigation.label());
        }
    }
}

/// Identical campaign reports across runs and across the fleet scheduler
/// (2-rover fleets, threaded collection).
#[test]
fn campaign_reports_are_identical_across_runs() {
    let base = MissionConfig {
        arch: Arch::Mlp,
        env: EnvKind::Simple,
        precision: Precision::Fixed,
        episodes: 5,
        max_steps: 30,
        seed: 11,
        ..Default::default()
    };
    let campaign = || {
        resilience(
            &base,
            &[BackendKind::Cpu, BackendKind::FpgaSim],
            &[2e-4],
            &[Mitigation::None, Mitigation::Tmr, Mitigation::Ecc],
            2,
        )
        .unwrap()
    };
    let a = campaign();
    let b = campaign();
    assert_eq!(a.cells.len(), 6);
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.backend, y.backend);
        assert_eq!(x.mitigation, y.mitigation);
        assert_eq!(x.learning_delta.to_bits(), y.learning_delta.to_bits());
        assert_eq!(x.baseline_delta.to_bits(), y.baseline_delta.to_bits());
        assert_eq!(x.stats, y.stats);
    }
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(a.render(), b.render());
}

/// Faulted fleets replay bit-identically rover by rover.
#[test]
fn faulted_fleet_is_reproducible_per_rover() {
    let cfg = MissionConfig {
        episodes: 5,
        max_steps: 30,
        backend: BackendKind::FpgaSim,
        fault: Some(FaultPlan::constant(5e-4, Mitigation::Scrub { interval: 16 })),
        ..Default::default()
    };
    let a = run_fleet(&cfg, 3).unwrap();
    let b = run_fleet(&cfg, 3).unwrap();
    let mut any_upsets = false;
    for (x, y) in a.rovers.iter().zip(&b.rovers) {
        assert_eq!(x.fault, y.fault);
        any_upsets |= x.fault.unwrap().total_upsets() > 0;
        for (ex, ey) in x.train.episodes.iter().zip(&y.train.episodes) {
            assert_eq!(ex.total_reward.to_bits(), ey.total_reward.to_bits());
        }
    }
    assert!(any_upsets, "fleet saw no radiation at 5e-4/bit/step");
    // distinct rovers get distinct seeds: trajectories and/or fault
    // exposure must differ
    let r0 = &a.rovers[0];
    let r1 = &a.rovers[1];
    assert!(
        r0.fault != r1.fault
            || r0.train.episodes[0].total_reward != r1.train.episodes[0].total_reward,
        "rover 0 and 1 are identical"
    );
}

// ----------------------------------------------------------- CRAM matrix

/// Every schedule shape × scrub arm: same seed + schedule ⇒ bit-identical
/// CRAM strike/repair logs and stats across runs; a different seed moves
/// the strikes.
#[test]
fn cram_logs_are_bit_identical_across_runs() {
    let frames = FrameMap::of(&NetConfig::new(Arch::Mlp, EnvKind::Simple), Precision::Fixed);
    let schedules: [Option<RateSchedule>; 3] = [
        None,
        Some(RateSchedule::Spike { base: 2e-6, peak: 2e-4, start: 20, len: 30 }),
        Some(RateSchedule::Phases(vec![(5e-5, 40), (5e-6, 60)])),
    ];
    for schedule in &schedules {
        for scrub in [None, Some(0), Some(8)] {
            let plan = CramPlan { rate: 2e-5, scrub };
            let run = |seed: u64| {
                let mut c = CramState::new(seed, plan, frames, schedule.clone());
                for chunk in [7u64, 1, 13, 4, 25, 50] {
                    c.advance(chunk);
                }
                (c.log().to_vec(), c.stats())
            };
            let (l1, s1) = run(77);
            let (l2, s2) = run(77);
            assert_eq!(l1, l2, "{schedule:?}/{scrub:?}: log diverged across runs");
            assert_eq!(s1, s2, "{schedule:?}/{scrub:?}: stats diverged");
            assert!(s1.cram_upsets > 0, "{schedule:?}/{scrub:?}: no strikes drawn");
            let (l3, _) = run(78);
            assert_ne!(l1, l3, "{schedule:?}/{scrub:?}: seed does not move strikes");
        }
    }
}

/// The same rover sees the same radiation regardless of fleet width: CRAM
/// strikes, repairs and trajectories derive from the rover's own seed,
/// never from the scheduler.
#[test]
fn cram_faulted_fleet_is_width_invariant() {
    let cfg = MissionConfig {
        episodes: 4,
        max_steps: 30,
        backend: BackendKind::FpgaSim,
        fault: Some(
            FaultPlan::constant(2e-4, Mitigation::None)
                .with_schedule(RateSchedule::Spike {
                    base: 2e-4,
                    peak: 2e-3,
                    start: 10,
                    len: 40,
                })
                .with_cram(CramPlan { rate: 2e-3, scrub: Some(16) }),
        ),
        ..Default::default()
    };
    let solo = run_fleet(&cfg, 1).unwrap();
    let wide = run_fleet(&cfg, 3).unwrap();
    let (a, b) = (&solo.rovers[0], &wide.rovers[0]);
    assert_eq!(a.fault, b.fault, "rover 0 fault exposure depends on fleet width");
    for (ex, ey) in a.train.episodes.iter().zip(&b.train.episodes) {
        assert_eq!(ex.total_reward.to_bits(), ey.total_reward.to_bits());
    }
    let s = a.fault.unwrap();
    assert!(s.cram_upsets > 0, "no CRAM strikes at 2e-3/bit/step");
    assert!(s.cram_repairs > 0, "scrub:16 never ran a repair pass");
}

/// Continuous readback scrubbing (`scrub: Some(0)`) masks every
/// single-frame upset: corruption never outlives the exposure window it
/// landed in, so the datapath transform is always the identity.
#[test]
fn prop_continuous_scrub_masks_every_frame_upset() {
    let frames = FrameMap::of(&NetConfig::new(Arch::Mlp, EnvKind::Simple), Precision::Fixed);
    let mut rng = Rng::seeded(0xC4A7);
    let params: Vec<f32> = (0..257).map(|i| (i as f32) * 0.125 - 16.0).collect();
    let mut total = 0;
    for case in 0..60 {
        let rate = [1e-4, 1e-5, 1e-6][rng.below(3)];
        let mut c = CramState::new(
            1000 + case as u64,
            CramPlan { rate, scrub: Some(0) },
            frames,
            None,
        );
        for _ in 0..rng.range(2, 8) {
            c.advance(rng.range(1, 40) as u64);
            assert_eq!(c.dirty_frames(), 0, "case {case}: corruption survived the window");
            let mut seen = params.clone();
            c.corrupt(&mut seen);
            assert_eq!(seen, params, "case {case}: corrupt() was not the identity");
        }
        let s = c.stats();
        // repairs are per frame, upsets per strike: same-window strikes on
        // one frame collapse into a single repair, never into survival
        assert!(s.cram_repairs <= s.cram_upsets, "case {case}");
        assert_eq!(s.cram_repairs > 0, s.cram_upsets > 0, "case {case}: unrepaired upsets");
        total += s.cram_upsets;
    }
    assert!(total > 0, "sweep never drew a strike");
}

/// A solar-event spike integrates to exactly the fluence of the
/// equivalent constant — base rate over the horizon plus the excess over
/// the event window — whether integrated one-shot or in random chunks.
#[test]
fn prop_spike_fluence_matches_equivalent_constant() {
    let mut rng = Rng::seeded(0x5014);
    for case in 0..CASES {
        let base = rng.f32_range(0.0, 1e-3) as f64;
        let peak = base + rng.f32_range(1e-4, 1e-2) as f64;
        let horizon = rng.range(50, 400) as u64;
        let start = rng.below(horizon as usize / 2) as u64;
        let len = rng.range(1, (horizon - start) as usize) as u64; // window ⊆ horizon
        let spike = RateSchedule::Spike { base, peak, start, len };
        let fluence = base * horizon as f64 + (peak - base) * len as f64;
        let tol = fluence.abs() * 1e-9 + 1e-15;
        let one_shot = spike.expected_upsets(0, horizon);
        assert!((one_shot - fluence).abs() <= tol, "case {case}: {one_shot} vs {fluence}");
        // the equivalent constant spreads the same fluence uniformly
        let flat = RateSchedule::Constant(fluence / horizon as f64).expected_upsets(0, horizon);
        assert!((one_shot - flat).abs() <= tol, "case {case}: {one_shot} vs flat {flat}");
        // chunked integration sums to the same fluence
        let mut cursor = 0u64;
        let mut sum = 0.0;
        while cursor < horizon {
            let chunk = (rng.range(1, 30) as u64).min(horizon - cursor);
            sum += spike.expected_upsets(cursor, chunk);
            cursor += chunk;
        }
        assert!((sum - one_shot).abs() <= tol, "case {case}: chunked {sum} vs {one_shot}");
    }
}

// ------------------------------------------------- masking property sweeps

/// TMR masks every single-bit flip on `Fixed` words at every spec in use:
/// random word contents, random strike site (word × bit × replica), the
/// voted read always returns the original words.
#[test]
fn prop_tmr_masks_single_flips_at_every_spec() {
    for spec in specs_in_use() {
        let codec = WordCodec::new(Precision::Fixed, spec);
        let mut rng = Rng::seeded(7000 + spec.word as u64);
        for case in 0..CASES {
            let n = rng.range(1, 24);
            let values: Vec<f32> = (0..n)
                .map(|_| Fixed::from_f32(rng.f32_range(-3.0, 3.0), spec).to_f32())
                .collect();
            let words = codec.encode_all(&values);
            let mut store = ProtectedStore::new(Mitigation::Tmr, spec.word, &words);
            let strikes = rng.range(1, n + 1);
            let mut struck = std::collections::BTreeSet::new();
            for _ in 0..strikes {
                // at most one strike per word per read window — the regime
                // TMR guarantees full masking in
                let w = rng.below(n);
                if struck.insert(w) {
                    store.force_flip(w, rng.below(spec.word as usize) as u32, rng.below(3));
                }
            }
            let mut stats = FaultStats::default();
            let read = store.read(&mut stats);
            assert_eq!(read, words, "Q({},{}) case {case}", spec.word, spec.frac);
            assert_eq!(stats.masked, struck.len() as u64, "case {case}");
            assert_eq!(codec.decode_all(&read), values, "case {case}");
        }
    }
}

/// SECDED corrects every single-bit flip — data, check or overall-parity
/// bit — at every spec in use.
#[test]
fn prop_ecc_corrects_single_flips_at_every_spec() {
    for spec in specs_in_use() {
        let codec = WordCodec::new(Precision::Fixed, spec);
        let total_bits = Secded::new(spec.word).total_bits();
        let mut rng = Rng::seeded(8000 + spec.word as u64);
        for case in 0..CASES {
            let n = rng.range(1, 24);
            let values: Vec<f32> = (0..n)
                .map(|_| Fixed::from_f32(rng.f32_range(-3.0, 3.0), spec).to_f32())
                .collect();
            let words = codec.encode_all(&values);
            let mut store = ProtectedStore::new(Mitigation::Ecc, spec.word, &words);
            let mut struck = std::collections::BTreeSet::new();
            for _ in 0..rng.range(1, n + 1) {
                let w = rng.below(n);
                if struck.insert(w) {
                    store.force_flip(w, rng.below(total_bits as usize) as u32, 0);
                }
            }
            let mut stats = FaultStats::default();
            let read = store.read(&mut stats);
            assert_eq!(read, words, "Q({},{}) case {case}", spec.word, spec.frac);
            assert_eq!(stats.corrected, struck.len() as u64, "case {case}");
            assert_eq!(stats.uncorrectable, 0, "case {case}");
        }
    }
}

/// The raw SECDED code corrects a flip at literally every codeword bit
/// position for every spec (exhaustive, not sampled).
#[test]
fn prop_secded_exhaustive_single_bit_positions() {
    for spec in specs_in_use() {
        let s = Secded::new(spec.word);
        let mut rng = Rng::seeded(9000 + spec.word as u64);
        for _ in 0..20 {
            let data = rng.next_u64() & ((1u64 << spec.word) - 1);
            let code = s.encode(data);
            for bit in 0..s.total_bits() {
                let (back, _) = s.decode(code ^ (1u128 << bit));
                assert_eq!(back, data, "Q{} bit {bit}", spec.word);
            }
        }
    }
}

/// Different fault seeds produce different corruption (the stream is live),
/// while a zero rate never perturbs anything.
#[test]
fn seeds_matter_and_zero_rate_is_silent() {
    let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
    let make = |seed: u64, rate: f64| {
        let inner = build(BackendKind::Cpu, net, Precision::Fixed, 9);
        let mut b = FaultyBackend::new(
            inner,
            Precision::Fixed,
            Mitigation::None,
            FaultModel::new(seed, rate),
        );
        drive_workload(&mut b, &net, 60);
        (b.params(), b.stats())
    };
    let (p1, s1) = make(1, 2e-3);
    let (p2, _) = make(2, 2e-3);
    assert!(s1.total_upsets() > 0);
    assert!(p1.max_abs_diff(&p2) > 0.0, "different seeds, same weights");
    let (_, s0) = make(1, 0.0);
    assert_eq!(s0, FaultStats::default());
}
