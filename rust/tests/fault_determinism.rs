//! Fault-subsystem determinism and masking guarantees:
//!
//! * same seed + rate + mitigation ⇒ bit-identical injected weights and
//!   identical campaign reports, across runs and across the fleet
//!   scheduler;
//! * TMR and SECDED fully mask single-bit flips on `Fixed` words at every
//!   `FixedSpec` the repo uses (seeded-random property sweep, same style
//!   as `tests/proptests.rs`).

use qfpga::config::{Arch, EnvKind, NetConfig, Precision};
use qfpga::coordinator::sweep::{resilience, Workload};
use qfpga::coordinator::{run_fleet, MissionConfig};
use qfpga::experiment::{AnyBackend, BackendFactory, BackendSpec};
use qfpga::fault::{
    FaultModel, FaultPlan, FaultStats, FaultyBackend, Mitigation, ProtectedStore, Secded,
    WordCodec,
};
use qfpga::fixed::{Fixed, FixedSpec};
use qfpga::nn::params::QNetParams;
use qfpga::qlearn::backend::{BackendKind, QBackend};
use qfpga::util::Rng;

/// Backends come from the factory — the only construction path.
fn build(kind: BackendKind, net: NetConfig, prec: Precision, seed: u64) -> AnyBackend {
    let mut rng = Rng::seeded(seed);
    let params = QNetParams::init(&net, 0.3, &mut rng);
    BackendFactory::offline()
        .build(&BackendSpec::new(kind, net, prec), params)
        .expect("backend")
}

const CASES: usize = 200;

/// Every fixed-point format the repo exercises: the DSP48 default plus the
/// X3 word-length ablation sweep.
fn specs_in_use() -> [FixedSpec; 6] {
    [
        FixedSpec::new(8, 4),
        FixedSpec::new(12, 8),
        FixedSpec::new(16, 8),
        FixedSpec::new(18, 12),
        FixedSpec::new(24, 16),
        FixedSpec::new(32, 24),
    ]
}

// ------------------------------------------------------------- determinism

fn drive_workload<B: QBackend>(backend: &mut B, net: &NetConfig, n: usize) -> Vec<f32> {
    let w = Workload::synthetic(*net, n, 501);
    let step = net.a * net.d;
    (0..n)
        .map(|i| {
            backend
                .update(
                    &w.sa_cur[i * step..(i + 1) * step],
                    &w.sa_next[i * step..(i + 1) * step],
                    w.actions[i],
                    w.rewards[i],
                )
                .unwrap()
        })
        .collect()
}

/// Same seed + rate + mitigation ⇒ bit-identical injected weights, for
/// both wrapped backends and both precisions.
#[test]
fn injected_weights_are_bit_identical_across_runs() {
    let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
    for prec in [Precision::Fixed, Precision::Float] {
        for mitigation in Mitigation::all() {
            let run_cpu = || {
                let inner = build(BackendKind::Cpu, net, prec, 9);
                let mut b = FaultyBackend::new(
                    inner,
                    prec,
                    mitigation,
                    FaultModel::new(1234, 1e-3),
                );
                drive_workload(&mut b, &net, 50);
                (b.params(), b.stats())
            };
            let run_sim = || {
                let inner = build(BackendKind::FpgaSim, net, prec, 9);
                let mut b = FaultyBackend::new(
                    inner,
                    prec,
                    mitigation,
                    FaultModel::new(1234, 1e-3),
                );
                drive_workload(&mut b, &net, 50);
                (b.params(), b.stats())
            };
            let (p1, s1) = run_cpu();
            let (p2, s2) = run_cpu();
            assert_eq!(p1, p2, "cpu {prec:?}/{}", mitigation.label());
            assert_eq!(s1, s2, "cpu {prec:?}/{}", mitigation.label());
            let (q1, t1) = run_sim();
            let (q2, t2) = run_sim();
            assert_eq!(q1, q2, "fpga-sim {prec:?}/{}", mitigation.label());
            assert_eq!(t1, t2, "fpga-sim {prec:?}/{}", mitigation.label());
        }
    }
}

/// Identical campaign reports across runs and across the fleet scheduler
/// (2-rover fleets, threaded collection).
#[test]
fn campaign_reports_are_identical_across_runs() {
    let base = MissionConfig {
        arch: Arch::Mlp,
        env: EnvKind::Simple,
        precision: Precision::Fixed,
        episodes: 5,
        max_steps: 30,
        seed: 11,
        ..Default::default()
    };
    let campaign = || {
        resilience(
            &base,
            &[BackendKind::Cpu, BackendKind::FpgaSim],
            &[2e-4],
            &[Mitigation::None, Mitigation::Tmr, Mitigation::Ecc],
            2,
        )
        .unwrap()
    };
    let a = campaign();
    let b = campaign();
    assert_eq!(a.cells.len(), 6);
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.backend, y.backend);
        assert_eq!(x.mitigation, y.mitigation);
        assert_eq!(x.learning_delta.to_bits(), y.learning_delta.to_bits());
        assert_eq!(x.baseline_delta.to_bits(), y.baseline_delta.to_bits());
        assert_eq!(x.stats, y.stats);
    }
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(a.render(), b.render());
}

/// Faulted fleets replay bit-identically rover by rover.
#[test]
fn faulted_fleet_is_reproducible_per_rover() {
    let cfg = MissionConfig {
        episodes: 5,
        max_steps: 30,
        backend: BackendKind::FpgaSim,
        fault: Some(FaultPlan { rate: 5e-4, mitigation: Mitigation::Scrub { interval: 16 } }),
        ..Default::default()
    };
    let a = run_fleet(&cfg, 3).unwrap();
    let b = run_fleet(&cfg, 3).unwrap();
    let mut any_upsets = false;
    for (x, y) in a.rovers.iter().zip(&b.rovers) {
        assert_eq!(x.fault, y.fault);
        any_upsets |= x.fault.unwrap().total_upsets() > 0;
        for (ex, ey) in x.train.episodes.iter().zip(&y.train.episodes) {
            assert_eq!(ex.total_reward.to_bits(), ey.total_reward.to_bits());
        }
    }
    assert!(any_upsets, "fleet saw no radiation at 5e-4/bit/step");
    // distinct rovers get distinct seeds: trajectories and/or fault
    // exposure must differ
    let r0 = &a.rovers[0];
    let r1 = &a.rovers[1];
    assert!(
        r0.fault != r1.fault
            || r0.train.episodes[0].total_reward != r1.train.episodes[0].total_reward,
        "rover 0 and 1 are identical"
    );
}

// ------------------------------------------------- masking property sweeps

/// TMR masks every single-bit flip on `Fixed` words at every spec in use:
/// random word contents, random strike site (word × bit × replica), the
/// voted read always returns the original words.
#[test]
fn prop_tmr_masks_single_flips_at_every_spec() {
    for spec in specs_in_use() {
        let codec = WordCodec::new(Precision::Fixed, spec);
        let mut rng = Rng::seeded(7000 + spec.word as u64);
        for case in 0..CASES {
            let n = rng.range(1, 24);
            let values: Vec<f32> = (0..n)
                .map(|_| Fixed::from_f32(rng.f32_range(-3.0, 3.0), spec).to_f32())
                .collect();
            let words = codec.encode_all(&values);
            let mut store = ProtectedStore::new(Mitigation::Tmr, spec.word, &words);
            let strikes = rng.range(1, n + 1);
            let mut struck = std::collections::BTreeSet::new();
            for _ in 0..strikes {
                // at most one strike per word per read window — the regime
                // TMR guarantees full masking in
                let w = rng.below(n);
                if struck.insert(w) {
                    store.force_flip(w, rng.below(spec.word as usize) as u32, rng.below(3));
                }
            }
            let mut stats = FaultStats::default();
            let read = store.read(&mut stats);
            assert_eq!(read, words, "Q({},{}) case {case}", spec.word, spec.frac);
            assert_eq!(stats.masked, struck.len() as u64, "case {case}");
            assert_eq!(codec.decode_all(&read), values, "case {case}");
        }
    }
}

/// SECDED corrects every single-bit flip — data, check or overall-parity
/// bit — at every spec in use.
#[test]
fn prop_ecc_corrects_single_flips_at_every_spec() {
    for spec in specs_in_use() {
        let codec = WordCodec::new(Precision::Fixed, spec);
        let total_bits = Secded::new(spec.word).total_bits();
        let mut rng = Rng::seeded(8000 + spec.word as u64);
        for case in 0..CASES {
            let n = rng.range(1, 24);
            let values: Vec<f32> = (0..n)
                .map(|_| Fixed::from_f32(rng.f32_range(-3.0, 3.0), spec).to_f32())
                .collect();
            let words = codec.encode_all(&values);
            let mut store = ProtectedStore::new(Mitigation::Ecc, spec.word, &words);
            let mut struck = std::collections::BTreeSet::new();
            for _ in 0..rng.range(1, n + 1) {
                let w = rng.below(n);
                if struck.insert(w) {
                    store.force_flip(w, rng.below(total_bits as usize) as u32, 0);
                }
            }
            let mut stats = FaultStats::default();
            let read = store.read(&mut stats);
            assert_eq!(read, words, "Q({},{}) case {case}", spec.word, spec.frac);
            assert_eq!(stats.corrected, struck.len() as u64, "case {case}");
            assert_eq!(stats.uncorrectable, 0, "case {case}");
        }
    }
}

/// The raw SECDED code corrects a flip at literally every codeword bit
/// position for every spec (exhaustive, not sampled).
#[test]
fn prop_secded_exhaustive_single_bit_positions() {
    for spec in specs_in_use() {
        let s = Secded::new(spec.word);
        let mut rng = Rng::seeded(9000 + spec.word as u64);
        for _ in 0..20 {
            let data = rng.next_u64() & ((1u64 << spec.word) - 1);
            let code = s.encode(data);
            for bit in 0..s.total_bits() {
                let (back, _) = s.decode(code ^ (1u128 << bit));
                assert_eq!(back, data, "Q{} bit {bit}", spec.word);
            }
        }
    }
}

/// Different fault seeds produce different corruption (the stream is live),
/// while a zero rate never perturbs anything.
#[test]
fn seeds_matter_and_zero_rate_is_silent() {
    let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
    let make = |seed: u64, rate: f64| {
        let inner = build(BackendKind::Cpu, net, Precision::Fixed, 9);
        let mut b = FaultyBackend::new(
            inner,
            Precision::Fixed,
            Mitigation::None,
            FaultModel::new(seed, rate),
        );
        drive_workload(&mut b, &net, 60);
        (b.params(), b.stats())
    };
    let (p1, s1) = make(1, 2e-3);
    let (p2, _) = make(2, 2e-3);
    assert!(s1.total_upsets() > 0);
    assert!(p1.max_abs_diff(&p2) > 0.0, "different seeds, same weights");
    let (_, s0) = make(1, 0.0);
    assert_eq!(s0, FaultStats::default());
}
