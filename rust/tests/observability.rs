//! Observability integration: run manifests are deterministic for a fixed
//! spec + seed, replay reproduces the recorded report hash bit-exactly,
//! and the deterministic report projection holds on every environment.
//!
//! The metrics registry is process-global and these tests run in parallel
//! with the rest of the suite, so manifest comparisons are made with a
//! shared delta snapshot — per-run metric isolation is a CLI-process
//! property (each `qfpga` invocation is one process), exercised by the
//! observability CI job, not something an in-process test can assert.

use qfpga::config::{Arch, EnvKind, Precision};
use qfpga::coordinator::{scenario_table, MissionConfig, ScenarioSpec};
use qfpga::experiment::Experiment;
use qfpga::obs::manifest::{report_sha256, strip_keys, RunManifest};
use qfpga::obs::metrics::MetricsSnapshot;
use qfpga::qlearn::backend::BackendKind;
use qfpga::qlearn::SharePlan;
use qfpga::serve::JobSpec;
use qfpga::util::Json;
use qfpga::Report;

fn crater_cfg() -> MissionConfig {
    MissionConfig {
        arch: Arch::Mlp,
        env: EnvKind::Crater,
        precision: Precision::Fixed,
        backend: BackendKind::Cpu,
        episodes: 8,
        max_steps: 40,
        seed: 2017,
        ..Default::default()
    }
}

/// Build a `train` manifest exactly the way the CLI does (config →
/// experiment → report → manifest), with the caller-provided metrics
/// delta so two builds are comparable under parallel-test pollution.
fn manifest_for(cfg: &MissionConfig, delta: &MetricsSnapshot) -> RunManifest {
    let doc = Experiment::from_mission(cfg).run().unwrap().to_json();
    RunManifest::build("train", cfg.seed, cfg.to_json(), "EXP", &doc, delta, 0.0)
}

#[test]
fn same_spec_same_seed_manifests_agree_modulo_volatile_fields() {
    let snap = MetricsSnapshot::capture();
    let delta = snap.delta(&snap);
    let a = manifest_for(&crater_cfg(), &delta);
    let b = manifest_for(&crater_cfg(), &delta);
    // the self-hash already excludes run_id + durations, so two identical
    // runs must self-hash identically...
    assert_eq!(a.manifest_sha256, b.manifest_sha256);
    assert_eq!(a.spec_sha256, b.spec_sha256);
    assert_eq!(a.report_sha256, b.report_sha256);
    // ...and the full documents must agree once the volatile fields are
    // stripped (the `qfpga diff --ignore-keys run_id,durations` contract)
    assert_eq!(
        strip_keys(&a.to_json(), &["run_id", "durations", "manifest_sha256"]),
        strip_keys(&b.to_json(), &["run_id", "durations", "manifest_sha256"]),
    );
}

#[test]
fn replay_of_a_crater_train_manifest_is_bit_exact() {
    let snap = MetricsSnapshot::capture();
    let m = manifest_for(&crater_cfg(), &snap.delta(&snap));
    // the replay path: rebuild the config from the embedded spec (not the
    // original struct) and re-run from scratch
    let cfg = MissionConfig::from_json(&m.spec).unwrap();
    let doc = Experiment::from_mission(&cfg).run().unwrap().to_json();
    assert_eq!(report_sha256(&doc), m.report_sha256);
}

#[test]
fn manifest_survives_save_load_validate() {
    let snap = MetricsSnapshot::capture();
    let m = manifest_for(&crater_cfg(), &snap.delta(&snap));
    let dir = std::env::temp_dir().join("qfpga_obs_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("manifest.json");
    m.save(&path).unwrap();
    // load() validates: schema major, spec hash, self-hash
    let back = RunManifest::load(&path).unwrap();
    assert_eq!(back.manifest_sha256, m.manifest_sha256);
    assert_eq!(back.report_sha256, m.report_sha256);
    assert_eq!(back.spec, m.spec);
    std::fs::remove_file(path).ok();
}

#[test]
fn every_environment_yields_a_deterministic_report_hash() {
    for &env in EnvKind::all().iter() {
        let cfg = MissionConfig { env, episodes: 4, max_steps: 25, ..crater_cfg() };
        let h1 = report_sha256(&Experiment::from_mission(&cfg).run().unwrap().to_json());
        let h2 = report_sha256(&Experiment::from_mission(&cfg).run().unwrap().to_json());
        assert_eq!(
            h1,
            h2,
            "{} report projection is not seed-deterministic",
            env.as_str()
        );
    }
}

#[test]
fn scenario_table_hash_is_deterministic_despite_measured_rows() {
    // S1 carries one host-measured row (the fpga-vs-cpu advantage); the
    // report projection drops it, so the hash must be run-to-run stable
    let spec = ScenarioSpec {
        envs: vec![EnvKind::Crater],
        arch: Arch::Mlp,
        precision: Precision::Fixed,
        episodes: 4,
        max_steps: 25,
        seed: 7,
        batch: 1,
    };
    let h1 = report_sha256(&scenario_table(&spec).unwrap().to_json());
    let h2 = report_sha256(&scenario_table(&spec).unwrap().to_json());
    assert_eq!(h1, h2);
}

/// Replay of a shared-fleet manifest: the embedded spec (mission config +
/// `rovers` + `share` block) must rebuild through the manifest dispatcher
/// and re-run to the recorded report hash — the exact path `qfpga replay`
/// and the serve gateway take. This closes the coverage gap where only
/// isolated fleets were replayed end to end.
#[test]
fn fleet_manifest_with_share_replays_bit_exactly() {
    let cfg = MissionConfig { episodes: 6, max_steps: 25, ..crater_cfg() };
    let plan = SharePlan { exchange_every: 2, avg_every: 4, pool_cap: 4 };
    let direct = Experiment::from_mission(&cfg)
        .rovers(2)
        .share(plan)
        .run()
        .unwrap();

    // the spec exactly as cmd_fleet records it in a manifest
    let mut spec = cfg.to_json();
    if let Json::Obj(map) = &mut spec {
        map.insert("rovers".into(), Json::Num(2.0));
        map.insert("share".into(), plan.to_json());
    }
    let snap = MetricsSnapshot::capture();
    let m = RunManifest::build(
        "fleet",
        cfg.seed,
        spec,
        "EXP",
        &direct.to_json(),
        &snap.delta(&snap),
        0.0,
    );
    assert!(m.is_replayable(), "shared fleets must stay replayable");

    let job = JobSpec::from_manifest(&m.subcommand, &m.spec).unwrap();
    let doc = job.run(&|_| {}).unwrap();
    assert_eq!(report_sha256(&doc), m.report_sha256);
}

/// Manifests from a pre-1.0 or future schema must be refused by the
/// version gate with an error that names `schema_version`, the offending
/// value, and what this build reads — never a parse panic. A torn
/// manifest (missing required field) must name the field.
#[test]
fn old_schema_manifests_fail_closed_with_a_clear_error() {
    let snap = MetricsSnapshot::capture();
    let m = manifest_for(&crater_cfg(), &snap.delta(&snap));
    for version in ["0.9.0", "2.0.0"] {
        let mut doc = m.to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("schema_version".into(), Json::Str(version.into()));
        }
        // round-trip through text first: the rejection must come from the
        // version gate on parsed JSON, not from the parser
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        let err = RunManifest::validate(&reparsed).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("schema_version") && msg.contains(version), "{msg}");
        assert!(msg.contains("1.x.y"), "should say what this build reads: {msg}");
    }
    // a manifest missing a required field names it instead of panicking
    let mut doc = m.to_json();
    if let Json::Obj(map) = &mut doc {
        map.remove("report_sha256");
    }
    let err = RunManifest::validate(&doc).unwrap_err();
    assert!(err.to_string().contains("report_sha256"), "{err}");
}
