//! Observability integration: run manifests are deterministic for a fixed
//! spec + seed, replay reproduces the recorded report hash bit-exactly,
//! and the deterministic report projection holds on every environment.
//!
//! The metrics registry is process-global and these tests run in parallel
//! with the rest of the suite, so manifest comparisons are made with a
//! shared delta snapshot — per-run metric isolation is a CLI-process
//! property (each `qfpga` invocation is one process), exercised by the
//! observability CI job, not something an in-process test can assert.

use qfpga::config::{Arch, EnvKind, Precision};
use qfpga::coordinator::{scenario_table, MissionConfig, ScenarioSpec};
use qfpga::experiment::Experiment;
use qfpga::obs::manifest::{report_sha256, strip_keys, RunManifest};
use qfpga::obs::metrics::MetricsSnapshot;
use qfpga::qlearn::backend::BackendKind;

fn crater_cfg() -> MissionConfig {
    MissionConfig {
        arch: Arch::Mlp,
        env: EnvKind::Crater,
        precision: Precision::Fixed,
        backend: BackendKind::Cpu,
        episodes: 8,
        max_steps: 40,
        seed: 2017,
        ..Default::default()
    }
}

/// Build a `train` manifest exactly the way the CLI does (config →
/// experiment → report → manifest), with the caller-provided metrics
/// delta so two builds are comparable under parallel-test pollution.
fn manifest_for(cfg: &MissionConfig, delta: &MetricsSnapshot) -> RunManifest {
    let doc = Experiment::from_mission(cfg).run().unwrap().to_json();
    RunManifest::build("train", cfg.seed, cfg.to_json(), "EXP", &doc, delta, 0.0)
}

#[test]
fn same_spec_same_seed_manifests_agree_modulo_volatile_fields() {
    let snap = MetricsSnapshot::capture();
    let delta = snap.delta(&snap);
    let a = manifest_for(&crater_cfg(), &delta);
    let b = manifest_for(&crater_cfg(), &delta);
    // the self-hash already excludes run_id + durations, so two identical
    // runs must self-hash identically...
    assert_eq!(a.manifest_sha256, b.manifest_sha256);
    assert_eq!(a.spec_sha256, b.spec_sha256);
    assert_eq!(a.report_sha256, b.report_sha256);
    // ...and the full documents must agree once the volatile fields are
    // stripped (the `qfpga diff --ignore-keys run_id,durations` contract)
    assert_eq!(
        strip_keys(&a.to_json(), &["run_id", "durations", "manifest_sha256"]),
        strip_keys(&b.to_json(), &["run_id", "durations", "manifest_sha256"]),
    );
}

#[test]
fn replay_of_a_crater_train_manifest_is_bit_exact() {
    let snap = MetricsSnapshot::capture();
    let m = manifest_for(&crater_cfg(), &snap.delta(&snap));
    // the replay path: rebuild the config from the embedded spec (not the
    // original struct) and re-run from scratch
    let cfg = MissionConfig::from_json(&m.spec).unwrap();
    let doc = Experiment::from_mission(&cfg).run().unwrap().to_json();
    assert_eq!(report_sha256(&doc), m.report_sha256);
}

#[test]
fn manifest_survives_save_load_validate() {
    let snap = MetricsSnapshot::capture();
    let m = manifest_for(&crater_cfg(), &snap.delta(&snap));
    let dir = std::env::temp_dir().join("qfpga_obs_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("manifest.json");
    m.save(&path).unwrap();
    // load() validates: schema major, spec hash, self-hash
    let back = RunManifest::load(&path).unwrap();
    assert_eq!(back.manifest_sha256, m.manifest_sha256);
    assert_eq!(back.report_sha256, m.report_sha256);
    assert_eq!(back.spec, m.spec);
    std::fs::remove_file(path).ok();
}

#[test]
fn every_environment_yields_a_deterministic_report_hash() {
    for &env in EnvKind::all().iter() {
        let cfg = MissionConfig { env, episodes: 4, max_steps: 25, ..crater_cfg() };
        let h1 = report_sha256(&Experiment::from_mission(&cfg).run().unwrap().to_json());
        let h2 = report_sha256(&Experiment::from_mission(&cfg).run().unwrap().to_json());
        assert_eq!(
            h1,
            h2,
            "{} report projection is not seed-deterministic",
            env.as_str()
        );
    }
}

#[test]
fn scenario_table_hash_is_deterministic_despite_measured_rows() {
    // S1 carries one host-measured row (the fpga-vs-cpu advantage); the
    // report projection drops it, so the hash must be run-to-run stable
    let spec = ScenarioSpec {
        envs: vec![EnvKind::Crater],
        arch: Arch::Mlp,
        precision: Precision::Fixed,
        episodes: 4,
        max_steps: 25,
        seed: 7,
        batch: 1,
    };
    let h1 = report_sha256(&scenario_table(&spec).unwrap().to_json());
    let h2 = report_sha256(&scenario_table(&spec).unwrap().to_json());
    assert_eq!(h1, h2);
}
