//! API-surface enforcement: no call site outside the backend module itself
//! and the [`qfpga::experiment::BackendFactory`] constructs a concrete
//! backend directly.
//!
//! External crates (these integration tests, the benches, the examples)
//! are already fenced off at compile time — the constructors are
//! `pub(crate)` — so this grep covers the remaining surface: the library
//! source itself.

use std::path::{Path, PathBuf};

/// Files allowed to mention the concrete constructors: the defining module
/// (including its own unit tests) and the factory.
const ALLOWED: &[&str] = &["src/qlearn/backend.rs", "src/experiment/spec.rs"];

const PATTERNS: &[&str] = &[
    "CpuBackend::new(",
    "CpuBackend::with_spec(",
    "FpgaSimBackend::new(",
    "FpgaSimBackend::with_spec(",
    "FpgaSimBackend::with_timing(",
    "XlaBackend::new(",
];

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).expect("read src dir") {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[test]
fn backends_are_constructed_only_through_the_factory() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src = root.join("src");
    let mut files = Vec::new();
    rust_files(&src, &mut files);
    assert!(files.len() > 30, "source walk looks wrong: {}", files.len());

    let mut offenders = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap()
            .to_string_lossy()
            .replace('\\', "/");
        if ALLOWED.iter().any(|a| rel == *a) {
            continue;
        }
        let text = std::fs::read_to_string(&path).expect("read source file");
        for pat in PATTERNS {
            if text.contains(pat) {
                offenders.push(format!("{rel}: {pat}"));
            }
        }
    }
    assert!(
        offenders.is_empty(),
        "backends must be built via experiment::BackendFactory, but found \
         direct construction in:\n  {}",
        offenders.join("\n  ")
    );
}
