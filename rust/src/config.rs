//! Experiment / system configuration, mirroring `python/compile/configs.py`.
//!
//! The four paper configurations (Section 5):
//!
//! | name               | arch       | D  | H | A  |
//! |--------------------|------------|----|---|----|
//! | perceptron_simple  | perceptron | 6  | – | 6  |
//! | perceptron_complex | perceptron | 20 | – | 40 |
//! | mlp_simple         | MLP        | 6  | 4 | 6  |
//! | mlp_complex        | MLP        | 20 | 4 | 40 |
//!
//! `D` is the state+action vector width, `H` the hidden-layer size
//! (“4 hidden layer neurons”), `A` the number of actions per state.

use crate::error::{Error, Result};

/// Paper hidden-layer width.
pub const HIDDEN: usize = 4;

/// Network architecture (paper Sections 3 and 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    /// Single neuron (Section 3).
    Perceptron,
    /// Multilayer perceptron with one hidden layer (Section 4).
    Mlp,
}

impl Arch {
    pub fn as_str(self) -> &'static str {
        match self {
            Arch::Perceptron => "perceptron",
            Arch::Mlp => "mlp",
        }
    }
}

impl std::str::FromStr for Arch {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "perceptron" | "neuron" => Ok(Arch::Perceptron),
            "mlp" => Ok(Arch::Mlp),
            other => Err(Error::Config(format!("unknown arch `{other}`"))),
        }
    }
}

/// Environment class: the paper's two benchmark gridworlds (Section 5)
/// plus the mission scenario library (see SCENARIOS.md).
///
/// Canonical spellings are what [`EnvKind::as_str`] emits (`"simple"`,
/// `"complex"`, `"crater"`, `"slip"`, `"energy"`); the long forms
/// `"crater-field"`, `"slip-slope"` and `"energy-budget"` are accepted as
/// input aliases but never printed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EnvKind {
    /// Paper benchmark: D = 6 (4 state + 2 action dims), A = 6.
    Simple,
    /// Paper benchmark: D = 20, A = 40, |S| = 1800.
    Complex,
    /// Crater field: procedural crater bowls with impassable rims and
    /// graded slope penalties. D = 10, A = 8.
    Crater,
    /// Slip-under-slope: seeded stochastic wheel slip proportional to the
    /// local elevation gradient. D = 11, A = 8.
    Slip,
    /// Energy budget: battery state in the encoding, per-move/thermal
    /// drain, recharge pads, episode ends on depletion. D = 12, A = 10.
    Energy,
}

impl EnvKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EnvKind::Simple => "simple",
            EnvKind::Complex => "complex",
            EnvKind::Crater => "crater",
            EnvKind::Slip => "slip",
            EnvKind::Energy => "energy",
        }
    }

    /// Every environment kind (canonical enumeration order: the paper
    /// benchmarks first, then the scenario library).
    pub fn all() -> [EnvKind; 5] {
        [
            EnvKind::Simple,
            EnvKind::Complex,
            EnvKind::Crater,
            EnvKind::Slip,
            EnvKind::Energy,
        ]
    }

    /// Whether this kind is one of the paper's two benchmark environments
    /// — the only configurations with baked XLA artifacts.
    pub fn is_paper(self) -> bool {
        matches!(self, EnvKind::Simple | EnvKind::Complex)
    }
}

impl std::str::FromStr for EnvKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "simple" => Ok(EnvKind::Simple),
            "complex" => Ok(EnvKind::Complex),
            "crater" | "crater-field" => Ok(EnvKind::Crater),
            "slip" | "slip-slope" => Ok(EnvKind::Slip),
            "energy" | "energy-budget" => Ok(EnvKind::Energy),
            other => Err(Error::Config(format!(
                "unknown env `{other}` (expected one of: simple, complex, crater, slip, \
                 energy; aliases: crater-field, slip-slope, energy-budget)"
            ))),
        }
    }
}

/// Arithmetic mode of the datapath (the paper's central comparison axis,
/// extended with the sub-8-bit kernel arms).
///
/// Canonical spellings are what [`Precision::as_str`] emits (`"fixed"`,
/// `"float"`, `"int8"`, `"binary"`); `"floating"` and `"bnn"` are accepted
/// as input aliases but never printed. The paper tables enumerate only the
/// two paper precisions (see `BackendSpec::matrix`); the sub-8-bit arms are
/// opted into explicitly by the CLI, the benches and the conformance suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Q(word, frac) fixed point on DSP48-style MACs.
    Fixed,
    /// Single-precision floating point on LogiCORE-style FP cores.
    Float,
    /// 8-bit fixed point on the canonical Q(8,4) grid — narrow-MAC arm
    /// (QForce-RL-style sub-byte arithmetic).
    Int8,
    /// Binarized ±1 register grid — XNOR/popcount-style arm (BNN).
    Binary,
}

impl Precision {
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::Fixed => "fixed",
            Precision::Float => "float",
            Precision::Int8 => "int8",
            Precision::Binary => "binary",
        }
    }

    /// Every precision arm (canonical enumeration order: the paper
    /// precisions first, then the sub-8-bit kernel arms).
    pub fn all() -> [Precision; 4] {
        [Precision::Fixed, Precision::Float, Precision::Int8, Precision::Binary]
    }

    /// Whether this arm is one of the paper's two precisions — the only
    /// ones with baked XLA artifacts and paper-table rows.
    pub fn is_paper(self) -> bool {
        matches!(self, Precision::Fixed | Precision::Float)
    }
}

impl std::str::FromStr for Precision {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "fixed" => Ok(Precision::Fixed),
            "float" | "floating" => Ok(Precision::Float),
            "int8" => Ok(Precision::Int8),
            "binary" | "bnn" => Ok(Precision::Binary),
            other => Err(Error::Config(format!(
                "unknown precision `{other}` (expected one of: fixed, float, int8, \
                 binary; aliases: floating, bnn)"
            ))),
        }
    }
}

/// One paper network/environment combination.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NetConfig {
    pub arch: Arch,
    pub env: EnvKind,
    /// State+action vector width.
    pub d: usize,
    /// Hidden neurons (0 for the perceptron).
    pub h: usize,
    /// Actions per state.
    pub a: usize,
}

impl NetConfig {
    pub const fn new(arch: Arch, env: EnvKind) -> Self {
        let (d, a) = match env {
            EnvKind::Simple => (6, 6),
            EnvKind::Complex => (20, 40),
            // scenario library (see SCENARIOS.md): 8 absolute-heading
            // moves (+ sample/recharge in the energy environment), state
            // features sized per environment
            EnvKind::Crater => (10, 8),
            EnvKind::Slip => (11, 8),
            EnvKind::Energy => (12, 10),
        };
        let h = match arch {
            Arch::Perceptron => 0,
            Arch::Mlp => HIDDEN,
        };
        NetConfig { arch, env, d, h, a }
    }

    /// All four paper configurations (the paper-table grid; the full
    /// mission grid including the scenario library is [`NetConfig::grid`]).
    pub fn all() -> [NetConfig; 4] {
        [
            NetConfig::new(Arch::Perceptron, EnvKind::Simple),
            NetConfig::new(Arch::Perceptron, EnvKind::Complex),
            NetConfig::new(Arch::Mlp, EnvKind::Simple),
            NetConfig::new(Arch::Mlp, EnvKind::Complex),
        ]
    }

    /// The full mission grid: every architecture × every [`EnvKind`]
    /// (paper benchmarks plus the scenario library), architecture-major.
    /// Paper tables stay on [`NetConfig::all`]; sweeps and campaigns
    /// enumerate this grid via
    /// [`crate::experiment::BackendSpec::matrix`].
    pub fn grid() -> Vec<NetConfig> {
        let mut out = Vec::with_capacity(2 * EnvKind::all().len());
        for arch in [Arch::Perceptron, Arch::Mlp] {
            for env in EnvKind::all() {
                out.push(NetConfig::new(arch, env));
            }
        }
        out
    }

    /// Canonical name, matching the python configs and artifact files.
    pub fn name(&self) -> String {
        format!("{}_{}", self.arch.as_str(), self.env.as_str())
    }

    /// Total trainable parameters (weights + biases).
    pub fn n_params(&self) -> usize {
        match self.arch {
            Arch::Perceptron => self.d + 1,
            Arch::Mlp => self.d * self.h + self.h + self.h + 1,
        }
    }

    /// Total “neurons” in the paper's counting (inputs + hidden + output):
    /// 11 for the simple MLP, 25 for the complex MLP.
    pub fn n_neurons(&self) -> usize {
        match self.arch {
            Arch::Perceptron => self.d + 1,
            Arch::Mlp => self.d + self.h + 1,
        }
    }
}

/// Q-learning hyper-parameters (paper Eq. 4, 8, 9). Must match the values
/// baked into the AOT artifacts (see `artifacts/manifest.json`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyper {
    /// Q-error scaling α (Eq. 8).
    pub alpha: f32,
    /// Discount γ.
    pub gamma: f32,
    /// Backprop learning factor C (Eq. 9/13).
    pub lr: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { alpha: 0.5, gamma: 0.9, lr: 0.25 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dimensions() {
        let ps = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        assert_eq!((ps.d, ps.a, ps.h), (6, 6, 0));
        let pc = NetConfig::new(Arch::Perceptron, EnvKind::Complex);
        assert_eq!((pc.d, pc.a), (20, 40));
    }

    #[test]
    fn paper_neuron_counts() {
        // “11 neurons in a simple environment and 25 neurons in a complex
        // environment with 4 hidden layer neurons” (Section 5).
        assert_eq!(NetConfig::new(Arch::Mlp, EnvKind::Simple).n_neurons(), 11);
        assert_eq!(NetConfig::new(Arch::Mlp, EnvKind::Complex).n_neurons(), 25);
    }

    #[test]
    fn param_counts() {
        assert_eq!(NetConfig::new(Arch::Perceptron, EnvKind::Simple).n_params(), 7);
        assert_eq!(NetConfig::new(Arch::Mlp, EnvKind::Simple).n_params(), 6 * 4 + 4 + 4 + 1);
    }

    #[test]
    fn names_roundtrip() {
        for cfg in NetConfig::grid() {
            let arch: Arch = cfg.arch.as_str().parse().unwrap();
            let env: EnvKind = cfg.env.as_str().parse().unwrap();
            assert_eq!(NetConfig::new(arch, env), cfg);
        }
    }

    #[test]
    fn scenario_dimensions() {
        let crater = NetConfig::new(Arch::Mlp, EnvKind::Crater);
        assert_eq!((crater.d, crater.a, crater.h), (10, 8, HIDDEN));
        let slip = NetConfig::new(Arch::Perceptron, EnvKind::Slip);
        assert_eq!((slip.d, slip.a, slip.h), (11, 8, 0));
        let energy = NetConfig::new(Arch::Mlp, EnvKind::Energy);
        assert_eq!((energy.d, energy.a), (12, 10));
    }

    #[test]
    fn grid_covers_paper_configs_and_scenarios() {
        let grid = NetConfig::grid();
        assert_eq!(grid.len(), 2 * EnvKind::all().len());
        for cfg in NetConfig::all() {
            assert!(grid.contains(&cfg), "{} missing from grid", cfg.name());
        }
        for env in EnvKind::all() {
            assert!(grid.iter().any(|c| c.env == env), "{} missing", env.as_str());
        }
    }

    #[test]
    fn env_kind_aliases_parse_to_canonical() {
        assert_eq!("crater-field".parse::<EnvKind>().unwrap(), EnvKind::Crater);
        assert_eq!("slip-slope".parse::<EnvKind>().unwrap(), EnvKind::Slip);
        assert_eq!("energy-budget".parse::<EnvKind>().unwrap(), EnvKind::Energy);
    }

    #[test]
    fn parse_errors() {
        assert!("gpu".parse::<Arch>().is_err());
        // the precision error must list the valid spellings, like env's
        let err = "double".parse::<Precision>().unwrap_err().to_string();
        for spelling in ["fixed", "float", "int8", "binary", "bnn"] {
            assert!(err.contains(spelling), "error must list `{spelling}`: {err}");
        }
        // the env error must list the valid spellings, not fail opaquely
        let err = "medium".parse::<EnvKind>().unwrap_err().to_string();
        for spelling in ["simple", "complex", "crater", "slip", "energy"] {
            assert!(err.contains(spelling), "error must list `{spelling}`: {err}");
        }
    }

    #[test]
    fn precision_aliases_parse_to_canonical() {
        assert_eq!("floating".parse::<Precision>().unwrap(), Precision::Float);
        assert_eq!("bnn".parse::<Precision>().unwrap(), Precision::Binary);
        for prec in Precision::all() {
            assert_eq!(prec.as_str().parse::<Precision>().unwrap(), prec);
        }
        assert!(Precision::Fixed.is_paper() && Precision::Float.is_paper());
        assert!(!Precision::Int8.is_paper() && !Precision::Binary.is_paper());
    }
}
