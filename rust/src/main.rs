//! `qfpga` — CLI for the FPGA Q-learning accelerator reproduction.
//!
//! Subcommands:
//!
//! * `report [--table N|--headline|--ablation X|--all]` — regenerate the
//!   paper's tables (with paper-vs-ours ratios).
//! * `train  [--arch A --env E --precision P --backend B --episodes N]` —
//!   run one rover mission and print its learning curve.
//! * `fleet  [--rovers N ...]` — multi-rover mission via the scheduler.
//! * `mission [--env all|E ...]` — the scenario-library campaign: train
//!   every environment kind on cpu + fpga-sim and print table S1.
//! * `fleetlearn [--fleets 1,2,4,8 ...]` — the fleet-learning campaign:
//!   shared (transition exchange + parameter averaging) vs isolated
//!   fleets swept over fleet size per scenario, printed as table F1.
//! * `harden [--env all|E ...]` — the radiation-hardening auto-tuner:
//!   mitigation placement × CRAM scrub interval × word length
//!   Pareto-searched per environment, printed as table H1.
//! * `sweep  [--updates N]` — measured per-update latency for every
//!   backend × configuration (the measured side of Tables 3–6).
//! * `throughput` — table B2: measured CPU updates/s (reference stepwise
//!   vs the prepared zero-alloc stepwise path vs batched) plus fleet
//!   scaling on the worker pool.
//! * `radiation` — resilience campaign under seeded SEU injection,
//!   optionally shaped by a `--rate-schedule` mission profile.
//! * `validate` — cross-backend numeric equivalence over random workloads.
//! * `serve --socket PATH` — mission gateway daemon: replayable job specs
//!   over a unix socket, bounded priority queue with preemption, a
//!   content-addressed result cache, graceful SIGTERM drain (see
//!   [`qfpga::serve`] for the frame-by-frame protocol reference).
//! * `loadgen` — load-test a gateway (embedded width sweep or a running
//!   daemon via `--socket`) and print table G1.
//! * `diff a.json b.json` — compare two report JSON files within
//!   tolerances (non-zero exit on drift; `--ignore-keys` deep-strips
//!   volatile keys first).
//! * `manifest validate f.json` — integrity-check a run manifest.
//! * `replay manifest.json` — re-run a recorded train/fleet/mission spec
//!   and require the reproduced report hash to match bit-exactly.
//! * `info` — artifact manifest + device/model summary.
//!
//! Every subcommand that prints a table or campaign accepts `--json FILE`
//! to also write the typed machine-readable report (the
//! [`qfpga::report::Report`] surface). Run subcommands additionally accept
//! the observability options (`--trace`, `--manifest`, `--metrics` — see
//! the README's Observability section).

use std::path::Path;
use std::process::ExitCode;
use std::time::Instant;

use qfpga::config::{Arch, EnvKind, NetConfig, Precision};
use qfpga::coordinator::sweep::Workload;
use qfpga::coordinator::telemetry::LearningCurve;
use qfpga::coordinator::{measure_backend, measure_backend_batched, MissionConfig, SweepReport};
use qfpga::error::Result;
use qfpga::experiment::{BackendFactory, BackendSpec, Experiment};
use qfpga::fpga::{TimingModel, Virtex7};
use qfpga::nn::params::QNetParams;
use qfpga::obs::manifest::RunManifest;
use qfpga::obs::metrics::MetricsSnapshot;
use qfpga::obs::trace;
use qfpga::qlearn::backend::{BackendKind, QBackend};
use qfpga::report::{self, Report};
use qfpga::runtime::Runtime;
use qfpga::util::cli::Args;
use qfpga::util::{shutdown, Json, Rng};

const USAGE: &str = "\
qfpga — FPGA Q-learning accelerator reproduction (Gankidi & Thangavelautham 2017)

USAGE: qfpga <report|train|fleet|mission|fleetlearn|harden|sweep|throughput|radiation|validate|serve|loadgen|diff|manifest|replay|info|help> [options]

  report    --table 1..8|energy|batch|resilience | --headline
            | --ablation pipeline|lut|wordlen | --all
            [--no-measure]        skip measuring the host-CPU rows
            [--batch B]           batch size for the B1 batched-datapath table
  train     --arch perceptron|mlp --precision fixed|float|int8|binary
            --env simple|complex|crater|slip|energy (see SCENARIOS.md)
            --backend cpu|xla|fpga-sim --episodes N --max-steps N --seed S
            [--microbatch]        flush at the backend's preferred batch size
            [--batch B]           flush through update_batch every B steps
            [--checkpoint-dir D]  checkpoint to D/rover-0.json and resume a
                                  file already present; with SIGINT/SIGTERM
                                  the run drains: final checkpoint, exit 0
            [--checkpoint-every N] episodes between checkpoints (default 25)
  fleet     --rovers N            plus all `train` options (incl. --batch)
            [--workers W]         worker-pool width (default: one per core,
                                  capped at the fleet; rovers scale past
                                  core count — seeds/ordering unchanged)
            [--progress]          stream per-rover episode progress lines
            [--checkpoint-dir D]  checkpoint each rover to D/rover-<i>.json
                                  and resume any file already present
            [--checkpoint-every N] episodes between checkpoints (default 25)
            [--share-every N]     fleet learning: pool transitions across
                                  rovers every N episodes (0 = off)
            [--avg-every N]       fleet learning: average parameters across
                                  rovers every N episodes (0 = off)
            [--pool-cap N]        transitions each rover contributes per
                                  exchange round (default 16); sharing is
                                  active when either cadence is non-zero
  mission   scenario-library campaign: train every env kind on cpu +
            fpga-sim and print table S1 (convergence episodes, final
            reward, fpga-vs-cpu latency advantage)
            [--env all|E]         one scenario or the whole library (default all)
            plus --arch/--precision/--episodes/--max-steps/--seed/--batch
  fleetlearn fleet-learning campaign: shared (transition exchange +
            parameter averaging) vs isolated fleets swept over fleet size
            per scenario, printed as table F1 (episodes-to-convergence
            per arm; a shared fleet of 1 must match isolated exactly)
            [--fleets 1,2,4,8]    fleet sizes to sweep
            [--share-every N]     exchange cadence in episodes (default 5)
            [--avg-every N]       averaging cadence in episodes (default 10)
            [--pool-cap N]        transitions per rover per exchange (default 16)
            [--env all|E]         one scenario or the whole library (default all)
            plus --arch/--precision/--episodes/--max-steps/--seed/--batch
  harden    radiation-hardening auto-tuner: per environment, Pareto-search
            data-plane mitigation × CRAM scrub interval × fixed word
            length under seeded data + configuration-memory strikes and
            print table H1 (reward retained, escape rate, area/power/
            latency overhead, rad-optimal pick per environment)
            [--env all|E]         one scenario or the whole library (default all)
            [--rate R]            data-plane upsets/bit/step (default 5e-4)
            [--cram-rate R]       CRAM upsets/bit/step (default 3e-3)
            [--rate-schedule S]   mission profile for both strike planes:
                                  R | spike:R0,Rpeak,START,LEN |
                                  phases:R1@N1,R2@N2,... | none
                                  (default spike:5e-4,5e-3,40,80)
            [--mitigations M,..]  data-plane arms (default none,tmr)
            [--scrubs S,..]       CRAM scrub arms: none|0|N steps
                                  (default none,0,64; 0 = continuous)
            [--words W,..]        fixed word lengths (default 8,18)
            plus --arch/--episodes/--max-steps/--seed
  sweep     --updates N           per-update latency, all backends/configs
            (the full mission grid; xla rows cover the paper configs only)
            [--batch B]           also measure the batched update_batch path
  throughput table B2: measured CPU updates/s — reference stepwise vs the
            prepared zero-alloc stepwise path vs batched, every paper
            config and kernel arm (fixed/float/int8/binary), plus fleet
            scaling at rovers >> workers
            [--updates N] [--batch B] [--rovers R] [--workers W]
            [--episodes E] [--max-steps N] [--seed S]
  radiation resilience campaign: train under seeded SEU injection and print
            learning-delta degradation vs mitigation overhead
            [--rate R]            upsets per bit per step (overrides --rad-env)
            [--rate-schedule S]   time-varying rate profile; every cell's
                                  constant rate scales its base:
                                  R | spike:R0,Rpeak,START,LEN |
                                  phases:R1@N1,R2@N2,...
            [--rad-env E]         cruise|mars-surface|jupiter-flyby (default
                                  mars-surface; rates are per bit per kilostep)
            [--mitigation M]      none|tmr|scrub[:N]|ecc|all   (default all)
            [--backend B]         cpu|fpga-sim|all              (default all)
            [--rovers N]          fleet width per campaign cell (default 2)
            plus --arch/--env/--precision/--episodes/--max-steps/--seed
  validate  --updates N           cross-backend + batch/stepwise equivalence
  serve     mission gateway daemon: accepts train/fleet/mission job specs
            (exactly the replayable manifest specs) as newline-delimited
            JSON over a unix socket; bounded priority queue with
            backpressure, checkpoint-backed preemption, per-job progress
            streaming, content-addressed result cache, healthz/metrics
            verbs; SIGINT/SIGTERM drains accepted jobs then exits 0
            --socket PATH         socket path (required; stale file replaced)
            [--workers W]         executor threads (default 2)
            [--queue N]           queue capacity (default 64)
            [--chunk E]           episodes between preemption probes (default 8)
  loadgen   load-test a gateway and print table G1 (p50/p99 job latency,
            sustained jobs/s, cache hit rate) over a deterministic
            train/fleet/mission mix; duplicates are resubmitted so the
            cache-hit columns are exact on a fresh daemon
            [--socket PATH]       drive a running daemon (default: embedded
                                  in-process daemons, one per --widths entry)
            [--jobs N] [--concurrency C] [--widths 1,2,4]
            [--episodes E] [--max-steps N] [--seed S]
            [--fetch-metrics F]   write the daemon's Prometheus text to F
            [--expect-hits N]     exit non-zero unless every pass observed
                                  exactly N cache hits
  diff      <ours.json> <golden.json> [--tol T] [--ignore-keys k1,k2]
            compare two report JSON files (default tolerance 0.05); exits
            non-zero when paper-ratio or latency fields drift out of band.
            Non-table documents (run manifests) compare structurally;
            --ignore-keys deep-strips the named keys from both sides first
            (e.g. --ignore-keys run_id,durations for two manifests of the
            same spec)
  manifest  validate <file.json>  parse + integrity-check a run manifest
            (schema major, spec_sha256, manifest self-hash)
  replay    <manifest.json>       re-run the recorded spec (train, fleet or
            mission manifests) and require the reproduced report_sha256 to
            match the recorded one bit-exactly; exits non-zero on mismatch
  info                            artifacts, device, cycle model summary

  --json FILE   (report/train/fleet/mission/fleetlearn/harden/sweep/
                throughput/radiation/validate/loadgen/info) also write the
                subcommand's typed JSON report to FILE

observability (train/fleet/mission/fleetlearn/harden/sweep/throughput/
radiation):
  --manifest FILE   write a versioned run-provenance manifest (schema,
                    run id, git describe, replayable spec + sha256, seed,
                    delta metrics snapshot, report sha256)
  --trace FILE      enable span tracing and write JSONL records to FILE;
                    prints a per-kind p50/p99 summary at exit
  --metrics FILE    write this run's delta metrics snapshot; Prometheus
                    text exposition, or JSON when FILE ends in .json
";

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

type Handler = fn(&Args) -> Result<()>;

/// Subcommand dispatch table — the single source of truth. The USAGE
/// synopsis and the unknown-subcommand message are both derived from (and
/// unit-tested against) this list, so a new subcommand cannot silently
/// stay out of the help text.
const COMMANDS: &[(&str, Handler)] = &[
    ("report", cmd_report),
    ("train", cmd_train),
    ("fleet", cmd_fleet),
    ("mission", cmd_mission),
    ("fleetlearn", cmd_fleetlearn),
    ("harden", cmd_harden),
    ("sweep", cmd_sweep),
    ("throughput", cmd_throughput),
    ("radiation", cmd_radiation),
    ("validate", cmd_validate),
    ("serve", cmd_serve),
    ("loadgen", cmd_loadgen),
    ("diff", cmd_diff),
    ("manifest", cmd_manifest),
    ("replay", cmd_replay),
    ("info", cmd_info),
];

fn run() -> Result<()> {
    let args = Args::from_env(&[
        "all",
        "headline",
        "measure",
        "microbatch",
        "no-measure",
        "progress",
        "help",
    ])?;
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    match args.positional().first().map(String::as_str) {
        None | Some("help") => {
            print!("{USAGE}");
            Ok(())
        }
        Some(name) => match COMMANDS.iter().find(|(n, _)| *n == name) {
            Some((_, handler)) => handler(&args),
            None => {
                eprint!("{USAGE}");
                let known: Vec<&str> = COMMANDS.iter().map(|(n, _)| *n).collect();
                Err(qfpga::error::Error::Config(format!(
                    "unknown subcommand `{name}` — expected one of: {}, help",
                    known.join(", ")
                )))
            }
        },
    }
}

/// Honor the uniform `--json FILE` contract.
fn write_json(args: &Args, doc: &Json) -> Result<()> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, doc.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Observability lifecycle for one run subcommand: snapshot the metrics
/// baseline (the registry is process-lifetime; a manifest must describe
/// this run only), arm tracing if `--trace` was given, and on `finish`
/// emit the manifest / trace file / metrics dump the flags asked for.
struct ObsRun {
    baseline: MetricsSnapshot,
    started: Instant,
    run_id: String,
    trace_path: Option<String>,
    manifest_path: Option<String>,
    metrics_path: Option<String>,
}

impl ObsRun {
    fn begin(args: &Args) -> ObsRun {
        let trace_path = args.get("trace").map(String::from);
        if trace_path.is_some() {
            trace::enable();
        }
        ObsRun {
            baseline: MetricsSnapshot::capture(),
            started: Instant::now(),
            run_id: qfpga::obs::manifest::new_run_id(),
            trace_path,
            manifest_path: args.get("manifest").map(String::from),
            metrics_path: args.get("metrics").map(String::from),
        }
    }

    /// Emit everything the observability flags requested. `spec` must be
    /// the complete replayable input of the run (what `qfpga replay`
    /// feeds back in), `report_doc` the run's `--json` document.
    fn finish(
        self,
        subcommand: &str,
        seed: u64,
        spec: Json,
        report_id: &str,
        report_doc: &Json,
    ) -> Result<()> {
        let wall = self.started.elapsed().as_secs_f64();
        let delta = MetricsSnapshot::capture().delta(&self.baseline);
        if let Some(path) = &self.metrics_path {
            let text = if path.ends_with(".json") {
                delta.to_json().to_string()
            } else {
                delta.to_prometheus()
            };
            std::fs::write(path, text)?;
            println!("wrote metrics {path}");
        }
        if let Some(path) = &self.manifest_path {
            let mut m =
                RunManifest::build(subcommand, seed, spec, report_id, report_doc, &delta, wall);
            // share the run id with the trace file (run_id is outside the
            // self-hash, so overriding it keeps the manifest valid)
            m.run_id = self.run_id.clone();
            m.save(Path::new(path))?;
            println!(
                "wrote manifest {path} (run {}, report_sha256 {}…)",
                m.run_id,
                &m.report_sha256[..12]
            );
        }
        if let Some(path) = &self.trace_path {
            let (records, dropped) = trace::disable_and_drain();
            trace::write_jsonl(path, &self.run_id, &records)?;
            print!("{}", trace::TraceSummary::from_records(&records, dropped).render());
            println!("wrote trace {path} ({} spans)", records.len());
        }
        Ok(())
    }
}

fn mission_config(args: &Args) -> Result<MissionConfig> {
    Ok(MissionConfig {
        arch: args.get_or("arch", "mlp").parse::<Arch>()?,
        env: args.get_or("env", "simple").parse::<EnvKind>()?,
        precision: args.get_or("precision", "fixed").parse::<Precision>()?,
        backend: args.get_or("backend", "cpu").parse::<BackendKind>()?,
        episodes: args.get_parse("episodes", 200usize)?,
        max_steps: args.get_parse("max-steps", 200usize)?,
        seed: args.get_parse("seed", 7u64)?,
        microbatch: args.flag("microbatch"),
        batch: args.get_parse("batch", 1usize)?,
        ..Default::default()
    })
}

/// Median per-update latency of the float CPU backend for a config, µs.
fn measure_cpu_us(net: NetConfig) -> Result<f64> {
    let mut rng = Rng::seeded(0xBEEF);
    let params = QNetParams::init(&net, 0.3, &mut rng);
    let mut backend =
        BackendFactory::offline().build(&BackendSpec::cpu(net, Precision::Float), params)?;
    let workload = Workload::synthetic(net, 2_000, 3);
    Ok(measure_backend(&mut backend, &workload, 200)?.median_us)
}

fn cmd_report(args: &Args) -> Result<()> {
    let measure = !args.flag("no-measure");
    let batch = args.get_parse("batch", 16usize)?;
    let completion = |arch, env| -> Result<report::PaperTable> {
        let inputs = report::CompletionInputs {
            measured_cpu_us: if measure {
                Some(measure_cpu_us(NetConfig::new(arch, env))?)
            } else {
                None
            },
        };
        Ok(report::table_completion(arch, env, inputs))
    };

    let table = args.get("table");
    let ablation = args.get("ablation");
    let all =
        args.flag("all") || (table.is_none() && ablation.is_none() && !args.flag("headline"));

    let mut tables: Vec<report::PaperTable> = Vec::new();
    if let Some(t) = table {
        tables.push(match t {
            "1" => report::table1(),
            "2" => report::table2(),
            "3" => completion(Arch::Perceptron, EnvKind::Simple)?,
            "4" => completion(Arch::Perceptron, EnvKind::Complex)?,
            "5" => completion(Arch::Mlp, EnvKind::Simple)?,
            "6" => completion(Arch::Mlp, EnvKind::Complex)?,
            "7" => report::table_power(EnvKind::Simple),
            "8" => report::table_power(EnvKind::Complex),
            "energy" => report::energy_table(),
            "batch" => report::table_batch(batch),
            "resilience" => report::resilience_overhead(),
            other => return Err(qfpga::error::Error::Config(format!("no table `{other}`"))),
        });
    } else if let Some(a) = ablation {
        tables.push(match a {
            "pipeline" => report::ablation_pipelining(),
            "lut" => report::ablation_lut_rom(),
            "wordlen" => report::ablation_wordlen(),
            other => {
                return Err(qfpga::error::Error::Config(format!("no ablation `{other}`")))
            }
        });
    } else if args.flag("headline") && !all {
        tables.push(report::headline());
    } else {
        // --all: the canonical list lives in report::all_tables, shared
        // with the golden-report tests
        tables = report::all_tables(|arch, env| completion(arch, env), batch)?;
    }

    for t in &tables {
        println!("{t}");
    }
    write_json(args, &report::set_to_json(&tables))
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = mission_config(args)?;
    let obs = ObsRun::begin(args);
    shutdown::install();
    println!("mission: {}", cfg.describe());
    let mut builder = Experiment::from_mission(&cfg).drain_on_signal(true);
    if let Some(dir) = args.get("checkpoint-dir") {
        builder = builder.checkpoint(dir, args.get_parse("checkpoint-every", 25usize)?);
    }
    let experiment = builder.run()?;
    let report = &experiment.rovers[0];
    let (first, last) = report.train.first_last_mean_reward(20);
    let curve = LearningCurve::from_report(&report.train, 10, 60);
    println!("reward curve   {}", curve.ascii(60));
    println!(
        "episodes {}  steps {}  updates {}  wall {:.2}s  ({:.0} updates/s)",
        report.train.episodes.len(),
        report.train.total_steps,
        report.train.total_updates,
        report.train.wall_seconds,
        report.train.updates_per_second()
    );
    println!(
        "mean reward: first-20 {first:.3} -> last-20 {last:.3} (Δ {:+.3})",
        last - first
    );
    if let (Some(us), Some(cycles)) = (report.fpga_modeled_us, report.fpga_cycles) {
        println!(
            "fpga model: {cycles} cycles = {:.1} ms on the Virtex-7 @150 MHz",
            us / 1e3
        );
    }
    if experiment.interrupted {
        println!(
            "INTERRUPTED: drained on signal after {} episode(s); rerun with the \
             same --checkpoint-dir to resume",
            report.train.episodes.len()
        );
    }
    let doc = experiment.to_json();
    write_json(args, &doc)?;
    obs.finish("train", cfg.seed, cfg.to_json(), "EXP", &doc)
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use qfpga::qlearn::SharePlan;

    let cfg = mission_config(args)?;
    let rovers = args.get_parse("rovers", 4usize)?;
    let workers = args.get_parse("workers", 0usize)?;
    let share_every = args.get_parse("share-every", 0usize)?;
    let avg_every = args.get_parse("avg-every", 0usize)?;
    let share = (share_every > 0 || avg_every > 0).then_some(SharePlan {
        exchange_every: share_every,
        avg_every,
        pool_cap: args.get_parse("pool-cap", 16usize)?,
    });
    let obs = ObsRun::begin(args);
    shutdown::install();
    let mut experiment = Experiment::from_mission(&cfg)
        .rovers(rovers)
        .workers(workers)
        .drain_on_signal(true);
    if let Some(plan) = share {
        experiment = experiment.share(plan);
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        experiment = experiment.checkpoint(dir, args.get_parse("checkpoint-every", 25usize)?);
    }
    match &share {
        Some(p) => println!(
            "fleet: {} × [{}] shared(ex{},avg{},cap{})",
            rovers,
            cfg.describe(),
            p.exchange_every,
            p.avg_every,
            p.pool_cap
        ),
        None => println!("fleet: {} × [{}]", rovers, cfg.describe()),
    }
    let report = if args.flag("progress") {
        // stream per-rover lines live from the worker pool
        experiment.run_with_progress(&|p| println!("  {}", p.render()))?
    } else {
        experiment.run()?
    };
    for (i, r) in report.rovers.iter().enumerate() {
        let (first, last) = r.train.first_last_mean_reward(20);
        println!(
            "  rover-{i}: steps {:>6}  reward {first:.3} -> {last:.3}",
            r.train.total_steps
        );
    }
    println!(
        "fleet total: {} steps on {} worker(s), {:.0} updates/s aggregate, \
         mean Δreward {:+.3}, wall {:.2}s",
        report.total_steps(),
        report.workers,
        report.aggregate_updates_per_second(),
        report.mean_learning_delta(),
        report.wall_seconds
    );
    // the replayable fleet spec is the mission config plus fleet width and
    // (when sharing) the share schedule — byte-identical to
    // `qfpga::serve::JobSpec::Fleet::to_json`, so manifests replay through
    // the same executor; worker count shapes wall time only (seeds/ordering
    // are worker-invariant), so it stays out of the spec hash
    let mut spec = cfg.to_json();
    if let Json::Obj(map) = &mut spec {
        map.insert("rovers".into(), Json::Num(rovers as f64));
        if let Some(plan) = &share {
            map.insert("share".into(), plan.to_json());
        }
    }
    let doc = report.to_json();
    write_json(args, &doc)?;
    obs.finish("fleet", cfg.seed, spec, "EXP", &doc)
}

/// `throughput` — table B2: measured CPU updates/s for the three host
/// execution paths plus fleet scaling on the worker pool.
fn cmd_throughput(args: &Args) -> Result<()> {
    use qfpga::coordinator::{throughput_table, ThroughputSpec};

    let spec = ThroughputSpec {
        updates: args.get_parse("updates", 4_000usize)?,
        batch: args.get_parse("batch", 32usize)?,
        rovers: args.get_parse("rovers", 8usize)?,
        workers: args.get_parse("workers", 0usize)?,
        episodes: args.get_parse("episodes", 25usize)?,
        max_steps: args.get_parse("max-steps", 60usize)?,
        seed: args.get_parse("seed", 7u64)?,
    };
    let obs = ObsRun::begin(args);
    println!(
        "throughput table: {} timed updates/row, batch {}, fleet {} rovers",
        spec.updates, spec.batch, spec.rovers
    );
    let table = throughput_table(&spec)?;
    println!("{table}");
    let spec_doc = Json::obj(vec![
        ("updates", Json::Num(spec.updates as f64)),
        ("batch", Json::Num(spec.batch as f64)),
        ("rovers", Json::Num(spec.rovers as f64)),
        ("workers", Json::Num(spec.workers as f64)),
        ("episodes", Json::Num(spec.episodes as f64)),
        ("max_steps", Json::Num(spec.max_steps as f64)),
        ("seed", Json::Num(spec.seed as f64)),
    ]);
    let doc = table.to_json();
    write_json(args, &doc)?;
    obs.finish("throughput", spec.seed, spec_doc, "B2", &doc)
}

/// `mission` — the scenario-library campaign: every requested environment
/// kind trained on cpu + fpga-sim through the experiment builder, reported
/// as table S1 (see SCENARIOS.md for the per-scenario documentation).
fn cmd_mission(args: &Args) -> Result<()> {
    use qfpga::coordinator::{scenario_table_with_drain, ScenarioSpec};

    let envs: Vec<EnvKind> = match args.get_or("env", "all") {
        "all" => EnvKind::all().to_vec(),
        e => vec![e.parse::<EnvKind>()?],
    };
    let spec = ScenarioSpec {
        envs,
        arch: args.get_or("arch", "mlp").parse::<Arch>()?,
        precision: args.get_or("precision", "fixed").parse::<Precision>()?,
        episodes: args.get_parse("episodes", 120usize)?,
        max_steps: args.get_parse("max-steps", 150usize)?,
        seed: args.get_parse("seed", 7u64)?,
        batch: args.get_parse("batch", 1usize)?,
    };
    let obs = ObsRun::begin(args);
    shutdown::install();
    println!(
        "scenario campaign: [{}] × [cpu + fpga-sim], {} {} ({} episodes × ≤{} steps each)",
        spec.envs.iter().map(|e| e.as_str()).collect::<Vec<_>>().join(", "),
        spec.arch.as_str(),
        spec.precision.as_str(),
        spec.episodes,
        spec.max_steps
    );
    let table = scenario_table_with_drain(&spec, true)?;
    print!("{table}");
    let doc = table.to_json();
    write_json(args, &doc)?;
    obs.finish("mission", spec.seed, spec.to_json(), "S1", &doc)
}

/// `fleetlearn` — the fleet-learning campaign: shared (transition exchange
/// + parameter averaging) vs isolated fleets swept over fleet size per
/// scenario, printed as table F1.
fn cmd_fleetlearn(args: &Args) -> Result<()> {
    use qfpga::coordinator::{fleetlearn_table_with_drain, FleetLearnSpec};

    let envs: Vec<EnvKind> = match args.get_or("env", "all") {
        "all" => EnvKind::all().to_vec(),
        e => vec![e.parse::<EnvKind>()?],
    };
    let mut fleets = Vec::new();
    for part in args.get_or("fleets", "1,2,4,8").split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        fleets.push(part.parse::<usize>().map_err(|_| {
            qfpga::error::Error::Config(format!("bad --fleets entry `{part}`"))
        })?);
    }
    let spec = FleetLearnSpec {
        envs,
        arch: args.get_or("arch", "mlp").parse::<Arch>()?,
        precision: args.get_or("precision", "fixed").parse::<Precision>()?,
        episodes: args.get_parse("episodes", 60usize)?,
        max_steps: args.get_parse("max-steps", 120usize)?,
        seed: args.get_parse("seed", 7u64)?,
        batch: args.get_parse("batch", 1usize)?,
        fleets,
        exchange_every: args.get_parse("share-every", 5usize)?,
        avg_every: args.get_parse("avg-every", 10usize)?,
        pool_cap: args.get_parse("pool-cap", 16usize)?,
    };
    let obs = ObsRun::begin(args);
    shutdown::install();
    println!(
        "fleet-learning campaign: [{}] × fleets [{}], shared(ex{},avg{},cap{}) vs \
         isolated, {} {} ({} episodes × ≤{} steps per rover)",
        spec.envs.iter().map(|e| e.as_str()).collect::<Vec<_>>().join(", "),
        spec.fleets.iter().map(|n| n.to_string()).collect::<Vec<_>>().join(", "),
        spec.exchange_every,
        spec.avg_every,
        spec.pool_cap,
        spec.arch.as_str(),
        spec.precision.as_str(),
        spec.episodes,
        spec.max_steps
    );
    let table = fleetlearn_table_with_drain(&spec, true)?;
    print!("{table}");
    let doc = table.to_json();
    write_json(args, &doc)?;
    obs.finish("fleetlearn", spec.seed, spec.to_json(), "F1", &doc)
}

/// Validate a rate the way `radiation`/`harden` need it: finite, in
/// [0, 1] upsets/bit/step, with the error spelling out the valid form.
fn parse_rate(flag: &str, text: &str) -> Result<f64> {
    let rate = text.parse::<f64>().map_err(|_| {
        qfpga::error::Error::Config(format!(
            "bad --{flag} `{text}` (expected upsets/bit/step as a number, e.g. 1e-4)"
        ))
    })?;
    if !rate.is_finite() || rate < 0.0 || rate > 1.0 {
        return Err(qfpga::error::Error::Config(format!(
            "--{flag} {rate} out of range [0, 1] upsets/bit/step (1.0 already \
             randomizes every bit every step)"
        )));
    }
    Ok(rate)
}

/// Parse `--rate-schedule`, rejecting profiles whose peak leaves [0, 1].
/// The `FromStr` error already enumerates the valid forms (`R`,
/// `spike:R0,Rpeak,START,LEN`, `phases:R1@N1,R2@N2,...`).
fn parse_rate_schedule(text: &str) -> Result<qfpga::fault::RateSchedule> {
    let schedule = text.parse::<qfpga::fault::RateSchedule>()?;
    let peak = schedule.max_rate();
    if !peak.is_finite() || peak < 0.0 || peak > 1.0 {
        return Err(qfpga::error::Error::Config(format!(
            "--rate-schedule peak rate {peak} out of range [0, 1] upsets/bit/step \
             (1.0 already randomizes every bit every step)"
        )));
    }
    Ok(schedule)
}

/// `harden` — the radiation-hardening auto-tuner: mitigation placement ×
/// CRAM scrub interval × word length Pareto-searched per environment,
/// printed as table H1.
fn cmd_harden(args: &Args) -> Result<()> {
    use qfpga::coordinator::{harden_table_with_drain, HardenSpec};
    use qfpga::fault::Mitigation;

    let d = HardenSpec::default();
    let spec = HardenSpec {
        envs: match args.get_or("env", "all") {
            "all" => EnvKind::all().to_vec(),
            e => vec![e.parse::<EnvKind>()?],
        },
        arch: args.get_or("arch", "mlp").parse::<Arch>()?,
        episodes: args.get_parse("episodes", d.episodes)?,
        max_steps: args.get_parse("max-steps", d.max_steps)?,
        seed: args.get_parse("seed", d.seed)?,
        rate: match args.get("rate") {
            Some(r) => parse_rate("rate", r)?,
            None => d.rate,
        },
        cram_rate: match args.get("cram-rate") {
            Some(r) => parse_rate("cram-rate", r)?,
            None => d.cram_rate,
        },
        schedule: match args.get("rate-schedule") {
            Some("none") => None,
            Some(s) => Some(parse_rate_schedule(s)?),
            None => d.schedule,
        },
        mitigations: match args.get("mitigations") {
            Some(list) => list
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| p.trim().parse::<Mitigation>())
                .collect::<Result<Vec<_>>>()?,
            None => d.mitigations,
        },
        scrubs: match args.get("scrubs") {
            Some(list) => list
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| match p.trim() {
                    "none" => Ok(None),
                    n => n.parse::<u32>().map(Some).map_err(|_| {
                        qfpga::error::Error::Config(format!(
                            "bad --scrubs entry `{n}` (none for unscrubbed, 0 for \
                             continuous readback, or a step interval)"
                        ))
                    }),
                })
                .collect::<Result<Vec<_>>>()?,
            None => d.scrubs,
        },
        words: match args.get("words") {
            Some(list) => list
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| {
                    p.trim().parse::<u32>().map_err(|_| {
                        qfpga::error::Error::Config(format!(
                            "bad --words entry `{p}` (use 8|12|16|18|24|32)"
                        ))
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            None => d.words,
        },
    };

    let obs = ObsRun::begin(args);
    shutdown::install();
    println!(
        "harden campaign: [{}] × mitigations [{}] × cram scrubs [{}] × words [{}], \
         data {:.1e} / cram {:.1e} upsets/bit/step{}",
        spec.envs.iter().map(|e| e.as_str()).collect::<Vec<_>>().join(", "),
        spec.mitigations.iter().map(Mitigation::label).collect::<Vec<_>>().join(", "),
        spec.scrubs
            .iter()
            .map(|s| s.map(|n| n.to_string()).unwrap_or_else(|| "none".into()))
            .collect::<Vec<_>>()
            .join(", "),
        spec.words.iter().map(|w| w.to_string()).collect::<Vec<_>>().join(", "),
        spec.rate,
        spec.cram_rate,
        spec.schedule
            .as_ref()
            .map(|s| format!(", schedule {}", s.label()))
            .unwrap_or_default(),
    );
    let table = harden_table_with_drain(&spec, true)?;
    print!("{table}");
    let doc = table.to_json();
    write_json(args, &doc)?;
    obs.finish("harden", spec.seed, spec.to_json(), "H1", &doc)
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let n = args.get_parse("updates", 1_000usize)?;
    let batch = args.get_parse("batch", 0usize)?;
    let obs = ObsRun::begin(args);
    let warmup = (n / 10).max(10).max(2 * batch);
    let factory = BackendFactory::auto();
    if !factory.has_runtime() {
        println!("(artifacts not built; skipping the xla backend)");
    }
    println!("{}", SweepReport::header());
    let mut rows = Vec::new();
    for spec in BackendSpec::matrix(&BackendKind::all()) {
        // xla artifacts are baked for the paper configurations only
        if spec.kind == BackendKind::Xla && (!factory.has_runtime() || !spec.net.env.is_paper()) {
            continue;
        }
        let workload = Workload::synthetic(spec.net, n + warmup, 11);
        let mut rng = Rng::seeded(0xF00D);
        let params = QNetParams::init(&spec.net, 0.3, &mut rng);
        let mut backend = factory.build(&spec, params)?;
        let t = measure_backend(&mut backend, &workload, warmup)?;
        print_timing(&t);
        rows.push(t);
        if batch > 1 {
            let t = measure_backend_batched(&mut backend, &workload, warmup, batch)?;
            print_timing(&t);
            rows.push(t);
        }
    }
    let sweep = SweepReport { updates: n, batch, rows };
    let spec_doc = Json::obj(vec![
        ("updates", Json::Num(n as f64)),
        ("batch", Json::Num(batch as f64)),
    ]);
    let doc = sweep.to_json();
    write_json(args, &doc)?;
    obs.finish("sweep", 0, spec_doc, "L1", &doc)
}

/// `radiation` — resilience campaign: per backend, a fault-free baseline
/// fleet plus one fleet per (rate × mitigation) cell, trained under seeded
/// SEU injection and scored as learning-delta degradation vs the modeled
/// mitigation overheads.
fn cmd_radiation(args: &Args) -> Result<()> {
    use qfpga::coordinator::sweep::resilience_scheduled;
    use qfpga::fault::{Mitigation, RadEnvironment};

    let base = MissionConfig {
        arch: args.get_or("arch", "mlp").parse::<Arch>()?,
        env: args.get_or("env", "simple").parse::<EnvKind>()?,
        precision: args.get_or("precision", "fixed").parse::<Precision>()?,
        episodes: args.get_parse("episodes", 150usize)?,
        max_steps: args.get_parse("max-steps", 200usize)?,
        seed: args.get_parse("seed", 7u64)?,
        batch: args.get_parse("batch", 1usize)?,
        ..Default::default()
    };

    let rad_env = args.get_or("rad-env", "mars-surface").parse::<RadEnvironment>()?;
    let rate = match args.get("rate") {
        Some(r) => parse_rate("rate", r)?,
        None => rad_env.upsets_per_bit_per_step(),
    };
    let schedule = args.get("rate-schedule").map(parse_rate_schedule).transpose()?;

    let mitigations: Vec<Mitigation> = match args.get_or("mitigation", "all") {
        "all" => Mitigation::all().to_vec(),
        m => vec![m.parse::<Mitigation>()?],
    };
    let backends: Vec<BackendKind> = match args.get_or("backend", "all") {
        "all" => vec![BackendKind::Cpu, BackendKind::FpgaSim],
        b => vec![b.parse::<BackendKind>()?],
    };
    let rovers = args.get_parse("rovers", 2usize)?.max(1);
    let obs = ObsRun::begin(args);

    println!(
        "radiation campaign: {} × [{} {} {}] @ {rate:.1e} upsets/bit/step ({}){}, \
         mitigations [{}], {rovers} rovers/cell",
        backends.iter().map(|b| b.as_str()).collect::<Vec<_>>().join("+"),
        base.arch.as_str(),
        base.env.as_str(),
        base.precision.as_str(),
        if args.get("rate").is_some() { "explicit".to_string() } else { rad_env.label() },
        schedule
            .as_ref()
            .map(|s| format!(", schedule {}", s.label()))
            .unwrap_or_default(),
        mitigations.iter().map(Mitigation::label).collect::<Vec<_>>().join(", "),
    );

    let campaign =
        resilience_scheduled(&base, &backends, &[rate], &mitigations, rovers, schedule.clone())?;
    print!("{}", campaign.render());
    let mut spec_fields = vec![
        ("mission", base.to_json()),
        ("rate", Json::Num(rate)),
        (
            "mitigations",
            Json::Arr(mitigations.iter().map(|m| Json::Str(m.label())).collect()),
        ),
        (
            "backends",
            Json::Arr(
                backends
                    .iter()
                    .map(|b| Json::Str(b.as_str().into()))
                    .collect(),
            ),
        ),
        ("rovers", Json::Num(rovers as f64)),
    ];
    // only-when-set keeps constant-rate spec documents byte-identical to
    // the pre-schedule wire format
    if let Some(s) = &schedule {
        spec_fields.push(("schedule", s.to_json()));
    }
    let spec_doc = Json::obj(spec_fields);
    let doc = campaign.to_json();
    write_json(args, &doc)?;
    obs.finish("radiation", base.seed, spec_doc, "R2", &doc)
}

fn print_timing(t: &qfpga::coordinator::WorkloadTiming) {
    println!("{}", t.render_row());
}

fn cmd_validate(args: &Args) -> Result<()> {
    let n = args.get_parse("updates", 50usize)?;
    let offline = BackendFactory::offline();
    let mut table = report::PaperTable::new(
        "V1",
        format!("Cross-backend conformance ({n} synthetic updates)"),
        "max |Δ|",
    );

    // ---- local conformance (no artifacts needed): the native batch paths
    // must reproduce the stepwise paths on identical transition streams
    println!("batch-vs-stepwise conformance (native update_batch paths):");
    let mut worst_batch: f64 = 0.0;
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let mut rng = Rng::seeded(0xCAFE);
            let params = QNetParams::init(&net, 0.3, &mut rng);
            let w = Workload::synthetic(net, n, 21);
            let batch = w.flat_batch(0, n);

            let mut cpu_step = offline.build(&BackendSpec::cpu(net, prec), params.clone())?;
            let mut cpu_batch = offline.build(&BackendSpec::cpu(net, prec), params.clone())?;
            let mut sim_step =
                offline.build(&BackendSpec::fpga_sim(net, prec), params.clone())?;
            let mut sim_batch = offline.build(&BackendSpec::fpga_sim(net, prec), params)?;

            let cpu_errs = cpu_batch.update_batch(&batch)?;
            let sim_errs = sim_batch.update_batch(&batch)?;
            let mut max_diff = 0f64;
            for (i, t) in batch.transitions().enumerate() {
                let e_cpu = cpu_step.update(t.sa_cur, t.sa_next, t.action, t.reward)? as f64;
                let e_sim = sim_step.update(t.sa_cur, t.sa_next, t.action, t.reward)? as f64;
                max_diff = max_diff.max((cpu_errs[i] as f64 - e_cpu).abs());
                max_diff = max_diff.max((sim_errs[i] as f64 - e_sim).abs());
            }
            max_diff = max_diff.max(cpu_batch.params().max_abs_diff(&cpu_step.params()) as f64);
            max_diff = max_diff.max(sim_batch.params().max_abs_diff(&sim_step.params()) as f64);
            println!(
                "  {:<26} {:<6} max |Δ| over {n} updates: {max_diff:.2e}",
                net.name(),
                prec.as_str()
            );
            table = table.row(
                format!("batch-vs-stepwise {} {}", net.name(), prec.as_str()),
                max_diff,
                None,
            );
            worst_batch = worst_batch.max(max_diff);
        }
    }
    if worst_batch > 1e-5 {
        // still honor --json on the failing path: the per-config rows are
        // exactly what a CI consumer needs to localize the divergence
        table = table.note(format!(
            "FAILED: batch path diverged from stepwise by {worst_batch:.2e} (budget 1e-5)"
        ));
        write_json(args, &table.to_json())?;
        return Err(qfpga::error::Error::Config(format!(
            "batch path diverged from stepwise by {worst_batch:.2e} (budget 1e-5)"
        )));
    }

    // ---- cross-backend check including XLA (needs built artifacts)
    let factory = match Runtime::from_default_dir() {
        Ok(rt) => BackendFactory::with_runtime(rt),
        Err(e) => {
            println!("OK: batch == stepwise within 1e-5 (xla cross-check skipped: {e})");
            table = table.note(format!("xla cross-check skipped: {e}"));
            return write_json(args, &table.to_json());
        }
    };
    let mut worst: f64 = 0.0;
    for net in NetConfig::all() {
        for prec in [Precision::Fixed, Precision::Float] {
            let mut rng = Rng::seeded(0xCAFE);
            let params = QNetParams::init(&net, 0.3, &mut rng);
            let w = Workload::synthetic(net, n, 21);
            let batch = w.flat_batch(0, n);
            let mut xla = factory.build(&BackendSpec::xla(net, prec), params.clone())?;
            let mut cpu = factory.build(&BackendSpec::cpu(net, prec), params.clone())?;
            let mut sim = factory.build(&BackendSpec::fpga_sim(net, prec), params)?;
            let mut max_diff = 0f64;
            for t in batch.transitions() {
                let e1 = xla.update(t.sa_cur, t.sa_next, t.action, t.reward)? as f64;
                let e2 = cpu.update(t.sa_cur, t.sa_next, t.action, t.reward)? as f64;
                let e3 = sim.update(t.sa_cur, t.sa_next, t.action, t.reward)? as f64;
                max_diff = max_diff.max((e1 - e2).abs()).max((e1 - e3).abs());
            }
            println!(
                "{:<28} {:<6} max |Δq_err| over {n} updates: {max_diff:.2e}",
                net.name(),
                prec.as_str()
            );
            table = table.row(
                format!("cross-backend {} {}", net.name(), prec.as_str()),
                max_diff,
                None,
            );
            worst = worst.max(max_diff);
        }
    }
    let budget = 4.0 / 4096.0; // 4 LSB of Q(18,12)
    if worst > budget {
        table = table.note(format!(
            "FAILED: cross-backend divergence {worst:.2e} exceeds budget {budget:.2e}"
        ));
        write_json(args, &table.to_json())?;
        return Err(qfpga::error::Error::Config(format!(
            "cross-backend divergence {worst:.2e} exceeds budget {budget:.2e}"
        )));
    }
    println!("OK: all backends agree within {budget:.2e}");
    table = table.note(format!("cross-backend budget {budget:.2e}, batch budget 1e-5"));
    write_json(args, &table.to_json())
}

/// `serve` — run the mission gateway daemon on a unix socket until a
/// drain signal (SIGINT/SIGTERM or a `shutdown` frame) lands, then exit 0.
fn cmd_serve(args: &Args) -> Result<()> {
    use qfpga::serve::{Gateway, ServeConfig};

    let Some(socket) = args.get("socket") else {
        return Err(qfpga::error::Error::Config(
            "usage: qfpga serve --socket PATH [--workers W] [--queue N] [--chunk E]".into(),
        ));
    };
    let mut cfg = ServeConfig::new(socket);
    cfg.workers = args.get_parse("workers", 2usize)?.max(1);
    cfg.queue_capacity = args.get_parse("queue", 64usize)?.max(1);
    cfg.chunk = args.get_parse("chunk", 8usize)?.max(1);
    shutdown::install();
    println!(
        "gateway listening on {} — {} worker(s), queue {}, preemption chunk {} \
         episode(s); SIGINT/SIGTERM drains",
        cfg.socket.display(),
        cfg.workers,
        cfg.queue_capacity,
        cfg.chunk
    );
    let stats = Gateway::new(cfg)?.run()?;
    println!(
        "gateway drained: {} submitted, {} completed ({} cache hit(s)), \
         {} preemption(s), {} rejected",
        stats.submitted, stats.completed, stats.cache_hits, stats.preemptions, stats.rejected
    );
    Ok(())
}

/// `loadgen` — drive a gateway (embedded width sweep, or a running daemon
/// via `--socket`) with a deterministic job mix and print table G1.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use qfpga::serve::{run_loadgen, LoadgenSpec};

    let mut widths = Vec::new();
    for part in args.get_or("widths", "1,2,4").split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        widths.push(part.parse::<usize>().map_err(|_| {
            qfpga::error::Error::Config(format!("bad --widths entry `{part}`"))
        })?);
    }
    if widths.is_empty() {
        return Err(qfpga::error::Error::Config(
            "--widths needs at least one worker width".into(),
        ));
    }
    let spec = LoadgenSpec {
        socket: args.get("socket").map(std::path::PathBuf::from),
        jobs: args.get_parse("jobs", 12usize)?,
        concurrency: args.get_parse("concurrency", 3usize)?.max(1),
        widths,
        episodes: args.get_parse("episodes", 3usize)?,
        max_steps: args.get_parse("max-steps", 15usize)?,
        seed: args.get_parse("seed", 7u64)?,
    };
    let out = run_loadgen(&spec)?;
    println!("{}", out.table);
    if let Some(path) = args.get("fetch-metrics") {
        // external mode scrapes the daemon's `metrics` verb; embedded
        // daemons share this process's registry, so snapshot it directly
        let text = match &out.prometheus {
            Some(text) => text.clone(),
            None => MetricsSnapshot::capture().to_prometheus(),
        };
        std::fs::write(path, text)?;
        println!("wrote metrics {path}");
    }
    write_json(args, &report::set_to_json(std::slice::from_ref(&out.table)))?;
    if let Some(raw) = args.get("expect-hits") {
        let expect: u64 = raw.parse().map_err(|_| {
            qfpga::error::Error::Config(format!("bad --expect-hits `{raw}`"))
        })?;
        if !out.hits_per_pass.iter().all(|&h| h == expect) {
            return Err(qfpga::error::Error::Config(format!(
                "cache-hit mismatch: expected {expect} per pass, observed {:?}",
                out.hits_per_pass
            )));
        }
        println!(
            "cache hits OK: {expect} per pass × {} pass(es)",
            out.hits_per_pass.len()
        );
    }
    Ok(())
}

fn cmd_diff(args: &Args) -> Result<()> {
    let pos = args.positional();
    let (Some(ours), Some(golden)) = (pos.get(1), pos.get(2)) else {
        return Err(qfpga::error::Error::Config(
            "usage: qfpga diff <ours.json> <golden.json> [--tol T] [--ignore-keys k1,k2]".into(),
        ));
    };
    let tol = args.get_parse("tol", 0.05f64)?;
    let ignore: Vec<&str> = args
        .get("ignore-keys")
        .map(|s| s.split(',').map(str::trim).filter(|k| !k.is_empty()).collect())
        .unwrap_or_default();
    let d = report::diff_files(ours, golden, tol, &ignore)?;
    print!("{}", d.render(tol));
    if d.compared == 0 {
        // a gate that compared nothing must not report success
        return Err(qfpga::error::Error::Config(format!(
            "no comparable values between `{ours}` and `{golden}` — are these \
             report JSON files with matching table ids?"
        )));
    }
    if !d.ok() {
        return Err(qfpga::error::Error::Config(format!(
            "{} report value(s) drifted beyond tolerance {tol} vs `{golden}`",
            d.problems.len()
        )));
    }
    Ok(())
}

/// `manifest validate <file.json>` — parse + integrity-check a manifest.
fn cmd_manifest(args: &Args) -> Result<()> {
    let pos = args.positional();
    let (Some(verb), Some(path)) = (pos.get(1), pos.get(2)) else {
        return Err(qfpga::error::Error::Config(
            "usage: qfpga manifest validate <file.json>".into(),
        ));
    };
    if verb != "validate" {
        return Err(qfpga::error::Error::Config(format!(
            "unknown manifest verb `{verb}` (expected `validate`)"
        )));
    }
    let m = RunManifest::load(Path::new(path))?;
    println!("manifest OK: {path}");
    println!("  schema          {}", m.schema_version);
    println!("  run             {}", m.run_id);
    println!("  subcommand      {} (report {})", m.subcommand, m.report_id);
    println!("  git             {}", m.git_describe);
    println!("  seed            {}", m.seed);
    println!("  spec_sha256     {}", m.spec_sha256);
    println!("  report_sha256   {}", m.report_sha256);
    println!("  manifest_sha256 {}", m.manifest_sha256);
    Ok(())
}

/// Re-run a manifest's recorded spec and return the reproduced report
/// document. Only seed-deterministic subcommands are replayable; the
/// measurement campaigns (`sweep`, `throughput`, `radiation` overheads)
/// record host-timed results that no re-run can reproduce bit-exactly.
/// Replay and the gateway share one executor — [`qfpga::serve::JobSpec`] —
/// so a spec the daemon caches is a spec `replay` can verify.
fn replay_report(m: &RunManifest) -> Result<Json> {
    if !m.is_replayable() {
        return Err(qfpga::error::Error::Config(format!(
            "`{}` manifests validate but cannot replay: only the \
             train/fleet/mission job shapes can be scheduled (measurement \
             campaigns record host-timed results; `fleetlearn` and `harden` \
             sweeps are re-checked with `qfpga fleetlearn --json` / \
             `qfpga harden --json` + `qfpga diff` instead)",
            m.subcommand
        )));
    }
    qfpga::serve::JobSpec::from_manifest(&m.subcommand, &m.spec)?.run(&|_| {})
}

/// `replay <manifest.json>` — re-run the recorded spec and require the
/// reproduced report projection to hash identically to the recorded one.
fn cmd_replay(args: &Args) -> Result<()> {
    let pos = args.positional();
    let Some(path) = pos.get(1) else {
        return Err(qfpga::error::Error::Config(
            "usage: qfpga replay <manifest.json>".into(),
        ));
    };
    let m = RunManifest::load(Path::new(path))?;
    println!(
        "replaying {} run {} (seed {}, spec {}…)",
        m.subcommand,
        m.run_id,
        m.seed,
        &m.spec_sha256[..12]
    );
    let doc = replay_report(&m)?;
    let got = qfpga::obs::manifest::report_sha256(&doc);
    if got != m.report_sha256 {
        return Err(qfpga::error::Error::Config(format!(
            "replay diverged: recorded report_sha256 {} but the re-run produced {got} — \
             the build is no longer bit-compatible with this manifest",
            m.report_sha256
        )));
    }
    println!("replay OK: report_sha256 {got} reproduced bit-exactly");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dev = Virtex7::default();
    println!("device: Virtex-7 XC7VX485T @ {:.0} MHz", dev.clock_hz / 1e6);
    println!(
        "  {} LUT / {} FF / {} DSP48 / {} BRAM36",
        dev.luts, dev.ffs, dev.dsps, dev.bram36
    );
    let t = TimingModel::default();
    println!("cycle model (per Q-update):");
    let mut model_rows = Vec::new();
    for net in NetConfig::all() {
        for prec in Precision::all() {
            let b = t.qupdate(&net, prec);
            let us = dev.cycles_to_us(b.total());
            println!(
                "  {:<22} {:<6} {:>7} cycles = {:>9.2} µs",
                net.name(),
                prec.as_str(),
                b.total(),
                us
            );
            model_rows.push(Json::obj(vec![
                ("config", Json::Str(net.name())),
                ("precision", Json::Str(prec.as_str().into())),
                ("cycles", Json::Num(b.total() as f64)),
                ("us", Json::Num(us)),
            ]));
        }
    }
    let artifacts = match Runtime::from_default_dir() {
        Ok(rt) => {
            println!(
                "artifacts: {} modules in {} (platform {})",
                rt.manifest().artifacts.len(),
                rt.manifest().dir.display(),
                rt.platform()
            );
            Json::obj(vec![
                ("available", Json::Bool(true)),
                ("modules", Json::Num(rt.manifest().artifacts.len() as f64)),
                ("platform", Json::Str(rt.platform().to_string())),
            ])
        }
        Err(e) => {
            println!("artifacts: unavailable ({e})");
            Json::obj(vec![
                ("available", Json::Bool(false)),
                ("error", Json::Str(e.to_string())),
            ])
        }
    };
    let doc = Json::obj(vec![
        ("id", Json::Str("INFO".into())),
        (
            "device",
            Json::obj(vec![
                ("name", Json::Str("Virtex-7 XC7VX485T".into())),
                ("clock_hz", Json::Num(dev.clock_hz)),
                ("luts", Json::Num(dev.luts as f64)),
                ("ffs", Json::Num(dev.ffs as f64)),
                ("dsps", Json::Num(dev.dsps as f64)),
                ("bram36", Json::Num(dev.bram36 as f64)),
            ]),
        ),
        ("cycle_model", Json::Arr(model_rows)),
        ("artifacts", artifacts),
    ]);
    write_json(args, &doc)
}

#[cfg(test)]
mod tests {
    use super::{COMMANDS, USAGE};

    /// The `USAGE: qfpga <...>` synopsis must list exactly the dispatchable
    /// subcommands (plus `help`) — adding an arm to `COMMANDS` without
    /// updating the help text fails here, and vice versa.
    #[test]
    fn usage_synopsis_matches_the_dispatch_table() {
        let synopsis = USAGE
            .lines()
            .find(|l| l.starts_with("USAGE: qfpga <"))
            .expect("USAGE synopsis line");
        let inner = synopsis
            .split_once('<')
            .and_then(|(_, rest)| rest.split_once('>'))
            .map(|(inner, _)| inner)
            .expect("angle-bracketed subcommand list");
        let mut listed: Vec<&str> = inner.split('|').collect();
        listed.sort_unstable();
        let mut known: Vec<&str> = COMMANDS.iter().map(|(n, _)| *n).collect();
        known.push("help");
        known.sort_unstable();
        assert_eq!(listed, known, "USAGE synopsis drifted from COMMANDS");
    }

    /// Every dispatchable subcommand must open a help block in USAGE —
    /// a line starting with its name — so `qfpga help` documents all of
    /// them, not just the ones someone remembered.
    #[test]
    fn every_subcommand_has_a_usage_help_block() {
        for (name, _) in COMMANDS {
            let has_block = USAGE.lines().any(|l| {
                let t = l.trim_start();
                t.starts_with(name)
                    && t[name.len()..].starts_with(|c: char| c == ' ' || c == '\t')
            });
            assert!(has_block, "no USAGE help block for subcommand `{name}`");
        }
    }

    /// The dispatch table stays duplicate-free (a duplicate would shadow
    /// the later handler silently — `find` returns the first match).
    #[test]
    fn dispatch_table_has_no_duplicates() {
        let mut names: Vec<&str> = COMMANDS.iter().map(|(n, _)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), COMMANDS.len());
    }
}
