//! `qfpga` — CLI for the FPGA Q-learning accelerator reproduction.
//!
//! Subcommands:
//!
//! * `report [--table N|--headline|--ablation X|--all]` — regenerate the
//!   paper's tables (with paper-vs-ours ratios).
//! * `train  [--arch A --env E --precision P --backend B --episodes N]` —
//!   run one rover mission and print its learning curve.
//! * `fleet  [--rovers N ...]` — multi-rover mission via the scheduler.
//! * `sweep  [--updates N]` — measured per-update latency for every
//!   backend × configuration (the measured side of Tables 3–6).
//! * `validate` — cross-backend numeric equivalence over random workloads.
//! * `info` — artifact manifest + device/model summary.

use std::process::ExitCode;

use qfpga::config::{Arch, EnvKind, Hyper, NetConfig, Precision};
use qfpga::coordinator::sweep::Workload;
use qfpga::coordinator::telemetry::LearningCurve;
use qfpga::coordinator::{measure_backend, run_fleet, run_mission, MissionConfig};
use qfpga::error::Result;
use qfpga::fpga::{TimingModel, Virtex7};
use qfpga::nn::params::QNetParams;
use qfpga::qlearn::backend::{BackendKind, CpuBackend, FpgaSimBackend, XlaBackend};
use qfpga::report;
use qfpga::report::CompletionInputs;
use qfpga::runtime::Runtime;
use qfpga::util::cli::Args;
use qfpga::util::Rng;

const USAGE: &str = "\
qfpga — FPGA Q-learning accelerator reproduction (Gankidi & Thangavelautham 2017)

USAGE: qfpga <report|train|fleet|sweep|radiation|validate|info> [options]

  report    --table 1..8|batch|resilience | --headline
            | --ablation pipeline|lut|wordlen | --all
            [--no-measure]        skip measuring the host-CPU rows
            [--batch B]           batch size for the B1 batched-datapath table
  train     --arch perceptron|mlp --env simple|complex --precision fixed|float
            --backend cpu|xla|fpga-sim --episodes N --max-steps N --seed S
            [--microbatch]        flush at the backend's preferred batch size
            [--batch B]           flush through update_batch every B steps
  fleet     --rovers N            plus all `train` options (incl. --batch)
  sweep     --updates N           per-update latency, all backends/configs
            [--batch B]           also measure the batched update_batch path
  radiation resilience campaign: train under seeded SEU injection and print
            learning-delta degradation vs mitigation overhead
            [--rate R]            upsets per bit per step (overrides --rad-env)
            [--rad-env E]         cruise|mars-surface|jupiter-flyby (default
                                  mars-surface; rates are per bit per kilostep)
            [--mitigation M]      none|tmr|scrub[:N]|ecc|all   (default all)
            [--backend B]         cpu|fpga-sim|all              (default all)
            [--rovers N]          fleet width per campaign cell (default 2)
            [--json FILE]         also write the machine-readable report
            plus --arch/--env/--precision/--episodes/--max-steps/--seed
  validate  --updates N           cross-backend + batch/stepwise equivalence
  info                            artifacts, device, cycle model summary
";

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&["all", "headline", "measure", "microbatch", "no-measure"])?;
    match args.positional().first().map(String::as_str) {
        Some("report") => cmd_report(&args),
        Some("train") => cmd_train(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("radiation") => cmd_radiation(&args),
        Some("validate") => cmd_validate(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn mission_config(args: &Args) -> Result<MissionConfig> {
    Ok(MissionConfig {
        arch: args.get_or("arch", "mlp").parse::<Arch>()?,
        env: args.get_or("env", "simple").parse::<EnvKind>()?,
        precision: args.get_or("precision", "fixed").parse::<Precision>()?,
        backend: args.get_or("backend", "cpu").parse::<BackendKind>()?,
        episodes: args.get_parse("episodes", 200usize)?,
        max_steps: args.get_parse("max-steps", 200usize)?,
        seed: args.get_parse("seed", 7u64)?,
        hyper: Hyper::default(),
        microbatch: args.flag("microbatch"),
        batch: args.get_parse("batch", 1usize)?,
    })
}

/// Median per-update latency of the float CPU backend for a config, µs.
fn measure_cpu_us(net: NetConfig) -> Result<f64> {
    let mut rng = Rng::seeded(0xBEEF);
    let params = QNetParams::init(&net, 0.3, &mut rng);
    let mut backend = CpuBackend::new(net, Precision::Float, params, Hyper::default());
    let workload = Workload::synthetic(net, 2_000, 3);
    Ok(measure_backend(&mut backend, &workload, 200)?.median_us)
}

fn cmd_report(args: &Args) -> Result<()> {
    let measure = !args.flag("no-measure");
    let completion = |arch, env| -> Result<()> {
        let inputs = CompletionInputs {
            measured_cpu_us: if measure {
                Some(measure_cpu_us(NetConfig::new(arch, env))?)
            } else {
                None
            },
        };
        println!("{}", report::table_completion(arch, env, inputs));
        Ok(())
    };

    let table = args.get("table");
    let ablation = args.get("ablation");
    let all =
        args.flag("all") || (table.is_none() && ablation.is_none() && !args.flag("headline"));

    if let Some(t) = table {
        match t {
            "1" => println!("{}", report::table1()),
            "2" => println!("{}", report::table2()),
            "3" => completion(Arch::Perceptron, EnvKind::Simple)?,
            "4" => completion(Arch::Perceptron, EnvKind::Complex)?,
            "5" => completion(Arch::Mlp, EnvKind::Simple)?,
            "6" => completion(Arch::Mlp, EnvKind::Complex)?,
            "7" => println!("{}", report::table_power(EnvKind::Simple)),
            "8" => println!("{}", report::table_power(EnvKind::Complex)),
            "energy" => println!("{}", report::energy_table()),
            "batch" => println!("{}", report::table_batch(args.get_parse("batch", 16usize)?)),
            "resilience" => println!("{}", report::resilience_overhead()),
            other => return Err(qfpga::error::Error::Config(format!("no table `{other}`"))),
        }
        return Ok(());
    }
    if let Some(a) = ablation {
        match a {
            "pipeline" => println!("{}", report::ablation_pipelining()),
            "lut" => println!("{}", report::ablation_lut_rom()),
            "wordlen" => println!("{}", report::ablation_wordlen()),
            other => return Err(qfpga::error::Error::Config(format!("no ablation `{other}`"))),
        }
        return Ok(());
    }
    if args.flag("headline") && !all {
        println!("{}", report::headline());
        return Ok(());
    }

    // --all
    println!("{}", report::table1());
    println!("{}", report::table2());
    completion(Arch::Perceptron, EnvKind::Simple)?;
    completion(Arch::Perceptron, EnvKind::Complex)?;
    completion(Arch::Mlp, EnvKind::Simple)?;
    completion(Arch::Mlp, EnvKind::Complex)?;
    println!("{}", report::table_power(EnvKind::Simple));
    println!("{}", report::table_power(EnvKind::Complex));
    println!("{}", report::energy_table());
    println!("{}", report::table_batch(args.get_parse("batch", 16usize)?));
    println!("{}", report::resilience_overhead());
    println!("{}", report::headline());
    println!("{}", report::ablation_pipelining());
    println!("{}", report::ablation_lut_rom());
    println!("{}", report::ablation_wordlen());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = mission_config(args)?;
    println!("mission: {}", cfg.describe());
    let runtime = match cfg.backend {
        BackendKind::Xla => Some(Runtime::from_default_dir()?),
        _ => None,
    };
    let report = run_mission(&cfg, runtime.as_ref())?;
    let (first, last) = report.train.first_last_mean_reward(20);
    let curve = LearningCurve::from_report(&report.train, 10, 60);
    println!("reward curve   {}", curve.ascii(60));
    println!(
        "episodes {}  steps {}  updates {}  wall {:.2}s  ({:.0} updates/s)",
        report.train.episodes.len(),
        report.train.total_steps,
        report.train.total_updates,
        report.train.wall_seconds,
        report.train.updates_per_second()
    );
    println!(
        "mean reward: first-20 {first:.3} -> last-20 {last:.3} (Δ {:+.3})",
        last - first
    );
    if let (Some(us), Some(cycles)) = (report.fpga_modeled_us, report.fpga_cycles) {
        println!(
            "fpga model: {cycles} cycles = {:.1} ms on the Virtex-7 @150 MHz",
            us / 1e3
        );
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let cfg = mission_config(args)?;
    let rovers = args.get_parse("rovers", 4usize)?;
    println!("fleet: {} × [{}]", rovers, cfg.describe());
    let report = run_fleet(&cfg, rovers)?;
    for (i, r) in report.rovers.iter().enumerate() {
        let (first, last) = r.train.first_last_mean_reward(20);
        println!(
            "  rover-{i}: steps {:>6}  reward {first:.3} -> {last:.3}",
            r.train.total_steps
        );
    }
    println!(
        "fleet total: {} steps, {:.0} updates/s aggregate, mean Δreward {:+.3}, wall {:.2}s",
        report.total_steps(),
        report.aggregate_updates_per_second(),
        report.mean_learning_delta(),
        report.wall_seconds
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use qfpga::coordinator::measure_backend_batched;
    let n = args.get_parse("updates", 1_000usize)?;
    let batch = args.get_parse("batch", 0usize)?;
    let warmup = (n / 10).max(10).max(2 * batch);
    let runtime = Runtime::from_default_dir().ok();
    if runtime.is_none() {
        println!("(artifacts not built; skipping the xla backend)");
    }
    println!(
        "{:<38} {:>10} {:>10} {:>12}",
        "backend", "mean µs", "median µs", "kQ/s"
    );
    for net in NetConfig::all() {
        let workload = Workload::synthetic(net, n + warmup, 11);
        for prec in [Precision::Fixed, Precision::Float] {
            let mut rng = Rng::seeded(0xF00D);
            let params = QNetParams::init(&net, 0.3, &mut rng);

            let mut cpu = CpuBackend::new(net, prec, params.clone(), Hyper::default());
            print_timing(measure_backend(&mut cpu, &workload, warmup)?);
            if batch > 1 {
                print_timing(measure_backend_batched(&mut cpu, &workload, warmup, batch)?);
            }

            let mut sim = FpgaSimBackend::new(net, prec, params.clone(), Hyper::default());
            print_timing(measure_backend(&mut sim, &workload, warmup)?);
            if batch > 1 {
                print_timing(measure_backend_batched(&mut sim, &workload, warmup, batch)?);
            }

            if let Some(rt) = &runtime {
                let mut xla = XlaBackend::new(rt, net, prec, params)?;
                print_timing(measure_backend(&mut xla, &workload, warmup)?);
                if batch > 1 {
                    print_timing(measure_backend_batched(&mut xla, &workload, warmup, batch)?);
                }
            }
        }
    }
    Ok(())
}

/// `radiation` — resilience campaign: per backend, a fault-free baseline
/// fleet plus one fleet per (rate × mitigation) cell, trained under seeded
/// SEU injection and scored as learning-delta degradation vs the modeled
/// mitigation overheads.
fn cmd_radiation(args: &Args) -> Result<()> {
    use qfpga::coordinator::sweep::resilience;
    use qfpga::fault::{Mitigation, RadEnvironment};

    let base = MissionConfig {
        arch: args.get_or("arch", "mlp").parse::<Arch>()?,
        env: args.get_or("env", "simple").parse::<EnvKind>()?,
        precision: args.get_or("precision", "fixed").parse::<Precision>()?,
        episodes: args.get_parse("episodes", 150usize)?,
        max_steps: args.get_parse("max-steps", 200usize)?,
        seed: args.get_parse("seed", 7u64)?,
        batch: args.get_parse("batch", 1usize)?,
        ..Default::default()
    };

    let rad_env = args.get_or("rad-env", "mars-surface").parse::<RadEnvironment>()?;
    let rate = match args.get("rate") {
        Some(r) => r
            .parse::<f64>()
            .map_err(|_| qfpga::error::Error::Config(format!("bad --rate `{r}`")))?,
        None => rad_env.upsets_per_bit_per_step(),
    };
    if !rate.is_finite() || rate < 0.0 || rate > 1.0 {
        return Err(qfpga::error::Error::Config(format!(
            "--rate {rate} out of range [0, 1] upsets/bit/step (1.0 already \
             randomizes every bit every step)"
        )));
    }

    let mitigations: Vec<Mitigation> = match args.get_or("mitigation", "all") {
        "all" => Mitigation::all().to_vec(),
        m => vec![m.parse::<Mitigation>()?],
    };
    let backends: Vec<BackendKind> = match args.get_or("backend", "all") {
        "all" => vec![BackendKind::Cpu, BackendKind::FpgaSim],
        b => vec![b.parse::<BackendKind>()?],
    };
    let rovers = args.get_parse("rovers", 2usize)?.max(1);

    println!(
        "radiation campaign: {} × [{} {} {}] @ {rate:.1e} upsets/bit/step ({}), \
         mitigations [{}], {rovers} rovers/cell",
        backends.iter().map(|b| b.as_str()).collect::<Vec<_>>().join("+"),
        base.arch.as_str(),
        base.env.as_str(),
        base.precision.as_str(),
        if args.get("rate").is_some() { "explicit".to_string() } else { rad_env.label() },
        mitigations.iter().map(Mitigation::label).collect::<Vec<_>>().join(", "),
    );

    let report = resilience(&base, &backends, &[rate], &mitigations, rovers)?;
    print!("{}", report.render());

    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn print_timing(t: qfpga::coordinator::WorkloadTiming) {
    println!(
        "{:<38} {:>10.2} {:>10.2} {:>12.1}",
        t.backend_name, t.mean_us, t.median_us, t.kq_per_s
    );
}

fn cmd_validate(args: &Args) -> Result<()> {
    use qfpga::qlearn::backend::QBackend;
    let n = args.get_parse("updates", 50usize)?;

    // ---- local conformance (no artifacts needed): the native batch paths
    // must reproduce the stepwise paths on identical transition streams
    println!("batch-vs-stepwise conformance (native update_batch paths):");
    let mut worst_batch: f64 = 0.0;
    for net in NetConfig::all() {
        for prec in [Precision::Fixed, Precision::Float] {
            let mut rng = Rng::seeded(0xCAFE);
            let params = QNetParams::init(&net, 0.3, &mut rng);
            let w = Workload::synthetic(net, n, 21);
            let batch = w.flat_batch(0, n);
            let step = net.a * net.d;

            let mut cpu_step = CpuBackend::new(net, prec, params.clone(), Hyper::default());
            let mut cpu_batch = CpuBackend::new(net, prec, params.clone(), Hyper::default());
            let mut sim_step = FpgaSimBackend::new(net, prec, params.clone(), Hyper::default());
            let mut sim_batch = FpgaSimBackend::new(net, prec, params, Hyper::default());

            let cpu_errs = cpu_batch.update_batch(&batch)?;
            let sim_errs = sim_batch.update_batch(&batch)?;
            let mut max_diff = 0f64;
            for i in 0..n {
                let sc = &w.sa_cur[i * step..(i + 1) * step];
                let sn = &w.sa_next[i * step..(i + 1) * step];
                let e_cpu = cpu_step.update(sc, sn, w.actions[i], w.rewards[i])? as f64;
                let e_sim = sim_step.update(sc, sn, w.actions[i], w.rewards[i])? as f64;
                max_diff = max_diff.max((cpu_errs[i] as f64 - e_cpu).abs());
                max_diff = max_diff.max((sim_errs[i] as f64 - e_sim).abs());
            }
            max_diff = max_diff.max(cpu_batch.params().max_abs_diff(&cpu_step.params()) as f64);
            max_diff = max_diff.max(sim_batch.params().max_abs_diff(&sim_step.params()) as f64);
            println!(
                "  {:<26} {:<6} max |Δ| over {n} updates: {max_diff:.2e}",
                net.name(),
                prec.as_str()
            );
            worst_batch = worst_batch.max(max_diff);
        }
    }
    if worst_batch > 1e-5 {
        return Err(qfpga::error::Error::Config(format!(
            "batch path diverged from stepwise by {worst_batch:.2e} (budget 1e-5)"
        )));
    }

    // ---- cross-backend check including XLA (needs built artifacts)
    let rt = match Runtime::from_default_dir() {
        Ok(rt) => rt,
        Err(e) => {
            println!("OK: batch == stepwise within 1e-5 (xla cross-check skipped: {e})");
            return Ok(());
        }
    };
    let mut worst: f64 = 0.0;
    for net in NetConfig::all() {
        for prec in [Precision::Fixed, Precision::Float] {
            let mut rng = Rng::seeded(0xCAFE);
            let params = QNetParams::init(&net, 0.3, &mut rng);
            let w = Workload::synthetic(net, n, 21);
            let mut xla = XlaBackend::new(&rt, net, prec, params.clone())?;
            let mut cpu = CpuBackend::new(net, prec, params.clone(), Hyper::default());
            let mut sim = FpgaSimBackend::new(net, prec, params, Hyper::default());
            let step = net.a * net.d;
            let mut max_diff = 0f64;
            for i in 0..n {
                let sc = &w.sa_cur[i * step..(i + 1) * step];
                let sn = &w.sa_next[i * step..(i + 1) * step];
                let e1 = xla.update(sc, sn, w.actions[i], w.rewards[i])? as f64;
                let e2 = cpu.update(sc, sn, w.actions[i], w.rewards[i])? as f64;
                let e3 = sim.update(sc, sn, w.actions[i], w.rewards[i])? as f64;
                max_diff = max_diff.max((e1 - e2).abs()).max((e1 - e3).abs());
            }
            println!(
                "{:<28} {:<6} max |Δq_err| over {n} updates: {max_diff:.2e}",
                net.name(),
                prec.as_str()
            );
            worst = worst.max(max_diff);
        }
    }
    let budget = 4.0 / 4096.0; // 4 LSB of Q(18,12)
    if worst > budget {
        return Err(qfpga::error::Error::Config(format!(
            "cross-backend divergence {worst:.2e} exceeds budget {budget:.2e}"
        )));
    }
    println!("OK: all backends agree within {budget:.2e}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dev = Virtex7::default();
    println!("device: Virtex-7 XC7VX485T @ {:.0} MHz", dev.clock_hz / 1e6);
    println!(
        "  {} LUT / {} FF / {} DSP48 / {} BRAM36",
        dev.luts, dev.ffs, dev.dsps, dev.bram36
    );
    let t = TimingModel::default();
    println!("cycle model (per Q-update):");
    for net in NetConfig::all() {
        for prec in [Precision::Fixed, Precision::Float] {
            let b = t.qupdate(&net, prec);
            println!(
                "  {:<22} {:<6} {:>7} cycles = {:>9.2} µs",
                net.name(),
                prec.as_str(),
                b.total(),
                dev.cycles_to_us(b.total())
            );
        }
    }
    match Runtime::from_default_dir() {
        Ok(rt) => {
            println!(
                "artifacts: {} modules in {} (platform {})",
                rt.manifest().artifacts.len(),
                rt.manifest().dir.display(),
                rt.platform()
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}
