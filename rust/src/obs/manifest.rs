//! Run-provenance manifests: every run records enough to be re-validated.
//!
//! A [`RunManifest`] pins a run's full identity — schema version, run id,
//! `git describe`, the complete replayable spec plus its sha256, the seed,
//! wall-clock durations, a delta metrics snapshot, and the sha256 of the
//! *deterministic projection* of the report the run produced. Hashing
//! follows the manifest exemplar rules: sha256 over canonical JSON (sorted
//! keys, compact `,`/`:` separators — exactly what [`Json`]'s `Display`
//! emits) with the volatile fields removed first.
//!
//! Two hash projections exist:
//!
//! * **manifest_sha256** — the manifest minus [`VOLATILE_MANIFEST_KEYS`]
//!   (the self-hash, `run_id` and `durations`). Two identical runs
//!   therefore produce identical `manifest_sha256` values, and
//!   `qfpga diff a b --ignore-keys run_id,durations` compares the rest.
//! * **report_sha256** — the report JSON minus host-timed keys
//!   ([`VOLATILE_REPORT_KEYS`], recursively) and minus any table row
//!   marked `"measured": true` (host-measured latencies). What remains is
//!   seed-deterministic, which is what makes `qfpga replay` a bit-exact
//!   check rather than a tolerance diff.
//!
//! Schema versioning is semver-shaped: readers accept any `1.x.y`,
//! additive fields bump the minor, incompatible changes bump the major
//! (see MIGRATION.md).

use std::path::Path;
use std::process::Command;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::error::{Error, Result};
use crate::util::{sha256_hex, Json};

use super::metrics::MetricsSnapshot;

/// Manifest schema version (semver; major gates compatibility).
pub const SCHEMA_VERSION: &str = "1.0.0";

/// Top-level manifest fields excluded from `manifest_sha256` (and the
/// `--ignore-keys` set that makes two runs of the same spec diff clean).
pub const VOLATILE_MANIFEST_KEYS: [&str; 3] = ["manifest_sha256", "run_id", "durations"];

/// Report keys (at any depth) whose values are host-timed and therefore
/// excluded from `report_sha256`. `workers` rides along because the
/// effective pool width is host-derived while the results are
/// width-independent (the PR 5 pool guarantee).
pub const VOLATILE_REPORT_KEYS: [&str; 4] = [
    "wall_seconds",
    "updates_per_second",
    "aggregate_updates_per_second",
    "workers",
];

/// Subcommands whose specs are seed-deterministic end to end — the only
/// ones `qfpga replay` re-runs and the only job kinds the serve gateway
/// accepts (a cache keyed on spec sha256 is sound exactly when the spec
/// determines the report bit-for-bit).
pub const REPLAYABLE_SUBCOMMANDS: [&str; 3] = ["train", "fleet", "mission"];

/// Fresh process-unique run id (time + pid; uniqueness, not secrecy).
pub fn new_run_id() -> String {
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    format!(
        "run-{:x}{:07x}-{:x}",
        now.as_secs(),
        now.subsec_nanos(),
        std::process::id()
    )
}

/// Best-effort `git describe --always --dirty` ("unknown" outside a work
/// tree or without git on PATH — manifests must never fail a run).
pub fn git_describe() -> String {
    Command::new("git")
        .args(["describe", "--always", "--dirty", "--tags"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Deep copy of `doc` with the named object keys removed at every depth.
pub fn strip_keys(doc: &Json, keys: &[&str]) -> Json {
    match doc {
        Json::Obj(map) => Json::Obj(
            map.iter()
                .filter(|(k, _)| !keys.contains(&k.as_str()))
                .map(|(k, v)| (k.clone(), strip_keys(v, keys)))
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(items.iter().map(|v| strip_keys(v, keys)).collect()),
        other => other.clone(),
    }
}

/// Is this array element a table row flagged as host-measured?
fn is_measured_row(v: &Json) -> bool {
    matches!(v.get("measured"), Some(Json::Bool(true)))
}

/// The deterministic projection of a report document: volatile keys out,
/// host-measured rows out.
pub fn report_projection(doc: &Json) -> Json {
    match doc {
        Json::Obj(map) => Json::Obj(
            map.iter()
                .filter(|(k, _)| !VOLATILE_REPORT_KEYS.contains(&k.as_str()))
                .map(|(k, v)| (k.clone(), report_projection(v)))
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(
            items
                .iter()
                .filter(|v| !is_measured_row(v))
                .map(report_projection)
                .collect(),
        ),
        other => other.clone(),
    }
}

/// sha256 of the deterministic report projection (canonical JSON bytes).
pub fn report_sha256(doc: &Json) -> String {
    sha256_hex(report_projection(doc).to_string().as_bytes())
}

/// sha256 of canonical `doc` bytes with no projection (spec hashing).
pub fn json_sha256(doc: &Json) -> String {
    sha256_hex(doc.to_string().as_bytes())
}

/// The manifest self-hash: top-level volatile fields removed, canonical
/// JSON hashed.
pub fn manifest_sha256_of(doc: &Json) -> String {
    let projected = match doc {
        Json::Obj(map) => Json::Obj(
            map.iter()
                .filter(|(k, _)| !VOLATILE_MANIFEST_KEYS.contains(&k.as_str()))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        ),
        other => other.clone(),
    };
    sha256_hex(projected.to_string().as_bytes())
}

/// Versioned provenance record for one `qfpga` run.
#[derive(Debug, Clone)]
pub struct RunManifest {
    pub schema_version: String,
    pub run_id: String,
    /// Which subcommand produced this run (`train`, `mission`, …) —
    /// doubles as the replay dispatcher key.
    pub subcommand: String,
    pub git_describe: String,
    pub seed: u64,
    /// The complete replayable input spec.
    pub spec: Json,
    pub spec_sha256: String,
    /// Host-timed durations — informational, excluded from hashing.
    pub durations: Json,
    /// Delta metrics snapshot for this run (JSON form).
    pub metrics: Json,
    /// `Report::id()` of the produced report (`S1`, `EXP`, …).
    pub report_id: String,
    pub report_sha256: String,
    pub manifest_sha256: String,
}

impl RunManifest {
    /// Assemble and self-hash a manifest for a finished run.
    pub fn build(
        subcommand: &str,
        seed: u64,
        spec: Json,
        report_id: &str,
        report_doc: &Json,
        metrics: &MetricsSnapshot,
        wall_seconds: f64,
    ) -> RunManifest {
        let mut m = RunManifest {
            schema_version: SCHEMA_VERSION.to_string(),
            run_id: new_run_id(),
            subcommand: subcommand.to_string(),
            git_describe: git_describe(),
            seed,
            spec_sha256: json_sha256(&spec),
            spec,
            durations: Json::obj(vec![("wall_seconds", Json::Num(wall_seconds))]),
            metrics: metrics.to_json(),
            report_id: report_id.to_string(),
            report_sha256: report_sha256(report_doc),
            manifest_sha256: String::new(),
        };
        m.manifest_sha256 = manifest_sha256_of(&m.to_json());
        m
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Str(self.schema_version.clone())),
            ("run_id", Json::Str(self.run_id.clone())),
            ("subcommand", Json::Str(self.subcommand.clone())),
            ("git_describe", Json::Str(self.git_describe.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("spec", self.spec.clone()),
            ("spec_sha256", Json::Str(self.spec_sha256.clone())),
            ("durations", self.durations.clone()),
            ("metrics", self.metrics.clone()),
            ("report_id", Json::Str(self.report_id.clone())),
            ("report_sha256", Json::Str(self.report_sha256.clone())),
            ("manifest_sha256", Json::Str(self.manifest_sha256.clone())),
        ])
    }

    /// Parse without integrity checks (see [`RunManifest::validate`]).
    pub fn from_json(j: &Json) -> Result<RunManifest> {
        Ok(RunManifest {
            schema_version: j.req_str("schema_version")?.to_string(),
            run_id: j.req_str("run_id")?.to_string(),
            subcommand: j.req_str("subcommand")?.to_string(),
            git_describe: j.req_str("git_describe")?.to_string(),
            seed: j.req_f64("seed")? as u64,
            spec: j
                .get("spec")
                .cloned()
                .ok_or_else(|| Error::interface("manifest missing `spec`"))?,
            spec_sha256: j.req_str("spec_sha256")?.to_string(),
            durations: j
                .get("durations")
                .cloned()
                .ok_or_else(|| Error::interface("manifest missing `durations`"))?,
            metrics: j
                .get("metrics")
                .cloned()
                .ok_or_else(|| Error::interface("manifest missing `metrics`"))?,
            report_id: j.req_str("report_id")?.to_string(),
            report_sha256: j.req_str("report_sha256")?.to_string(),
            manifest_sha256: j.req_str("manifest_sha256")?.to_string(),
        })
    }

    /// Parse + integrity-check a manifest document: schema major must be
    /// supported, `spec_sha256` must match the embedded spec, and the
    /// self-hash must recompute exactly.
    pub fn validate(j: &Json) -> Result<RunManifest> {
        let m = Self::from_json(j)?;
        let major = m.schema_version.split('.').next().unwrap_or("");
        let supported = SCHEMA_VERSION.split('.').next().unwrap_or("");
        if major != supported {
            return Err(Error::interface(format!(
                "manifest schema_version `{}` is not supported (this build reads {supported}.x.y)",
                m.schema_version
            )));
        }
        let spec_hash = json_sha256(&m.spec);
        if spec_hash != m.spec_sha256 {
            return Err(Error::interface(format!(
                "manifest spec_sha256 mismatch: recorded {} but the embedded spec hashes to \
                 {spec_hash} (manifest edited or torn)",
                m.spec_sha256
            )));
        }
        let self_hash = manifest_sha256_of(j);
        if self_hash != m.manifest_sha256 {
            return Err(Error::interface(format!(
                "manifest_sha256 mismatch: recorded {} but the manifest hashes to {self_hash} \
                 (manifest edited or torn)",
                m.manifest_sha256
            )));
        }
        Ok(m)
    }

    /// Can `qfpga replay` (and the serve gateway) re-run this manifest's
    /// spec bit-exactly? See [`REPLAYABLE_SUBCOMMANDS`].
    pub fn is_replayable(&self) -> bool {
        REPLAYABLE_SUBCOMMANDS.contains(&self.subcommand.as_str())
    }

    /// Load + validate a manifest file.
    pub fn load(path: &Path) -> Result<RunManifest> {
        let text = std::fs::read_to_string(path)?;
        Self::validate(&Json::parse(&text)?)
    }

    /// Write the manifest (atomic temp + rename, like checkpoints).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().to_string())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Json {
        Json::obj(vec![
            ("kind", Json::Str("train".into())),
            ("episodes", Json::Num(5.0)),
        ])
    }

    fn report() -> Json {
        Json::obj(vec![
            ("id", Json::Str("EXP".into())),
            ("wall_seconds", Json::Num(1.25)),
            (
                "rows",
                Json::Arr(vec![
                    Json::obj(vec![("label", Json::Str("a".into())), ("ours", Json::Num(2.0))]),
                    Json::obj(vec![
                        ("label", Json::Str("b measured".into())),
                        ("ours", Json::Num(123.4)),
                        ("measured", Json::Bool(true)),
                    ]),
                ]),
            ),
        ])
    }

    fn build() -> RunManifest {
        let snap = MetricsSnapshot::capture();
        let delta = snap.delta(&snap);
        RunManifest::build("train", 7, spec(), "EXP", &report(), &delta, 0.5)
    }

    #[test]
    fn report_projection_drops_volatile_and_measured() {
        let p = report_projection(&report());
        let s = p.to_string();
        assert!(!s.contains("wall_seconds"));
        assert!(!s.contains("measured"));
        assert!(!s.contains("123.4"));
        assert!(s.contains("\"a\""));
        // projection is stable: hashing twice agrees
        assert_eq!(report_sha256(&report()), report_sha256(&report()));
    }

    #[test]
    fn manifest_round_trips_and_validates() {
        let m = build();
        let doc = m.to_json();
        let parsed = RunManifest::validate(&doc).unwrap();
        assert_eq!(parsed.schema_version, SCHEMA_VERSION);
        assert_eq!(parsed.report_sha256, m.report_sha256);
        assert_eq!(parsed.manifest_sha256, m.manifest_sha256);
        // text round-trip too (what `save`/`load` do)
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        assert!(RunManifest::validate(&reparsed).is_ok());
    }

    #[test]
    fn self_hash_ignores_run_id_and_durations_only() {
        let a = build();
        let mut b = a.clone();
        b.run_id = "run-different".into();
        b.durations = Json::obj(vec![("wall_seconds", Json::Num(99.0))]);
        assert_eq!(manifest_sha256_of(&a.to_json()), manifest_sha256_of(&b.to_json()));
        let mut c = a.clone();
        c.seed = 8;
        assert_ne!(manifest_sha256_of(&a.to_json()), manifest_sha256_of(&c.to_json()));
    }

    #[test]
    fn replayability_follows_the_subcommand() {
        let m = build();
        assert!(m.is_replayable());
        let mut s = m.clone();
        s.subcommand = "sweep".into();
        assert!(!s.is_replayable());
        for sub in REPLAYABLE_SUBCOMMANDS {
            let mut r = m.clone();
            r.subcommand = sub.into();
            assert!(r.is_replayable());
        }
    }

    #[test]
    fn validate_rejects_tampering() {
        let m = build();
        let mut doc = m.to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("seed".into(), Json::Num(999.0));
        }
        let err = RunManifest::validate(&doc).unwrap_err();
        assert!(err.to_string().contains("manifest_sha256 mismatch"), "{err}");
    }

    #[test]
    fn validate_rejects_unsupported_major() {
        let m = build();
        let mut doc = m.to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("schema_version".into(), Json::Str("2.0.0".into()));
        }
        // rehash so only the version gate can complain
        let hash = manifest_sha256_of(&doc);
        if let Json::Obj(map) = &mut doc {
            map.insert("manifest_sha256".into(), Json::Str(hash));
        }
        let err = RunManifest::validate(&doc).unwrap_err();
        assert!(err.to_string().contains("schema_version"), "{err}");
    }
}
