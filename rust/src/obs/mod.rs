//! Mission observability: metrics, structured tracing, run provenance.
//!
//! Flight software must prove its budgets are met, and a reproduction
//! must prove its runs are reproducible. This module supplies both
//! halves without touching the numerics:
//!
//! * [`metrics`] — a const-initialized process-global registry of
//!   counters/gauges/histograms behind `Relaxed` atomics, wired into the
//!   hot paths (Q-updates by precision/kernel arm, episodes/steps/ε,
//!   fleet pool claims, checkpoint writes, modeled FPGA cycles, FIFO
//!   high-water, SEU strike accounting) and snapshotted deterministically
//!   as JSON or Prometheus text ([`MetricsSnapshot`]).
//! * [`trace`] — a span API over a bounded preallocated ring; disabled it
//!   costs one atomic load per span site, enabled it records coarse
//!   (mission/episode/flush/checkpoint/measure) timing to a JSONL file
//!   with a p50/p99 [`TraceSummary`] at exit.
//! * [`manifest`] — versioned [`RunManifest`] records (spec + sha256,
//!   seed, git describe, metrics delta, deterministic report hash) that
//!   `qfpga manifest validate` integrity-checks and `qfpga replay`
//!   re-runs to a bit-identical report hash.

pub mod manifest;
pub mod metrics;
pub mod trace;

pub use manifest::{report_sha256, RunManifest, SCHEMA_VERSION};
pub use metrics::{metrics, Metrics, MetricsSnapshot};
pub use trace::{span, Span, SpanKind, TraceSummary};
