//! Process-global metrics registry: typed counters/gauges/histograms
//! behind `Relaxed` atomics.
//!
//! The registry is a single const-initialized `static` — recording a
//! sample is one `fetch_add`/`fetch_max`/`store` with no locking and no
//! allocation, cheap enough to live inside the hot paths that PR 5 made
//! allocation-free. Instrumentation never touches the numerics (atomics
//! only observe, they do not participate in any arithmetic the learner
//! performs), so bit-exactness guarantees are preserved by construction.
//!
//! Reading happens through [`MetricsSnapshot::capture`], which produces a
//! deterministic, ordered sample set renderable as canonical JSON or
//! Prometheus text exposition format. Because the registry is
//! process-global and monotone, snapshots embedded in run manifests are
//! *delta* snapshots: capture a baseline at run start and subtract
//! ([`MetricsSnapshot::delta`]) so a manifest describes one run, not the
//! process history.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::Precision;
use crate::nn::KernelPath;
use crate::util::Json;

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge storing an `f64` as its bit pattern.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        // 0u64 is the bit pattern of 0.0f64, so const-init stays trivial.
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// High-water-mark gauge (monotone `fetch_max` over a `u64`).
#[derive(Debug, Default)]
pub struct MaxGauge(AtomicU64);

impl MaxGauge {
    pub const fn new() -> MaxGauge {
        MaxGauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of power-of-two histogram buckets: upper bounds
/// 1, 2, 4, …, 2^(N−2), +Inf.
pub const HIST_BUCKETS: usize = 12;

/// Fixed-bucket histogram over small integer magnitudes (batch sizes).
///
/// Bucket `i` counts observations `v ≤ 2^i`; the last bucket is +Inf.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        // Const-item repeat is the only way to const-init an atomic array;
        // each use instantiates a fresh atomic, so sharing is not possible.
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [ZERO; HIST_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        let idx = if v <= 1 {
            0
        } else {
            (64 - (v - 1).leading_zeros() as usize).min(HIST_BUCKETS - 1)
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Upper bound of bucket `i` (`u64::MAX` stands in for +Inf).
    pub fn bound(i: usize) -> u64 {
        if i + 1 == HIST_BUCKETS {
            u64::MAX
        } else {
            1u64 << i
        }
    }
}

/// Cap on per-worker claim counters; workers beyond this share the last
/// slot (fleets that wide are outside the paper's envelope anyway).
pub const MAX_WORKER_SLOTS: usize = 32;

/// The registry: every named instrument in the system, const-initialized.
#[derive(Debug)]
pub struct Metrics {
    /// Q-updates applied, per (precision, kernel path). Indexed
    /// `[precision][kernel]` via [`precision_index`] / [`kernel_index`].
    pub nn_updates: [[Counter; 2]; 4],
    /// Batch sizes seen by the vectorized update path.
    pub nn_batch_size: Histogram,
    /// Training episodes completed.
    pub train_episodes: Counter,
    /// Environment steps taken across all episodes.
    pub train_steps: Counter,
    /// Exploration rate at the most recent episode boundary.
    pub train_epsilon: Gauge,
    /// Fleet jobs claimed, per worker slot.
    pub fleet_jobs_claimed: [Counter; MAX_WORKER_SLOTS],
    /// Fleet jobs claimed by a worker other than the round-robin "home"
    /// worker — the work-stealing signal.
    pub fleet_jobs_stolen: Counter,
    /// Fleet-share transition-exchange rounds applied.
    pub fleet_exchanges: Counter,
    /// Fleet-share parameter-averaging rounds applied.
    pub fleet_avg_rounds: Counter,
    /// Mission checkpoints written to disk.
    pub checkpoint_writes: Counter,
    /// Modeled FPGA cycles charged by the accelerator timing model.
    pub fpga_cycles: Counter,
    /// Deepest simultaneous occupancy seen across the datapath FIFOs.
    pub fpga_fifo_high_water: MaxGauge,
    /// SEU bit-flips drawn by the fault model.
    pub fault_strikes: Counter,
    /// Strikes absorbed by a mitigation (TMR vote, SECDED correct).
    pub fault_masked: Counter,
    /// Strikes delivered into live state.
    pub fault_escaped: Counter,
    /// Scrub passes executed by the protected store.
    pub fault_scrub_bursts: Counter,
    /// Configuration-memory (CRAM) frame upsets injected.
    pub fault_cram_upsets: Counter,
    /// CRAM frames repaired by the configuration scrubber.
    pub fault_cram_repairs: Counter,
    /// Steps a corrupted CRAM frame stood before its scrub repair.
    pub fault_cram_scrub_latency: Histogram,
    /// Jobs accepted by the serve gateway.
    pub serve_jobs_submitted: Counter,
    /// Jobs completed (executed or served from cache).
    pub serve_jobs_completed: Counter,
    /// Submissions rejected by queue backpressure or drain.
    pub serve_jobs_rejected: Counter,
    /// Running jobs checkpointed and requeued for a higher-priority job.
    pub serve_preemptions: Counter,
    /// Jobs answered from the content-addressed result cache.
    pub serve_cache_hits: Counter,
    /// Current depth of the gateway job queue.
    pub serve_queue_depth: Gauge,
    /// Jobs currently executing on gateway workers.
    pub serve_jobs_in_flight: Gauge,
}

/// Stable row index for a precision arm (order matches [`Precision::all`]).
pub fn precision_index(p: Precision) -> usize {
    match p {
        Precision::Float => 0,
        Precision::Fixed => 1,
        Precision::Int8 => 2,
        Precision::Binary => 3,
    }
}

/// Stable column index for a kernel path.
pub fn kernel_index(k: KernelPath) -> usize {
    match k {
        KernelPath::Scalar => 0,
        KernelPath::Simd => 1,
    }
}

const PRECISION_NAMES: [&str; 4] = ["float", "fixed", "int8", "binary"];
const KERNEL_NAMES: [&str; 2] = ["scalar", "simd"];

impl Metrics {
    pub const fn new() -> Metrics {
        // See Histogram::new for why the const-item repeat idiom is safe.
        #[allow(clippy::declare_interior_mutable_const)]
        const C: Counter = Counter::new();
        #[allow(clippy::declare_interior_mutable_const)]
        const ROW: [Counter; 2] = [C, C];
        Metrics {
            nn_updates: [ROW; 4],
            nn_batch_size: Histogram::new(),
            train_episodes: C,
            train_steps: C,
            train_epsilon: Gauge::new(),
            fleet_jobs_claimed: [C; MAX_WORKER_SLOTS],
            fleet_jobs_stolen: C,
            fleet_exchanges: C,
            fleet_avg_rounds: C,
            checkpoint_writes: C,
            fpga_cycles: C,
            fpga_fifo_high_water: MaxGauge::new(),
            fault_strikes: C,
            fault_masked: C,
            fault_escaped: C,
            fault_scrub_bursts: C,
            fault_cram_upsets: C,
            fault_cram_repairs: C,
            fault_cram_scrub_latency: Histogram::new(),
            serve_jobs_submitted: C,
            serve_jobs_completed: C,
            serve_jobs_rejected: C,
            serve_preemptions: C,
            serve_cache_hits: C,
            serve_queue_depth: Gauge::new(),
            serve_jobs_in_flight: Gauge::new(),
        }
    }

    /// Count `n` Q-updates on the given precision/kernel arm.
    #[inline]
    pub fn nn_update(&self, prec: Precision, kernel: KernelPath, n: u64) {
        self.nn_updates[precision_index(prec)][kernel_index(kernel)].add(n);
    }

    /// Count a fleet job claim by worker `w` (clamped to the slot table).
    #[inline]
    pub fn fleet_claim(&self, w: usize) {
        self.fleet_jobs_claimed[w.min(MAX_WORKER_SLOTS - 1)].inc();
    }
}

static METRICS: Metrics = Metrics::new();

/// The process-global registry.
pub fn metrics() -> &'static Metrics {
    &METRICS
}

/// One sample family (shared name + type across its labeled series).
#[derive(Debug, Clone)]
pub struct Family {
    pub name: &'static str,
    pub kind: MetricKind,
    pub help: &'static str,
    pub series: Vec<Series>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// One labeled series within a family.
#[derive(Debug, Clone)]
pub struct Series {
    pub labels: Vec<(&'static str, String)>,
    pub value: SeriesValue,
}

#[derive(Debug, Clone)]
pub enum SeriesValue {
    Int(u64),
    Float(f64),
    /// Cumulative `(upper_bound, count≤bound)` pairs plus sum/count.
    Hist {
        buckets: Vec<(u64, u64)>,
        sum: u64,
        count: u64,
    },
}

/// A deterministic point-in-time read of the registry.
///
/// Capture order is fixed, so two snapshots of identical registry state
/// render to byte-identical JSON and Prometheus text.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub families: Vec<Family>,
}

fn label_suffix(labels: &[(&'static str, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect();
    format!("{{{}}}", body.join(","))
}

fn bound_label(le: u64) -> String {
    if le == u64::MAX {
        "+Inf".to_string()
    } else {
        le.to_string()
    }
}

impl MetricsSnapshot {
    /// Read every instrument in the registry, in fixed order.
    pub fn capture() -> MetricsSnapshot {
        let m = metrics();
        let mut families = Vec::new();

        let mut updates = Vec::new();
        for (pi, row) in m.nn_updates.iter().enumerate() {
            for (ki, c) in row.iter().enumerate() {
                updates.push(Series {
                    labels: vec![
                        ("precision", PRECISION_NAMES[pi].to_string()),
                        ("kernel", KERNEL_NAMES[ki].to_string()),
                    ],
                    value: SeriesValue::Int(c.get()),
                });
            }
        }
        families.push(Family {
            name: "qfpga_nn_updates_total",
            kind: MetricKind::Counter,
            help: "Q-updates applied, by precision arm and kernel path",
            series: updates,
        });

        let buckets: Vec<(u64, u64)> = {
            // Render cumulative counts so `le` buckets nest, per the
            // Prometheus histogram contract.
            let mut cum = 0;
            (0..HIST_BUCKETS)
                .map(|i| {
                    cum += m.nn_batch_size.buckets[i].load(Ordering::Relaxed);
                    (Histogram::bound(i), cum)
                })
                .collect()
        };
        families.push(Family {
            name: "qfpga_nn_batch_size",
            kind: MetricKind::Histogram,
            help: "Batch sizes seen by the vectorized update path",
            series: vec![Series {
                labels: Vec::new(),
                value: SeriesValue::Hist {
                    buckets,
                    sum: m.nn_batch_size.sum.load(Ordering::Relaxed),
                    count: m.nn_batch_size.count.load(Ordering::Relaxed),
                },
            }],
        });

        let scalar_counter = |name, help, c: &Counter| Family {
            name,
            kind: MetricKind::Counter,
            help,
            series: vec![Series {
                labels: Vec::new(),
                value: SeriesValue::Int(c.get()),
            }],
        };
        families.push(scalar_counter(
            "qfpga_train_episodes_total",
            "Training episodes completed",
            &m.train_episodes,
        ));
        families.push(scalar_counter(
            "qfpga_train_steps_total",
            "Environment steps taken",
            &m.train_steps,
        ));
        families.push(Family {
            name: "qfpga_train_epsilon",
            kind: MetricKind::Gauge,
            help: "Exploration rate at the last episode boundary",
            series: vec![Series {
                labels: Vec::new(),
                value: SeriesValue::Float(m.train_epsilon.get()),
            }],
        });

        let claimed: Vec<Series> = m
            .fleet_jobs_claimed
            .iter()
            .enumerate()
            .filter(|(_, c)| c.get() > 0)
            .map(|(w, c)| Series {
                labels: vec![("worker", w.to_string())],
                value: SeriesValue::Int(c.get()),
            })
            .collect();
        families.push(Family {
            name: "qfpga_fleet_jobs_claimed_total",
            kind: MetricKind::Counter,
            help: "Fleet jobs claimed, by worker slot",
            series: claimed,
        });
        families.push(scalar_counter(
            "qfpga_fleet_jobs_stolen_total",
            "Fleet jobs claimed away from their round-robin home worker",
            &m.fleet_jobs_stolen,
        ));
        families.push(scalar_counter(
            "qfpga_fleet_exchanges_total",
            "Fleet-share transition-exchange rounds applied",
            &m.fleet_exchanges,
        ));
        families.push(scalar_counter(
            "qfpga_fleet_avg_rounds_total",
            "Fleet-share parameter-averaging rounds applied",
            &m.fleet_avg_rounds,
        ));
        families.push(scalar_counter(
            "qfpga_checkpoint_writes_total",
            "Mission checkpoints written to disk",
            &m.checkpoint_writes,
        ));
        families.push(scalar_counter(
            "qfpga_fpga_cycles_total",
            "Modeled FPGA cycles charged by the timing model",
            &m.fpga_cycles,
        ));
        families.push(Family {
            name: "qfpga_fpga_fifo_high_water",
            kind: MetricKind::Gauge,
            help: "Deepest datapath FIFO occupancy observed",
            series: vec![Series {
                labels: Vec::new(),
                value: SeriesValue::Int(m.fpga_fifo_high_water.get()),
            }],
        });
        families.push(scalar_counter(
            "qfpga_fault_strikes_total",
            "SEU bit-flips drawn by the fault model",
            &m.fault_strikes,
        ));
        families.push(scalar_counter(
            "qfpga_fault_masked_total",
            "Strikes absorbed by a mitigation",
            &m.fault_masked,
        ));
        families.push(scalar_counter(
            "qfpga_fault_escaped_total",
            "Strikes delivered into live state",
            &m.fault_escaped,
        ));
        families.push(scalar_counter(
            "qfpga_fault_scrub_bursts_total",
            "Scrub passes executed by the protected store",
            &m.fault_scrub_bursts,
        ));
        families.push(scalar_counter(
            "qfpga_fault_cram_upsets_total",
            "Configuration-memory frame upsets injected",
            &m.fault_cram_upsets,
        ));
        families.push(scalar_counter(
            "qfpga_fault_cram_repairs_total",
            "CRAM frames repaired by the configuration scrubber",
            &m.fault_cram_repairs,
        ));
        let cram_buckets: Vec<(u64, u64)> = {
            let mut cum = 0;
            (0..HIST_BUCKETS)
                .map(|i| {
                    cum += m.fault_cram_scrub_latency.buckets[i].load(Ordering::Relaxed);
                    (Histogram::bound(i), cum)
                })
                .collect()
        };
        families.push(Family {
            name: "qfpga_fault_cram_scrub_latency_steps",
            kind: MetricKind::Histogram,
            help: "Steps a corrupted CRAM frame stood before its scrub repair",
            series: vec![Series {
                labels: Vec::new(),
                value: SeriesValue::Hist {
                    buckets: cram_buckets,
                    sum: m.fault_cram_scrub_latency.sum.load(Ordering::Relaxed),
                    count: m.fault_cram_scrub_latency.count.load(Ordering::Relaxed),
                },
            }],
        });
        families.push(scalar_counter(
            "qfpga_serve_jobs_submitted_total",
            "Jobs accepted by the serve gateway",
            &m.serve_jobs_submitted,
        ));
        families.push(scalar_counter(
            "qfpga_serve_jobs_completed_total",
            "Jobs completed (executed or served from cache)",
            &m.serve_jobs_completed,
        ));
        families.push(scalar_counter(
            "qfpga_serve_jobs_rejected_total",
            "Submissions rejected by queue backpressure or drain",
            &m.serve_jobs_rejected,
        ));
        families.push(scalar_counter(
            "qfpga_serve_preemptions_total",
            "Running jobs checkpointed and requeued for a higher-priority job",
            &m.serve_preemptions,
        ));
        families.push(scalar_counter(
            "qfpga_serve_cache_hits_total",
            "Jobs answered from the content-addressed result cache",
            &m.serve_cache_hits,
        ));
        families.push(Family {
            name: "qfpga_serve_queue_depth",
            kind: MetricKind::Gauge,
            help: "Current depth of the gateway job queue",
            series: vec![Series {
                labels: Vec::new(),
                value: SeriesValue::Float(m.serve_queue_depth.get()),
            }],
        });
        families.push(Family {
            name: "qfpga_serve_jobs_in_flight",
            kind: MetricKind::Gauge,
            help: "Jobs currently executing on gateway workers",
            series: vec![Series {
                labels: Vec::new(),
                value: SeriesValue::Float(m.serve_jobs_in_flight.get()),
            }],
        });

        MetricsSnapshot { families }
    }

    /// `self − baseline`: counters and histograms subtract, gauges keep
    /// their end value. Both snapshots must come from [`capture`] (same
    /// family order); series present only in `self` pass through.
    pub fn delta(&self, baseline: &MetricsSnapshot) -> MetricsSnapshot {
        let mut out = self.clone();
        for fam in &mut out.families {
            let base = match baseline.families.iter().find(|b| b.name == fam.name) {
                Some(b) => b,
                None => continue,
            };
            if fam.kind == MetricKind::Gauge {
                continue;
            }
            for s in &mut fam.series {
                let bs = match base.series.iter().find(|b| b.labels == s.labels) {
                    Some(b) => b,
                    None => continue,
                };
                match (&mut s.value, &bs.value) {
                    (SeriesValue::Int(v), SeriesValue::Int(b)) => *v = v.saturating_sub(*b),
                    (SeriesValue::Float(v), SeriesValue::Float(b)) => *v -= b,
                    (
                        SeriesValue::Hist {
                            buckets,
                            sum,
                            count,
                        },
                        SeriesValue::Hist {
                            buckets: bb,
                            sum: bsum,
                            count: bcount,
                        },
                    ) => {
                        for ((_, c), (_, bc)) in buckets.iter_mut().zip(bb) {
                            *c = c.saturating_sub(*bc);
                        }
                        *sum = sum.saturating_sub(*bsum);
                        *count = count.saturating_sub(*bcount);
                    }
                    _ => {}
                }
            }
        }
        out
    }

    /// Sum of a counter family across its series (0 if absent/empty).
    pub fn total(&self, family: &str) -> u64 {
        self.families
            .iter()
            .filter(|f| f.name == family)
            .flat_map(|f| &f.series)
            .map(|s| match &s.value {
                SeriesValue::Int(v) => *v,
                SeriesValue::Float(v) => *v as u64,
                SeriesValue::Hist { count, .. } => *count,
            })
            .sum()
    }

    /// Canonical JSON: one key per series, Prometheus-style names, sorted
    /// by the `Json` object's key order (deterministic).
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(String, Json)> = Vec::new();
        for fam in &self.families {
            for s in &fam.series {
                let key = format!("{}{}", fam.name, label_suffix(&s.labels));
                match &s.value {
                    SeriesValue::Int(v) => pairs.push((key, Json::Num(*v as f64))),
                    SeriesValue::Float(v) => pairs.push((key, Json::Num(*v))),
                    SeriesValue::Hist {
                        buckets,
                        sum,
                        count,
                    } => {
                        for (le, c) in buckets {
                            pairs.push((
                                format!("{}_bucket{{le=\"{}\"}}", fam.name, bound_label(*le)),
                                Json::Num(*c as f64),
                            ));
                        }
                        pairs.push((format!("{}_sum", fam.name), Json::Num(*sum as f64)));
                        pairs.push((format!("{}_count", fam.name), Json::Num(*count as f64)));
                    }
                }
            }
        }
        Json::Obj(pairs.into_iter().collect())
    }

    /// Prometheus text exposition format (`# HELP`/`# TYPE` + samples).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for fam in &self.families {
            out.push_str(&format!("# HELP {} {}\n", fam.name, fam.help));
            out.push_str(&format!("# TYPE {} {}\n", fam.name, fam.kind.as_str()));
            for s in &fam.series {
                match &s.value {
                    SeriesValue::Int(v) => {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            fam.name,
                            label_suffix(&s.labels),
                            v
                        ));
                    }
                    SeriesValue::Float(v) => {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            fam.name,
                            label_suffix(&s.labels),
                            v
                        ));
                    }
                    SeriesValue::Hist {
                        buckets,
                        sum,
                        count,
                    } => {
                        for (le, c) in buckets {
                            out.push_str(&format!(
                                "{}_bucket{{le=\"{}\"}} {}\n",
                                fam.name,
                                bound_label(*le),
                                c
                            ));
                        }
                        out.push_str(&format!("{}_sum {}\n", fam.name, sum));
                        out.push_str(&format!("{}_count {}\n", fam.name, count));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_maxgauge() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(0.25);
        assert_eq!(g.get(), 0.25);
        let hw = MaxGauge::new();
        hw.observe(3);
        hw.observe(2);
        assert_eq!(hw.get(), 3);
    }

    #[test]
    fn histogram_buckets_are_powers_of_two_and_cumulative() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4, 8, 9, 4096] {
            h.observe(v);
        }
        // Raw (non-cumulative) per-bucket counts.
        let raw: Vec<u64> = h
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        assert_eq!(raw[0], 1); // v=1 ≤ 1
        assert_eq!(raw[1], 1); // v=2 ≤ 2
        assert_eq!(raw[2], 2); // v=3,4 ≤ 4
        assert_eq!(raw[3], 1); // v=8 ≤ 8
        assert_eq!(raw[4], 1); // v=9 ≤ 16
        assert_eq!(raw[HIST_BUCKETS - 1], 1); // v=4096 overflows into +Inf
        assert_eq!(h.count.load(Ordering::Relaxed), 7);
        assert_eq!(h.sum.load(Ordering::Relaxed), 1 + 2 + 3 + 4 + 8 + 9 + 4096);
        assert_eq!(Histogram::bound(HIST_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn snapshot_renders_both_formats_deterministically() {
        let a = MetricsSnapshot::capture();
        let json_a = a.to_json().to_string();
        let prom = a.to_prometheus();
        assert!(prom.contains("# TYPE qfpga_nn_updates_total counter"));
        assert!(prom.contains("# TYPE qfpga_nn_batch_size histogram"));
        assert!(prom.contains("qfpga_nn_batch_size_bucket{le=\"+Inf\"}"));
        assert!(json_a.contains("qfpga_train_episodes_total"));
        // Same state → byte-identical rendering (modulo concurrent tests;
        // re-render the same snapshot rather than re-capture).
        assert_eq!(json_a, a.to_json().to_string());
        assert_eq!(prom, a.to_prometheus());
    }

    #[test]
    fn delta_subtracts_counters_keeps_gauges() {
        let base = MetricsSnapshot::capture();
        metrics().train_epsilon.set(0.125);
        metrics().checkpoint_writes.add(2);
        let end = MetricsSnapshot::capture();
        let d = end.delta(&base);
        assert!(d.total("qfpga_checkpoint_writes_total") >= 2);
        let eps = d
            .families
            .iter()
            .find(|f| f.name == "qfpga_train_epsilon")
            .unwrap();
        match &eps.series[0].value {
            // Gauges keep the end value, not a difference. Another test
            // may race the gauge, so only check it is a sane ε, not 0−x.
            SeriesValue::Float(v) => assert!((0.0..=1.0).contains(v)),
            v => panic!("epsilon gauge has wrong shape: {v:?}"),
        }
    }

    #[test]
    fn serve_families_are_exposed() {
        let base = MetricsSnapshot::capture();
        metrics().serve_jobs_submitted.add(3);
        metrics().serve_cache_hits.inc();
        let d = MetricsSnapshot::capture().delta(&base);
        assert!(d.total("qfpga_serve_jobs_submitted_total") >= 3);
        assert!(d.total("qfpga_serve_cache_hits_total") >= 1);
        let prom = d.to_prometheus();
        assert!(prom.contains("# TYPE qfpga_serve_queue_depth gauge"));
        assert!(prom.contains("# TYPE qfpga_serve_jobs_in_flight gauge"));
        assert!(prom.contains("# TYPE qfpga_serve_preemptions_total counter"));
    }

    #[test]
    fn cram_families_are_exposed() {
        let base = MetricsSnapshot::capture();
        metrics().fault_cram_upsets.add(2);
        metrics().fault_cram_repairs.inc();
        metrics().fault_cram_scrub_latency.observe(5);
        let d = MetricsSnapshot::capture().delta(&base);
        assert!(d.total("qfpga_fault_cram_upsets_total") >= 2);
        assert!(d.total("qfpga_fault_cram_repairs_total") >= 1);
        assert!(d.total("qfpga_fault_cram_scrub_latency_steps") >= 1);
        let prom = d.to_prometheus();
        assert!(prom.contains("# TYPE qfpga_fault_cram_upsets_total counter"));
        assert!(prom.contains("# TYPE qfpga_fault_cram_scrub_latency_steps histogram"));
        assert!(prom.contains("qfpga_fault_cram_scrub_latency_steps_bucket{le=\"+Inf\"}"));
    }

    #[test]
    fn nn_update_routes_by_arm() {
        let base = MetricsSnapshot::capture();
        metrics().nn_update(Precision::Int8, KernelPath::Scalar, 7);
        let d = MetricsSnapshot::capture().delta(&base);
        let fam = d
            .families
            .iter()
            .find(|f| f.name == "qfpga_nn_updates_total")
            .unwrap();
        let s = fam
            .series
            .iter()
            .find(|s| {
                s.labels
                    == vec![
                        ("precision", "int8".to_string()),
                        ("kernel", "scalar".to_string()),
                    ]
            })
            .unwrap();
        match s.value {
            SeriesValue::Int(v) => assert!(v >= 7),
            ref v => panic!("wrong shape: {v:?}"),
        }
    }
}
