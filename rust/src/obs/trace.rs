//! Structured tracing: bounded, ring-buffered span records.
//!
//! Tracing is off by default and gated on a single `AtomicBool`: when
//! disabled, [`span`] performs one `Relaxed` load and returns an inert
//! handle — no clock read, no lock, no allocation — so the hot paths keep
//! PR 5's allocation-free guarantee and instrumented runs stay bit-exact
//! (spans observe wall time only, never the numerics).
//!
//! When enabled (CLI `--trace FILE`), span completion appends a fixed-size
//! [`SpanRecord`] to a preallocated ring; once full, the oldest records
//! are overwritten and counted as dropped. Records carry coarse-grained
//! work units (mission, episode, batch flush, checkpoint, measurement) —
//! never per-step events — so tracing cost stays far off the update path.
//! At exit the ring is drained to a JSONL file (one record per line,
//! `run_id`-correlated with the run manifest) and a [`TraceSummary`] with
//! per-kind counts and p50/p99 durations is printed.

use std::fs;
use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::Json;

/// Default ring capacity (records, not bytes). At ~48 bytes per record
/// this is ~3 MB — bounded regardless of run length.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// What a span measures. Kinds are coarse work units, deliberately at
/// episode/flush granularity and never per environment step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// One rover's full mission (all episodes).
    Mission,
    /// One training episode.
    Episode,
    /// One microbatch/batch flush through the backend.
    Flush,
    /// One checkpoint serialization + atomic write.
    Checkpoint,
    /// One fleet-share round boundary (transition exchange + averaging).
    Exchange,
    /// One host-timed measurement block (sweep/throughput).
    Measure,
}

/// Every kind, in summary display order.
pub const SPAN_KINDS: [SpanKind; 6] = [
    SpanKind::Mission,
    SpanKind::Episode,
    SpanKind::Flush,
    SpanKind::Checkpoint,
    SpanKind::Exchange,
    SpanKind::Measure,
];

impl SpanKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanKind::Mission => "mission",
            SpanKind::Episode => "episode",
            SpanKind::Flush => "flush",
            SpanKind::Checkpoint => "checkpoint",
            SpanKind::Exchange => "exchange",
            SpanKind::Measure => "measure",
        }
    }
}

/// Maximum key=val fields a span can carry (fixed so records stay `Copy`).
pub const MAX_FIELDS: usize = 2;

/// A completed span. Fixed-size and `Copy` so ring writes never allocate.
#[derive(Debug, Clone, Copy)]
pub struct SpanRecord {
    pub kind: SpanKind,
    /// Nanoseconds since the process trace epoch (first clock use).
    pub start_ns: u64,
    pub end_ns: u64,
    /// `key=val` annotations; unused slots have an empty key.
    pub fields: [(&'static str, f64); MAX_FIELDS],
}

impl SpanRecord {
    pub fn dur_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    /// One JSONL line (without trailing newline).
    pub fn to_json(&self, run_id: &str) -> Json {
        let mut pairs = vec![
            ("run_id", Json::Str(run_id.to_string())),
            ("kind", Json::Str(self.kind.as_str().to_string())),
            ("start_ns", Json::Num(self.start_ns as f64)),
            ("end_ns", Json::Num(self.end_ns as f64)),
            ("dur_ns", Json::Num(self.dur_ns() as f64)),
        ];
        for (k, v) in self.fields {
            if !k.is_empty() {
                pairs.push((k, Json::Num(v)));
            }
        }
        Json::obj(pairs)
    }
}

struct Ring {
    buf: Vec<SpanRecord>,
    cap: usize,
    /// Overwrite cursor once `buf.len() == cap`.
    next: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, rec: SpanRecord) {
        if self.buf.len() < self.cap {
            self.buf.push(rec);
        } else {
            self.buf[self.next] = rec;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Records in chronological order (oldest first).
    fn drain_ordered(&mut self) -> Vec<SpanRecord> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.next..]);
        out.extend_from_slice(&self.buf[..self.next]);
        self.buf.clear();
        self.next = 0;
        out
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING: Mutex<Option<Ring>> = Mutex::new(None);
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Turn tracing on with the default ring capacity.
pub fn enable() {
    enable_with_capacity(DEFAULT_CAPACITY);
}

/// Turn tracing on with an explicit ring capacity (records).
pub fn enable_with_capacity(cap: usize) {
    let cap = cap.max(1);
    let mut guard = RING.lock().unwrap_or_else(|e| e.into_inner());
    *guard = Some(Ring {
        buf: Vec::with_capacity(cap),
        cap,
        next: 0,
        dropped: 0,
    });
    drop(guard);
    ENABLED.store(true, Ordering::Release);
}

/// Is tracing currently on? One `Relaxed` load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing off and return `(records, dropped)` in chronological
/// order. Idempotent: a second call returns an empty drain.
pub fn disable_and_drain() -> (Vec<SpanRecord>, u64) {
    ENABLED.store(false, Ordering::Release);
    let mut guard = RING.lock().unwrap_or_else(|e| e.into_inner());
    match guard.take() {
        Some(mut ring) => {
            let records = ring.drain_ordered();
            (records, ring.dropped)
        }
        None => (Vec::new(), 0),
    }
}

fn push(rec: SpanRecord) {
    let mut guard = RING.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(ring) = guard.as_mut() {
        ring.push(rec);
    }
}

/// An in-flight span. Obtain via [`span`], annotate with [`Span::field`],
/// finish with [`Span::done`] (dropping without `done` records nothing).
#[must_use = "a span records nothing until .done() is called"]
pub struct Span {
    kind: SpanKind,
    start_ns: u64,
    fields: [(&'static str, f64); MAX_FIELDS],
    n_fields: usize,
    armed: bool,
}

/// Start a span. When tracing is disabled this is one atomic load and an
/// inert handle — no clock read.
#[inline]
pub fn span(kind: SpanKind) -> Span {
    let armed = enabled();
    Span {
        kind,
        start_ns: if armed { now_ns() } else { 0 },
        fields: [("", 0.0); MAX_FIELDS],
        n_fields: 0,
        armed,
    }
}

impl Span {
    /// Attach a `key=val` annotation (up to [`MAX_FIELDS`]; extras are
    /// silently ignored — keep spans coarse).
    #[inline]
    pub fn field(mut self, key: &'static str, val: f64) -> Span {
        if self.armed && self.n_fields < MAX_FIELDS {
            self.fields[self.n_fields] = (key, val);
            self.n_fields += 1;
        }
        self
    }

    /// Complete the span, appending its record to the ring.
    #[inline]
    pub fn done(self) {
        if !self.armed {
            return;
        }
        push(SpanRecord {
            kind: self.kind,
            start_ns: self.start_ns,
            end_ns: now_ns(),
            fields: self.fields,
        });
    }
}

/// Record an instantaneous event (a zero-duration span).
pub fn event(kind: SpanKind) {
    span(kind).done();
}

/// Per-kind duration statistics for a drained trace.
#[derive(Debug, Clone)]
pub struct KindSummary {
    pub kind: SpanKind,
    pub count: usize,
    pub p50_ns: u64,
    pub p99_ns: u64,
}

/// Aggregate view printed at exit when `--trace` was active.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    pub rows: Vec<KindSummary>,
    pub total: usize,
    pub dropped: u64,
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl TraceSummary {
    pub fn from_records(records: &[SpanRecord], dropped: u64) -> TraceSummary {
        let mut rows = Vec::new();
        for kind in SPAN_KINDS {
            let mut durs: Vec<u64> = records
                .iter()
                .filter(|r| r.kind == kind)
                .map(SpanRecord::dur_ns)
                .collect();
            if durs.is_empty() {
                continue;
            }
            durs.sort_unstable();
            rows.push(KindSummary {
                kind,
                count: durs.len(),
                p50_ns: percentile(&durs, 0.50),
                p99_ns: percentile(&durs, 0.99),
            });
        }
        TraceSummary {
            rows,
            total: records.len(),
            dropped,
        }
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "trace summary: {} spans ({} dropped)\n  {:<12}  {:>8}  {:>12}  {:>12}\n",
            self.total, self.dropped, "kind", "count", "p50 (µs)", "p99 (µs)"
        );
        for row in &self.rows {
            out.push_str(&format!(
                "  {:<12}  {:>8}  {:>12.1}  {:>12.1}\n",
                row.kind.as_str(),
                row.count,
                row.p50_ns as f64 / 1e3,
                row.p99_ns as f64 / 1e3,
            ));
        }
        out
    }
}

/// Write drained records as JSONL (one record per line, newline-
/// terminated), each line carrying `run_id` for manifest correlation.
pub fn write_jsonl(path: &str, run_id: &str, records: &[SpanRecord]) -> io::Result<()> {
    let mut out = String::new();
    for rec in records {
        out.push_str(&rec.to_json(run_id).to_string());
        out.push('\n');
    }
    fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global; this single test exercises the
    // whole lifecycle serially so parallel test binaries stay unaffected
    // (no other unit test enables tracing).
    #[test]
    fn lifecycle_ring_summary_jsonl() {
        assert!(!enabled());
        // Disabled spans are inert.
        span(SpanKind::Episode).field("episode", 1.0).done();
        let (empty, dropped) = disable_and_drain();
        assert!(empty.is_empty());
        assert_eq!(dropped, 0);

        enable_with_capacity(4);
        assert!(enabled());
        for i in 0..6 {
            span(SpanKind::Episode).field("episode", i as f64).done();
        }
        event(SpanKind::Checkpoint);
        let (records, dropped) = disable_and_drain();
        assert!(!enabled());
        // Ring holds 4 of the 7 records; 3 oldest were overwritten.
        assert_eq!(records.len(), 4);
        assert_eq!(dropped, 3);
        // Chronological order survives wraparound.
        for pair in records.windows(2) {
            assert!(pair[0].start_ns <= pair[1].start_ns);
        }
        // The newest episode (i=5) and the checkpoint event survived.
        let kinds: Vec<SpanKind> = records.iter().map(|r| r.kind).collect();
        assert!(kinds.contains(&SpanKind::Checkpoint));
        assert!(records
            .iter()
            .any(|r| r.kind == SpanKind::Episode && r.fields[0] == ("episode", 5.0)));

        let summary = TraceSummary::from_records(&records, dropped);
        assert_eq!(summary.total, 4);
        assert_eq!(summary.dropped, 3);
        let rendered = summary.render();
        assert!(rendered.contains("episode"));
        assert!(rendered.contains("checkpoint"));

        // JSONL round-trips through the in-repo parser.
        let line = records[0].to_json("run-test").to_string();
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.req_str("run_id").unwrap(), "run-test");
        assert!(parsed.get("dur_ns").is_some());
    }
}
