//! Fixed-point scalar value and the wide MAC accumulator.

use super::FixedSpec;

/// A fixed-point value: raw integer word interpreted as `raw / 2^frac`.
///
/// `Fixed` deliberately carries its [`FixedSpec`] so mixed-format bugs are
/// caught in debug builds (`debug_assert!`) while the release hot path stays
/// branch-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fixed {
    raw: i64,
    spec: FixedSpec,
}

#[inline]
fn round_half_even(x: f64) -> i64 {
    // `round_ties_even` matches numpy/jax `round` used on the python side.
    x.round_ties_even() as i64
}

impl Fixed {
    /// Quantize a float to the grid: scale, round-half-even, saturate.
    #[inline]
    pub fn from_f64(x: f64, spec: FixedSpec) -> Self {
        let scaled = round_half_even(x * spec.scale());
        let raw = scaled.clamp(spec.qmin(), spec.qmax());
        Fixed { raw, spec }
    }

    #[inline]
    pub fn from_f32(x: f32, spec: FixedSpec) -> Self {
        // Match python: jnp.round operates on the f32 product; promoting the
        // f32 input to f64 first is exact, so one shared path suffices.
        Self::from_f64(x as f64, spec)
    }

    /// Construct from a raw integer word (saturating).
    #[inline]
    pub fn from_raw(raw: i64, spec: FixedSpec) -> Self {
        Fixed { raw: raw.clamp(spec.qmin(), spec.qmax()), spec }
    }

    #[inline]
    pub fn zero(spec: FixedSpec) -> Self {
        Fixed { raw: 0, spec }
    }

    #[inline]
    pub fn raw(&self) -> i64 {
        self.raw
    }

    #[inline]
    pub fn spec(&self) -> FixedSpec {
        self.spec
    }

    #[inline]
    pub fn to_f64(&self) -> f64 {
        self.raw as f64 / self.spec.scale()
    }

    #[inline]
    pub fn to_f32(&self) -> f32 {
        self.to_f64() as f32
    }

    /// Saturating addition (single adder stage).
    #[inline]
    pub fn add(&self, rhs: Fixed) -> Fixed {
        debug_assert_eq!(self.spec, rhs.spec);
        Fixed::from_raw(self.raw + rhs.raw, self.spec)
    }

    /// Saturating subtraction.
    #[inline]
    pub fn sub(&self, rhs: Fixed) -> Fixed {
        debug_assert_eq!(self.spec, rhs.spec);
        Fixed::from_raw(self.raw - rhs.raw, self.spec)
    }

    /// Fixed-point multiply: exact 2·frac-bit product, one rounding back to
    /// frac bits (round-half-even on the dropped bits), saturate — DSP48
    /// multiplier followed by the rounding stage.
    #[inline]
    pub fn mul(&self, rhs: Fixed) -> Fixed {
        debug_assert_eq!(self.spec, rhs.spec);
        let prod = self.raw as i128 * rhs.raw as i128; // 2*frac fraction bits
        Fixed::from_raw(round_q(prod, self.spec.frac), self.spec)
    }

    /// Negation (saturating: −qmin saturates to qmax).
    #[inline]
    pub fn neg(&self) -> Fixed {
        Fixed::from_raw(-self.raw, self.spec)
    }

    /// Single-event upset: flip one physical bit of the stored word
    /// (two's complement, bit 0 = LSB, bit word−1 = sign). Every word-bit
    /// pattern is representable, so no saturation is involved — the result
    /// is exactly the register content after the upset.
    #[inline]
    pub fn flip_bit(&self, bit: u32) -> Fixed {
        debug_assert!(bit < self.spec.word);
        let mask = (1u64 << self.spec.word) - 1;
        let flipped = ((self.raw as u64) & mask) ^ (1u64 << bit);
        let sign = 1u64 << (self.spec.word - 1);
        let raw = if flipped & sign != 0 {
            (flipped | !mask) as i64
        } else {
            flipped as i64
        };
        Fixed { raw, spec: self.spec }
    }
}

/// Round a 2·frac-fraction-bit integer down to frac fraction bits with
/// round-half-even, mirroring `round(x * 2^frac) / 2^frac` on exact values.
#[inline]
fn round_q(wide: i128, frac: u32) -> i64 {
    let div = 1i128 << frac;
    let q = wide >> frac; // floor division (arithmetic shift)
    let rem = wide - (q << frac);
    let half = div / 2;
    let rounded = if rem > half {
        q + 1
    } else if rem < half {
        q
    } else {
        // exactly half: round to even
        if q & 1 == 0 {
            q
        } else {
            q + 1
        }
    };
    rounded as i64
}

/// Wide MAC accumulator: holds 2·frac fraction bits in i128, so a whole dot
/// product accumulates exactly and is rounded **once** on readout. This is
/// the DSP48 accumulation-chain semantics the python oracle's `qdot`
/// reproduces (see kernels/fixed_point.py).
#[derive(Debug, Clone, Copy)]
pub struct Acc {
    wide: i128,
    spec: FixedSpec,
}

impl Acc {
    #[inline]
    pub fn new(spec: FixedSpec) -> Self {
        Acc { wide: 0, spec }
    }

    /// Accumulate the exact product a·b (no intermediate rounding).
    #[inline]
    pub fn mac(&mut self, a: Fixed, b: Fixed) {
        debug_assert_eq!(a.spec(), self.spec);
        debug_assert_eq!(b.spec(), self.spec);
        self.wide += a.raw() as i128 * b.raw() as i128;
    }

    /// Add a frac-bit value (e.g. the bias) by widening it to 2·frac bits.
    #[inline]
    pub fn add_value(&mut self, v: Fixed) {
        debug_assert_eq!(v.spec(), self.spec);
        self.wide += (v.raw() as i128) << self.spec.frac;
    }

    /// Round once back to the Q(word, frac) grid and saturate.
    #[inline]
    pub fn finish(self) -> Fixed {
        Fixed::from_raw(round_q(self.wide, self.spec.frac), self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const Q: FixedSpec = FixedSpec::new(18, 12);

    /// Pinned convention vectors, shared with
    /// python/tests/test_fixed_point.py::VECTORS.
    #[test]
    fn matches_python_convention() {
        let cases: &[(f64, f64)] = &[
            (0.0, 0.0),
            (1.0, 1.0),
            (-1.0, -1.0),
            (0.5, 0.5),
            // round-half-even at the grid midpoint
            (2048.5 / 4096.0, 2048.0 / 4096.0),
            (2049.5 / 4096.0, 2050.0 / 4096.0),
            // saturation
            (100.0, 131071.0 / 4096.0),
            (-100.0, -131072.0 / 4096.0),
        ];
        for &(x, want) in cases {
            let got = Fixed::from_f64(x, Q).to_f64();
            assert_eq!(got, want, "quantize({x})");
        }
    }

    #[test]
    fn quantize_idempotent() {
        for i in -2000..2000 {
            let x = i as f64 * 0.01;
            let q1 = Fixed::from_f64(x, Q);
            let q2 = Fixed::from_f64(q1.to_f64(), Q);
            assert_eq!(q1, q2);
        }
    }

    #[test]
    fn mul_single_rounding() {
        let a = Fixed::from_f64(0.3, Q);
        let b = Fixed::from_f64(0.7, Q);
        let got = a.mul(b);
        // exact product of the quantized values, rounded once
        let want = Fixed::from_f64(a.to_f64() * b.to_f64(), Q);
        assert_eq!(got, want);
    }

    #[test]
    fn mul_negative_rounding() {
        // rounding of negative products must also be round-half-even
        for (x, y) in [(-0.3, 0.7), (0.3, -0.7), (-0.3, -0.7), (-1.5, 1.5)] {
            let a = Fixed::from_f64(x, Q);
            let b = Fixed::from_f64(y, Q);
            let want = Fixed::from_f64(a.to_f64() * b.to_f64(), Q);
            assert_eq!(a.mul(b), want, "{x} * {y}");
        }
    }

    #[test]
    fn acc_matches_single_rounding_of_exact_dot() {
        let xs: Vec<Fixed> = (0..16)
            .map(|i| Fixed::from_f64(0.1 * i as f64 - 0.8, Q))
            .collect();
        let ws: Vec<Fixed> = (0..16)
            .map(|i| Fixed::from_f64(0.05 * i as f64 - 0.4, Q))
            .collect();
        let mut acc = Acc::new(Q);
        let mut exact = 0.0f64;
        for (x, w) in xs.iter().zip(&ws) {
            acc.mac(*x, *w);
            exact += x.to_f64() * w.to_f64();
        }
        assert_eq!(acc.finish(), Fixed::from_f64(exact, Q));
    }

    #[test]
    fn acc_bias_widening() {
        let mut acc = Acc::new(Q);
        acc.add_value(Fixed::from_f64(0.25, Q));
        acc.mac(Fixed::from_f64(0.5, Q), Fixed::from_f64(0.5, Q));
        assert_eq!(acc.finish().to_f64(), 0.5);
    }

    #[test]
    fn saturating_arithmetic() {
        let max = Fixed::from_raw(Q.qmax(), Q);
        assert_eq!(max.add(max).raw(), Q.qmax());
        let min = Fixed::from_raw(Q.qmin(), Q);
        assert_eq!(min.add(min).raw(), Q.qmin());
        assert_eq!(min.neg().raw(), Q.qmax()); // −qmin saturates
        assert_eq!(max.mul(max).raw(), Q.qmax()); // 32*32 >> range
    }

    #[test]
    fn flip_bit_is_involutive_and_in_range() {
        for (w, f) in [(8u32, 4u32), (16, 8), (18, 12), (24, 16), (32, 24)] {
            let spec = FixedSpec::new(w, f);
            for x in [-3.25f64, -0.5, 0.0, 0.125, 2.75] {
                let v = Fixed::from_f64(x, spec);
                for bit in 0..w {
                    let u = v.flip_bit(bit);
                    assert_ne!(u, v, "Q({w},{f}) bit {bit}");
                    assert_eq!(u.flip_bit(bit), v, "Q({w},{f}) bit {bit}");
                    assert!(u.raw() >= spec.qmin() && u.raw() <= spec.qmax());
                }
            }
        }
    }

    #[test]
    fn flip_sign_bit_of_zero_is_qmin() {
        let v = Fixed::zero(Q).flip_bit(Q.word - 1);
        assert_eq!(v.raw(), Q.qmin());
    }

    #[test]
    fn sub_basic() {
        let a = Fixed::from_f64(1.5, Q);
        let b = Fixed::from_f64(0.25, Q);
        assert_eq!(a.sub(b).to_f64(), 1.25);
    }
}
