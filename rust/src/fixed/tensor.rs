//! Slice / matrix helpers over [`Fixed`] used by the NN baseline and the
//! FPGA datapath simulator.

use super::{Acc, Fixed, FixedSpec};

/// Quantize an f32 slice onto the grid.
pub fn quantize_slice(xs: &[f32], spec: FixedSpec) -> Vec<Fixed> {
    xs.iter().map(|&x| Fixed::from_f32(x, spec)).collect()
}

/// Dequantize back to f32.
pub fn to_f32_vec(xs: &[Fixed]) -> Vec<f32> {
    xs.iter().map(Fixed::to_f32).collect()
}

/// Fixed-point dot product with a single final rounding (wide accumulator).
pub fn dot(x: &[Fixed], w: &[Fixed], spec: FixedSpec) -> Fixed {
    debug_assert_eq!(x.len(), w.len());
    let mut acc = Acc::new(spec);
    for (a, b) in x.iter().zip(w) {
        acc.mac(*a, *b);
    }
    acc.finish()
}

/// Dot product plus bias, one rounding: the paper's MAC block (Fig. 4).
pub fn dot_bias(x: &[Fixed], w: &[Fixed], b: Fixed, spec: FixedSpec) -> Fixed {
    debug_assert_eq!(x.len(), w.len());
    let mut acc = Acc::new(spec);
    for (a, ww) in x.iter().zip(w) {
        acc.mac(*a, *ww);
    }
    acc.add_value(b);
    acc.finish()
}

/// y = x · W + b for a row-major W of shape (d, h): h wide accumulators,
/// one rounding per output — the parallel-MAC hidden layer.
pub fn matvec_bias(
    x: &[Fixed],
    w: &[Fixed],
    b: &[Fixed],
    d: usize,
    h: usize,
    spec: FixedSpec,
) -> Vec<Fixed> {
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(w.len(), d * h);
    debug_assert_eq!(b.len(), h);
    let mut out = Vec::with_capacity(h);
    for j in 0..h {
        let mut acc = Acc::new(spec);
        for i in 0..d {
            acc.mac(x[i], w[i * h + j]);
        }
        acc.add_value(b[j]);
        out.push(acc.finish());
    }
    out
}

/// Flip one physical bit of one word in a weight store — the fault
/// subsystem's entry point into fixed-point tensors ([`crate::fault`]).
pub fn flip_bit_at(xs: &mut [Fixed], word: usize, bit: u32) {
    debug_assert!(word < xs.len());
    xs[word] = xs[word].flip_bit(bit);
}

/// Max over a slice (the error-capture block's comparator chain).
pub fn max(xs: &[Fixed]) -> Fixed {
    debug_assert!(!xs.is_empty());
    let mut m = xs[0];
    for &x in &xs[1..] {
        if x.raw() > m.raw() {
            m = x;
        }
    }
    m
}

/// Index of the maximum (action selection on the fixed datapath).
pub fn argmax(xs: &[Fixed]) -> usize {
    debug_assert!(!xs.is_empty());
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if x.raw() > xs[best].raw() {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    const Q: FixedSpec = FixedSpec::new(18, 12);

    #[test]
    fn dot_matches_scalar_chain() {
        let x = quantize_slice(&[0.5, -0.25, 1.0], Q);
        let w = quantize_slice(&[1.0, 2.0, -0.5], Q);
        let d = dot(&x, &w, Q);
        assert_eq!(d.to_f64(), 0.5 - 0.5 - 0.5);
    }

    #[test]
    fn matvec_matches_dots() {
        let x = quantize_slice(&[0.1, 0.2, 0.3, 0.4], Q);
        let w = quantize_slice(&(0..8).map(|i| i as f32 * 0.1).collect::<Vec<_>>(), Q);
        let b = quantize_slice(&[0.5, -0.5], Q);
        let y = matvec_bias(&x, &w, &b, 4, 2, Q);
        for j in 0..2 {
            let col: Vec<Fixed> = (0..4).map(|i| w[i * 2 + j]).collect();
            let want = dot_bias(&x, &col, b[j], Q);
            assert_eq!(y[j], want);
        }
    }

    #[test]
    fn max_and_argmax() {
        let xs = quantize_slice(&[0.1, 0.9, -0.4, 0.9, 0.2], Q);
        assert_eq!(max(&xs), Fixed::from_f64(0.9, Q));
        assert_eq!(argmax(&xs), 1); // first max wins
    }

    #[test]
    fn roundtrip() {
        let xs = [0.125f32, -0.75, 3.0, -3.0];
        let q = quantize_slice(&xs, Q);
        assert_eq!(to_f32_vec(&q), xs.to_vec());
    }
}
