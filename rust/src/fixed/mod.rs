//! Qm.n fixed-point arithmetic — the paper's fixed datapath substrate.
//!
//! The paper's headline result (Tables 1–6) hinges on replacing floating
//! point with fixed point so the datapath maps onto DSP48 MACs. This module
//! provides:
//!
//! * [`FixedSpec`] — a Q(word, frac) format description (default Q(18,12),
//!   chosen so words feed the DSP48E1 18-bit multiplier port directly);
//! * [`Fixed`] — a saturating fixed-point value with round-half-even
//!   conversion, matching `python/compile/kernels/fixed_point.py`;
//! * [`Acc`] — the wide MAC accumulator (2·frac fraction bits, i128 width)
//!   modelling the DSP48 accumulation chain: products accumulate exactly and
//!   are rounded **once** on readout;
//! * [`tensor`] — slice/matrix helpers used by the NN baseline and the FPGA
//!   datapath simulator.
//!
//! Cross-layer contract: the python side fake-quantizes in float32 while
//! this module uses true integer words. For the value ranges exercised here
//! (|x| ≤ 32, word ≤ 24) both representations are exact in f32/f64 and agree
//! to the bit; `tests/backend_equiv.rs` and the pinned vectors below enforce
//! the shared convention (round-half-even, saturate at ±2^(word−1)).

mod quant;
mod spec;
mod value;

pub mod tensor;

pub use quant::Quantizer;
pub use spec::FixedSpec;
pub use value::{Acc, Fixed};
