//! Fast fake-quantizer for the f32 datapaths.
//!
//! [`Fixed::from_f32`] routes through f64 (the canonical convention used by
//! the integer datapath). The NN fake-quant path calls a quantizer once per
//! register value on the hot loop, so this precomputes the constants and
//! stays entirely in f32 — which also matches the python/XLA float32
//! fake-quant (`jnp.round(x * scale)`) bit-for-bit, where the f64 route can
//! differ by one LSB at rounding ties. §Perf: ~2.3× on the fixed-mode CPU
//! backend (EXPERIMENTS.md).

use super::FixedSpec;

/// Precomputed Q(word, frac) fake-quantizer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    scale: f32,
    inv_scale: f32,
    qmin: f32,
    qmax: f32,
    spec: FixedSpec,
}

impl Quantizer {
    pub fn new(spec: FixedSpec) -> Self {
        Quantizer {
            scale: spec.scale() as f32,
            inv_scale: (1.0 / spec.scale()) as f32,
            qmin: spec.qmin() as f32,
            qmax: spec.qmax() as f32,
            spec,
        }
    }

    pub fn spec(&self) -> FixedSpec {
        self.spec
    }

    /// Quantize one value: scale, round-half-even, saturate — all in f32,
    /// matching `jnp.round(x * 2^frac).clip(...) / 2^frac`.
    #[inline(always)]
    pub fn q(&self, x: f32) -> f32 {
        let scaled = (x * self.scale).round_ties_even();
        scaled.clamp(self.qmin, self.qmax) * self.inv_scale
    }

    /// Quantize straight to the raw integer word (for the integer
    /// datapath's input registers — avoids the f64 round trip of
    /// `Fixed::from_f32` on the per-element hot path).
    #[inline(always)]
    pub fn to_raw(&self, x: f32) -> i64 {
        (x * self.scale).round_ties_even().clamp(self.qmin, self.qmax) as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Fixed;
    use crate::util::Rng;

    #[test]
    fn matches_f64_convention_on_typical_range() {
        let spec = FixedSpec::default();
        let q = Quantizer::new(spec);
        let mut rng = Rng::seeded(7);
        let mut tie_diffs = 0usize;
        for _ in 0..50_000 {
            let x = rng.f32_range(-40.0, 40.0);
            let fast = q.q(x);
            let slow = Fixed::from_f32(x, spec).to_f32();
            // the f32 path may resolve a rounding tie differently than the
            // f64 path when x*scale lands exactly on .5 after f32 rounding;
            // anything larger than one LSB is a bug
            if fast != slow {
                assert!(
                    (fast - slow).abs() <= spec.lsb() as f32,
                    "{x}: fast {fast} vs slow {slow}"
                );
                tie_diffs += 1;
            }
        }
        assert!(tie_diffs < 100, "too many tie mismatches: {tie_diffs}");
    }

    #[test]
    fn saturates() {
        let q = Quantizer::new(FixedSpec::default());
        assert_eq!(q.q(1e9), FixedSpec::default().max_value() as f32);
        assert_eq!(q.q(-1e9), FixedSpec::default().min_value() as f32);
    }

    #[test]
    fn idempotent() {
        let q = Quantizer::new(FixedSpec::new(16, 8));
        for i in -1000..1000 {
            let x = i as f32 * 0.013;
            assert_eq!(q.q(q.q(x)), q.q(x));
        }
    }

    #[test]
    fn exact_on_grid_values() {
        let q = Quantizer::new(FixedSpec::default());
        for k in [-4096i32, -1, 0, 1, 2048, 131071] {
            let x = k as f32 / 4096.0;
            assert_eq!(q.q(x), x);
        }
    }
}
