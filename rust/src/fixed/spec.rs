//! Q(word, frac) format description.

use crate::error::{Error, Result};

/// A signed fixed-point format with `word` total bits (including sign) and
/// `frac` fraction bits — “Q(word, frac)”.
///
/// The paper (Section 5) notes that “the fixed point word length and
/// fraction length plays a major role in trading off accuracy with power
/// consumption”; the X3 ablation sweeps this spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FixedSpec {
    /// Total bits, including sign. 2 ..= 63.
    pub word: u32,
    /// Fraction bits. < word.
    pub frac: u32,
}

impl Default for FixedSpec {
    /// Q(18,12): 18-bit words drive the DSP48E1 18×25 multiplier directly.
    fn default() -> Self {
        FixedSpec { word: 18, frac: 12 }
    }
}

impl FixedSpec {
    pub const fn new(word: u32, frac: u32) -> Self {
        FixedSpec { word, frac }
    }

    /// Q(8,4): the canonical grid of the `Precision::Int8` kernel arm.
    /// Range ±8 matches the sigmoid LUT input window (`LutSpec::xmax`), so
    /// the narrow words lose fraction bits, not dynamic range.
    pub const fn int8() -> Self {
        FixedSpec { word: 8, frac: 4 }
    }

    /// Validate the format (word within machine limits, frac < word).
    pub fn validate(&self) -> Result<()> {
        if self.word < 2 || self.word > 63 {
            return Err(Error::Config(format!(
                "fixed word length {} out of range 2..=63",
                self.word
            )));
        }
        if self.frac >= self.word {
            return Err(Error::Config(format!(
                "fraction bits {} must be < word length {}",
                self.frac, self.word
            )));
        }
        Ok(())
    }

    /// Largest representable raw integer: 2^(word−1) − 1.
    #[inline]
    pub const fn qmax(&self) -> i64 {
        (1i64 << (self.word - 1)) - 1
    }

    /// Smallest representable raw integer: −2^(word−1).
    #[inline]
    pub const fn qmin(&self) -> i64 {
        -(1i64 << (self.word - 1))
    }

    /// 2^frac as f64.
    #[inline]
    pub fn scale(&self) -> f64 {
        (1i64 << self.frac) as f64
    }

    /// Value of one least-significant bit.
    #[inline]
    pub fn lsb(&self) -> f64 {
        1.0 / self.scale()
    }

    /// Largest representable value.
    #[inline]
    pub fn max_value(&self) -> f64 {
        self.qmax() as f64 / self.scale()
    }

    /// Smallest (most negative) representable value.
    #[inline]
    pub fn min_value(&self) -> f64 {
        self.qmin() as f64 / self.scale()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q18_12_constants() {
        let s = FixedSpec::default();
        assert_eq!(s.qmax(), 131_071);
        assert_eq!(s.qmin(), -131_072);
        assert_eq!(s.scale(), 4096.0);
        assert_eq!(s.lsb(), 1.0 / 4096.0);
    }

    #[test]
    fn validation() {
        assert!(FixedSpec::new(18, 12).validate().is_ok());
        assert!(FixedSpec::new(1, 0).validate().is_err());
        assert!(FixedSpec::new(64, 12).validate().is_err());
        assert!(FixedSpec::new(16, 16).validate().is_err());
        assert!(FixedSpec::new(16, 17).validate().is_err());
    }

    #[test]
    fn int8_grid_constants() {
        let s = FixedSpec::int8();
        assert!(s.validate().is_ok());
        assert_eq!((s.word, s.frac), (8, 4));
        assert_eq!(s.lsb(), 1.0 / 16.0);
        // dynamic range covers the sigmoid LUT window ±8
        assert!(s.max_value() >= 7.9 && s.min_value() <= -8.0);
    }

    #[test]
    fn range_symmetry() {
        for (w, f) in [(8u32, 4u32), (16, 8), (18, 12), (24, 16), (32, 24)] {
            let s = FixedSpec::new(w, f);
            assert_eq!(s.qmax(), -s.qmin() - 1);
            assert!(s.max_value() > 0.0 && s.min_value() < 0.0);
        }
    }
}
