//! The gateway wire protocol: newline-delimited canonical JSON frames.
//!
//! One request or response per line, each a single JSON object carrying a
//! `type` tag. Canonical form (sorted keys, compact separators — what
//! [`Json`]'s `Display` prints) means a frame re-serializes to the exact
//! bytes it was parsed from, which the property tests pin down. See the
//! [`crate::serve`] module docs for the full frame-by-frame reference.

use std::io::{self, BufRead, Write};

use crate::coordinator::telemetry::RoverProgress;
use crate::error::{Error, Result};
use crate::util::Json;

use super::job::JobSpec;

/// Default priority class for submissions that do not name one.
pub const DEFAULT_PRIORITY: u8 = 1;
/// Highest accepted priority class.
pub const MAX_PRIORITY: u8 = 9;

/// Client → daemon frames.
#[derive(Debug, Clone)]
pub enum Request {
    /// Submit a job. `stream` asks for progress frames before the result.
    Submit {
        job: JobSpec,
        priority: u8,
        stream: bool,
    },
    /// Liveness + queue occupancy probe.
    Healthz,
    /// Prometheus exposition of the full metrics registry.
    Metrics,
    /// Ask the daemon to drain and exit (same path as SIGTERM).
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit { job, priority, stream } => Json::obj(vec![
                ("type", Json::Str("submit".into())),
                ("job", job.to_json()),
                ("priority", Json::Num(*priority as f64)),
                ("stream", Json::Bool(*stream)),
            ]),
            Request::Healthz => Json::obj(vec![("type", Json::Str("healthz".into()))]),
            Request::Metrics => Json::obj(vec![("type", Json::Str("metrics".into()))]),
            Request::Shutdown => Json::obj(vec![("type", Json::Str("shutdown".into()))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        match j.req_str("type")? {
            "submit" => {
                let job = JobSpec::from_json(
                    j.get("job").ok_or_else(|| Error::interface("submit missing `job`"))?,
                )?;
                let priority = match j.get("priority") {
                    Some(p) => {
                        let p = p
                            .as_f64()
                            .ok_or_else(|| Error::interface("priority must be a number"))?;
                        if !(0.0..=MAX_PRIORITY as f64).contains(&p) || p.fract() != 0.0 {
                            return Err(Error::interface(format!(
                                "priority must be an integer in 0..={MAX_PRIORITY}, got {p}"
                            )));
                        }
                        p as u8
                    }
                    None => DEFAULT_PRIORITY,
                };
                let stream = matches!(j.get("stream"), Some(Json::Bool(true)));
                Ok(Request::Submit { job, priority, stream })
            }
            "healthz" => Ok(Request::Healthz),
            "metrics" => Ok(Request::Metrics),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(Error::interface(format!("unknown request type `{other}`"))),
        }
    }
}

/// Daemon → client frames.
#[derive(Debug, Clone)]
pub enum Response {
    /// The job was admitted to the queue.
    Accepted {
        job_id: String,
        spec_sha256: String,
        queue_depth: usize,
    },
    /// Backpressure: try again after the hinted delay.
    Rejected { reason: String, retry_after_ms: u64 },
    /// One streamed progress sample (only when the submit set `stream`).
    Progress { job_id: String, sample: RoverProgress },
    /// Terminal frame for a submission.
    JobResult {
        job_id: String,
        ok: bool,
        cache_hit: bool,
        /// Times this job was checkpointed + requeued for a higher-
        /// priority job before completing.
        preemptions: u64,
        report_id: String,
        report_sha256: String,
        /// The full report document (`Json::Null` when `ok` is false).
        report: Json,
        /// Present exactly when `ok` is false.
        error: Option<String>,
    },
    /// Answer to [`Request::Healthz`].
    Health {
        status: String,
        queue_depth: usize,
        in_flight: usize,
        workers: usize,
        cache_entries: usize,
        completed: u64,
    },
    /// Answer to [`Request::Metrics`]: Prometheus text exposition.
    MetricsText { prometheus: String },
    /// Protocol-level failure (unparseable frame, bad spec).
    ProtocolError { message: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Accepted { job_id, spec_sha256, queue_depth } => Json::obj(vec![
                ("type", Json::Str("accepted".into())),
                ("job_id", Json::Str(job_id.clone())),
                ("spec_sha256", Json::Str(spec_sha256.clone())),
                ("queue_depth", Json::Num(*queue_depth as f64)),
            ]),
            Response::Rejected { reason, retry_after_ms } => Json::obj(vec![
                ("type", Json::Str("rejected".into())),
                ("reason", Json::Str(reason.clone())),
                ("retry_after_ms", Json::Num(*retry_after_ms as f64)),
            ]),
            Response::Progress { job_id, sample } => {
                let mut doc = sample.to_json();
                if let Json::Obj(map) = &mut doc {
                    map.insert("type".into(), Json::Str("progress".into()));
                    map.insert("job_id".into(), Json::Str(job_id.clone()));
                }
                doc
            }
            Response::JobResult {
                job_id,
                ok,
                cache_hit,
                preemptions,
                report_id,
                report_sha256,
                report,
                error,
            } => {
                let mut fields = vec![
                    ("type", Json::Str("result".into())),
                    ("job_id", Json::Str(job_id.clone())),
                    ("ok", Json::Bool(*ok)),
                    ("cache_hit", Json::Bool(*cache_hit)),
                    ("preemptions", Json::Num(*preemptions as f64)),
                    ("report_id", Json::Str(report_id.clone())),
                    ("report_sha256", Json::Str(report_sha256.clone())),
                    ("report", report.clone()),
                ];
                if let Some(e) = error {
                    fields.push(("error", Json::Str(e.clone())));
                }
                Json::obj(fields)
            }
            Response::Health {
                status,
                queue_depth,
                in_flight,
                workers,
                cache_entries,
                completed,
            } => Json::obj(vec![
                ("type", Json::Str("health".into())),
                ("status", Json::Str(status.clone())),
                ("queue_depth", Json::Num(*queue_depth as f64)),
                ("in_flight", Json::Num(*in_flight as f64)),
                ("workers", Json::Num(*workers as f64)),
                ("cache_entries", Json::Num(*cache_entries as f64)),
                ("completed", Json::Num(*completed as f64)),
            ]),
            Response::MetricsText { prometheus } => Json::obj(vec![
                ("type", Json::Str("metrics".into())),
                ("prometheus", Json::Str(prometheus.clone())),
            ]),
            Response::ProtocolError { message } => Json::obj(vec![
                ("type", Json::Str("error".into())),
                ("message", Json::Str(message.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Response> {
        match j.req_str("type")? {
            "accepted" => Ok(Response::Accepted {
                job_id: j.req_str("job_id")?.to_string(),
                spec_sha256: j.req_str("spec_sha256")?.to_string(),
                queue_depth: j.req_usize("queue_depth")?,
            }),
            "rejected" => Ok(Response::Rejected {
                reason: j.req_str("reason")?.to_string(),
                retry_after_ms: j.req_f64("retry_after_ms")? as u64,
            }),
            "progress" => Ok(Response::Progress {
                job_id: j.req_str("job_id")?.to_string(),
                sample: RoverProgress::from_json(j)?,
            }),
            "result" => Ok(Response::JobResult {
                job_id: j.req_str("job_id")?.to_string(),
                ok: matches!(j.get("ok"), Some(Json::Bool(true))),
                cache_hit: matches!(j.get("cache_hit"), Some(Json::Bool(true))),
                preemptions: j.req_f64("preemptions")? as u64,
                report_id: j.req_str("report_id")?.to_string(),
                report_sha256: j.req_str("report_sha256")?.to_string(),
                report: j
                    .get("report")
                    .cloned()
                    .ok_or_else(|| Error::interface("result missing `report`"))?,
                error: j.get("error").and_then(|e| e.as_str()).map(String::from),
            }),
            "health" => Ok(Response::Health {
                status: j.req_str("status")?.to_string(),
                queue_depth: j.req_usize("queue_depth")?,
                in_flight: j.req_usize("in_flight")?,
                workers: j.req_usize("workers")?,
                cache_entries: j.req_usize("cache_entries")?,
                completed: j.req_f64("completed")? as u64,
            }),
            "metrics" => Ok(Response::MetricsText {
                prometheus: j.req_str("prometheus")?.to_string(),
            }),
            "error" => Ok(Response::ProtocolError {
                message: j.req_str("message")?.to_string(),
            }),
            other => Err(Error::interface(format!("unknown response type `{other}`"))),
        }
    }
}

/// Write one frame: canonical JSON + `\n`, flushed (a frame is a unit of
/// conversation; buffering across frames would deadlock request/reply).
pub fn write_frame(w: &mut impl Write, doc: &Json) -> io::Result<()> {
    writeln!(w, "{doc}")?;
    w.flush()
}

/// Incremental NDJSON frame reader tolerant of read timeouts.
///
/// The daemon sets a read timeout on connections so it can observe drain
/// requests; a timeout can therefore split one line across several
/// `read_line` calls. The buffer persists across calls, so partial bytes
/// are never lost — a frame completes whenever the buffer gains its `\n`.
pub struct FrameReader<R: io::Read> {
    reader: io::BufReader<R>,
    buf: String,
}

impl<R: io::Read> FrameReader<R> {
    pub fn new(inner: R) -> FrameReader<R> {
        FrameReader { reader: io::BufReader::new(inner), buf: String::new() }
    }

    /// Read the next frame. Returns `Ok(None)` on clean EOF or when
    /// `keep_waiting` answers false after a read timeout
    /// (`WouldBlock`/`TimedOut`); any other IO or parse failure is an
    /// error.
    pub fn read_frame(&mut self, keep_waiting: &dyn Fn() -> bool) -> Result<Option<Json>> {
        loop {
            if let Some(pos) = self.buf.find('\n') {
                let line: String = self.buf.drain(..=pos).collect();
                let line = line.trim();
                if line.is_empty() {
                    continue; // blank lines between frames are tolerated
                }
                return Ok(Some(Json::parse(line)?));
            }
            match self.reader.read_line(&mut self.buf) {
                Ok(0) => {
                    let tail = self.buf.trim();
                    if tail.is_empty() {
                        return Ok(None);
                    }
                    // torn final frame without trailing newline: parse it
                    let doc = Json::parse(tail)?;
                    self.buf.clear();
                    return Ok(Some(doc));
                }
                Ok(_) => {}
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    if !keep_waiting() {
                        return Ok(None);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvKind, Precision};
    use crate::coordinator::mission::MissionConfig;
    use crate::coordinator::ScenarioSpec;
    use crate::util::Rng;

    fn arb_job(rng: &mut Rng) -> JobSpec {
        let cfg = MissionConfig {
            env: *pick(rng, &EnvKind::all()),
            precision: *pick(rng, &[Precision::Float, Precision::Fixed]),
            episodes: rng.range(1, 50),
            max_steps: rng.range(5, 80),
            seed: rng.next_u64() % 1000,
            batch: rng.range(1, 8),
            ..Default::default()
        };
        match rng.below(3) {
            0 => JobSpec::Train(cfg),
            1 => JobSpec::Fleet { cfg, rovers: rng.range(1, 6), share: None },
            _ => JobSpec::Mission(ScenarioSpec {
                envs: vec![*pick(rng, &EnvKind::all())],
                episodes: rng.range(1, 20),
                max_steps: rng.range(5, 40),
                seed: rng.next_u64() % 1000,
                ..Default::default()
            }),
        }
    }

    fn pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
        &xs[rng.below(xs.len())]
    }

    fn arb_progress(rng: &mut Rng) -> RoverProgress {
        RoverProgress {
            rover: rng.below(8),
            episode: rng.below(100),
            episodes: rng.range(100, 200),
            reward: rng.f32_range(-5.0, 5.0),
            epsilon: rng.f32_range(0.0, 1.0),
        }
    }

    /// serialize → parse → serialize must be the identity on bytes.
    fn assert_fixed_point(doc: &Json) {
        let text = doc.to_string();
        let reparsed = Json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text);
    }

    #[test]
    fn request_frames_round_trip_property() {
        let mut rng = Rng::seeded(0x5EEDED);
        for case in 0..100 {
            let req = match rng.below(4) {
                0 | 1 => Request::Submit {
                    job: arb_job(&mut rng),
                    priority: rng.below(10) as u8,
                    stream: rng.chance(0.5),
                },
                2 => Request::Healthz,
                _ => match rng.below(2) {
                    0 => Request::Metrics,
                    _ => Request::Shutdown,
                },
            };
            let text = req.to_json().to_string();
            let back = Request::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_json().to_string(), text, "case {case}");
            assert_fixed_point(&req.to_json());
        }
    }

    #[test]
    fn response_frames_round_trip_property() {
        let mut rng = Rng::seeded(0xCAB1E);
        for case in 0..100 {
            let resp = match rng.below(7) {
                0 => Response::Accepted {
                    job_id: format!("job-{:06}", rng.below(1_000_000)),
                    spec_sha256: format!("{:064x}", rng.next_u64()),
                    queue_depth: rng.below(64),
                },
                1 => Response::Rejected {
                    reason: "queue full".into(),
                    retry_after_ms: rng.next_u64() % 10_000,
                },
                2 => Response::Progress {
                    job_id: "job-000001".into(),
                    sample: arb_progress(&mut rng),
                },
                3 => Response::JobResult {
                    job_id: "job-000002".into(),
                    ok: rng.chance(0.8),
                    cache_hit: rng.chance(0.3),
                    preemptions: rng.next_u64() % 4,
                    report_id: "EXP".into(),
                    report_sha256: format!("{:064x}", rng.next_u64()),
                    report: Json::obj(vec![("x", Json::Num(rng.f64()))]),
                    error: if rng.chance(0.2) { Some("boom".into()) } else { None },
                },
                4 => Response::Health {
                    status: if rng.chance(0.5) { "ok".into() } else { "draining".into() },
                    queue_depth: rng.below(64),
                    in_flight: rng.below(8),
                    workers: rng.range(1, 8),
                    cache_entries: rng.below(100),
                    completed: rng.next_u64() % 1000,
                },
                5 => Response::MetricsText {
                    prometheus: "# HELP x y\n# TYPE x counter\nx 1\n".into(),
                },
                _ => Response::ProtocolError { message: "bad frame".into() },
            };
            let text = resp.to_json().to_string();
            let back = Response::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_json().to_string(), text, "case {case}");
            assert_fixed_point(&resp.to_json());
        }
    }

    #[test]
    fn priority_is_validated() {
        let bad = r#"{"job":{"kind":"mission","spec":{"arch":"mlp","batch":1,"envs":["simple"],"episodes":1,"max_steps":5,"precision":"fixed","seed":7}},"priority":12,"type":"submit"}"#;
        assert!(Request::from_json(&Json::parse(bad).unwrap()).is_err());
        let frac = bad.replace("12", "1.5");
        assert!(Request::from_json(&Json::parse(&frac).unwrap()).is_err());
        let ok = bad.replace("12", "9");
        assert!(Request::from_json(&Json::parse(&ok).unwrap()).is_ok());
    }

    #[test]
    fn unknown_types_error_cleanly() {
        let j = Json::obj(vec![("type", Json::Str("warp".into()))]);
        assert!(Request::from_json(&j).is_err());
        assert!(Response::from_json(&j).is_err());
        assert!(Request::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn frame_reader_splits_lines_and_handles_eof() {
        let text = "{\"type\":\"healthz\"}\n\n{\"type\":\"metrics\"}\n{\"type\":\"shutdown\"}";
        let mut r = FrameReader::new(text.as_bytes());
        let keep = || true;
        let a = r.read_frame(&keep).unwrap().unwrap();
        assert_eq!(a.req_str("type").unwrap(), "healthz");
        let b = r.read_frame(&keep).unwrap().unwrap();
        assert_eq!(b.req_str("type").unwrap(), "metrics");
        // final frame lacks its newline (torn write at EOF) — still parsed
        let c = r.read_frame(&keep).unwrap().unwrap();
        assert_eq!(c.req_str("type").unwrap(), "shutdown");
        assert!(r.read_frame(&keep).unwrap().is_none());
    }

    #[test]
    fn write_frame_is_one_line_of_canonical_json() {
        let mut out = Vec::new();
        write_frame(&mut out, &Request::Healthz.to_json()).unwrap();
        assert_eq!(String::from_utf8(out).unwrap(), "{\"type\":\"healthz\"}\n");
    }
}
