//! Content-addressed result cache.
//!
//! Keyed on the sha256 of the job's canonical spec JSON (which embeds the
//! seed — see [`crate::serve::job::JobSpec::key`]). PR 7's replay gate
//! already proves spec → report determinism bit-for-bit, so a cache hit
//! can return the recorded report verbatim: byte-identical by
//! construction, because the in-repo [`crate::util::Json`] writer prints
//! canonical text (sorted keys, fixed float formatting) and the stored
//! value *is* the parsed document of the first run.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::Json;

/// One cached outcome: the report document plus its identity.
#[derive(Debug, Clone)]
pub struct CachedResult {
    /// `Report::id()` of the document (`"EXP"`, `"S1"`).
    pub report_id: String,
    /// The full report JSON as produced by the first execution.
    pub report: Json,
    /// Deterministic-projection hash ([`crate::obs::manifest::report_sha256`]).
    pub report_sha256: String,
}

/// Spec-sha256 → result map shared by every gateway worker.
#[derive(Debug, Default)]
pub struct ResultCache {
    inner: Mutex<HashMap<String, CachedResult>>,
    hits: Mutex<u64>,
}

impl ResultCache {
    pub fn new() -> ResultCache {
        ResultCache::default()
    }

    /// Look up a spec key; counts a hit (here and in the metrics
    /// registry) when present.
    pub fn get(&self, key: &str) -> Option<CachedResult> {
        let found = self.inner.lock().unwrap().get(key).cloned();
        if found.is_some() {
            *self.hits.lock().unwrap() += 1;
            crate::obs::metrics().serve_cache_hits.inc();
        }
        found
    }

    /// Record a completed job's report. Last writer wins; identical specs
    /// produce identical reports (the replay guarantee), so overwrites are
    /// value-idempotent.
    pub fn insert(&self, key: String, value: CachedResult) {
        self.inner.lock().unwrap().insert(key, value);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hits served since construction.
    pub fn hits(&self) -> u64 {
        *self.hits.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(v: f64) -> Json {
        Json::obj(vec![("id", Json::Str("EXP".into())), ("x", Json::Num(v))])
    }

    #[test]
    fn miss_then_hit_returns_the_identical_document() {
        let cache = ResultCache::new();
        assert!(cache.get("k1").is_none());
        assert_eq!(cache.hits(), 0);
        cache.insert(
            "k1".into(),
            CachedResult {
                report_id: "EXP".into(),
                report: doc(1.5),
                report_sha256: "abc".into(),
            },
        );
        let hit = cache.get("k1").unwrap();
        assert_eq!(hit.report.to_string(), doc(1.5).to_string());
        assert_eq!(hit.report_id, "EXP");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        // a second hit is byte-identical again
        assert_eq!(cache.get("k1").unwrap().report.to_string(), doc(1.5).to_string());
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn keys_are_independent() {
        let cache = ResultCache::new();
        cache.insert(
            "a".into(),
            CachedResult { report_id: "EXP".into(), report: doc(1.0), report_sha256: "h1".into() },
        );
        assert!(cache.get("b").is_none());
        assert_eq!(cache.len(), 1);
    }
}
