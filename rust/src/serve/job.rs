//! Gateway job specs: exactly the replayable run specs from
//! [`crate::obs::manifest::RunManifest`] (train / fleet / mission), plus
//! chunked execution with checkpoint-backed preemption.
//!
//! A job's cache key is the sha256 of its canonical spec JSON — the same
//! bytes a manifest records as `spec_sha256` input, and the same specs
//! `qfpga replay` proves deterministic. That is the whole soundness
//! argument for the result cache: spec bytes → report bytes is a pure
//! function for these three subcommands.

use std::time::Instant;

use crate::coordinator::mission::{MissionCheckpoint, MissionConfig, MissionRun};
use crate::coordinator::telemetry::RoverProgress;
use crate::coordinator::{scenario_table, ScenarioSpec};
use crate::error::{Error, Result};
use crate::experiment::{BackendFactory, Experiment, ExperimentReport};
use crate::obs::manifest::json_sha256;
use crate::qlearn::SharePlan;
use crate::report::Report;
use crate::util::Json;

/// One schedulable job — the three replayable run shapes.
#[derive(Debug, Clone)]
pub enum JobSpec {
    /// Single-rover training run (`qfpga train`). Preemptible when
    /// fault-free: it executes as a resumable [`MissionRun`].
    Train(MissionConfig),
    /// Fleet run (`qfpga fleet --rovers N`), executed on the PR 5 worker
    /// pool, optionally under a fleet-learning [`SharePlan`]. Runs to
    /// completion once started.
    Fleet { cfg: MissionConfig, rovers: usize, share: Option<SharePlan> },
    /// Scenario campaign (`qfpga mission`, table S1). Runs to completion
    /// once started.
    Mission(ScenarioSpec),
}

/// Outcome of one execution slice of a job.
pub enum JobStep {
    /// The job finished; here is its report document.
    Done(Json),
    /// A higher-priority job needs the worker: the mission state at the
    /// last episode boundary, resumable bit-exactly via
    /// [`JobSpec::run_step`]'s `resume` argument.
    Preempted(Box<MissionCheckpoint>),
}

impl JobSpec {
    /// The manifest subcommand this job replays.
    pub fn subcommand(&self) -> &'static str {
        match self {
            JobSpec::Train(_) => "train",
            JobSpec::Fleet { .. } => "fleet",
            JobSpec::Mission(_) => "mission",
        }
    }

    /// `Report::id()` of the document this job produces.
    pub fn report_id(&self) -> &'static str {
        match self {
            JobSpec::Train(_) | JobSpec::Fleet { .. } => "EXP",
            JobSpec::Mission(_) => "S1",
        }
    }

    /// The job's base seed (recorded in result frames and manifests).
    pub fn seed(&self) -> u64 {
        match self {
            JobSpec::Train(cfg) | JobSpec::Fleet { cfg, .. } => cfg.seed,
            JobSpec::Mission(spec) => spec.seed,
        }
    }

    /// Can this job be checkpointed and requeued mid-run? Only fault-free
    /// train jobs: [`MissionRun::checkpoint`] cannot serialize an SEU
    /// injection stream, and fleet/mission runs span multiple missions.
    pub fn preemptible(&self) -> bool {
        matches!(self, JobSpec::Train(cfg) if cfg.fault.is_none())
    }

    /// One-line description for daemon logs.
    pub fn describe(&self) -> String {
        match self {
            JobSpec::Train(cfg) => format!("train [{}]", cfg.describe()),
            JobSpec::Fleet { cfg, rovers, share } => format!(
                "fleet {rovers} × [{}]{}",
                cfg.describe(),
                match share {
                    Some(p) => format!(
                        " shared(ex{},avg{},cap{})",
                        p.exchange_every, p.avg_every, p.pool_cap
                    ),
                    None => String::new(),
                }
            ),
            JobSpec::Mission(spec) => format!(
                "mission [{}] {} {}",
                spec.envs.iter().map(|e| e.as_str()).collect::<Vec<_>>().join(","),
                spec.arch.as_str(),
                spec.precision.as_str()
            ),
        }
    }

    /// Wire form: `{"kind": ..., "spec": ...}` where `spec` is exactly
    /// the replayable spec a [`crate::obs::manifest::RunManifest`] embeds
    /// for the same run (fleet = mission config + `rovers`).
    pub fn to_json(&self) -> Json {
        let (kind, spec) = match self {
            JobSpec::Train(cfg) => ("train", cfg.to_json()),
            JobSpec::Fleet { cfg, rovers, share } => {
                let mut spec = cfg.to_json();
                if let Json::Obj(map) = &mut spec {
                    map.insert("rovers".into(), Json::Num(*rovers as f64));
                    // only-when-set: isolated fleet specs keep their exact
                    // historical bytes (cache keys and manifests unchanged)
                    if let Some(plan) = share {
                        map.insert("share".into(), plan.to_json());
                    }
                }
                ("fleet", spec)
            }
            JobSpec::Mission(spec) => ("mission", spec.to_json()),
        };
        Json::obj(vec![("kind", Json::Str(kind.into())), ("spec", spec)])
    }

    /// Inverse of [`JobSpec::to_json`].
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let kind = j.req_str("kind")?.to_string();
        let spec = j
            .get("spec")
            .ok_or_else(|| Error::interface("job missing `spec`"))?;
        Self::from_manifest(&kind, spec)
    }

    /// Build a job from a manifest-shaped (subcommand, spec) pair — shared
    /// by the wire decoder and `qfpga replay`.
    pub fn from_manifest(subcommand: &str, spec: &Json) -> Result<JobSpec> {
        match subcommand {
            "train" => Ok(JobSpec::Train(MissionConfig::from_json(spec)?)),
            "fleet" => Ok(JobSpec::Fleet {
                cfg: MissionConfig::from_json(spec)?,
                rovers: spec.req_usize("rovers")?,
                share: match spec.get("share") {
                    None | Some(Json::Null) => None,
                    Some(s) => Some(SharePlan::from_json(s).map_err(|e| {
                        Error::Config(format!("fleet spec `share` block: {e}"))
                    })?),
                },
            }),
            "mission" => Ok(JobSpec::Mission(ScenarioSpec::from_json(spec)?)),
            other => Err(Error::Config(format!(
                "`{other}` specs cannot be scheduled: the run records host-measured \
                 results (only train/fleet/mission are seed-deterministic end to end)"
            ))),
        }
    }

    /// Content-address of this job: sha256 of the canonical spec bytes.
    /// Seeds live inside the spec, so (spec, seed) collisions are
    /// impossible by construction.
    pub fn key(&self) -> String {
        json_sha256(&self.to_json())
    }

    /// Execute (a slice of) the job. `resume` continues a previously
    /// preempted run bit-exactly; `preempt` is polled at episode-chunk
    /// boundaries on preemptible jobs and, when it returns true, the job
    /// checkpoints and yields [`JobStep::Preempted`]. Non-preemptible jobs
    /// ignore `preempt` and always return [`JobStep::Done`].
    pub fn run_step(
        &self,
        resume: Option<MissionCheckpoint>,
        preempt: &dyn Fn() -> bool,
        chunk: usize,
        progress: &(dyn Fn(RoverProgress) + Sync),
    ) -> Result<JobStep> {
        match self {
            JobSpec::Train(cfg) if self.preemptible() => {
                let start = Instant::now();
                let factory = BackendFactory::for_kind(cfg.backend)?;
                let mut run = match resume {
                    Some(ckpt) => MissionRun::restore(cfg, &factory, ckpt)?,
                    None => MissionRun::new(cfg, &factory)?,
                };
                let episodes = cfg.episodes;
                while !run.is_complete() {
                    run.run_episodes(chunk.max(1), &mut |s| {
                        progress(RoverProgress {
                            rover: 0,
                            episode: s.episode,
                            episodes,
                            reward: s.total_reward,
                            epsilon: s.epsilon,
                        });
                    })?;
                    if !run.is_complete() && preempt() {
                        return Ok(JobStep::Preempted(Box::new(run.checkpoint()?)));
                    }
                }
                let report = run.finish()?;
                // same wrapper shape cmd_train produces, so the report
                // hashes identically to a CLI run of the same spec
                let doc = ExperimentReport {
                    desc: cfg.describe(),
                    rovers: vec![report],
                    workers: 1,
                    wall_seconds: start.elapsed().as_secs_f64(),
                    interrupted: false,
                    share: None,
                }
                .to_json();
                Ok(JobStep::Done(doc))
            }
            JobSpec::Train(cfg) => {
                // fault-injected train: not checkpointable, run whole
                let doc = Experiment::from_mission(cfg).run_with_progress(progress)?.to_json();
                Ok(JobStep::Done(doc))
            }
            JobSpec::Fleet { cfg, rovers, share } => {
                let mut exp = Experiment::from_mission(cfg).rovers(*rovers);
                if let Some(plan) = share {
                    exp = exp.share(*plan);
                }
                let doc = exp.run_with_progress(progress)?.to_json();
                Ok(JobStep::Done(doc))
            }
            JobSpec::Mission(spec) => Ok(JobStep::Done(scenario_table(spec)?.to_json())),
        }
    }

    /// Run the whole job with no preemption (replay, tests).
    pub fn run(&self, progress: &(dyn Fn(RoverProgress) + Sync)) -> Result<Json> {
        match self.run_step(None, &|| false, usize::MAX, progress)? {
            JobStep::Done(doc) => Ok(doc),
            JobStep::Preempted(_) => unreachable!("preempt closure never fires"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvKind, Precision};
    use crate::obs::manifest::report_sha256;

    fn tiny_cfg() -> MissionConfig {
        MissionConfig {
            env: EnvKind::Simple,
            precision: Precision::Float,
            episodes: 6,
            max_steps: 20,
            seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn wire_form_round_trips_bit_exactly() {
        let jobs = [
            JobSpec::Train(tiny_cfg()),
            JobSpec::Fleet { cfg: tiny_cfg(), rovers: 3, share: None },
            JobSpec::Fleet {
                cfg: tiny_cfg(),
                rovers: 4,
                share: Some(SharePlan { exchange_every: 2, avg_every: 4, pool_cap: 8 }),
            },
            JobSpec::Mission(ScenarioSpec {
                envs: vec![EnvKind::Crater],
                episodes: 2,
                max_steps: 10,
                ..Default::default()
            }),
        ];
        for job in &jobs {
            let text = job.to_json().to_string();
            let back = JobSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_json().to_string(), text);
            assert_eq!(back.key(), job.key());
            assert_eq!(back.subcommand(), job.subcommand());
        }
    }

    #[test]
    fn keys_are_content_addresses() {
        let a = JobSpec::Train(tiny_cfg());
        let mut cfg = tiny_cfg();
        cfg.seed = 12;
        let b = JobSpec::Train(cfg);
        assert_ne!(a.key(), b.key(), "seed is part of the content address");
        assert_eq!(a.key(), JobSpec::Train(tiny_cfg()).key());
        // a fleet of 1 is still a different job than a train
        let isolated = JobSpec::Fleet { cfg: tiny_cfg(), rovers: 1, share: None };
        assert_ne!(a.key(), isolated.key());
        // the share schedule is part of the content address
        let shared = JobSpec::Fleet {
            cfg: tiny_cfg(),
            rovers: 1,
            share: Some(SharePlan { exchange_every: 2, avg_every: 0, pool_cap: 4 }),
        };
        assert_ne!(isolated.key(), shared.key());
    }

    #[test]
    fn non_replayable_subcommands_are_rejected() {
        let err = JobSpec::from_manifest("sweep", &Json::obj(vec![])).unwrap_err();
        assert!(err.to_string().contains("cannot be scheduled"), "{err}");
    }

    #[test]
    fn malformed_share_blocks_fail_with_context() {
        let mut spec = tiny_cfg().to_json();
        if let Json::Obj(map) = &mut spec {
            map.insert("rovers".into(), Json::Num(2.0));
            // degenerate schedule: both cadences zero
            map.insert(
                "share".into(),
                SharePlan { exchange_every: 0, avg_every: 0, pool_cap: 4 }.to_json(),
            );
        }
        let err = JobSpec::from_manifest("fleet", &spec).unwrap_err();
        assert!(err.to_string().contains("`share` block"), "{err}");
        // an explicit null reads back as an isolated fleet
        if let Json::Obj(map) = &mut spec {
            map.insert("share".into(), Json::Null);
        }
        let job = JobSpec::from_manifest("fleet", &spec).unwrap();
        assert!(matches!(job, JobSpec::Fleet { share: None, .. }));
    }

    #[test]
    fn preemptibility_rules() {
        assert!(JobSpec::Train(tiny_cfg()).preemptible());
        assert!(!JobSpec::Fleet { cfg: tiny_cfg(), rovers: 2, share: None }.preemptible());
        assert!(!JobSpec::Mission(ScenarioSpec::default()).preemptible());
        let mut faulted = tiny_cfg();
        faulted.fault = Some(crate::fault::FaultPlan::constant(
            1e-4,
            crate::fault::Mitigation::None,
        ));
        assert!(!JobSpec::Train(faulted).preemptible());
    }

    #[test]
    fn preempt_resume_equals_uninterrupted() {
        let job = JobSpec::Train(tiny_cfg());
        let baseline = job.run(&|_| {}).unwrap();

        // preempt exactly once, at the first chunk boundary
        let fired = std::sync::atomic::AtomicBool::new(false);
        let once = || !fired.swap(true, std::sync::atomic::Ordering::SeqCst);
        let ckpt = match job.run_step(None, &once, 2, &|_| {}).unwrap() {
            JobStep::Preempted(c) => c,
            JobStep::Done(_) => panic!("expected a preemption"),
        };
        let resumed = match job.run_step(Some(*ckpt), &|| false, 2, &|_| {}).unwrap() {
            JobStep::Done(doc) => doc,
            JobStep::Preempted(_) => panic!("preempt closure is off"),
        };
        // bit-exact on the deterministic projection (wall time differs)
        assert_eq!(report_sha256(&resumed), report_sha256(&baseline));
    }

    #[test]
    fn progress_streams_final_episode() {
        let job = JobSpec::Train(tiny_cfg());
        let seen = std::sync::Mutex::new(Vec::new());
        job.run(&|p| seen.lock().unwrap().push(p)).unwrap();
        let seen = seen.into_inner().unwrap();
        assert_eq!(seen.len(), 6);
        assert!(seen.last().unwrap().is_final());
    }
}
