//! The mission gateway daemon: a unix-socket job server over the
//! [`super::protocol`] frames.
//!
//! Architecture: one nonblocking accept loop, one detached thread per
//! connection, `workers` executor threads pulling from a shared
//! [`super::queue::JobQueue`]. Results flow back to the submitting
//! connection over a per-job mpsc channel, so a preempted-and-requeued job
//! keeps talking to the same client. SIGTERM/SIGINT (via
//! [`crate::util::shutdown`]) or a `shutdown` frame start a drain: no new
//! admissions, every accepted job still runs to completion, then the
//! socket is unlinked and [`Gateway::run`] returns its tallies.

use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::coordinator::mission::MissionCheckpoint;
use crate::coordinator::telemetry::RoverProgress;
use crate::error::{Error, Result};
use crate::obs::{metrics, report_sha256, MetricsSnapshot};
use crate::util::{shutdown, Json};

use super::cache::{CachedResult, ResultCache};
use super::job::{JobSpec, JobStep};
use super::protocol::{write_frame, FrameReader, Request, Response, MAX_PRIORITY};
use super::queue::JobQueue;

/// How the accept loop naps when no connection is pending.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Read timeout on connections, so idle readers observe drain requests.
const READ_POLL: Duration = Duration::from_millis(250);
/// Progress frames are throttled to every Nth episode (plus the final one).
const PROGRESS_EVERY: usize = 5;

/// Gateway tunables (see `qfpga serve --help`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Unix socket path; a stale file from a dead daemon is replaced.
    pub socket: PathBuf,
    /// Executor threads (jobs running concurrently).
    pub workers: usize,
    /// Queue capacity; pushes beyond it are rejected with a retry hint.
    pub queue_capacity: usize,
    /// Episodes a preemptible job runs between preemption probes.
    pub chunk: usize,
}

impl ServeConfig {
    pub fn new(socket: impl Into<PathBuf>) -> ServeConfig {
        ServeConfig { socket: socket.into(), workers: 2, queue_capacity: 64, chunk: 8 }
    }
}

/// Tallies returned by [`Gateway::run`] after a drain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    /// Submit frames received (including cache hits and rejections).
    pub submitted: u64,
    /// Terminal result frames sent by executors (ok or error).
    pub completed: u64,
    /// Submissions rejected for backpressure or drain.
    pub rejected: u64,
    /// Results answered straight from the content-addressed cache.
    pub cache_hits: u64,
    /// Checkpoint-and-requeue events.
    pub preemptions: u64,
}

/// One queued execution: the spec plus its client reply channel and any
/// checkpoint carried over a preemption.
struct QueuedJob {
    id: String,
    key: String,
    spec: JobSpec,
    priority: u8,
    stream: bool,
    resume: Option<Box<MissionCheckpoint>>,
    preemptions: u64,
    reply: Sender<Response>,
}

/// The daemon. Shared (`Arc`) between the accept loop, connection threads,
/// and executors.
pub struct Gateway {
    cfg: ServeConfig,
    listener: UnixListener,
    queue: JobQueue<QueuedJob>,
    cache: ResultCache,
    draining: AtomicBool,
    in_flight: AtomicUsize,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    preemptions: AtomicU64,
    next_job: AtomicU64,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Gateway {
    /// Bind the socket (unlinking any stale file) and build the daemon.
    /// The socket is connectable as soon as this returns.
    pub fn new(cfg: ServeConfig) -> Result<Arc<Gateway>> {
        if cfg.socket.exists() {
            std::fs::remove_file(&cfg.socket)?;
        }
        let listener = UnixListener::bind(&cfg.socket).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("cannot bind {}: {e}", cfg.socket.display()),
            ))
        })?;
        listener.set_nonblocking(true)?;
        let queue = JobQueue::new(cfg.queue_capacity);
        Ok(Arc::new(Gateway {
            cfg,
            listener,
            queue,
            cache: ResultCache::new(),
            draining: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            next_job: AtomicU64::new(1),
            conns: Mutex::new(Vec::new()),
        }))
    }

    /// Begin draining: stop admitting, finish what's accepted, shut down.
    /// Safe from any thread; also triggered by SIGINT/SIGTERM.
    pub fn request_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst) || shutdown::requested()
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            cache_hits: self.cache.hits(),
            preemptions: self.preemptions.load(Ordering::Relaxed),
        }
    }

    /// Serve until drained. Blocks the calling thread; returns the final
    /// tallies once every accepted job has its terminal frame sent and the
    /// socket file is removed.
    pub fn run(self: Arc<Gateway>) -> Result<ServeStats> {
        let workers: Vec<_> = (0..self.cfg.workers.max(1))
            .map(|_| {
                let g = Arc::clone(&self);
                thread::spawn(move || {
                    while let Some(entry) = g.queue.pop() {
                        g.execute(entry);
                    }
                })
            })
            .collect();

        while !self.draining() {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let g = Arc::clone(&self);
                    let h = thread::spawn(move || g.handle_conn(stream));
                    self.conns.lock().unwrap().push(h);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    thread::sleep(ACCEPT_POLL);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }

        // Drain: admissions off, let executors empty the queue, then make
        // sure every connection thread has written its last frame.
        self.request_drain();
        self.queue.close();
        for w in workers {
            let _ = w.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for c in conns {
            let _ = c.join();
        }
        let _ = std::fs::remove_file(&self.cfg.socket);
        Ok(self.stats())
    }

    /// Executor body: run one queue entry to its next boundary.
    fn execute(&self, mut entry: QueuedJob) {
        metrics().serve_queue_depth.set(self.queue.len() as f64);
        // A twin job may have completed while this one sat queued.
        if entry.resume.is_none() {
            if let Some(hit) = self.cache.get(&entry.key) {
                self.finish(&entry, Ok(hit), true);
                return;
            }
        }

        self.in_flight.fetch_add(1, Ordering::SeqCst);
        metrics().serve_jobs_in_flight.set(self.in_flight.load(Ordering::SeqCst) as f64);

        let priority = entry.priority;
        let preempt = || {
            !self.draining()
                && priority < MAX_PRIORITY
                && self.queue.has_higher_priority_than(priority)
        };
        // Sender is !Sync; the Mutex wrapper makes the closure Sync as
        // `run_with_progress` requires. Send failures mean the client hung
        // up — the job still runs to completion for the cache.
        let tx = Mutex::new(entry.reply.clone());
        let id = entry.id.clone();
        let stream_on = entry.stream;
        let progress = move |p: RoverProgress| {
            if stream_on && (p.is_final() || p.episode % PROGRESS_EVERY == 0) {
                let _ = tx
                    .lock()
                    .unwrap()
                    .send(Response::Progress { job_id: id.clone(), sample: p });
            }
        };

        let outcome = entry.spec.run_step(
            entry.resume.take().map(|b| *b),
            &preempt,
            self.cfg.chunk,
            &progress,
        );

        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        metrics().serve_jobs_in_flight.set(self.in_flight.load(Ordering::SeqCst) as f64);

        match outcome {
            Ok(JobStep::Done(doc)) => {
                let value = CachedResult {
                    report_id: entry.spec.report_id().to_string(),
                    report_sha256: report_sha256(&doc),
                    report: doc,
                };
                self.cache.insert(entry.key.clone(), value.clone());
                self.finish(&entry, Ok(value), false);
            }
            Ok(JobStep::Preempted(ckpt)) => {
                entry.resume = Some(ckpt);
                entry.preemptions += 1;
                self.preemptions.fetch_add(1, Ordering::Relaxed);
                metrics().serve_preemptions.inc();
                self.queue.requeue(entry.priority, entry);
            }
            Err(e) => self.finish(&entry, Err(e), false),
        }
    }

    /// Send a job's terminal frame and count it.
    fn finish(&self, entry: &QueuedJob, outcome: Result<CachedResult>, cache_hit: bool) {
        let resp = match outcome {
            Ok(v) => Response::JobResult {
                job_id: entry.id.clone(),
                ok: true,
                cache_hit,
                preemptions: entry.preemptions,
                report_id: v.report_id,
                report_sha256: v.report_sha256,
                report: v.report,
                error: None,
            },
            Err(e) => Response::JobResult {
                job_id: entry.id.clone(),
                ok: false,
                cache_hit: false,
                preemptions: entry.preemptions,
                report_id: entry.spec.report_id().to_string(),
                report_sha256: String::new(),
                report: Json::Null,
                error: Some(e.to_string()),
            },
        };
        let _ = entry.reply.send(resp);
        self.completed.fetch_add(1, Ordering::Relaxed);
        metrics().serve_jobs_completed.inc();
    }

    /// Connection thread: read request frames until EOF or drain; answer
    /// each. A `submit` blocks this connection until its terminal frame.
    fn handle_conn(self: Arc<Gateway>, stream: UnixStream) {
        let _ = stream.set_read_timeout(Some(READ_POLL));
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let mut reader = FrameReader::new(stream);
        loop {
            let frame = match reader.read_frame(&|| !self.draining()) {
                Ok(Some(f)) => f,
                Ok(None) => break,
                Err(e) => {
                    let err = Response::ProtocolError { message: e.to_string() };
                    let _ = write_frame(&mut writer, &err.to_json());
                    break;
                }
            };
            let req = match Request::from_json(&frame) {
                Ok(r) => r,
                Err(e) => {
                    let err = Response::ProtocolError { message: e.to_string() };
                    if write_frame(&mut writer, &err.to_json()).is_err() {
                        break;
                    }
                    continue;
                }
            };
            let sent = match req {
                Request::Healthz => write_frame(&mut writer, &self.health().to_json()),
                Request::Metrics => {
                    let resp = Response::MetricsText {
                        prometheus: MetricsSnapshot::capture().to_prometheus(),
                    };
                    write_frame(&mut writer, &resp.to_json())
                }
                Request::Shutdown => {
                    self.request_drain();
                    write_frame(&mut writer, &self.health().to_json())
                }
                Request::Submit { job, priority, stream } => {
                    self.handle_submit(&mut writer, job, priority, stream)
                }
            };
            if sent.is_err() {
                break;
            }
        }
    }

    fn health(&self) -> Response {
        Response::Health {
            status: if self.draining() { "draining" } else { "ok" }.to_string(),
            queue_depth: self.queue.len(),
            in_flight: self.in_flight.load(Ordering::SeqCst),
            workers: self.cfg.workers.max(1),
            cache_entries: self.cache.len(),
            completed: self.completed.load(Ordering::Relaxed),
        }
    }

    /// Admit one submission and relay its frames back to the client.
    fn handle_submit(
        &self,
        writer: &mut UnixStream,
        job: JobSpec,
        priority: u8,
        stream: bool,
    ) -> std::io::Result<()> {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        metrics().serve_jobs_submitted.inc();
        let key = job.key();
        let id = format!("job-{:06}", self.next_job.fetch_add(1, Ordering::Relaxed));

        // Cache check at admission: an identical completed job answers
        // instantly, bypassing the queue entirely.
        if let Some(hit) = self.cache.get(&key) {
            let resp = Response::JobResult {
                job_id: id,
                ok: true,
                cache_hit: true,
                preemptions: 0,
                report_id: hit.report_id,
                report_sha256: hit.report_sha256,
                report: hit.report,
                error: None,
            };
            return write_frame(writer, &resp.to_json());
        }

        if self.draining() {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            metrics().serve_jobs_rejected.inc();
            let resp = Response::Rejected { reason: "draining".into(), retry_after_ms: 500 };
            return write_frame(writer, &resp.to_json());
        }

        let (reply, frames) = mpsc::channel();
        let entry = QueuedJob {
            id: id.clone(),
            key: key.clone(),
            spec: job,
            priority,
            stream,
            resume: None,
            preemptions: 0,
            reply,
        };
        match self.queue.push(priority, entry) {
            Ok(depth) => {
                metrics().serve_queue_depth.set(depth as f64);
                let resp = Response::Accepted { job_id: id, spec_sha256: key, queue_depth: depth };
                write_frame(writer, &resp.to_json())?;
                // Relay progress until the terminal result frame. recv()
                // always terminates: requeue bypasses close, so executors
                // drain every accepted entry even mid-shutdown.
                for resp in frames {
                    let terminal = matches!(resp, Response::JobResult { .. });
                    write_frame(writer, &resp.to_json())?;
                    if terminal {
                        break;
                    }
                }
                Ok(())
            }
            Err(full) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                metrics().serve_jobs_rejected.inc();
                let resp = Response::Rejected {
                    reason: format!("queue full ({} queued)", full.depth),
                    retry_after_ms: 100 + 25 * full.depth as u64,
                };
                write_frame(writer, &resp.to_json())
            }
        }
    }
}

/// A gateway running on its own thread — the embedded form used by tests
/// and `qfpga loadgen`'s self-hosted mode.
pub struct GatewayHandle {
    gateway: Arc<Gateway>,
    thread: JoinHandle<Result<ServeStats>>,
}

impl GatewayHandle {
    /// Bind and start serving. The socket accepts connections as soon as
    /// this returns.
    pub fn spawn(cfg: ServeConfig) -> Result<GatewayHandle> {
        let gateway = Gateway::new(cfg)?;
        let g = Arc::clone(&gateway);
        let thread = thread::spawn(move || g.run());
        Ok(GatewayHandle { gateway, thread })
    }

    pub fn socket(&self) -> PathBuf {
        self.gateway.cfg.socket.clone()
    }

    /// Ask the daemon to drain (returns immediately).
    pub fn drain(&self) {
        self.gateway.request_drain();
    }

    /// Live tallies (final ones come from [`GatewayHandle::join`]).
    pub fn stats(&self) -> ServeStats {
        self.gateway.stats()
    }

    /// Wait for the drain to finish and return the final tallies.
    pub fn join(self) -> Result<ServeStats> {
        self.thread
            .join()
            .map_err(|_| Error::Io(std::io::Error::other("gateway thread panicked")))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn temp_socket(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("qfpga-daemon-{tag}-{}.sock", std::process::id()))
    }

    /// The daemon polls the process-global drain flag; hold the shared
    /// guard so the shutdown module's tests can't flip it mid-test.
    fn flag_guard() -> std::sync::MutexGuard<'static, ()> {
        let g = shutdown::TEST_FLAG_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        shutdown::reset();
        g
    }

    fn roundtrip(stream: &mut UnixStream, req: &Request) -> Response {
        write_frame(stream, &req.to_json()).unwrap();
        let mut reader = FrameReader::new(stream.try_clone().unwrap());
        let frame = reader.read_frame(&|| true).unwrap().unwrap();
        Response::from_json(&frame).unwrap()
    }

    #[test]
    fn healthz_then_drain_returns_stats() {
        let _guard = flag_guard();
        let cfg = ServeConfig::new(temp_socket("health"));
        let handle = GatewayHandle::spawn(cfg).unwrap();
        let mut conn = UnixStream::connect(handle.socket()).unwrap();
        match roundtrip(&mut conn, &Request::Healthz) {
            Response::Health { status, workers, completed, .. } => {
                assert_eq!(status, "ok");
                assert_eq!(workers, 2);
                assert_eq!(completed, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        handle.drain();
        let stats = handle.join().unwrap();
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.submitted, 0);
    }

    #[test]
    fn shutdown_frame_drains_the_daemon() {
        let _guard = flag_guard();
        let cfg = ServeConfig::new(temp_socket("shutdown"));
        let handle = GatewayHandle::spawn(cfg).unwrap();
        let mut conn = UnixStream::connect(handle.socket()).unwrap();
        match roundtrip(&mut conn, &Request::Shutdown) {
            Response::Health { status, .. } => assert_eq!(status, "draining"),
            other => panic!("unexpected {other:?}"),
        }
        let stats = handle.join().unwrap();
        assert_eq!(stats.submitted, 0);
    }

    #[test]
    fn garbage_frames_get_a_protocol_error() {
        let _guard = flag_guard();
        let cfg = ServeConfig::new(temp_socket("garbage"));
        let handle = GatewayHandle::spawn(cfg).unwrap();
        let mut conn = UnixStream::connect(handle.socket()).unwrap();
        conn.write_all(b"{\"type\":\"warp-drive\"}\n").unwrap();
        conn.flush().unwrap();
        let mut reader = FrameReader::new(conn.try_clone().unwrap());
        let frame = reader.read_frame(&|| true).unwrap().unwrap();
        match Response::from_json(&frame).unwrap() {
            Response::ProtocolError { message } => {
                assert!(message.contains("warp-drive"), "{message}");
            }
            other => panic!("unexpected {other:?}"),
        }
        handle.drain();
        handle.join().unwrap();
    }
}
