//! `qfpga serve` — the mission gateway daemon.
//!
//! A ground-segment (or rover-side) job server: clients submit the same
//! replayable run specs that [`crate::obs::manifest::RunManifest`] records
//! (train / fleet / mission), the daemon executes them on a bounded
//! priority queue with worker threads, streams per-episode
//! [`crate::coordinator::RoverProgress`] telemetry, answers repeats from a
//! content-addressed result cache, and drains gracefully on SIGTERM.
//!
//! # Wire protocol
//!
//! Newline-delimited JSON over a unix socket: each frame is one canonical
//! JSON object (sorted keys — exactly what [`crate::util::Json`] prints)
//! terminated by `\n`. Requests carry a `type` tag:
//!
//! | request | fields | reply |
//! |---|---|---|
//! | `submit` | `job`, `priority` (0–9, default 1), `stream` (bool) | `accepted` → `progress`* → `result`, or `rejected`, or immediate `result` on a cache hit |
//! | `healthz` | — | `health` |
//! | `metrics` | — | `metrics` (Prometheus text) |
//! | `shutdown` | — | `health` (status `draining`), then the daemon drains |
//!
//! The `job` object is `{"kind": "train"|"fleet"|"mission", "spec": ...}`
//! where `spec` is byte-identical to the manifest spec `qfpga replay`
//! re-runs — see [`job::JobSpec`]. Response frames:
//!
//! * `accepted` — `job_id`, `spec_sha256` (the cache key), `queue_depth`.
//! * `rejected` — `reason`, `retry_after_ms` (backpressure hint; grows
//!   with queue depth).
//! * `progress` — `job_id` plus the flat [`crate::coordinator::RoverProgress`]
//!   fields, throttled to every 5th episode plus the final one.
//! * `result` — `job_id`, `ok`, `cache_hit`, `preemptions`, `report_id`,
//!   `report_sha256` (deterministic projection hash), `report` (the full
//!   document), `error` (only when `ok` is false).
//! * `health` — `status` (`ok`/`draining`), `queue_depth`, `in_flight`,
//!   `workers`, `cache_entries`, `completed`.
//! * `error` — protocol-level failure (unparseable or unknown frame).
//!
//! # Guarantees
//!
//! * **Determinism**: a job's report depends only on its spec bytes (the
//!   PR 7 replay property), so the cache may answer any resubmission with
//!   the recorded document — bit-identical, `cache_hit: true`.
//! * **Preemption without loss**: a fault-free train job yields its worker
//!   to a strictly higher-priority submission at an episode-chunk
//!   boundary via [`crate::coordinator::MissionCheckpoint`]; the resumed
//!   run's report hashes identically to an uninterrupted one.
//! * **Drain**: SIGTERM/SIGINT (or a `shutdown` frame) stops admissions;
//!   every accepted job still runs to its terminal `result` frame before
//!   the daemon exits 0 and unlinks the socket.
//!
//! # Example
//!
//! ```
//! use qfpga::coordinator::MissionConfig;
//! use qfpga::serve::{Client, GatewayHandle, JobSpec, ServeConfig};
//!
//! let socket = std::env::temp_dir().join(format!("qfpga-doc-{}.sock", std::process::id()));
//! let gateway = GatewayHandle::spawn(ServeConfig::new(&socket)).unwrap();
//!
//! let mut client = Client::connect(&gateway.socket()).unwrap();
//! let job = JobSpec::Train(MissionConfig { episodes: 2, max_steps: 8, ..Default::default() });
//! let first = client.submit_and_wait(&job, 1, false, &mut |_| {}).unwrap();
//! assert!(first.ok && !first.cache_hit);
//!
//! // identical spec → answered from the cache, bit-identical report
//! let again = client.submit_and_wait(&job, 1, false, &mut |_| {}).unwrap();
//! assert!(again.cache_hit);
//! assert_eq!(again.report.to_string(), first.report.to_string());
//!
//! gateway.drain();
//! let stats = gateway.join().unwrap();
//! assert_eq!(stats.cache_hits, 1);
//! ```

pub mod cache;
pub mod daemon;
pub mod job;
pub mod loadgen;
pub mod protocol;
pub mod queue;

pub use cache::{CachedResult, ResultCache};
pub use daemon::{Gateway, GatewayHandle, ServeConfig, ServeStats};
pub use job::{JobSpec, JobStep};
pub use loadgen::{job_mix, run_loadgen, Client, JobOutcome, LoadgenOutcome, LoadgenSpec};
pub use protocol::{Request, Response};
pub use queue::{JobQueue, QueueFull};
