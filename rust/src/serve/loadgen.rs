//! Gateway load generator + table G1.
//!
//! `qfpga loadgen` drives a gateway with a deterministic train/fleet/
//! mission job mix in two phases — unique jobs first, then exact
//! duplicates — so the cache-hit count is a *deterministic* column on a
//! fresh daemon: `floor(jobs/2)` duplicates, every one a hit. Latency
//! percentiles and sustained throughput are host-measured and tagged
//! [`crate::report::TableRow::measured`], exactly like table B2's timing
//! rows.
//!
//! Two modes:
//! * **embedded** (no `--socket`): spawns an in-process
//!   [`super::daemon::GatewayHandle`] per requested worker width — the
//!   self-contained benchmark that produces G1's width sweep.
//! * **external** (`--socket PATH`): drives an already-running daemon —
//!   what the CI smoke job uses.

use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::EnvKind;
use crate::coordinator::mission::MissionConfig;
use crate::coordinator::ScenarioSpec;
use crate::error::{Error, Result};
use crate::report::PaperTable;
use crate::util::Json;

use super::daemon::{GatewayHandle, ServeConfig};
use super::job::JobSpec;
use super::protocol::{write_frame, FrameReader, Request, Response};

/// Give up after this many reject-retry rounds per job.
const RETRY_LIMIT: usize = 50;

/// Blocking NDJSON client for the gateway socket.
pub struct Client {
    writer: UnixStream,
    reader: FrameReader<UnixStream>,
}

/// Terminal outcome of one submission as seen by a client.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: String,
    pub ok: bool,
    pub cache_hit: bool,
    pub preemptions: u64,
    pub report_id: String,
    pub report_sha256: String,
    pub report: Json,
    pub error: Option<String>,
}

impl Client {
    pub fn connect(path: &Path) -> Result<Client> {
        let stream = UnixStream::connect(path).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("cannot connect to {}: {e}", path.display()),
            ))
        })?;
        Ok(Client { writer: stream.try_clone()?, reader: FrameReader::new(stream) })
    }

    fn send(&mut self, req: &Request) -> Result<()> {
        write_frame(&mut self.writer, &req.to_json())?;
        Ok(())
    }

    fn read_response(&mut self) -> Result<Response> {
        let frame = self
            .reader
            .read_frame(&|| true)?
            .ok_or_else(|| Error::interface("gateway closed the connection"))?;
        Response::from_json(&frame)
    }

    /// One request, one response (healthz / metrics / shutdown).
    pub fn request(&mut self, req: &Request) -> Result<Response> {
        self.send(req)?;
        self.read_response()
    }

    /// Submit a job and block until its terminal frame, retrying on
    /// backpressure rejections after the daemon's hinted delay. Progress
    /// frames (if `stream`) are passed to `on_progress`.
    pub fn submit_and_wait(
        &mut self,
        job: &JobSpec,
        priority: u8,
        stream: bool,
        on_progress: &mut dyn FnMut(&Response),
    ) -> Result<JobOutcome> {
        for _ in 0..RETRY_LIMIT {
            self.send(&Request::Submit { job: job.clone(), priority, stream })?;
            match self.read_response()? {
                Response::Rejected { retry_after_ms, .. } => {
                    std::thread::sleep(Duration::from_millis(retry_after_ms.min(2_000)));
                }
                Response::Accepted { .. } => loop {
                    match self.read_response()? {
                        p @ Response::Progress { .. } => on_progress(&p),
                        Response::JobResult {
                            job_id,
                            ok,
                            cache_hit,
                            preemptions,
                            report_id,
                            report_sha256,
                            report,
                            error,
                        } => {
                            return Ok(JobOutcome {
                                job_id,
                                ok,
                                cache_hit,
                                preemptions,
                                report_id,
                                report_sha256,
                                report,
                                error,
                            })
                        }
                        other => {
                            return Err(Error::interface(format!(
                                "unexpected frame while waiting for result: {}",
                                other.to_json()
                            )))
                        }
                    }
                },
                // answered straight from the cache, no queue round-trip
                Response::JobResult {
                    job_id,
                    ok,
                    cache_hit,
                    preemptions,
                    report_id,
                    report_sha256,
                    report,
                    error,
                } => {
                    return Ok(JobOutcome {
                        job_id,
                        ok,
                        cache_hit,
                        preemptions,
                        report_id,
                        report_sha256,
                        report,
                        error,
                    })
                }
                other => {
                    return Err(Error::interface(format!(
                        "unexpected submit reply: {}",
                        other.to_json()
                    )))
                }
            }
        }
        Err(Error::Config(format!(
            "job rejected {RETRY_LIMIT} times — daemon saturated or draining"
        )))
    }

    /// Fetch the daemon's Prometheus metrics text.
    pub fn metrics_text(&mut self) -> Result<String> {
        match self.request(&Request::Metrics)? {
            Response::MetricsText { prometheus } => Ok(prometheus),
            other => Err(Error::interface(format!("unexpected reply: {}", other.to_json()))),
        }
    }

    /// Ask the daemon to drain (the `shutdown` protocol verb).
    pub fn shutdown_daemon(&mut self) -> Result<()> {
        match self.request(&Request::Shutdown)? {
            Response::Health { .. } => Ok(()),
            other => Err(Error::interface(format!("unexpected reply: {}", other.to_json()))),
        }
    }
}

/// Loadgen parameters (`qfpga loadgen --help`).
#[derive(Debug, Clone)]
pub struct LoadgenSpec {
    /// Drive this running daemon; `None` = embedded width sweep.
    pub socket: Option<PathBuf>,
    /// Total submissions: `ceil(jobs/2)` unique + `floor(jobs/2)` dupes.
    pub jobs: usize,
    /// Concurrent client connections.
    pub concurrency: usize,
    /// Worker widths for the embedded sweep (ignored with `--socket`).
    pub widths: Vec<usize>,
    /// Episodes per train/fleet/mission job in the mix.
    pub episodes: usize,
    pub max_steps: usize,
    /// Base seed; job `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for LoadgenSpec {
    fn default() -> Self {
        LoadgenSpec {
            socket: None,
            jobs: 12,
            concurrency: 3,
            widths: vec![1, 2, 4],
            episodes: 3,
            max_steps: 15,
            seed: 7,
        }
    }
}

/// What a loadgen run produced: the G1 table plus the raw tallies the CI
/// smoke job asserts on.
pub struct LoadgenOutcome {
    pub table: PaperTable,
    /// Cache hits observed per pass (one entry per embedded width, or a
    /// single entry in external mode) — deterministic on a fresh daemon.
    pub hits_per_pass: Vec<u64>,
    /// Daemon-side Prometheus text (external mode only).
    pub prometheus: Option<String>,
}

/// The deterministic job mix: `unique` distinct specs cycling
/// train, train, train, fleet(×2 rovers), mission(crater), with seeds
/// `seed + i`. Two mix calls with equal arguments are bit-identical —
/// that's what makes resubmission a guaranteed cache hit.
pub fn job_mix(unique: usize, episodes: usize, max_steps: usize, seed: u64) -> Vec<JobSpec> {
    (0..unique)
        .map(|i| {
            let cfg = MissionConfig {
                episodes,
                max_steps,
                seed: seed + i as u64,
                ..Default::default()
            };
            match i % 5 {
                4 => JobSpec::Mission(ScenarioSpec {
                    envs: vec![EnvKind::Crater],
                    episodes,
                    max_steps,
                    seed: seed + i as u64,
                    ..Default::default()
                }),
                3 => JobSpec::Fleet { cfg, rovers: 2, share: None },
                _ => JobSpec::Train(cfg),
            }
        })
        .collect()
}

struct PassStats {
    latencies_ms: Vec<f64>,
    wall_seconds: f64,
    cache_hits: u64,
}

/// Push `jobs` through the gateway on `concurrency` connections; collect
/// per-job latency and the observed hit count.
fn run_pass(socket: &Path, jobs: &[JobSpec], concurrency: usize) -> Result<PassStats> {
    let next = AtomicUsize::new(0);
    let hits = AtomicU64::new(0);
    let latencies = Mutex::new(Vec::with_capacity(jobs.len()));
    let failures: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let start = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..concurrency.max(1) {
            s.spawn(|| {
                let mut client = match Client::connect(socket) {
                    Ok(c) => c,
                    Err(e) => {
                        failures.lock().unwrap().push(e.to_string());
                        return;
                    }
                };
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= jobs.len() {
                        break;
                    }
                    let t0 = Instant::now();
                    match client.submit_and_wait(&jobs[i], 1, false, &mut |_| {}) {
                        Ok(out) if out.ok => {
                            if out.cache_hit {
                                hits.fetch_add(1, Ordering::Relaxed);
                            }
                            let ms = t0.elapsed().as_secs_f64() * 1e3;
                            latencies.lock().unwrap().push(ms);
                        }
                        Ok(out) => failures.lock().unwrap().push(format!(
                            "{} failed: {}",
                            out.job_id,
                            out.error.unwrap_or_default()
                        )),
                        Err(e) => failures.lock().unwrap().push(e.to_string()),
                    }
                }
            });
        }
    });
    let failures = failures.into_inner().unwrap();
    if let Some(first) = failures.first() {
        return Err(Error::Config(format!(
            "{} of {} jobs failed; first: {first}",
            failures.len(),
            jobs.len()
        )));
    }
    Ok(PassStats {
        latencies_ms: latencies.into_inner().unwrap(),
        wall_seconds: start.elapsed().as_secs_f64(),
        cache_hits: hits.load(Ordering::Relaxed),
    })
}

/// Nearest-rank percentile (p in 0..=100) of an unsorted sample.
fn percentile(sample: &[f64], p: f64) -> f64 {
    if sample.is_empty() {
        return 0.0;
    }
    let mut sorted = sample.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Drive one daemon through both phases and append its six G1 rows.
fn measure_pass(
    table: PaperTable,
    prefix: &str,
    socket: &Path,
    spec: &LoadgenSpec,
) -> Result<(PaperTable, u64)> {
    let unique = job_mix(spec.jobs.div_ceil(2).max(1), spec.episodes, spec.max_steps, spec.seed);
    let dupes: Vec<JobSpec> = unique.iter().take(spec.jobs / 2).cloned().collect();

    // phase 1: unique jobs; the scope join is the phase barrier, so every
    // phase-2 duplicate finds its twin already cached on a fresh daemon
    let first = run_pass(socket, &unique, spec.concurrency)?;
    let second = if dupes.is_empty() {
        PassStats { latencies_ms: Vec::new(), wall_seconds: 0.0, cache_hits: 0 }
    } else {
        run_pass(socket, &dupes, spec.concurrency)?
    };

    let completed = (first.latencies_ms.len() + second.latencies_ms.len()) as f64;
    let hits = first.cache_hits + second.cache_hits;
    let all_ms: Vec<f64> = first
        .latencies_ms
        .iter()
        .chain(&second.latencies_ms)
        .copied()
        .collect();
    let wall = first.wall_seconds + second.wall_seconds;
    let table = table
        .row(format!("{prefix} jobs completed"), completed, None)
        .row(format!("{prefix} cache hits"), hits as f64, None)
        .row(format!("{prefix} cache hit rate"), hits as f64 / completed.max(1.0), None)
        .measured_row(format!("{prefix} p50 job latency (ms)"), percentile(&all_ms, 50.0), None)
        .measured_row(format!("{prefix} p99 job latency (ms)"), percentile(&all_ms, 99.0), None)
        .measured_row(format!("{prefix} sustained jobs/s"), completed / wall.max(1e-9), None);
    Ok((table, hits))
}

/// Embedded temp sockets must be unique per pass even within one process.
static PASS_COUNTER: AtomicU64 = AtomicU64::new(0);

fn temp_socket() -> PathBuf {
    let n = PASS_COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("qfpga-loadgen-{}-{n}.sock", std::process::id()))
}

/// Run the load test and build table G1.
pub fn run_loadgen(spec: &LoadgenSpec) -> Result<LoadgenOutcome> {
    if spec.jobs == 0 {
        return Err(Error::Config("loadgen needs --jobs >= 1".into()));
    }
    let mut table = PaperTable::new(
        "G1",
        format!(
            "Gateway load test ({} jobs = {} unique + {} duplicate, concurrency {}, \
             train/fleet/mission mix, {} episodes x {} steps)",
            spec.jobs,
            spec.jobs.div_ceil(2),
            spec.jobs / 2,
            spec.concurrency,
            spec.episodes,
            spec.max_steps
        ),
        "mixed",
    );
    let mut hits_per_pass = Vec::new();
    let mut prometheus = None;

    match &spec.socket {
        Some(path) => {
            let (t, hits) = measure_pass(table, "external", path, spec)?;
            table = t;
            hits_per_pass.push(hits);
            prometheus = Some(Client::connect(path)?.metrics_text()?);
        }
        None => {
            for &w in &spec.widths {
                let mut cfg = ServeConfig::new(temp_socket());
                cfg.workers = w.max(1);
                // headroom so the benchmark measures latency, not rejects
                cfg.queue_capacity = spec.jobs + 4;
                let handle = GatewayHandle::spawn(cfg)?;
                let socket = handle.socket();
                let measured = measure_pass(table, &format!("W={w}"), &socket, spec);
                handle.drain();
                let stats = handle.join()?;
                let (t, hits) = measured?;
                debug_assert_eq!(stats.cache_hits, hits);
                table = t;
                hits_per_pass.push(hits);
            }
        }
    }

    table = table.note(
        "completed/hits/hit-rate columns are deterministic on a fresh daemon \
         (duplicates always hit the content-addressed cache); latency and jobs/s \
         rows are measured on this host. Regenerate: qfpga loadgen --jobs N \
         --concurrency C [--socket PATH | --widths 1,2,4] --json g1.json",
    );
    Ok(LoadgenOutcome { table, hits_per_pass, prometheus })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 50.0), 20.0);
        assert_eq!(percentile(&xs, 99.0), 40.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn job_mix_is_deterministic_and_mixed() {
        let a = job_mix(6, 3, 10, 7);
        let b = job_mix(6, 3, 10, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.key(), y.key());
        }
        let kinds: Vec<&str> = a.iter().map(|j| j.subcommand()).collect();
        assert_eq!(kinds, ["train", "train", "train", "fleet", "mission", "train"]);
        // seeds make every job a distinct content address
        let mut keys: Vec<String> = a.iter().map(|j| j.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 6);
    }

    #[test]
    fn embedded_sweep_hits_are_deterministic() {
        let _guard = crate::util::shutdown::TEST_FLAG_GUARD
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        crate::util::shutdown::reset();
        let spec = LoadgenSpec {
            jobs: 4,
            concurrency: 2,
            widths: vec![1, 2],
            episodes: 2,
            max_steps: 8,
            ..Default::default()
        };
        let out = run_loadgen(&spec).unwrap();
        // floor(4/2) duplicates hit on each fresh daemon
        assert_eq!(out.hits_per_pass, vec![2, 2]);
        let doc = out.table.to_json();
        let rows = doc.req_arr("rows").unwrap();
        assert_eq!(rows.len(), 12, "6 rows per width");
        assert_eq!(rows[2].req_str("label").unwrap(), "W=1 cache hit rate");
        assert_eq!(rows[2].req_f64("ours").unwrap(), 0.5);
        assert!(rows[3].get("measured").is_some(), "latency rows are tagged");
        assert!(rows[0].get("measured").is_none(), "count rows are not");
    }
}
