//! Bounded priority job queue with backpressure.
//!
//! The gateway's admission point: `push` is bounded (callers get a
//! [`QueueFull`] to turn into a reject-with-retry-after frame), `pop`
//! blocks until work or close, and `requeue` re-admits a preempted job
//! *above* the capacity bound and the closed flag — an accepted job must
//! never be lost to its own preemption or to a drain race.
//!
//! Ordering is strict: higher priority first, FIFO (submission sequence)
//! within a priority class. Because every worker pulls from this one
//! ordered queue, a given submission order reaches the executors in a
//! deterministic order at any worker width — the queue is what makes the
//! gateway's determinism test (same jobs, any `--workers`) hold.

use std::collections::BinaryHeap;
use std::sync::{Condvar, Mutex};

/// Push rejection: the queue is at capacity (or closed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull {
    /// Depth observed at rejection time (feeds the retry-after hint).
    pub depth: usize,
}

/// One queued entry: priority class, admission sequence, payload.
struct Entry<T> {
    priority: u8,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap: higher priority wins; within a class, *smaller* seq
        // (earlier admission) must surface first, so compare reversed
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct Inner<T> {
    heap: BinaryHeap<Entry<T>>,
    next_seq: u64,
    closed: bool,
}

/// Bounded, closable, priority-ordered MPMC queue (mutex + condvar — the
/// queue guards milliseconds-long jobs, not nanosecond ops).
pub struct JobQueue<T> {
    inner: Mutex<Inner<T>>,
    available: Condvar,
    capacity: usize,
}

impl<T> JobQueue<T> {
    pub fn new(capacity: usize) -> JobQueue<T> {
        JobQueue {
            inner: Mutex::new(Inner {
                heap: BinaryHeap::new(),
                next_seq: 0,
                closed: false,
            }),
            available: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Admit a job. Returns the resulting depth, or [`QueueFull`] when at
    /// capacity or closed (a draining gateway admits nothing new).
    pub fn push(&self, priority: u8, item: T) -> Result<usize, QueueFull> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.heap.len() >= self.capacity {
            return Err(QueueFull { depth: inner.heap.len() });
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.heap.push(Entry { priority, seq, item });
        let depth = inner.heap.len();
        drop(inner);
        self.available.notify_one();
        Ok(depth)
    }

    /// Re-admit a preempted job, bypassing capacity *and* the closed flag:
    /// the job was already accepted once and its client is waiting — it
    /// must drain, never drop. Keeps the original admission order within
    /// its class (pass the entry's original `seq` via `push` semantics is
    /// not needed: a preempted job resumes at the same priority and a
    /// fresh seq, i.e. behind peers admitted meanwhile — documented
    /// fairness, not starvation).
    pub fn requeue(&self, priority: u8, item: T) {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.heap.push(Entry { priority, seq, item });
        drop(inner);
        self.available.notify_one();
    }

    /// Block until an entry is available (highest priority, FIFO within
    /// the class) or the queue is closed *and* empty (→ `None`).
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(e) = inner.heap.pop() {
                return Some(e.item);
            }
            if inner.closed {
                return None;
            }
            inner = self.available.wait(inner).unwrap();
        }
    }

    /// Stop admitting; wake every blocked `pop` so workers can drain the
    /// remainder and exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Is any queued entry strictly higher-priority than `p`? The
    /// pull-based preemption probe: a running preemptible job checks this
    /// between episode chunks and yields its worker when true.
    pub fn has_higher_priority_than(&self, p: u8) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.heap.peek().is_some_and(|e| e.priority > p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_then_fifo_order() {
        let q = JobQueue::new(16);
        q.push(1, "a").unwrap();
        q.push(1, "b").unwrap();
        q.push(5, "urgent").unwrap();
        q.push(1, "c").unwrap();
        q.close();
        let order: Vec<&str> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, ["urgent", "a", "b", "c"]);
    }

    #[test]
    fn capacity_rejects_with_depth() {
        let q = JobQueue::new(2);
        q.push(0, 1).unwrap();
        q.push(0, 2).unwrap();
        assert_eq!(q.push(0, 3), Err(QueueFull { depth: 2 }));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_returns_none() {
        let q = JobQueue::new(4);
        q.push(0, "x").unwrap();
        q.close();
        assert!(q.push(0, "y").is_err());
        assert_eq!(q.pop(), Some("x"));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn requeue_bypasses_capacity_and_close() {
        let q = JobQueue::new(1);
        q.push(0, "full").unwrap();
        q.requeue(9, "preempted");
        assert_eq!(q.len(), 2);
        q.close();
        q.requeue(0, "late-preempt");
        assert_eq!(q.pop(), Some("preempted"));
        assert_eq!(q.pop(), Some("full"));
        assert_eq!(q.pop(), Some("late-preempt"));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn higher_priority_probe() {
        let q = JobQueue::new(4);
        assert!(!q.has_higher_priority_than(0));
        q.push(1, "low").unwrap();
        assert!(!q.has_higher_priority_than(1));
        assert!(q.has_higher_priority_than(0));
        q.push(7, "high").unwrap();
        assert!(q.has_higher_priority_than(1));
        assert!(!q.has_higher_priority_than(7));
    }

    #[test]
    fn pop_blocks_until_push() {
        use std::sync::Arc;
        let q = Arc::new(JobQueue::new(4));
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(0, 42).unwrap();
        assert_eq!(t.join().unwrap(), Some(42));
    }
}
