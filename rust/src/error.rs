//! Crate-wide error type.

/// Convenience alias used across the crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors surfaced by the qfpga library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Failure inside the XLA/PJRT runtime (compile, execute, transfer).
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Artifact directory / manifest problems.
    #[error("artifact: {0}")]
    Artifact(String),

    /// Mismatch between an artifact's declared interface and what the
    /// caller supplied (wrong shape, arity, dtype, ...).
    #[error("interface mismatch: {0}")]
    Interface(String),

    /// Invalid experiment or system configuration.
    #[error("config: {0}")]
    Config(String),

    /// Environment misuse (invalid action id, step after terminal, ...).
    #[error("environment: {0}")]
    Env(String),

    /// FPGA model inconsistency (e.g. design does not fit the device).
    #[error("fpga model: {0}")]
    Fpga(String),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Helper for interface errors.
    pub fn interface(msg: impl Into<String>) -> Self {
        Error::Interface(msg.into())
    }
}
