//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the offline flight
//! image carries no proc-macro dependencies (see `util` module docs for the
//! zero-dependency rationale).

use std::fmt;

/// Convenience alias used across the crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors surfaced by the qfpga library.
#[derive(Debug)]
pub enum Error {
    /// Failure inside the XLA/PJRT runtime (compile, execute, transfer).
    Xla(String),

    /// Artifact directory / manifest problems.
    Artifact(String),

    /// Mismatch between an artifact's declared interface and what the
    /// caller supplied (wrong shape, arity, dtype, ...).
    Interface(String),

    /// Invalid experiment or system configuration.
    Config(String),

    /// Environment misuse (invalid action id, step after terminal, ...).
    Env(String),

    /// FPGA model inconsistency (e.g. design does not fit the device).
    Fpga(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla runtime: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Interface(m) => write!(f, "interface mismatch: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Env(m) => write!(f, "environment: {m}"),
            Error::Fpga(m) => write!(f, "fpga model: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Helper for interface errors.
    pub fn interface(msg: impl Into<String>) -> Self {
        Error::Interface(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(Error::Config("x".into()).to_string(), "config: x");
        assert_eq!(Error::interface("y").to_string(), "interface mismatch: y");
        let io: Error = std::io::Error::other("gone").into();
        assert!(io.to_string().starts_with("io: "));
    }

    #[test]
    fn io_source_is_preserved() {
        use std::error::Error as _;
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "f").into();
        assert!(e.source().is_some());
        assert!(Error::Config("x".into()).source().is_none());
    }
}
