//! Control FSM: the phase sequence of Fig. 6 (perceptron) / Fig. 8 (MLP).
//!
//! The datapath simulator executes this schedule; tests assert the phase
//! order and per-phase cycle charges stay consistent with [`TimingModel`].

use crate::config::{NetConfig, Precision};

use super::timing::TimingModel;

/// FSM phases of one Q-update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Feed-forward sweep over the current state's actions (fills FIFO 1).
    FeedForwardCurrent,
    /// Feed-forward sweep over the next state's actions (fills FIFO 2).
    FeedForwardNext,
    /// FIFO drain + max scan + Eq. 8.
    ErrorCapture,
    /// δ/ΔW generation and weight write-back (Eq. 7, 9–14).
    Backprop,
    /// Update complete, weights committed.
    Idle,
}

/// One scheduled phase with its cycle charge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledPhase {
    pub phase: Phase,
    pub cycles: u64,
}

/// The full Q-update schedule for a configuration.
pub fn qupdate_schedule(
    timing: &TimingModel,
    cfg: &NetConfig,
    prec: Precision,
) -> Vec<ScheduledPhase> {
    let b = timing.qupdate(cfg, prec);
    vec![
        ScheduledPhase { phase: Phase::FeedForwardCurrent, cycles: b.ff_current },
        ScheduledPhase { phase: Phase::FeedForwardNext, cycles: b.ff_next },
        ScheduledPhase { phase: Phase::ErrorCapture, cycles: b.error_capture },
        ScheduledPhase { phase: Phase::Backprop, cycles: b.backprop },
        ScheduledPhase { phase: Phase::Idle, cycles: 0 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};

    #[test]
    fn phase_order_is_the_papers() {
        let t = TimingModel::default();
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let sched = qupdate_schedule(&t, &cfg, Precision::Fixed);
        let phases: Vec<Phase> = sched.iter().map(|s| s.phase).collect();
        assert_eq!(
            phases,
            vec![
                Phase::FeedForwardCurrent,
                Phase::FeedForwardNext,
                Phase::ErrorCapture,
                Phase::Backprop,
                Phase::Idle
            ]
        );
    }

    #[test]
    fn schedule_cycles_match_breakdown() {
        let t = TimingModel::default();
        for arch in [Arch::Perceptron, Arch::Mlp] {
            for prec in [Precision::Fixed, Precision::Float] {
                let cfg = NetConfig::new(arch, EnvKind::Complex);
                let total: u64 = qupdate_schedule(&t, &cfg, prec)
                    .iter()
                    .map(|s| s.cycles)
                    .sum();
                assert_eq!(total, t.qupdate(&cfg, prec).total());
            }
        }
    }
}
