//! Target device: Xilinx Virtex-7 XC7VX485T (the MSL-heritage space-grade
//! Virtex family part the paper simulates).

/// Device capacity (XC7VX485T datasheet, DS180).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Virtex7 {
    /// 6-input LUTs.
    pub luts: u64,
    /// Flip-flops.
    pub ffs: u64,
    /// DSP48E1 slices.
    pub dsps: u64,
    /// 36 Kb block RAMs.
    pub bram36: u64,
    /// Design clock in Hz (the paper simulates at 150 MHz).
    pub clock_hz: f64,
}

impl Default for Virtex7 {
    fn default() -> Self {
        Virtex7 {
            luts: 303_600,
            ffs: 607_200,
            dsps: 2_800,
            bram36: 1_030,
            clock_hz: 150.0e6,
        }
    }
}

impl Virtex7 {
    /// Seconds per clock cycle.
    pub fn cycle_time_s(&self) -> f64 {
        1.0 / self.clock_hz
    }

    /// Convert a cycle count to microseconds.
    pub fn cycles_to_us(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz * 1e6
    }

    /// Q-updates per second for a per-update cycle count, in kQ/s
    /// (the paper's throughput unit).
    pub fn throughput_kq_s(&self, cycles_per_update: u64) -> f64 {
        self.clock_hz / cycles_per_update as f64 / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_constants() {
        let d = Virtex7::default();
        assert_eq!(d.dsps, 2800);
        assert_eq!(d.clock_hz, 150.0e6);
    }

    #[test]
    fn conversions() {
        let d = Virtex7::default();
        assert!((d.cycles_to_us(150) - 1.0).abs() < 1e-12);
        // paper: 64 cycles (A = 9, fixed perceptron) -> 2.34 MQ/s
        assert!((d.throughput_kq_s(64) - 2343.75).abs() < 0.01);
    }
}
