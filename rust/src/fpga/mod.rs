//! Cycle-accurate simulator of the paper's FPGA Q-learning accelerator.
//!
//! The paper evaluates its architecture with Xilinx tools on a Virtex-7
//! 485T; no RTL is published. This module rebuilds the accelerator from the
//! paper's block diagrams and equations, at the fidelity the paper's own
//! evaluation used (simulation):
//!
//! * [`device`] — Virtex-7 XC7VX485T capacity and the 150 MHz clock.
//! * [`units`] — functional-unit timing/resource models: 1-cycle pipelined
//!   DSP48 fixed MACs, multi-cycle LogiCORE-class FP cores, BRAM sigmoid
//!   ROMs, FIFO Q-buffers.
//! * [`timing`] — the structural cycle model of the control FSM (Fig. 6/8).
//!   For the fixed-point perceptron it reproduces the paper's stated law
//!   `cycles = 7A + 1` *exactly* (unit-tested), giving 2.34 MQ/s at A = 9
//!   and 0.53 MQ/s at A = 40 at 150 MHz — the Table 1 values.
//! * [`datapath`] — [`FpgaAccelerator`]: executes Q-updates **bit-accurately**
//!   (true integer Q(18,12) arithmetic in fixed mode via [`crate::fixed`],
//!   IEEE f32 in float mode) while charging cycles per the timing model.
//! * [`control`] — the FSM phase schedule (trace used by tests/debug).
//! * [`area`] — LUT/FF/DSP/BRAM counts vs device capacity.
//! * [`power`] — XPower-style power estimate (static + activity-weighted
//!   dynamic), calibrated against the paper's Tables 7–8 operating points.
//!
//! Fidelity note: fixed-mode numerics use a *wide integer accumulator*
//! (exact DSP48 semantics). The python/XLA fixed path fake-quantizes in
//! float32, which can differ by ~1 LSB on accumulations; cross-backend
//! tests budget a few LSB accordingly (see `tests/backend_equiv.rs`).

pub mod area;
pub mod control;
pub mod datapath;
pub mod device;
pub mod fifo;
pub mod power;
pub mod timing;
pub mod units;

pub use datapath::FpgaAccelerator;
pub use device::Virtex7;
pub use timing::{CycleBreakdown, TimingModel};
