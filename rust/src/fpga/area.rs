//! Resource (area) model: LUT/FF/DSP/BRAM counts for each accelerator
//! configuration, checked against the Virtex-7 485T capacity.
//!
//! Derivation (paper Fig. 4–10, "fine-grained parallelism"):
//!
//! * **Fixed feed-forward**: one DSP48 multiplier per weight of a layer
//!   stage (D per perceptron / per hidden neuron; H at the MLP output), a
//!   balanced adder tree (fan-in − 1 adders + bias), one sigmoid+derivative
//!   ROM pair per neuron.
//! * **Fixed backprop**: the δ and ΔW generators are "done using separate
//!   resources" (Section 4) — one multiplier per weight again, plus the
//!   update adders.
//! * **Float**: one LogiCORE MAC chain (mul + add) per layer for the
//!   perceptron, H parallel chains for the MLP hidden layer, one backprop
//!   chain, two auxiliary multipliers and a comparator. Area is dominated
//!   by the FP cores and nearly independent of D (the chains are serial).
//! * **Int8**: the fixed fine-grained structure with 8-bit MACs — same
//!   DSP count (a DSP48 multiply is one DSP at any width), thinner fabric.
//! * **Binary**: XNOR + popcount dot products in pure LUT fabric — zero
//!   DSPs; only the sigmoid ROMs and common plumbing remain.
//! * All: two Q-value FIFOs, control FSM per block (3 blocks).

use crate::config::{Arch, NetConfig, Precision};
use crate::error::{Error, Result};

use super::device::Virtex7;
use super::units::{cost, Resources};

/// The paper's fine-grained parallel structure — one multiplier per weight
/// plus the adder trees and ROMs — parameterized by the MAC unit costs so
/// the Fixed and Int8 arms share one derivation.
fn fine_grained(r: &mut Resources, cfg: &NetConfig, mul: Resources, add: Resources) {
    let d = cfg.d as u64;
    let h = cfg.h as u64;
    match cfg.arch {
        Arch::Perceptron => {
            // feed-forward: D multipliers, D adders (tree + bias), ROM
            r.add(mul.scaled(d));
            r.add(add.scaled(d));
            r.add(cost::SIGMOID_ROM);
            // backprop: δ (1 mul) + ΔW (D+1 mul) + update adders
            r.add(mul.scaled(d + 2));
            r.add(add.scaled(d + 1));
        }
        Arch::Mlp => {
            // hidden: H neurons × (D mul + D add + ROM)
            r.add(mul.scaled(d * h));
            r.add(add.scaled(d * h));
            r.add(cost::SIGMOID_ROM.scaled(h));
            // output: H mul + H add + ROM
            r.add(mul.scaled(h));
            r.add(add.scaled(h));
            r.add(cost::SIGMOID_ROM);
            // backprop: δ2 (1) + δ1 (2H) + ΔW2 (H+1) + ΔW1 (DH+H)
            r.add(mul.scaled(1 + 2 * h + h + 1 + d * h + h));
            r.add(add.scaled(d * h + 2 * h + 1));
        }
    }
}

/// Count the resources of one accelerator instance.
pub fn accelerator_resources(cfg: &NetConfig, prec: Precision) -> Resources {
    let d = cfg.d as u64;
    let h = cfg.h as u64;
    let mut r = Resources::default();

    match prec {
        Precision::Fixed => fine_grained(&mut r, cfg, cost::FX_MUL, cost::FX_ADD),
        Precision::Int8 => fine_grained(&mut r, cfg, cost::INT8_MUL, cost::INT8_ADD),
        Precision::Binary => {
            // one XNOR+popcount slice per weight for the forward sweeps,
            // one more per weight for the sign-flip write-back generators;
            // the sigmoid ROMs survive (activations stay LUT-indexed).
            let (fwd, bp, roms) = match cfg.arch {
                Arch::Perceptron => (d, d + 2, 1),
                Arch::Mlp => (d * h + h, 1 + 2 * h + h + 1 + d * h + h, h + 1),
            };
            r.add(cost::XNOR_POP.scaled(fwd + bp));
            r.add(cost::SIGMOID_ROM.scaled(roms));
        }
        Precision::Float => {
            let chains = match cfg.arch {
                Arch::Perceptron => 1 + 1, // forward chain + backprop chain
                Arch::Mlp => h + 1 + 1,    // hidden chains + output + backprop
            };
            r.add(cost::FP_MUL.scaled(chains));
            r.add(cost::FP_ADD.scaled(chains));
            // δ generators: two extra multipliers
            r.add(cost::FP_MUL.scaled(2));
            // error-capture comparator
            r.add(cost::FP_CMP);
            // ROMs (sigmoid + derivative), shared per layer
            let roms = match cfg.arch {
                Arch::Perceptron => 1,
                Arch::Mlp => 2,
            };
            r.add(cost::SIGMOID_ROM.scaled(roms));
        }
    }

    // common: two Q-FIFOs + control FSMs for the three blocks
    r.add(cost::FIFO.scaled(2));
    r.add(cost::CONTROL.scaled(3));
    r
}

/// Utilization of the target device, as fractions in [0, 1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Utilization {
    pub luts: f64,
    pub ffs: f64,
    pub dsps: f64,
    pub bram36: f64,
}

impl Utilization {
    pub fn max_fraction(&self) -> f64 {
        self.luts.max(self.ffs).max(self.dsps).max(self.bram36)
    }
}

/// Compute utilization and fail if the design does not fit.
pub fn check_fit(cfg: &NetConfig, prec: Precision, dev: &Virtex7) -> Result<Utilization> {
    check_fit_with(cfg, prec, dev, &Resources::default())
}

/// Resources of one accelerator instance plus additional hardening
/// hardware (TMR replicas, SECDED codecs, scrub controllers — supplied by
/// [`crate::fault::Mitigation::extra_resources`]).
pub fn mitigated_resources(cfg: &NetConfig, prec: Precision, extra: &Resources) -> Resources {
    let mut r = accelerator_resources(cfg, prec);
    r.add(*extra);
    r
}

/// Hardware of the configuration-memory scrubber: a control-FSM-class
/// readback/repair engine around the ICAP, a frame buffer BRAM, and the
/// frame-ECC syndrome fabric. Fixed-size — the scrubber walks frames
/// sequentially, so its footprint does not scale with the design it
/// protects. Charged when a [`crate::fault::CramPlan`] enables scrubbing.
pub fn cram_scrubber_resources() -> Resources {
    let mut r = cost::CONTROL; // readback/repair FSM
    r.add(Resources::new(150, 120, 0, 1)); // ECC syndrome fabric + frame buffer BRAM
    r
}

/// Device-fit check for a mitigated design.
pub fn check_fit_with(
    cfg: &NetConfig,
    prec: Precision,
    dev: &Virtex7,
    extra: &Resources,
) -> Result<Utilization> {
    let r = mitigated_resources(cfg, prec, extra);
    let u = Utilization {
        luts: r.luts as f64 / dev.luts as f64,
        ffs: r.ffs as f64 / dev.ffs as f64,
        dsps: r.dsps as f64 / dev.dsps as f64,
        bram36: r.bram36 as f64 / dev.bram36 as f64,
    };
    if u.max_fraction() > 1.0 {
        return Err(Error::Fpga(format!(
            "{}/{:?} does not fit the device: {u:?}",
            cfg.name(),
            prec
        )));
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvKind;

    #[test]
    fn all_paper_configs_fit_the_485t() {
        let dev = Virtex7::default();
        for cfg in NetConfig::all() {
            for prec in Precision::all() {
                let u = check_fit(&cfg, prec, &dev).unwrap();
                assert!(
                    u.max_fraction() < 0.25,
                    "{}/{prec:?}: {u:?} — these tiny nets must be far below capacity",
                    cfg.name()
                );
            }
        }
    }

    /// Ordering of the fabric footprints: Int8 keeps the Fixed DSP count
    /// but sheds LUT/FF area; Binary drops the DSPs entirely and is the
    /// smallest arm of all.
    #[test]
    fn sub8_arms_shrink_the_fabric() {
        for cfg in NetConfig::all() {
            let fx = accelerator_resources(&cfg, Precision::Fixed);
            let i8r = accelerator_resources(&cfg, Precision::Int8);
            let bin = accelerator_resources(&cfg, Precision::Binary);
            assert_eq!(i8r.dsps, fx.dsps, "{}", cfg.name());
            assert!(i8r.luts < fx.luts && i8r.ffs < fx.ffs, "{}", cfg.name());
            assert_eq!(bin.dsps, 0, "{}", cfg.name());
            assert!(bin.luts < i8r.luts, "{}", cfg.name());
            assert_eq!(bin.bram36, i8r.bram36, "{}", cfg.name());
        }
    }

    #[test]
    fn mitigated_fit_even_a_triplicated_complex_mlp_fits() {
        let dev = Virtex7::default();
        for cfg in NetConfig::all() {
            for prec in Precision::all() {
                // triple the whole design (TMR-class overhead): still fits
                let extra = accelerator_resources(&cfg, prec).scaled(2);
                let u = check_fit_with(&cfg, prec, &dev, &extra).unwrap();
                let base = check_fit(&cfg, prec, &dev).unwrap();
                assert!(u.max_fraction() > base.max_fraction());
                assert!(u.max_fraction() < 0.75, "{}/{prec:?}: {u:?}", cfg.name());
            }
        }
    }

    #[test]
    fn cram_scrubber_is_small_and_fits_alongside_tmr() {
        let s = cram_scrubber_resources();
        assert_eq!(s.bram36, 1, "one frame-buffer BRAM");
        assert_eq!(s.dsps, 0, "a scrubber has no arithmetic datapath");
        assert!(s.luts > 0 && s.ffs > 0);
        // the scrubber must be a rounding error next to the accelerator
        let dev = Virtex7::default();
        for cfg in NetConfig::all() {
            let u = check_fit_with(&cfg, Precision::Fixed, &dev, &s).unwrap();
            let base = check_fit(&cfg, Precision::Fixed, &dev).unwrap();
            assert!(u.max_fraction() < base.max_fraction() + 0.01, "{}", cfg.name());
        }
    }

    #[test]
    fn fixed_area_scales_with_network_size() {
        let simple = accelerator_resources(
            &NetConfig::new(Arch::Mlp, EnvKind::Simple),
            Precision::Fixed,
        );
        let complex = accelerator_resources(
            &NetConfig::new(Arch::Mlp, EnvKind::Complex),
            Precision::Fixed,
        );
        assert!(complex.dsps > 2 * simple.dsps);
        assert!(complex.luts > simple.luts);
    }

    #[test]
    fn float_area_dominated_by_fp_cores_not_fanin() {
        let simple = accelerator_resources(
            &NetConfig::new(Arch::Perceptron, EnvKind::Simple),
            Precision::Float,
        );
        let complex = accelerator_resources(
            &NetConfig::new(Arch::Perceptron, EnvKind::Complex),
            Precision::Float,
        );
        // serial chains: area does not grow with D
        assert_eq!(simple.luts, complex.luts);
        assert_eq!(simple.dsps, complex.dsps);
    }

    #[test]
    fn float_uses_far_more_lut_than_fixed_for_small_nets() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let fx = accelerator_resources(&cfg, Precision::Fixed);
        let fp = accelerator_resources(&cfg, Precision::Float);
        assert!(fp.luts > 2 * fx.luts, "{} vs {}", fp.luts, fx.luts);
    }
}
