//! FIFO Q-value buffers (Fig. 6/8: one for the current state's Q-values,
//! one for the next state's).
//!
//! A bounded ring buffer with explicit overflow/underflow detection and
//! high-water tracking — the structural invariants (`capacity == A`, drained
//! exactly once per update) are asserted by the datapath and property tests.

use crate::error::{Error, Result};

/// Bounded FIFO with usage statistics.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    buf: Vec<Option<T>>,
    head: usize,
    len: usize,
    high_water: usize,
    pushes: u64,
    pops: u64,
}

impl<T: Clone> Fifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "fifo capacity must be positive");
        Fifo {
            buf: vec![None; capacity],
            head: 0,
            len: 0,
            high_water: 0,
            pushes: 0,
            pops: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len == self.buf.len()
    }

    /// Deepest occupancy ever observed (sizing validation).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn counts(&self) -> (u64, u64) {
        (self.pushes, self.pops)
    }

    /// Push; errors on overflow (a hardware FIFO would drop or stall —
    /// either is a design bug here).
    pub fn push(&mut self, v: T) -> Result<()> {
        if self.is_full() {
            return Err(Error::Fpga("FIFO overflow".into()));
        }
        let tail = (self.head + self.len) % self.buf.len();
        self.buf[tail] = Some(v);
        self.len += 1;
        self.high_water = self.high_water.max(self.len);
        self.pushes += 1;
        Ok(())
    }

    /// Pop; errors on underflow.
    pub fn pop(&mut self) -> Result<T> {
        if self.is_empty() {
            return Err(Error::Fpga("FIFO underflow".into()));
        }
        let v = self.buf[self.head].take().expect("occupied slot");
        self.head = (self.head + 1) % self.buf.len();
        self.len -= 1;
        self.pops += 1;
        Ok(v)
    }

    /// Apply `f` to the occupied slot at queue position `idx` (0 = oldest
    /// entry) — models a single-event upset striking a buffered word
    /// between its write and its read (see [`crate::fault`]).
    pub fn corrupt_at<F: FnOnce(&mut T)>(&mut self, idx: usize, f: F) -> Result<()> {
        if idx >= self.len {
            return Err(Error::Fpga(format!(
                "FIFO corrupt index {idx} out of range 0..{}",
                self.len
            )));
        }
        let pos = (self.head + idx) % self.buf.len();
        match self.buf[pos].as_mut() {
            Some(v) => {
                f(v);
                Ok(())
            }
            None => Err(Error::Fpga("FIFO slot unexpectedly empty".into())),
        }
    }

    /// Drain everything in order.
    pub fn drain_all(&mut self) -> Result<Vec<T>> {
        let mut out = Vec::with_capacity(self.len);
        while !self.is_empty() {
            out.push(self.pop()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            f.push(i).unwrap();
        }
        assert!(f.is_full());
        assert_eq!(f.drain_all().unwrap(), vec![0, 1, 2, 3]);
        assert!(f.is_empty());
    }

    #[test]
    fn overflow_underflow() {
        let mut f = Fifo::new(2);
        f.push(1).unwrap();
        f.push(2).unwrap();
        assert!(f.push(3).is_err());
        f.pop().unwrap();
        f.pop().unwrap();
        assert!(f.pop().is_err());
    }

    #[test]
    fn wraparound() {
        let mut f = Fifo::new(3);
        for round in 0..10 {
            f.push(round).unwrap();
            assert_eq!(f.pop().unwrap(), round);
        }
        assert_eq!(f.counts(), (10, 10));
    }

    #[test]
    fn corrupt_at_hits_queue_position_and_survives_wraparound() {
        let mut f = Fifo::new(3);
        // advance head so the ring wraps
        f.push(0).unwrap();
        f.pop().unwrap();
        f.push(10).unwrap();
        f.push(20).unwrap();
        f.push(30).unwrap();
        f.corrupt_at(1, |v| *v += 1).unwrap();
        assert_eq!(f.drain_all().unwrap(), vec![10, 21, 30]);
        assert!(f.corrupt_at(0, |_| {}).is_err()); // empty
    }

    #[test]
    fn high_water_tracking() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.push(i).unwrap();
        }
        f.drain_all().unwrap();
        f.push(0).unwrap();
        assert_eq!(f.high_water(), 5);
    }
}
