//! Power model — regenerates the paper's Tables 7 and 8.
//!
//! XPower-style decomposition at the 150 MHz operating point:
//!
//! ```text
//! P = P_static + P_clock/config + Σ_resources (count × per-unit dynamic)
//!            + P_data-movement(A·D)
//! ```
//!
//! CALIBRATION. The paper reports only four operating points (Tables 7–8:
//! simple MLP 5.6 W fixed / 7.1 W float; complex MLP 7.1 W fixed / 10 W
//! float) and gives no resource-level breakdown, so the per-unit
//! coefficients below are calibrated to land the model inside the paper's
//! band while keeping physically sensible proportions (FP cores toggle
//! hardest, then DSP MACs, BRAM, fabric). What the model *predicts* rather
//! than fits — and what the T7/T8 reproduction checks — is the **shape**:
//! float > fixed at the same design point (paper: 1.3×), complex > simple,
//! and pipelining (X1) trading power for throughput. The calibrated
//! absolute values agree with the paper within ~25%; see EXPERIMENTS.md.

use crate::config::{NetConfig, Precision};

use super::area::accelerator_resources;
use super::device::Virtex7;
use super::timing::TimingModel;

/// Per-unit dynamic power at 150 MHz (calibrated; see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerCoeffs {
    /// Device static power, W (XC7VX485T typical).
    pub static_w: f64,
    /// Clock tree + configuration + I/O baseline at 150 MHz, W.
    pub clock_base_w: f64,
    /// Per-LUT dynamic, W.
    pub per_lut: f64,
    /// Per-FF dynamic, W.
    pub per_ff: f64,
    /// Per-DSP48 dynamic, W.
    pub per_dsp: f64,
    /// Per-BRAM36 dynamic, W.
    pub per_bram: f64,
    /// Extra per-DSP dynamic for FP cores (wide mantissa datapaths), W —
    /// applied to the whole design only in float mode.
    pub fp_core_extra: f64,
    /// Data-movement term: W per (A·D) element streamed per update.
    pub per_stream_elem: f64,
}

impl Default for PowerCoeffs {
    fn default() -> Self {
        PowerCoeffs {
            static_w: 0.24,
            clock_base_w: 4.30,
            per_lut: 0.10e-3,
            per_ff: 0.03e-3,
            per_dsp: 4.0e-3,
            per_bram: 40.0e-3,
            fp_core_extra: 40.0e-3,
            per_stream_elem: 2.0e-3,
        }
    }
}

/// Dynamic (resource-toggling) power of an arbitrary resource set, W —
/// the hook the radiation-mitigation overhead accounting
/// ([`crate::fault::Mitigation`]) charges additional hardware through.
pub fn dynamic_power_w(
    r: &super::units::Resources,
    prec: Precision,
    coeffs: &PowerCoeffs,
) -> f64 {
    let mut p = 0.0;
    p += r.luts as f64 * coeffs.per_lut;
    p += r.ffs as f64 * coeffs.per_ff;
    p += r.dsps as f64 * coeffs.per_dsp;
    p += r.bram36 as f64 * coeffs.per_bram;
    if prec == Precision::Float {
        // FP cores burn disproportionate dynamic power per DSP
        p += r.dsps as f64 * coeffs.fp_core_extra;
    }
    p
}

/// Data-movement term: streaming the (A, D) tile through input registers
/// and FIFOs, W.
pub fn stream_power_w(cfg: &NetConfig, coeffs: &PowerCoeffs) -> f64 {
    (cfg.a * cfg.d) as f64 * coeffs.per_stream_elem
}

/// Power of the configuration-memory scrubber, W: its readback/repair
/// engine toggles continuously (frame walking is precision-independent
/// control fabric, so the fixed-point coefficient set applies). Charged
/// on top of [`power_w`] when a [`crate::fault::CramPlan`] enables
/// scrubbing.
pub fn cram_scrubber_power_w(coeffs: &PowerCoeffs) -> f64 {
    dynamic_power_w(
        &super::area::cram_scrubber_resources(),
        Precision::Fixed,
        coeffs,
    )
}

/// Power estimate for one configuration, W.
pub fn power_w(cfg: &NetConfig, prec: Precision, coeffs: &PowerCoeffs) -> f64 {
    let r = accelerator_resources(cfg, prec);
    coeffs.static_w
        + coeffs.clock_base_w
        + dynamic_power_w(&r, prec, coeffs)
        + stream_power_w(cfg, coeffs)
}

/// Energy per Q-update, µJ (power × modeled completion time) — the metric
/// the paper's Section 5 says actually matters for comparisons.
pub fn energy_per_update_uj(
    cfg: &NetConfig,
    prec: Precision,
    coeffs: &PowerCoeffs,
    timing: &TimingModel,
    dev: &Virtex7,
) -> f64 {
    power_w(cfg, prec, coeffs) * timing.completion_us(cfg, prec, dev)
}

/// Energy per Q-update on the **batched** datapath, µJ. The pipelined MAC
/// array keeps the same power envelope (the same units toggle, just with
/// fewer idle cycles), so fewer cycles per update translate directly into
/// less energy per update — the paper's Section 6 expectation that
/// "power consumption can be further reduced by introducing pipelining".
/// `b` must be nonzero.
pub fn batched_energy_per_update_uj(
    cfg: &NetConfig,
    prec: Precision,
    coeffs: &PowerCoeffs,
    timing: &TimingModel,
    dev: &Virtex7,
    b: usize,
) -> f64 {
    debug_assert!(b > 0);
    let us_per_update =
        dev.cycles_to_us(timing.qupdate_batch_cycles(cfg, prec, b)) / b as f64;
    power_w(cfg, prec, coeffs) * us_per_update
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};

    fn mlp(env: EnvKind) -> NetConfig {
        NetConfig::new(Arch::Mlp, env)
    }

    /// Tables 7–8 shape: float > fixed by roughly the paper's 1.3×.
    #[test]
    fn float_costs_more_power_than_fixed() {
        let c = PowerCoeffs::default();
        for env in [EnvKind::Simple, EnvKind::Complex] {
            let fx = power_w(&mlp(env), Precision::Fixed, &c);
            let fp = power_w(&mlp(env), Precision::Float, &c);
            let ratio = fp / fx;
            assert!(
                (1.1..=1.8).contains(&ratio),
                "{env:?}: {fp:.2} / {fx:.2} = {ratio:.2}"
            );
        }
    }

    /// Complex designs draw more than simple ones at both precisions.
    #[test]
    fn complex_costs_more_than_simple() {
        let c = PowerCoeffs::default();
        for prec in [Precision::Fixed, Precision::Float] {
            let s = power_w(&mlp(EnvKind::Simple), prec, &c);
            let x = power_w(&mlp(EnvKind::Complex), prec, &c);
            assert!(x > s, "{prec:?}: {x:.2} <= {s:.2}");
        }
    }

    /// Calibration lands inside the paper's band (Tables 7–8, ±35%).
    #[test]
    fn within_paper_band() {
        let c = PowerCoeffs::default();
        let anchors = [
            (EnvKind::Simple, Precision::Fixed, 5.6),
            (EnvKind::Simple, Precision::Float, 7.1),
            (EnvKind::Complex, Precision::Fixed, 7.1),
            (EnvKind::Complex, Precision::Float, 10.0),
        ];
        for (env, prec, paper_w) in anchors {
            let w = power_w(&mlp(env), prec, &c);
            let ratio = w / paper_w;
            assert!(
                (0.65..=1.35).contains(&ratio),
                "{env:?}/{prec:?}: model {w:.2} W vs paper {paper_w} W"
            );
        }
    }

    /// Batched execution lowers fixed-point energy per update and leaves
    /// float unchanged (its serial chains cannot pipeline).
    #[test]
    fn batching_cuts_fixed_energy_only() {
        let c = PowerCoeffs::default();
        let t = TimingModel::default();
        let dev = Virtex7::default();
        for env in [EnvKind::Simple, EnvKind::Complex] {
            let fx = energy_per_update_uj(&mlp(env), Precision::Fixed, &c, &t, &dev);
            let fx_b = batched_energy_per_update_uj(&mlp(env), Precision::Fixed, &c, &t, &dev, 32);
            assert!(fx_b < fx, "{env:?}: batched {fx_b} >= stepwise {fx}");
            let fp = energy_per_update_uj(&mlp(env), Precision::Float, &c, &t, &dev);
            let fp_b = batched_energy_per_update_uj(&mlp(env), Precision::Float, &c, &t, &dev, 32);
            assert!((fp_b - fp).abs() < 1e-9, "{env:?}: float changed");
        }
    }

    /// The narrow kernel arms draw strictly less than Q(18,12), which in
    /// turn draws less than float — power tracks the fabric footprint
    /// (Binary sheds every DSP, Int8 thins the routing).
    #[test]
    fn narrow_arms_draw_less_power() {
        let c = PowerCoeffs::default();
        for env in [EnvKind::Simple, EnvKind::Complex] {
            let bin = power_w(&mlp(env), Precision::Binary, &c);
            let i8w = power_w(&mlp(env), Precision::Int8, &c);
            let fx = power_w(&mlp(env), Precision::Fixed, &c);
            let fp = power_w(&mlp(env), Precision::Float, &c);
            assert!(bin < i8w && i8w < fx && fx < fp, "{env:?}: {bin} {i8w} {fx} {fp}");
        }
    }

    /// The refactored decomposition reproduces the calibrated totals.
    #[test]
    fn decomposition_sums_to_power_w() {
        use crate::fpga::area::accelerator_resources;
        let c = PowerCoeffs::default();
        for env in [EnvKind::Simple, EnvKind::Complex] {
            for prec in [Precision::Fixed, Precision::Float] {
                let cfg = mlp(env);
                let whole = power_w(&cfg, prec, &c);
                let parts = c.static_w
                    + c.clock_base_w
                    + dynamic_power_w(&accelerator_resources(&cfg, prec), prec, &c)
                    + stream_power_w(&cfg, &c);
                assert!((whole - parts).abs() < 1e-12);
            }
        }
    }

    /// The scrubber's draw is real but small against any design point.
    #[test]
    fn scrubber_power_is_a_small_additive_term() {
        let c = PowerCoeffs::default();
        let w = cram_scrubber_power_w(&c);
        assert!(w > 0.0);
        assert!(w < 0.2, "scrubber draws {w} W — should be well under a watt");
        assert!(w < 0.05 * power_w(&mlp(EnvKind::Simple), Precision::Fixed, &c));
    }

    /// Energy favors fixed point overwhelmingly (power × time both win).
    #[test]
    fn fixed_wins_energy_per_update() {
        let c = PowerCoeffs::default();
        let t = TimingModel::default();
        let dev = Virtex7::default();
        for env in [EnvKind::Simple, EnvKind::Complex] {
            let e_fx = energy_per_update_uj(&mlp(env), Precision::Fixed, &c, &t, &dev);
            let e_fp = energy_per_update_uj(&mlp(env), Precision::Float, &c, &t, &dev);
            assert!(e_fp > 5.0 * e_fx, "{env:?}: {e_fp:.2} vs {e_fx:.2}");
        }
    }
}
