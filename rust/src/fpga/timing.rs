//! Structural cycle model of the accelerator control FSM (Fig. 6 / Fig. 8).
//!
//! One Q-update executes four phases (paper Section 2 state-flow):
//!
//! 1. feed-forward sweep over all A actions of the current state,
//! 2. feed-forward sweep over all A actions of the next state,
//! 3. error capture: drain the two Q-FIFOs, max-scan the next-state values,
//!    apply Eq. 8,
//! 4. backpropagation: δ and ΔW generation + weight write-back.
//!
//! # Fixed point (fine-grained parallel datapath)
//!
//! DSP48 multipliers are 1-cycle and cheap, so every weight gets its own
//! multiplier; one action is evaluated per layer stage in 3 cycles:
//! (1) all multipliers fire, (2) balanced adder tree + bias, (3) sigmoid ROM
//! read (FIFO write overlaps). Hence per sweep:
//!
//! * perceptron: 3 cycles/action → `3A`
//! * MLP: hidden stage (all H neurons in parallel) + output stage → `6A`
//!
//! Error capture pops one FIFO entry per cycle with a comparator: `A`.
//! Backprop is fully parallel: 1 cycle for the perceptron (Eq. 7/9/10 in
//! one registered stage); 3 cycles for the MLP (δ_out → δ_hidden → parallel
//! ΔW + write-back, Eq. 11–14).
//!
//! **Fixed perceptron total: `3A + 3A + A + 1 = 7A + 1` — exactly the law
//! the paper states in Section 3**, giving 2.34 MQ/s at A = 9 and
//! 0.53 MQ/s at A = 40 at 150 MHz (Table 1). Fixed MLP total: `13A + 3`.
//!
//! # Floating point (resource-limited serial datapath)
//!
//! LogiCORE FP cores are multi-cycle and large, so one MAC chain serves each
//! layer, elements pipelined at the adder latency (the accumulation carries
//! a loop dependence): per action `fp_mul + D·fp_add + fp_to_fx + rom`.
//! The MLP instantiates one chain per hidden neuron (H ≤ 4 chains fit
//! comfortably) so layers contribute additively, not multiplicatively.
//! See `float_*` methods for the full derivation; EXPERIMENTS.md compares
//! each derived count against the paper's Tables 3–6.
//!
//! # Sub-8-bit arms
//!
//! `Precision::Int8` and `Precision::Binary` follow the **fixed-point cycle
//! law verbatim**: a DSP48 multiply is 1 cycle whether the operands are 18
//! or 8 bits wide, and the binary XNOR + popcount dot product closes timing
//! at least as easily as the Q(18,12) adder tree. The narrow arms differ in
//! *area and power* (see [`super::area`]), never in cycles.
//!
//! # Pipelined variant (X1 ablation)
//!
//! The paper's conclusion proposes “introducing pipelining in the data
//! path”. With `pipelined = true` the fixed datapath accepts a new action
//! every cycle (II = 1), filling a 3-stage (perceptron) or 6-stage (MLP)
//! pipe, and error capture overlaps the second sweep.

use crate::config::{Arch, NetConfig, Precision};

use super::device::Virtex7;
use super::units::FuTimings;

/// Cycle cost of one Q-update, by phase.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CycleBreakdown {
    pub ff_current: u64,
    pub ff_next: u64,
    pub error_capture: u64,
    pub backprop: u64,
}

impl CycleBreakdown {
    pub fn total(&self) -> u64 {
        self.ff_current + self.ff_next + self.error_capture + self.backprop
    }
}

/// The structural timing model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingModel {
    pub fu: FuTimings,
    /// X1 ablation: action-level pipelining (paper future work).
    pub pipelined: bool,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel { fu: FuTimings::default(), pipelined: false }
    }
}

impl TimingModel {
    pub fn pipelined() -> Self {
        TimingModel { fu: FuTimings::default(), pipelined: true }
    }

    /// Per-action cycles of one feed-forward layer stage, fixed point:
    /// parallel multiply, adder tree + bias, sigmoid ROM.
    fn fx_stage(&self) -> u64 {
        self.fu.fx_mul + self.fu.fx_tree + self.fu.rom_read
    }

    /// Per-action cycles of one feed-forward layer in float: serial MAC
    /// chain (fill `fp_mul`, then one element per `fp_add`) + ROM addressing.
    fn fp_layer(&self, fan_in: u64) -> u64 {
        self.fu.fp_mul + self.fu.fp_add * fan_in + self.fu.fp_to_fx + self.fu.rom_read
    }

    /// One feed-forward sweep over all A actions.
    pub fn forward_cycles(&self, cfg: &NetConfig, prec: Precision) -> u64 {
        let a = cfg.a as u64;
        let d = cfg.d as u64;
        let h = cfg.h as u64;
        match prec {
            Precision::Fixed | Precision::Int8 | Precision::Binary => {
                let stages = match cfg.arch {
                    Arch::Perceptron => 1,
                    Arch::Mlp => 2,
                };
                let depth = stages * self.fx_stage();
                if self.pipelined {
                    // II = 1: fill the pipe once, then one action per cycle
                    a + depth - 1
                } else {
                    a * depth
                }
            }
            Precision::Float => {
                // serial MAC chains: no action-level overlap is possible
                // (the single chain is busy for the whole action)
                let per_action = match cfg.arch {
                    Arch::Perceptron => self.fp_layer(d),
                    Arch::Mlp => self.fp_layer(d) + self.fp_layer(h),
                };
                a * per_action
            }
        }
    }

    /// Error-capture phase: drain FIFOs, max-scan, Eq. 8.
    pub fn error_cycles(&self, cfg: &NetConfig, prec: Precision) -> u64 {
        let a = cfg.a as u64;
        match prec {
            Precision::Fixed | Precision::Int8 | Precision::Binary => {
                a * (self.fu.fifo_rw.max(self.fu.fx_cmp))
            }
            Precision::Float => a * self.fu.fp_cmp,
        }
    }

    /// Backpropagation phase (Eq. 7, 9–14).
    pub fn backprop_cycles(&self, cfg: &NetConfig, prec: Precision) -> u64 {
        let d = cfg.d as u64;
        let h = cfg.h as u64;
        match prec {
            Precision::Fixed | Precision::Int8 | Precision::Binary => match cfg.arch {
                // one registered stage: parallel δ + ΔW + write-back
                Arch::Perceptron => 1,
                // δ_out → δ_hidden → parallel ΔW/write-back
                Arch::Mlp => 3,
            },
            Precision::Float => {
                let delta = self.fu.fp_to_fx + self.fu.rom_read + self.fu.fp_mul;
                match cfg.arch {
                    Arch::Perceptron => {
                        // serial ΔW chain over D weights + bias
                        let dw = 2 * self.fu.fp_mul + self.fu.fp_add * (d + 1);
                        delta + dw
                    }
                    Arch::Mlp => {
                        // δ1 (H parallel): mul, addr+rom, mul
                        let d1 = 2 * self.fu.fp_mul + self.fu.fp_to_fx + self.fu.rom_read;
                        let dw2 = 2 * self.fu.fp_mul + self.fu.fp_add * (h + 1);
                        // H parallel columns, serial over D+1 rows
                        let dw1 = 2 * self.fu.fp_mul + self.fu.fp_add * (d + 1);
                        delta + d1 + dw2 + dw1
                    }
                }
            }
        }
    }

    /// Full Q-update cycle breakdown.
    pub fn qupdate(&self, cfg: &NetConfig, prec: Precision) -> CycleBreakdown {
        let ff = self.forward_cycles(cfg, prec);
        let mut err = self.error_cycles(cfg, prec);
        if self.pipelined && prec != Precision::Float {
            // error capture overlaps the tail of the second sweep: only the
            // final compare + Eq. 8 stage remains exposed
            err = self.fx_stage();
        }
        CycleBreakdown {
            ff_current: ff,
            ff_next: ff,
            error_capture: err,
            backprop: self.backprop_cycles(cfg, prec),
        }
    }

    /// Cycles for `b` Q-updates streamed back-to-back through the **batched
    /// datapath** — the paper's Section 6 pipelining proposal realized for
    /// multi-transition streams.
    ///
    /// Fixed point: the MAC array accepts a new action every cycle (II = 1)
    /// and the *two* feed-forward sweeps of one update share the pre-update
    /// weights, so the second sweep enters the pipe right behind the first
    /// (one fill per update, not per sweep); the error-capture comparator
    /// consumes Q-values as they stream out, leaving only its final stage
    /// exposed. The weight write-back of update *i* must complete before
    /// the sweeps of update *i+1* (the scan dependence), so updates
    /// themselves remain serial:
    ///
    /// ```text
    /// per-update = (2A + depth − 1) + fx_stage + backprop
    /// ```
    ///
    /// Float: the serial LogiCORE MAC chains leave no action-level overlap
    /// to exploit (the chain is busy for the whole action), so batching
    /// buys nothing on-device — cycles are `b ×` the stepwise cost. This
    /// asymmetry widens the paper's fixed-vs-float gap under batching.
    pub fn qupdate_batch_cycles(&self, cfg: &NetConfig, prec: Precision, b: usize) -> u64 {
        if b == 0 {
            return 0;
        }
        let n = b as u64;
        match prec {
            Precision::Fixed | Precision::Int8 | Precision::Binary => {
                let a = cfg.a as u64;
                let stages = match cfg.arch {
                    Arch::Perceptron => 1,
                    Arch::Mlp => 2,
                };
                let depth = stages * self.fx_stage();
                let per = (2 * a + depth - 1) + self.fx_stage()
                    + self.backprop_cycles(cfg, prec);
                n * per
            }
            Precision::Float => n * self.qupdate(cfg, prec).total(),
        }
    }

    /// Steady-state throughput of the batched datapath, kQ/s.
    pub fn batch_throughput_kq_s(
        &self,
        cfg: &NetConfig,
        prec: Precision,
        b: usize,
        dev: &Virtex7,
    ) -> f64 {
        let cycles = self.qupdate_batch_cycles(cfg, prec, b);
        if cycles == 0 {
            return 0.0;
        }
        dev.clock_hz * b as f64 / cycles as f64 / 1e3
    }

    /// Protected-storage read phases per Q-update — where a TMR majority
    /// voter or SECDED decoder inserts one registered stage each (see
    /// [`crate::fault::Mitigation`]): the two feed-forward sweeps read the
    /// weight store once per layer stage, and backprop reads it once more
    /// for the δ/ΔW generators.
    pub fn protected_read_phases(&self, cfg: &NetConfig) -> u64 {
        match cfg.arch {
            Arch::Perceptron => 2 + 1, // two sweeps × one stage + backprop
            Arch::Mlp => 2 * 2 + 1,    // two sweeps × two stages + backprop
        }
    }

    /// Cycles one full scrub burst takes over an `n_words` weight store:
    /// read the golden copy and rewrite every working word through the
    /// store port (one FIFO-class read + write per word).
    pub fn scrub_burst_cycles(&self, n_words: u64) -> u64 {
        2 * n_words * self.fu.fifo_rw
    }

    /// Cycles one partial-reconfiguration repair of a single CRAM frame
    /// costs: an ECC/CRC detect pass plus a readback + rewrite of the
    /// frame's 101 configuration words through the ICAP port (FIFO-class
    /// read + write per word, same port model as
    /// [`TimingModel::scrub_burst_cycles`]). Charged per repaired frame by
    /// the mission accounting when a [`crate::fault::CramPlan`] is active.
    pub fn cram_repair_cycles(&self) -> u64 {
        const CRAM_FRAME_WORDS: u64 = 101; // 7-series frame: 101 × 32-bit
        const DETECT_CYCLES: u64 = 32; // frame-ECC syndrome + address latch
        DETECT_CYCLES + 2 * CRAM_FRAME_WORDS * self.fu.fifo_rw
    }

    /// Modeled (stepwise, batched) device throughput for one configuration
    /// — the row pair of the model-derived bench trajectory (table `BM1`
    /// in `BENCH_backends.json`, diffed against
    /// `ci/BENCH_backends_baseline.json` by the CI `bench-smoke` job).
    /// Deterministic, unlike the host-measured records beside it.
    pub fn trajectory_kq_s(
        &self,
        cfg: &NetConfig,
        prec: Precision,
        b: usize,
        dev: &Virtex7,
    ) -> (f64, f64) {
        (
            self.throughput_kq_s(cfg, prec, dev),
            self.batch_throughput_kq_s(cfg, prec, b, dev),
        )
    }

    /// Completion time in µs for one Q-update (paper Tables 3–6).
    pub fn completion_us(&self, cfg: &NetConfig, prec: Precision, dev: &Virtex7) -> f64 {
        dev.cycles_to_us(self.qupdate(cfg, prec).total())
    }

    /// Throughput in kQ/s (paper Tables 1–2).
    pub fn throughput_kq_s(&self, cfg: &NetConfig, prec: Precision, dev: &Virtex7) -> f64 {
        dev.throughput_kq_s(self.qupdate(cfg, prec).total())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvKind;

    fn cfg(arch: Arch, env: EnvKind) -> NetConfig {
        NetConfig::new(arch, env)
    }

    /// The paper's Section 3 law, verbatim: “total number of clock cycles to
    /// update a single Q value equals 7A + 1”.
    #[test]
    fn fixed_perceptron_follows_7a_plus_1() {
        let t = TimingModel::default();
        for a in [1usize, 6, 9, 40, 64] {
            let mut c = cfg(Arch::Perceptron, EnvKind::Simple);
            c.a = a;
            assert_eq!(t.qupdate(&c, Precision::Fixed).total(), 7 * a as u64 + 1);
        }
    }

    /// Table 1 anchor points: “for an action size equal to 9, the total
    /// number of Q-values computed per second equals 2.34 million … and
    /// 0.53 Million for a complex environment [A = 40]”.
    #[test]
    fn table1_throughput_anchors() {
        let t = TimingModel::default();
        let dev = Virtex7::default();
        let mut c9 = cfg(Arch::Perceptron, EnvKind::Simple);
        c9.a = 9;
        let kq9 = t.throughput_kq_s(&c9, Precision::Fixed, &dev);
        assert!((kq9 - 2340.0).abs() / 2340.0 < 0.01, "{kq9}");

        let c40 = cfg(Arch::Perceptron, EnvKind::Complex);
        let kq40 = t.throughput_kq_s(&c40, Precision::Fixed, &dev);
        assert!((kq40 - 530.0).abs() / 530.0 < 0.01, "{kq40}");
    }

    /// Table 4 anchor: complex fixed perceptron 1.8 µs.
    #[test]
    fn table4_completion_anchor() {
        let t = TimingModel::default();
        let dev = Virtex7::default();
        let us = t.completion_us(&cfg(Arch::Perceptron, EnvKind::Complex),
                                 Precision::Fixed, &dev);
        assert!((us - 1.87).abs() < 0.1, "{us}");
    }

    /// Shape: float is dramatically slower than fixed everywhere, and the
    /// gap widens with the serial fan-in (paper Tables 3–6).
    #[test]
    fn float_much_slower_than_fixed() {
        let t = TimingModel::default();
        for arch in [Arch::Perceptron, Arch::Mlp] {
            for env in [EnvKind::Simple, EnvKind::Complex] {
                let c = cfg(arch, env);
                let fx = t.qupdate(&c, Precision::Fixed).total();
                let fp = t.qupdate(&c, Precision::Float).total();
                assert!(fp > 10 * fx, "{arch:?}/{env:?}: {fp} vs {fx}");
            }
        }
        // the serial-MAC model widens the gap on the complex env
        let gap_simple = t.qupdate(&cfg(Arch::Perceptron, EnvKind::Simple), Precision::Float).total()
            as f64
            / t.qupdate(&cfg(Arch::Perceptron, EnvKind::Simple), Precision::Fixed).total() as f64;
        let gap_complex = t.qupdate(&cfg(Arch::Perceptron, EnvKind::Complex), Precision::Float).total()
            as f64
            / t.qupdate(&cfg(Arch::Perceptron, EnvKind::Complex), Precision::Fixed).total() as f64;
        assert!(gap_complex > gap_simple);
    }

    /// Shape: MLP costs more than the perceptron at equal precision/env.
    #[test]
    fn mlp_costs_more_than_perceptron() {
        let t = TimingModel::default();
        for prec in [Precision::Fixed, Precision::Float] {
            for env in [EnvKind::Simple, EnvKind::Complex] {
                let p = t.qupdate(&cfg(Arch::Perceptron, env), prec).total();
                let m = t.qupdate(&cfg(Arch::Mlp, env), prec).total();
                assert!(m > p, "{prec:?}/{env:?}");
            }
        }
    }

    /// Paper-band check for the float completion times (Tables 3–6 give
    /// 7.7 / 102 / 13 / 107 µs; the structural model must land within 2×).
    #[test]
    fn float_completion_in_paper_band() {
        let t = TimingModel::default();
        let dev = Virtex7::default();
        let anchors = [
            (Arch::Perceptron, EnvKind::Simple, 7.7),
            (Arch::Perceptron, EnvKind::Complex, 102.0),
            (Arch::Mlp, EnvKind::Simple, 13.0),
            (Arch::Mlp, EnvKind::Complex, 107.0),
        ];
        for (arch, env, paper_us) in anchors {
            let us = t.completion_us(&cfg(arch, env), Precision::Float, &dev);
            let ratio = us / paper_us;
            assert!(
                (0.5..=2.0).contains(&ratio),
                "{arch:?}/{env:?}: model {us:.1} µs vs paper {paper_us} µs (ratio {ratio:.2})"
            );
        }
    }

    /// X1 ablation: pipelining must help fixed point substantially.
    #[test]
    fn pipelining_speeds_up_fixed() {
        let base = TimingModel::default();
        let pipe = TimingModel::pipelined();
        for arch in [Arch::Perceptron, Arch::Mlp] {
            let c = cfg(arch, EnvKind::Complex);
            let b = base.qupdate(&c, Precision::Fixed).total();
            let p = pipe.qupdate(&c, Precision::Fixed).total();
            assert!(p * 2 < b, "{arch:?}: {p} vs {b}");
        }
    }

    /// The batched datapath must beat the stepwise one in fixed point on
    /// every paper configuration, and match it exactly in float (serial
    /// chains cannot pipeline).
    #[test]
    fn batched_beats_stepwise_fixed_matches_float() {
        let t = TimingModel::default();
        for arch in [Arch::Perceptron, Arch::Mlp] {
            for env in [EnvKind::Simple, EnvKind::Complex] {
                let c = cfg(arch, env);
                for b in [1usize, 8, 32, 256] {
                    let step_total = b as u64 * t.qupdate(&c, Precision::Fixed).total();
                    let batch_total = t.qupdate_batch_cycles(&c, Precision::Fixed, b);
                    assert!(
                        batch_total < step_total,
                        "{arch:?}/{env:?} b={b}: {batch_total} >= {step_total}"
                    );
                    assert_eq!(
                        t.qupdate_batch_cycles(&c, Precision::Float, b),
                        b as u64 * t.qupdate(&c, Precision::Float).total(),
                        "{arch:?}/{env:?} b={b}: float batching must be neutral"
                    );
                }
            }
        }
    }

    /// Batched throughput: ≥2× over stepwise for the fixed perceptron, and
    /// linear in the batch (per-update cost is batch-size independent).
    #[test]
    fn batch_throughput_shape() {
        let t = TimingModel::default();
        let dev = Virtex7::default();
        let c = cfg(Arch::Perceptron, EnvKind::Complex);
        let stepwise = t.throughput_kq_s(&c, Precision::Fixed, &dev);
        let batched = t.batch_throughput_kq_s(&c, Precision::Fixed, 32, &dev);
        assert!(batched > 2.0 * stepwise, "{batched} vs {stepwise}");
        // linearity: kQ/s is independent of b
        let b8 = t.batch_throughput_kq_s(&c, Precision::Fixed, 8, &dev);
        let b64 = t.batch_throughput_kq_s(&c, Precision::Fixed, 64, &dev);
        assert!((b8 - b64).abs() < 1e-9, "{b8} vs {b64}");
        // degenerate inputs
        assert_eq!(t.qupdate_batch_cycles(&c, Precision::Fixed, 0), 0);
        assert_eq!(t.batch_throughput_kq_s(&c, Precision::Fixed, 0, &dev), 0.0);
    }

    /// Int8 and Binary share the fixed-point cycle law exactly — stepwise,
    /// batched, and pipelined. DSP48 multiplies are 1 cycle at any operand
    /// width; XNOR + popcount closes timing like the adder tree.
    #[test]
    fn sub8_arms_follow_the_fixed_cycle_law() {
        for t in [TimingModel::default(), TimingModel::pipelined()] {
            for arch in [Arch::Perceptron, Arch::Mlp] {
                for env in [EnvKind::Simple, EnvKind::Complex] {
                    let c = cfg(arch, env);
                    let fx = t.qupdate(&c, Precision::Fixed);
                    for prec in [Precision::Int8, Precision::Binary] {
                        assert_eq!(t.qupdate(&c, prec), fx, "{arch:?}/{env:?}/{prec:?}");
                        for b in [0usize, 1, 32] {
                            assert_eq!(
                                t.qupdate_batch_cycles(&c, prec, b),
                                t.qupdate_batch_cycles(&c, Precision::Fixed, b),
                                "{arch:?}/{env:?}/{prec:?} b={b}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mitigation_hooks_are_small_and_scale_right() {
        let t = TimingModel::default();
        let per = cfg(Arch::Perceptron, EnvKind::Simple);
        let mlp = cfg(Arch::Mlp, EnvKind::Complex);
        assert_eq!(t.protected_read_phases(&per), 3);
        assert_eq!(t.protected_read_phases(&mlp), 5);
        // the voter stages are a tiny fraction of an update
        assert!(t.protected_read_phases(&mlp) * 20 < t.qupdate(&mlp, Precision::Fixed).total());
        assert_eq!(t.scrub_burst_cycles(89), 178);
        assert_eq!(t.scrub_burst_cycles(0), 0);
        // one frame repair: 32 detect + 2×101 words at fifo_rw (1 cycle)
        assert_eq!(t.cram_repair_cycles(), 32 + 202);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let t = TimingModel::default();
        let b = t.qupdate(&cfg(Arch::Mlp, EnvKind::Complex), Precision::Float);
        assert_eq!(
            b.total(),
            b.ff_current + b.ff_next + b.error_capture + b.backprop
        );
        assert_eq!(b.ff_current, b.ff_next);
    }
}
