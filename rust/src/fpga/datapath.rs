//! The accelerator datapath: bit-accurate execution + cycle accounting.
//!
//! [`FpgaAccelerator`] holds the network weights on-chip (18-bit words in
//! “BRAM/FF” for fixed point, f32 for the float variant), streams
//! state-action vectors through the MAC + sigmoid-ROM pipeline, buffers
//! Q-values in the two FIFOs of Fig. 6/8, and runs the error-capture and
//! backprop blocks of Fig. 5/10.
//!
//! * **Fixed mode** computes with true integer Q(word,frac) arithmetic:
//!   wide DSP48-style accumulators ([`crate::fixed::Acc`]), one rounding per
//!   register write — the datapath the paper synthesizes.
//! * **Int8 mode** is the same integer datapath with the word format pinned
//!   to the canonical Q(8,4) grid ([`FixedSpec::int8`]) — the narrow-MAC
//!   sub-8-bit arm.
//! * **Float mode** computes in IEEE f32 (LogiCORE cores are IEEE), which is
//!   numerically identical to the CPU/XLA float path; only the *timing*
//!   differs.
//! * **Binary mode** delegates to the `nn` kernel with the ±1 sign-grid
//!   register rule (the XNOR/popcount fabric computes exact ±1 dot
//!   products, so the f32 delegation is bit-identical to the CPU arm).
//!
//! Every call returns its cycle charge from the structural
//! [`TimingModel`], and the accelerator accumulates lifetime counters used
//! by the benches and the mission telemetry.

use crate::config::{Hyper, NetConfig, Precision};
use crate::error::{Error, Result};
use crate::fault::{FaultStats, SeuHook};
use crate::fixed::{tensor, Acc, Fixed, FixedSpec, Quantizer};
use crate::nn::activation::LutSpec;
use crate::nn::params::QNetParams;
use crate::nn::qupdate::QUpdateOutput;

use super::device::Virtex7;
use super::fifo::Fifo;
use super::timing::{CycleBreakdown, TimingModel};

/// Sigmoid + derivative ROM holding fixed-point words.
#[derive(Debug, Clone)]
struct FixedRom {
    spec: LutSpec,
    table: Vec<Fixed>,
    dtable: Vec<Fixed>,
}

impl FixedRom {
    fn build(spec: LutSpec, q: FixedSpec) -> Self {
        let n = spec.size;
        let mut table = Vec::with_capacity(n);
        let mut dtable = Vec::with_capacity(n);
        for i in 0..n {
            let x = -spec.xmax as f64 + (2.0 * spec.xmax as f64) * i as f64 / (n - 1) as f64;
            let s = 1.0 / (1.0 + (-x).exp());
            table.push(Fixed::from_f64(s, q));
            dtable.push(Fixed::from_f64(s * (1.0 - s), q));
        }
        FixedRom { spec, table, dtable }
    }

    #[inline]
    fn f(&self, x: Fixed) -> Fixed {
        self.table[self.spec.index(x.to_f32())]
    }

    #[inline]
    fn fprime(&self, x: Fixed) -> Fixed {
        self.dtable[self.spec.index(x.to_f32())]
    }
}

/// On-chip weight store, fixed mode.
#[derive(Debug, Clone)]
enum FixedParams {
    Perceptron { w: Vec<Fixed>, b: Fixed },
    Mlp { w1: Vec<Fixed>, b1: Vec<Fixed>, w2: Vec<Fixed>, b2: Fixed },
}

impl FixedParams {
    fn quantize(p: &QNetParams, q: FixedSpec) -> Self {
        match p {
            QNetParams::Perceptron { w, b } => FixedParams::Perceptron {
                w: tensor::quantize_slice(w, q),
                b: Fixed::from_f32(*b, q),
            },
            QNetParams::Mlp { w1, b1, w2, b2 } => FixedParams::Mlp {
                w1: tensor::quantize_slice(w1, q),
                b1: tensor::quantize_slice(b1, q),
                w2: tensor::quantize_slice(w2, q),
                b2: Fixed::from_f32(*b2, q),
            },
        }
    }

    fn dequantize(&self) -> QNetParams {
        match self {
            FixedParams::Perceptron { w, b } => QNetParams::Perceptron {
                w: tensor::to_f32_vec(w),
                b: b.to_f32(),
            },
            FixedParams::Mlp { w1, b1, w2, b2 } => QNetParams::Mlp {
                w1: tensor::to_f32_vec(w1),
                b1: tensor::to_f32_vec(b1),
                w2: tensor::to_f32_vec(w2),
                b2: b2.to_f32(),
            },
        }
    }
}

/// Lifetime statistics (for telemetry and the benches).
#[derive(Debug, Clone, Copy, Default)]
pub struct AccelStats {
    pub updates: u64,
    pub forwards: u64,
    /// Batched `qupdate_batch` calls (each covers ≥1 update).
    pub batches: u64,
    pub cycles: u64,
}

/// Cycle-accurate Q-learning accelerator instance.
pub struct FpgaAccelerator {
    cfg: NetConfig,
    precision: Precision,
    qspec: FixedSpec,
    /// Fast input-register quantizer (hot path: A·D conversions per sweep).
    quant: Quantizer,
    hyper: Hyper,
    timing: TimingModel,
    device: Virtex7,
    // datapath state
    fixed_params: Option<FixedParams>,
    float_params: Option<QNetParams>,
    rom: FixedRom,
    stats: AccelStats,
    /// Radiation hook: strikes the Q-value FIFO words of the fixed
    /// datapath mid-update when attached (see [`crate::fault`]).
    seu: Option<SeuHook>,
    // scratch (avoids per-update allocation on the hot path)
    scratch_q: Vec<Fixed>,
    scratch_pre: Vec<Fixed>,
    scratch_hid: Vec<Fixed>,
}

/// A single transition to learn from.
#[derive(Debug, Clone)]
pub struct Transition<'a> {
    /// (A, D) row-major encodings of all actions in the current state.
    pub sa_cur: &'a [f32],
    /// (A, D) encodings for the next state.
    pub sa_next: &'a [f32],
    pub action: usize,
    pub reward: f32,
}

impl FpgaAccelerator {
    /// Instantiate the accelerator with initial weights at the default
    /// Q(18,12) word format.
    pub fn new(
        cfg: NetConfig,
        precision: Precision,
        params: &QNetParams,
        hyper: Hyper,
        timing: TimingModel,
    ) -> Self {
        Self::with_spec(cfg, precision, params, hyper, timing, FixedSpec::default())
    }

    /// Instantiate with an explicit fixed-point word format (the X3
    /// word-length axis); `qspec` is ignored in float and binary precision,
    /// and pinned to the canonical Q(8,4) grid in int8 precision (matching
    /// the CPU arm).
    pub fn with_spec(
        cfg: NetConfig,
        precision: Precision,
        params: &QNetParams,
        hyper: Hyper,
        timing: TimingModel,
        qspec: FixedSpec,
    ) -> Self {
        let qspec = match precision {
            Precision::Int8 => FixedSpec::int8(),
            _ => qspec,
        };
        let quant = Quantizer::new(qspec);
        let rom = FixedRom::build(LutSpec::default(), qspec);
        let (fixed_params, float_params) = match precision {
            Precision::Fixed | Precision::Int8 => {
                (Some(FixedParams::quantize(params, qspec)), None)
            }
            Precision::Float | Precision::Binary => (None, Some(params.clone())),
        };
        FpgaAccelerator {
            scratch_q: Vec::with_capacity(cfg.a),
            scratch_pre: Vec::with_capacity(cfg.a),
            scratch_hid: Vec::with_capacity(cfg.a * cfg.h.max(1)),
            cfg,
            precision,
            qspec,
            quant,
            hyper,
            timing,
            device: Virtex7::default(),
            fixed_params,
            float_params,
            rom,
            stats: AccelStats::default(),
            seu: None,
        }
    }

    /// Paper-default accelerator.
    pub fn paper(cfg: NetConfig, precision: Precision, params: &QNetParams, hyper: Hyper) -> Self {
        Self::new(cfg, precision, params, hyper, TimingModel::default())
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Hyper-parameters baked into the datapath's error-capture/backprop
    /// blocks.
    pub fn hyper(&self) -> Hyper {
        self.hyper
    }

    pub fn stats(&self) -> AccelStats {
        self.stats
    }

    pub fn device(&self) -> &Virtex7 {
        &self.device
    }

    pub fn timing(&self) -> &TimingModel {
        &self.timing
    }

    /// Current weights, dequantized to f32 (telemetry / checkpointing).
    pub fn params(&self) -> QNetParams {
        match self.precision {
            Precision::Fixed | Precision::Int8 => {
                self.fixed_params.as_ref().unwrap().dequantize()
            }
            Precision::Float | Precision::Binary => self.float_params.as_ref().unwrap().clone(),
        }
    }

    /// Load new weights (e.g. from a checkpoint or the XLA trainer).
    pub fn load_params(&mut self, params: &QNetParams) {
        match self.precision {
            Precision::Fixed | Precision::Int8 => {
                self.fixed_params = Some(FixedParams::quantize(params, self.qspec))
            }
            Precision::Float | Precision::Binary => self.float_params = Some(params.clone()),
        }
    }

    /// Wall-clock the accelerator *would* take on the Virtex-7, in µs.
    pub fn modeled_time_us(&self) -> f64 {
        self.device.cycles_to_us(self.stats.cycles)
    }

    /// Attach (or clear) the transient-SEU hook. While attached, every
    /// fixed-mode Q-update exposes the buffered FIFO Q-values to seeded
    /// bit flips between their write and their read.
    pub fn set_seu_hook(&mut self, hook: Option<SeuHook>) {
        self.seu = hook;
    }

    /// Accounting from the attached SEU hook, if any.
    pub fn seu_stats(&self) -> Option<FaultStats> {
        self.seu.as_ref().map(SeuHook::stats)
    }

    // ------------------------------------------------------------- forward

    /// One feed-forward sweep: Q-values for all A actions.
    /// Returns the values and the cycle charge.
    pub fn forward(&mut self, sa: &[f32]) -> Result<(Vec<f32>, u64)> {
        self.check_sa(sa)?;
        let q = match self.precision {
            Precision::Fixed | Precision::Int8 => {
                let mut out = Vec::with_capacity(self.cfg.a);
                self.fixed_sweep(sa, &mut out, None, None)?;
                out.iter().map(Fixed::to_f32).collect()
            }
            Precision::Float | Precision::Binary => self.nn_forward(sa)?.q,
        };
        let cycles = self.timing.forward_cycles(&self.cfg, self.precision);
        self.stats.forwards += 1;
        self.stats.cycles += cycles;
        crate::obs::metrics().fpga_cycles.add(cycles);
        Ok((q, cycles))
    }

    // ------------------------------------------------------------- qupdate

    /// One full Q-update (the paper's unit of work).
    pub fn qupdate(&mut self, t: &Transition) -> Result<(QUpdateOutput, CycleBreakdown)> {
        self.check_sa(t.sa_cur)?;
        self.check_sa(t.sa_next)?;
        if t.action >= self.cfg.a {
            return Err(Error::Env(format!(
                "action {} out of range 0..{}",
                t.action, self.cfg.a
            )));
        }
        let out = match self.precision {
            Precision::Fixed | Precision::Int8 => self.fixed_qupdate(t)?,
            Precision::Float | Precision::Binary => self.nn_qupdate(t)?,
        };
        let breakdown = self.timing.qupdate(&self.cfg, self.precision);
        self.stats.updates += 1;
        self.stats.cycles += breakdown.total();
        crate::obs::metrics().fpga_cycles.add(breakdown.total());
        Ok((out, breakdown))
    }

    /// Apply a batch of transitions back-to-back — the paper's proposed
    /// datapath pipelining (Section 6) realized for multi-transition
    /// streams. Numerics are **identical** to calling [`Self::qupdate`] per
    /// transition (the weight chain is inherently sequential); the cycle
    /// charge uses [`TimingModel::qupdate_batch_cycles`], where the control
    /// FSM streams transitions through the action-pipelined MAC array and
    /// overlaps error capture with the sweep tail.
    ///
    /// Inputs are flattened (B·A·D) row-major; returns one Q-error per
    /// transition and charges the batch's cycle cost once.
    pub fn qupdate_batch(
        &mut self,
        sa_cur: &[f32],
        sa_next: &[f32],
        actions: &[usize],
        rewards: &[f32],
    ) -> Result<Vec<f32>> {
        let step = self.cfg.a * self.cfg.d;
        let b = actions.len();
        if rewards.len() != b || sa_cur.len() != b * step || sa_next.len() != b * step {
            return Err(Error::interface(format!(
                "batch shapes: {} actions, {} rewards, {}/{} encoded elements (step {step})",
                b,
                rewards.len(),
                sa_cur.len(),
                sa_next.len()
            )));
        }
        // validate every action before touching the weights: a rejected
        // batch must leave the accelerator untouched
        for &a in actions {
            if a >= self.cfg.a {
                return Err(Error::Env(format!(
                    "action {a} out of range 0..{}",
                    self.cfg.a
                )));
            }
        }
        if b == 0 {
            return Ok(Vec::new());
        }
        let mut errs = Vec::with_capacity(b);
        for k in 0..b {
            let t = Transition {
                sa_cur: &sa_cur[k * step..(k + 1) * step],
                sa_next: &sa_next[k * step..(k + 1) * step],
                action: actions[k],
                reward: rewards[k],
            };
            let out = match self.precision {
                Precision::Fixed | Precision::Int8 => self.fixed_qupdate(&t)?,
                Precision::Float | Precision::Binary => self.nn_qupdate(&t)?,
            };
            errs.push(out.q_err);
        }
        let cycles = self.timing.qupdate_batch_cycles(&self.cfg, self.precision, b);
        self.stats.updates += b as u64;
        self.stats.batches += 1;
        self.stats.cycles += cycles;
        crate::obs::metrics().fpga_cycles.add(cycles);
        Ok(errs)
    }

    fn check_sa(&self, sa: &[f32]) -> Result<()> {
        if sa.len() != self.cfg.a * self.cfg.d {
            return Err(Error::interface(format!(
                "sa length {} != A*D = {}",
                sa.len(),
                self.cfg.a * self.cfg.d
            )));
        }
        Ok(())
    }

    // --------------------------------------------------------- fixed path

    /// One sweep through the fixed datapath. Optionally records
    /// pre-activations and hidden activations (needed for backprop on the
    /// current state).
    fn fixed_sweep(
        &mut self,
        sa: &[f32],
        q_out: &mut Vec<Fixed>,
        mut pre_out: Option<&mut Vec<Fixed>>,
        mut hid_out: Option<&mut Vec<Fixed>>,
    ) -> Result<()> {
        let (a_n, d, h) = (self.cfg.a, self.cfg.d, self.cfg.h);
        let q = self.qspec;
        q_out.clear();
        match self.fixed_params.as_ref().expect("fixed params") {
            FixedParams::Perceptron { w, b } => {
                for ai in 0..a_n {
                    // input registers quantize the encoded vector
                    let mut acc = Acc::new(q);
                    for i in 0..d {
                        // input registers: fast f32->raw quantization
                        let x = Fixed::from_raw(self.quant.to_raw(sa[ai * d + i]), q);
                        acc.mac(x, w[i]); // parallel DSP48 multipliers
                    }
                    acc.add_value(*b);
                    let pre = acc.finish(); // adder tree + single rounding
                    if let Some(p) = pre_out.as_deref_mut() {
                        p.push(pre);
                    }
                    q_out.push(self.rom.f(pre)); // sigmoid ROM read
                }
            }
            FixedParams::Mlp { w1, b1, w2, b2 } => {
                for ai in 0..a_n {
                    // hidden layer: H parallel MAC columns
                    let mut hid_row = Vec::with_capacity(h);
                    for j in 0..h {
                        let mut acc = Acc::new(q);
                        for i in 0..d {
                            let x = Fixed::from_raw(self.quant.to_raw(sa[ai * d + i]), q);
                            acc.mac(x, w1[i * h + j]);
                        }
                        acc.add_value(b1[j]);
                        let pre1 = acc.finish();
                        if let Some(p) = pre_out.as_deref_mut() {
                            p.push(pre1);
                        }
                        let o = self.rom.f(pre1);
                        if let Some(hh) = hid_out.as_deref_mut() {
                            hh.push(o);
                        }
                        hid_row.push(o);
                    }
                    // output layer
                    let mut acc = Acc::new(q);
                    for j in 0..h {
                        acc.mac(hid_row[j], w2[j]);
                    }
                    acc.add_value(*b2);
                    let pre2 = acc.finish();
                    if let Some(p) = pre_out.as_deref_mut() {
                        p.push(pre2); // layout: per action, H hidden then 1 output
                    }
                    q_out.push(self.rom.f(pre2));
                }
            }
        }
        Ok(())
    }

    fn fixed_qupdate(&mut self, t: &Transition) -> Result<QUpdateOutput> {
        let (a_n, d, h) = (self.cfg.a, self.cfg.d, self.cfg.h);
        let q = self.qspec;
        let hyper = self.hyper;

        // ---- two feed-forward sweeps, Q-values through the FIFOs --------
        let mut fifo_cur: Fifo<Fixed> = Fifo::new(a_n);
        let mut fifo_next: Fifo<Fixed> = Fifo::new(a_n);

        let mut q_cur = std::mem::take(&mut self.scratch_q);
        let mut pre = std::mem::take(&mut self.scratch_pre);
        let mut hid = std::mem::take(&mut self.scratch_hid);
        pre.clear();
        hid.clear();
        self.fixed_sweep(t.sa_cur, &mut q_cur, Some(&mut pre), Some(&mut hid))?;
        for &v in &q_cur {
            fifo_cur.push(v)?;
        }
        let mut q_next = Vec::with_capacity(a_n);
        self.fixed_sweep(t.sa_next, &mut q_next, None, None)?;
        for &v in &q_next {
            fifo_next.push(v)?;
        }

        // radiation: buffered Q-values sit in the FIFOs for a full phase —
        // the attached hook strikes them before error capture reads them
        if let Some(hook) = self.seu.as_mut() {
            hook.corrupt_fifo(&mut fifo_cur, q)?;
            hook.corrupt_fifo(&mut fifo_next, q)?;
        }

        // ---- error capture (Fig. 5): drain FIFOs, max scan, Eq. 8 -------
        let drained_next = fifo_next.drain_all()?;
        let q_next_max = tensor::max(&drained_next);
        let drained_cur = fifo_cur.drain_all()?;
        let q_sa = drained_cur[t.action];
        crate::obs::metrics()
            .fpga_fifo_high_water
            .observe(fifo_cur.high_water().max(fifo_next.high_water()) as u64);

        let gamma = Fixed::from_f32(hyper.gamma, q);
        let alpha = Fixed::from_f32(hyper.alpha, q);
        let lr = Fixed::from_f32(hyper.lr, q);
        let reward = Fixed::from_f32(t.reward, q);
        let target = reward.add(gamma.mul(q_next_max));
        let err = alpha.mul(target.sub(q_sa));

        // ---- backprop block (Eq. 7, 9–14) --------------------------------
        let x_row: Vec<Fixed> = (0..d)
            .map(|i| Fixed::from_raw(self.quant.to_raw(t.sa_cur[t.action * d + i]), q))
            .collect();

        match self.fixed_params.as_mut().expect("fixed params") {
            FixedParams::Perceptron { w, b } => {
                let sigma = pre[t.action];
                let delta = self.rom.fprime(sigma).mul(err); // Eq. 7
                for i in 0..d {
                    let dw = lr.mul(x_row[i].mul(delta)); // Eq. 9
                    w[i] = w[i].add(dw); // Eq. 10
                }
                *b = b.add(lr.mul(delta));
            }
            FixedParams::Mlp { w1, b1, w2, b2 } => {
                // pre layout per action: H hidden pre-activations, then the
                // output pre-activation
                let base = t.action * (h + 1);
                let s1 = &pre[base..base + h];
                let s2 = pre[base + h];
                let o1 = &hid[t.action * h..(t.action + 1) * h];

                let d2 = self.rom.fprime(s2).mul(err); // Eq. 11
                let mut d1 = Vec::with_capacity(h);
                for j in 0..h {
                    // Eq. 12
                    d1.push(self.rom.fprime(s1[j]).mul(d2.mul(w2[j])));
                }
                for j in 0..h {
                    let dw2 = lr.mul(o1[j].mul(d2)); // Eq. 13
                    w2[j] = w2[j].add(dw2); // Eq. 14
                }
                *b2 = b2.add(lr.mul(d2));
                for i in 0..d {
                    for j in 0..h {
                        let dw1 = lr.mul(x_row[i].mul(d1[j]));
                        w1[i * h + j] = w1[i * h + j].add(dw1);
                    }
                }
                for j in 0..h {
                    b1[j] = b1[j].add(lr.mul(d1[j]));
                }
            }
        }

        let out = QUpdateOutput {
            params: self.fixed_params.as_ref().unwrap().dequantize(),
            q_cur: q_cur.iter().map(Fixed::to_f32).collect(),
            q_next: q_next.iter().map(Fixed::to_f32).collect(),
            q_err: err.to_f32(),
        };
        // return scratch buffers
        self.scratch_q = q_cur;
        self.scratch_pre = pre;
        self.scratch_hid = hid;
        Ok(out)
    }

    // ------------------------------------------- nn-delegated paths
    // (float: LogiCORE FP cores are IEEE-754; binary: the XNOR/popcount
    // fabric computes exact ±1 dot products — both are bit-identical to
    // the CPU `nn` kernel, so the simulator delegates and only the cycle
    // accounting differs.)

    fn nn_datapath(&self) -> crate::nn::qupdate::Datapath {
        crate::nn::qupdate::Datapath::for_precision(self.precision)
    }

    fn nn_forward(&self, sa: &[f32]) -> Result<crate::nn::qupdate::ForwardTrace> {
        crate::nn::qupdate::forward_full(
            &self.cfg,
            self.float_params.as_ref().expect("nn-delegated params"),
            sa,
            &self.nn_datapath(),
        )
    }

    fn nn_qupdate(&mut self, t: &Transition) -> Result<QUpdateOutput> {
        let out = crate::nn::qupdate::qupdate(
            &self.cfg,
            self.float_params.as_ref().expect("nn-delegated params"),
            t.sa_cur,
            t.sa_next,
            t.action,
            t.reward,
            &self.hyper,
            &self.nn_datapath(),
        )?;
        self.float_params = Some(out.params.clone());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};
    use crate::nn::activation::Activation;
    use crate::nn::qupdate::{self, Datapath};
    use crate::util::Rng;

    fn setup(arch: Arch, env: EnvKind, prec: Precision) -> (NetConfig, QNetParams, FpgaAccelerator) {
        let cfg = NetConfig::new(arch, env);
        let mut rng = Rng::seeded(11);
        let params = QNetParams::init(&cfg, 0.4, &mut rng);
        let acc = FpgaAccelerator::paper(cfg, prec, &params, Hyper::default());
        (cfg, params, acc)
    }

    fn transition(cfg: &NetConfig, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, usize, f32) {
        (
            rng.vec_f32(cfg.a * cfg.d, -1.0, 1.0),
            rng.vec_f32(cfg.a * cfg.d, -1.0, 1.0),
            rng.below(cfg.a),
            rng.f32_range(-1.0, 1.0),
        )
    }

    #[test]
    fn float_mode_matches_cpu_nn_exactly() {
        for arch in [Arch::Perceptron, Arch::Mlp] {
            let (cfg, params, mut acc) = setup(arch, EnvKind::Simple, Precision::Float);
            let mut rng = Rng::seeded(12);
            let (sa_cur, sa_next, action, reward) = transition(&cfg, &mut rng);
            let (out, _) = acc
                .qupdate(&Transition { sa_cur: &sa_cur, sa_next: &sa_next, action, reward })
                .unwrap();
            let dp = Datapath::new(None, Activation::lut_default(None));
            let want =
                qupdate::qupdate(&cfg, &params, &sa_cur, &sa_next, action, reward,
                                 &Hyper::default(), &dp)
                    .unwrap();
            assert_eq!(out.q_err, want.q_err);
            assert_eq!(out.params, want.params);
            assert_eq!(out.q_cur, want.q_cur);
        }
    }

    #[test]
    fn fixed_mode_tracks_fakequant_nn_within_lsb_budget() {
        // integer datapath vs f32 fake-quant: a few LSB of divergence is
        // expected (f32 rounds 36-bit products); assert a tight budget.
        let lsb = FixedSpec::default().lsb() as f32;
        for arch in [Arch::Perceptron, Arch::Mlp] {
            for env in [EnvKind::Simple, EnvKind::Complex] {
                let (cfg, params, mut acc) = setup(arch, env, Precision::Fixed);
                let mut rng = Rng::seeded(13);
                let (sa_cur, sa_next, action, reward) = transition(&cfg, &mut rng);
                let (out, _) = acc
                    .qupdate(&Transition { sa_cur: &sa_cur, sa_next: &sa_next, action, reward })
                    .unwrap();
                let dp = Datapath::new(
                    Some(FixedSpec::default()),
                    Activation::lut_default(Some(FixedSpec::default())),
                );
                let want = qupdate::qupdate(&cfg, &params, &sa_cur, &sa_next, action, reward,
                                            &Hyper::default(), &dp)
                    .unwrap();
                assert!(
                    (out.q_err - want.q_err).abs() <= 4.0 * lsb,
                    "{arch:?}/{env:?}: q_err {} vs {}",
                    out.q_err,
                    want.q_err
                );
                assert!(
                    out.params.max_abs_diff(&want.params) <= 4.0 * lsb,
                    "{arch:?}/{env:?}: params diverged"
                );
            }
        }
    }

    #[test]
    fn forward_outputs_are_quantized_in_fixed_mode() {
        let (cfg, _, mut acc) = setup(Arch::Mlp, EnvKind::Simple, Precision::Fixed);
        let mut rng = Rng::seeded(14);
        let sa = rng.vec_f32(cfg.a * cfg.d, -1.0, 1.0);
        let (q, cycles) = acc.forward(&sa).unwrap();
        assert_eq!(q.len(), cfg.a);
        assert_eq!(cycles, TimingModel::default().forward_cycles(&cfg, Precision::Fixed));
        let spec = FixedSpec::default();
        for v in q {
            let back = Fixed::from_f32(v, spec).to_f32();
            assert_eq!(v, back, "Q-value not on the Q(18,12) grid");
        }
    }

    #[test]
    fn cycle_counters_accumulate() {
        let (cfg, _, mut acc) = setup(Arch::Perceptron, EnvKind::Simple, Precision::Fixed);
        let mut rng = Rng::seeded(15);
        let (sa_cur, sa_next, action, reward) = transition(&cfg, &mut rng);
        let per_update = TimingModel::default().qupdate(&cfg, Precision::Fixed).total();
        for i in 1..=5u64 {
            acc.qupdate(&Transition { sa_cur: &sa_cur, sa_next: &sa_next, action, reward })
                .unwrap();
            assert_eq!(acc.stats().updates, i);
            assert_eq!(acc.stats().cycles, i * per_update);
        }
        // 7A+1 at A=6 → 43 cycles per update
        assert_eq!(per_update, 43);
    }

    #[test]
    fn learning_happens_on_fixed_datapath() {
        let (cfg, _, mut acc) = setup(Arch::Mlp, EnvKind::Simple, Precision::Fixed);
        let mut rng = Rng::seeded(16);
        let (sa_cur, sa_next, _, _) = transition(&cfg, &mut rng);
        let mut first = None;
        let mut last = 0f32;
        // stationary target: repeated updates must reduce |q_err|
        for _ in 0..200 {
            let (out, _) = acc
                .qupdate(&Transition { sa_cur: &sa_cur, sa_next: &sa_next, action: 1, reward: 0.9 })
                .unwrap();
            last = out.q_err.abs();
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap(), "{first:?} -> {last}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let (_, _, mut acc) = setup(Arch::Perceptron, EnvKind::Simple, Precision::Fixed);
        let short = vec![0f32; 5];
        assert!(acc.forward(&short).is_err());
        let ok = vec![0f32; 36];
        assert!(acc
            .qupdate(&Transition { sa_cur: &ok, sa_next: &ok, action: 99, reward: 0.0 })
            .is_err());
    }

    /// Binary mode must delegate to the `nn` kernel bit-exactly, like the
    /// float path — the cross-backend backbone of the binary arm.
    #[test]
    fn binary_mode_matches_cpu_nn_exactly() {
        for arch in [Arch::Perceptron, Arch::Mlp] {
            let (cfg, params, mut acc) = setup(arch, EnvKind::Simple, Precision::Binary);
            let mut rng = Rng::seeded(19);
            let (sa_cur, sa_next, action, reward) = transition(&cfg, &mut rng);
            let (out, _) = acc
                .qupdate(&Transition { sa_cur: &sa_cur, sa_next: &sa_next, action, reward })
                .unwrap();
            let dp = Datapath::for_precision(Precision::Binary);
            let want =
                qupdate::qupdate(&cfg, &params, &sa_cur, &sa_next, action, reward,
                                 &Hyper::default(), &dp)
                    .unwrap();
            assert_eq!(out.q_err, want.q_err, "{arch:?}");
            assert_eq!(out.params, want.params, "{arch:?}");
            assert_eq!(out.q_cur, want.q_cur, "{arch:?}");
            // updated weights live on the ±1 sign grid
            for t in out.params.to_tensors() {
                for v in t {
                    assert!(v == 1.0 || v == -1.0, "{arch:?}: off-grid weight {v}");
                }
            }
        }
    }

    /// Int8 mode is the integer datapath pinned to Q(8,4): it must track
    /// the CPU fake-quant arm within the same per-update LSB budget the
    /// Q(18,12) fixed mode honors.
    #[test]
    fn int8_mode_tracks_fakequant_nn_within_lsb_budget() {
        let lsb = FixedSpec::int8().lsb() as f32;
        for arch in [Arch::Perceptron, Arch::Mlp] {
            let (cfg, params, mut acc) = setup(arch, EnvKind::Simple, Precision::Int8);
            let mut rng = Rng::seeded(20);
            let (sa_cur, sa_next, action, reward) = transition(&cfg, &mut rng);
            let (out, _) = acc
                .qupdate(&Transition { sa_cur: &sa_cur, sa_next: &sa_next, action, reward })
                .unwrap();
            let want = qupdate::qupdate(
                &cfg,
                &params,
                &sa_cur,
                &sa_next,
                action,
                reward,
                &Hyper::default(),
                &Datapath::for_precision(Precision::Int8),
            )
            .unwrap();
            assert!(
                (out.q_err - want.q_err).abs() <= 4.0 * lsb,
                "{arch:?}: q_err {} vs {}",
                out.q_err,
                want.q_err
            );
            assert!(
                out.params.max_abs_diff(&want.params) <= 4.0 * lsb,
                "{arch:?}: params diverged"
            );
            // the word format really is pinned: Q-values land on the Q(8,4)
            // grid even when a wider spec was requested
            let spec = FixedSpec::int8();
            let wide = FpgaAccelerator::with_spec(
                cfg,
                Precision::Int8,
                &params,
                Hyper::default(),
                TimingModel::default(),
                FixedSpec::default(),
            );
            for v in wide.params().to_tensors().concat() {
                assert_eq!(v, Fixed::from_f32(v, spec).to_f32(), "off the Q(8,4) grid");
            }
        }
    }

    #[test]
    fn batched_qupdate_matches_stepwise_and_charges_pipelined_cycles() {
        for prec in Precision::all() {
            let (cfg, params, mut batched) = setup(Arch::Mlp, EnvKind::Simple, prec);
            let mut stepwise = FpgaAccelerator::paper(cfg, prec, &params, Hyper::default());
            let mut rng = Rng::seeded(17);
            let n = 6;
            let step = cfg.a * cfg.d;
            let sa_cur = rng.vec_f32(n * step, -1.0, 1.0);
            let sa_next = rng.vec_f32(n * step, -1.0, 1.0);
            let actions: Vec<usize> = (0..n).map(|_| rng.below(cfg.a)).collect();
            let rewards = rng.vec_f32(n, -1.0, 1.0);

            let got = batched.qupdate_batch(&sa_cur, &sa_next, &actions, &rewards).unwrap();
            let mut want = Vec::new();
            for i in 0..n {
                let (out, _) = stepwise
                    .qupdate(&Transition {
                        sa_cur: &sa_cur[i * step..(i + 1) * step],
                        sa_next: &sa_next[i * step..(i + 1) * step],
                        action: actions[i],
                        reward: rewards[i],
                    })
                    .unwrap();
                want.push(out.q_err);
            }
            // numerics: identical datapath, identical bits
            assert_eq!(got, want, "{prec:?}");
            assert_eq!(
                batched.params().max_abs_diff(&stepwise.params()),
                0.0,
                "{prec:?}"
            );
            // accounting: the batched charge follows the batch cycle model
            let expect = TimingModel::default().qupdate_batch_cycles(&cfg, prec, n);
            assert_eq!(batched.stats().cycles, expect, "{prec:?}");
            assert_eq!(batched.stats().updates, n as u64);
            assert_eq!(batched.stats().batches, 1);
        }
    }

    #[test]
    fn batched_qupdate_validates_before_mutating() {
        let (cfg, _, mut acc) = setup(Arch::Perceptron, EnvKind::Simple, Precision::Fixed);
        let before = acc.params();
        let step = cfg.a * cfg.d;
        let sa = vec![0.25f32; 2 * step];
        // second action out of range: nothing may be applied
        let r = acc.qupdate_batch(&sa, &sa, &[0, cfg.a], &[0.1, 0.2]);
        assert!(r.is_err());
        assert_eq!(acc.stats().updates, 0);
        assert_eq!(acc.stats().cycles, 0);
        assert_eq!(acc.params().max_abs_diff(&before), 0.0);
        // empty batch: no-op
        assert!(acc.qupdate_batch(&[], &[], &[], &[]).unwrap().is_empty());
        assert_eq!(acc.stats().batches, 0);
    }

    #[test]
    fn seu_hook_perturbs_fixed_updates_deterministically() {
        use crate::fault::{Mitigation, SeuHook};
        let run = |hot: bool| {
            let (cfg, _, mut acc) = setup(Arch::Mlp, EnvKind::Simple, Precision::Fixed);
            if hot {
                // ~0.05 upsets/bit/update over 2×6 buffered 18-bit words
                acc.set_seu_hook(Some(SeuHook::new(77, 0.05, Mitigation::None)));
            }
            let mut rng = Rng::seeded(18);
            let (sa_cur, sa_next, action, reward) = transition(&cfg, &mut rng);
            let mut errs = Vec::new();
            for _ in 0..50 {
                let (out, _) = acc
                    .qupdate(&Transition { sa_cur: &sa_cur, sa_next: &sa_next, action, reward })
                    .unwrap();
                errs.push(out.q_err);
            }
            (errs, acc.seu_stats())
        };
        let (clean, no_stats) = run(false);
        assert!(no_stats.is_none());
        let (hot_a, stats_a) = run(true);
        let (hot_b, stats_b) = run(true);
        // deterministic under a seed, and actually perturbing the datapath
        assert_eq!(hot_a, hot_b);
        assert_eq!(stats_a.unwrap(), stats_b.unwrap());
        assert!(stats_a.unwrap().transient > 0);
        assert_ne!(clean, hot_a);
    }

    #[test]
    fn load_params_roundtrip_float() {
        let (cfg, params, mut acc) = setup(Arch::Mlp, EnvKind::Simple, Precision::Float);
        assert_eq!(acc.params(), params);
        let zero = QNetParams::zeros(&cfg);
        acc.load_params(&zero);
        assert_eq!(acc.params(), zero);
    }
}
