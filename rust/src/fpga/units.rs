//! Functional-unit models: latency, initiation interval and resource cost.
//!
//! The fixed-vs-float gap in the paper's tables is driven entirely by these
//! unit characteristics:
//!
//! * **Fixed point**: DSP48E1 multipliers are 1-cycle at 150 MHz and cheap,
//!   so the design instantiates one multiplier *per input weight* (the
//!   paper's “fine-grained parallelism”) plus a 1-cycle balanced adder tree
//!   and a 1-cycle sigmoid ROM read.
//! * **Floating point**: LogiCORE FP cores are multi-cycle and large
//!   (hundreds of LUTs + several DSPs each), so only one MAC chain fits per
//!   layer and elements are processed serially, pipelined at the adder's
//!   initiation interval.
//!
//! Default latencies are LogiCORE Floating-Point Operator (v7.x)-class
//! values for a 150 MHz Virtex-7 design: multiplier 8 cycles, adder 11
//! cycles. The sigmoid is a LUT in both modes (paper Section 3); in float
//! mode indexing costs a float→fixed address conversion.

/// Timing/size characteristics of the datapath's functional units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FuTimings {
    /// Fixed multiply (DSP48), cycles.
    pub fx_mul: u64,
    /// One balanced adder-tree level (fixed), cycles. The paper's datapath
    /// registers the whole tree + bias in a single stage.
    pub fx_tree: u64,
    /// Sigmoid/derivative ROM read, cycles (BRAM synchronous read).
    pub rom_read: u64,
    /// Floating multiply latency, cycles.
    pub fp_mul: u64,
    /// Floating add latency, cycles (also the serial MAC initiation
    /// interval — the accumulator carries a loop dependence).
    pub fp_add: u64,
    /// Floating compare, cycles (error-capture max scan in float mode).
    pub fp_cmp: u64,
    /// Float→fixed conversion for ROM addressing, cycles.
    pub fp_to_fx: u64,
    /// Fixed compare, cycles.
    pub fx_cmp: u64,
    /// FIFO push/pop, cycles (overlapped with compute when pipelined).
    pub fifo_rw: u64,
}

impl Default for FuTimings {
    fn default() -> Self {
        FuTimings {
            fx_mul: 1,
            fx_tree: 1,
            rom_read: 1,
            fp_mul: 8,
            fp_add: 11,
            fp_cmp: 2,
            fp_to_fx: 2,
            fx_cmp: 1,
            fifo_rw: 1,
        }
    }
}

/// Resource footprint of one unit instance (DS180/LogiCORE-class numbers).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    pub luts: u64,
    pub ffs: u64,
    pub dsps: u64,
    /// BRAM36 equivalents (two 18 Kb halves).
    pub bram36: u64,
}

impl Resources {
    pub const fn new(luts: u64, ffs: u64, dsps: u64, bram36: u64) -> Self {
        Resources { luts, ffs, dsps, bram36 }
    }

    pub fn add(&mut self, other: Resources) {
        self.luts += other.luts;
        self.ffs += other.ffs;
        self.dsps += other.dsps;
        self.bram36 += other.bram36;
    }

    pub fn scaled(&self, n: u64) -> Resources {
        Resources {
            luts: self.luts * n,
            ffs: self.ffs * n,
            dsps: self.dsps * n,
            bram36: self.bram36 * n,
        }
    }
}

/// Per-instance resource costs.
pub mod cost {
    use super::Resources;

    /// Fixed 18×18 multiplier: one DSP48E1 + routing registers.
    pub const FX_MUL: Resources = Resources::new(10, 40, 1, 0);
    /// Fixed adder (one tree node), 18-bit.
    pub const FX_ADD: Resources = Resources::new(20, 18, 0, 0);
    /// 8×8 multiplier for the `Precision::Int8` arm: still one DSP48E1
    /// (1-cycle at any operand width) but thinner routing/pipeline
    /// registers than the Q(18,12) unit.
    pub const INT8_MUL: Resources = Resources::new(6, 18, 1, 0);
    /// 8-bit adder (one tree node) for the Int8 arm.
    pub const INT8_ADD: Resources = Resources::new(9, 9, 0, 0);
    /// One weight's slice of a binary XNOR + popcount dot product:
    /// an XNOR gate plus its amortized share of the popcount compressor
    /// tree — pure LUT fabric, zero DSPs.
    pub const XNOR_POP: Resources = Resources::new(2, 2, 0, 0);
    /// Sigmoid + derivative ROM pair (1024 × 18 bit each → one BRAM36).
    pub const SIGMOID_ROM: Resources = Resources::new(30, 20, 0, 1);
    /// FIFO Q-buffer (A ≤ 64 entries × 18/32 bit → LUTRAM + control).
    pub const FIFO: Resources = Resources::new(80, 60, 0, 0);
    /// LogiCORE single-precision multiplier.
    pub const FP_MUL: Resources = Resources::new(700, 850, 3, 0);
    /// LogiCORE single-precision adder.
    pub const FP_ADD: Resources = Resources::new(850, 950, 2, 0);
    /// Float comparator.
    pub const FP_CMP: Resources = Resources::new(120, 80, 0, 0);
    /// Control FSM + address generators per block.
    pub const CONTROL: Resources = Resources::new(350, 420, 0, 0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_reflect_fixed_vs_float_gap() {
        let t = FuTimings::default();
        assert_eq!(t.fx_mul, 1);
        assert!(t.fp_mul > 4 * t.fx_mul);
        assert!(t.fp_add > t.fp_mul / 2);
    }

    #[test]
    fn resource_accumulation() {
        let mut r = Resources::default();
        r.add(cost::FX_MUL.scaled(6));
        r.add(cost::SIGMOID_ROM);
        assert_eq!(r.dsps, 6);
        assert_eq!(r.bram36, 1);
        assert_eq!(r.luts, 6 * 10 + 30);
    }

    #[test]
    fn fp_cores_dwarf_fixed_units() {
        assert!(cost::FP_MUL.luts > 20 * cost::FX_MUL.luts);
        assert!(cost::FP_ADD.dsps >= 2);
    }

    /// The narrow arms must be strictly cheaper per unit: Int8 keeps the
    /// one-DSP multiplier but sheds fabric; Binary is DSP-free entirely.
    #[test]
    fn narrow_units_are_cheaper() {
        assert_eq!(cost::INT8_MUL.dsps, 1);
        assert!(cost::INT8_MUL.luts < cost::FX_MUL.luts);
        assert!(cost::INT8_MUL.ffs < cost::FX_MUL.ffs);
        assert!(cost::INT8_ADD.luts < cost::FX_ADD.luts);
        assert_eq!(cost::XNOR_POP.dsps, 0);
        assert_eq!(cost::XNOR_POP.bram36, 0);
        assert!(cost::XNOR_POP.luts < cost::INT8_ADD.luts);
    }
}
