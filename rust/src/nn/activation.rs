//! Sigmoid activation: exact form and the paper's ROM/LUT form.
//!
//! “We utilize a Look-up Table approach, which stores the pre-calculated
//! values of the sigmoid values. … The derivative of the sigmoid is also
//! implemented using a Look-up Table (ROM)” (paper, Section 3).

use crate::fixed::{Fixed, FixedSpec};

/// Exact logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Exact sigmoid derivative expressed in the pre-activation σ.
#[inline]
pub fn sigmoid_deriv(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 - s)
}

/// ROM geometry: `size` entries sampled uniformly over [−xmax, xmax].
/// Must match `python/compile/configs.py::LutSpec`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LutSpec {
    pub size: usize,
    pub xmax: f32,
}

impl Default for LutSpec {
    fn default() -> Self {
        LutSpec { size: 1024, xmax: 8.0 }
    }
}

impl LutSpec {
    /// Address generator: clip to range, map to nearest entry
    /// (round-half-even, matching `jnp.round`).
    #[inline]
    pub fn index(&self, x: f32) -> usize {
        let xc = x.clamp(-self.xmax, self.xmax);
        let pos =
            (xc + self.xmax) as f64 / (2.0 * self.xmax as f64) * (self.size - 1) as f64;
        pos.round_ties_even() as usize
    }

    /// ROM word count for both tables (sigmoid + derivative).
    pub fn total_entries(&self) -> usize {
        2 * self.size
    }
}

/// The pair of ROMs: sigmoid values and derivative values, pre-computed at
/// build time (on the FPGA: BRAM init data; in the artifacts: HLO constants).
#[derive(Debug, Clone)]
pub struct SigmoidLut {
    pub spec: LutSpec,
    table: Vec<f32>,
    dtable: Vec<f32>,
}

impl SigmoidLut {
    /// Build the ROMs; with `fixed` set the stored words are quantized to
    /// the datapath grid, as they would be in an 18-bit-wide BRAM.
    pub fn build(spec: LutSpec, fixed: Option<FixedSpec>) -> Self {
        let n = spec.size;
        let mut table = Vec::with_capacity(n);
        let mut dtable = Vec::with_capacity(n);
        for i in 0..n {
            // f64 grid math matches numpy's linspace closely enough that the
            // stored f32 words agree bit-for-bit for all tested specs.
            let x = -spec.xmax as f64
                + (2.0 * spec.xmax as f64) * i as f64 / (n - 1) as f64;
            let s = 1.0 / (1.0 + (-x).exp());
            let (mut v, mut d) = (s as f32, (s * (1.0 - s)) as f32);
            if let Some(q) = fixed {
                v = Fixed::from_f32(v, q).to_f32();
                d = Fixed::from_f32(d, q).to_f32();
            }
            table.push(v);
            dtable.push(d);
        }
        SigmoidLut { spec, table, dtable }
    }

    /// One BRAM read: f(σ).
    #[inline]
    pub fn lookup(&self, x: f32) -> f32 {
        self.table[self.spec.index(x)]
    }

    /// One BRAM read: f′(σ).
    #[inline]
    pub fn lookup_deriv(&self, x: f32) -> f32 {
        self.dtable[self.spec.index(x)]
    }

    /// Maximum absolute error of the stored table vs the exact sigmoid,
    /// evaluated on a dense probe grid — the X2 ablation metric.
    pub fn max_abs_error(&self, probes: usize) -> f32 {
        let mut worst = 0f32;
        for i in 0..probes {
            let x = -self.spec.xmax
                + 2.0 * self.spec.xmax * i as f32 / (probes - 1) as f32;
            let err = (self.lookup(x) - sigmoid(x)).abs();
            worst = worst.max(err);
        }
        worst
    }
}

/// Datapath activation selector.
#[derive(Debug, Clone)]
pub enum Activation {
    /// Exact sigmoid (ablation reference).
    Exact,
    /// ROM lookup — the paper's implementation.
    Lut(SigmoidLut),
}

impl Activation {
    /// Default paper activation for a given precision.
    pub fn lut_default(fixed: Option<FixedSpec>) -> Self {
        Activation::Lut(SigmoidLut::build(LutSpec::default(), fixed))
    }

    #[inline]
    pub fn f(&self, x: f32) -> f32 {
        match self {
            Activation::Exact => sigmoid(x),
            Activation::Lut(l) => l.lookup(x),
        }
    }

    #[inline]
    pub fn fprime(&self, x: f32) -> f32 {
        match self {
            Activation::Exact => sigmoid_deriv(x),
            Activation::Lut(l) => l.lookup_deriv(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_sigmoid_values() {
        assert_eq!(sigmoid(0.0), 0.5);
        assert!((sigmoid(8.0) - 0.99966466).abs() < 1e-6);
        assert!((sigmoid_deriv(0.0) - 0.25).abs() < 1e-7);
    }

    #[test]
    fn index_endpoints_and_center() {
        let spec = LutSpec { size: 1024, xmax: 8.0 };
        assert_eq!(spec.index(-100.0), 0);
        assert_eq!(spec.index(100.0), 1023);
        // center: 511.5 rounds half-even to 512 (matches python test)
        assert_eq!(spec.index(0.0), 512);
    }

    #[test]
    fn lut_monotone_and_bounded() {
        let lut = SigmoidLut::build(LutSpec::default(), None);
        let mut prev = -1.0f32;
        for i in 0..200 {
            let x = -10.0 + i as f32 * 0.1;
            let v = lut.lookup(x);
            assert!(v >= prev - 1e-7, "monotone at {x}");
            assert!((0.0..=1.0).contains(&v));
            prev = v;
        }
    }

    #[test]
    fn rom_size_improves_accuracy() {
        // X2 ablation shape (same budgets as the python test).
        for (size, budget) in [(64, 0.07f32), (256, 0.02), (1024, 0.006), (4096, 0.0025)] {
            let lut = SigmoidLut::build(LutSpec { size, xmax: 8.0 }, None);
            assert!(
                lut.max_abs_error(10_001) < budget,
                "size {size}: {} >= {budget}",
                lut.max_abs_error(10_001)
            );
        }
    }

    #[test]
    fn quantized_table_on_grid() {
        let q = FixedSpec::new(18, 12);
        let lut = SigmoidLut::build(LutSpec { size: 128, xmax: 8.0 }, Some(q));
        for i in 0..128 {
            let x = -8.0 + 16.0 * i as f32 / 127.0;
            let v = lut.lookup(x);
            let back = Fixed::from_f32(v, q).to_f32();
            assert_eq!(v, back, "entry {i} not on the Q(18,12) grid");
        }
    }

    #[test]
    fn deriv_peak_at_center() {
        let lut = SigmoidLut::build(LutSpec { size: 1025, xmax: 8.0 }, None);
        assert!((lut.lookup_deriv(0.0) - 0.25).abs() < 1e-6);
        assert!(lut.lookup_deriv(7.9) < 0.01);
    }
}
