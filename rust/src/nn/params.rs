//! Network parameters, in the exact layout of the AOT artifacts.
//!
//! * Perceptron: `w` (D×1, flat length D), `b` (scalar).
//! * MLP: `w1` (D×H row-major), `b1` (H), `w2` (H×1, flat length H),
//!   `b2` (scalar).

use std::path::Path;

use crate::config::{Arch, NetConfig};
use crate::error::{Error, Result};
use crate::util::{Json, Rng};

/// Parameters of a Q-network, matching the artifact tensor layout.
#[derive(Debug, Clone, PartialEq)]
pub enum QNetParams {
    Perceptron {
        /// Input weights, length D.
        w: Vec<f32>,
        /// Bias.
        b: f32,
    },
    Mlp {
        /// Hidden weights, row-major (D, H).
        w1: Vec<f32>,
        /// Hidden biases, length H.
        b1: Vec<f32>,
        /// Output weights, length H.
        w2: Vec<f32>,
        /// Output bias.
        b2: f32,
    },
}

impl QNetParams {
    /// Random init: weights ~ scale·N(0,1)-ish uniform, biases zero
    /// (the paper does not specify an init; this matches ref.init_params'
    /// spirit — small symmetric weights, zero biases).
    pub fn init(cfg: &NetConfig, scale: f32, rng: &mut Rng) -> Self {
        let mut draw = |n: usize| -> Vec<f32> { rng.vec_f32(n, -scale, scale) };
        match cfg.arch {
            Arch::Perceptron => QNetParams::Perceptron { w: draw(cfg.d), b: 0.0 },
            Arch::Mlp => QNetParams::Mlp {
                w1: draw(cfg.d * cfg.h),
                b1: vec![0.0; cfg.h],
                w2: draw(cfg.h),
                b2: 0.0,
            },
        }
    }

    /// Zero-initialized parameters.
    pub fn zeros(cfg: &NetConfig) -> Self {
        match cfg.arch {
            Arch::Perceptron => QNetParams::Perceptron { w: vec![0.0; cfg.d], b: 0.0 },
            Arch::Mlp => QNetParams::Mlp {
                w1: vec![0.0; cfg.d * cfg.h],
                b1: vec![0.0; cfg.h],
                w2: vec![0.0; cfg.h],
                b2: 0.0,
            },
        }
    }

    pub fn arch(&self) -> Arch {
        match self {
            QNetParams::Perceptron { .. } => Arch::Perceptron,
            QNetParams::Mlp { .. } => Arch::Mlp,
        }
    }

    /// Number of parameter tensors as passed to the artifacts (2 or 4).
    pub fn n_tensors(&self) -> usize {
        match self {
            QNetParams::Perceptron { .. } => 2,
            QNetParams::Mlp { .. } => 4,
        }
    }

    /// Total scalar parameter count.
    pub fn n_scalars(&self) -> usize {
        match self {
            QNetParams::Perceptron { w, .. } => w.len() + 1,
            QNetParams::Mlp { w1, b1, w2, .. } => w1.len() + b1.len() + w2.len() + 1,
        }
    }

    /// Flatten into per-tensor vectors in artifact order.
    pub fn to_tensors(&self) -> Vec<Vec<f32>> {
        match self {
            QNetParams::Perceptron { w, b } => vec![w.clone(), vec![*b]],
            QNetParams::Mlp { w1, b1, w2, b2 } => {
                vec![w1.clone(), b1.clone(), w2.clone(), vec![*b2]]
            }
        }
    }

    /// Rebuild from per-tensor vectors in artifact order.
    pub fn from_tensors(cfg: &NetConfig, tensors: &[Vec<f32>]) -> Result<Self> {
        let bad = |msg: &str| Error::interface(format!("params from_tensors: {msg}"));
        match cfg.arch {
            Arch::Perceptron => {
                if tensors.len() != 2 {
                    return Err(bad("expected 2 tensors"));
                }
                if tensors[0].len() != cfg.d || tensors[1].len() != 1 {
                    return Err(bad("perceptron tensor shapes"));
                }
                Ok(QNetParams::Perceptron { w: tensors[0].clone(), b: tensors[1][0] })
            }
            Arch::Mlp => {
                if tensors.len() != 4 {
                    return Err(bad("expected 4 tensors"));
                }
                if tensors[0].len() != cfg.d * cfg.h
                    || tensors[1].len() != cfg.h
                    || tensors[2].len() != cfg.h
                    || tensors[3].len() != 1
                {
                    return Err(bad("mlp tensor shapes"));
                }
                Ok(QNetParams::Mlp {
                    w1: tensors[0].clone(),
                    b1: tensors[1].clone(),
                    w2: tensors[2].clone(),
                    b2: tensors[3][0],
                })
            }
        }
    }

    /// Serialize to JSON (mission checkpointing / cross-run hand-off).
    pub fn to_json(&self) -> Json {
        let tensors = self
            .to_tensors()
            .into_iter()
            .map(|t| Json::from_f32s(&t))
            .collect();
        Json::obj(vec![
            ("arch", Json::Str(self.arch().as_str().to_string())),
            ("tensors", Json::Arr(tensors)),
        ])
    }

    /// Deserialize from JSON produced by [`QNetParams::to_json`].
    pub fn from_json(cfg: &NetConfig, j: &Json) -> Result<Self> {
        let arch: Arch = j.req_str("arch")?.parse()?;
        if arch != cfg.arch {
            return Err(Error::interface(format!(
                "checkpoint arch {} != config arch {}",
                arch.as_str(),
                cfg.arch.as_str()
            )));
        }
        let tensors = j
            .req_arr("tensors")?
            .iter()
            .map(|t| {
                t.as_arr()
                    .ok_or_else(|| Error::interface("tensor not an array"))?
                    .iter()
                    .map(|v| {
                        v.as_f64()
                            .map(|x| x as f32)
                            .ok_or_else(|| Error::interface("non-numeric weight"))
                    })
                    .collect::<Result<Vec<f32>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        Self::from_tensors(cfg, &tensors)
    }

    /// Write a checkpoint file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load a checkpoint file.
    pub fn load(cfg: &NetConfig, path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(cfg, &Json::parse(&text)?)
    }

    /// Max |Δ| between two parameter sets (convergence / equivalence metric).
    pub fn max_abs_diff(&self, other: &QNetParams) -> f32 {
        let a = self.to_tensors();
        let b = other.to_tensors();
        let mut worst = 0f32;
        for (ta, tb) in a.iter().zip(&b) {
            for (x, y) in ta.iter().zip(tb) {
                worst = worst.max((x - y).abs());
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvKind;

    #[test]
    fn tensors_roundtrip() {
        let mut rng = Rng::seeded(1);
        for cfg in NetConfig::all() {
            let p = QNetParams::init(&cfg, 0.5, &mut rng);
            let t = p.to_tensors();
            let back = QNetParams::from_tensors(&cfg, &t).unwrap();
            assert_eq!(p, back);
            assert_eq!(p.n_scalars(), cfg.n_params());
        }
    }

    #[test]
    fn from_tensors_validates_shapes() {
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let bad = vec![vec![0.0; 3]; 4];
        assert!(QNetParams::from_tensors(&cfg, &bad).is_err());
        let wrong_arity = vec![vec![0.0; 6]];
        assert!(QNetParams::from_tensors(&cfg, &wrong_arity).is_err());
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let a = QNetParams::init(&cfg, 0.5, &mut Rng::seeded(9));
        let b = QNetParams::init(&cfg, 0.5, &mut Rng::seeded(9));
        assert_eq!(a, b);
        let c = QNetParams::init(&cfg, 0.5, &mut Rng::seeded(10));
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn json_checkpoint_roundtrip() {
        let mut rng = Rng::seeded(77);
        for cfg in NetConfig::all() {
            let p = QNetParams::init(&cfg, 0.5, &mut rng);
            let j = p.to_json();
            let back = QNetParams::from_json(&cfg, &j).unwrap();
            // JSON round-trips f32 through f64 text — exact for f32 values
            assert!(p.max_abs_diff(&back) < 1e-6);
        }
    }

    #[test]
    fn checkpoint_file_roundtrip_and_arch_check() {
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let p = QNetParams::init(&cfg, 0.5, &mut Rng::seeded(78));
        let dir = std::env::temp_dir().join("qfpga_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mlp.json");
        p.save(&path).unwrap();
        let back = QNetParams::load(&cfg, &path).unwrap();
        assert!(p.max_abs_diff(&back) < 1e-6);
        // wrong arch must be rejected
        let wrong = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        assert!(QNetParams::load(&wrong, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn max_abs_diff_zero_on_self() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let p = QNetParams::zeros(&cfg);
        assert_eq!(p.max_abs_diff(&p), 0.0);
    }
}
