//! Feed-forward + Q-update numeric core (the CPU baseline datapath).
//!
//! Mirrors `python/compile/kernels/ref.py` operation-for-operation so the
//! three backends (XLA artifact, this module, FPGA simulator) can be
//! cross-validated. All math is f32 with optional fake-quantization after
//! every register-level value, exactly like the python oracle.
//!
//! # Kernel dispatch
//!
//! The MAC-dominated inner loops exist in two implementations behind
//! [`KernelPath`]:
//!
//! * [`KernelPath::Scalar`] — the reference loops, one multiply-accumulate
//!   at a time, exactly as the python oracle orders them;
//! * [`KernelPath::Simd`] — chunked lane-parallel loops shaped for the
//!   compiler's auto-vectorizer (contiguous `w1` hidden rows, action-lane
//!   blocking for the perceptron). Every lane keeps its own accumulator in
//!   the **same index order** as the scalar loop and no FMA contraction is
//!   used, so the two paths are bit-identical — a guarantee enforced by
//!   `tests/kernel_conformance.rs` across every precision arm.
//!
//! The process-wide default is [`KernelPath::Simd`]; set `QFPGA_KERNEL=scalar`
//! in the environment to force the reference loops (debugging, A/B timing),
//! or pin a path in-process with [`Datapath::with_kernel`]. Backprop loops
//! quantize after every element and are therefore elementwise (one code
//! path, trivially order-identical).

use std::sync::OnceLock;

use crate::config::{Hyper, NetConfig, Precision};
use crate::error::{Error, Result};
use crate::fixed::{FixedSpec, Quantizer};

use super::activation::Activation;
use super::params::QNetParams;

/// Which inner-loop implementation a [`Datapath`] dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Reference loops: one MAC at a time, python-oracle order.
    Scalar,
    /// Chunked lane-parallel loops (order-preserving, autovectorizable;
    /// bit-identical to [`KernelPath::Scalar`] by construction).
    Simd,
}

impl KernelPath {
    /// Process-wide default, resolved once: [`KernelPath::Simd`] unless
    /// `QFPGA_KERNEL=scalar` is set in the environment (the CI conformance
    /// job runs the whole suite both ways).
    pub fn from_env() -> KernelPath {
        static PATH: OnceLock<KernelPath> = OnceLock::new();
        *PATH.get_or_init(|| match std::env::var("QFPGA_KERNEL") {
            Ok(v) if v == "scalar" => KernelPath::Scalar,
            _ => KernelPath::Simd,
        })
    }
}

/// Register-write quantization rule of a [`Datapath`].
#[derive(Debug, Clone)]
enum QuantKind {
    /// float32: registers pass through untouched.
    Exact,
    /// Fake-quantize onto a Q(word, frac) grid (fixed and int8 arms).
    Grid(Quantizer),
    /// Binarize to the ±1 sign grid (the BNN arm). `sign(0) = +1`, so the
    /// rule is deterministic and idempotent.
    Sign,
}

/// Datapath configuration: arithmetic grid + activation implementation.
#[derive(Debug, Clone)]
pub struct Datapath {
    /// `None` -> float32 or binary; `Some(spec)` -> fake-quantized fixed
    /// point (including the int8 arm's Q(8,4)).
    pub precision: Option<FixedSpec>,
    pub activation: Activation,
    /// Register quantization rule (kept in sync with `precision`).
    quant: QuantKind,
    /// Inner-loop implementation the kernels dispatch to.
    kernel: KernelPath,
}

impl Datapath {
    /// Build a datapath; use this (not a struct literal) so the precomputed
    /// quantizer stays in sync with `precision`. The kernel path defaults
    /// to [`KernelPath::from_env`].
    pub fn new(precision: Option<FixedSpec>, activation: Activation) -> Self {
        let quant = match precision {
            None => QuantKind::Exact,
            Some(spec) => QuantKind::Grid(Quantizer::new(spec)),
        };
        Datapath { precision, activation, quant, kernel: KernelPath::from_env() }
    }

    /// Paper-default datapath for a precision: LUT sigmoid, Q(18,12) grid
    /// when fixed.
    pub fn paper(fixed: Option<FixedSpec>) -> Self {
        Self::new(fixed, Activation::lut_default(fixed))
    }

    /// Default datapath for a [`Precision`] arm: `Fixed`/`Float` as
    /// [`Datapath::paper`], `Int8` on the canonical Q(8,4) grid
    /// ([`FixedSpec::int8`]), `Binary` on the ±1 sign grid with a float
    /// sigmoid LUT.
    pub fn for_precision(prec: Precision) -> Self {
        Self::for_precision_spec(prec, FixedSpec::default())
    }

    /// Like [`Datapath::for_precision`] but with an explicit fixed-point
    /// format for the `Fixed` arm (word-length sweeps). `Int8` always uses
    /// Q(8,4); the spec is ignored by the float and binary arms.
    pub fn for_precision_spec(prec: Precision, spec: FixedSpec) -> Self {
        match prec {
            Precision::Fixed => Self::new(Some(spec), Activation::lut_default(Some(spec))),
            Precision::Float => Self::new(None, Activation::lut_default(None)),
            Precision::Int8 => {
                let s = FixedSpec::int8();
                Self::new(Some(s), Activation::lut_default(Some(s)))
            }
            Precision::Binary => Datapath {
                precision: None,
                activation: Activation::lut_default(None),
                quant: QuantKind::Sign,
                kernel: KernelPath::from_env(),
            },
        }
    }

    /// Pin the kernel path, overriding the environment default (the
    /// conformance suite forces both paths in one process).
    pub fn with_kernel(mut self, kernel: KernelPath) -> Self {
        self.kernel = kernel;
        self
    }

    /// The inner-loop implementation this datapath dispatches to.
    pub fn kernel(&self) -> KernelPath {
        self.kernel
    }

    /// Whether registers are binarized to the ±1 sign grid.
    pub fn is_binary(&self) -> bool {
        matches!(self.quant, QuantKind::Sign)
    }

    /// Quantize one register value (identity in float mode).
    #[inline(always)]
    pub fn q(&self, x: f32) -> f32 {
        match &self.quant {
            QuantKind::Exact => x,
            QuantKind::Grid(q) => q.q(x),
            QuantKind::Sign => {
                if x < 0.0 {
                    -1.0
                } else {
                    1.0
                }
            }
        }
    }
}

/// Feed-forward internals needed by backprop (python `forward_full`).
#[derive(Debug, Clone, Default)]
pub struct ForwardTrace {
    /// Q-values, length A.
    pub q: Vec<f32>,
    /// Output pre-activations σ, length A.
    pub pre2: Vec<f32>,
    /// Hidden activations, row-major (A, H). Empty for the perceptron.
    pub hid: Vec<f32>,
    /// Hidden pre-activations, row-major (A, H). Empty for the perceptron.
    pub pre1: Vec<f32>,
}

/// Result of one full Q-update.
#[derive(Debug, Clone)]
pub struct QUpdateOutput {
    pub params: QNetParams,
    pub q_cur: Vec<f32>,
    pub q_next: Vec<f32>,
    pub q_err: f32,
}

#[inline]
#[allow(dead_code)] // kept as the scalar-path reference for dot-product reviews
fn dot_q(dp: &Datapath, x: &[f32], w: &[f32]) -> f32 {
    // f32 accumulation in index order, matching jnp.matmul closely enough
    // for the 1e-6 cross-checks; rounded once afterwards in fixed mode.
    let mut acc = 0f32;
    for (a, b) in x.iter().zip(w) {
        acc += a * b;
    }
    dp.q(acc)
}

/// Feed-forward for all A actions; `sa` is row-major (A, D).
///
/// This is the convenience/reference entry point: it quantizes a working
/// copy of the parameters once and runs the shared scratch kernel
/// ([`forward_into`]) — one code path for both architectures. Hot loops
/// should hold a [`PreparedNet`] instead, which caches the on-grid
/// parameters and reuses the scratch buffers across calls.
pub fn forward_full(
    cfg: &NetConfig,
    params: &QNetParams,
    sa: &[f32],
    dp: &Datapath,
) -> Result<ForwardTrace> {
    let mut on_grid = params.clone();
    quantize_params_in_place(&mut on_grid, dp);
    let mut sa_q = Vec::with_capacity(sa.len());
    let mut trace = ForwardTrace::default();
    forward_into(cfg, &on_grid, sa, dp, &mut sa_q, &mut trace)?;
    Ok(trace)
}

/// Q-values only (action-selection path).
pub fn forward(
    cfg: &NetConfig,
    params: &QNetParams,
    sa: &[f32],
    dp: &Datapath,
) -> Result<Vec<f32>> {
    Ok(forward_full(cfg, params, sa, dp)?.q)
}

/// Eq. 8: Q_error = α·(r + γ·max_a′ Q(s′,a′) − Q(s,a)).
pub fn q_error(dp: &Datapath, hyper: &Hyper, q_sa: f32, q_next_max: f32, reward: f32) -> f32 {
    let target = dp.q(reward + dp.q(hyper.gamma * q_next_max));
    dp.q(hyper.alpha * dp.q(target - q_sa))
}

/// One full paper Q-update (two sweeps + error capture + backprop).
///
/// NOTE: [`qupdate_batch`] applies the identical op chain in place over
/// reused buffers; any numeric change here must be mirrored there (the
/// conformance suite in `tests/batch_equiv.rs` enforces bit-equality).
#[allow(clippy::too_many_arguments)]
pub fn qupdate(
    cfg: &NetConfig,
    params: &QNetParams,
    sa_cur: &[f32],
    sa_next: &[f32],
    action: usize,
    reward: f32,
    hyper: &Hyper,
    dp: &Datapath,
) -> Result<QUpdateOutput> {
    if action >= cfg.a {
        return Err(Error::Env(format!("action {action} out of range 0..{}", cfg.a)));
    }
    let qz = |x: f32| dp.q(x);

    let cur = forward_full(cfg, params, sa_cur, dp)?;
    let nxt = forward_full(cfg, params, sa_next, dp)?;

    let q_next_max = nxt.q.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let err = q_error(dp, hyper, cur.q[action], q_next_max, reward);

    let d = cfg.d;
    let x_row: Vec<f32> = sa_cur[action * d..(action + 1) * d]
        .iter()
        .map(|&x| qz(x))
        .collect();
    let lr = hyper.lr;

    let new_params = match params {
        QNetParams::Perceptron { w, b } => {
            let w_q: Vec<f32> = w.iter().map(|&x| qz(x)).collect();
            let b_q = qz(*b);
            // Eq. 7: δ = f′(σ)·Q_error
            let delta = qz(dp.activation.fprime(cur.pre2[action]) * err);
            // Eq. 9/10: ΔW = C·O·δ ; W += ΔW
            let mut w_new = Vec::with_capacity(d);
            for i in 0..d {
                let dw = qz(lr * qz(x_row[i] * delta));
                w_new.push(qz(w_q[i] + dw));
            }
            let db = qz(lr * delta);
            QNetParams::Perceptron { w: w_new, b: qz(b_q + db) }
        }
        QNetParams::Mlp { w1, b1, w2, b2 } => {
            let h = cfg.h;
            let w1_q: Vec<f32> = w1.iter().map(|&x| qz(x)).collect();
            let b1_q: Vec<f32> = b1.iter().map(|&x| qz(x)).collect();
            let w2_q: Vec<f32> = w2.iter().map(|&x| qz(x)).collect();
            let b2_q = qz(*b2);

            let s2 = cur.pre2[action];
            let o1 = &cur.hid[action * h..(action + 1) * h];
            let s1 = &cur.pre1[action * h..(action + 1) * h];

            // Eq. 11: output delta
            let d2 = qz(dp.activation.fprime(s2) * err);
            // Eq. 12: hidden deltas  δ_i = f′(σ_i)·(δ_out·W_i)
            let d1: Vec<f32> = (0..h)
                .map(|j| qz(dp.activation.fprime(s1[j]) * qz(d2 * w2_q[j])))
                .collect();
            // Eq. 13/14: ΔW generators + in-place update
            let mut w2_new = Vec::with_capacity(h);
            for j in 0..h {
                let dw2 = qz(lr * qz(o1[j] * d2));
                w2_new.push(qz(w2_q[j] + dw2));
            }
            let b2_new = qz(b2_q + qz(lr * d2));
            let mut w1_new = vec![0f32; d * h];
            for i in 0..d {
                for j in 0..h {
                    let dw1 = qz(lr * qz(x_row[i] * d1[j]));
                    w1_new[i * h + j] = qz(w1_q[i * h + j] + dw1);
                }
            }
            let b1_new: Vec<f32> =
                (0..h).map(|j| qz(b1_q[j] + qz(lr * d1[j]))).collect();
            QNetParams::Mlp { w1: w1_new, b1: b1_new, w2: w2_new, b2: b2_new }
        }
    };

    Ok(QUpdateOutput { params: new_params, q_cur: cur.q, q_next: nxt.q, q_err: err })
}

// --------------------------------------------------------------- fast path

/// Scratch buffers for the in-place update kernel: two quantized input
/// tiles, two forward traces and the hidden-delta vector. Reused across
/// calls so the steady-state fast paths — batched flushes *and* the
/// [`PreparedNet`] stepwise path — perform **no allocation**; that (plus
/// skipping the per-call weight requantization, which is an identity on the
/// on-grid weights the paths maintain) is where the CPU speedup comes from.
#[derive(Debug, Default)]
pub struct UpdateScratch {
    sa_cur_q: Vec<f32>,
    sa_next_q: Vec<f32>,
    cur: ForwardTrace,
    nxt: ForwardTrace,
    d1: Vec<f32>,
}

impl UpdateScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Former name of [`UpdateScratch`], kept for callers of the batch-only
/// era's API.
pub type BatchScratch = UpdateScratch;

/// Quantize every parameter onto the datapath grid in place (identity in
/// float mode). `qupdate` does this implicitly on every call; the batch
/// path does it once at batch entry and then keeps the weights on-grid,
/// which is bit-equivalent because quantization is idempotent.
fn quantize_params_in_place(params: &mut QNetParams, dp: &Datapath) {
    match params {
        QNetParams::Perceptron { w, b } => {
            for v in w.iter_mut() {
                *v = dp.q(*v);
            }
            *b = dp.q(*b);
        }
        QNetParams::Mlp { w1, b1, w2, b2 } => {
            for v in w1.iter_mut().chain(b1.iter_mut()).chain(w2.iter_mut()) {
                *v = dp.q(*v);
            }
            *b2 = dp.q(*b2);
        }
    }
}

/// Feed-forward into reused buffers. Identical arithmetic to
/// [`forward_full`] except the weights are *not* requantized — callers must
/// pass on-grid parameters (see [`quantize_params_in_place`]).
fn forward_into(
    cfg: &NetConfig,
    params: &QNetParams,
    sa: &[f32],
    dp: &Datapath,
    sa_q: &mut Vec<f32>,
    trace: &mut ForwardTrace,
) -> Result<()> {
    let (a_n, d) = (cfg.a, cfg.d);
    if sa.len() != a_n * d {
        return Err(Error::interface(format!(
            "sa length {} != A*D = {}",
            sa.len(),
            a_n * d
        )));
    }
    sa_q.clear();
    sa_q.extend(sa.iter().map(|&x| dp.q(x)));
    trace.q.clear();
    trace.pre2.clear();
    trace.hid.clear();
    trace.pre1.clear();

    match params {
        QNetParams::Perceptron { w, b } => {
            if w.len() != d {
                return Err(Error::interface("perceptron weight length != D"));
            }
            match dp.kernel {
                KernelPath::Scalar => forward_perceptron_scalar(a_n, d, sa_q, w, *b, dp, trace),
                KernelPath::Simd => forward_perceptron_lanes(a_n, d, sa_q, w, *b, dp, trace),
            }
        }
        QNetParams::Mlp { w1, b1, w2, b2 } => {
            let h = cfg.h;
            if w1.len() != d * h || b1.len() != h || w2.len() != h {
                return Err(Error::interface("mlp parameter shapes"));
            }
            // the lane kernel holds hidden accumulators on the stack; wider
            // hidden layers than the blocking width fall back to reference
            if dp.kernel == KernelPath::Simd && h <= MAX_HID_LANES {
                forward_mlp_lanes(a_n, d, h, sa_q, w1, b1, w2, *b2, dp, trace);
            } else {
                forward_mlp_scalar(a_n, d, h, sa_q, w1, b1, w2, *b2, dp, trace);
            }
        }
    }
    Ok(())
}

/// Quantize one output pre-activation and emit (pre2, Q) into the trace.
#[inline(always)]
fn emit_output(dp: &Datapath, trace: &mut ForwardTrace, acc_plus_b: f32) {
    let pre = dp.q(acc_plus_b);
    trace.pre2.push(pre);
    trace.q.push(dp.activation.f(pre));
}

/// Action-lane blocking width of the perceptron SIMD kernel.
const ACTION_LANES: usize = 4;
/// Widest hidden layer the MLP lane kernel keeps on the stack (paper H=4).
const MAX_HID_LANES: usize = 16;

/// Reference perceptron sweep: per action, one dot product in index order.
fn forward_perceptron_scalar(
    a_n: usize,
    d: usize,
    sa_q: &[f32],
    w: &[f32],
    b: f32,
    dp: &Datapath,
    trace: &mut ForwardTrace,
) {
    for ai in 0..a_n {
        let x = &sa_q[ai * d..(ai + 1) * d];
        let mut acc = 0f32;
        for (xi, wi) in x.iter().zip(w.iter()) {
            acc += xi * wi;
        }
        emit_output(dp, trace, acc + b);
    }
}

/// Lane-parallel perceptron sweep: [`ACTION_LANES`] independent action
/// accumulators advance together through the shared weight vector. Each
/// lane still sums `x[i]·w[i]` in ascending `i` — bit-identical to the
/// scalar sweep, but the inner block is a vectorizable broadcast-MAC.
fn forward_perceptron_lanes(
    a_n: usize,
    d: usize,
    sa_q: &[f32],
    w: &[f32],
    b: f32,
    dp: &Datapath,
    trace: &mut ForwardTrace,
) {
    let mut ai = 0usize;
    while ai + ACTION_LANES <= a_n {
        let mut acc = [0f32; ACTION_LANES];
        for (i, &wi) in w.iter().enumerate() {
            for (l, a) in acc.iter_mut().enumerate() {
                *a += sa_q[(ai + l) * d + i] * wi;
            }
        }
        for &a in &acc {
            emit_output(dp, trace, a + b);
        }
        ai += ACTION_LANES;
    }
    // ragged tail: reference order
    for at in ai..a_n {
        let x = &sa_q[at * d..(at + 1) * d];
        let mut acc = 0f32;
        for (xi, wi) in x.iter().zip(w.iter()) {
            acc += xi * wi;
        }
        emit_output(dp, trace, acc + b);
    }
}

/// Reference MLP sweep: hidden-unit-outer, input-inner (strided `w1`).
#[allow(clippy::too_many_arguments)]
fn forward_mlp_scalar(
    a_n: usize,
    d: usize,
    h: usize,
    sa_q: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: f32,
    dp: &Datapath,
    trace: &mut ForwardTrace,
) {
    for ai in 0..a_n {
        let x = &sa_q[ai * d..(ai + 1) * d];
        for j in 0..h {
            let mut acc = 0f32;
            for i in 0..d {
                acc += x[i] * w1[i * h + j];
            }
            let pre = dp.q(acc + b1[j]);
            trace.pre1.push(pre);
            trace.hid.push(dp.activation.f(pre));
        }
        let hid_row = &trace.hid[ai * h..(ai + 1) * h];
        let mut acc = 0f32;
        for j in 0..h {
            acc += hid_row[j] * w2[j];
        }
        emit_output(dp, trace, acc + b2);
    }
}

/// Lane-parallel MLP sweep: input-outer, hidden-inner over the contiguous
/// `w1[i·h .. (i+1)·h]` rows — `h` independent accumulators each summing in
/// ascending `i`, so every hidden pre-activation matches the scalar sweep
/// to the bit while the inner loop is a contiguous vectorizable
/// broadcast-MAC (the layout win `PreparedNet` already pays for).
#[allow(clippy::too_many_arguments)]
fn forward_mlp_lanes(
    a_n: usize,
    d: usize,
    h: usize,
    sa_q: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: f32,
    dp: &Datapath,
    trace: &mut ForwardTrace,
) {
    debug_assert!(h <= MAX_HID_LANES);
    for ai in 0..a_n {
        let x = &sa_q[ai * d..(ai + 1) * d];
        let mut acc = [0f32; MAX_HID_LANES];
        let acc = &mut acc[..h];
        for (i, &xi) in x.iter().enumerate() {
            for (a, &wv) in acc.iter_mut().zip(&w1[i * h..(i + 1) * h]) {
                *a += xi * wv;
            }
        }
        for (j, &a) in acc.iter().enumerate() {
            let pre = dp.q(a + b1[j]);
            trace.pre1.push(pre);
            trace.hid.push(dp.activation.f(pre));
        }
        let hid_row = &trace.hid[ai * h..(ai + 1) * h];
        let mut out = 0f32;
        for (hj, wj) in hid_row.iter().zip(w2.iter()) {
            out += hj * wj;
        }
        emit_output(dp, trace, out + b2);
    }
}

/// One full in-place Q-update over **on-grid** parameters — the shared
/// kernel of the batched and [`PreparedNet`] stepwise fast paths. Callers
/// must have quantized `params` onto the datapath grid (see
/// [`quantize_params_in_place`]) and validated `action`.
#[allow(clippy::too_many_arguments)]
fn step_on_grid(
    cfg: &NetConfig,
    params: &mut QNetParams,
    sa_cur: &[f32],
    sa_next: &[f32],
    action: usize,
    reward: f32,
    hyper: &Hyper,
    dp: &Datapath,
    scratch: &mut UpdateScratch,
) -> Result<f32> {
    let d = cfg.d;
    let lr = hyper.lr;

    forward_into(cfg, params, sa_cur, dp, &mut scratch.sa_cur_q, &mut scratch.cur)?;
    forward_into(cfg, params, sa_next, dp, &mut scratch.sa_next_q, &mut scratch.nxt)?;

    let q_next_max = scratch.nxt.q.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let err = q_error(dp, hyper, scratch.cur.q[action], q_next_max, reward);
    let x_row = &scratch.sa_cur_q[action * d..(action + 1) * d];

    // The backprop loops below quantize after every element, so they are
    // elementwise: one code path, identical bits under either kernel path.
    match params {
        QNetParams::Perceptron { w, b } => {
            // Eq. 7: δ = f′(σ)·Q_error
            let delta = dp.q(dp.activation.fprime(scratch.cur.pre2[action]) * err);
            // Eq. 9/10: ΔW = C·O·δ ; W += ΔW (in place)
            for (wi, &xi) in w.iter_mut().zip(x_row.iter()) {
                let dw = dp.q(lr * dp.q(xi * delta));
                *wi = dp.q(*wi + dw);
            }
            *b = dp.q(*b + dp.q(lr * delta));
        }
        QNetParams::Mlp { w1, b1, w2, b2 } => {
            let h = cfg.h;
            let base = action * h;
            let s2 = scratch.cur.pre2[action];

            // Eq. 11: output delta
            let d2 = dp.q(dp.activation.fprime(s2) * err);
            // Eq. 12: hidden deltas from the *pre-update* output weights
            scratch.d1.clear();
            for j in 0..h {
                let s1j = scratch.cur.pre1[base + j];
                scratch.d1.push(dp.q(dp.activation.fprime(s1j) * dp.q(d2 * w2[j])));
            }
            // Eq. 13/14: ΔW generators + in-place update
            for j in 0..h {
                let o1j = scratch.cur.hid[base + j];
                let dw2 = dp.q(lr * dp.q(o1j * d2));
                w2[j] = dp.q(w2[j] + dw2);
            }
            *b2 = dp.q(*b2 + dp.q(lr * d2));
            for (i, &xi) in x_row.iter().enumerate() {
                for (wv, &d1j) in w1[i * h..(i + 1) * h].iter_mut().zip(scratch.d1.iter()) {
                    let dw1 = dp.q(lr * dp.q(xi * d1j));
                    *wv = dp.q(*wv + dw1);
                }
            }
            for j in 0..h {
                b1[j] = dp.q(b1[j] + dp.q(lr * scratch.d1[j]));
            }
        }
    }
    Ok(err)
}

/// Validate flattened batch shapes and action ranges (shared by the free
/// [`qupdate_batch`] and [`PreparedNet::update_batch`]).
fn validate_batch(
    cfg: &NetConfig,
    sa_cur: &[f32],
    sa_next: &[f32],
    actions: &[usize],
    rewards: &[f32],
) -> Result<()> {
    let a_n = cfg.a;
    let step = a_n * cfg.d;
    let b_n = actions.len();
    if rewards.len() != b_n || sa_cur.len() != b_n * step || sa_next.len() != b_n * step {
        return Err(Error::interface(format!(
            "batch shapes: {} actions, {} rewards, {}/{} encoded elements (step {step})",
            b_n,
            rewards.len(),
            sa_cur.len(),
            sa_next.len()
        )));
    }
    for &a in actions {
        if a >= a_n {
            return Err(Error::Env(format!("action {a} out of range 0..{a_n}")));
        }
    }
    Ok(())
}

/// Apply a *sequence* of Q-updates in one call, mutating `params` in place
/// and appending one Q-error per transition to `errs`.
///
/// Bit-for-bit equivalent to calling [`qupdate`] per transition and
/// threading the parameters through (the conformance suite in
/// `tests/batch_equiv.rs` enforces this for every backend pair), but with
/// the per-call costs hoisted out of the loop: no allocation in steady
/// state, one weight quantization per batch instead of three per update.
/// Inputs are flattened (B·A·D) row-major with per-step actions/rewards.
#[allow(clippy::too_many_arguments)]
pub fn qupdate_batch(
    cfg: &NetConfig,
    params: &mut QNetParams,
    sa_cur: &[f32],
    sa_next: &[f32],
    actions: &[usize],
    rewards: &[f32],
    hyper: &Hyper,
    dp: &Datapath,
    scratch: &mut UpdateScratch,
    errs: &mut Vec<f32>,
) -> Result<()> {
    validate_batch(cfg, sa_cur, sa_next, actions, rewards)?;
    if actions.is_empty() {
        return Ok(());
    }
    quantize_params_in_place(params, dp);

    let step = cfg.a * cfg.d;
    for k in 0..actions.len() {
        let err = step_on_grid(
            cfg,
            params,
            &sa_cur[k * step..(k + 1) * step],
            &sa_next[k * step..(k + 1) * step],
            actions[k],
            rewards[k],
            hyper,
            dp,
            scratch,
        )?;
        errs.push(err);
    }
    Ok(())
}

// ------------------------------------------------------------ PreparedNet

/// Quantize-once parameter cache + reusable scratch: the stepwise hot path.
///
/// [`qupdate`] re-quantizes every weight tensor on every call (an identity
/// on weights that are already on the datapath grid — but still O(params)
/// work) and allocates fresh traces. `PreparedNet` hoists both costs out of
/// the loop the way [`qupdate_batch`] does, while keeping per-transition
/// call granularity:
///
/// * the parameters are quantized onto the grid **once**, at the first call
///   after construction or [`PreparedNet::load`], and every in-place update
///   keeps them on-grid (quantization is idempotent);
/// * forwards and updates run through [`forward_into`] /
///   [`step_on_grid`] over reused buffers — **zero steady-state heap
///   allocation**.
///
/// Bit-for-bit equivalent to the [`qupdate`] / [`forward`] reference path
/// (enforced by `tests/batch_equiv.rs`, the unit suite below and the
/// cache-soundness property in `tests/proptests.rs`). Loading arbitrary
/// (off-grid) parameters invalidates the cache; the next call re-prepares.
#[derive(Debug)]
pub struct PreparedNet {
    params: QNetParams,
    /// Whether `params` are known to be on the datapath grid.
    on_grid: bool,
    scratch: UpdateScratch,
}

impl PreparedNet {
    pub fn new(params: QNetParams) -> PreparedNet {
        PreparedNet { params, on_grid: false, scratch: UpdateScratch::new() }
    }

    /// Replace the parameters (checkpoint restore, fault injection, …).
    /// Invalidates the cache: the next call re-quantizes.
    pub fn load(&mut self, params: &QNetParams) {
        self.params.clone_from(params);
        self.on_grid = false;
    }

    /// The current parameters (on the datapath grid once any forward or
    /// update has run since the last [`PreparedNet::load`]).
    pub fn params(&self) -> &QNetParams {
        &self.params
    }

    /// Quantize the cached parameters onto the datapath grid and return
    /// them — the fleet parameter-averaging entry point: an element-wise
    /// mean of on-grid weights is generally off-grid and must land back
    /// on the grid before any rover trains on it.
    pub fn params_on_grid(&mut self, dp: &Datapath) -> &QNetParams {
        self.prepare(dp);
        &self.params
    }

    /// Quantize the parameters onto the grid if the cache is stale.
    #[inline]
    fn prepare(&mut self, dp: &Datapath) {
        if !self.on_grid {
            quantize_params_in_place(&mut self.params, dp);
            self.on_grid = true;
        }
    }

    /// Q-values for all A actions written into `out` (cleared first) — the
    /// allocation-free action-selection path (`out` reuses its capacity).
    pub fn forward_into(
        &mut self,
        cfg: &NetConfig,
        sa: &[f32],
        dp: &Datapath,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.prepare(dp);
        forward_into(cfg, &self.params, sa, dp, &mut self.scratch.sa_cur_q, &mut self.scratch.cur)?;
        out.clear();
        out.extend_from_slice(&self.scratch.cur.q);
        Ok(())
    }

    /// One stepwise Q-update in place; returns the Q-error (Eq. 8).
    /// Bit-exact vs [`qupdate`] on the same transition stream.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        cfg: &NetConfig,
        sa_cur: &[f32],
        sa_next: &[f32],
        action: usize,
        reward: f32,
        hyper: &Hyper,
        dp: &Datapath,
    ) -> Result<f32> {
        if action >= cfg.a {
            return Err(Error::Env(format!("action {action} out of range 0..{}", cfg.a)));
        }
        self.prepare(dp);
        step_on_grid(cfg, &mut self.params, sa_cur, sa_next, action, reward, hyper, dp,
                     &mut self.scratch)
    }

    /// Batched flush over the cached parameters: like [`qupdate_batch`] but
    /// skipping even the per-batch quantize pass once the cache is warm.
    #[allow(clippy::too_many_arguments)]
    pub fn update_batch(
        &mut self,
        cfg: &NetConfig,
        sa_cur: &[f32],
        sa_next: &[f32],
        actions: &[usize],
        rewards: &[f32],
        hyper: &Hyper,
        dp: &Datapath,
        errs: &mut Vec<f32>,
    ) -> Result<()> {
        validate_batch(cfg, sa_cur, sa_next, actions, rewards)?;
        if actions.is_empty() {
            return Ok(());
        }
        self.prepare(dp);
        let step = cfg.a * cfg.d;
        for k in 0..actions.len() {
            let err = step_on_grid(
                cfg,
                &mut self.params,
                &sa_cur[k * step..(k + 1) * step],
                &sa_next[k * step..(k + 1) * step],
                actions[k],
                rewards[k],
                hyper,
                dp,
                &mut self.scratch,
            )?;
            errs.push(err);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};
    use crate::util::Rng;

    fn rand_sa(cfg: &NetConfig, rng: &mut Rng) -> Vec<f32> {
        rng.vec_f32(cfg.a * cfg.d, -1.0, 1.0)
    }

    fn paper_dp(fixed: bool) -> Datapath {
        Datapath::paper(fixed.then(FixedSpec::default))
    }

    /// The kernel dispatch contract: scalar and SIMD paths produce the
    /// same bits for every precision arm and paper configuration, through
    /// forwards and a full stepwise update stream.
    #[test]
    fn simd_and_scalar_paths_agree_to_the_bit() {
        let mut rng = Rng::seeded(12);
        for cfg in NetConfig::all() {
            for prec in Precision::all() {
                let dp_s = Datapath::for_precision(prec).with_kernel(KernelPath::Scalar);
                let dp_v = Datapath::for_precision(prec).with_kernel(KernelPath::Simd);
                let hyper = Hyper::default();
                let init = QNetParams::init(&cfg, 0.4, &mut rng);
                let mut p_s = PreparedNet::new(init.clone());
                let mut p_v = PreparedNet::new(init);
                let (mut qs, mut qv) = (Vec::new(), Vec::new());
                let step = cfg.a * cfg.d;
                for i in 0..10 {
                    let sc = rng.vec_f32(step, -1.0, 1.0);
                    let sn = rng.vec_f32(step, -1.0, 1.0);
                    let action = rng.below(cfg.a);
                    let reward = rng.f32_range(-1.0, 1.0);
                    p_s.forward_into(&cfg, &sc, &dp_s, &mut qs).unwrap();
                    p_v.forward_into(&cfg, &sc, &dp_v, &mut qv).unwrap();
                    let ctx = format!("{}/{} step {i}", cfg.name(), prec.as_str());
                    assert_eq!(qs, qv, "{ctx}: forward diverged");
                    let es =
                        p_s.update(&cfg, &sc, &sn, action, reward, &hyper, &dp_s).unwrap();
                    let ev =
                        p_v.update(&cfg, &sc, &sn, action, reward, &hyper, &dp_v).unwrap();
                    assert_eq!(es.to_bits(), ev.to_bits(), "{ctx}: q_err diverged");
                }
                assert_eq!(
                    p_s.params().max_abs_diff(p_v.params()),
                    0.0,
                    "{}/{}: params diverged",
                    cfg.name(),
                    prec.as_str()
                );
            }
        }
    }

    #[test]
    fn binary_grid_signs_and_is_idempotent() {
        let dp = Datapath::for_precision(Precision::Binary);
        assert!(dp.is_binary());
        assert_eq!(dp.precision, None);
        for (x, want) in
            [(0.3f32, 1.0f32), (-0.2, -1.0), (0.0, 1.0), (-0.0, 1.0), (7.0, 1.0), (-9.0, -1.0)]
        {
            assert_eq!(dp.q(x), want, "sign({x})");
            assert_eq!(dp.q(dp.q(x)), dp.q(x), "idempotence at {x}");
        }
        // forward still emits Q-values in (0, 1): σ(±1) through the LUT
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let mut rng = Rng::seeded(13);
        let params = QNetParams::init(&cfg, 0.5, &mut rng);
        let sa = rand_sa(&cfg, &mut rng);
        let q = forward(&cfg, &params, &sa, &dp).unwrap();
        for v in &q {
            assert!((0.0..=1.0).contains(v), "{v}");
        }
    }

    #[test]
    fn int8_arm_lives_on_the_q8_4_grid() {
        let dp = Datapath::for_precision(Precision::Int8);
        assert_eq!(dp.precision, Some(FixedSpec::int8()));
        assert!(!dp.is_binary());
        // Q(8,4): lsb 1/16, saturation at ±(127/16 | 8)
        assert_eq!(dp.q(0.06), 1.0 / 16.0);
        assert_eq!(dp.q(100.0), 127.0 / 16.0);
        assert_eq!(dp.q(-100.0), -8.0);
        // the fixed arm still honors an explicit spec; int8 ignores it
        let wide = FixedSpec::new(24, 16);
        assert_eq!(
            Datapath::for_precision_spec(Precision::Fixed, wide).precision,
            Some(wide)
        );
        assert_eq!(
            Datapath::for_precision_spec(Precision::Int8, wide).precision,
            Some(FixedSpec::int8())
        );
    }

    #[test]
    fn kernel_path_is_overridable_in_process() {
        let dp = Datapath::paper(None);
        let forced = dp.clone().with_kernel(KernelPath::Scalar);
        assert_eq!(forced.kernel(), KernelPath::Scalar);
        let simd = forced.with_kernel(KernelPath::Simd);
        assert_eq!(simd.kernel(), KernelPath::Simd);
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::seeded(2);
        for cfg in NetConfig::all() {
            let params = QNetParams::init(&cfg, 0.5, &mut rng);
            let sa = rand_sa(&cfg, &mut rng);
            let t = forward_full(&cfg, &params, &sa, &paper_dp(false)).unwrap();
            assert_eq!(t.q.len(), cfg.a);
            assert_eq!(t.pre2.len(), cfg.a);
            if cfg.arch == Arch::Mlp {
                assert_eq!(t.hid.len(), cfg.a * cfg.h);
            }
            for q in &t.q {
                assert!((0.0..=1.0).contains(q));
            }
        }
    }

    #[test]
    fn qupdate_moves_q_toward_target() {
        // γ=0, fixed reward: repeated updates shrink |q_err| (learning works)
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let mut rng = Rng::seeded(3);
        let mut params = QNetParams::init(&cfg, 0.2, &mut rng);
        let sa_cur = rand_sa(&cfg, &mut rng);
        let sa_next = rand_sa(&cfg, &mut rng);
        let hyper = Hyper { alpha: 1.0, gamma: 0.0, lr: 0.5 };
        let dp = paper_dp(false);

        let mut first = None;
        let mut last = 0f32;
        for _ in 0..150 {
            let out = qupdate(&cfg, &params, &sa_cur, &sa_next, 2, 0.8, &hyper, &dp).unwrap();
            params = out.params;
            last = out.q_err.abs();
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");
    }

    #[test]
    fn zero_alpha_freezes() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let mut rng = Rng::seeded(4);
        let params = QNetParams::init(&cfg, 0.5, &mut rng);
        let sa_cur = rand_sa(&cfg, &mut rng);
        let sa_next = rand_sa(&cfg, &mut rng);
        let hyper = Hyper { alpha: 0.0, gamma: 0.9, lr: 0.25 };
        let out = qupdate(&cfg, &params, &sa_cur, &sa_next, 0, 1.0, &hyper, &paper_dp(false))
            .unwrap();
        assert_eq!(out.q_err, 0.0);
        assert_eq!(out.params, params);
    }

    #[test]
    fn fixed_tracks_float_within_budget() {
        let mut rng = Rng::seeded(5);
        for cfg in NetConfig::all() {
            let params = QNetParams::init(&cfg, 0.5, &mut rng);
            let sa = rand_sa(&cfg, &mut rng);
            let qf = forward(&cfg, &params, &sa, &paper_dp(false)).unwrap();
            let qx = forward(&cfg, &params, &sa, &paper_dp(true)).unwrap();
            let lsb = FixedSpec::default().lsb() as f32;
            for (f, x) in qf.iter().zip(&qx) {
                assert!((f - x).abs() < 64.0 * lsb, "{f} vs {x}");
            }
        }
    }

    #[test]
    fn invalid_action_rejected() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let params = QNetParams::zeros(&cfg);
        let sa = vec![0.0; cfg.a * cfg.d];
        let r = qupdate(&cfg, &params, &sa, &sa, cfg.a, 0.0, &Hyper::default(),
                        &paper_dp(false));
        assert!(r.is_err());
    }

    #[test]
    fn wrong_sa_length_rejected() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let params = QNetParams::zeros(&cfg);
        let sa = vec![0.0; 5];
        assert!(forward(&cfg, &params, &sa, &paper_dp(false)).is_err());
    }

    /// The core batch-path contract: identical bits to the sequential path,
    /// in both precisions, for every paper configuration.
    #[test]
    fn qupdate_batch_is_bit_exact_vs_sequential() {
        let mut rng = Rng::seeded(6);
        for cfg in NetConfig::all() {
            for fixed in [false, true] {
                let dp = paper_dp(fixed);
                let hyper = Hyper::default();
                let init = QNetParams::init(&cfg, 0.4, &mut rng);
                let n = 9;
                let step = cfg.a * cfg.d;
                let sa_cur = rng.vec_f32(n * step, -1.0, 1.0);
                let sa_next = rng.vec_f32(n * step, -1.0, 1.0);
                let actions: Vec<usize> = (0..n).map(|_| rng.below(cfg.a)).collect();
                let rewards = rng.vec_f32(n, -1.0, 1.0);

                // sequential oracle
                let mut p_seq = init.clone();
                let mut want = Vec::new();
                for i in 0..n {
                    let out = qupdate(
                        &cfg,
                        &p_seq,
                        &sa_cur[i * step..(i + 1) * step],
                        &sa_next[i * step..(i + 1) * step],
                        actions[i],
                        rewards[i],
                        &hyper,
                        &dp,
                    )
                    .unwrap();
                    p_seq = out.params;
                    want.push(out.q_err);
                }

                // batched path
                let mut p_batch = init;
                let mut scratch = BatchScratch::new();
                let mut got = Vec::new();
                qupdate_batch(
                    &cfg, &mut p_batch, &sa_cur, &sa_next, &actions, &rewards, &hyper, &dp,
                    &mut scratch, &mut got,
                )
                .unwrap();

                assert_eq!(got, want, "{}/fixed={fixed}: q_errs diverged", cfg.name());
                assert_eq!(
                    p_batch.max_abs_diff(&p_seq),
                    0.0,
                    "{}/fixed={fixed}: params diverged",
                    cfg.name()
                );
            }
        }
    }

    #[test]
    fn qupdate_batch_scratch_reuse_is_stable() {
        // two flushes through the same scratch must equal one long sequence
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let mut rng = Rng::seeded(7);
        let dp = paper_dp(true);
        let hyper = Hyper::default();
        let init = QNetParams::init(&cfg, 0.4, &mut rng);
        let step = cfg.a * cfg.d;
        let sa_cur = rng.vec_f32(6 * step, -1.0, 1.0);
        let sa_next = rng.vec_f32(6 * step, -1.0, 1.0);
        let actions: Vec<usize> = (0..6).map(|_| rng.below(cfg.a)).collect();
        let rewards = rng.vec_f32(6, -1.0, 1.0);

        let mut p_one = init.clone();
        let mut s_one = BatchScratch::new();
        let mut e_one = Vec::new();
        qupdate_batch(
            &cfg, &mut p_one, &sa_cur, &sa_next, &actions, &rewards, &hyper, &dp, &mut s_one,
            &mut e_one,
        )
        .unwrap();

        let mut p_two = init;
        let mut s_two = BatchScratch::new();
        let mut e_two = Vec::new();
        for half in 0..2 {
            let lo = half * 3;
            qupdate_batch(
                &cfg,
                &mut p_two,
                &sa_cur[lo * step..(lo + 3) * step],
                &sa_next[lo * step..(lo + 3) * step],
                &actions[lo..lo + 3],
                &rewards[lo..lo + 3],
                &hyper,
                &dp,
                &mut s_two,
                &mut e_two,
            )
            .unwrap();
        }
        assert_eq!(e_one, e_two);
        assert_eq!(p_one, p_two);
    }

    #[test]
    fn qupdate_batch_rejects_bad_shapes_and_actions() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let dp = paper_dp(false);
        let hyper = Hyper::default();
        let step = cfg.a * cfg.d;
        let mut scratch = BatchScratch::new();
        let mut errs = Vec::new();

        // ragged encodings
        let mut p = QNetParams::zeros(&cfg);
        let r = qupdate_batch(
            &cfg, &mut p, &vec![0.0; step], &vec![0.0; step - 1], &[0], &[0.0], &hyper, &dp,
            &mut scratch, &mut errs,
        );
        assert!(r.is_err());

        // action out of range
        let r = qupdate_batch(
            &cfg, &mut p, &vec![0.0; step], &vec![0.0; step], &[cfg.a], &[0.0], &hyper, &dp,
            &mut scratch, &mut errs,
        );
        assert!(r.is_err());

        // empty batch is a no-op and must not touch the parameters
        let mut rng = Rng::seeded(8);
        let mut p = QNetParams::init(&cfg, 0.4, &mut rng);
        let before = p.clone();
        qupdate_batch(&cfg, &mut p, &[], &[], &[], &[], &hyper, &paper_dp(true), &mut scratch,
                      &mut errs)
            .unwrap();
        assert!(errs.is_empty());
        assert_eq!(p, before);
    }

    /// The stepwise fast path: a `PreparedNet` driven one transition at a
    /// time must reproduce the reference `qupdate` chain to the bit, in
    /// both precisions, for every paper configuration.
    #[test]
    fn prepared_stepwise_is_bit_exact_vs_reference() {
        let mut rng = Rng::seeded(9);
        for cfg in NetConfig::all() {
            for fixed in [false, true] {
                let dp = paper_dp(fixed);
                let hyper = Hyper::default();
                let init = QNetParams::init(&cfg, 0.4, &mut rng);
                let n = 12;
                let step = cfg.a * cfg.d;
                let sa_cur = rng.vec_f32(n * step, -1.0, 1.0);
                let sa_next = rng.vec_f32(n * step, -1.0, 1.0);
                let actions: Vec<usize> = (0..n).map(|_| rng.below(cfg.a)).collect();
                let rewards = rng.vec_f32(n, -1.0, 1.0);

                let mut p_ref = init.clone();
                let mut prepared = PreparedNet::new(init);
                let mut q_buf = Vec::new();
                for i in 0..n {
                    let sc = &sa_cur[i * step..(i + 1) * step];
                    let sn = &sa_next[i * step..(i + 1) * step];
                    // action-selection forward agrees with the reference
                    let want_q = forward(&cfg, &p_ref, sc, &dp).unwrap();
                    prepared.forward_into(&cfg, sc, &dp, &mut q_buf).unwrap();
                    assert_eq!(q_buf, want_q, "{}/fixed={fixed} step {i}", cfg.name());
                    // the update agrees, bit for bit
                    let out =
                        qupdate(&cfg, &p_ref, sc, sn, actions[i], rewards[i], &hyper, &dp)
                            .unwrap();
                    p_ref = out.params;
                    let got = prepared
                        .update(&cfg, sc, sn, actions[i], rewards[i], &hyper, &dp)
                        .unwrap();
                    assert_eq!(got, out.q_err, "{}/fixed={fixed} step {i}", cfg.name());
                }
                assert_eq!(
                    prepared.params().max_abs_diff(&p_ref),
                    0.0,
                    "{}/fixed={fixed}: params diverged",
                    cfg.name()
                );
            }
        }
    }

    /// Loading parameters invalidates the cache: off-grid weights must be
    /// re-quantized before the next forward, never used raw.
    #[test]
    fn prepared_load_invalidates_the_cache() {
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let mut rng = Rng::seeded(10);
        let dp = paper_dp(true);
        let sa = rand_sa(&cfg, &mut rng);
        let a_params = QNetParams::init(&cfg, 0.4, &mut rng);
        let b_params = QNetParams::init(&cfg, 0.4, &mut rng);

        let mut prepared = PreparedNet::new(a_params);
        let mut q = Vec::new();
        prepared.forward_into(&cfg, &sa, &dp, &mut q).unwrap();

        // swap in fresh (off-grid) parameters: the next forward must match
        // the reference path over those parameters, not the stale cache
        prepared.load(&b_params);
        prepared.forward_into(&cfg, &sa, &dp, &mut q).unwrap();
        assert_eq!(q, forward(&cfg, &b_params, &sa, &dp).unwrap());
        // and the cached copy is now the quantized view of the load
        let mut on_grid = b_params;
        quantize_params_in_place(&mut on_grid, &dp);
        assert_eq!(prepared.params(), &on_grid);
    }

    #[test]
    fn prepared_rejects_bad_inputs_without_corrupting_state() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let mut rng = Rng::seeded(11);
        let dp = paper_dp(true);
        let hyper = Hyper::default();
        let init = QNetParams::init(&cfg, 0.4, &mut rng);
        let sa = rand_sa(&cfg, &mut rng);
        let mut prepared = PreparedNet::new(init.clone());

        // out-of-range action, short encodings, ragged batches
        assert!(prepared.update(&cfg, &sa, &sa, cfg.a, 0.0, &hyper, &dp).is_err());
        assert!(prepared.update(&cfg, &sa[..3], &sa, 0, 0.0, &hyper, &dp).is_err());
        let mut errs = Vec::new();
        assert!(prepared
            .update_batch(&cfg, &sa, &sa[..sa.len() - 1], &[0], &[0.0], &hyper, &dp, &mut errs)
            .is_err());
        assert!(errs.is_empty());

        // after the rejections the net still tracks the reference exactly
        let got = prepared.update(&cfg, &sa, &sa, 1, 0.5, &hyper, &dp).unwrap();
        let want = qupdate(&cfg, &init, &sa, &sa, 1, 0.5, &hyper, &dp).unwrap();
        assert_eq!(got, want.q_err);
        assert_eq!(prepared.params().max_abs_diff(&want.params), 0.0);
    }
}
