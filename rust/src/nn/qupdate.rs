//! Feed-forward + Q-update numeric core (the CPU baseline datapath).
//!
//! Mirrors `python/compile/kernels/ref.py` operation-for-operation so the
//! three backends (XLA artifact, this module, FPGA simulator) can be
//! cross-validated. All math is f32 with optional fake-quantization after
//! every register-level value, exactly like the python oracle.

use crate::config::{Hyper, NetConfig};
use crate::error::{Error, Result};
use crate::fixed::{FixedSpec, Quantizer};

use super::activation::Activation;
use super::params::QNetParams;

/// Datapath configuration: arithmetic grid + activation implementation.
#[derive(Debug, Clone)]
pub struct Datapath {
    /// `None` -> float32; `Some(spec)` -> fake-quantized fixed point.
    pub precision: Option<FixedSpec>,
    pub activation: Activation,
    /// Precomputed fast quantizer (kept in sync with `precision`).
    quantizer: Option<Quantizer>,
}

impl Datapath {
    /// Build a datapath; use this (not a struct literal) so the precomputed
    /// quantizer stays in sync with `precision`.
    pub fn new(precision: Option<FixedSpec>, activation: Activation) -> Self {
        Datapath { precision, activation, quantizer: precision.map(Quantizer::new) }
    }

    /// Paper-default datapath for a precision: LUT sigmoid, Q(18,12) grid
    /// when fixed.
    pub fn paper(fixed: Option<FixedSpec>) -> Self {
        Self::new(fixed, Activation::lut_default(fixed))
    }

    /// Quantize one register value (identity in float mode).
    #[inline(always)]
    pub fn q(&self, x: f32) -> f32 {
        match &self.quantizer {
            None => x,
            Some(q) => q.q(x),
        }
    }
}

/// Feed-forward internals needed by backprop (python `forward_full`).
#[derive(Debug, Clone, Default)]
pub struct ForwardTrace {
    /// Q-values, length A.
    pub q: Vec<f32>,
    /// Output pre-activations σ, length A.
    pub pre2: Vec<f32>,
    /// Hidden activations, row-major (A, H). Empty for the perceptron.
    pub hid: Vec<f32>,
    /// Hidden pre-activations, row-major (A, H). Empty for the perceptron.
    pub pre1: Vec<f32>,
}

/// Result of one full Q-update.
#[derive(Debug, Clone)]
pub struct QUpdateOutput {
    pub params: QNetParams,
    pub q_cur: Vec<f32>,
    pub q_next: Vec<f32>,
    pub q_err: f32,
}

#[inline]
#[allow(dead_code)] // kept as the scalar-path reference for dot-product reviews
fn dot_q(dp: &Datapath, x: &[f32], w: &[f32]) -> f32 {
    // f32 accumulation in index order, matching jnp.matmul closely enough
    // for the 1e-6 cross-checks; rounded once afterwards in fixed mode.
    let mut acc = 0f32;
    for (a, b) in x.iter().zip(w) {
        acc += a * b;
    }
    dp.q(acc)
}

/// Feed-forward for all A actions; `sa` is row-major (A, D).
pub fn forward_full(
    cfg: &NetConfig,
    params: &QNetParams,
    sa: &[f32],
    dp: &Datapath,
) -> Result<ForwardTrace> {
    let (a_n, d) = (cfg.a, cfg.d);
    if sa.len() != a_n * d {
        return Err(Error::interface(format!(
            "sa length {} != A*D = {}",
            sa.len(),
            a_n * d
        )));
    }
    let qz = |x: f32| dp.q(x);
    let sa_q: Vec<f32> = sa.iter().map(|&x| qz(x)).collect();

    match params {
        QNetParams::Perceptron { w, b } => {
            if w.len() != d {
                return Err(Error::interface("perceptron weight length != D"));
            }
            let w_q: Vec<f32> = w.iter().map(|&x| qz(x)).collect();
            let b_q = qz(*b);
            let mut trace = ForwardTrace {
                q: Vec::with_capacity(a_n),
                pre2: Vec::with_capacity(a_n),
                ..Default::default()
            };
            for ai in 0..a_n {
                let x = &sa_q[ai * d..(ai + 1) * d];
                // Eq. 5: σ = Σ x_i w_i (+ bias); one rounding (MAC block)
                let mut acc = 0f32;
                for (xi, wi) in x.iter().zip(&w_q) {
                    acc += xi * wi;
                }
                let pre = qz(acc + b_q);
                trace.pre2.push(pre);
                // Eq. 6: firing rate through the sigmoid ROM
                trace.q.push(dp.activation.f(pre));
            }
            Ok(trace)
        }
        QNetParams::Mlp { w1, b1, w2, b2 } => {
            let h = cfg.h;
            if w1.len() != d * h || b1.len() != h || w2.len() != h {
                return Err(Error::interface("mlp parameter shapes"));
            }
            let w1_q: Vec<f32> = w1.iter().map(|&x| qz(x)).collect();
            let b1_q: Vec<f32> = b1.iter().map(|&x| qz(x)).collect();
            let w2_q: Vec<f32> = w2.iter().map(|&x| qz(x)).collect();
            let b2_q = qz(*b2);
            let mut trace = ForwardTrace {
                q: Vec::with_capacity(a_n),
                pre2: Vec::with_capacity(a_n),
                hid: Vec::with_capacity(a_n * h),
                pre1: Vec::with_capacity(a_n * h),
            };
            for ai in 0..a_n {
                let x = &sa_q[ai * d..(ai + 1) * d];
                // hidden layer: H parallel MAC columns
                let mut hid_row = Vec::with_capacity(h);
                for j in 0..h {
                    let mut acc = 0f32;
                    for i in 0..d {
                        acc += x[i] * w1_q[i * h + j];
                    }
                    let pre = qz(acc + b1_q[j]);
                    trace.pre1.push(pre);
                    let o = dp.activation.f(pre);
                    trace.hid.push(o);
                    hid_row.push(o);
                }
                // output layer
                let pre2 = {
                    let mut acc = 0f32;
                    for j in 0..h {
                        acc += hid_row[j] * w2_q[j];
                    }
                    qz(acc + b2_q)
                };
                trace.pre2.push(pre2);
                trace.q.push(dp.activation.f(pre2));
            }
            Ok(trace)
        }
    }
}

/// Q-values only (action-selection path).
pub fn forward(
    cfg: &NetConfig,
    params: &QNetParams,
    sa: &[f32],
    dp: &Datapath,
) -> Result<Vec<f32>> {
    Ok(forward_full(cfg, params, sa, dp)?.q)
}

/// Eq. 8: Q_error = α·(r + γ·max_a′ Q(s′,a′) − Q(s,a)).
pub fn q_error(dp: &Datapath, hyper: &Hyper, q_sa: f32, q_next_max: f32, reward: f32) -> f32 {
    let target = dp.q(reward + dp.q(hyper.gamma * q_next_max));
    dp.q(hyper.alpha * dp.q(target - q_sa))
}

/// One full paper Q-update (two sweeps + error capture + backprop).
#[allow(clippy::too_many_arguments)]
pub fn qupdate(
    cfg: &NetConfig,
    params: &QNetParams,
    sa_cur: &[f32],
    sa_next: &[f32],
    action: usize,
    reward: f32,
    hyper: &Hyper,
    dp: &Datapath,
) -> Result<QUpdateOutput> {
    if action >= cfg.a {
        return Err(Error::Env(format!("action {action} out of range 0..{}", cfg.a)));
    }
    let qz = |x: f32| dp.q(x);

    let cur = forward_full(cfg, params, sa_cur, dp)?;
    let nxt = forward_full(cfg, params, sa_next, dp)?;

    let q_next_max = nxt.q.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let err = q_error(dp, hyper, cur.q[action], q_next_max, reward);

    let d = cfg.d;
    let x_row: Vec<f32> = sa_cur[action * d..(action + 1) * d]
        .iter()
        .map(|&x| qz(x))
        .collect();
    let lr = hyper.lr;

    let new_params = match params {
        QNetParams::Perceptron { w, b } => {
            let w_q: Vec<f32> = w.iter().map(|&x| qz(x)).collect();
            let b_q = qz(*b);
            // Eq. 7: δ = f′(σ)·Q_error
            let delta = qz(dp.activation.fprime(cur.pre2[action]) * err);
            // Eq. 9/10: ΔW = C·O·δ ; W += ΔW
            let mut w_new = Vec::with_capacity(d);
            for i in 0..d {
                let dw = qz(lr * qz(x_row[i] * delta));
                w_new.push(qz(w_q[i] + dw));
            }
            let db = qz(lr * delta);
            QNetParams::Perceptron { w: w_new, b: qz(b_q + db) }
        }
        QNetParams::Mlp { w1, b1, w2, b2 } => {
            let h = cfg.h;
            let w1_q: Vec<f32> = w1.iter().map(|&x| qz(x)).collect();
            let b1_q: Vec<f32> = b1.iter().map(|&x| qz(x)).collect();
            let w2_q: Vec<f32> = w2.iter().map(|&x| qz(x)).collect();
            let b2_q = qz(*b2);

            let s2 = cur.pre2[action];
            let o1 = &cur.hid[action * h..(action + 1) * h];
            let s1 = &cur.pre1[action * h..(action + 1) * h];

            // Eq. 11: output delta
            let d2 = qz(dp.activation.fprime(s2) * err);
            // Eq. 12: hidden deltas  δ_i = f′(σ_i)·(δ_out·W_i)
            let d1: Vec<f32> = (0..h)
                .map(|j| qz(dp.activation.fprime(s1[j]) * qz(d2 * w2_q[j])))
                .collect();
            // Eq. 13/14: ΔW generators + in-place update
            let mut w2_new = Vec::with_capacity(h);
            for j in 0..h {
                let dw2 = qz(lr * qz(o1[j] * d2));
                w2_new.push(qz(w2_q[j] + dw2));
            }
            let b2_new = qz(b2_q + qz(lr * d2));
            let mut w1_new = vec![0f32; d * h];
            for i in 0..d {
                for j in 0..h {
                    let dw1 = qz(lr * qz(x_row[i] * d1[j]));
                    w1_new[i * h + j] = qz(w1_q[i * h + j] + dw1);
                }
            }
            let b1_new: Vec<f32> =
                (0..h).map(|j| qz(b1_q[j] + qz(lr * d1[j]))).collect();
            QNetParams::Mlp { w1: w1_new, b1: b1_new, w2: w2_new, b2: b2_new }
        }
    };

    Ok(QUpdateOutput { params: new_params, q_cur: cur.q, q_next: nxt.q, q_err: err })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};
    use crate::util::Rng;

    fn rand_sa(cfg: &NetConfig, rng: &mut Rng) -> Vec<f32> {
        rng.vec_f32(cfg.a * cfg.d, -1.0, 1.0)
    }

    fn paper_dp(fixed: bool) -> Datapath {
        Datapath::paper(fixed.then(FixedSpec::default))
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::seeded(2);
        for cfg in NetConfig::all() {
            let params = QNetParams::init(&cfg, 0.5, &mut rng);
            let sa = rand_sa(&cfg, &mut rng);
            let t = forward_full(&cfg, &params, &sa, &paper_dp(false)).unwrap();
            assert_eq!(t.q.len(), cfg.a);
            assert_eq!(t.pre2.len(), cfg.a);
            if cfg.arch == Arch::Mlp {
                assert_eq!(t.hid.len(), cfg.a * cfg.h);
            }
            for q in &t.q {
                assert!((0.0..=1.0).contains(q));
            }
        }
    }

    #[test]
    fn qupdate_moves_q_toward_target() {
        // γ=0, fixed reward: repeated updates shrink |q_err| (learning works)
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let mut rng = Rng::seeded(3);
        let mut params = QNetParams::init(&cfg, 0.2, &mut rng);
        let sa_cur = rand_sa(&cfg, &mut rng);
        let sa_next = rand_sa(&cfg, &mut rng);
        let hyper = Hyper { alpha: 1.0, gamma: 0.0, lr: 0.5 };
        let dp = paper_dp(false);

        let mut first = None;
        let mut last = 0f32;
        for _ in 0..150 {
            let out = qupdate(&cfg, &params, &sa_cur, &sa_next, 2, 0.8, &hyper, &dp).unwrap();
            params = out.params;
            last = out.q_err.abs();
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");
    }

    #[test]
    fn zero_alpha_freezes() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let mut rng = Rng::seeded(4);
        let params = QNetParams::init(&cfg, 0.5, &mut rng);
        let sa_cur = rand_sa(&cfg, &mut rng);
        let sa_next = rand_sa(&cfg, &mut rng);
        let hyper = Hyper { alpha: 0.0, gamma: 0.9, lr: 0.25 };
        let out = qupdate(&cfg, &params, &sa_cur, &sa_next, 0, 1.0, &hyper, &paper_dp(false))
            .unwrap();
        assert_eq!(out.q_err, 0.0);
        assert_eq!(out.params, params);
    }

    #[test]
    fn fixed_tracks_float_within_budget() {
        let mut rng = Rng::seeded(5);
        for cfg in NetConfig::all() {
            let params = QNetParams::init(&cfg, 0.5, &mut rng);
            let sa = rand_sa(&cfg, &mut rng);
            let qf = forward(&cfg, &params, &sa, &paper_dp(false)).unwrap();
            let qx = forward(&cfg, &params, &sa, &paper_dp(true)).unwrap();
            let lsb = FixedSpec::default().lsb() as f32;
            for (f, x) in qf.iter().zip(&qx) {
                assert!((f - x).abs() < 64.0 * lsb, "{f} vs {x}");
            }
        }
    }

    #[test]
    fn invalid_action_rejected() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let params = QNetParams::zeros(&cfg);
        let sa = vec![0.0; cfg.a * cfg.d];
        let r = qupdate(&cfg, &params, &sa, &sa, cfg.a, 0.0, &Hyper::default(),
                        &paper_dp(false));
        assert!(r.is_err());
    }

    #[test]
    fn wrong_sa_length_rejected() {
        let cfg = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let params = QNetParams::zeros(&cfg);
        let sa = vec![0.0; 5];
        assert!(forward(&cfg, &params, &sa, &paper_dp(false)).is_err());
    }
}
