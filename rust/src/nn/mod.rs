//! Pure-Rust reference network — the paper's CPU baseline (Tables 3–6).
//!
//! Implements exactly the op chain of the python oracle
//! (`python/compile/kernels/ref.py`): feed-forward (Eq. 5/6), error capture
//! (Eq. 8) and backpropagation (Eq. 7, 9–14), in float32 with optional
//! fake-quantization to a [`crate::fixed::FixedSpec`] grid after every
//! register-level operation.
//!
//! Three roles:
//! 1. the measured CPU baseline for the completion-time tables,
//! 2. the host-side oracle the XLA artifacts are validated against
//!    (`tests/backend_equiv.rs`),
//! 3. the numeric core reused by the FPGA datapath simulator in float mode.

pub mod activation;
pub mod params;
pub mod qupdate;

pub use activation::{sigmoid, sigmoid_deriv, Activation, LutSpec, SigmoidLut};
pub use params::QNetParams;
pub use qupdate::{
    forward, forward_full, q_error, qupdate, qupdate_batch, BatchScratch, Datapath, ForwardTrace,
    KernelPath, PreparedNet, QUpdateOutput, UpdateScratch,
};
