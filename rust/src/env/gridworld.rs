//! Shared gridworld machinery: rover pose, headings, movement.

use super::terrain::Terrain;

/// 8-connected compass headings, clockwise from north.
pub const HEADINGS: [(i32, i32); 8] = [
    (0, -1),  // N
    (1, -1),  // NE
    (1, 0),   // E
    (1, 1),   // SE
    (0, 1),   // S
    (-1, 1),  // SW
    (-1, 0),  // W
    (-1, -1), // NW
];

/// Rover pose on the grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pose {
    pub x: usize,
    pub y: usize,
    /// Index into [`HEADINGS`].
    pub heading: usize,
}

impl Pose {
    pub fn origin() -> Self {
        Pose { x: 0, y: 0, heading: 2 } // facing east
    }

    /// Unit direction of the current heading.
    pub fn dir(&self) -> (i32, i32) {
        HEADINGS[self.heading % 8]
    }

    /// sin/cos encoding of the heading (continuous, wrap-free).
    pub fn heading_sincos(&self) -> (f32, f32) {
        let theta = self.heading as f32 * std::f32::consts::FRAC_PI_4;
        (theta.sin(), theta.cos())
    }
}

/// A grid the rover moves on (wraps [`Terrain`] with movement rules).
#[derive(Debug, Clone)]
pub struct Grid {
    pub terrain: Terrain,
}

/// Result of attempting a move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoveOutcome {
    Moved,
    /// Blocked by the map edge — pose unchanged.
    Edge,
    /// Entered a hazard cell (move happens; the environment decides the
    /// penalty / termination).
    Hazard,
}

impl Grid {
    pub fn new(terrain: Terrain) -> Self {
        Grid { terrain }
    }

    pub fn width(&self) -> usize {
        self.terrain.width
    }

    pub fn height(&self) -> usize {
        self.terrain.height
    }

    pub fn n_cells(&self) -> usize {
        self.terrain.width * self.terrain.height
    }

    /// Discrete cell id of a pose (the tabular state id base).
    pub fn cell_id(&self, pose: &Pose) -> usize {
        pose.y * self.width() + pose.x
    }

    /// Try to move `steps` cells along `heading`. Clips at map edges:
    /// returns `Moved` if at least one cell of progress was made, `Edge` if
    /// blocked immediately, `Hazard` as soon as a hazard cell is entered.
    pub fn advance(&self, pose: &mut Pose, heading: usize, steps: usize) -> MoveOutcome {
        pose.heading = heading % 8;
        let (dx, dy) = HEADINGS[pose.heading];
        let mut moved = false;
        for _ in 0..steps {
            let nx = pose.x as i32 + dx;
            let ny = pose.y as i32 + dy;
            if nx < 0 || ny < 0 || nx >= self.width() as i32 || ny >= self.height() as i32 {
                break;
            }
            pose.x = nx as usize;
            pose.y = ny as usize;
            if self.terrain.is_hazard(pose.x, pose.y) {
                return MoveOutcome::Hazard;
            }
            moved = true;
        }
        if moved {
            MoveOutcome::Moved
        } else {
            MoveOutcome::Edge
        }
    }

    /// Ray-cast from the pose along a heading: distance (in cells, capped at
    /// `range`) to the first hazard or edge, normalized to [0,1].
    /// This models the rover's terrain sensors (navcam/radar rays).
    pub fn ray_hazard_distance(&self, pose: &Pose, heading: usize, range: usize) -> f32 {
        let (dx, dy) = HEADINGS[heading % 8];
        let (mut x, mut y) = (pose.x as i32, pose.y as i32);
        for step in 1..=range {
            x += dx;
            y += dy;
            if x < 0 || y < 0 || x >= self.width() as i32 || y >= self.height() as i32 {
                return step as f32 / range as f32;
            }
            if self.terrain.is_hazard(x as usize, y as usize) {
                return step as f32 / range as f32;
            }
        }
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_grid(w: usize, h: usize) -> Grid {
        Grid::new(Terrain::generate(w, h, 0.0, 0, 1))
    }

    #[test]
    fn advance_moves_and_respects_edges() {
        let g = flat_grid(5, 5);
        let mut p = Pose::origin();
        assert_eq!(g.advance(&mut p, 2, 3), MoveOutcome::Moved); // east
        assert_eq!((p.x, p.y), (3, 0));
        assert_eq!(g.advance(&mut p, 2, 10), MoveOutcome::Moved); // clipped at edge
        assert_eq!((p.x, p.y), (4, 0));
        assert_eq!(g.advance(&mut p, 2, 1), MoveOutcome::Edge);
        assert_eq!((p.x, p.y), (4, 0));
        assert_eq!(g.advance(&mut p, 0, 1), MoveOutcome::Edge); // north off map
    }

    #[test]
    fn hazard_detection() {
        let mut t = Terrain::generate(5, 1, 0.0, 0, 2);
        t.hazard[2] = true; // cell (2,0)
        let g = Grid::new(t);
        let mut p = Pose::origin();
        assert_eq!(g.advance(&mut p, 2, 4), MoveOutcome::Hazard);
        assert_eq!((p.x, p.y), (2, 0)); // stopped in the hazard cell
    }

    #[test]
    fn ray_distances() {
        let mut t = Terrain::generate(10, 1, 0.0, 0, 3);
        t.hazard[4] = true;
        let g = Grid::new(t);
        let p = Pose::origin();
        let d = g.ray_hazard_distance(&p, 2, 8); // east: hazard at 4 cells
        assert!((d - 0.5).abs() < 1e-6);
        let d_clear = g.ray_hazard_distance(&p, 4, 8); // south: immediate edge
        assert!((d_clear - 1.0 / 8.0).abs() < 1e-6);
    }

    #[test]
    fn heading_sincos_unit_norm() {
        for h in 0..8 {
            let p = Pose { x: 0, y: 0, heading: h };
            let (s, c) = p.heading_sincos();
            assert!((s * s + c * c - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn cell_ids_unique() {
        let g = flat_grid(6, 4);
        let mut seen = std::collections::HashSet::new();
        for y in 0..4 {
            for x in 0..6 {
                assert!(seen.insert(g.cell_id(&Pose { x, y, heading: 0 })));
            }
        }
        assert_eq!(seen.len(), 24);
    }
}
