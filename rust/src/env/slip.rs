//! Slip-under-slope scenario (`EnvKind::Slip`): D = 11, A = 8.
//!
//! A 24×18 traverse over rough, sloped ground where commanded moves can
//! **fail**: the probability that the wheels slip is proportional to the
//! elevation gradient along the commanded move. A slipped move either
//! leaves the rover in place (wheels spinning) or drifts it one cell
//! toward the locally steepest descent — the classic "sliding down the
//! dune" failure MER Opportunity hit at Purgatory ripple. All slip draws
//! come from an internal RNG reseeded from the constructor seed on every
//! reset, so trajectories are **stochastic in-episode but bit-identical
//! across replays** of the same seed and action sequence (the
//! seed-determinism contract every environment honors; see
//! `tests/proptests.rs`).
//!
//! Actions are the 8 absolute compass headings. The state encodes position,
//! local terrain (elevation, gradient, slip risk) and the goal vector; the
//! tabular state is the cell id (|S| = 432).

use crate::config::{Arch, EnvKind, NetConfig};
use crate::util::Rng;

use super::encoding::ActionCode;
use super::gridworld::{Grid, MoveOutcome, Pose, HEADINGS};
use super::terrain::Terrain;
use super::traits::{Environment, StepResult};
use super::SHAPING_GAMMA;

const W: usize = 24;
const H: usize = 18;
const MAX_STEPS: usize = 300;
/// Slip probability per unit of |elevation gradient| along the move.
const SLIP_GAIN: f32 = 4.0;
/// Hard cap so even cliff faces keep some traction.
const SLIP_MAX: f32 = 0.8;

/// Slip-under-slope navigation environment.
pub struct SlipSlopeEnv {
    grid: Grid,
    pristine: Terrain,
    pose: Pose,
    steps: usize,
    slips: usize,
    done: bool,
    episodes: u64,
    seed: u64,
    /// Slip-draw stream — reseeded from `seed` and the episode counter on
    /// every reset, so replays are bit-identical.
    rng: Rng,
    /// Cached 9 state dims, recomputed once per state change.
    state_feat: [f32; 9],
}

impl SlipSlopeEnv {
    pub fn new(seed: u64) -> Self {
        let terrain = Terrain::generate(W, H, 0.05, 1, seed.wrapping_add(0x5119));
        let mut env = SlipSlopeEnv {
            grid: Grid::new(terrain.clone()),
            pristine: terrain,
            pose: Pose::origin(),
            steps: 0,
            slips: 0,
            done: false,
            episodes: 0,
            seed,
            rng: Rng::seeded(seed),
            state_feat: [0.0; 9],
        };
        env.reset();
        env
    }

    pub fn pose(&self) -> Pose {
        self.pose
    }

    /// Slip events so far this episode.
    pub fn slips(&self) -> usize {
        self.slips
    }

    /// Slip probability of commanding `heading` from the current cell:
    /// proportional to the elevation change to the target cell, capped at
    /// [`SLIP_MAX`]. Zero when the move would leave the map.
    fn slip_probability(&self, heading: usize) -> f32 {
        let (dx, dy) = HEADINGS[heading % 8];
        let nx = self.pose.x as i32 + dx;
        let ny = self.pose.y as i32 + dy;
        if nx < 0 || ny < 0 || nx >= W as i32 || ny >= H as i32 {
            return 0.0;
        }
        let grade = (self.grid.terrain.elevation_at(nx as usize, ny as usize)
            - self.grid.terrain.elevation_at(self.pose.x, self.pose.y))
        .abs();
        (SLIP_GAIN * grade).min(SLIP_MAX)
    }

    /// Worst-case slip risk over all 8 headings from the current cell —
    /// the "how treacherous is this ground" state feature.
    fn local_slip_risk(&self) -> f32 {
        (0..8)
            .map(|h| self.slip_probability(h))
            .fold(0.0f32, f32::max)
    }

    /// Steepest-descent passable neighbor of the current cell (drift
    /// target), if any neighbor is strictly lower.
    fn downhill_neighbor(&self) -> Option<(usize, usize)> {
        let here = self.grid.terrain.elevation_at(self.pose.x, self.pose.y);
        let mut best: Option<((usize, usize), f32)> = None;
        for (dx, dy) in HEADINGS {
            let nx = self.pose.x as i32 + dx;
            let ny = self.pose.y as i32 + dy;
            if nx < 0 || ny < 0 || nx >= W as i32 || ny >= H as i32 {
                continue;
            }
            let (nx, ny) = (nx as usize, ny as usize);
            if self.grid.terrain.is_hazard(nx, ny) {
                continue;
            }
            let e = self.grid.terrain.elevation_at(nx, ny);
            if e < here && best.map_or(true, |(_, b)| e < b) {
                best = Some(((nx, ny), e));
            }
        }
        best.map(|(p, _)| p)
    }

    fn refresh_state_features(&mut self) {
        let t = &self.grid.terrain;
        let mut f = [0f32; 9];
        f[0] = self.pose.x as f32 / (W - 1) as f32 * 2.0 - 1.0;
        f[1] = self.pose.y as f32 / (H - 1) as f32 * 2.0 - 1.0;
        f[2] = t.elevation_at(self.pose.x, self.pose.y) * 2.0 - 1.0;
        let (gx, gy) = t.gradient(self.pose.x, self.pose.y);
        f[3] = gx;
        f[4] = gy;
        f[5] = self.local_slip_risk() * 2.0 - 1.0;
        let (gs, gc, gd) = t.science_vector(self.pose.x, self.pose.y);
        f[6] = gs;
        f[7] = gc;
        f[8] = gd;
        self.state_feat = f;
    }

    /// Shaping potential φ(s) = −0.04 · distance-to-goal
    /// ([`Terrain::science_potential`]).
    fn potential(&self) -> f32 {
        self.grid.terrain.science_potential(self.pose.x, self.pose.y, 0.04)
    }

    /// Collect the goal if the rover is standing on it (moves *and* drifts
    /// can land on the target).
    fn check_goal(&mut self, reward: &mut f32) {
        if self.grid.terrain.is_science(self.pose.x, self.pose.y) {
            *reward += 1.0; // mission success
            self.done = true;
        }
    }
}

impl Environment for SlipSlopeEnv {
    fn net_config(&self) -> NetConfig {
        NetConfig::new(Arch::Perceptron, EnvKind::Slip) // D/A only
    }

    fn state_space(&self) -> usize {
        W * H
    }

    fn state_id(&self) -> usize {
        self.grid.cell_id(&self.pose)
    }

    fn reset(&mut self) {
        self.grid = Grid::new(self.pristine.clone());
        let mut rng = Rng::seeded(self.seed ^ (self.episodes << 23));
        loop {
            let x = rng.below(W / 3);
            let y = rng.below(H);
            if !self.grid.terrain.is_hazard(x, y) && !self.grid.terrain.is_science(x, y) {
                self.pose = Pose { x, y, heading: rng.below(8) };
                break;
            }
        }
        // independent, episode-salted slip stream — deterministic replays
        self.rng = Rng::seeded(self.seed ^ (self.episodes << 29) ^ 0x0511_9B0B);
        self.steps = 0;
        self.slips = 0;
        self.done = false;
        self.episodes += 1;
        self.refresh_state_features();
    }

    fn encode_sa(&self, action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 11);
        out[..9].copy_from_slice(&self.state_feat);
        ActionCode::heading8(action, &mut out[9..11]);
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.done, "step() after terminal state");
        assert!(action < 8, "slip action {action} out of range");
        self.steps += 1;
        let phi_before = self.potential();
        let mut reward = -0.01; // time/step cost

        let (dx, dy) = HEADINGS[action];
        let nx = self.pose.x as i32 + dx;
        let ny = self.pose.y as i32 + dy;
        if nx < 0 || ny < 0 || nx >= W as i32 || ny >= H as i32 {
            // no traction question at the map edge — the move just fails
            self.pose.heading = action;
            reward -= 0.05;
        } else {
            let p_slip = self.slip_probability(action);
            if self.rng.chance(p_slip as f64) {
                // wheels slip: wasted drive energy, and a 50/50 chance the
                // rover drifts one cell toward the steepest descent
                self.slips += 1;
                self.pose.heading = action;
                reward -= 0.05;
                if self.rng.chance(0.5) {
                    if let Some((tx, ty)) = self.downhill_neighbor() {
                        self.pose.x = tx;
                        self.pose.y = ty;
                        self.check_goal(&mut reward);
                    }
                }
            } else {
                match self.grid.advance(&mut self.pose, action, 1) {
                    MoveOutcome::Moved => self.check_goal(&mut reward),
                    MoveOutcome::Edge => reward -= 0.05, // unreachable: bounds pre-checked
                    MoveOutcome::Hazard => {
                        reward -= 1.0; // sand trap
                        self.done = true;
                    }
                }
            }
        }

        // potential-based shaping (policy-invariant)
        reward += SHAPING_GAMMA * self.potential() - phi_before;

        if self.steps >= MAX_STEPS {
            self.done = true;
        }
        self.refresh_state_features();
        StepResult { reward, done: self.done }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn name(&self) -> &'static str {
        "slip-slope-24x18"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_match_config() {
        let env = SlipSlopeEnv::new(1);
        assert_eq!(env.d(), 11);
        assert_eq!(env.n_actions(), 8);
        assert_eq!(env.state_space(), W * H);
    }

    #[test]
    fn encode_bounded() {
        let env = SlipSlopeEnv::new(2);
        let mut out = vec![0f32; 8 * 11];
        env.encode_all(&mut out);
        for v in out {
            assert!((-1.0..=1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn stochastic_slip_replays_bit_identically() {
        // the whole point of the seeded slip stream: same seed + same
        // actions ⇒ identical rewards, slips and trajectory
        let mut a = SlipSlopeEnv::new(3);
        let mut b = SlipSlopeEnv::new(3);
        let mut action_rng = Rng::seeded(99);
        for _ in 0..150 {
            if a.is_done() {
                a.reset();
                b.reset();
            }
            let action = action_rng.below(8);
            let ra = a.step(action);
            let rb = b.step(action);
            assert_eq!(ra, rb);
            assert_eq!(a.state_id(), b.state_id());
            assert_eq!(a.slips(), b.slips());
        }
    }

    #[test]
    fn slips_actually_happen_on_slopes() {
        // random walk long enough to cross sloped ground: the slip counter
        // must advance for at least one seed
        let mut total = 0usize;
        for seed in 0..5 {
            let mut env = SlipSlopeEnv::new(seed);
            let mut rng = Rng::seeded(seed ^ 0xAB);
            for _ in 0..250 {
                if env.is_done() {
                    env.reset();
                }
                env.step(rng.below(8));
                total += env.slips();
            }
        }
        assert!(total > 0, "no slip ever occurred across 5 seeds");
    }

    #[test]
    fn slip_probability_bounded_and_zero_off_map() {
        let env = SlipSlopeEnv::new(6);
        for h in 0..8 {
            let p = env.slip_probability(h);
            assert!((0.0..=SLIP_MAX).contains(&p), "{p}");
        }
        let mut corner = SlipSlopeEnv::new(7);
        corner.pose = Pose { x: 0, y: 0, heading: 0 };
        assert_eq!(corner.slip_probability(0), 0.0); // north off-map
        assert_eq!(corner.slip_probability(6), 0.0); // west off-map
    }

    #[test]
    fn episode_terminates() {
        let mut env = SlipSlopeEnv::new(8);
        let mut steps = 0;
        while !env.is_done() {
            env.step(4); // drive south until edge/timeout/goal/hazard
            steps += 1;
            assert!(steps <= MAX_STEPS);
        }
    }

    #[test]
    fn drift_never_enters_hazard() {
        let mut env = SlipSlopeEnv::new(9);
        let mut rng = Rng::seeded(11);
        for _ in 0..300 {
            if env.is_done() {
                env.reset();
            }
            let r = env.step(rng.below(8));
            let p = env.pose();
            if !r.done {
                assert!(!env.grid.terrain.is_hazard(p.x, p.y));
            }
        }
    }
}
