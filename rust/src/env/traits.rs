//! Environment interface consumed by the Q-learning core and coordinator.

use crate::config::NetConfig;

/// Outcome of one environment step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepResult {
    pub reward: f32,
    pub done: bool,
}

/// A discrete-action environment whose state-action pairs encode into the
/// fixed-width vectors the accelerator consumes.
///
/// The contract mirrors the paper's Section 2 state-flow: the learner asks
/// for the encodings of **all** A actions in the current state (one
/// feed-forward sweep), selects an action, steps, and repeats in the next
/// state.
///
/// Implementations must keep every encoding component in [−1, 1] (the
/// Q(18,12) no-saturation invariant) and make trajectories a deterministic
/// function of the constructor seed and the action sequence — see the
/// [module docs](crate::env) for the full contract.
pub trait Environment: Send {
    /// Network/interface dimensions this environment targets.
    fn net_config(&self) -> NetConfig;

    /// Number of actions per state (A).
    fn n_actions(&self) -> usize {
        self.net_config().a
    }

    /// State+action encoding width (D).
    fn d(&self) -> usize {
        self.net_config().d
    }

    /// Size of the discrete state space |S| (for the tabular baseline;
    /// the paper quotes 1800 for the complex environment).
    fn state_space(&self) -> usize;

    /// Discrete id of the current state, in `0..state_space()`.
    fn state_id(&self) -> usize;

    /// Reset to a start state (deterministic given the constructor seed
    /// and reset count).
    fn reset(&mut self);

    /// Encode (current state, action) into `out` (length D, values ⊂ [−1,1]
    /// so they are representable in Q(18,12) without saturation).
    fn encode_sa(&self, action: usize, out: &mut [f32]);

    /// Encode all A actions of the current state into `out` (row-major
    /// (A, D)) — the input tile of one feed-forward sweep.
    fn encode_all(&self, out: &mut [f32]) {
        let (a_n, d) = (self.n_actions(), self.d());
        debug_assert_eq!(out.len(), a_n * d);
        for a in 0..a_n {
            self.encode_sa(a, &mut out[a * d..(a + 1) * d]);
        }
    }

    /// Apply `action`; returns the reward and terminal flag.
    fn step(&mut self, action: usize) -> StepResult;

    /// Whether the current episode has terminated.
    fn is_done(&self) -> bool;

    /// Human-readable name for logs/telemetry.
    fn name(&self) -> &'static str;
}
