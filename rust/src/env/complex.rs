//! The paper's “complex environment”: D = 20, A = 40, |S| = 1800.
//!
//! A 60×30 Mars-yard traverse (60·30 = 1800 cells = the paper's state-space
//! size). The rover senses hazard distance along all 8 headings (ray-cast
//! “navcam” sensors), knows the bearing/range to the nearest science target,
//! and commands one of 40 actions = 8 headings × 5 speed levels (speed 0 =
//! turn in place; heading-0/speed-0 doubles as “sample”).

use crate::config::{Arch, EnvKind, NetConfig};
use crate::util::Rng;

use super::encoding::ActionCode;
use super::gridworld::{Grid, MoveOutcome, Pose};
use super::terrain::Terrain;
use super::traits::{Environment, StepResult};
use super::SHAPING_GAMMA;

const W: usize = 60;
const H: usize = 30;
const MAX_STEPS: usize = 400;
const SENSOR_RANGE: usize = 8;
const N_SCIENCE: usize = 5;

/// Complex Mars-yard traverse environment.
pub struct ComplexRoverEnv {
    grid: Grid,
    pristine: Terrain,
    pose: Pose,
    battery: f32,
    steps: usize,
    collected: usize,
    done: bool,
    episodes: u64,
    seed: u64,
    /// Cached 16 state dims, recomputed once per state change. `encode_all`
    /// evaluates A = 40 action encodings per step; without the cache each
    /// would redo the ray casts and the nearest-science scan (the dominant
    /// cost on the coordinator hot path — see EXPERIMENTS.md §Perf).
    state_feat: [f32; 16],
}

impl ComplexRoverEnv {
    pub fn new(seed: u64) -> Self {
        let terrain = Terrain::generate(W, H, 0.08, N_SCIENCE, seed.wrapping_add(101));
        let mut env = ComplexRoverEnv {
            grid: Grid::new(terrain.clone()),
            pristine: terrain,
            pose: Pose::origin(),
            battery: 1.0,
            steps: 0,
            collected: 0,
            done: false,
            episodes: 0,
            seed,
            state_feat: [0.0; 16],
        };
        env.reset();
        env
    }

    /// Recompute the cached state features (after every state change).
    fn refresh_state_features(&mut self) {
        let mut f = [0f32; 16];
        f[0] = self.pose.x as f32 / (W - 1) as f32 * 2.0 - 1.0;
        f[1] = self.pose.y as f32 / (H - 1) as f32 * 2.0 - 1.0;
        let (s, c) = self.pose.heading_sincos();
        f[2] = s;
        f[3] = c;
        f[4] = self.battery * 2.0 - 1.0;
        for h in 0..8 {
            f[5 + h] = self.grid.ray_hazard_distance(&self.pose, h, SENSOR_RANGE) * 2.0 - 1.0;
        }
        let (gs, gc, gd) = self.goal_vector();
        f[13] = gs;
        f[14] = gc;
        f[15] = gd;
        self.state_feat = f;
    }

    pub fn pose(&self) -> Pose {
        self.pose
    }

    pub fn collected(&self) -> usize {
        self.collected
    }

    pub fn battery(&self) -> f32 {
        self.battery
    }

    fn goal_vector(&self) -> (f32, f32, f32) {
        // (sin bearing, cos bearing, normalized distance) to nearest target
        match self.grid.terrain.nearest_science(self.pose.x, self.pose.y) {
            None => (0.0, 0.0, -1.0),
            Some((tx, ty)) => {
                let dx = tx as f32 - self.pose.x as f32;
                let dy = ty as f32 - self.pose.y as f32;
                let dist = (dx * dx + dy * dy).sqrt();
                let max_d = ((W * W + H * H) as f32).sqrt();
                if dist < 0.5 {
                    (0.0, 0.0, 2.0 * (dist / max_d) - 1.0)
                } else {
                    (dx / dist, dy / dist, 2.0 * (dist / max_d) - 1.0)
                }
            }
        }
    }

    fn spend(&mut self, amount: f32) -> bool {
        self.battery = (self.battery - amount).max(0.0);
        if self.battery == 0.0 {
            self.done = true;
            true
        } else {
            false
        }
    }

    /// Shaping potential φ(s) = −0.02 · distance-to-nearest-science
    /// ([`Terrain::science_potential`]).
    fn potential(&self) -> f32 {
        self.grid.terrain.science_potential(self.pose.x, self.pose.y, 0.02)
    }
}

impl Environment for ComplexRoverEnv {
    fn net_config(&self) -> NetConfig {
        NetConfig::new(Arch::Perceptron, EnvKind::Complex) // D/A only
    }

    fn state_space(&self) -> usize {
        W * H // = 1800, the paper's state-space size
    }

    fn state_id(&self) -> usize {
        self.grid.cell_id(&self.pose)
    }

    fn reset(&mut self) {
        self.grid = Grid::new(self.pristine.clone());
        let mut rng = Rng::seeded(self.seed ^ (self.episodes << 23));
        loop {
            let x = rng.below(W / 3);
            let y = rng.below(H);
            if !self.grid.terrain.is_hazard(x, y) && !self.grid.terrain.is_science(x, y) {
                self.pose = Pose { x, y, heading: rng.below(8) };
                break;
            }
        }
        self.battery = 1.0;
        self.steps = 0;
        self.collected = 0;
        self.done = false;
        self.episodes += 1;
        self.refresh_state_features();
    }

    fn encode_sa(&self, action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 20);
        // 16 state dims (cached — recomputed once per state change)
        out[..16].copy_from_slice(&self.state_feat);
        // 4 action dims
        ActionCode::complex(action, &mut out[16..20]);
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.done, "step() after terminal state");
        assert!(action < 40, "complex action {action} out of range");
        self.steps += 1;
        let (heading, speed) = ActionCode::complex_parts(action);
        let phi_before = self.potential();
        let mut reward = -0.01;

        if ActionCode::complex_is_sample(action) {
            if self.grid.terrain.is_science(self.pose.x, self.pose.y) {
                self.grid.terrain.clear_science(self.pose.x, self.pose.y);
                self.collected += 1;
                reward += 1.0;
                if self.grid.terrain.science_remaining() == 0 {
                    self.done = true; // full mission success
                    reward += 1.0;
                }
            } else {
                reward -= 0.1;
            }
            if self.spend(0.01) {
                reward -= 0.5;
            }
        } else if speed == 0 {
            // turn in place toward `heading`
            self.pose.heading = heading;
            if self.spend(0.005) {
                reward -= 0.5;
            }
        } else {
            let before = (self.pose.x, self.pose.y);
            match self.grid.advance(&mut self.pose, heading, speed) {
                MoveOutcome::Moved => {
                    // energy scales with distance and climbed slope
                    let slope = self.grid.terrain.slope(before, (self.pose.x, self.pose.y));
                    if self.spend(0.005 * speed as f32 + 0.02 * slope) {
                        reward -= 0.5;
                    }
                }
                MoveOutcome::Edge => {
                    reward -= 0.05;
                    if self.spend(0.005) {
                        reward -= 0.5;
                    }
                }
                MoveOutcome::Hazard => {
                    reward -= 1.0;
                    self.done = true;
                }
            }
        }

        // potential-based shaping (policy-invariant)
        reward += SHAPING_GAMMA * self.potential() - phi_before;

        if self.steps >= MAX_STEPS {
            self.done = true;
        }
        self.refresh_state_features();
        StepResult { reward, done: self.done }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn name(&self) -> &'static str {
        "complex-mars-yard-60x30"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_match_paper() {
        let env = ComplexRoverEnv::new(1);
        assert_eq!(env.d(), 20);
        assert_eq!(env.n_actions(), 40);
        assert_eq!(env.state_space(), 1800); // the paper's |S|
    }

    #[test]
    fn encode_bounded() {
        let env = ComplexRoverEnv::new(2);
        let mut out = vec![0f32; 40 * 20];
        env.encode_all(&mut out);
        for v in out {
            assert!((-1.0..=1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = ComplexRoverEnv::new(3);
        let mut b = ComplexRoverEnv::new(3);
        for action in [7, 12, 3, 22, 17, 9] {
            let ra = a.step(action);
            let rb = b.step(action);
            assert_eq!(ra, rb);
            if ra.done {
                break;
            }
        }
    }

    #[test]
    fn sampling_collects_targets() {
        let mut env = ComplexRoverEnv::new(4);
        // place the rover directly on the nearest science target (test has
        // module access to the pose) and sample
        let (tx, ty) = env.grid.terrain.nearest_science(env.pose.x, env.pose.y).unwrap();
        env.pose.x = tx;
        env.pose.y = ty;
        let r = env.step(0); // sample action
        assert!(r.reward > 0.5, "reward {}", r.reward);
        assert_eq!(env.collected(), 1);
        // sampling on a non-science cell is penalized
        env.reset();
        assert!(!env.grid.terrain.is_science(env.pose.x, env.pose.y));
        let r2 = env.step(0);
        assert!(r2.reward < 0.0);
        assert_eq!(env.collected(), 0);
    }

    #[test]
    fn turn_in_place_changes_heading_only() {
        let mut env = ComplexRoverEnv::new(5);
        let p0 = env.pose();
        env.step(3 * 5); // heading 3, speed 0 -> turn
        let p1 = env.pose();
        assert_eq!((p0.x, p0.y), (p1.x, p1.y));
        assert_eq!(p1.heading, 3);
    }

    #[test]
    fn episode_always_terminates() {
        let mut env = ComplexRoverEnv::new(6);
        let mut n = 0;
        while !env.is_done() {
            env.step(2 * 5 + 4); // drive east fast
            n += 1;
            assert!(n <= MAX_STEPS);
        }
    }

    #[test]
    fn state_id_tracks_cell() {
        let mut env = ComplexRoverEnv::new(7);
        let id0 = env.state_id();
        assert!(id0 < 1800);
        env.step(2 * 5 + 2); // move east 2
        let id1 = env.state_id();
        assert!(id1 < 1800);
        if !env.is_done() {
            assert_ne!(id0, id1);
        }
    }
}
