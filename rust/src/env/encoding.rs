//! Action-code encodings: map discrete action ids into the low-dimensional
//! continuous action slice of the state-action vector.
//!
//! The paper gives the action-code widths (2 dims in the simple
//! environment, part of the 20-dim vector in the complex one) but not the
//! encoding itself; we use smooth, bounded codes (sin/cos for directions,
//! normalized magnitudes) so nearby actions have nearby codes — the property
//! a function-approximating Q-net needs to generalize.

/// Encoding of one discrete action into `width` floats in [−1, 1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActionCode;

impl ActionCode {
    /// Simple environment: 6 actions -> 2 dims.
    ///
    /// Actions: 0 forward, 1 reverse, 2 turn-left, 3 turn-right,
    /// 4 sample, 5 idle/recharge.
    /// dim0 = category (move −1, turn 0, task +1), dim1 = polarity.
    pub fn simple(action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 2);
        let (cat, pol) = match action {
            0 => (-1.0, 1.0),  // forward
            1 => (-1.0, -1.0), // reverse
            2 => (0.0, -1.0),  // turn left
            3 => (0.0, 1.0),   // turn right
            4 => (1.0, 1.0),   // sample
            5 => (1.0, -1.0),  // idle / recharge
            _ => panic!("simple action {action} out of range"),
        };
        out[0] = cat;
        out[1] = pol;
    }

    /// Complex environment: 40 actions = 8 headings × 5 speeds -> 4 dims:
    /// (sin θ, cos θ, speed/4 scaled to [−1,1], drive-vs-sample flag).
    /// Speed 0 of heading 0 doubles as the “sample” action; all other
    /// speed-0 variants are “hold” (turn in place to that heading).
    pub fn complex(action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 4);
        let (heading, speed) = Self::complex_parts(action);
        let theta = heading as f32 * std::f32::consts::FRAC_PI_4;
        out[0] = theta.sin();
        out[1] = theta.cos();
        out[2] = speed as f32 / 4.0 * 2.0 - 1.0;
        out[3] = if Self::complex_is_sample(action) { 1.0 } else { -1.0 };
    }

    /// Crater/slip scenarios: A = 8 absolute-heading moves -> 2 dims
    /// (sin θ, cos θ) — the same smooth direction code the complex
    /// environment uses, without the speed/sample axes.
    pub fn heading8(action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 2);
        assert!(action < 8, "heading action {action} out of range");
        let theta = action as f32 * std::f32::consts::FRAC_PI_4;
        out[0] = theta.sin();
        out[1] = theta.cos();
    }

    /// Energy-budget scenario: A = 10 (8 heading moves + sample +
    /// recharge) -> 3 dims: (sin θ, cos θ, task code). Moves carry task
    /// code −1; sample is (0, 0, +1); recharge is (0, 0, +0.5) — distinct,
    /// bounded, and smooth within the move family.
    pub fn energy(action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 3);
        match action {
            0..=7 => {
                let theta = action as f32 * std::f32::consts::FRAC_PI_4;
                out[0] = theta.sin();
                out[1] = theta.cos();
                out[2] = -1.0;
            }
            8 => {
                // sample
                out[0] = 0.0;
                out[1] = 0.0;
                out[2] = 1.0;
            }
            9 => {
                // recharge
                out[0] = 0.0;
                out[1] = 0.0;
                out[2] = 0.5;
            }
            _ => panic!("energy action {action} out of range"),
        }
    }

    /// Decompose a complex action id into (heading 0..8, speed 0..5).
    #[inline]
    pub fn complex_parts(action: usize) -> (usize, usize) {
        debug_assert!(action < 40, "complex action {action} out of range");
        (action / 5, action % 5)
    }

    /// Whether a complex action is the sampling action (heading 0, speed 0).
    #[inline]
    pub fn complex_is_sample(action: usize) -> bool {
        action == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_codes_distinct_and_bounded() {
        let mut seen = std::collections::HashSet::new();
        for a in 0..6 {
            let mut out = [0f32; 2];
            ActionCode::simple(a, &mut out);
            for v in out {
                assert!((-1.0..=1.0).contains(&v));
            }
            assert!(seen.insert(format!("{out:?}")), "duplicate code for {a}");
        }
    }

    #[test]
    fn complex_codes_distinct_and_bounded() {
        let mut seen = std::collections::HashSet::new();
        for a in 0..40 {
            let mut out = [0f32; 4];
            ActionCode::complex(a, &mut out);
            for v in out {
                assert!((-1.0..=1.0).contains(&v), "action {a}: {v}");
            }
            assert!(
                seen.insert(format!("{:?}", out.map(|v| (v * 1e4) as i32))),
                "duplicate code for {a}"
            );
        }
    }

    #[test]
    fn heading8_codes_distinct_and_bounded() {
        let mut seen = std::collections::HashSet::new();
        for a in 0..8 {
            let mut out = [0f32; 2];
            ActionCode::heading8(a, &mut out);
            for v in out {
                assert!((-1.0..=1.0).contains(&v), "action {a}: {v}");
            }
            assert!(
                seen.insert(format!("{:?}", out.map(|v| (v * 1e4) as i32))),
                "duplicate code for {a}"
            );
        }
    }

    #[test]
    fn energy_codes_distinct_and_bounded() {
        let mut seen = std::collections::HashSet::new();
        for a in 0..10 {
            let mut out = [0f32; 3];
            ActionCode::energy(a, &mut out);
            for v in out {
                assert!((-1.0..=1.0).contains(&v), "action {a}: {v}");
            }
            assert!(
                seen.insert(format!("{:?}", out.map(|v| (v * 1e4) as i32))),
                "duplicate code for {a}"
            );
        }
    }

    #[test]
    #[should_panic]
    fn energy_action_out_of_range_panics() {
        let mut out = [0f32; 3];
        ActionCode::energy(10, &mut out);
    }

    #[test]
    fn complex_parts_roundtrip() {
        for a in 0..40 {
            let (h, s) = ActionCode::complex_parts(a);
            assert_eq!(h * 5 + s, a);
            assert!(h < 8 && s < 5);
        }
        assert!(ActionCode::complex_is_sample(0));
        assert!(!ActionCode::complex_is_sample(5));
    }

    #[test]
    #[should_panic]
    fn simple_action_out_of_range_panics() {
        let mut out = [0f32; 2];
        ActionCode::simple(6, &mut out);
    }
}
