//! Energy-budget scenario (`EnvKind::Energy`): D = 12, A = 10.
//!
//! A 16×16 survey where the binding constraint is the **battery**, not the
//! terrain: every step pays a thermal draw (survival heaters), every move
//! pays a drive cost that grows with the slope climbed, and the episode
//! ends — stranded, with a penalty — the moment the charge hits zero.
//! Three recharge pads are scattered over the map; the `RECHARGE` action
//! restores charge only while parked on one. Two science targets must be
//! sampled to finish the mission, so a competent policy interleaves
//! science approaches with detours to the pads — the MER/MSL energy-aware
//! traverse problem in miniature.
//!
//! Battery is a *continuous* state dimension in the encoding (the NN
//! backends see it directly); the tabular baseline, as in the paper's
//! simple environment, discretizes state to the cell id (|S| = 256).

use crate::config::{Arch, EnvKind, NetConfig};
use crate::util::Rng;

use super::encoding::ActionCode;
use super::gridworld::{Grid, MoveOutcome, Pose};
use super::terrain::Terrain;
use super::traits::{Environment, StepResult};
use super::SHAPING_GAMMA;

const W: usize = 16;
const H: usize = 16;
const MAX_STEPS: usize = 300;
const N_SCIENCE: usize = 2;
const N_CHARGERS: usize = 3;
/// Survival-heater draw, every step regardless of action.
const THERMAL_DRAIN: f32 = 0.01;
/// Base drive cost per move, plus a slope-proportional surcharge.
const MOVE_DRAIN: f32 = 0.02;
const SLOPE_DRAIN: f32 = 0.04;
/// Charge restored per `RECHARGE` action on a pad.
const RECHARGE_AMOUNT: f32 = 0.25;

/// Action ids: 0..8 move along the compass heading, then the two tasks.
pub const SAMPLE: usize = 8;
pub const RECHARGE: usize = 9;

/// Energy-budget survey environment.
pub struct EnergyBudgetEnv {
    grid: Grid,
    pristine: Terrain,
    /// Recharge pads — fixed map features, same across episodes.
    chargers: Vec<(usize, usize)>,
    pose: Pose,
    battery: f32,
    steps: usize,
    collected: usize,
    done: bool,
    episodes: u64,
    seed: u64,
    /// Cached 9 state dims, recomputed once per state change.
    state_feat: [f32; 9],
}

impl EnergyBudgetEnv {
    pub fn new(seed: u64) -> Self {
        let terrain = Terrain::generate(W, H, 0.06, N_SCIENCE, seed.wrapping_add(0xE6E7));
        // pads on free cells, away from hazards and targets
        let mut rng = Rng::seeded(seed ^ 0x00E6_E76B);
        let mut chargers = Vec::with_capacity(N_CHARGERS);
        while chargers.len() < N_CHARGERS {
            let x = rng.below(W);
            let y = rng.below(H);
            if (x, y) != (0, 0)
                && !terrain.is_hazard(x, y)
                && !terrain.is_science(x, y)
                && !chargers.contains(&(x, y))
            {
                chargers.push((x, y));
            }
        }
        let mut env = EnergyBudgetEnv {
            grid: Grid::new(terrain.clone()),
            pristine: terrain,
            chargers,
            pose: Pose::origin(),
            battery: 1.0,
            steps: 0,
            collected: 0,
            done: false,
            episodes: 0,
            seed,
            state_feat: [0.0; 9],
        };
        env.reset();
        env
    }

    pub fn pose(&self) -> Pose {
        self.pose
    }

    pub fn battery(&self) -> f32 {
        self.battery
    }

    pub fn collected(&self) -> usize {
        self.collected
    }

    pub fn on_charger(&self) -> bool {
        self.chargers.contains(&(self.pose.x, self.pose.y))
    }

    /// Drain `amount`; terminal (stranded) when the charge hits zero.
    fn spend(&mut self, amount: f32) -> bool {
        self.battery = (self.battery - amount).max(0.0);
        if self.battery == 0.0 {
            self.done = true;
            true
        } else {
            false
        }
    }

    fn nearest_charger_vector(&self) -> (f32, f32, f32) {
        let mut best: Option<((usize, usize), f32)> = None;
        for &(cx, cy) in &self.chargers {
            let dx = cx as f32 - self.pose.x as f32;
            let dy = cy as f32 - self.pose.y as f32;
            let d2 = dx * dx + dy * dy;
            if best.map_or(true, |(_, b)| d2 < b) {
                best = Some(((cx, cy), d2));
            }
        }
        match best {
            None => (0.0, 0.0, -1.0),
            Some(((cx, cy), _)) => self.grid.terrain.vector_to(self.pose.x, self.pose.y, cx, cy),
        }
    }

    fn refresh_state_features(&mut self) {
        let t = &self.grid.terrain;
        let mut f = [0f32; 9];
        f[0] = self.pose.x as f32 / (W - 1) as f32 * 2.0 - 1.0;
        f[1] = self.pose.y as f32 / (H - 1) as f32 * 2.0 - 1.0;
        f[2] = self.battery * 2.0 - 1.0;
        let (gs, gc, gd) = t.science_vector(self.pose.x, self.pose.y);
        f[3] = gs;
        f[4] = gc;
        f[5] = gd;
        let (cs, cc, cd) = self.nearest_charger_vector();
        f[6] = cs;
        f[7] = cc;
        f[8] = cd;
        self.state_feat = f;
    }

    /// Shaping potential φ(s) = −0.04 · distance-to-nearest-science
    /// ([`Terrain::science_potential`]).
    fn potential(&self) -> f32 {
        self.grid.terrain.science_potential(self.pose.x, self.pose.y, 0.04)
    }
}

impl Environment for EnergyBudgetEnv {
    fn net_config(&self) -> NetConfig {
        NetConfig::new(Arch::Perceptron, EnvKind::Energy) // D/A only
    }

    fn state_space(&self) -> usize {
        // battery is continuous and excluded from the tabular id — the NN
        // backends see it through the encoding (as in the simple env)
        W * H
    }

    fn state_id(&self) -> usize {
        self.grid.cell_id(&self.pose)
    }

    fn reset(&mut self) {
        self.grid = Grid::new(self.pristine.clone());
        let mut rng = Rng::seeded(self.seed ^ (self.episodes << 19));
        loop {
            let x = rng.below(W);
            let y = rng.below(H / 2);
            if !self.grid.terrain.is_hazard(x, y) && !self.grid.terrain.is_science(x, y) {
                self.pose = Pose { x, y, heading: rng.below(8) };
                break;
            }
        }
        self.battery = 1.0;
        self.steps = 0;
        self.collected = 0;
        self.done = false;
        self.episodes += 1;
        self.refresh_state_features();
    }

    fn encode_sa(&self, action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 12);
        out[..9].copy_from_slice(&self.state_feat);
        ActionCode::energy(action, &mut out[9..12]);
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.done, "step() after terminal state");
        assert!(action < 10, "energy action {action} out of range");
        self.steps += 1;
        let phi_before = self.potential();
        let mut reward = -0.01; // time/step cost
        let mut stranded = false;

        match action {
            0..=7 => {
                let before = (self.pose.x, self.pose.y);
                match self.grid.advance(&mut self.pose, action, 1) {
                    MoveOutcome::Moved => {
                        let slope =
                            self.grid.terrain.slope(before, (self.pose.x, self.pose.y));
                        stranded = self.spend(MOVE_DRAIN + SLOPE_DRAIN * slope);
                    }
                    MoveOutcome::Edge => {
                        reward -= 0.05;
                        stranded = self.spend(0.5 * MOVE_DRAIN); // wheels still spun
                    }
                    MoveOutcome::Hazard => {
                        reward -= 1.0;
                        self.done = true;
                    }
                }
            }
            SAMPLE => {
                if self.grid.terrain.is_science(self.pose.x, self.pose.y) {
                    self.grid.terrain.clear_science(self.pose.x, self.pose.y);
                    self.collected += 1;
                    reward += 1.0;
                    if self.grid.terrain.science_remaining() == 0 {
                        reward += 0.5; // full mission success
                        self.done = true;
                    }
                } else {
                    reward -= 0.1; // wasted sampling cycle
                }
                // a mission-completing sample cannot strand the rover —
                // the traverse is over, so the drain no longer applies
                if !self.done {
                    stranded = self.spend(MOVE_DRAIN);
                }
            }
            RECHARGE => {
                if self.on_charger() {
                    self.battery = (self.battery + RECHARGE_AMOUNT).min(1.0);
                } else {
                    reward -= 0.05; // nothing to plug into here
                }
            }
            _ => unreachable!(),
        }

        // survival heaters draw every step, even while parked — unless the
        // episode already ended (hazard, full mission, or stranded above)
        if !self.done {
            stranded = self.spend(THERMAL_DRAIN) || stranded;
        }
        if stranded {
            reward -= 1.0; // dead rover, mission over
        }

        // potential-based shaping (policy-invariant)
        reward += SHAPING_GAMMA * self.potential() - phi_before;

        if self.steps >= MAX_STEPS {
            self.done = true;
        }
        self.refresh_state_features();
        StepResult { reward, done: self.done }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn name(&self) -> &'static str {
        "energy-budget-16x16"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_match_config() {
        let env = EnergyBudgetEnv::new(1);
        assert_eq!(env.d(), 12);
        assert_eq!(env.n_actions(), 10);
        assert_eq!(env.state_space(), W * H);
    }

    #[test]
    fn encode_bounded() {
        let env = EnergyBudgetEnv::new(2);
        let mut out = vec![0f32; 10 * 12];
        env.encode_all(&mut out);
        for v in out {
            assert!((-1.0..=1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = EnergyBudgetEnv::new(3);
        let mut b = EnergyBudgetEnv::new(3);
        for action in [2, 2, 9, 4, 8, 0, 6, 2] {
            let ra = a.step(action);
            let rb = b.step(action);
            assert_eq!(ra, rb);
            assert_eq!(a.state_id(), b.state_id());
            assert_eq!(a.battery(), b.battery());
            if ra.done {
                break;
            }
        }
    }

    #[test]
    fn battery_depletion_ends_the_episode() {
        // park and let the heaters drain the battery: 1.0 / 0.01 = 100
        // steps of recharging off-pad (which costs only the thermal draw)
        let mut env = EnergyBudgetEnv::new(4);
        // drive to a non-charger state deterministically: if the start is a
        // pad the first recharge is free but the thermal draw still applies
        let mut steps = 0;
        while !env.is_done() {
            env.step(RECHARGE);
            steps += 1;
            assert!(steps <= MAX_STEPS, "depletion must terminate the episode");
        }
        if !env.on_charger() {
            assert_eq!(env.battery(), 0.0);
            assert!(steps <= 100, "thermal drain alone caps survival at 100 steps");
        }
    }

    #[test]
    fn recharge_works_only_on_pads() {
        let mut env = EnergyBudgetEnv::new(5);
        // move once to spend charge, then park off-pad and recharge
        env.step(2);
        if env.is_done() {
            return; // unlucky hazard start — covered by other seeds
        }
        let b = env.battery();
        if env.on_charger() {
            env.step(RECHARGE);
            assert!(env.battery() > b, "pad recharge must restore charge");
        } else {
            env.step(RECHARGE);
            // off-pad: only the thermal draw applies
            assert!((env.battery() - (b - THERMAL_DRAIN)).abs() < 1e-6);
        }
    }

    #[test]
    fn charger_pads_are_deterministic_map_features() {
        let a = EnergyBudgetEnv::new(6);
        let b = EnergyBudgetEnv::new(6);
        assert_eq!(a.chargers, b.chargers);
        assert_eq!(a.chargers.len(), N_CHARGERS);
        for &(x, y) in &a.chargers {
            assert!(!a.grid.terrain.is_hazard(x, y));
            assert!(!a.grid.terrain.is_science(x, y));
        }
    }

    #[test]
    fn mission_completing_sample_is_not_stranded() {
        // regression: the final sample used to pay the stranded penalty
        // when its drive drain emptied an almost-dead battery
        let mut env = EnergyBudgetEnv::new(11);
        // leave exactly one target, stand on it with a nearly dead battery
        let (t1x, t1y) = env.grid.terrain.nearest_science(0, 0).unwrap();
        env.grid.terrain.clear_science(t1x, t1y);
        let (tx, ty) = env.grid.terrain.nearest_science(0, 0).unwrap();
        env.pose.x = tx;
        env.pose.y = ty;
        env.battery = 0.015; // below MOVE_DRAIN: a charged sample would strand
        let r = env.step(SAMPLE);
        assert!(r.done, "full mission success must terminate");
        assert!(
            r.reward > 1.0,
            "completing sample must not pay the stranded penalty: {}",
            r.reward
        );
    }

    #[test]
    fn sampling_collects_targets() {
        let mut env = EnergyBudgetEnv::new(7);
        let (tx, ty) = env.grid.terrain.nearest_science(env.pose.x, env.pose.y).unwrap();
        env.pose.x = tx;
        env.pose.y = ty;
        let r = env.step(SAMPLE);
        assert!(r.reward > 0.5, "reward {}", r.reward);
        assert_eq!(env.collected(), 1);
    }

    #[test]
    fn reset_restores_battery_and_map() {
        let mut env = EnergyBudgetEnv::new(8);
        for _ in 0..40 {
            if env.is_done() {
                break;
            }
            env.step(2);
        }
        env.reset();
        assert!(!env.is_done());
        assert_eq!(env.battery(), 1.0);
        assert_eq!(env.collected(), 0);
        assert_eq!(env.grid.terrain.science_remaining(), N_SCIENCE);
    }
}
