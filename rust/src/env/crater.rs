//! Crater-field scenario (`EnvKind::Crater`): D = 10, A = 8.
//!
//! A 20×20 traverse across a procedurally cratered plain. Craters are
//! stamped onto the value-noise base terrain ([`Terrain::stamp_crater`]):
//! a graded parabolic bowl the rover *can* drive through — paying a
//! slope-proportional penalty on every descent and climb — ringed by a
//! raised ejecta rim that is **impassable** (bumping it costs reward but
//! does not end the episode, unlike the lethal hazards of the paper
//! environments). The mission is to reach a single science target on the
//! far side of the field; the interesting policy question is *which bowls
//! to cross and which to drive around*.
//!
//! Actions are the 8 absolute compass headings (move one cell). The
//! tabular state is the cell id (|S| = 400); heading is not part of the
//! state because moves are absolute.

use crate::config::{Arch, EnvKind, NetConfig};
use crate::util::Rng;

use super::encoding::ActionCode;
use super::gridworld::{Grid, MoveOutcome, Pose, HEADINGS};
use super::terrain::Terrain;
use super::traits::{Environment, StepResult};
use super::SHAPING_GAMMA;

const W: usize = 20;
const H: usize = 20;
const MAX_STEPS: usize = 250;
const N_CRATERS: usize = 6;

/// Crater-field navigation environment.
pub struct CraterFieldEnv {
    grid: Grid,
    pristine: Terrain,
    pose: Pose,
    steps: usize,
    done: bool,
    episodes: u64,
    seed: u64,
    /// Cached 8 state dims, recomputed once per state change (encode_all
    /// evaluates A = 8 action encodings per step).
    state_feat: [f32; 8],
}

/// Base terrain + stamped craters + one goal cell, all from the seed.
/// Every rim gets a carved entrance gap (a sealed bowl would be a dead
/// zone under 8-connected movement), and the goal is placed only on a
/// cell BFS-reachable from the start region, so every episode is solvable.
fn cratered_terrain(seed: u64) -> Terrain {
    let mut t = Terrain::generate(W, H, 0.0, 0, seed.wrapping_add(0xC8A7));
    let mut rng = Rng::seeded(seed ^ 0x00C8_A7E8);
    for _ in 0..N_CRATERS {
        let cx = rng.range(2, W - 2);
        let cy = rng.range(2, H - 2);
        let radius = 1.5 + rng.f32() * 1.8;
        let depth = 0.3 + rng.f32() * 0.3;
        t.stamp_crater(cx, cy, radius, depth);
        // carve an entrance: clear the rim cells around one azimuth
        let gap = rng.f32() * std::f32::consts::TAU;
        for offset in [-0.4f32, 0.0, 0.4] {
            let gx = cx as f32 + radius * (gap + offset).cos();
            let gy = cy as f32 + radius * (gap + offset).sin();
            let (gx, gy) = (gx.round(), gy.round());
            if gx >= 0.0 && gy >= 0.0 && (gx as usize) < W && (gy as usize) < H {
                let i = t.idx(gx as usize, gy as usize);
                t.hazard[i] = false;
            }
        }
    }
    // one science target on a cell reachable from the start region (the
    // western third, where reset() places the rover)
    let reachable = reachable_cells(&t);
    let pick = |band: std::ops::Range<usize>| -> Vec<usize> {
        (0..W * H)
            .filter(|&i| reachable[i] && band.contains(&(i % W)))
            .collect()
    };
    let mut candidates = pick(W / 2..W);
    if candidates.is_empty() {
        candidates = pick(1..W); // degenerate map: anywhere but column 0
    }
    let goal = candidates[rng.below(candidates.len())];
    t.science[goal] = true;
    t
}

/// 8-connected flood fill over non-hazard cells, seeded from every
/// passable cell of the start region (x < W/3).
fn reachable_cells(t: &Terrain) -> Vec<bool> {
    let mut seen = vec![false; W * H];
    let mut queue = std::collections::VecDeque::new();
    for y in 0..H {
        for x in 0..W / 3 {
            if !t.is_hazard(x, y) {
                seen[t.idx(x, y)] = true;
                queue.push_back((x, y));
            }
        }
    }
    while let Some((x, y)) = queue.pop_front() {
        for (dx, dy) in HEADINGS {
            let nx = x as i32 + dx;
            let ny = y as i32 + dy;
            if nx < 0 || ny < 0 || nx >= W as i32 || ny >= H as i32 {
                continue;
            }
            let (nx, ny) = (nx as usize, ny as usize);
            if !t.is_hazard(nx, ny) && !seen[t.idx(nx, ny)] {
                seen[t.idx(nx, ny)] = true;
                queue.push_back((nx, ny));
            }
        }
    }
    seen
}

impl CraterFieldEnv {
    pub fn new(seed: u64) -> Self {
        let terrain = cratered_terrain(seed);
        let mut env = CraterFieldEnv {
            grid: Grid::new(terrain.clone()),
            pristine: terrain,
            pose: Pose::origin(),
            steps: 0,
            done: false,
            episodes: 0,
            seed,
            state_feat: [0.0; 8],
        };
        env.reset();
        env
    }

    pub fn pose(&self) -> Pose {
        self.pose
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    fn refresh_state_features(&mut self) {
        let t = &self.grid.terrain;
        let mut f = [0f32; 8];
        f[0] = self.pose.x as f32 / (W - 1) as f32 * 2.0 - 1.0;
        f[1] = self.pose.y as f32 / (H - 1) as f32 * 2.0 - 1.0;
        let (gs, gc, gd) = t.science_vector(self.pose.x, self.pose.y);
        f[2] = gs;
        f[3] = gc;
        f[4] = gd;
        let (gx, gy) = t.gradient(self.pose.x, self.pose.y);
        f[5] = gx;
        f[6] = gy;
        f[7] = t.elevation_at(self.pose.x, self.pose.y) * 2.0 - 1.0;
        self.state_feat = f;
    }

    /// Shaping potential φ(s) = −0.04 · distance-to-goal
    /// ([`Terrain::science_potential`]).
    fn potential(&self) -> f32 {
        self.grid.terrain.science_potential(self.pose.x, self.pose.y, 0.04)
    }
}

impl Environment for CraterFieldEnv {
    fn net_config(&self) -> NetConfig {
        NetConfig::new(Arch::Perceptron, EnvKind::Crater) // D/A only
    }

    fn state_space(&self) -> usize {
        W * H // moves are absolute, so heading is not state
    }

    fn state_id(&self) -> usize {
        self.grid.cell_id(&self.pose)
    }

    fn reset(&mut self) {
        self.grid = Grid::new(self.pristine.clone());
        let mut rng = Rng::seeded(self.seed ^ (self.episodes << 17));
        loop {
            let x = rng.below(W / 3);
            let y = rng.below(H);
            if !self.grid.terrain.is_hazard(x, y) && !self.grid.terrain.is_science(x, y) {
                self.pose = Pose { x, y, heading: rng.below(8) };
                break;
            }
        }
        self.steps = 0;
        self.done = false;
        self.episodes += 1;
        self.refresh_state_features();
    }

    fn encode_sa(&self, action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 10);
        out[..8].copy_from_slice(&self.state_feat);
        ActionCode::heading8(action, &mut out[8..10]);
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.done, "step() after terminal state");
        assert!(action < 8, "crater action {action} out of range");
        self.steps += 1;
        let phi_before = self.potential();
        let mut reward = -0.01; // time/step cost

        let before = self.pose;
        match self.grid.advance(&mut self.pose, action, 1) {
            MoveOutcome::Moved => {
                // graded slope penalties: descending into a bowl risks the
                // rover (steeper = worse), climbing out costs drive energy
                let e0 = self.grid.terrain.elevation_at(before.x, before.y);
                let e1 = self.grid.terrain.elevation_at(self.pose.x, self.pose.y);
                let drop = (e0 - e1).max(0.0);
                let rise = (e1 - e0).max(0.0);
                reward -= 0.4 * drop + 0.2 * rise;
                if self.grid.terrain.is_science(self.pose.x, self.pose.y) {
                    reward += 1.0; // mission success
                    self.done = true;
                }
            }
            MoveOutcome::Edge => reward -= 0.05,
            MoveOutcome::Hazard => {
                // crater rims are impassable, not lethal: bounce back
                self.pose = before;
                self.pose.heading = action;
                reward -= 0.2;
            }
        }

        // potential-based shaping (policy-invariant)
        reward += SHAPING_GAMMA * self.potential() - phi_before;

        if self.steps >= MAX_STEPS {
            self.done = true;
        }
        self.refresh_state_features();
        StepResult { reward, done: self.done }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn name(&self) -> &'static str {
        "crater-field-20x20"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_match_config() {
        let env = CraterFieldEnv::new(1);
        assert_eq!(env.d(), 10);
        assert_eq!(env.n_actions(), 8);
        assert_eq!(env.state_space(), W * H);
    }

    #[test]
    fn encode_bounded() {
        let env = CraterFieldEnv::new(2);
        let mut out = vec![0f32; 8 * 10];
        env.encode_all(&mut out);
        for v in out {
            assert!((-1.0..=1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = CraterFieldEnv::new(3);
        let mut b = CraterFieldEnv::new(3);
        for action in [2, 2, 0, 4, 6, 2, 1, 3] {
            let ra = a.step(action);
            let rb = b.step(action);
            assert_eq!(ra, rb);
            assert_eq!(a.state_id(), b.state_id());
            if ra.done {
                break;
            }
        }
    }

    #[test]
    fn rims_are_impassable_not_lethal() {
        // walk the map; every rim bump must leave the rover on a passable
        // cell with the episode still alive (unless it timed out)
        let mut env = CraterFieldEnv::new(4);
        for i in 0..200 {
            if env.is_done() {
                break;
            }
            env.step(i % 8);
            let p = env.pose();
            assert!(
                !env.grid.terrain.is_hazard(p.x, p.y),
                "rover ended up inside a rim cell at ({}, {})",
                p.x,
                p.y
            );
        }
    }

    #[test]
    fn episode_terminates() {
        let mut env = CraterFieldEnv::new(5);
        let mut steps = 0;
        while !env.is_done() {
            env.step(0); // keep driving north into the edge
            steps += 1;
            assert!(steps <= MAX_STEPS);
        }
    }

    #[test]
    fn terrain_has_craters_and_one_goal() {
        let t = cratered_terrain(6);
        assert!(t.hazard.iter().any(|&h| h), "no rim cells stamped");
        assert_eq!(t.science_remaining(), 1);
        // the goal is reachable terrain, not a rim cell
        let (gx, gy) = t.nearest_science(0, 0).unwrap();
        assert!(!t.is_hazard(gx, gy));
    }

    #[test]
    fn goal_is_reachable_from_the_start_region_for_many_seeds() {
        // the mission must be solvable: rims get entrance gaps and the
        // goal is placed by flood fill, so no seed may seal it off
        for seed in 0..40 {
            let t = cratered_terrain(seed);
            let reach = reachable_cells(&t);
            let (gx, gy) = t.nearest_science(0, 0).unwrap();
            assert!(reach[t.idx(gx, gy)], "seed {seed}: goal sealed off at ({gx}, {gy})");
        }
    }

    #[test]
    fn reset_varies_start_but_restores_map() {
        let mut env = CraterFieldEnv::new(7);
        let science_before = env.grid.terrain.science.clone();
        for _ in 0..30 {
            if env.is_done() {
                break;
            }
            env.step(2);
        }
        env.reset();
        assert!(!env.is_done());
        assert_eq!(env.steps(), 0);
        assert_eq!(env.grid.terrain.science, science_before);
    }
}
