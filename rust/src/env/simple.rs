//! The paper's “simple environment”: D = 6 (4 state + 2 action dims), A = 6.
//!
//! Concretely: an 8×8 ridge-crossing gridworld. The rover must reach and
//! sample a science target while avoiding hazards and managing its battery —
//! the minimal version of the AEGIS-style autonomy the paper motivates.

use crate::config::{Arch, EnvKind, NetConfig};
use crate::util::Rng;

use super::encoding::ActionCode;
use super::gridworld::{Grid, MoveOutcome, Pose};
use super::terrain::Terrain;
use super::traits::{Environment, StepResult};
use super::SHAPING_GAMMA;

const W: usize = 8;
const H: usize = 8;
const MAX_STEPS: usize = 200;

/// Action ids (see [`ActionCode::simple`]).
pub const FORWARD: usize = 0;
pub const REVERSE: usize = 1;
pub const TURN_LEFT: usize = 2;
pub const TURN_RIGHT: usize = 3;
pub const SAMPLE: usize = 4;
pub const RECHARGE: usize = 5;

/// Simple rover navigation environment.
pub struct SimpleRoverEnv {
    grid: Grid,
    pristine: Terrain,
    pose: Pose,
    battery: f32,
    steps: usize,
    done: bool,
    episodes: u64,
    seed: u64,
}

impl SimpleRoverEnv {
    pub fn new(seed: u64) -> Self {
        let terrain = Terrain::generate(W, H, 0.10, 1, seed);
        let mut env = SimpleRoverEnv {
            grid: Grid::new(terrain.clone()),
            pristine: terrain,
            pose: Pose::origin(),
            battery: 1.0,
            steps: 0,
            done: false,
            episodes: 0,
            seed,
        };
        env.reset();
        env
    }

    pub fn pose(&self) -> Pose {
        self.pose
    }

    pub fn battery(&self) -> f32 {
        self.battery
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    fn spend(&mut self, amount: f32) -> bool {
        self.battery = (self.battery - amount).max(0.0);
        if self.battery == 0.0 {
            self.done = true;
            true
        } else {
            false
        }
    }

    /// Shaping potential φ(s) = −0.05 · distance-to-nearest-science
    /// ([`Terrain::science_potential`]) — a dense progress signal,
    /// necessary for a single tiny MLP to make visible progress in a few
    /// hundred episodes.
    fn potential(&self) -> f32 {
        self.grid.terrain.science_potential(self.pose.x, self.pose.y, 0.05)
    }
}

impl Environment for SimpleRoverEnv {
    fn net_config(&self) -> NetConfig {
        NetConfig::new(Arch::Perceptron, EnvKind::Simple) // D/A only; arch irrelevant
    }

    fn state_space(&self) -> usize {
        // cell × heading (battery is continuous and excluded from the
        // tabular id — the NN backends see it through the encoding).
        W * H * 8
    }

    fn state_id(&self) -> usize {
        self.grid.cell_id(&self.pose) * 8 + self.pose.heading
    }

    fn reset(&mut self) {
        self.grid = Grid::new(self.pristine.clone());
        // deterministic but episode-varying start, clear of hazards
        let mut rng = Rng::seeded(self.seed ^ (self.episodes << 17));
        loop {
            let x = rng.below(W / 2);
            let y = rng.below(H / 2);
            if !self.grid.terrain.is_hazard(x, y) && !self.grid.terrain.is_science(x, y) {
                self.pose = Pose { x, y, heading: rng.below(8) };
                break;
            }
        }
        self.battery = 1.0;
        self.steps = 0;
        self.done = false;
        self.episodes += 1;
    }

    fn encode_sa(&self, action: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 6);
        // 4 state dims, all in [−1, 1]
        out[0] = self.pose.x as f32 / (W - 1) as f32 * 2.0 - 1.0;
        out[1] = self.pose.y as f32 / (H - 1) as f32 * 2.0 - 1.0;
        out[2] = self.pose.heading as f32 / 7.0 * 2.0 - 1.0;
        out[3] = self.battery * 2.0 - 1.0;
        // 2 action dims
        ActionCode::simple(action, &mut out[4..6]);
    }

    fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.done, "step() after terminal state");
        assert!(action < 6, "simple action {action} out of range");
        self.steps += 1;
        let phi_before = self.potential();
        let mut reward = -0.01; // time/step cost

        match action {
            FORWARD | REVERSE => {
                let heading = if action == FORWARD {
                    self.pose.heading
                } else {
                    (self.pose.heading + 4) % 8
                };
                let kept = self.pose.heading;
                match self.grid.advance(&mut self.pose, heading, 1) {
                    MoveOutcome::Moved => {}
                    MoveOutcome::Edge => reward -= 0.05,
                    MoveOutcome::Hazard => {
                        reward -= 1.0;
                        self.done = true;
                    }
                }
                // reversing does not change the facing direction
                self.pose.heading = kept;
                if self.spend(0.02) {
                    reward -= 0.5; // stranded
                }
            }
            TURN_LEFT => {
                self.pose.heading = (self.pose.heading + 7) % 8;
                if self.spend(0.01) {
                    reward -= 0.5;
                }
            }
            TURN_RIGHT => {
                self.pose.heading = (self.pose.heading + 1) % 8;
                if self.spend(0.01) {
                    reward -= 0.5;
                }
            }
            SAMPLE => {
                if self.grid.terrain.is_science(self.pose.x, self.pose.y) {
                    reward += 1.0; // mission success
                    self.grid.terrain.clear_science(self.pose.x, self.pose.y);
                    self.done = true;
                } else {
                    reward -= 0.1; // wasted sampling cycle
                }
                if self.spend(0.02) {
                    reward -= 0.5;
                }
            }
            RECHARGE => {
                self.battery = (self.battery + 0.05).min(1.0);
            }
            _ => unreachable!(),
        }

        // potential-based shaping (policy-invariant)
        reward += SHAPING_GAMMA * self.potential() - phi_before;

        if self.steps >= MAX_STEPS {
            self.done = true;
        }
        StepResult { reward, done: self.done }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn name(&self) -> &'static str {
        "simple-rover-8x8"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_match_paper() {
        let env = SimpleRoverEnv::new(1);
        assert_eq!(env.d(), 6);
        assert_eq!(env.n_actions(), 6);
    }

    #[test]
    fn encode_bounded() {
        let env = SimpleRoverEnv::new(2);
        let mut out = vec![0f32; 6 * 6];
        env.encode_all(&mut out);
        for v in out {
            assert!((-1.0..=1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SimpleRoverEnv::new(3);
        let mut b = SimpleRoverEnv::new(3);
        for action in [0, 2, 0, 3, 0, 4, 5, 1] {
            let ra = a.step(action);
            let rb = b.step(action);
            assert_eq!(ra, rb);
            assert_eq!(a.state_id(), b.state_id());
            if ra.done {
                break;
            }
        }
    }

    #[test]
    fn episode_terminates() {
        let mut env = SimpleRoverEnv::new(4);
        let mut steps = 0;
        while !env.is_done() {
            env.step(RECHARGE);
            steps += 1;
            assert!(steps <= MAX_STEPS);
        }
        assert_eq!(steps, MAX_STEPS);
    }

    #[test]
    fn turning_cycles_heading() {
        let mut env = SimpleRoverEnv::new(5);
        let h0 = env.pose().heading;
        for _ in 0..8 {
            env.step(TURN_RIGHT);
        }
        assert_eq!(env.pose().heading, h0);
    }

    #[test]
    fn battery_drains_and_recharges() {
        let mut env = SimpleRoverEnv::new(6);
        let b0 = env.battery();
        env.step(TURN_LEFT);
        assert!(env.battery() < b0);
        let b1 = env.battery();
        env.step(RECHARGE);
        assert!(env.battery() > b1);
    }

    #[test]
    fn state_ids_within_space() {
        let mut env = SimpleRoverEnv::new(7);
        for action in [0, 1, 2, 3, 0, 0, 2, 0] {
            assert!(env.state_id() < env.state_space());
            if env.step(action).done {
                break;
            }
        }
    }

    #[test]
    fn reset_restores_terrain_and_battery() {
        let mut env = SimpleRoverEnv::new(8);
        for _ in 0..50 {
            if env.is_done() {
                break;
            }
            env.step(FORWARD);
        }
        env.reset();
        assert!(!env.is_done());
        assert_eq!(env.battery(), 1.0);
        assert_eq!(env.steps(), 0);
    }
}
