//! Rover environments — the paper's “simple” and “complex” environments.
//!
//! The paper specifies only the interface dimensions (Section 5):
//!
//! * simple:  state+action vector D = 6 (4 state dims + 2 action dims),
//!   A = 6 actions per state;
//! * complex: D = 20, A = 40, |S| = 1800.
//!
//! Any environment with those dimensions exercises the identical accelerator
//! datapath, so we build what the paper's introduction motivates: planetary
//! rover navigation with terrain hazards, science targets and an energy
//! budget (MSL/AEGIS-style target seeking). [`SimpleRoverEnv`] is a small
//! ridge-crossing gridworld; [`ComplexRoverEnv`] is a 60×30 Mars-yard
//! traverse (60·30 = 1800 = |S|) with ray-cast terrain sensing and 8
//! headings × 5 speed levels = 40 actions.

mod complex;
mod encoding;
mod gridworld;
mod simple;
mod terrain;
mod traits;

pub use complex::ComplexRoverEnv;
pub use encoding::ActionCode;
pub use gridworld::{Grid, Pose};
pub use simple::SimpleRoverEnv;
pub use terrain::Terrain;
pub use traits::{Environment, StepResult};

use crate::config::EnvKind;

/// Construct the paper environment of the given kind with a seed.
pub fn make_env(kind: EnvKind, seed: u64) -> Box<dyn Environment> {
    match kind {
        EnvKind::Simple => Box::new(SimpleRoverEnv::new(seed)),
        EnvKind::Complex => Box::new(ComplexRoverEnv::new(seed)),
    }
}
