//! Rover environments: the paper's two benchmarks plus the mission
//! scenario library.
//!
//! The paper specifies only the interface dimensions of its two
//! environments (Section 5): simple (D = 6, A = 6) and complex (D = 20,
//! A = 40, |S| = 1800). Any environment with fixed D/A exercises the
//! identical accelerator datapath, so this module grows the workload set
//! the way the paper's introduction motivates — planetary rover autonomy —
//! into five [`crate::config::EnvKind`]s (see SCENARIOS.md for maps,
//! reward tables and runnable commands):
//!
//! | kind      | environment                                   | D  | A  | \|S\| |
//! |-----------|-----------------------------------------------|----|----|------|
//! | `simple`  | [`SimpleRoverEnv`] 8×8 ridge crossing         | 6  | 6  | 512  |
//! | `complex` | [`ComplexRoverEnv`] 60×30 Mars yard           | 20 | 40 | 1800 |
//! | `crater`  | [`CraterFieldEnv`] 20×20 crater field         | 10 | 8  | 400  |
//! | `slip`    | [`SlipSlopeEnv`] 24×18 slip-under-slope       | 11 | 8  | 432  |
//! | `energy`  | [`EnergyBudgetEnv`] 16×16 battery survey      | 12 | 10 | 256  |
//!
//! # The `Environment` contract
//!
//! Every environment implements [`Environment`] and honors three
//! invariants the rest of the stack is built on:
//!
//! 1. **Encode-all feed-forward sweep.** The learner asks for the
//!    encodings of *all* A actions of the current state at once
//!    ([`Environment::encode_all`], row-major (A, D)) — the input tile of
//!    one feed-forward sweep through the accelerator — selects an action,
//!    steps, and repeats (the paper's Section 2 state-flow).
//! 2. **Q(18,12) range invariant.** Every encoding component lies in
//!    [−1, 1], so state-action vectors are representable in the default
//!    Q(18,12) fixed-point format without saturation. Enforced for all
//!    kinds by the property tests in `tests/proptests.rs`.
//! 3. **Seed determinism.** Trajectories are bit-identical functions of
//!    the constructor seed and the action sequence — including the slip
//!    environment's stochastic dynamics, which draw from an internal
//!    seeded stream. Replays, fleet workers and CI campaigns depend on it.
//!
//! ```
//! use qfpga::config::EnvKind;
//! use qfpga::env::make_env;
//!
//! let mut env = make_env(EnvKind::Crater, 7);
//! let mut tile = vec![0.0; env.n_actions() * env.d()];
//! env.encode_all(&mut tile); // one feed-forward sweep's worth of input
//! assert!(tile.iter().all(|v| (-1.0..=1.0).contains(v)));
//! let result = env.step(2); // drive east
//! assert!(result.reward.is_finite());
//! ```

mod complex;
mod crater;
mod encoding;
mod energy;
mod gridworld;
mod simple;
mod slip;
mod terrain;
mod traits;

pub use complex::ComplexRoverEnv;
pub use crater::CraterFieldEnv;
pub use encoding::ActionCode;
pub use energy::EnergyBudgetEnv;
pub use gridworld::{Grid, Pose};
pub use simple::SimpleRoverEnv;
pub use slip::SlipSlopeEnv;
pub use terrain::Terrain;
pub use traits::{Environment, StepResult};

use crate::config::EnvKind;

/// Discount used for potential-based reward shaping (γ·φ(s′) − φ(s),
/// Ng et al. 1999) in every environment. Matches the default γ of
/// [`crate::config::Hyper`] so shaping stays policy-invariant under the
/// default hyper-parameters; see [`Terrain::science_potential`] for the
/// potential itself.
pub const SHAPING_GAMMA: f32 = 0.9;

/// Construct the environment of the given kind with a seed. The seed fully
/// determines the terrain, the start states and (for the slip environment)
/// the stochastic dynamics.
pub fn make_env(kind: EnvKind, seed: u64) -> Box<dyn Environment> {
    match kind {
        EnvKind::Simple => Box::new(SimpleRoverEnv::new(seed)),
        EnvKind::Complex => Box::new(ComplexRoverEnv::new(seed)),
        EnvKind::Crater => Box::new(CraterFieldEnv::new(seed)),
        EnvKind::Slip => Box::new(SlipSlopeEnv::new(seed)),
        EnvKind::Energy => Box::new(EnergyBudgetEnv::new(seed)),
    }
}
