//! Procedural Mars-yard terrain: seeded value-noise elevation, hazard mask
//! and science targets. Deterministic for a given seed so every experiment
//! is reproducible.

use crate::util::Rng;

/// A rectangular terrain patch.
#[derive(Debug, Clone)]
pub struct Terrain {
    pub width: usize,
    pub height: usize,
    /// Elevation in [0, 1], row-major.
    pub elevation: Vec<f32>,
    /// Hazard cells (craters, sand traps) the rover must avoid.
    pub hazard: Vec<bool>,
    /// Science-target cells (AEGIS-style laser targets).
    pub science: Vec<bool>,
}

impl Terrain {
    /// Generate terrain with roughly `hazard_frac` hazards and
    /// `n_science` science targets, none of them on the start cell (0,0).
    pub fn generate(
        width: usize,
        height: usize,
        hazard_frac: f64,
        n_science: usize,
        seed: u64,
    ) -> Self {
        assert!(width >= 2 && height >= 1, "terrain too small");
        let mut rng = Rng::seeded(seed);

        // Coarse value-noise: random lattice, bilinear upsample, two octaves.
        let elevation = Self::value_noise(width, height, &mut rng);

        let mut hazard = vec![false; width * height];
        let mut placed = 0usize;
        let target_hazards = ((width * height) as f64 * hazard_frac) as usize;
        while placed < target_hazards {
            let idx = rng.below(width * height);
            // keep the start region clear
            if idx == 0 || hazard[idx] {
                continue;
            }
            hazard[idx] = true;
            placed += 1;
        }

        let mut science = vec![false; width * height];
        let mut placed = 0usize;
        while placed < n_science {
            let idx = rng.below(width * height);
            if idx == 0 || hazard[idx] || science[idx] {
                continue;
            }
            science[idx] = true;
            placed += 1;
        }

        Terrain { width, height, elevation, hazard, science }
    }

    fn value_noise(width: usize, height: usize, rng: &mut Rng) -> Vec<f32> {
        let mut out = vec![0f32; width * height];
        for (octave, amp) in [(4usize, 0.7f32), (8, 0.3)] {
            let gw = octave + 1;
            let gh = octave + 1;
            let lattice: Vec<f32> = rng.vec_f32(gw * gh, 0.0, 1.0);
            for y in 0..height {
                for x in 0..width {
                    let fx = x as f32 / (width - 1).max(1) as f32 * (gw - 1) as f32;
                    let fy = y as f32 / (height - 1).max(1) as f32 * (gh - 1) as f32;
                    let (x0, y0) = (fx as usize, fy as usize);
                    let (x1, y1) = ((x0 + 1).min(gw - 1), (y0 + 1).min(gh - 1));
                    let (tx, ty) = (fx - x0 as f32, fy - y0 as f32);
                    let v00 = lattice[y0 * gw + x0];
                    let v10 = lattice[y0 * gw + x1];
                    let v01 = lattice[y1 * gw + x0];
                    let v11 = lattice[y1 * gw + x1];
                    let v = v00 * (1.0 - tx) * (1.0 - ty)
                        + v10 * tx * (1.0 - ty)
                        + v01 * (1.0 - tx) * ty
                        + v11 * tx * ty;
                    out[y * width + x] += amp * v;
                }
            }
        }
        out
    }

    #[inline]
    pub fn idx(&self, x: usize, y: usize) -> usize {
        debug_assert!(x < self.width && y < self.height);
        y * self.width + x
    }

    #[inline]
    pub fn elevation_at(&self, x: usize, y: usize) -> f32 {
        self.elevation[self.idx(x, y)]
    }

    #[inline]
    pub fn is_hazard(&self, x: usize, y: usize) -> bool {
        self.hazard[self.idx(x, y)]
    }

    #[inline]
    pub fn is_science(&self, x: usize, y: usize) -> bool {
        self.science[self.idx(x, y)]
    }

    /// Remove a science target once sampled.
    pub fn clear_science(&mut self, x: usize, y: usize) {
        let i = self.idx(x, y);
        self.science[i] = false;
    }

    /// Slope magnitude between two cells (for energy cost / hazard checks).
    pub fn slope(&self, from: (usize, usize), to: (usize, usize)) -> f32 {
        (self.elevation_at(to.0, to.1) - self.elevation_at(from.0, from.1)).abs()
    }

    /// Stamp a crater: a parabolic bowl of `depth` depressed inside
    /// `radius`, ringed by a raised, **impassable** rim (the rim cells are
    /// marked hazard). Elevation stays clamped to [0, 1]. Used by the
    /// crater-field scenario (see SCENARIOS.md).
    pub fn stamp_crater(&mut self, cx: usize, cy: usize, radius: f32, depth: f32) {
        assert!(radius > 0.5, "crater radius {radius} too small for a rim");
        for y in 0..self.height {
            for x in 0..self.width {
                let dx = x as f32 - cx as f32;
                let dy = y as f32 - cy as f32;
                let dist = (dx * dx + dy * dy).sqrt();
                let i = y * self.width + x;
                if dist <= radius - 0.5 {
                    // graded bowl, deepest at the centre
                    let bowl = depth * (1.0 - (dist / radius) * (dist / radius));
                    self.elevation[i] = (self.elevation[i] - bowl).max(0.0);
                } else if dist <= radius + 0.5 {
                    // ejecta rim: raised and impassable
                    self.elevation[i] = (self.elevation[i] + 0.5 * depth).min(1.0);
                    self.hazard[i] = true;
                }
            }
        }
    }

    /// Central-difference elevation gradient at a cell, clamped at the map
    /// borders. Each component is bounded by [−1, 1] since elevation is.
    pub fn gradient(&self, x: usize, y: usize) -> (f32, f32) {
        let ex = |x: usize, y: usize| self.elevation_at(x, y);
        let gx = ex((x + 1).min(self.width - 1), y) - ex(x.saturating_sub(1), y);
        let gy = ex(x, (y + 1).min(self.height - 1)) - ex(x, y.saturating_sub(1));
        (gx, gy)
    }

    /// Shaping potential φ(x, y) = −`coeff` · euclidean distance to the
    /// nearest remaining science target (0 when none remain). Every
    /// environment shapes its reward with γ·φ(s′) − φ(s) (potential-based
    /// shaping, Ng et al. 1999, policy-invariant) using
    /// [`crate::env::SHAPING_GAMMA`]; only the distance coefficient
    /// differs per environment.
    pub fn science_potential(&self, x: usize, y: usize, coeff: f32) -> f32 {
        match self.nearest_science(x, y) {
            None => 0.0,
            Some((tx, ty)) => {
                let dx = tx as f32 - x as f32;
                let dy = ty as f32 - y as f32;
                -coeff * (dx * dx + dy * dy).sqrt()
            }
        }
    }

    /// (sin bearing, cos bearing, distance scaled to [−1, 1]) from `(x, y)`
    /// toward the nearest remaining science target; `(0, 0, −1)` when none
    /// remain or the rover is on the target.
    pub fn science_vector(&self, x: usize, y: usize) -> (f32, f32, f32) {
        match self.nearest_science(x, y) {
            None => (0.0, 0.0, -1.0),
            Some((tx, ty)) => self.vector_to(x, y, tx, ty),
        }
    }

    /// (sin bearing, cos bearing, distance scaled to [−1, 1]) from `(x, y)`
    /// to an arbitrary cell; the bearing degenerates to `(0, 0)` when the
    /// two cells coincide.
    pub fn vector_to(&self, x: usize, y: usize, tx: usize, ty: usize) -> (f32, f32, f32) {
        let dx = tx as f32 - x as f32;
        let dy = ty as f32 - y as f32;
        let dist = (dx * dx + dy * dy).sqrt();
        let max_d = ((self.width * self.width + self.height * self.height) as f32).sqrt();
        let scaled = 2.0 * (dist / max_d) - 1.0;
        if dist < 0.5 {
            (0.0, 0.0, scaled)
        } else {
            (dx / dist, dy / dist, scaled)
        }
    }

    /// Nearest science target to `(x, y)` (euclidean), if any remain.
    pub fn nearest_science(&self, x: usize, y: usize) -> Option<(usize, usize)> {
        let mut best: Option<((usize, usize), f32)> = None;
        for ty in 0..self.height {
            for tx in 0..self.width {
                if self.science[self.idx(tx, ty)] {
                    let dx = tx as f32 - x as f32;
                    let dy = ty as f32 - y as f32;
                    let d2 = dx * dx + dy * dy;
                    if best.map_or(true, |(_, b)| d2 < b) {
                        best = Some(((tx, ty), d2));
                    }
                }
            }
        }
        best.map(|(p, _)| p)
    }

    pub fn science_remaining(&self) -> usize {
        self.science.iter().filter(|&&s| s).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Terrain::generate(30, 20, 0.1, 5, 42);
        let b = Terrain::generate(30, 20, 0.1, 5, 42);
        assert_eq!(a.elevation, b.elevation);
        assert_eq!(a.hazard, b.hazard);
        assert_eq!(a.science, b.science);
        let c = Terrain::generate(30, 20, 0.1, 5, 43);
        assert_ne!(a.hazard, c.hazard);
    }

    #[test]
    fn start_cell_clear() {
        for seed in 0..20 {
            let t = Terrain::generate(10, 10, 0.2, 3, seed);
            assert!(!t.hazard[0], "seed {seed}");
            assert!(!t.science[0], "seed {seed}");
        }
    }

    #[test]
    fn counts_respected() {
        let t = Terrain::generate(40, 25, 0.1, 7, 7);
        assert_eq!(t.science_remaining(), 7);
        let hazards = t.hazard.iter().filter(|&&h| h).count();
        assert_eq!(hazards, (40.0f64 * 25.0 * 0.1) as usize);
    }

    #[test]
    fn elevation_bounded() {
        let t = Terrain::generate(30, 30, 0.0, 0, 3);
        for &e in &t.elevation {
            assert!((0.0..=1.0).contains(&e));
        }
    }

    #[test]
    fn crater_stamps_bowl_and_impassable_rim() {
        let mut t = Terrain::generate(20, 20, 0.0, 0, 9);
        let before_center = t.elevation_at(10, 10);
        t.stamp_crater(10, 10, 3.0, 0.5);
        // bowl floor depressed (or already at the 0.0 clamp)
        assert!(t.elevation_at(10, 10) < before_center || t.elevation_at(10, 10) == 0.0);
        // rim cells (distance ≈ radius) are hazard; the centre is not
        assert!(t.is_hazard(13, 10), "rim east");
        assert!(t.is_hazard(7, 10), "rim west");
        assert!(!t.is_hazard(10, 10), "bowl centre must stay passable");
        for &e in &t.elevation {
            assert!((0.0..=1.0).contains(&e));
        }
    }

    #[test]
    fn gradient_bounded_and_flat_on_constant_terrain() {
        let mut t = Terrain::generate(8, 8, 0.0, 0, 12);
        t.elevation.fill(0.5);
        for y in 0..8 {
            for x in 0..8 {
                assert_eq!(t.gradient(x, y), (0.0, 0.0));
            }
        }
        let t = Terrain::generate(8, 8, 0.0, 0, 13);
        for y in 0..8 {
            for x in 0..8 {
                let (gx, gy) = t.gradient(x, y);
                assert!((-1.0..=1.0).contains(&gx) && (-1.0..=1.0).contains(&gy));
            }
        }
    }

    #[test]
    fn science_vector_points_at_target_and_degenerates_cleanly() {
        let mut t = Terrain::generate(10, 10, 0.0, 0, 14);
        let target = t.idx(9, 0);
        t.science[target] = true;
        let (s, c, d) = t.science_vector(0, 0);
        assert!(s > 0.9 && c.abs() < 0.1, "({s}, {c})"); // due east
        assert!((-1.0..=1.0).contains(&d));
        // on the target: zero bearing
        assert_eq!(t.science_vector(9, 0).0, 0.0);
        t.clear_science(9, 0);
        assert_eq!(t.science_vector(0, 0), (0.0, 0.0, -1.0));
    }

    #[test]
    fn nearest_science_finds_target() {
        let mut t = Terrain::generate(10, 10, 0.0, 1, 11);
        let (tx, ty) = t.nearest_science(0, 0).unwrap();
        assert!(t.is_science(tx, ty));
        t.clear_science(tx, ty);
        assert_eq!(t.nearest_science(0, 0), None);
    }
}
