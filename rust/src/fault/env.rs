//! Mission radiation environments.
//!
//! Upset rates are expressed per bit per **kilostep** (a step being one
//! coordinator interaction step). These are *simulation-scale* figures: the
//! relative ordering follows the space-radiation literature (interplanetary
//! cruise under galactic cosmic rays, the partially shielded Mars surface,
//! the brutal Jovian trapped-radiation belts), while the absolute scale is
//! chosen so a full training mission accumulates a physically meaningful
//! number of upsets. Calibrate `Custom` against a real device/mission pair.

use crate::error::{Error, Result};

/// A mission radiation environment, i.e. an upset-rate operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RadEnvironment {
    /// Interplanetary cruise: galactic cosmic rays, no planetary shielding.
    Cruise,
    /// Mars surface: ~2.5 g/cm² CO₂ column + planet body shadowing.
    MarsSurface,
    /// Jupiter flyby: trapped-electron belts, orders of magnitude harsher.
    JupiterFlyby,
    /// Explicit rate, upsets per bit per kilostep.
    Custom(f64),
}

impl RadEnvironment {
    /// Upsets per bit per kilostep.
    pub fn upsets_per_bit_per_kilostep(&self) -> f64 {
        match self {
            RadEnvironment::Cruise => 3.0e-2,
            RadEnvironment::MarsSurface => 1.0e-2,
            RadEnvironment::JupiterFlyby => 2.0,
            RadEnvironment::Custom(r) => *r,
        }
    }

    /// Upsets per bit per step — the unit [`crate::fault::FaultModel`] uses.
    pub fn upsets_per_bit_per_step(&self) -> f64 {
        self.upsets_per_bit_per_kilostep() / 1e3
    }

    /// The named environments (CLI enumeration, campaign sweeps).
    pub fn named() -> [RadEnvironment; 3] {
        [
            RadEnvironment::Cruise,
            RadEnvironment::MarsSurface,
            RadEnvironment::JupiterFlyby,
        ]
    }

    pub fn label(&self) -> String {
        match self {
            RadEnvironment::Cruise => "cruise".into(),
            RadEnvironment::MarsSurface => "mars-surface".into(),
            RadEnvironment::JupiterFlyby => "jupiter-flyby".into(),
            RadEnvironment::Custom(r) => format!("custom({r:e})"),
        }
    }
}

impl std::str::FromStr for RadEnvironment {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "cruise" => Ok(RadEnvironment::Cruise),
            "mars" | "mars-surface" => Ok(RadEnvironment::MarsSurface),
            "jupiter" | "jupiter-flyby" => Ok(RadEnvironment::JupiterFlyby),
            other => match other.parse::<f64>() {
                Ok(r) if r >= 0.0 => Ok(RadEnvironment::Custom(r)),
                _ => Err(Error::Config(format!(
                    "unknown radiation environment `{other}` \
                     (cruise|mars-surface|jupiter-flyby|<rate/bit/kstep>)"
                ))),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_ordering() {
        let c = RadEnvironment::Cruise.upsets_per_bit_per_step();
        let m = RadEnvironment::MarsSurface.upsets_per_bit_per_step();
        let j = RadEnvironment::JupiterFlyby.upsets_per_bit_per_step();
        assert!(m < c, "Mars surface is shielded relative to cruise");
        assert!(c < j, "Jupiter is the harshest environment");
    }

    #[test]
    fn parse_roundtrip_and_custom() {
        for e in RadEnvironment::named() {
            let back: RadEnvironment = e.label().parse().unwrap();
            assert_eq!(back, e);
        }
        let c: RadEnvironment = "0.5".parse().unwrap();
        assert_eq!(c, RadEnvironment::Custom(0.5));
        assert!("-1".parse::<RadEnvironment>().is_err());
        assert!("ganymede".parse::<RadEnvironment>().is_err());
    }
}
