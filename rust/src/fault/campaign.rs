//! Resilience campaigns: rate × mitigation × backend across the fleet.
//!
//! One campaign cell = a full multi-rover training run (through
//! [`crate::coordinator::scheduler::run_fleet`]) under a fault plan, scored
//! as the fleet's mean learning delta against the fault-free baseline of
//! the same backend, alongside the mitigation's modeled hardware overheads.
//! Campaigns are deterministic: the same spec reproduces the same report
//! bit-for-bit (see `tests/fault_determinism.rs`).

use crate::config::Precision;
use crate::coordinator::mission::MissionConfig;
use crate::coordinator::scheduler::run_fleet;
use crate::error::Result;
use crate::fpga::power::PowerCoeffs;
use crate::fpga::TimingModel;
use crate::qlearn::backend::BackendKind;
use crate::util::Json;

use super::mitigation::Mitigation;
use super::model::FaultStats;
use super::schedule::RateSchedule;
use super::FaultPlan;

/// What to campaign.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Mission template (arch/env/precision/episodes/seed/batch…); its
    /// `backend` and `fault` fields are overridden per cell.
    pub base: MissionConfig,
    pub backends: Vec<BackendKind>,
    /// Upset rates, per bit per step.
    pub rates: Vec<f64>,
    pub mitigations: Vec<Mitigation>,
    /// Rovers per cell (the fleet width).
    pub rovers: usize,
    /// Optional time profile (`--rate-schedule`): each cell's constant
    /// rate becomes the base of this profile, rescaled so the profile's
    /// base matches the cell rate (a zero-base profile — a pure solar
    /// event — is applied as-is). `None` keeps constant rates.
    pub schedule: Option<RateSchedule>,
}

/// One campaign cell outcome.
#[derive(Debug, Clone)]
pub struct ResilienceCell {
    pub backend: BackendKind,
    pub rate: f64,
    pub mitigation: Mitigation,
    /// Fleet mean learning delta under injection.
    pub learning_delta: f32,
    /// Fault-free fleet mean learning delta (same backend/seeds).
    pub baseline_delta: f32,
    /// Summed fault accounting across the fleet.
    pub stats: FaultStats,
    /// Modeled hardening overheads vs the unmitigated datapath.
    pub area_overhead: f64,
    pub power_overhead: f64,
    pub cycle_overhead: f64,
}

impl ResilienceCell {
    /// Learning lost to radiation: baseline − faulty (positive = worse).
    pub fn degradation(&self) -> f32 {
        self.baseline_delta - self.learning_delta
    }
}

/// A full campaign outcome.
#[derive(Debug, Clone)]
pub struct ResilienceReport {
    pub cells: Vec<ResilienceCell>,
    pub rovers: usize,
    pub episodes: usize,
    pub seed: u64,
    pub precision: Precision,
    /// The time profile the cells ran under, when not constant.
    pub schedule: Option<RateSchedule>,
}

impl ResilienceReport {
    /// Plain-text resilience table (the `radiation` subcommand's output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "[R2] Resilience campaign ({} rovers × {} episodes, {}, seed {})\n",
            self.rovers,
            self.episodes,
            self.precision.as_str(),
            self.seed
        ));
        if let Some(s) = &self.schedule {
            out.push_str(&format!("  rate schedule: {} (cell rates scale its base)\n", s.label()));
        }
        out.push_str(&format!(
            "  {:<9} {:>9} {:<9} {:>8} {:>8} {:>7} {:>8} {:>8} {:>7} {:>7} {:>7}\n",
            "backend",
            "rate/bit",
            "mitig.",
            "Δreward",
            "clean Δ",
            "degr.",
            "upsets",
            "masked",
            "corr.",
            "area×",
            "power×"
        ));
        out.push_str(&format!("  {:-<97}\n", ""));
        for c in &self.cells {
            out.push_str(&format!(
                "  {:<9} {:>9.1e} {:<9} {:>8.3} {:>8.3} {:>7.3} {:>8} {:>8} {:>7} {:>7.2} {:>7.2}\n",
                c.backend.as_str(),
                c.rate,
                c.mitigation.label(),
                c.learning_delta,
                c.baseline_delta,
                c.degradation(),
                c.stats.total_upsets(),
                c.stats.masked,
                c.stats.corrected,
                c.area_overhead,
                c.power_overhead
            ));
        }
        out.push_str(
            "  note: Δreward = fleet mean(last-20 − first-20 episode reward); \
             area×/power× = mitigated datapath vs unmitigated (model)\n",
        );
        out
    }

    /// Machine-readable form (campaign tracking across PRs).
    pub fn to_json(&self) -> Json {
        let cells = self
            .cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("backend", Json::Str(c.backend.as_str().into())),
                    ("rate", Json::Num(c.rate)),
                    ("mitigation", Json::Str(c.mitigation.label())),
                    ("learning_delta", Json::Num(c.learning_delta as f64)),
                    ("baseline_delta", Json::Num(c.baseline_delta as f64)),
                    ("degradation", Json::Num(c.degradation() as f64)),
                    ("upsets", Json::Num(c.stats.total_upsets() as f64)),
                    ("masked", Json::Num(c.stats.masked as f64)),
                    ("corrected", Json::Num(c.stats.corrected as f64)),
                    ("uncorrectable", Json::Num(c.stats.uncorrectable as f64)),
                    ("scrubbed", Json::Num(c.stats.scrubbed as f64)),
                    ("area_overhead", Json::Num(c.area_overhead)),
                    ("power_overhead", Json::Num(c.power_overhead)),
                    ("cycle_overhead", Json::Num(c.cycle_overhead)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("id", Json::Str("R2".into())),
            ("campaign", Json::Str("resilience".into())),
            ("rovers", Json::Num(self.rovers as f64)),
            ("episodes", Json::Num(self.episodes as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("precision", Json::Str(self.precision.as_str().into())),
        ];
        // only-when-set: constant-rate campaigns keep their historical
        // byte-identical JSON
        if let Some(s) = &self.schedule {
            fields.push(("schedule", s.to_json()));
        }
        fields.push(("cells", Json::Arr(cells)));
        Json::obj(fields)
    }
}

impl crate::report::Report for ResilienceReport {
    fn id(&self) -> &str {
        "R2"
    }

    fn render(&self) -> String {
        ResilienceReport::render(self)
    }

    fn to_json(&self) -> Json {
        ResilienceReport::to_json(self)
    }
}

/// Run the campaign: one fault-free baseline fleet per backend, then one
/// fleet per (backend, rate, mitigation) cell.
pub fn run_campaign(spec: &CampaignSpec) -> Result<ResilienceReport> {
    let coeffs = PowerCoeffs::default();
    let timing = TimingModel::default();
    let net = spec.base.net();
    let mut cells = Vec::new();

    for &backend in &spec.backends {
        let mut clean_cfg = spec.base.clone();
        clean_cfg.backend = backend;
        clean_cfg.fault = None;
        let baseline = run_fleet(&clean_cfg, spec.rovers)?.mean_learning_delta();

        for &rate in &spec.rates {
            for &mitigation in &spec.mitigations {
                let mut cfg = clean_cfg.clone();
                let schedule = spec.schedule.clone().map(|s| {
                    let base = s.base_rate();
                    if base > 0.0 {
                        s.scaled(rate / base)
                    } else {
                        s
                    }
                });
                cfg.fault = Some(FaultPlan {
                    rate,
                    mitigation,
                    schedule,
                    cram: None,
                });
                let fleet = run_fleet(&cfg, spec.rovers)?;
                let mut stats = FaultStats::default();
                for rover in &fleet.rovers {
                    if let Some(s) = rover.fault {
                        stats.add(&s);
                    }
                }
                cells.push(ResilienceCell {
                    backend,
                    rate,
                    mitigation,
                    learning_delta: fleet.mean_learning_delta(),
                    baseline_delta: baseline,
                    stats,
                    area_overhead: mitigation.area_overhead_factor(&net, cfg.precision),
                    power_overhead: mitigation
                        .power_overhead_factor(&net, cfg.precision, &coeffs),
                    cycle_overhead: mitigation
                        .cycle_overhead_factor(&net, cfg.precision, &timing),
                });
            }
        }
    }

    Ok(ResilienceReport {
        cells,
        rovers: spec.rovers,
        episodes: spec.base.episodes,
        seed: spec.base.seed,
        precision: spec.base.precision,
        schedule: spec.schedule.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};

    fn quick_spec() -> CampaignSpec {
        CampaignSpec {
            base: MissionConfig {
                arch: Arch::Mlp,
                env: EnvKind::Simple,
                precision: Precision::Fixed,
                episodes: 5,
                max_steps: 30,
                seed: 3,
                ..Default::default()
            },
            backends: vec![BackendKind::Cpu],
            rates: vec![1e-4],
            mitigations: vec![Mitigation::None, Mitigation::Tmr],
            rovers: 2,
            schedule: None,
        }
    }

    #[test]
    fn campaign_produces_one_cell_per_combination() {
        let r = run_campaign(&quick_spec()).unwrap();
        assert_eq!(r.cells.len(), 2);
        assert_eq!(r.rovers, 2);
        for c in &r.cells {
            assert_eq!(c.backend, BackendKind::Cpu);
            assert!(c.stats.total_upsets() > 0, "{}", c.mitigation.label());
            assert!(c.learning_delta.is_finite());
        }
        // the TMR cell reports the >2× hardware bill
        let tmr = r.cells.iter().find(|c| c.mitigation == Mitigation::Tmr).unwrap();
        assert!(tmr.area_overhead > 2.0);
        assert!(tmr.power_overhead > 2.0);
        let none = r.cells.iter().find(|c| c.mitigation == Mitigation::None).unwrap();
        assert_eq!(none.area_overhead, 1.0);
        assert_eq!(none.cycle_overhead, 1.0);
    }

    #[test]
    fn report_renders_and_serializes() {
        let r = run_campaign(&quick_spec()).unwrap();
        let text = r.render();
        assert!(text.contains("tmr"));
        assert!(text.contains("Δreward"));
        let j = r.to_json();
        let cells = j.get("cells").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 2);
        // serialized text parses back
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("rovers").and_then(Json::as_usize), Some(2));
        // the typed-report surface pairs campaigns by id
        assert_eq!(parsed.req_str("id").unwrap(), "R2");
        assert_eq!(crate::report::Report::id(&r), "R2");
        // constant-rate campaigns carry no schedule key (wire back-compat)
        assert!(j.get("schedule").is_none());
    }

    #[test]
    fn scheduled_campaign_is_deterministic_and_labels_its_profile() {
        let mut spec = quick_spec();
        // base matches the cell rate, so the scaling factor is exactly 1
        // and every cell sees the constant profile *plus* the event window
        spec.schedule = Some(RateSchedule::Spike { base: 1e-4, peak: 5e-3, start: 10, len: 40 });
        let a = run_campaign(&spec).unwrap();
        let b = run_campaign(&spec).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.cells.len(), 2);
        for c in &a.cells {
            assert!(c.stats.total_upsets() > 0, "{}", c.mitigation.label());
        }
        let j = a.to_json();
        assert_eq!(j.req_str("schedule").unwrap(), spec.schedule.as_ref().unwrap().label());
        assert!(a.render().contains("rate schedule:"));
    }
}
