//! Configuration-memory (CRAM) upsets: structural faults on the modeled
//! configuration-frame map of the synthesized design.
//!
//! Data upsets ([`super::model`]) flip *stored values* — a struck weight is
//! wrong until the next write-back. CRAM upsets flip *configuration bits*:
//! the LUT equations, routing muxes and DSP opmodes that define the
//! datapath itself, so a struck frame makes the hardware **misbehave
//! deterministically on every operation** until the frame is repaired.
//! On real SRAM FPGAs the configuration plane dominates the SEU
//! cross-section (tens of Mb of CRAM vs kilobits of user registers), which
//! is exactly why space deployments pair TMR with configuration scrubbing.
//!
//! The model here:
//!
//! * A [`FrameMap`] derived from the [`crate::fpga::area`] unit counts of
//!   the synthesized design: LUT fabric, DSP columns, BRAM (sigmoid ROM)
//!   columns and control-FSM registers each map to a deterministic number
//!   of configuration frames ([`CRAM_FRAME_BITS`] bits each).
//! * A seeded Poisson strike process over the frame-bit population
//!   (schedule-aware, same [`super::RateSchedule`] machinery as the data
//!   process), each strike marking one frame *dirty*.
//! * While a frame is dirty, [`CramState::corrupt`] applies that frame's
//!   class-specific structural fault to the datapath's loaded parameters —
//!   the same deterministic transform every exposure window (a struck
//!   multiplier keeps producing sign-inverted products; it does not
//!   re-randomize), until a scrub pass repairs the frame.
//! * **Partial-reconfiguration scrub** is the mitigation: `scrub: Some(n)`
//!   runs a readback+repair pass every `n` steps; `Some(0)` models
//!   continuous readback scrubbing (every upset is detected and repaired
//!   within its own exposure window, so the corruption never reaches the
//!   datapath); `None` leaves the design unscrubbed. Detection latency and
//!   repair cycles are charged through
//!   [`crate::fpga::TimingModel::cram_repair_cycles`], the scrubber
//!   hardware through [`crate::fpga::area::cram_scrubber_resources`] and
//!   [`crate::fpga::power::cram_scrubber_power_w`].
//!
//! Every strike and repair is appended to an event log
//! ([`CramState::log`]) keyed by (step, frame), which is what the
//! determinism suite compares bit-for-bit across runs and fleet widths.

use std::collections::BTreeMap;

use crate::config::{NetConfig, Precision};
use crate::error::{Error, Result};
use crate::fpga::area::accelerator_resources;
use crate::util::Json;

use super::model::{FaultModel, FaultStats};
use super::schedule::RateSchedule;

/// Bits per configuration frame (7-series: 101 words × 32 bits).
pub const CRAM_FRAME_BITS: u64 = 3232;

/// LUTs configured per logic frame (column-granularity abstraction).
const LUTS_PER_FRAME: u64 = 400;

/// Flip-flop init/control bits configured per control frame.
const FFS_PER_FRAME: u64 = 800;

/// The CRAM leg of a [`super::FaultPlan`]: strike rate on the
/// configuration plane plus the scrub mitigation setting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CramPlan {
    /// Upsets per CRAM bit per step (typically well above the data rate —
    /// the configuration plane is the larger target).
    pub rate: f64,
    /// Partial-reconfiguration scrub interval in steps: `None` leaves the
    /// design unscrubbed, `Some(0)` is continuous readback scrubbing,
    /// `Some(n)` runs a pass every `n` steps.
    pub scrub: Option<u32>,
}

impl CramPlan {
    /// Fingerprint/label component, e.g. `3e-3@scrub:64` or
    /// `3e-3@unscrubbed`.
    pub fn label(&self) -> String {
        match self.scrub {
            Some(n) => format!("{:e}@scrub:{n}", self.rate),
            None => format!("{:e}@unscrubbed", self.rate),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rate", Json::Num(self.rate)),
            (
                "scrub",
                self.scrub.map(|n| Json::Num(n as f64)).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CramPlan> {
        let rate = j.req_f64("rate")?;
        if !rate.is_finite() || rate < 0.0 {
            return Err(Error::interface(format!(
                "cram plan rate {rate} must be a finite non-negative upsets/bit/step"
            )));
        }
        let scrub = match j.get("scrub") {
            None | Some(Json::Null) => None,
            Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 && *n <= u32::MAX as f64 => {
                Some(*n as u32)
            }
            Some(other) => {
                return Err(Error::interface(format!(
                    "cram plan scrub must be null or a step interval, got `{other}`"
                )))
            }
        };
        Ok(CramPlan { rate, scrub })
    }
}

/// What a struck frame configures — selects the deterministic structural
/// fault the corruption applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameClass {
    /// LUT fabric (adder trees, comparators): a stuck intermediate line.
    Logic,
    /// DSP column (multipliers): opmode corruption, sign-inverted products.
    Arith,
    /// BRAM column (sigmoid ROMs): stuck-at-zero output port.
    Rom,
    /// Control-FSM registers: a stuck state bit forcing magnitudes.
    Control,
}

/// Configuration frames of the synthesized design, by class — derived
/// deterministically from the [`crate::fpga::area`] resource counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameMap {
    pub logic: u64,
    pub arith: u64,
    pub rom: u64,
    pub control: u64,
}

impl FrameMap {
    /// The frame map of one accelerator configuration: LUTs pack
    /// [`LUTS_PER_FRAME`] per logic frame, each DSP occupies one arithmetic
    /// frame, each BRAM36 one ROM frame, and FF init/control bits pack
    /// [`FFS_PER_FRAME`] per control frame.
    pub fn of(cfg: &NetConfig, prec: Precision) -> FrameMap {
        let r = accelerator_resources(cfg, prec);
        FrameMap {
            logic: r.luts.div_ceil(LUTS_PER_FRAME).max(1),
            arith: r.dsps,
            rom: r.bram36,
            control: r.ffs.div_ceil(FFS_PER_FRAME).max(1),
        }
    }

    pub fn total(&self) -> u64 {
        self.logic + self.arith + self.rom + self.control
    }

    /// Total susceptible configuration bits (the strike-process λ driver).
    pub fn total_bits(&self) -> u64 {
        self.total() * CRAM_FRAME_BITS
    }

    /// Which class frame index `frame` (in `[0, total)`) belongs to.
    pub fn class_of(&self, frame: u64) -> FrameClass {
        if frame < self.logic {
            FrameClass::Logic
        } else if frame < self.logic + self.arith {
            FrameClass::Arith
        } else if frame < self.logic + self.arith + self.rom {
            FrameClass::Rom
        } else {
            FrameClass::Control
        }
    }
}

/// One entry of the strike/repair event log.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CramEvent {
    /// Mission step at which the event landed (exposure-window end).
    pub step: u64,
    pub frame: u64,
    pub kind: CramEventKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CramEventKind {
    Upset,
    /// A scrub pass rewrote the frame `latency` steps after its strike.
    Repair { latency: u64 },
}

/// Live CRAM fault state of one accelerator instance: seeded strike
/// process, dirty-frame set, scrub countdown, and the deterministic event
/// log.
#[derive(Debug, Clone)]
pub struct CramState {
    model: FaultModel,
    frames: FrameMap,
    scrub: Option<u32>,
    since_scrub: u64,
    step: u64,
    /// Dirty frames → the step their (earliest) strike landed.
    dirty: BTreeMap<u64, u64>,
    log: Vec<CramEvent>,
}

impl CramState {
    /// `schedule` is the (already CRAM-scaled) rate profile; `None` keeps
    /// the plan's constant rate.
    pub fn new(
        seed: u64,
        plan: CramPlan,
        frames: FrameMap,
        schedule: Option<RateSchedule>,
    ) -> CramState {
        CramState {
            model: FaultModel::with_schedule(seed, plan.rate, schedule),
            frames,
            scrub: plan.scrub,
            since_scrub: 0,
            step: 0,
            dirty: BTreeMap::new(),
            log: Vec::new(),
        }
    }

    pub fn frames(&self) -> FrameMap {
        self.frames
    }

    pub fn dirty_frames(&self) -> usize {
        self.dirty.len()
    }

    /// The deterministic strike/repair history (what the determinism suite
    /// compares across runs and fleet widths).
    pub fn log(&self) -> &[CramEvent] {
        &self.log
    }

    /// Upset/repair accounting (folded into the mission's fault stats).
    pub fn stats(&self) -> FaultStats {
        self.model.stats
    }

    /// Advance `steps` mission steps: sample seeded strikes over the frame
    /// population, then run any due scrub pass. Returns `true` when the
    /// datapath needs a (re)load — new strikes landed, frames were
    /// repaired, or corruption is still standing.
    pub fn advance(&mut self, steps: u64) -> bool {
        if steps == 0 || self.frames.total() == 0 {
            return !self.dirty.is_empty();
        }
        let strikes = self.model.upsets(self.frames.total_bits(), steps);
        self.step += steps;
        let met = crate::obs::metrics();
        for _ in 0..strikes {
            let frame = self.model.pick(self.frames.total() as usize) as u64;
            self.model.stats.injected += 1;
            self.model.stats.cram_upsets += 1;
            met.fault_cram_upsets.inc();
            self.log.push(CramEvent { step: self.step, frame, kind: CramEventKind::Upset });
            self.dirty.entry(frame).or_insert(self.step);
        }
        let due = match self.scrub {
            // continuous readback: every strike is caught inside its own
            // exposure window
            Some(0) => !self.dirty.is_empty(),
            Some(n) => {
                self.since_scrub += steps;
                if self.since_scrub >= n as u64 {
                    self.since_scrub %= n as u64;
                    true
                } else {
                    false
                }
            }
            None => false,
        };
        let mut repaired = false;
        if due {
            for (frame, struck_at) in std::mem::take(&mut self.dirty) {
                let latency = self.step - struck_at;
                self.model.stats.cram_repairs += 1;
                met.fault_cram_repairs.inc();
                met.fault_cram_scrub_latency.observe(latency);
                self.log.push(CramEvent {
                    step: self.step,
                    frame,
                    kind: CramEventKind::Repair { latency },
                });
                repaired = true;
            }
        }
        strikes > 0 || repaired || !self.dirty.is_empty()
    }

    /// Apply the structural fault of every dirty frame to the loaded
    /// parameters. Frames tile the parameter space deterministically, and
    /// each class applies a fixed transform — the corruption is identical
    /// every window the frame stays dirty, and vanishes once scrubbed
    /// (the store itself is never touched; CRAM corrupts the datapath).
    pub fn corrupt(&self, params: &mut [f32]) {
        if params.is_empty() || self.dirty.is_empty() {
            return;
        }
        let total = self.frames.total();
        let n = params.len() as u64;
        for (&frame, _) in &self.dirty {
            let lo = (frame * n / total) as usize;
            let hi = (((frame + 1) * n / total) as usize).clamp(lo + 1, params.len());
            let class = self.frames.class_of(frame);
            for w in &mut params[lo..hi] {
                *w = match class {
                    // struck multiplier: sign-inverted products
                    FrameClass::Arith => -*w,
                    // stuck routing line: one mantissa bit forced
                    FrameClass::Logic => f32::from_bits(w.to_bits() ^ (1 << 22)),
                    // ROM output port stuck at zero
                    FrameClass::Rom => 0.0,
                    // control mux stuck: magnitudes only
                    FrameClass::Control => w.abs(),
                };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};

    fn mlp() -> NetConfig {
        NetConfig::new(Arch::Mlp, EnvKind::Simple)
    }

    fn frames() -> FrameMap {
        FrameMap::of(&mlp(), Precision::Fixed)
    }

    #[test]
    fn frame_map_is_deterministic_and_nonempty() {
        for prec in Precision::all() {
            for cfg in NetConfig::all() {
                let a = FrameMap::of(&cfg, prec);
                assert_eq!(a, FrameMap::of(&cfg, prec));
                assert!(a.total() > 0, "{}/{prec:?}", cfg.name());
                assert!(a.logic >= 1 && a.control >= 1, "{}/{prec:?}", cfg.name());
                // every frame index classifies without panicking, classes
                // appear in map order
                let mut last = FrameClass::Logic;
                for f in 0..a.total() {
                    let c = a.class_of(f);
                    if c != last {
                        last = c;
                    }
                }
                assert_eq!(a.class_of(a.total() - 1), FrameClass::Control);
            }
        }
    }

    #[test]
    fn fixed_mlp_has_arith_and_rom_frames() {
        let f = frames();
        assert!(f.arith > 0, "DSP multipliers must map to arith frames");
        assert!(f.rom > 0, "sigmoid ROMs must map to ROM frames");
        assert_eq!(f.total_bits(), f.total() * CRAM_FRAME_BITS);
    }

    #[test]
    fn same_seed_same_log() {
        let plan = CramPlan { rate: 2e-5, scrub: Some(16) };
        let mut a = CramState::new(99, plan, frames(), None);
        let mut b = CramState::new(99, plan, frames(), None);
        for _ in 0..200 {
            a.advance(1);
            b.advance(1);
        }
        assert!(!a.log().is_empty(), "rate 2e-5 over {} bits must strike", frames().total_bits());
        assert_eq!(a.log(), b.log());
        assert_eq!(a.stats(), b.stats());
        // a different seed produces a different history
        let mut c = CramState::new(100, plan, frames(), None);
        for _ in 0..200 {
            c.advance(1);
        }
        assert_ne!(a.log(), c.log());
    }

    #[test]
    fn window_chunking_does_not_change_the_strike_count_law() {
        // the strike count per window depends only on the λ integral, so a
        // constant-rate process sees the same expected totals; the exact
        // event log legitimately differs with chunking (fewer, larger
        // windows), but each chunking is individually reproducible
        let plan = CramPlan { rate: 1e-5, scrub: None };
        let mut a = CramState::new(7, plan, frames(), None);
        let mut b = CramState::new(7, plan, frames(), None);
        for _ in 0..50 {
            a.advance(4);
        }
        for _ in 0..50 {
            b.advance(4);
        }
        assert_eq!(a.log(), b.log());
    }

    #[test]
    fn continuous_scrub_masks_every_upset() {
        let plan = CramPlan { rate: 5e-5, scrub: Some(0) };
        let mut s = CramState::new(11, plan, frames(), None);
        let mut params = vec![0.5f32; 64];
        let clean = params.clone();
        for _ in 0..300 {
            s.advance(1);
            assert_eq!(s.dirty_frames(), 0, "continuous scrub leaves no frame dirty");
            s.corrupt(&mut params);
            assert_eq!(params, clean, "masked upsets never reach the datapath");
        }
        let st = s.stats();
        assert!(st.cram_upsets > 0, "the strike process must have fired");
        // repairs are per frame: same-window strikes on one frame collapse
        // into a single repair, never into survival
        assert!(st.cram_repairs > 0 && st.cram_repairs <= st.cram_upsets);
        // all repairs landed within their own window: latency 0
        for e in s.log() {
            if let CramEventKind::Repair { latency } = e.kind {
                assert_eq!(latency, 0);
            }
        }
    }

    #[test]
    fn unscrubbed_corruption_stands_until_repair() {
        let plan = CramPlan { rate: 0.0, scrub: None };
        let mut s = CramState::new(3, plan, frames(), None);
        // stage a strike by hand through the public API: advance with a
        // huge one-off rate via a schedule spike
        let spiked = CramPlan { rate: 0.0, scrub: None };
        let schedule = RateSchedule::Spike { base: 0.0, peak: 1e-3, start: 0, len: 1 };
        let mut struck = CramState::new(3, spiked, frames(), Some(schedule));
        struck.advance(1);
        assert!(struck.dirty_frames() > 0, "spike window must strike");
        let mut params = vec![0.25f32; 128];
        let clean = params.clone();
        struck.corrupt(&mut params);
        assert_ne!(params, clean, "dirty frames corrupt the datapath");
        // the corruption is the same deterministic transform every window
        let mut again = clean.clone();
        struck.corrupt(&mut again);
        assert_eq!(params, again);
        // quiet tail: no more strikes, corruption stands
        for _ in 0..50 {
            assert!(struck.advance(1), "dirty frames keep forcing reloads");
        }
        assert!(struck.dirty_frames() > 0);
        // the zero-rate control never strikes at all
        for _ in 0..50 {
            s.advance(1);
        }
        assert_eq!(s.stats().cram_upsets, 0);
    }

    #[test]
    fn periodic_scrub_repairs_with_the_right_latency() {
        let schedule = RateSchedule::Spike { base: 0.0, peak: 1e-3, start: 0, len: 1 };
        let plan = CramPlan { rate: 0.0, scrub: Some(8) };
        let mut s = CramState::new(3, plan, frames(), Some(schedule));
        s.advance(1); // strikes land at step 1
        let upsets = s.stats().cram_upsets;
        let struck_frames = s.dirty_frames() as u64;
        assert!(upsets > 0 && struck_frames > 0);
        for _ in 0..7 {
            s.advance(1); // pass comes due at step 8
        }
        assert_eq!(s.dirty_frames(), 0, "the step-8 pass repairs everything");
        // one repair per distinct struck frame (strikes may share a frame)
        assert_eq!(s.stats().cram_repairs, struck_frames);
        let latencies: Vec<u64> = s
            .log()
            .iter()
            .filter_map(|e| match e.kind {
                CramEventKind::Repair { latency } => Some(latency),
                _ => None,
            })
            .collect();
        assert!(!latencies.is_empty());
        assert!(latencies.iter().all(|&l| l == 7), "struck at 1, repaired at 8: {latencies:?}");
        // post-repair the datapath reloads clean
        let mut params = vec![1.0f32; 32];
        let clean = params.clone();
        s.corrupt(&mut params);
        assert_eq!(params, clean);
    }

    #[test]
    fn corruption_transforms_are_class_shaped() {
        let f = FrameMap { logic: 1, arith: 1, rom: 1, control: 1 };
        let plan = CramPlan { rate: 0.0, scrub: None };
        let schedule = RateSchedule::Spike { base: 0.0, peak: 0.5, start: 0, len: 1 };
        let mut s = CramState::new(5, plan, f, Some(schedule));
        s.advance(1);
        assert!(s.dirty_frames() > 0);
        let mut params = vec![-0.75f32; 4];
        s.corrupt(&mut params);
        // at least one quarter of the param space took a class transform
        assert_ne!(params, vec![-0.75f32; 4]);
        for w in &params {
            assert!(w.is_finite(), "corruption must never produce NaN/inf");
        }
    }

    #[test]
    fn plan_labels_and_json_round_trip() {
        for plan in [
            CramPlan { rate: 3e-3, scrub: None },
            CramPlan { rate: 3e-3, scrub: Some(0) },
            CramPlan { rate: 1e-4, scrub: Some(64) },
        ] {
            let back = CramPlan::from_json(&plan.to_json()).unwrap();
            assert_eq!(back, plan, "{}", plan.label());
        }
        assert_eq!(CramPlan { rate: 3e-3, scrub: Some(64) }.label(), "3e-3@scrub:64");
        assert_eq!(CramPlan { rate: 3e-3, scrub: None }.label(), "3e-3@unscrubbed");
        let bad = Json::obj(vec![("rate", Json::Num(-1.0)), ("scrub", Json::Null)]);
        assert!(CramPlan::from_json(&bad).is_err());
    }
}
