//! Bit-level injection primitives and the storage-word view of parameters.

use crate::config::{NetConfig, Precision};
use crate::error::{Error, Result};
use crate::fixed::{Fixed, FixedSpec};
use crate::nn::params::QNetParams;

/// Flip one bit of an IEEE-754 single (bit 0 = LSB of the mantissa,
/// bit 31 = sign). Any resulting pattern — subnormal, ±∞, NaN — is kept:
/// that is exactly what an upset in a float register produces.
#[inline]
pub fn flip_f32_bit(x: f32, bit: u32) -> f32 {
    debug_assert!(bit < 32);
    f32::from_bits(x.to_bits() ^ (1u32 << bit))
}

/// Flip one bit of a fixed-point raw word of `spec.word` bits
/// (two's complement, sign-extended back into the i64 carrier).
#[inline]
pub fn flip_fixed_raw(raw: i64, bit: u32, spec: FixedSpec) -> i64 {
    Fixed::from_raw(raw, spec).flip_bit(bit).raw()
}

/// Flatten parameters into one scalar stream in artifact tensor order.
pub fn flatten_params(p: &QNetParams) -> Vec<f32> {
    let mut out = Vec::with_capacity(p.n_scalars());
    for t in p.to_tensors() {
        out.extend_from_slice(&t);
    }
    out
}

/// Rebuild parameters from a flat scalar stream (inverse of
/// [`flatten_params`] for a matching configuration).
pub fn unflatten_params(cfg: &NetConfig, flat: &[f32]) -> Result<QNetParams> {
    let shapes: Vec<usize> = QNetParams::zeros(cfg)
        .to_tensors()
        .iter()
        .map(|t| t.len())
        .collect();
    let total: usize = shapes.iter().sum();
    if flat.len() != total {
        return Err(Error::interface(format!(
            "flat params length {} != expected {total}",
            flat.len()
        )));
    }
    let mut tensors = Vec::with_capacity(shapes.len());
    let mut i = 0usize;
    for n in shapes {
        tensors.push(flat[i..i + n].to_vec());
        i += n;
    }
    QNetParams::from_tensors(cfg, &tensors)
}

/// Views network weights as the raw storage words the radiation model
/// flips: Q(word, frac) integer words in fixed mode (the BRAM/FF weight
/// store of the paper's datapath), IEEE-754 bit patterns in float mode,
/// Q(8,4) words for the int8 kernel arm (the spec is pinned — the arm has
/// exactly one grid) and single sign bits for the binary arm (a strike on
/// a ±1 weight can only flip its sign).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WordCodec {
    prec: Precision,
    spec: FixedSpec,
}

impl WordCodec {
    pub fn new(prec: Precision, spec: FixedSpec) -> WordCodec {
        let spec = if prec == Precision::Int8 { FixedSpec::int8() } else { spec };
        WordCodec { prec, spec }
    }

    /// Susceptible bits per stored word.
    pub fn bits_per_word(&self) -> u32 {
        match self.prec {
            Precision::Fixed | Precision::Int8 => self.spec.word,
            Precision::Float => 32,
            Precision::Binary => 1,
        }
    }

    pub fn spec(&self) -> FixedSpec {
        self.spec
    }

    /// Scalar → storage word (low `bits_per_word()` bits of the u64).
    pub fn encode(&self, x: f32) -> u64 {
        match self.prec {
            Precision::Fixed | Precision::Int8 => {
                let mask = (1u64 << self.spec.word) - 1;
                (Fixed::from_f32(x, self.spec).raw() as u64) & mask
            }
            Precision::Float => x.to_bits() as u64,
            // sign bit: 1 = negative, matching the kernel's sign grid
            // (sign(0) = +1 → encodes 0)
            Precision::Binary => (x < 0.0) as u64,
        }
    }

    /// Storage word → scalar.
    pub fn decode(&self, w: u64) -> f32 {
        match self.prec {
            Precision::Fixed | Precision::Int8 => {
                let mask = (1u64 << self.spec.word) - 1;
                let sign = 1u64 << (self.spec.word - 1);
                let w = w & mask;
                let raw = if w & sign != 0 { (w | !mask) as i64 } else { w as i64 };
                Fixed::from_raw(raw, self.spec).to_f32()
            }
            Precision::Float => f32::from_bits(w as u32),
            Precision::Binary => {
                if w & 1 == 1 {
                    -1.0
                } else {
                    1.0
                }
            }
        }
    }

    pub fn encode_all(&self, xs: &[f32]) -> Vec<u64> {
        xs.iter().map(|&x| self.encode(x)).collect()
    }

    pub fn decode_all(&self, ws: &[u64]) -> Vec<f32> {
        ws.iter().map(|&w| self.decode(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};
    use crate::util::Rng;

    #[test]
    fn f32_flip_is_involutive() {
        for bit in 0..32 {
            let x = 1.375f32;
            let y = flip_f32_bit(x, bit);
            assert_ne!(x.to_bits(), y.to_bits());
            assert_eq!(flip_f32_bit(y, bit).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn fixed_raw_flip_matches_value_flip() {
        let spec = FixedSpec::default();
        let v = Fixed::from_f64(-1.625, spec);
        for bit in 0..spec.word {
            assert_eq!(flip_fixed_raw(v.raw(), bit, spec), v.flip_bit(bit).raw());
        }
    }

    #[test]
    fn params_flatten_roundtrip() {
        let mut rng = Rng::seeded(3);
        for cfg in NetConfig::all() {
            let p = QNetParams::init(&cfg, 0.4, &mut rng);
            let flat = flatten_params(&p);
            assert_eq!(flat.len(), cfg.n_params());
            let back = unflatten_params(&cfg, &flat).unwrap();
            assert_eq!(p, back);
            assert!(unflatten_params(&cfg, &flat[1..]).is_err());
        }
    }

    #[test]
    fn codec_roundtrips_on_grid_values() {
        let mut rng = Rng::seeded(4);
        for (w, f) in [(8u32, 4u32), (12, 8), (16, 8), (18, 12), (24, 16), (32, 24)] {
            let spec = FixedSpec::new(w, f);
            let codec = WordCodec::new(Precision::Fixed, spec);
            assert_eq!(codec.bits_per_word(), w);
            for _ in 0..200 {
                let x = Fixed::from_f32(rng.f32_range(-4.0, 4.0), spec).to_f32();
                assert_eq!(codec.decode(codec.encode(x)), x, "Q({w},{f}) {x}");
            }
        }
        let fc = WordCodec::new(Precision::Float, FixedSpec::default());
        assert_eq!(fc.bits_per_word(), 32);
        for _ in 0..200 {
            let x = rng.f32_range(-100.0, 100.0);
            assert_eq!(fc.decode(fc.encode(x)).to_bits(), x.to_bits());
        }
    }

    /// The kernel-arm codecs: Int8 pins Q(8,4) no matter what spec the
    /// caller supplies; Binary words are a single sign bit whose flip is
    /// exactly a sign flip.
    #[test]
    fn kernel_arm_codecs() {
        let i8c = WordCodec::new(Precision::Int8, FixedSpec::default());
        assert_eq!(i8c.bits_per_word(), 8);
        assert_eq!(i8c.spec(), FixedSpec::int8());
        let mut rng = Rng::seeded(14);
        for _ in 0..200 {
            let x = Fixed::from_f32(rng.f32_range(-4.0, 4.0), FixedSpec::int8()).to_f32();
            assert_eq!(i8c.decode(i8c.encode(x)), x, "{x}");
        }
        let bc = WordCodec::new(Precision::Binary, FixedSpec::default());
        assert_eq!(bc.bits_per_word(), 1);
        assert_eq!(bc.encode(1.0), 0);
        assert_eq!(bc.encode(-1.0), 1);
        assert_eq!(bc.encode(0.0), 0); // sign(0) = +1, like the kernel grid
        assert_eq!(bc.decode(0), 1.0);
        assert_eq!(bc.decode(1), -1.0);
        // a single-bit upset flips the sign and nothing else
        assert_eq!(bc.decode(bc.encode(1.0) ^ 1), -1.0);
        assert_eq!(bc.decode(bc.encode(-1.0) ^ 1), 1.0);
    }

    #[test]
    fn codec_negative_words_sign_extend() {
        let spec = FixedSpec::default();
        let codec = WordCodec::new(Precision::Fixed, spec);
        let x = -3.0f32;
        let w = codec.encode(x);
        assert!(w < (1u64 << spec.word)); // stays within the word
        assert_eq!(codec.decode(w), x);
    }

    #[test]
    fn arch_mix_guard() {
        let mlp = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let per = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let p = QNetParams::zeros(&per);
        assert!(unflatten_params(&mlp, &flatten_params(&p)).is_err());
    }
}
