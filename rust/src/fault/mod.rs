//! Radiation effects: single-event-upset (SEU) injection and mitigation.
//!
//! The paper's cost case is radiation — MSL flies space-grade parts because
//! upsets corrupt configuration and datapath state — yet the accelerator
//! model alone says nothing about what a bit flip *costs in learning*. This
//! subsystem closes that loop:
//!
//! * [`env`] — mission radiation environments ([`RadEnvironment`]: cruise,
//!   Mars surface, Jupiter flyby) expressed as upsets per bit per kilostep.
//! * [`model`] — [`FaultModel`]: a seeded, deterministic upset sampler
//!   (Poisson arrivals over the protected bit population) plus
//!   [`FaultStats`] accounting and the [`SeuHook`] that strikes the FPGA
//!   datapath FIFOs ([`crate::fpga::fifo`]) mid-update.
//! * [`schedule`] — [`RateSchedule`]: time-varying upset-rate profiles
//!   (constant / solar-event spikes / per-mission-phase piecewise rates)
//!   driving both the data and CRAM strike processes through one exact
//!   piecewise λ integral.
//! * [`inject`] — bit-level flip primitives for fixed-point words
//!   ([`crate::fixed::Fixed::flip_bit`]), IEEE f32 words, and the
//!   [`inject::WordCodec`] that views network weights as raw storage words.
//! * [`mitigation`] — [`Mitigation`] strategies (`None`, `Tmr`,
//!   `Scrub { interval }`, `Ecc` SECDED) as a [`mitigation::ProtectedStore`]
//!   state machine, with area/power/timing overheads charged through the
//!   [`crate::fpga::area`], [`crate::fpga::power`] and
//!   [`crate::fpga::timing`] hooks.
//! * [`cram`] — configuration-memory upsets ([`CramState`]): seeded strikes
//!   on the modeled frame map of the synthesized design that corrupt the
//!   datapath *structure* until a partial-reconfiguration scrub pass
//!   repairs the frame (detection latency, repair cycles and scrubber
//!   area/power charged through the same [`crate::fpga`] hooks).
//! * [`backend`] — [`FaultyBackend`]: wraps any [`crate::qlearn::QBackend`]
//!   so missions train *under injection*; weight storage goes through the
//!   protected store, transition encodings (replay/input registers) take
//!   transient upsets, and CRAM strikes warp the loaded datapath.
//! * [`campaign`] — resilience campaigns: rate × mitigation × backend
//!   across the fleet scheduler, reported as learning-delta degradation vs
//!   hardening overhead.
//!
//! Everything is seeded: the same seed, rate schedule and mitigation
//! reproduce the same injected bits, weights, strike/repair logs and
//! campaign report (see `tests/fault_determinism.rs`).

pub mod backend;
pub mod campaign;
pub mod cram;
pub mod env;
pub mod inject;
pub mod mitigation;
pub mod model;
pub mod schedule;

pub use backend::FaultyBackend;
pub use campaign::{run_campaign, CampaignSpec, ResilienceCell, ResilienceReport};
pub use cram::{CramEvent, CramEventKind, CramPlan, CramState, FrameClass, FrameMap};
pub use env::RadEnvironment;
pub use inject::{flip_f32_bit, WordCodec};
pub use mitigation::{Mitigation, ProtectedStore, Secded};
pub use model::{FaultModel, FaultStats, SeuHook};
pub use schedule::RateSchedule;

/// Per-mission injection plan carried by
/// [`crate::coordinator::MissionConfig`].
///
/// `schedule` and `cram` are optional extensions: a plain
/// `FaultPlan::constant(rate, mitigation)` keeps the historical
/// constant-rate data-upset behaviour (and the historical JSON wire form)
/// exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Upsets per bit per environment step (the base rate; when a
    /// `schedule` is set it should equal the schedule's rate at step 0).
    pub rate: f64,
    /// Hardening strategy for the weight store (and, for TMR/ECC, the
    /// datapath registers).
    pub mitigation: Mitigation,
    /// Time-varying rate profile; `None` keeps the constant `rate`.
    pub schedule: Option<RateSchedule>,
    /// Configuration-memory strike plan; `None` strikes data only.
    pub cram: Option<CramPlan>,
}

impl FaultPlan {
    /// The historical constant-rate data-upset plan.
    pub fn constant(rate: f64, mitigation: Mitigation) -> FaultPlan {
        FaultPlan { rate, mitigation, schedule: None, cram: None }
    }

    /// Attach a time-varying rate profile (also syncs the base `rate`).
    pub fn with_schedule(mut self, schedule: RateSchedule) -> FaultPlan {
        self.rate = schedule.base_rate();
        self.schedule = Some(schedule);
        self
    }

    /// Attach a CRAM strike plan.
    pub fn with_cram(mut self, cram: CramPlan) -> FaultPlan {
        self.cram = Some(cram);
        self
    }

    /// The CRAM-scaled rate profile: the mission's time profile rescaled so
    /// its base matches the CRAM strike rate (solar events modulate the
    /// configuration plane and the datapath identically). A zero-base
    /// profile (pure event) is applied as-is.
    pub fn cram_schedule(&self) -> Option<RateSchedule> {
        let cram = self.cram.as_ref()?;
        let s = self.schedule.as_ref()?;
        let base = s.base_rate();
        Some(if base > 0.0 { s.scaled(cram.rate / base) } else { s.clone() })
    }
}
