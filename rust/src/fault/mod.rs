//! Radiation effects: single-event-upset (SEU) injection and mitigation.
//!
//! The paper's cost case is radiation — MSL flies space-grade parts because
//! upsets corrupt configuration and datapath state — yet the accelerator
//! model alone says nothing about what a bit flip *costs in learning*. This
//! subsystem closes that loop:
//!
//! * [`env`] — mission radiation environments ([`RadEnvironment`]: cruise,
//!   Mars surface, Jupiter flyby) expressed as upsets per bit per kilostep.
//! * [`model`] — [`FaultModel`]: a seeded, deterministic upset sampler
//!   (Poisson arrivals over the protected bit population) plus
//!   [`FaultStats`] accounting and the [`SeuHook`] that strikes the FPGA
//!   datapath FIFOs ([`crate::fpga::fifo`]) mid-update.
//! * [`inject`] — bit-level flip primitives for fixed-point words
//!   ([`crate::fixed::Fixed::flip_bit`]), IEEE f32 words, and the
//!   [`inject::WordCodec`] that views network weights as raw storage words.
//! * [`mitigation`] — [`Mitigation`] strategies (`None`, `Tmr`,
//!   `Scrub { interval }`, `Ecc` SECDED) as a [`mitigation::ProtectedStore`]
//!   state machine, with area/power/timing overheads charged through the
//!   [`crate::fpga::area`], [`crate::fpga::power`] and
//!   [`crate::fpga::timing`] hooks.
//! * [`backend`] — [`FaultyBackend`]: wraps any [`crate::qlearn::QBackend`]
//!   so missions train *under injection*; weight storage goes through the
//!   protected store, transition encodings (replay/input registers) take
//!   transient upsets.
//! * [`campaign`] — resilience campaigns: rate × mitigation × backend
//!   across the fleet scheduler, reported as learning-delta degradation vs
//!   hardening overhead.
//!
//! Everything is seeded: the same seed, rate and mitigation reproduce the
//! same injected bits, weights and campaign report (see
//! `tests/fault_determinism.rs`).

pub mod backend;
pub mod campaign;
pub mod env;
pub mod inject;
pub mod mitigation;
pub mod model;

pub use backend::FaultyBackend;
pub use campaign::{run_campaign, CampaignSpec, ResilienceCell, ResilienceReport};
pub use env::RadEnvironment;
pub use inject::{flip_f32_bit, WordCodec};
pub use mitigation::{Mitigation, ProtectedStore, Secded};
pub use model::{FaultModel, FaultStats, SeuHook};

/// Per-mission injection plan carried by
/// [`crate::coordinator::MissionConfig`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Upsets per bit per environment step.
    pub rate: f64,
    /// Hardening strategy for the weight store (and, for TMR/ECC, the
    /// datapath registers).
    pub mitigation: Mitigation,
}
