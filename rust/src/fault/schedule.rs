//! Time-varying upset-rate schedules.
//!
//! A [`RateSchedule`] generalizes the constant per-bit-per-step upset rate
//! of [`crate::fault::FaultPlan`] to mission-shaped time profiles:
//!
//! * **Constant** — the historical behaviour: one rate for the whole run.
//! * **Spike** — a solar-event transient: a quiet base rate with a
//!   `peak`-rate window of `len` steps starting at step `start` (SEP events
//!   raise upset rates by orders of magnitude for hours against a
//!   months-long cruise).
//! * **Phases** — piecewise per-mission-phase rates (`R1` for `N1` steps,
//!   then `R2` for `N2`, …); the final phase's rate holds for the remainder
//!   of the mission.
//!
//! Schedules drive both the data-upset and CRAM strike processes through
//! the same mechanism: [`crate::fault::FaultModel`] keeps a step cursor and
//! asks the schedule for the *expected* number of upsets over each exposure
//! window ([`RateSchedule::expected_upsets`], an exact piecewise integral —
//! never a per-step loop), so seeded replays stay bit-identical at any
//! window chunking the training loop happens to use.
//!
//! The canonical text form (`R` / `spike:R0,Rpeak,start,len` /
//! `phases:R1@N1,R2@N2,...`) is both the CLI spelling
//! (`qfpga radiation --rate-schedule`) and the JSON wire form inside
//! mission configs, so specs round-trip byte-exactly.

use crate::error::{Error, Result};
use crate::util::Json;

/// A time-varying upset-rate profile (upsets per bit per step).
#[derive(Debug, Clone, PartialEq)]
pub enum RateSchedule {
    /// One rate for the whole mission.
    Constant(f64),
    /// Solar-event transient: `base` everywhere except a `[start,
    /// start+len)` window at `peak`.
    Spike { base: f64, peak: f64, start: u64, len: u64 },
    /// Per-mission-phase piecewise rates: `(rate, duration_steps)` pairs,
    /// the last rate holding beyond the final phase boundary.
    Phases(Vec<(f64, u64)>),
}

/// Steps of `[a0, a1)` that fall inside `[b0, b1)`.
fn overlap(a0: u64, a1: u64, b0: u64, b1: u64) -> u64 {
    a1.min(b1).saturating_sub(a0.max(b0))
}

impl RateSchedule {
    /// The instantaneous rate at `step`.
    pub fn rate_at(&self, step: u64) -> f64 {
        match self {
            RateSchedule::Constant(r) => *r,
            RateSchedule::Spike { base, peak, start, len } => {
                if step >= *start && step - start < *len {
                    *peak
                } else {
                    *base
                }
            }
            RateSchedule::Phases(phases) => {
                let mut seg_start = 0u64;
                let mut rate = 0.0;
                for &(r, n) in phases {
                    rate = r;
                    seg_start += n;
                    if step < seg_start {
                        return r;
                    }
                }
                rate // last phase holds for the rest of the mission
            }
        }
    }

    /// Expected upsets **per bit** over the window `[start, start+steps)` —
    /// the exact piecewise integral of the rate profile, so the value is
    /// independent of how a caller chunks a mission into exposure windows
    /// (up to float summation order). `Constant(r)` yields exactly
    /// `r * steps`, preserving the historical constant-rate λ bit-for-bit.
    pub fn expected_upsets(&self, start: u64, steps: u64) -> f64 {
        if steps == 0 {
            return 0.0;
        }
        let end = start + steps;
        match self {
            RateSchedule::Constant(r) => r * steps as f64,
            RateSchedule::Spike { base, peak, start: s0, len } => {
                base * steps as f64
                    + (peak - base) * overlap(start, end, *s0, s0.saturating_add(*len)) as f64
            }
            RateSchedule::Phases(phases) => {
                let mut total = 0.0;
                let mut seg_start = 0u64;
                let mut last_rate = 0.0;
                for &(r, n) in phases {
                    let seg_end = seg_start + n;
                    total += r * overlap(start, end, seg_start, seg_end) as f64;
                    seg_start = seg_end;
                    last_rate = r;
                }
                let tail_start = seg_start.max(start);
                if end > tail_start {
                    total += last_rate * (end - tail_start) as f64;
                }
                total
            }
        }
    }

    /// The largest instantaneous rate the profile reaches — what the CLI
    /// range-checks against the physical `[0, 1]` upsets/bit/step bound.
    pub fn max_rate(&self) -> f64 {
        match self {
            RateSchedule::Constant(r) => *r,
            RateSchedule::Spike { base, peak, .. } => base.max(*peak),
            RateSchedule::Phases(phases) => {
                phases.iter().fold(0.0, |acc: f64, &(r, _)| acc.max(r))
            }
        }
    }

    /// The rate at step 0 — the `FaultPlan::rate` a schedule-bearing plan
    /// reports for labels and legacy consumers.
    pub fn base_rate(&self) -> f64 {
        self.rate_at(0)
    }

    /// The same time profile with every rate multiplied by `factor` — how
    /// one mission profile drives both the data and CRAM strike processes
    /// at their own base rates (CRAM cross-sections are larger than the
    /// datapath's, but solar events modulate both identically).
    pub fn scaled(&self, factor: f64) -> RateSchedule {
        match self {
            RateSchedule::Constant(r) => RateSchedule::Constant(r * factor),
            RateSchedule::Spike { base, peak, start, len } => RateSchedule::Spike {
                base: base * factor,
                peak: peak * factor,
                start: *start,
                len: *len,
            },
            RateSchedule::Phases(phases) => RateSchedule::Phases(
                phases.iter().map(|&(r, n)| (r * factor, n)).collect(),
            ),
        }
    }

    /// Canonical text form — the CLI spelling, the JSON wire form, and the
    /// fingerprint component. Round-trips through [`std::str::FromStr`].
    pub fn label(&self) -> String {
        match self {
            RateSchedule::Constant(r) => format!("{r:e}"),
            RateSchedule::Spike { base, peak, start, len } => {
                format!("spike:{base:e},{peak:e},{start},{len}")
            }
            RateSchedule::Phases(phases) => {
                let parts: Vec<String> =
                    phases.iter().map(|(r, n)| format!("{r:e}@{n}")).collect();
                format!("phases:{}", parts.join(","))
            }
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Str(self.label())
    }

    pub fn from_json(j: &Json) -> Result<RateSchedule> {
        match j {
            Json::Str(s) => s.parse(),
            other => Err(Error::interface(format!(
                "rate schedule must be a string, got `{other}`"
            ))),
        }
    }
}

/// The error every malformed schedule gets: it enumerates the three valid
/// forms, mirroring the env/precision parse-error style.
fn bad(s: &str) -> Error {
    Error::Config(format!(
        "bad rate schedule `{s}`: expected a constant rate `R`, a solar-event \
         spike `spike:R0,Rpeak,start,len`, or mission phases \
         `phases:R1@N1,R2@N2,...` (rates in upsets/bit/step, times in steps)"
    ))
}

fn parse_rate(part: &str, whole: &str) -> Result<f64> {
    match part.parse::<f64>() {
        Ok(r) if r.is_finite() && r >= 0.0 => Ok(r),
        _ => Err(bad(whole)),
    }
}

impl std::str::FromStr for RateSchedule {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        if let Some(rest) = s.strip_prefix("spike:") {
            let parts: Vec<&str> = rest.split(',').collect();
            if parts.len() != 4 {
                return Err(bad(s));
            }
            let base = parse_rate(parts[0], s)?;
            let peak = parse_rate(parts[1], s)?;
            let start: u64 = parts[2].parse().map_err(|_| bad(s))?;
            let len: u64 = parts[3].parse().map_err(|_| bad(s))?;
            if len == 0 {
                return Err(bad(s));
            }
            Ok(RateSchedule::Spike { base, peak, start, len })
        } else if let Some(rest) = s.strip_prefix("phases:") {
            let mut phases = Vec::new();
            for part in rest.split(',') {
                let Some((r, n)) = part.split_once('@') else {
                    return Err(bad(s));
                };
                let rate = parse_rate(r, s)?;
                let steps: u64 = n.parse().map_err(|_| bad(s))?;
                if steps == 0 {
                    return Err(bad(s));
                }
                phases.push((rate, steps));
            }
            if phases.is_empty() {
                return Err(bad(s));
            }
            Ok(RateSchedule::Phases(phases))
        } else {
            Ok(RateSchedule::Constant(parse_rate(s, s)?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_matches_the_historical_lambda_exactly() {
        let s = RateSchedule::Constant(5e-4);
        for (start, steps) in [(0u64, 1u64), (17, 200), (1000, 1)] {
            assert_eq!(s.expected_upsets(start, steps), 5e-4 * steps as f64);
        }
        assert_eq!(s.rate_at(0), 5e-4);
        assert_eq!(s.rate_at(u64::MAX), 5e-4);
    }

    #[test]
    fn spike_rate_profile_and_integral() {
        let s = RateSchedule::Spike { base: 1e-4, peak: 2e-2, start: 10, len: 5 };
        assert_eq!(s.rate_at(9), 1e-4);
        assert_eq!(s.rate_at(10), 2e-2);
        assert_eq!(s.rate_at(14), 2e-2);
        assert_eq!(s.rate_at(15), 1e-4);
        // window fully before, straddling, and fully inside the spike
        assert_eq!(s.expected_upsets(0, 10), 1e-3);
        let straddle = s.expected_upsets(8, 4); // 2 base + 2 peak steps
        assert!((straddle - (2.0 * 1e-4 + 2.0 * 2e-2)).abs() < 1e-15, "{straddle}");
        assert_eq!(s.expected_upsets(11, 2), 2.0 * 2e-2);
    }

    #[test]
    fn phases_hold_the_last_rate() {
        let s = RateSchedule::Phases(vec![(1e-3, 10), (5e-3, 20)]);
        assert_eq!(s.rate_at(0), 1e-3);
        assert_eq!(s.rate_at(9), 1e-3);
        assert_eq!(s.rate_at(10), 5e-3);
        assert_eq!(s.rate_at(29), 5e-3);
        assert_eq!(s.rate_at(1000), 5e-3, "final phase holds");
        let tail = s.expected_upsets(25, 10); // 5 in phase 2 + 5 in the tail
        assert!((tail - 10.0 * 5e-3).abs() < 1e-15, "{tail}");
    }

    #[test]
    fn chunked_integration_matches_one_shot() {
        let schedules = [
            RateSchedule::Constant(3e-4),
            RateSchedule::Spike { base: 1e-4, peak: 3e-2, start: 50, len: 17 },
            RateSchedule::Phases(vec![(1e-3, 33), (2e-4, 10), (7e-3, 5)]),
        ];
        for s in &schedules {
            let total = s.expected_upsets(0, 200);
            for chunk in [1u64, 3, 7, 50] {
                let mut sum = 0.0;
                let mut at = 0;
                while at < 200 {
                    let n = chunk.min(200 - at);
                    sum += s.expected_upsets(at, n);
                    at += n;
                }
                assert!(
                    (sum - total).abs() <= 1e-12 * total.max(1.0),
                    "{}: chunk {chunk}: {sum} vs {total}",
                    s.label()
                );
            }
        }
    }

    #[test]
    fn spike_integrates_like_its_equivalent_constant() {
        // a spike and the constant carrying the same time-averaged rate
        // must expect the same strike count over the full horizon
        let (base, peak, start, len, horizon) = (2e-4, 1e-2, 40u64, 25u64, 200u64);
        let spike = RateSchedule::Spike { base, peak, start, len };
        let equivalent =
            (base * horizon as f64 + (peak - base) * len as f64) / horizon as f64;
        let constant = RateSchedule::Constant(equivalent);
        let a = spike.expected_upsets(0, horizon);
        let b = constant.expected_upsets(0, horizon);
        assert!((a - b).abs() <= 1e-12 * a.max(1.0), "{a} vs {b}");
    }

    #[test]
    fn labels_round_trip() {
        let schedules = [
            RateSchedule::Constant(5e-4),
            RateSchedule::Spike { base: 1e-4, peak: 2e-2, start: 10, len: 5 },
            RateSchedule::Phases(vec![(1e-3, 10), (5e-3, 20)]),
        ];
        for s in &schedules {
            let back: RateSchedule = s.label().parse().unwrap();
            assert_eq!(&back, s, "{}", s.label());
            let json = RateSchedule::from_json(&s.to_json()).unwrap();
            assert_eq!(&json, s);
        }
    }

    #[test]
    fn malformed_schedules_enumerate_the_valid_forms() {
        for s in [
            "spike:1e-4,2e-2,10",  // missing len
            "spike:1e-4,2e-2,x,5", // non-numeric start
            "spike:1e-4,2e-2,0,0", // zero-length spike
            "phases:",             // empty
            "phases:1e-3",         // missing @N
            "phases:1e-3@0",       // zero-length phase
            "phases:-1@5",         // negative rate
            "-2e-4",               // negative constant
            "warp",                // not a number at all
        ] {
            let err = s.parse::<RateSchedule>().unwrap_err().to_string();
            assert!(err.contains("spike:R0,Rpeak,start,len"), "{s}: {err}");
            assert!(err.contains("phases:R1@N1,R2@N2"), "{s}: {err}");
        }
    }
}
