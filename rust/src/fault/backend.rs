//! [`FaultyBackend`]: train any backend under SEU injection.
//!
//! The wrapper routes the inner backend's weights through a
//! [`ProtectedStore`] (the on-board weight memory under a mitigation
//! strategy) and exposes transition encodings to transient upsets (the
//! replay/input registers of the datapath). Per update:
//!
//! 1. sample Poisson arrivals over the susceptible bit population and
//!    advance the scrub timer — a clean step (no strike) ends here;
//! 2. on a strike: replay the hardware write-through (store := inner
//!    weights, re-encoding ECC words / resynchronizing TMR replicas /
//!    refreshing the scrub shadow), apply the upsets, run any due scrub
//!    pass, then mitigated-read and load the result into the inner
//!    backend;
//! 3. the inner backend runs the (possibly corrupted) Q-update.
//!
//! The lazy replay is sound because arrival *counts* depend only on the
//! population, never on store content, and the hardware rewrites every
//! weight each update anyway. Everything draws from one seeded
//! [`FaultModel`] stream, so a mission is bit-reproducible from
//! `(seed, rate, mitigation)`.
//!
//! With a CRAM plan attached ([`FaultyBackend::with_cram`]), a second
//! seeded process ([`CramState`]) strikes the configuration plane each
//! window; dirty frames structurally warp the loaded parameters until a
//! partial-reconfiguration scrub pass repairs them.

use crate::config::{NetConfig, Precision};
use crate::error::Result;
use crate::fixed::FixedSpec;
use crate::nn::params::QNetParams;
use crate::qlearn::backend::QBackend;
use crate::qlearn::replay::FlatBatch;

use super::cram::CramState;
use super::inject::{flatten_params, flip_f32_bit, unflatten_params, WordCodec};
use super::mitigation::{Mitigation, ProtectedStore};
use super::model::{strike_window, FaultModel, FaultStats};

/// A [`QBackend`] whose weight storage and input registers live in a
/// radiation environment.
pub struct FaultyBackend<B: QBackend> {
    inner: B,
    cfg: NetConfig,
    codec: WordCodec,
    store: ProtectedStore,
    model: FaultModel,
    mitigation: Mitigation,
    /// Configuration-memory strike process; `None` strikes data only.
    cram: Option<CramState>,
}

impl<B: QBackend> FaultyBackend<B> {
    pub fn new(inner: B, prec: Precision, mitigation: Mitigation, model: FaultModel) -> Self {
        Self::with_spec(inner, prec, FixedSpec::default(), mitigation, model)
    }

    /// Like [`FaultyBackend::new`] with an explicit fixed-point storage
    /// format (must match the wrapped backend's datapath format so the
    /// store roundtrip stays bit-exact).
    pub fn with_spec(
        inner: B,
        prec: Precision,
        spec: FixedSpec,
        mitigation: Mitigation,
        model: FaultModel,
    ) -> Self {
        let cfg = *inner.net();
        let codec = WordCodec::new(prec, spec);
        let words = codec.encode_all(&flatten_params(&inner.params()));
        let store = ProtectedStore::new(mitigation, codec.bits_per_word(), &words);
        FaultyBackend { inner, cfg, codec, store, model, mitigation, cram: None }
    }

    /// Attach a configuration-memory strike process: CRAM upsets corrupt
    /// the loaded datapath structurally (on top of any data strikes) until
    /// a scrub pass repairs the struck frames.
    pub fn with_cram(mut self, cram: CramState) -> Self {
        self.cram = Some(cram);
        self
    }

    /// The CRAM strike state, when a CRAM plan is attached.
    pub fn cram(&self) -> Option<&CramState> {
        self.cram.as_ref()
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }

    pub fn mitigation(&self) -> Mitigation {
        self.mitigation
    }

    /// Injection + masking accounting so far (data process plus any
    /// attached CRAM process).
    pub fn stats(&self) -> FaultStats {
        let mut s = self.model.stats;
        if let Some(c) = &self.cram {
            s.add(&c.stats());
        }
        s
    }

    /// Transient upsets on a register file of f32 words (transition
    /// encodings / replay entries): one [`strike_window`] per exposure.
    /// TMR and ECC harden these registers too, but are not structurally
    /// immune — vote-breaking and double-strike escapes land per the
    /// shared policy.
    fn corrupt_f32s(&mut self, xs: &mut [f32]) {
        if xs.is_empty() {
            return;
        }
        strike_window(&mut self.model, self.mitigation, xs.len(), 32, |word, bit| {
            xs[word] = flip_f32_bit(xs[word], bit);
        });
    }

    /// Steps 1–2 of the update cycle: inject, scrub, mitigated read, load.
    ///
    /// The hardware rewrites every weight (and its protected
    /// representation) each update, but the arrival count depends only on
    /// the susceptible bit *population* — so the store content is replayed
    /// from the inner backend's weights lazily, only when a strike window
    /// actually needs it. At realistic rates the overwhelming majority of
    /// steps take the early exit and pay no encode/decode work at all.
    fn expose_and_load(&mut self, steps: u64) -> Result<()> {
        let flips = self.model.upsets(self.store.susceptible_bits(), steps);
        let scrub_due = self.store.tick_scrub(steps);
        // the CRAM clock must advance every window regardless of the data
        // outcome — its strike process is independent, and a standing
        // dirty frame forces a (re)corrupted load even on data-clean steps
        let cram_active = match &mut self.cram {
            Some(c) => c.advance(steps),
            None => false,
        };
        if flips == 0 && !cram_active {
            // a due scrub pass on an (effectively) freshly written store
            // restores nothing; the timer was advanced above
            return Ok(());
        }
        self.sync_store();
        if flips > 0 {
            self.store.apply_upsets(&mut self.model, flips);
            if scrub_due {
                crate::obs::metrics().fault_scrub_bursts.inc();
                self.store.scrub_now(&mut self.model);
            }
        }
        let words = self.store.read(&mut self.model.stats);
        let mut flat = self.codec.decode_all(&words);
        // CRAM corruption warps the *datapath*, not the store: dirty
        // frames re-apply their structural transform to whatever the
        // hardware loads this window, and vanish once scrubbed
        if let Some(c) = &self.cram {
            c.corrupt(&mut flat);
        }
        let params = unflatten_params(&self.cfg, &flat)?;
        self.inner.load_params(&params);
        Ok(())
    }

    /// Replay the write-through: store (and golden/replicas/codewords)
    /// := the inner backend's current weights.
    fn sync_store(&mut self) {
        let words = self.codec.encode_all(&flatten_params(&self.inner.params()));
        self.store.write(&words);
    }
}

impl<B: QBackend> QBackend for FaultyBackend<B> {
    fn net(&self) -> &NetConfig {
        &self.cfg
    }

    fn name(&self) -> String {
        format!(
            "seu[{}@{:.1e}]/{}",
            self.mitigation.label(),
            self.model.rate(),
            self.inner.name()
        )
    }

    fn q_values(&mut self, sa: &[f32]) -> Result<Vec<f32>> {
        // action selection reads the weights as last exposed/written; the
        // next update's injection covers the elapsed step
        self.inner.q_values(sa)
    }

    fn q_values_into(&mut self, sa: &[f32], out: &mut Vec<f32>) -> Result<()> {
        // same exposure model as `q_values`; keeps the inner backend's
        // allocation-free action-selection path reachable under injection
        self.inner.q_values_into(sa, out)
    }

    fn update(
        &mut self,
        sa_cur: &[f32],
        sa_next: &[f32],
        action: usize,
        reward: f32,
    ) -> Result<f32> {
        let mut cur = sa_cur.to_vec();
        let mut next = sa_next.to_vec();
        let mut rw = [reward];
        self.corrupt_f32s(&mut cur);
        self.corrupt_f32s(&mut next);
        self.corrupt_f32s(&mut rw);
        self.expose_and_load(1)?;
        self.inner.update(&cur, &next, action, rw[0])
    }

    fn update_batch(&mut self, batch: &FlatBatch) -> Result<Vec<f32>> {
        batch.validate(&self.cfg)?;
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        // replay-buffer entries sat in memory for the whole flush window
        let mut b = batch.clone();
        self.corrupt_f32s(&mut b.sa_cur);
        self.corrupt_f32s(&mut b.sa_next);
        self.corrupt_f32s(&mut b.rewards);
        self.expose_and_load(batch.len() as u64)?;
        self.inner.update_batch(&b)
    }

    fn preferred_batch(&self) -> usize {
        self.inner.preferred_batch()
    }

    fn params(&self) -> QNetParams {
        self.inner.params()
    }

    fn load_params(&mut self, params: &QNetParams) {
        // the store is replayed from the inner weights at strike time, so
        // no eager resynchronization is needed here
        self.inner.load_params(params);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};
    use crate::coordinator::sweep::Workload;
    use crate::experiment::{AnyBackend, BackendFactory, BackendSpec};
    use crate::util::Rng;

    fn cpu(net: NetConfig, prec: Precision, seed: u64) -> AnyBackend {
        let mut rng = Rng::seeded(seed);
        let params = QNetParams::init(&net, 0.3, &mut rng);
        BackendFactory::offline()
            .build(&BackendSpec::cpu(net, prec), params)
            .unwrap()
    }

    fn drive<B: QBackend>(backend: &mut B, net: &NetConfig, n: usize) -> Vec<f32> {
        let w = Workload::synthetic(*net, n, 77);
        let step = net.a * net.d;
        (0..n)
            .map(|i| {
                backend
                    .update(
                        &w.sa_cur[i * step..(i + 1) * step],
                        &w.sa_next[i * step..(i + 1) * step],
                        w.actions[i],
                        w.rewards[i],
                    )
                    .unwrap()
            })
            .collect()
    }

    #[test]
    fn zero_rate_none_is_transparent_for_float() {
        // float precision: the storage roundtrip is bit-exact, so a
        // zero-rate unmitigated wrapper must reproduce the bare backend
        let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let mut bare = cpu(net, Precision::Float, 5);
        let mut wrapped = FaultyBackend::new(
            cpu(net, Precision::Float, 5),
            Precision::Float,
            Mitigation::None,
            FaultModel::new(1, 0.0),
        );
        let a = drive(&mut bare, &net, 30);
        let b = drive(&mut wrapped, &net, 30);
        assert_eq!(a, b);
        assert_eq!(bare.params(), wrapped.params());
        assert_eq!(wrapped.stats(), FaultStats::default());
    }

    #[test]
    fn unmitigated_injection_corrupts_weights() {
        let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let mut clean = FaultyBackend::new(
            cpu(net, Precision::Fixed, 5),
            Precision::Fixed,
            Mitigation::None,
            FaultModel::new(11, 0.0),
        );
        let mut hot = FaultyBackend::new(
            cpu(net, Precision::Fixed, 5),
            Precision::Fixed,
            Mitigation::None,
            FaultModel::new(11, 2e-3), // λ ≈ 1.2 store flips/step
        );
        drive(&mut clean, &net, 60);
        drive(&mut hot, &net, 60);
        assert!(hot.stats().injected > 0);
        assert!(hot.stats().transient > 0);
        assert!(clean.params().max_abs_diff(&hot.params()) > 0.0);
    }

    #[test]
    fn same_seed_is_bit_identical_all_mitigations() {
        let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        for prec in [Precision::Fixed, Precision::Float] {
            for m in Mitigation::all() {
                let mut run = || {
                    let mut b = FaultyBackend::new(
                        cpu(net, prec, 5),
                        prec,
                        m,
                        FaultModel::new(21, 1e-3),
                    );
                    let errs = drive(&mut b, &net, 40);
                    (errs, b.params(), b.stats())
                };
                let (e1, p1, s1) = run();
                let (e2, p2, s2) = run();
                assert_eq!(e1, e2, "{prec:?}/{}", m.label());
                assert_eq!(p1, p2, "{prec:?}/{}", m.label());
                assert_eq!(s1, s2, "{prec:?}/{}", m.label());
            }
        }
    }

    #[test]
    fn batch_path_injects_and_stays_deterministic() {
        let net = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
        let w = Workload::synthetic(net, 32, 9);
        let mut run = || {
            let mut b = FaultyBackend::new(
                cpu(net, Precision::Fixed, 5),
                Precision::Fixed,
                Mitigation::Tmr,
                FaultModel::new(31, 5e-3),
            );
            let errs = b.update_batch(&w.flat_batch(0, 32)).unwrap();
            (errs, b.params(), b.stats())
        };
        let (e1, p1, s1) = run();
        let (e2, p2, s2) = run();
        assert_eq!(e1, e2);
        assert_eq!(p1, p2);
        assert_eq!(s1, s2);
        assert!(s1.total_upsets() > 0);
        assert!(s1.masked > 0);
        // empty batch is a no-op
        let mut b = FaultyBackend::new(
            cpu(net, Precision::Fixed, 5),
            Precision::Fixed,
            Mitigation::Tmr,
            FaultModel::new(31, 5e-3),
        );
        assert!(b.update_batch(&FlatBatch::empty()).unwrap().is_empty());
        assert_eq!(b.stats(), FaultStats::default());
    }

    #[test]
    fn cram_strikes_warp_training_and_scrubbing_contains_them() {
        use crate::fault::cram::{CramPlan, CramState, FrameMap};
        let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let frames = FrameMap::of(&net, Precision::Fixed);
        let build = |scrub: Option<u32>| {
            let plan = CramPlan { rate: 2e-4, scrub };
            FaultyBackend::new(
                cpu(net, Precision::Fixed, 5),
                Precision::Fixed,
                Mitigation::None,
                FaultModel::new(51, 0.0), // data plane quiet: isolate CRAM
            )
            .with_cram(CramState::new(51, plan, frames, None))
        };
        let mut clean = FaultyBackend::new(
            cpu(net, Precision::Fixed, 5),
            Precision::Fixed,
            Mitigation::None,
            FaultModel::new(51, 0.0),
        );
        let mut unscrubbed = build(None);
        let mut scrubbed = build(Some(0));
        drive(&mut clean, &net, 80);
        drive(&mut unscrubbed, &net, 80);
        drive(&mut scrubbed, &net, 80);
        let s = unscrubbed.stats();
        assert!(s.cram_upsets > 0, "the CRAM process must strike");
        assert_eq!(s.cram_repairs, 0, "no scrubber, no repairs");
        let sc = scrubbed.stats();
        // repairs count distinct struck frames per window, so they can
        // trail the strike count — but never reach zero while strikes land
        assert!(
            sc.cram_repairs > 0 && sc.cram_repairs <= sc.cram_upsets,
            "continuous scrub repairs every struck frame"
        );
        // same arrival stream: standing CRAM corruption drags training off
        // the fault-free trajectory where continuous scrub stays on it
        let un_drift = clean.params().max_abs_diff(&unscrubbed.params());
        let sc_drift = clean.params().max_abs_diff(&scrubbed.params());
        assert!(un_drift > 0.0, "dirty frames must perturb the weights");
        assert!(sc_drift < un_drift, "scrubbed drift {sc_drift} >= unscrubbed {un_drift}");
        // and both arms replay bit-identically from their seed
        let mut replay = build(None);
        drive(&mut replay, &net, 80);
        assert_eq!(replay.params(), unscrubbed.params());
        assert_eq!(replay.stats(), unscrubbed.stats());
    }

    #[test]
    fn tmr_tracks_the_fault_free_trajectory_where_none_diverges() {
        // same arrival stream, same transitions: TMR masks the store
        // strikes and votes out the register strikes, so its weights stay
        // near the fault-free run while the unmitigated copy drifts
        let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let rate = 1e-3;
        let mut clean = FaultyBackend::new(
            cpu(net, Precision::Fixed, 5),
            Precision::Fixed,
            Mitigation::None,
            FaultModel::new(41, 0.0),
        );
        let mut tmr = FaultyBackend::new(
            cpu(net, Precision::Fixed, 5),
            Precision::Fixed,
            Mitigation::Tmr,
            FaultModel::new(41, rate),
        );
        let mut none = FaultyBackend::new(
            cpu(net, Precision::Fixed, 5),
            Precision::Fixed,
            Mitigation::None,
            FaultModel::new(41, rate),
        );
        drive(&mut clean, &net, 80);
        drive(&mut tmr, &net, 80);
        drive(&mut none, &net, 80);
        assert!(tmr.stats().masked > 0, "TMR saw no work");
        let tmr_drift = clean.params().max_abs_diff(&tmr.params());
        let none_drift = clean.params().max_abs_diff(&none.params());
        assert!(
            none_drift > tmr_drift,
            "unmitigated drift {none_drift} <= TMR drift {tmr_drift}"
        );
    }
}
