//! Seeded SEU sampler + accounting, and the datapath FIFO strike hook.

use crate::error::Result;
use crate::fixed::{Fixed, FixedSpec};
use crate::fpga::fifo::Fifo;
use crate::util::Rng;

use super::mitigation::Mitigation;
use super::schedule::RateSchedule;

/// Lifetime fault accounting (per backend / summed per campaign cell).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Upsets injected into persistent state (the weight store, or — for
    /// the CRAM process — a configuration frame; those are additionally
    /// broken out in `cram_upsets`).
    pub injected: u64,
    /// Transient upsets (replay/input registers, datapath FIFO words).
    pub transient: u64,
    /// Bit flips masked by TMR majority voting.
    pub masked: u64,
    /// Words corrected by SECDED decode.
    pub corrected: u64,
    /// Words with uncorrectable (multi-bit) ECC errors.
    pub uncorrectable: u64,
    /// Corrupted bits restored by a scrub pass.
    pub scrubbed: u64,
    /// Configuration-memory strikes (subset of `injected`).
    pub cram_upsets: u64,
    /// CRAM frames rewritten by partial-reconfiguration scrub passes.
    pub cram_repairs: u64,
}

impl FaultStats {
    pub fn add(&mut self, other: &FaultStats) {
        self.injected += other.injected;
        self.transient += other.transient;
        self.masked += other.masked;
        self.corrected += other.corrected;
        self.uncorrectable += other.uncorrectable;
        self.scrubbed += other.scrubbed;
        self.cram_upsets += other.cram_upsets;
        self.cram_repairs += other.cram_repairs;
    }

    /// Total upsets that struck anything.
    pub fn total_upsets(&self) -> u64 {
        self.injected + self.transient
    }
}

/// Deterministic SEU arrival process: one seeded stream drives Poisson
/// arrival counts and uniform site selection, so an entire campaign replays
/// bit-identically from its seed.
#[derive(Debug, Clone)]
pub struct FaultModel {
    rng: Rng,
    /// Upsets per bit per step (the constant rate when `schedule` is
    /// `None`, otherwise the schedule's base rate, kept for labels).
    rate: f64,
    /// Time-varying rate profile; `None` keeps the exact historical
    /// constant-λ arithmetic.
    schedule: Option<RateSchedule>,
    /// Mission step the process has been advanced to (the schedule clock).
    cursor: u64,
    pub stats: FaultStats,
}

impl FaultModel {
    /// `rate` is upsets per bit per step; any seed is valid.
    pub fn new(seed: u64, rate: f64) -> FaultModel {
        FaultModel {
            rng: Rng::seeded(seed),
            rate: rate.max(0.0),
            schedule: None,
            cursor: 0,
            stats: FaultStats::default(),
        }
    }

    /// A model whose λ follows `schedule` over mission steps; `None` is
    /// exactly [`FaultModel::new`].
    pub fn with_schedule(seed: u64, rate: f64, schedule: Option<RateSchedule>) -> FaultModel {
        let mut m = FaultModel::new(seed, rate);
        m.schedule = schedule;
        m
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Poisson(λ) arrival count via Knuth's product-of-uniforms method —
    /// exact for the small λ this model produces, deterministic from the
    /// seed. Above λ ≈ 700, `exp(−λ)` underflows f64 and Knuth's loop
    /// would silently plateau, so large λ (pathological rates) returns the
    /// rounded mean instead.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 700.0 {
            return lambda.round() as u64;
        }
        let limit = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0f64;
        loop {
            p *= self.rng.f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }

    /// Upset count for `n_bits` susceptible bits over `steps` steps,
    /// capped at the bit population per window — beyond one flip per bit
    /// the memory is fully randomized and extra draws model nothing (the
    /// cap also bounds the injection loop under nonsensical rates).
    ///
    /// With a [`RateSchedule`] attached, λ is the exact piecewise integral
    /// of the schedule over this window of the mission clock; the
    /// schedule-free path keeps the historical constant-λ expression
    /// bit-for-bit (multiplication order matters for f64 reproducibility).
    pub fn upsets(&mut self, n_bits: u64, steps: u64) -> u64 {
        let lambda = match &self.schedule {
            // the Constant arm repeats the None expression (not the
            // integral × n_bits form) deliberately: f64 multiplication is
            // not associative, and `Some(Constant(r))` must draw the same
            // stream as the historical constant-rate model to the last ulp
            None => self.rate * n_bits as f64 * steps as f64,
            Some(RateSchedule::Constant(r)) => r * n_bits as f64 * steps as f64,
            Some(s) => s.expected_upsets(self.cursor, steps) * n_bits as f64,
        };
        self.cursor = self.cursor.saturating_add(steps);
        self.poisson(lambda).min(n_bits.saturating_mul(steps))
    }

    /// Uniform site selection in `[0, n)`.
    pub fn pick(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        self.rng.below(n)
    }
}

/// Drive one read window of transient strikes against a register file of
/// `n_sites` words × `bits` bits: sample the arrival count, draw sites,
/// apply the mitigation's escape policy, and call `apply(word, bit)` for
/// every flip that reaches the delivered data.
///
/// Escape policy per strategy:
/// * `None`/`Scrub`: the registers are soft — every strike lands;
/// * `Tmr`: a strike is masked unless an earlier strike in this window
///   hit the same (word, bit) in a *different* replica — then two of
///   three replicas agree on the flipped bit and the vote delivers it
///   (the earlier strike is re-classified from masked to uncorrectable;
///   further strikes at a failed site leave the majority unchanged);
/// * `Ecc`: a strike is corrected unless its word was already struck in
///   this window — the word then decodes uncorrectable and is delivered
///   raw, so the earlier optimistically-corrected flip lands
///   retroactively along with every later strike on that word.
///
/// Shared by [`SeuHook::corrupt_fifo`] (FIFO words) and
/// [`crate::fault::FaultyBackend`]'s register-file/replay injection so
/// the arrival semantics and escape policy cannot drift between the two.
/// (Repeated strikes on the *same bit of the same replica* are tracked
/// conservatively, not XOR-exactly — a vanishing corner at any sane λ.)
pub(crate) fn strike_window<F: FnMut(usize, u32)>(
    model: &mut FaultModel,
    mitigation: Mitigation,
    n_sites: usize,
    bits: u32,
    mut apply: F,
) {
    if n_sites == 0 || bits == 0 {
        return;
    }
    let flips = model.upsets(n_sites as u64 * bits as u64, 1);
    // process-wide fault telemetry, beside the per-model `stats`: strikes
    // count arrivals, escaped counts every flip delivered into data, and
    // masked counts mitigation-absorbed strikes. The registry counters are
    // monotone, so reclassification (TMR vote break, ECC double strike)
    // never decrements masked — the exact books stay in `FaultStats`.
    let met = crate::obs::metrics();
    let mut deliver = |word: usize, bit: u32| {
        met.fault_escaped.inc();
        apply(word, bit);
    };
    // strikes of this window, and sites whose protection already failed
    let mut window: Vec<(usize, u32, usize)> = Vec::new();
    let mut failed_bits: Vec<(usize, u32)> = Vec::new(); // TMR voted-through sites
    let mut failed_words: Vec<usize> = Vec::new(); // ECC uncorrectable words
    for _ in 0..flips {
        let word = model.pick(n_sites);
        let bit = model.pick(bits as usize) as u32;
        model.stats.transient += 1;
        met.fault_strikes.inc();
        match mitigation {
            Mitigation::None | Mitigation::Scrub { .. } => deliver(word, bit),
            Mitigation::Tmr => {
                let replica = model.pick(3);
                if failed_bits.contains(&(word, bit)) {
                    // ≥2 replicas already agree on the flip; another
                    // strike there cannot restore the majority
                    model.stats.uncorrectable += 1;
                } else if window
                    .iter()
                    .any(|&(w, b, r)| w == word && b == bit && r != replica)
                {
                    // second replica takes the same bit: the vote flips;
                    // the earlier strike no longer counts as masked
                    model.stats.masked -= 1;
                    model.stats.uncorrectable += 2;
                    failed_bits.push((word, bit));
                    deliver(word, bit);
                } else {
                    model.stats.masked += 1;
                    met.fault_masked.inc();
                }
                window.push((word, bit, replica));
            }
            Mitigation::Ecc => {
                if failed_words.contains(&word) {
                    model.stats.uncorrectable += 1;
                    deliver(word, bit);
                } else {
                    let earlier: Vec<u32> = window
                        .iter()
                        .filter(|&&(w, _, _)| w == word)
                        .map(|&(_, b, _)| b)
                        .collect();
                    if earlier.is_empty() {
                        model.stats.corrected += 1;
                        met.fault_masked.inc();
                    } else {
                        // the word now decodes uncorrectable: deliver it
                        // raw — re-classify the optimistic corrections and
                        // land every flip (a same-bit pair XORs back to
                        // clean, matching the physics)
                        model.stats.corrected -= earlier.len() as u64;
                        model.stats.uncorrectable += earlier.len() as u64 + 1;
                        for b in earlier {
                            deliver(word, b);
                        }
                        deliver(word, bit);
                        failed_words.push(word);
                    }
                }
                window.push((word, bit, 0));
            }
        }
    }
}

/// Transient-fault hook for the FPGA datapath: strikes the Q-value FIFO
/// words of the fixed datapath between their write and their read (the
/// paper's Fig. 6/8 buffers). The hook sees the same arrival population
/// under every [`Mitigation`]; strategies that harden the datapath (TMR,
/// ECC) vote or correct the strike at the word, so it is counted as
/// masked/corrected rather than applied — keeping per-cell upset counts
/// comparable across mitigations.
#[derive(Debug, Clone)]
pub struct SeuHook {
    model: FaultModel,
    mitigation: Mitigation,
}

impl SeuHook {
    pub fn new(seed: u64, rate: f64, mitigation: Mitigation) -> SeuHook {
        SeuHook { model: FaultModel::new(seed, rate), mitigation }
    }

    /// A hook whose arrival rate follows a [`RateSchedule`]; `None` is
    /// exactly [`SeuHook::new`].
    pub fn with_schedule(
        seed: u64,
        rate: f64,
        mitigation: Mitigation,
        schedule: Option<RateSchedule>,
    ) -> SeuHook {
        SeuHook { model: FaultModel::with_schedule(seed, rate, schedule), mitigation }
    }

    pub fn stats(&self) -> FaultStats {
        self.model.stats
    }

    /// Expose the FIFO's buffered fixed-point words to one
    /// [`strike_window`]. Hardened strategies are not structurally
    /// immune: TMR vote breaks and SECDED double strikes escape per the
    /// shared policy, and the escapes land in the buffered words.
    pub fn corrupt_fifo(&mut self, fifo: &mut Fifo<Fixed>, spec: FixedSpec) -> Result<()> {
        if fifo.is_empty() {
            return Ok(());
        }
        let mut failure: Option<crate::error::Error> = None;
        strike_window(
            &mut self.model,
            self.mitigation,
            fifo.len(),
            spec.word,
            |word, bit| {
                if failure.is_none() {
                    if let Err(e) = fifo.corrupt_at(word, |v| *v = v.flip_bit(bit)) {
                        failure = Some(e);
                    }
                }
            },
        );
        match failure {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_and_mean_tracks_lambda() {
        let mut a = FaultModel::new(42, 1.0);
        let mut b = FaultModel::new(42, 1.0);
        for _ in 0..50 {
            assert_eq!(a.poisson(0.7), b.poisson(0.7));
        }
        let mut m = FaultModel::new(7, 1.0);
        let n = 20_000;
        let total: u64 = (0..n).map(|_| m.poisson(2.0)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
        assert_eq!(m.poisson(0.0), 0);
        assert_eq!(m.poisson(2e4), 20_000);
    }

    #[test]
    fn upsets_scale_with_population_and_rate() {
        let mut hot = FaultModel::new(1, 1e-2);
        let mut cold = FaultModel::new(1, 1e-6);
        let hot_total: u64 = (0..1000).map(|_| hot.upsets(1000, 1)).sum();
        let cold_total: u64 = (0..1000).map(|_| cold.upsets(1000, 1)).sum();
        assert!(hot_total > 1000, "{hot_total}"); // λ·calls = 10⁴
        assert!(cold_total < 50, "{cold_total}"); // λ·calls = 1
        // zero-rate model never fires
        let mut none = FaultModel::new(1, 0.0);
        assert_eq!((0..100).map(|_| none.upsets(u64::MAX / 2, 1)).sum::<u64>(), 0);
    }

    #[test]
    fn constant_schedule_is_bit_identical_to_no_schedule() {
        // the compatibility contract: a Constant schedule must reproduce
        // the historical constant-rate draw stream exactly, so attaching
        // `schedule: Some(Constant(r))` never perturbs an existing replay
        let mut plain = FaultModel::new(21, 3e-4);
        let mut sched =
            FaultModel::with_schedule(21, 3e-4, Some(RateSchedule::Constant(3e-4)));
        for steps in [1u64, 3, 1, 7, 2] {
            assert_eq!(plain.upsets(4096, steps), sched.upsets(4096, steps));
        }
    }

    #[test]
    fn spike_schedule_concentrates_upsets_in_the_event_window() {
        let spike = RateSchedule::Spike { base: 0.0, peak: 1e-3, start: 10, len: 5 };
        let mut m = FaultModel::with_schedule(33, 0.0, Some(spike));
        let mut per_step = Vec::new();
        for _ in 0..30 {
            per_step.push(m.upsets(10_000, 1));
        }
        assert!(per_step[..10].iter().all(|&u| u == 0), "quiet before the event");
        assert!(per_step[15..].iter().all(|&u| u == 0), "quiet after the event");
        assert!(per_step[10..15].iter().sum::<u64>() > 0, "the event must strike");
    }

    #[test]
    fn hook_strikes_fifo_words_deterministically() {
        let spec = FixedSpec::default();
        let run = |seed: u64| {
            // hot: ~5 flips over 108 bits
            let mut hook = SeuHook::new(seed, 0.05, Mitigation::None);
            let mut fifo: Fifo<Fixed> = Fifo::new(6);
            for i in 0..6 {
                fifo.push(Fixed::from_f64(i as f64 * 0.1, spec)).unwrap();
            }
            hook.corrupt_fifo(&mut fifo, spec).unwrap();
            (fifo.drain_all().unwrap(), hook.stats().transient)
        };
        let (a, na) = run(9);
        let (b, nb) = run(9);
        assert_eq!(a, b);
        assert_eq!(na, nb);
    }

    #[test]
    fn hardened_hook_masks_or_flags_every_strike() {
        let spec = FixedSpec::default();
        let words: Vec<Fixed> = (0..6).map(|i| Fixed::from_f64(i as f64 * 0.1, spec)).collect();
        for m in [Mitigation::Tmr, Mitigation::Ecc] {
            // accumulate strikes over many read windows so both the
            // masked/corrected path and (for ECC, likely) the
            // collision-escape path are exercised
            let mut hook = SeuHook::new(9, 0.02, m);
            let mut any_window_clean = false;
            for _ in 0..40 {
                let mut fifo: Fifo<Fixed> = Fifo::new(6);
                for &w in &words {
                    fifo.push(w).unwrap();
                }
                let before = hook.stats();
                hook.corrupt_fifo(&mut fifo, spec).unwrap();
                let after = hook.stats();
                let escaped = after.uncorrectable - before.uncorrectable;
                let out = fifo.drain_all().unwrap();
                if escaped == 0 {
                    // no collision in this window: fully masked/corrected
                    assert_eq!(out, words, "{}", m.label());
                    any_window_clean |= after.transient > before.transient;
                }
            }
            let s = hook.stats();
            assert!(s.transient > 0, "{}", m.label());
            assert!(any_window_clean, "{}: no masked window observed", m.label());
            // every strike is accounted exactly once
            let handled = if m == Mitigation::Tmr { s.masked } else { s.corrected };
            assert_eq!(handled + s.uncorrectable, s.transient, "{}", m.label());
        }
    }
}
