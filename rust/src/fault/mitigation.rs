//! SEU mitigation strategies and the protected weight store.
//!
//! Three classic space-FPGA hardening techniques (Antunes & Podobas's
//! survey axes), each with bit-accurate masking behaviour *and* a hardware
//! cost charged through the [`crate::fpga`] area/power/timing hooks:
//!
//! * **TMR** — the whole datapath and weight store triplicated; reads pass
//!   a bitwise majority voter. Masks every single upset per word per read
//!   window; costs ~3× area and dynamic power plus a voter stage.
//! * **Scrub** — a golden copy in hardened memory, periodically rewritten
//!   over the working store. Cheap — but for *continuously retrained*
//!   weight memory it is nearly ineffective by construction: backprop
//!   rewrites every weight (and its golden shadow) each update, so a flip
//!   is either caught by a pass inside its own injection window or read
//!   into training and legitimized by the next write-back. The campaign
//!   table makes this visible (scrub degradation ≈ unmitigated at scrub
//!   cost); scrubbing's classical value is for memory that is **not**
//!   rewritten every cycle — configuration memory, modeled in
//!   [`crate::fault::cram`] with its own partial-reconfiguration scrubber
//!   (`CramPlan`; Pareto-searched by `qfpga harden`).
//! * **ECC** — SECDED (Hamming + overall parity) on every stored word:
//!   single-bit errors corrected on read (and written back), double-bit
//!   errors detected but not corrected.

use crate::config::{NetConfig, Precision};
use crate::error::{Error, Result};
use crate::fixed::FixedSpec;
use crate::fpga::area::accelerator_resources;
use crate::fpga::power::{dynamic_power_w, power_w, stream_power_w, PowerCoeffs};
use crate::fpga::units::{cost, Resources};
use crate::fpga::TimingModel;

use super::inject::WordCodec;
use super::model::{FaultModel, FaultStats};

/// A hardening strategy for the weight store / datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mitigation {
    /// Soft everything — the paper's baseline datapath.
    None,
    /// Triple modular redundancy with bitwise majority voting.
    Tmr,
    /// Golden-copy scrubbing every `interval` steps. Note: against
    /// weight memory that every update rewrites (write-through golden
    /// shadow), only flips repaired within their own injection window are
    /// caught — see the module docs for why this is a result, not a bug.
    Scrub { interval: u32 },
    /// SECDED on every stored word.
    Ecc,
}

/// Default scrub period, steps.
pub const DEFAULT_SCRUB_INTERVAL: u32 = 64;

impl Mitigation {
    /// The canonical strategy sweep (campaigns, CLI `all`).
    pub fn all() -> [Mitigation; 4] {
        [
            Mitigation::None,
            Mitigation::Tmr,
            Mitigation::Scrub { interval: DEFAULT_SCRUB_INTERVAL },
            Mitigation::Ecc,
        ]
    }

    pub fn label(&self) -> String {
        match self {
            Mitigation::None => "none".into(),
            Mitigation::Tmr => "tmr".into(),
            Mitigation::Scrub { interval } => format!("scrub:{interval}"),
            Mitigation::Ecc => "ecc".into(),
        }
    }

    /// Does this strategy also harden datapath registers/FIFOs (not just
    /// the weight memory)? TMR triplicates logic; ECC here covers the
    /// buffered words. Scrubbing only repairs the store between passes.
    pub fn hardens_datapath(&self) -> bool {
        matches!(self, Mitigation::Tmr | Mitigation::Ecc)
    }

    fn words(cfg: &NetConfig) -> u64 {
        cfg.n_params() as u64
    }

    fn data_bits(prec: Precision) -> u32 {
        WordCodec::new(prec, FixedSpec::default()).bits_per_word()
    }

    /// Hardware added on top of the base accelerator
    /// ([`accelerator_resources`]) — folded into the device-fit check via
    /// [`crate::fpga::area::check_fit_with`].
    pub fn extra_resources(&self, cfg: &NetConfig, prec: Precision) -> Resources {
        let words = Self::words(cfg);
        let bits = Self::data_bits(prec) as u64;
        match self {
            Mitigation::None => Resources::default(),
            Mitigation::Tmr => {
                // two more full copies of the datapath + a per-bit majority
                // voter on every stored word
                let mut r = accelerator_resources(cfg, prec).scaled(2);
                r.add(Resources::new(words * bits, words, 0, 0));
                r
            }
            Mitigation::Scrub { .. } => {
                // scrub FSM + golden-copy BRAM and its write-through bus
                let mut r = cost::CONTROL;
                r.add(Resources::new(60, 40, 0, 1));
                r
            }
            Mitigation::Ecc => {
                // encoder + decoder trees per word class, check-bit storage
                let r = (Secded::new(bits as u32).check_bits() + 1) as u64;
                Resources::new(words * 2 * r + 120, words * r, 0, 0)
            }
        }
    }

    /// Data-movement scale factor for the power model (TMR triplicates the
    /// streamed writes; ECC streams the check bits alongside the data).
    pub fn stream_factor(&self, prec: Precision) -> f64 {
        let bits = Self::data_bits(prec);
        match self {
            Mitigation::None | Mitigation::Scrub { .. } => 1.0,
            Mitigation::Tmr => 3.0,
            Mitigation::Ecc => {
                (bits + Secded::new(bits).check_bits() + 1) as f64 / bits as f64
            }
        }
    }

    /// Mitigated-design LUT count relative to the unmitigated datapath.
    pub fn area_overhead_factor(&self, cfg: &NetConfig, prec: Precision) -> f64 {
        let base = accelerator_resources(cfg, prec);
        let extra = self.extra_resources(cfg, prec);
        (base.luts + extra.luts) as f64 / base.luts as f64
    }

    /// Mitigated dynamic (datapath) power relative to the unmitigated
    /// datapath — static and clock-tree power are excluded on both sides,
    /// so the ratio isolates what the hardening hardware toggles.
    pub fn power_overhead_factor(
        &self,
        cfg: &NetConfig,
        prec: Precision,
        coeffs: &PowerCoeffs,
    ) -> f64 {
        let base = dynamic_power_w(&accelerator_resources(cfg, prec), prec, coeffs)
            + stream_power_w(cfg, coeffs);
        let extra = dynamic_power_w(&self.extra_resources(cfg, prec), prec, coeffs)
            + (self.stream_factor(prec) - 1.0) * stream_power_w(cfg, coeffs);
        (base + extra) / base
    }

    /// Absolute mitigated power, W (the Tables 7–8 model plus the
    /// mitigation hardware).
    pub fn mitigated_power_w(
        &self,
        cfg: &NetConfig,
        prec: Precision,
        coeffs: &PowerCoeffs,
    ) -> f64 {
        power_w(cfg, prec, coeffs)
            + dynamic_power_w(&self.extra_resources(cfg, prec), prec, coeffs)
            + (self.stream_factor(prec) - 1.0) * stream_power_w(cfg, coeffs)
    }

    /// Extra cycles one Q-update pays under this strategy: voter/decode
    /// stages on every protected storage read phase, or the amortized
    /// scrub burst. Charged identically at both precisions (the voter /
    /// SECDED decoder sits on the weight read path either way).
    pub fn extra_cycles_per_update(
        &self,
        cfg: &NetConfig,
        _prec: Precision,
        t: &TimingModel,
    ) -> u64 {
        match self {
            Mitigation::None => 0,
            Mitigation::Tmr => t.protected_read_phases(cfg),
            Mitigation::Ecc => t.protected_read_phases(cfg) + 1, // + encode on write-back
            Mitigation::Scrub { interval } => {
                let burst = t.scrub_burst_cycles(Self::words(cfg));
                burst.div_ceil((*interval).max(1) as u64)
            }
        }
    }

    /// Per-update cycle cost relative to the unmitigated datapath.
    pub fn cycle_overhead_factor(
        &self,
        cfg: &NetConfig,
        prec: Precision,
        t: &TimingModel,
    ) -> f64 {
        let base = t.qupdate(cfg, prec).total();
        (base + self.extra_cycles_per_update(cfg, prec, t)) as f64 / base as f64
    }
}

impl std::str::FromStr for Mitigation {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(Mitigation::None),
            "tmr" => Ok(Mitigation::Tmr),
            "ecc" => Ok(Mitigation::Ecc),
            "scrub" => Ok(Mitigation::Scrub { interval: DEFAULT_SCRUB_INTERVAL }),
            other => {
                if let Some(n) = other.strip_prefix("scrub:") {
                    let interval: u32 = n.parse().map_err(|_| {
                        Error::Config(format!("bad scrub interval `{n}`"))
                    })?;
                    if interval == 0 {
                        return Err(Error::Config("scrub interval must be positive".into()));
                    }
                    Ok(Mitigation::Scrub { interval })
                } else {
                    Err(Error::Config(format!(
                        "unknown mitigation `{other}` (none|tmr|scrub[:N]|ecc)"
                    )))
                }
            }
        }
    }
}

// ------------------------------------------------------------------- SECDED

/// Outcome of one SECDED word decode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EccOutcome {
    Clean,
    Corrected,
    /// Double-bit (or worse) error: detected, data returned uncorrected.
    Uncorrectable,
}

/// SECDED (Hamming + overall parity) over `k` data bits, `k ≤ 63`.
/// Codeword layout (LSB-first in the u128): bit 0 is the overall parity,
/// bits 1..=k+r hold the classic Hamming arrangement (parity bits at
/// power-of-two positions, data bits LSB-first elsewhere).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Secded {
    k: u32,
    r: u32,
}

impl Secded {
    pub fn new(k: u32) -> Secded {
        assert!((1..=63).contains(&k), "SECDED data width {k} out of range");
        let mut r = 0u32;
        while (1u32 << r) < k + r + 1 {
            r += 1;
        }
        Secded { k, r }
    }

    /// Hamming check bits (excludes the overall parity bit).
    pub fn check_bits(&self) -> u32 {
        self.r
    }

    /// Total codeword bits, including the overall parity bit.
    pub fn total_bits(&self) -> u32 {
        self.k + self.r + 1
    }

    pub fn encode(&self, data: u64) -> u128 {
        debug_assert!(self.k == 63 || data < (1u64 << self.k));
        let m = self.k + self.r;
        let mut code: u128 = 0;
        let mut di = 0u32;
        for pos in 1..=m {
            if !pos.is_power_of_two() {
                if (data >> di) & 1 == 1 {
                    code |= 1u128 << pos;
                }
                di += 1;
            }
        }
        for p in 0..self.r {
            let pp = 1u32 << p;
            if self.group_parity(code, pp) == 1 {
                code |= 1u128 << pp;
            }
        }
        if self.overall_parity(code) == 1 {
            code |= 1;
        }
        code
    }

    /// Decode (and correct a single-bit error in) a codeword.
    pub fn decode(&self, code: u128) -> (u64, EccOutcome) {
        let m = self.k + self.r;
        let mut syndrome = 0u32;
        for p in 0..self.r {
            let pp = 1u32 << p;
            if self.group_parity(code, pp) == 1 {
                syndrome |= pp;
            }
        }
        // parity of the whole codeword (bit 0 included): 0 when the number
        // of flipped bits is even
        let overall = self.overall_parity(code);
        let mut fixed = code;
        let outcome = if syndrome == 0 && overall == 0 {
            EccOutcome::Clean
        } else if overall == 1 {
            if syndrome == 0 {
                fixed ^= 1; // the overall parity bit itself flipped
                EccOutcome::Corrected
            } else if syndrome <= m {
                fixed ^= 1u128 << syndrome;
                EccOutcome::Corrected
            } else {
                EccOutcome::Uncorrectable // ≥3 odd-count flips
            }
        } else {
            EccOutcome::Uncorrectable // even flip count > 0
        };
        let mut data = 0u64;
        let mut di = 0u32;
        for pos in 1..=m {
            if !pos.is_power_of_two() {
                if (fixed >> pos) & 1 == 1 {
                    data |= 1u64 << di;
                }
                di += 1;
            }
        }
        (data, outcome)
    }

    #[inline]
    fn group_parity(&self, code: u128, pp: u32) -> u32 {
        let m = self.k + self.r;
        let mut parity = 0u32;
        for pos in 1..=m {
            if pos & pp != 0 {
                parity ^= ((code >> pos) & 1) as u32;
            }
        }
        parity & 1
    }

    #[inline]
    fn overall_parity(&self, code: u128) -> u32 {
        let m = self.k + self.r;
        let mut parity = (code & 1) as u32;
        for pos in 1..=m {
            parity ^= ((code >> pos) & 1) as u32;
        }
        parity & 1
    }
}

// ---------------------------------------------------------------- the store

#[derive(Debug, Clone)]
enum StoreState {
    Plain { words: Vec<u64> },
    Tmr { replicas: [Vec<u64>; 3] },
    Scrub { words: Vec<u64>, golden: Vec<u64>, interval: u32, since: u32 },
    Ecc { code: Vec<u128>, secded: Secded },
}

/// The weight store under a mitigation strategy: write-through on every
/// update, upset injection between updates, mitigated reads.
#[derive(Debug, Clone)]
pub struct ProtectedStore {
    mitigation: Mitigation,
    bits: u32,
    state: StoreState,
}

impl ProtectedStore {
    /// `bits` is the data width per word; `initial` the starting words
    /// (low `bits` of each u64).
    pub fn new(mitigation: Mitigation, bits: u32, initial: &[u64]) -> ProtectedStore {
        let words = initial.to_vec();
        let state = match mitigation {
            Mitigation::None => StoreState::Plain { words },
            Mitigation::Tmr => {
                StoreState::Tmr { replicas: [words.clone(), words.clone(), words] }
            }
            Mitigation::Scrub { interval } => StoreState::Scrub {
                golden: words.clone(),
                words,
                interval: interval.max(1),
                since: 0,
            },
            Mitigation::Ecc => {
                let secded = Secded::new(bits);
                StoreState::Ecc {
                    code: words.iter().map(|&w| secded.encode(w)).collect(),
                    secded,
                }
            }
        };
        ProtectedStore { mitigation, bits, state }
    }

    pub fn mitigation(&self) -> Mitigation {
        self.mitigation
    }

    pub fn n_words(&self) -> usize {
        match &self.state {
            StoreState::Plain { words } => words.len(),
            StoreState::Tmr { replicas } => replicas[0].len(),
            StoreState::Scrub { words, .. } => words.len(),
            StoreState::Ecc { code, .. } => code.len(),
        }
    }

    /// SEU-susceptible bits per stored word under this strategy.
    pub fn susceptible_bits_per_word(&self) -> u32 {
        match &self.state {
            StoreState::Plain { .. } | StoreState::Scrub { .. } => self.bits,
            StoreState::Tmr { .. } => 3 * self.bits,
            StoreState::Ecc { secded, .. } => secded.total_bits(),
        }
    }

    /// Total susceptible bit population (the injection λ driver).
    pub fn susceptible_bits(&self) -> u64 {
        self.n_words() as u64 * self.susceptible_bits_per_word() as u64
    }

    /// Full-store write-back: every Q-update rewrites the weights, which
    /// re-encodes ECC words, resynchronizes TMR replicas and refreshes the
    /// scrub golden copy (write-through shadow).
    pub fn write(&mut self, new_words: &[u64]) {
        debug_assert_eq!(new_words.len(), self.n_words());
        let mask = if self.bits == 64 { u64::MAX } else { (1u64 << self.bits) - 1 };
        match &mut self.state {
            StoreState::Plain { words } => {
                for (w, &n) in words.iter_mut().zip(new_words) {
                    *w = n & mask;
                }
            }
            StoreState::Tmr { replicas } => {
                for r in replicas.iter_mut() {
                    for (w, &n) in r.iter_mut().zip(new_words) {
                        *w = n & mask;
                    }
                }
            }
            StoreState::Scrub { words, golden, .. } => {
                for ((w, g), &n) in words.iter_mut().zip(golden.iter_mut()).zip(new_words) {
                    *w = n & mask;
                    *g = n & mask;
                }
            }
            StoreState::Ecc { code, secded } => {
                for (c, &n) in code.iter_mut().zip(new_words) {
                    *c = secded.encode(n & mask);
                }
            }
        }
    }

    /// Mitigated read of the whole store. TMR votes (latent flips counted
    /// as `masked`), ECC corrects single-bit words in place (`corrected`) /
    /// flags multi-bit words (`uncorrectable`); None/Scrub read raw.
    pub fn read(&mut self, stats: &mut FaultStats) -> Vec<u64> {
        match &mut self.state {
            StoreState::Plain { words } | StoreState::Scrub { words, .. } => words.clone(),
            StoreState::Tmr { replicas } => {
                let n = replicas[0].len();
                let mut out = Vec::with_capacity(n);
                for i in 0..n {
                    let (a, b, c) = (replicas[0][i], replicas[1][i], replicas[2][i]);
                    let v = (a & b) | (a & c) | (b & c);
                    let latent = (a ^ v).count_ones() + (b ^ v).count_ones()
                        + (c ^ v).count_ones();
                    stats.masked += latent as u64;
                    out.push(v);
                }
                out
            }
            StoreState::Ecc { code, secded } => {
                let mut out = Vec::with_capacity(code.len());
                for c in code.iter_mut() {
                    let (data, outcome) = secded.decode(*c);
                    match outcome {
                        EccOutcome::Clean => {}
                        EccOutcome::Corrected => {
                            stats.corrected += 1;
                            *c = secded.encode(data); // scrub-on-read
                        }
                        EccOutcome::Uncorrectable => stats.uncorrectable += 1,
                    }
                    out.push(data);
                }
                out
            }
        }
    }

    /// Advance `steps` environment steps: sample Poisson upsets over the
    /// susceptible population, then run any due scrub pass. Returns `true`
    /// when any upset struck. Composed from [`Self::apply_upsets`],
    /// [`Self::tick_scrub`] and [`Self::scrub_now`] — callers that replay
    /// the write-through lazily ([`crate::fault::FaultyBackend`]) use the
    /// primitives directly so a clean step skips all store work.
    pub fn step(&mut self, model: &mut FaultModel, steps: u64) -> bool {
        if self.n_words() == 0 {
            return false;
        }
        let flips = model.upsets(self.susceptible_bits(), steps);
        self.apply_upsets(model, flips);
        if self.tick_scrub(steps) {
            self.scrub_now(model);
        }
        flips > 0
    }

    /// Strike `flips` pre-sampled upsets: uniform site draws (word ×
    /// replica/codeword-bit) from the model's stream, applied in order.
    pub fn apply_upsets(&mut self, model: &mut FaultModel, flips: u64) {
        if self.n_words() == 0 {
            return;
        }
        for _ in 0..flips {
            let word = model.pick(self.n_words());
            let replica = match self.state {
                StoreState::Tmr { .. } => model.pick(3),
                _ => 0,
            };
            let bit = match &self.state {
                StoreState::Ecc { secded, .. } => model.pick(secded.total_bits() as usize),
                _ => model.pick(self.bits as usize),
            } as u32;
            self.force_flip(word, bit, replica);
            model.stats.injected += 1;
        }
    }

    /// Advance the scrub timer by `steps`; returns whether a pass came
    /// due (timer wraps modulo the interval). Always `false` for
    /// non-scrub strategies.
    pub fn tick_scrub(&mut self, steps: u64) -> bool {
        if let StoreState::Scrub { interval, since, .. } = &mut self.state {
            *since = since.saturating_add(steps.min(u32::MAX as u64) as u32);
            if *since >= *interval {
                *since %= *interval;
                return true;
            }
        }
        false
    }

    /// Run one scrub pass now: rewrite the working store from the golden
    /// copy, counting restored bits. No-op for non-scrub strategies.
    pub fn scrub_now(&mut self, model: &mut FaultModel) {
        if let StoreState::Scrub { words, golden, .. } = &mut self.state {
            for (w, g) in words.iter_mut().zip(golden.iter()) {
                model.stats.scrubbed += (*w ^ *g).count_ones() as u64;
                *w = *g;
            }
        }
    }

    /// Flip one specific bit — the deterministic primitive `step` uses,
    /// public so tests can stage exact fault patterns. For TMR, `replica`
    /// selects the copy (0..3); for ECC, `bit` indexes the full codeword
    /// (0 = overall parity); otherwise `bit` indexes the data word.
    pub fn force_flip(&mut self, word: usize, bit: u32, replica: usize) {
        match &mut self.state {
            StoreState::Plain { words } | StoreState::Scrub { words, .. } => {
                words[word] ^= 1u64 << bit;
            }
            StoreState::Tmr { replicas } => {
                replicas[replica][word] ^= 1u64 << bit;
            }
            StoreState::Ecc { code, .. } => {
                code[word] ^= 1u128 << bit;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, EnvKind};
    use crate::util::Rng;

    fn specs_in_use() -> [FixedSpec; 6] {
        // default Q(18,12) plus the X3 word-length ablation sweep
        [
            FixedSpec::new(8, 4),
            FixedSpec::new(12, 8),
            FixedSpec::new(16, 8),
            FixedSpec::new(18, 12),
            FixedSpec::new(24, 16),
            FixedSpec::new(32, 24),
        ]
    }

    #[test]
    fn secded_roundtrip_clean() {
        for spec in specs_in_use() {
            let s = Secded::new(spec.word);
            let mut rng = Rng::seeded(spec.word as u64);
            for _ in 0..100 {
                let data = rng.next_u64() & ((1u64 << spec.word) - 1);
                let (back, outcome) = s.decode(s.encode(data));
                assert_eq!(back, data);
                assert_eq!(outcome, EccOutcome::Clean);
            }
        }
    }

    #[test]
    fn secded_corrects_every_single_bit_flip() {
        for spec in specs_in_use() {
            let s = Secded::new(spec.word);
            let mut rng = Rng::seeded(100 + spec.word as u64);
            for _ in 0..20 {
                let data = rng.next_u64() & ((1u64 << spec.word) - 1);
                let code = s.encode(data);
                for bit in 0..s.total_bits() {
                    let (back, outcome) = s.decode(code ^ (1u128 << bit));
                    assert_eq!(back, data, "Q{} bit {bit}", spec.word);
                    assert_eq!(outcome, EccOutcome::Corrected, "Q{} bit {bit}", spec.word);
                }
            }
        }
    }

    #[test]
    fn secded_detects_double_bit_flips() {
        let s = Secded::new(18);
        let data = 0x2A5_5Au64 & ((1 << 18) - 1);
        let code = s.encode(data);
        let mut rng = Rng::seeded(5);
        for _ in 0..200 {
            let b1 = rng.below(s.total_bits() as usize) as u32;
            let mut b2 = rng.below(s.total_bits() as usize) as u32;
            while b2 == b1 {
                b2 = rng.below(s.total_bits() as usize) as u32;
            }
            let (_, outcome) = s.decode(code ^ (1u128 << b1) ^ (1u128 << b2));
            assert_eq!(outcome, EccOutcome::Uncorrectable, "bits {b1},{b2}");
        }
    }

    #[test]
    fn tmr_store_masks_single_flips_everywhere() {
        for spec in specs_in_use() {
            let mut rng = Rng::seeded(spec.word as u64);
            let words: Vec<u64> =
                (0..16).map(|_| rng.next_u64() & ((1u64 << spec.word) - 1)).collect();
            let mut store = ProtectedStore::new(Mitigation::Tmr, spec.word, &words);
            let mut stats = FaultStats::default();
            // one flip per word, random replica/bit: all must vote away
            for w in 0..words.len() {
                let replica = rng.below(3);
                let bit = rng.below(spec.word as usize) as u32;
                store.force_flip(w, bit, replica);
            }
            assert_eq!(store.read(&mut stats), words, "Q({},{})", spec.word, spec.frac);
            assert_eq!(stats.masked, words.len() as u64);
        }
    }

    #[test]
    fn ecc_store_corrects_single_flips_everywhere() {
        for spec in specs_in_use() {
            let mut rng = Rng::seeded(1000 + spec.word as u64);
            let words: Vec<u64> =
                (0..16).map(|_| rng.next_u64() & ((1u64 << spec.word) - 1)).collect();
            let mut store = ProtectedStore::new(Mitigation::Ecc, spec.word, &words);
            let mut stats = FaultStats::default();
            let total = Secded::new(spec.word).total_bits();
            for w in 0..words.len() {
                store.force_flip(w, rng.below(total as usize) as u32, 0);
            }
            assert_eq!(store.read(&mut stats), words, "Q({},{})", spec.word, spec.frac);
            assert_eq!(stats.corrected, words.len() as u64);
            // corrected in place: a second read is clean
            let mut stats2 = FaultStats::default();
            assert_eq!(store.read(&mut stats2), words);
            assert_eq!(stats2.corrected, 0);
        }
    }

    #[test]
    fn ecc_double_flip_is_flagged_not_silently_wrong() {
        let spec = FixedSpec::default();
        let words = vec![0x155AAu64 & ((1 << 18) - 1); 1];
        let mut store = ProtectedStore::new(Mitigation::Ecc, spec.word, &words);
        store.force_flip(0, 3, 0);
        store.force_flip(0, 7, 0);
        let mut stats = FaultStats::default();
        store.read(&mut stats);
        assert_eq!(stats.uncorrectable, 1);
        assert_eq!(stats.corrected, 0);
    }

    #[test]
    fn scrub_restores_at_interval_boundaries() {
        let spec = FixedSpec::default();
        let words = vec![0u64, 1, 2, 3];
        let mut store =
            ProtectedStore::new(Mitigation::Scrub { interval: 4 }, spec.word, &words);
        let mut model = FaultModel::new(1, 0.0); // no random upsets
        store.force_flip(1, 0, 0);
        store.force_flip(2, 5, 0);
        let mut stats = FaultStats::default();
        store.step(&mut model, 3); // not due yet
        assert_ne!(store.read(&mut stats), words);
        store.step(&mut model, 1); // pass due
        assert_eq!(store.read(&mut stats), words);
        assert_eq!(model.stats.scrubbed, 2);
    }

    #[test]
    fn none_store_keeps_corruption() {
        let spec = FixedSpec::default();
        let words = vec![0u64; 8];
        let mut store = ProtectedStore::new(Mitigation::None, spec.word, &words);
        store.force_flip(4, 9, 0);
        let mut stats = FaultStats::default();
        let read = store.read(&mut stats);
        assert_eq!(read[4], 1u64 << 9);
        assert_eq!(stats, FaultStats::default());
    }

    #[test]
    fn write_resynchronizes_all_representations() {
        let spec = FixedSpec::default();
        let words = vec![7u64; 4];
        for m in Mitigation::all() {
            let mut store = ProtectedStore::new(m, spec.word, &words);
            store.force_flip(0, 2, 0);
            let fresh = vec![9u64; 4];
            store.write(&fresh);
            let mut stats = FaultStats::default();
            assert_eq!(store.read(&mut stats), fresh, "{}", m.label());
            // post-write reads are clean: nothing masked or corrected
            assert_eq!(stats, FaultStats::default(), "{}", m.label());
        }
    }

    #[test]
    fn susceptible_population_reflects_strategy() {
        let spec = FixedSpec::default();
        let words = vec![0u64; 10];
        let plain = ProtectedStore::new(Mitigation::None, spec.word, &words);
        let tmr = ProtectedStore::new(Mitigation::Tmr, spec.word, &words);
        let ecc = ProtectedStore::new(Mitigation::Ecc, spec.word, &words);
        assert_eq!(plain.susceptible_bits(), 180);
        assert_eq!(tmr.susceptible_bits(), 540);
        assert_eq!(ecc.susceptible_bits(), 10 * Secded::new(18).total_bits() as u64);
    }

    #[test]
    fn mitigation_parsing() {
        assert_eq!("tmr".parse::<Mitigation>().unwrap(), Mitigation::Tmr);
        assert_eq!(
            "scrub".parse::<Mitigation>().unwrap(),
            Mitigation::Scrub { interval: DEFAULT_SCRUB_INTERVAL }
        );
        assert_eq!(
            "scrub:9".parse::<Mitigation>().unwrap(),
            Mitigation::Scrub { interval: 9 }
        );
        assert!("scrub:0".parse::<Mitigation>().is_err());
        assert!("rhbd".parse::<Mitigation>().is_err());
        for m in Mitigation::all() {
            assert_eq!(m.label().parse::<Mitigation>().unwrap(), m);
        }
    }

    #[test]
    fn tmr_overheads_exceed_2x_everywhere() {
        let coeffs = PowerCoeffs::default();
        for cfg in NetConfig::all() {
            for prec in [Precision::Fixed, Precision::Float] {
                let a = Mitigation::Tmr.area_overhead_factor(&cfg, prec);
                let p = Mitigation::Tmr.power_overhead_factor(&cfg, prec, &coeffs);
                assert!(a > 2.0, "{} {prec:?}: area {a}", cfg.name());
                assert!(p > 2.0, "{} {prec:?}: power {p}", cfg.name());
            }
        }
    }

    #[test]
    fn cheap_mitigations_stay_cheap() {
        let cfg = NetConfig::new(Arch::Mlp, EnvKind::Complex);
        let coeffs = PowerCoeffs::default();
        let t = TimingModel::default();
        for m in [Mitigation::Scrub { interval: 64 }, Mitigation::Ecc] {
            assert!(m.area_overhead_factor(&cfg, Precision::Fixed) < 2.0, "{}", m.label());
            assert!(
                m.power_overhead_factor(&cfg, Precision::Fixed, &coeffs) < 2.0,
                "{}",
                m.label()
            );
            assert!(
                m.cycle_overhead_factor(&cfg, Precision::Fixed, &t) < 1.5,
                "{}",
                m.label()
            );
        }
        assert_eq!(
            Mitigation::None.extra_cycles_per_update(&cfg, Precision::Fixed, &t),
            0
        );
    }
}
