//! Table data model + plain-text rendering.

use std::fmt;

/// One table row: our value vs the paper's.
#[derive(Debug, Clone)]
pub struct TableRow {
    pub label: String,
    pub ours: f64,
    /// The paper's published value, if it reports one for this row.
    pub paper: Option<f64>,
}

impl TableRow {
    pub fn new(label: impl Into<String>, ours: f64, paper: Option<f64>) -> TableRow {
        TableRow { label: label.into(), ours, paper }
    }

    /// ours / paper (reproduction ratio; 1.0 = exact).
    pub fn ratio(&self) -> Option<f64> {
        self.paper.map(|p| self.ours / p)
    }
}

/// A regenerated paper table.
#[derive(Debug, Clone)]
pub struct PaperTable {
    /// Experiment id from DESIGN.md (e.g. "T3").
    pub id: &'static str,
    pub title: String,
    /// Unit of the value column.
    pub unit: &'static str,
    pub rows: Vec<TableRow>,
    /// Methodology / discrepancy notes printed under the table.
    pub notes: Vec<String>,
}

impl PaperTable {
    pub fn new(id: &'static str, title: impl Into<String>, unit: &'static str) -> PaperTable {
        PaperTable { id, title: title.into(), unit, rows: Vec::new(), notes: Vec::new() }
    }

    pub fn row(mut self, label: impl Into<String>, ours: f64, paper: Option<f64>) -> Self {
        self.rows.push(TableRow::new(label, ours, paper));
        self
    }

    pub fn note(mut self, n: impl Into<String>) -> Self {
        self.notes.push(n.into());
        self
    }

    /// Worst |log-ratio| across rows with paper values — the headline
    /// reproduction-quality scalar for EXPERIMENTS.md.
    pub fn worst_ratio(&self) -> Option<f64> {
        self.rows
            .iter()
            .filter_map(TableRow::ratio)
            .map(|r| if r >= 1.0 { r } else { 1.0 / r })
            .fold(None, |acc, r| Some(acc.map_or(r, |a: f64| a.max(r))))
    }
}

fn fmt_value(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

impl fmt::Display for PaperTable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "[{}] {}", self.id, self.title)?;
        let label_w = self
            .rows
            .iter()
            .map(|r| r.label.len())
            .max()
            .unwrap_or(10)
            .max(12);
        writeln!(
            f,
            "  {:<label_w$}  {:>12}  {:>12}  {:>7}",
            "row",
            format!("ours ({})", self.unit),
            "paper",
            "ratio"
        )?;
        writeln!(f, "  {:-<label_w$}  {:->12}  {:->12}  {:->7}", "", "", "", "")?;
        for r in &self.rows {
            let paper = r.paper.map(fmt_value).unwrap_or_else(|| "—".into());
            let ratio = r
                .ratio()
                .map(|x| format!("{x:.2}×"))
                .unwrap_or_else(|| "—".into());
            writeln!(
                f,
                "  {:<label_w$}  {:>12}  {:>12}  {:>7}",
                r.label,
                fmt_value(r.ours),
                paper,
                ratio
            )?;
        }
        for n in &self.notes {
            writeln!(f, "  note: {n}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_and_worst() {
        let t = PaperTable::new("T0", "test", "µs")
            .row("a", 2.0, Some(1.0))
            .row("b", 0.5, Some(1.0))
            .row("c", 1.0, None);
        assert_eq!(t.rows[0].ratio(), Some(2.0));
        assert_eq!(t.worst_ratio(), Some(2.0)); // both a and b are 2× off
    }

    #[test]
    fn renders_all_rows_and_notes() {
        let t = PaperTable::new("T1", "Throughput", "kQ/s")
            .row("fixed simple", 3488.0, Some(2340.0))
            .note("paper quotes A=9");
        let s = t.to_string();
        assert!(s.contains("fixed simple"));
        assert!(s.contains("note: paper quotes A=9"));
        assert!(s.contains("1.49×"));
    }

    #[test]
    fn empty_paper_prints_dash() {
        let t = PaperTable::new("T2", "x", "u").row("only-ours", 1.0, None);
        assert!(t.to_string().contains("—"));
        assert_eq!(t.worst_ratio(), None);
    }
}
