//! Table data model + plain-text rendering + JSON serialization.

use std::fmt;

use crate::util::Json;

use super::Report;

/// One table row: our value vs the paper's.
#[derive(Debug, Clone)]
pub struct TableRow {
    pub label: String,
    pub ours: f64,
    /// The paper's published value, if it reports one for this row.
    pub paper: Option<f64>,
    /// Host-measured (wall-clock) rather than model-derived: serialized as
    /// `"measured": true` so run-provenance hashing can exclude the row
    /// (measured values are not reproducible across hosts).
    pub measured: bool,
}

impl TableRow {
    pub fn new(label: impl Into<String>, ours: f64, paper: Option<f64>) -> TableRow {
        TableRow { label: label.into(), ours, paper, measured: false }
    }

    /// ours / paper (reproduction ratio; 1.0 = exact).
    pub fn ratio(&self) -> Option<f64> {
        self.paper.map(|p| self.ours / p)
    }
}

/// A regenerated paper table.
#[derive(Debug, Clone)]
pub struct PaperTable {
    /// Experiment id from DESIGN.md (e.g. "T3").
    pub id: &'static str,
    pub title: String,
    /// Unit of the value column.
    pub unit: &'static str,
    pub rows: Vec<TableRow>,
    /// Methodology / discrepancy notes printed under the table.
    pub notes: Vec<String>,
}

impl PaperTable {
    pub fn new(id: &'static str, title: impl Into<String>, unit: &'static str) -> PaperTable {
        PaperTable { id, title: title.into(), unit, rows: Vec::new(), notes: Vec::new() }
    }

    pub fn row(mut self, label: impl Into<String>, ours: f64, paper: Option<f64>) -> Self {
        self.rows.push(TableRow::new(label, ours, paper));
        self
    }

    /// Like [`PaperTable::row`], flagged host-measured (see
    /// [`TableRow::measured`]).
    pub fn measured_row(mut self, label: impl Into<String>, ours: f64, paper: Option<f64>) -> Self {
        let mut row = TableRow::new(label, ours, paper);
        row.measured = true;
        self.rows.push(row);
        self
    }

    pub fn note(mut self, n: impl Into<String>) -> Self {
        self.notes.push(n.into());
        self
    }

    /// Worst |log-ratio| across rows with paper values — the headline
    /// reproduction-quality scalar for EXPERIMENTS.md.
    pub fn worst_ratio(&self) -> Option<f64> {
        self.rows
            .iter()
            .filter_map(TableRow::ratio)
            .map(|r| if r >= 1.0 { r } else { 1.0 / r })
            .fold(None, |acc, r| Some(acc.map_or(r, |a: f64| a.max(r))))
    }

    /// Machine-readable form (the [`Report`] contract).
    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                let mut pairs = vec![
                    ("label", Json::Str(r.label.clone())),
                    ("ours", Json::Num(r.ours)),
                    ("paper", r.paper.map(Json::Num).unwrap_or(Json::Null)),
                    ("ratio", r.ratio().map(Json::Num).unwrap_or(Json::Null)),
                ];
                // emitted only when set: model-derived rows keep their
                // pre-observability JSON shape (golden files unchanged)
                if r.measured {
                    pairs.push(("measured", Json::Bool(true)));
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("id", Json::Str(self.id.into())),
            ("title", Json::Str(self.title.clone())),
            ("unit", Json::Str(self.unit.into())),
            ("rows", Json::Arr(rows)),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
            ),
            (
                "worst_ratio",
                self.worst_ratio().map(Json::Num).unwrap_or(Json::Null),
            ),
        ])
    }
}

impl Report for PaperTable {
    fn id(&self) -> &str {
        self.id
    }

    fn render(&self) -> String {
        self.to_string()
    }

    fn to_json(&self) -> Json {
        PaperTable::to_json(self)
    }
}

/// Wrap a collection of tables into the one document shape `--json`
/// writes and `qfpga diff` consumes.
pub fn set_to_json(tables: &[PaperTable]) -> Json {
    Json::obj(vec![
        ("report", Json::Str("qfpga".into())),
        ("version", Json::Num(1.0)),
        (
            "tables",
            Json::Arr(tables.iter().map(PaperTable::to_json).collect()),
        ),
    ])
}

fn fmt_value(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

impl fmt::Display for PaperTable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "[{}] {}", self.id, self.title)?;
        let label_w = self
            .rows
            .iter()
            .map(|r| r.label.len())
            .max()
            .unwrap_or(10)
            .max(12);
        writeln!(
            f,
            "  {:<label_w$}  {:>12}  {:>12}  {:>7}",
            "row",
            format!("ours ({})", self.unit),
            "paper",
            "ratio"
        )?;
        writeln!(f, "  {:-<label_w$}  {:->12}  {:->12}  {:->7}", "", "", "", "")?;
        for r in &self.rows {
            let paper = r.paper.map(fmt_value).unwrap_or_else(|| "—".into());
            let ratio = r
                .ratio()
                .map(|x| format!("{x:.2}×"))
                .unwrap_or_else(|| "—".into());
            writeln!(
                f,
                "  {:<label_w$}  {:>12}  {:>12}  {:>7}",
                r.label,
                fmt_value(r.ours),
                paper,
                ratio
            )?;
        }
        for n in &self.notes {
            writeln!(f, "  note: {n}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_and_worst() {
        let t = PaperTable::new("T0", "test", "µs")
            .row("a", 2.0, Some(1.0))
            .row("b", 0.5, Some(1.0))
            .row("c", 1.0, None);
        assert_eq!(t.rows[0].ratio(), Some(2.0));
        assert_eq!(t.worst_ratio(), Some(2.0)); // both a and b are 2× off
    }

    #[test]
    fn renders_all_rows_and_notes() {
        let t = PaperTable::new("T1", "Throughput", "kQ/s")
            .row("fixed simple", 3488.0, Some(2340.0))
            .note("paper quotes A=9");
        let s = t.to_string();
        assert!(s.contains("fixed simple"));
        assert!(s.contains("note: paper quotes A=9"));
        assert!(s.contains("1.49×"));
    }

    #[test]
    fn empty_paper_prints_dash() {
        let t = PaperTable::new("T2", "x", "u").row("only-ours", 1.0, None);
        assert!(t.to_string().contains("—"));
        assert_eq!(t.worst_ratio(), None);
    }

    #[test]
    fn json_form_is_stable_and_roundtrips() {
        let t = PaperTable::new("T9", "json test", "µs")
            .row("a", 2.0, Some(1.0))
            .row("b", 1.5, None)
            .note("a note");
        let j = t.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
        assert_eq!(parsed.req_str("id").unwrap(), "T9");
        let rows = parsed.req_arr("rows").unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req_f64("ours").unwrap(), 2.0);
        assert_eq!(rows[0].req_f64("ratio").unwrap(), 2.0);
        assert!(rows[1].get("paper").unwrap().is_null());
        assert_eq!(parsed.req_f64("worst_ratio").unwrap(), 2.0);
    }

    #[test]
    fn measured_rows_are_flagged_only_when_measured() {
        let t = PaperTable::new("T9", "m", "µs")
            .row("model", 1.0, None)
            .measured_row("host", 2.0, None);
        assert!(!t.rows[0].measured);
        assert!(t.rows[1].measured);
        let rows = t.to_json();
        let rows = rows.req_arr("rows").unwrap();
        assert!(rows[0].get("measured").is_none());
        assert_eq!(rows[1].get("measured"), Some(&Json::Bool(true)));
    }

    #[test]
    fn set_wraps_tables_with_ids() {
        let a = PaperTable::new("T1", "a", "u").row("x", 1.0, None);
        let b = PaperTable::new("T2", "b", "u").row("y", 2.0, None);
        let doc = set_to_json(&[a, b]);
        let tables = doc.req_arr("tables").unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].req_str("id").unwrap(), "T1");
        assert_eq!(tables[1].req_str("id").unwrap(), "T2");
    }
}
