//! Generators for every table/claim in the paper's evaluation (Section 5),
//! plus the ablations called out in DESIGN.md (X1–X3).

use crate::config::{Arch, EnvKind, NetConfig, Precision};
use crate::error::Result;
use crate::fixed::FixedSpec;
use crate::fpga::area::check_fit;
use crate::fpga::power::{energy_per_update_uj, power_w, PowerCoeffs};
use crate::fpga::{TimingModel, Virtex7};
use crate::nn::activation::{LutSpec, SigmoidLut};

use super::format::PaperTable;

fn model() -> (TimingModel, Virtex7) {
    (TimingModel::default(), Virtex7::default())
}

/// Every table `qfpga report --all` emits, in canonical order — the single
/// source of truth shared by the CLI and the golden-report tests.
/// `completion` supplies Tables 3–6 (the caller decides whether to measure
/// the host CPU); `batch` sizes the B1 batched-datapath table.
pub fn all_tables(
    mut completion: impl FnMut(Arch, EnvKind) -> Result<PaperTable>,
    batch: usize,
) -> Result<Vec<PaperTable>> {
    Ok(vec![
        table1(),
        table2(),
        completion(Arch::Perceptron, EnvKind::Simple)?,
        completion(Arch::Perceptron, EnvKind::Complex)?,
        completion(Arch::Mlp, EnvKind::Simple)?,
        completion(Arch::Mlp, EnvKind::Complex)?,
        table_power(EnvKind::Simple),
        table_power(EnvKind::Complex),
        energy_table(),
        table_batch(batch),
        resilience_overhead(),
        headline(),
        ablation_pipelining(),
        ablation_lut_rom(),
        ablation_wordlen(),
    ])
}

// ------------------------------------------------------------- Tables 1 & 2

/// Table 1: single-neuron (perceptron) throughput.
pub fn table1() -> PaperTable {
    let (t, dev) = model();
    let simple = NetConfig::new(Arch::Perceptron, EnvKind::Simple);
    let mut a9 = simple;
    a9.a = 9;
    let complex = NetConfig::new(Arch::Perceptron, EnvKind::Complex);

    PaperTable::new("T1", "Perceptron throughput (Table 1)", "kQ/s")
        .row(
            "fixed simple (A=6)",
            t.throughput_kq_s(&simple, Precision::Fixed, &dev),
            None,
        )
        .row(
            "fixed simple (A=9, paper's anchor)",
            t.throughput_kq_s(&a9, Precision::Fixed, &dev),
            Some(2340.0),
        )
        .row(
            "float simple",
            t.throughput_kq_s(&simple, Precision::Float, &dev),
            Some(290.0),
        )
        .row(
            "fixed complex (A=40)",
            t.throughput_kq_s(&complex, Precision::Fixed, &dev),
            Some(530.0),
        )
        .row(
            "float complex",
            t.throughput_kq_s(&complex, Precision::Float, &dev),
            Some(10.0),
        )
        .note("paper's 2.34 MQ/s quote is self-consistent only with A=9 (7·9+1=64 cycles \
               @150 MHz), while Section 5 defines the simple env with A=6 — both rows shown")
}

/// Table 2: MLP throughput.
pub fn table2() -> PaperTable {
    let (t, dev) = model();
    let simple = NetConfig::new(Arch::Mlp, EnvKind::Simple);
    let complex = NetConfig::new(Arch::Mlp, EnvKind::Complex);

    PaperTable::new("T2", "MLP throughput (Table 2)", "kQ/s")
        .row(
            "fixed simple",
            t.throughput_kq_s(&simple, Precision::Fixed, &dev),
            Some(1060.0),
        )
        .row(
            "float simple",
            t.throughput_kq_s(&simple, Precision::Float, &dev),
            Some(745.0),
        )
        .row(
            "fixed complex",
            t.throughput_kq_s(&complex, Precision::Fixed, &dev),
            Some(247.0),
        )
        .row(
            "float complex",
            t.throughput_kq_s(&complex, Precision::Float, &dev),
            Some(9.0),
        )
        .note("the paper's own Tables 2 and 5 disagree: 745 kQ/s (Table 2) implies 1.3 µs \
               per update, but Table 5 reports 13 µs (≈77 kQ/s) for the same float simple \
               MLP; our structural model reproduces the Table 5 figure")
}

// --------------------------------------------------------------- Tables 3–6

/// Inputs for a completion-time table: the measured host-CPU latency (µs)
/// and the paper's CPU constant (µs).
#[derive(Debug, Clone, Copy)]
pub struct CompletionInputs {
    /// Median per-update latency measured on this host (float CPU backend),
    /// µs. `None` prints the model-only rows.
    pub measured_cpu_us: Option<f64>,
}

/// Paper constants for Tables 3–6. The paper only published numbers for
/// its own two environments; scenario-library kinds have no paper row.
fn paper_completion(arch: Arch, env: EnvKind) -> (f64, f64, f64) {
    // (fixed µs, float µs, cpu µs)
    match (arch, env) {
        (Arch::Perceptron, EnvKind::Simple) => (0.4, 7.7, 20.0),
        (Arch::Perceptron, EnvKind::Complex) => (1.8, 102.0, 172.0),
        (Arch::Mlp, EnvKind::Simple) => (0.9, 13.0, 20.0),
        (Arch::Mlp, EnvKind::Complex) => (4.0, 107.0, 172.0),
        _ => panic!("no paper completion table for env `{}`", env.as_str()),
    }
}

fn completion_id(arch: Arch, env: EnvKind) -> (&'static str, &'static str) {
    match (arch, env) {
        (Arch::Perceptron, EnvKind::Simple) => ("T3", "Simple neuron (Table 3)"),
        (Arch::Perceptron, EnvKind::Complex) => ("T4", "Complex neuron (Table 4)"),
        (Arch::Mlp, EnvKind::Simple) => ("T5", "Simple MLP (Table 5)"),
        (Arch::Mlp, EnvKind::Complex) => ("T6", "Complex MLP (Table 6)"),
        _ => panic!("no paper completion table for env `{}`", env.as_str()),
    }
}

/// Tables 3–6: completion time per Q-update + advantage over CPU.
pub fn table_completion(arch: Arch, env: EnvKind, inputs: CompletionInputs) -> PaperTable {
    let (t, dev) = model();
    let net = NetConfig::new(arch, env);
    let (id, title) = completion_id(arch, env);
    let (paper_fx, paper_fp, paper_cpu) = paper_completion(arch, env);

    let fx = t.completion_us(&net, Precision::Fixed, &dev);
    let fp = t.completion_us(&net, Precision::Float, &dev);

    let mut table = PaperTable::new(id, title, "µs")
        .row("FPGA Virtex-7, fixed (model)", fx, Some(paper_fx))
        .row("FPGA Virtex-7, floating (model)", fp, Some(paper_fp))
        .row("CPU (paper's i5 2.3 GHz)", paper_cpu, Some(paper_cpu))
        // the paper's Advantage column, with its own CPU baseline
        .row("advantage: fixed vs paper CPU", paper_cpu / fx, Some(paper_cpu / paper_fx))
        .row("advantage: float vs paper CPU", paper_cpu / fp, Some(paper_cpu / paper_fp));

    if let Some(cpu) = inputs.measured_cpu_us {
        // this host is a ~2020s core, far faster than the 2017 i5 — shown
        // without a paper ratio (different baselines are not comparable)
        table = table
            .row("CPU (this host, measured)", cpu, None)
            .row("advantage: fixed vs host CPU", cpu / fx, None);
    }
    table.note("FPGA rows from the structural cycle model at 150 MHz; the paper's FPGA \
                numbers are likewise simulation-derived (Xilinx tools)")
}

// --------------------------------------------------------------- Tables 7–8

/// Tables 7 (simple MLP) and 8 (complex MLP): power at 150 MHz.
pub fn table_power(env: EnvKind) -> PaperTable {
    let coeffs = PowerCoeffs::default();
    let net = NetConfig::new(Arch::Mlp, env);
    let (id, title, paper_fx, paper_fp) = match env {
        EnvKind::Simple => ("T7", "Power, simple MLP (Table 7)", 5.6, 7.1),
        EnvKind::Complex => ("T8", "Power, complex MLP (Table 8)", 7.1, 10.0),
        other => panic!("no paper power table for env `{}`", other.as_str()),
    };
    let fx = power_w(&net, Precision::Fixed, &coeffs);
    let fp = power_w(&net, Precision::Float, &coeffs);
    let dev = Virtex7::default();
    let u_fx = check_fit(&net, Precision::Fixed, &dev).map(|u| u.max_fraction()).unwrap_or(1.0);
    let u_fp = check_fit(&net, Precision::Float, &dev).map(|u| u.max_fraction()).unwrap_or(1.0);

    PaperTable::new(id, title, "W")
        .row("FPGA Virtex-7, fixed", fx, Some(paper_fx))
        .row("FPGA Virtex-7, floating", fp, Some(paper_fp))
        .row("advantage (float/fixed)", fp / fx, Some(paper_fp / paper_fx))
        .note(format!(
            "device utilization: fixed {:.1}%, float {:.1}% of the 485T \
             (coefficients calibrated per fpga::power docs)",
            u_fx * 100.0,
            u_fp * 100.0
        ))
}

/// Energy per Q-update — “the energy values is what that is most useful
/// for comparisons” (paper Section 5, which could not measure it on real
/// hardware; the model can).
pub fn energy_table() -> PaperTable {
    let coeffs = PowerCoeffs::default();
    let (t, dev) = model();
    let mut table = PaperTable::new(
        "E1",
        "Energy per Q-update (paper Section 5's preferred metric)",
        "µJ",
    );
    for net in NetConfig::all() {
        for prec in [Precision::Fixed, Precision::Float] {
            let e = energy_per_update_uj(&net, prec, &coeffs, &t, &dev);
            table = table.row(format!("{} {}", net.name(), prec.as_str()), e, None);
        }
    }
    table.note("power model × modeled completion time; fixed point wins both factors, \
                so its energy advantage exceeds its speed advantage")
}

// ------------------------------------------------------------------ batched

/// B1: batched-datapath throughput vs stepwise, all configurations — the
/// modeled side of the `update_batch` fast path (`--batch`). Float rows are
/// expected at 1.00×: the serial LogiCORE chains cannot pipeline, which is
/// itself a paper-shaped result (fixed point benefits *more* from batching).
pub fn table_batch(b: usize) -> PaperTable {
    let (t, dev) = model();
    let mut table = PaperTable::new(
        "B1",
        format!("Batched Q-update datapath, modeled throughput (B = {b})"),
        "kQ/s",
    );
    for net in NetConfig::all() {
        for prec in [Precision::Fixed, Precision::Float] {
            let stepwise = t.throughput_kq_s(&net, prec, &dev);
            let batched = t.batch_throughput_kq_s(&net, prec, b, &dev);
            table = table
                .row(format!("{} {} stepwise", net.name(), prec.as_str()), stepwise, None)
                .row(
                    format!("{} {} batched (×{:.2})", net.name(), prec.as_str(),
                            batched / stepwise),
                    batched,
                    None,
                );
        }
    }
    table.note(
        "batched fixed datapath: II=1 action pipelining, dual sweeps chained through one \
         pipe fill, error capture overlapped — the Section 6 pipelining proposal; \
         regenerate with `qfpga report --table batch --batch <B>`",
    )
}

// --------------------------------------------------------------- resilience

/// R1: modeled SEU-mitigation overheads — what each hardening strategy
/// costs in datapath area, dynamic power and per-update cycles, relative
/// to the unmitigated design (the paper never prices radiation hardening;
/// this closes that gap for the complex fixed-point MLP). The measured
/// learning-survival side comes from `qfpga radiation` (the [R2] campaign
/// table).
pub fn resilience_overhead() -> PaperTable {
    use crate::fault::Mitigation;
    let (t, _dev) = model();
    let coeffs = PowerCoeffs::default();
    let net = NetConfig::new(Arch::Mlp, EnvKind::Complex);
    let prec = Precision::Fixed;
    let mut table = PaperTable::new(
        "R1",
        "SEU mitigation overhead vs unmitigated datapath (complex MLP, fixed)",
        "×",
    );
    for m in Mitigation::all() {
        table = table
            .row(
                format!("{:<9} area (LUT-eq)", m.label()),
                m.area_overhead_factor(&net, prec),
                None,
            )
            .row(
                format!("{:<9} dynamic power", m.label()),
                m.power_overhead_factor(&net, prec, &coeffs),
                None,
            )
            .row(
                format!("{:<9} cycles/update", m.label()),
                m.cycle_overhead_factor(&net, prec, &t),
                None,
            );
    }
    table.note(
        "TMR triplicates the datapath (+ per-bit voters); scrub adds a golden-copy \
         controller and an amortized rewrite burst; ECC stores SECDED codewords with \
         decode-on-read — regenerate with `qfpga report --table resilience`, measure \
         learning survival with `qfpga radiation`",
    )
}

// ----------------------------------------------------------------- headline

/// H1: the abstract's speedup claims (“up to 43-fold [MLP] / 95-fold
/// [neuron] … compared to a conventional Intel i5 2.3 GHz CPU”).
pub fn headline() -> PaperTable {
    let (t, dev) = model();
    let neuron = NetConfig::new(Arch::Perceptron, EnvKind::Complex);
    let mlp = NetConfig::new(Arch::Mlp, EnvKind::Complex);
    // paper CPU constants (its own baseline)
    let cpu = 172.0;
    let neuron_speedup = cpu / t.completion_us(&neuron, Precision::Fixed, &dev);
    let mlp_speedup = cpu / t.completion_us(&mlp, Precision::Fixed, &dev);

    PaperTable::new("H1", "Headline speedups vs the paper's CPU baseline", "×")
        .row("single neuron, complex, fixed", neuron_speedup, Some(95.0))
        .row("MLP, complex, fixed", mlp_speedup, Some(43.0))
        .note("paper Table 4/6 'Advantage' column; our FPGA time from the cycle model, \
               CPU time fixed to the paper's 172 µs so the ratio isolates the FPGA model")
}

// ---------------------------------------------------------------- ablations

/// X1: datapath pipelining (the paper's stated future work).
pub fn ablation_pipelining() -> PaperTable {
    let base = TimingModel::default();
    let pipe = TimingModel::pipelined();
    let dev = Virtex7::default();
    let mut t = PaperTable::new("X1", "Ablation: action-pipelined fixed datapath", "µs");
    for net in NetConfig::all() {
        let b = base.completion_us(&net, Precision::Fixed, &dev);
        let p = pipe.completion_us(&net, Precision::Fixed, &dev);
        t = t
            .row(format!("{} baseline", net.name()), b, None)
            .row(format!("{} pipelined", net.name()), p, None);
    }
    t.note("paper Section 6: “power consumption can be further reduced by introducing \
            pipelining in the data path” — here pipelining buys throughput at equal clock")
}

/// X2: sigmoid-ROM size vs activation accuracy (paper Section 3 remark).
pub fn ablation_lut_rom() -> PaperTable {
    let mut t = PaperTable::new("X2", "Ablation: sigmoid ROM size vs max |error|", "abs err");
    for size in [64usize, 128, 256, 512, 1024, 2048, 4096] {
        let lut = SigmoidLut::build(LutSpec { size, xmax: 8.0 }, None);
        t = t.row(format!("{size} entries"), lut.max_abs_error(20_001) as f64, None);
    }
    t.note("paper: “The size of ROM plays a major role in the accuracy of the output \
            value” — error halves per doubling, as expected for nearest-entry lookup")
}

/// X3: fixed-point word/fraction length vs quantization error (paper
/// Section 5: word length trades accuracy against power).
pub fn ablation_wordlen() -> PaperTable {
    use crate::nn::params::QNetParams;
    use crate::nn::qupdate::{forward, Datapath};
    use crate::nn::activation::Activation;
    use crate::util::Rng;

    let net = NetConfig::new(Arch::Mlp, EnvKind::Complex);
    let mut rng = Rng::seeded(77);
    let params = QNetParams::init(&net, 0.4, &mut rng);
    let sa = rng.vec_f32(net.a * net.d, -1.0, 1.0);
    let float_dp = Datapath::new(None, Activation::lut_default(None));
    let q_ref = forward(&net, &params, &sa, &float_dp).expect("forward");

    let mut t = PaperTable::new("X3", "Ablation: fixed word length vs Q-value error", "abs err");
    for (w, f) in [(8u32, 4u32), (12, 8), (16, 8), (18, 12), (24, 16), (32, 24)] {
        let spec = FixedSpec::new(w, f);
        let dp = Datapath::new(Some(spec), Activation::lut_default(Some(spec)));
        let q = forward(&net, &params, &sa, &dp).expect("forward");
        let err = q
            .iter()
            .zip(&q_ref)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        t = t.row(format!("Q({w},{f})"), err as f64, None);
    }
    t.note("error vs the float datapath on the complex MLP; Q(18,12) is the default \
            (DSP48-friendly) and sits below the sigmoid-LUT error floor")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_anchor_rows_accurate() {
        let t = table1();
        // A=9 anchor within 1%
        let a9 = &t.rows[1];
        assert!((a9.ratio().unwrap() - 1.0).abs() < 0.01, "{a9:?}");
        // complex fixed within 1%
        let cfx = &t.rows[3];
        assert!((cfx.ratio().unwrap() - 1.0).abs() < 0.01, "{cfx:?}");
    }

    #[test]
    fn completion_tables_within_2x_of_paper() {
        for (arch, env) in [
            (Arch::Perceptron, EnvKind::Simple),
            (Arch::Perceptron, EnvKind::Complex),
            (Arch::Mlp, EnvKind::Simple),
            (Arch::Mlp, EnvKind::Complex),
        ] {
            let t = table_completion(arch, env, CompletionInputs { measured_cpu_us: None });
            // FPGA model rows (first two) stay within 2.5× of the paper
            for row in &t.rows[..2] {
                let r = row.ratio().unwrap();
                let r = if r < 1.0 { 1.0 / r } else { r };
                assert!(r < 2.5, "{arch:?}/{env:?} {}: ratio {r}", row.label);
            }
        }
    }

    #[test]
    fn power_tables_shape() {
        for env in [EnvKind::Simple, EnvKind::Complex] {
            let t = table_power(env);
            assert!(t.rows[1].ours > t.rows[0].ours, "float must cost more");
            let adv = &t.rows[2];
            assert!((1.05..=1.9).contains(&adv.ours), "{}", adv.ours);
        }
    }

    #[test]
    fn headline_order_of_magnitude() {
        let t = headline();
        // neuron headline: paper 95×; our model 172/1.87 ≈ 92×
        assert!((t.rows[0].ratio().unwrap() - 1.0).abs() < 0.25, "{:?}", t.rows[0]);
        // MLP headline: paper 43×; ours differs only via the MLP cycle model
        let r = t.rows[1].ratio().unwrap();
        assert!((0.4..=2.5).contains(&r), "{r}");
    }

    #[test]
    fn energy_table_fixed_dominates() {
        let t = energy_table();
        assert_eq!(t.rows.len(), 8);
        for pair in t.rows.chunks(2) {
            // fixed row then float row per config
            assert!(
                pair[1].ours > 5.0 * pair[0].ours,
                "{} vs {}",
                pair[0].label,
                pair[1].label
            );
        }
    }

    #[test]
    fn batch_table_fixed_speedups_float_neutral() {
        let t = table_batch(32);
        assert_eq!(t.rows.len(), 16); // 4 configs × 2 precisions × 2 rows
        for pair in t.rows.chunks(2) {
            let (stepwise, batched) = (&pair[0], &pair[1]);
            if stepwise.label.contains("fixed") {
                assert!(
                    batched.ours > 2.0 * stepwise.ours,
                    "{}: {} vs {}",
                    stepwise.label,
                    batched.ours,
                    stepwise.ours
                );
            } else {
                assert!(
                    (batched.ours - stepwise.ours).abs() < 1e-9,
                    "{}: float must be batch-neutral",
                    stepwise.label
                );
            }
        }
    }

    #[test]
    fn resilience_overhead_table_shape() {
        let t = resilience_overhead();
        assert_eq!(t.rows.len(), 12); // 4 mitigations × 3 overhead axes
        // row 0–2: unmitigated baseline is exactly 1×
        for r in &t.rows[..3] {
            assert!((r.ours - 1.0).abs() < 1e-12, "{}: {}", r.label, r.ours);
        }
        // TMR rows (3–5): area and power both >2× the unmitigated datapath
        assert!(t.rows[3].ours > 2.0, "TMR area {}", t.rows[3].ours);
        assert!(t.rows[4].ours > 2.0, "TMR power {}", t.rows[4].ours);
        // every overhead factor is ≥1 (hardening never comes free-negative)
        for r in &t.rows {
            assert!(r.ours >= 1.0, "{}: {}", r.label, r.ours);
        }
    }

    #[test]
    fn ablations_have_expected_shape() {
        let lut = ablation_lut_rom();
        // error strictly decreases with ROM size
        for w in lut.rows.windows(2) {
            assert!(w[1].ours < w[0].ours, "{:?}", w);
        }
        let word = ablation_wordlen();
        // widest format must beat the narrowest
        assert!(word.rows.last().unwrap().ours < word.rows[0].ours);
        let pipe = ablation_pipelining();
        for pair in pipe.rows.chunks(2) {
            assert!(pair[1].ours < pair[0].ours, "pipelining must help");
        }
    }
}
