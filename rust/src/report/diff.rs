//! `qfpga diff` — compare two report JSON files within tolerances.
//!
//! The reference (`golden`) side defines what gets compared: every table in
//! it (matched by `id`) must exist in `ours`, every golden row (matched by
//! `label`) must exist in the matching table, and every shared numeric
//! field (`ours`, `ratio` on table rows; all numeric fields on campaign
//! `cells`) must agree within the relative tolerance. Extra tables or rows
//! on the `ours` side are ignored — the golden can be a stable subset
//! (e.g. model-derived rows only, excluding host-measured latencies).
//!
//! Non-table documents (run manifests, checkpoints) fall back to a strict
//! structural walk: same keys on both sides, numerics within tolerance,
//! everything else exact. `--ignore-keys run_id,durations` deep-strips the
//! named keys from both sides first, which is how two manifests of the
//! same spec diff clean (see [`crate::obs::manifest`]).

use crate::error::{Error, Result};
use crate::obs::manifest::strip_keys;
use crate::util::Json;

/// Outcome of one diff run.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    /// Numeric values compared.
    pub compared: usize,
    /// Human-readable problem lines (drift, missing tables/rows).
    pub problems: Vec<String>,
}

impl DiffReport {
    pub fn ok(&self) -> bool {
        self.problems.is_empty()
    }

    /// One-paragraph summary for the CLI.
    pub fn render(&self, tol: f64) -> String {
        let mut out = format!(
            "compared {} values (relative tolerance {tol}): {}\n",
            self.compared,
            if self.ok() {
                "OK".to_string()
            } else {
                format!("{} problem(s)", self.problems.len())
            }
        );
        for p in &self.problems {
            out.push_str(&format!("  {p}\n"));
        }
        out
    }
}

/// Relative closeness: |a − b| within `tol` of the larger magnitude. A
/// tiny absolute escape keeps exact-zero pairs (and float dust around
/// them) from failing vacuously; it is far below any reported quantity,
/// so sub-1.0 paper ratios still get a genuinely relative gate.
fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()) + 1e-12
}

/// The list of table objects in a report document: either the `tables`
/// array of a [`crate::report::set_to_json`] wrapper, or the document
/// itself when it is a single report object.
fn tables_of(doc: &Json) -> Vec<&Json> {
    match doc.get("tables").and_then(Json::as_arr) {
        Some(arr) => arr.iter().collect(),
        None => vec![doc],
    }
}

fn table_id(t: &Json) -> Option<&str> {
    t.get("id").and_then(Json::as_str)
}

/// Find `label`'s row in a table's `rows` array.
fn find_row<'a>(table: &'a Json, label: &str) -> Option<&'a Json> {
    table
        .get("rows")?
        .as_arr()?
        .iter()
        .find(|r| r.get("label").and_then(Json::as_str) == Some(label))
}

/// Composite key for a resilience-campaign cell.
fn cell_key(c: &Json) -> Option<String> {
    let backend = c.get("backend")?.as_str()?;
    let mitigation = c.get("mitigation")?.as_str()?;
    let rate = c.get("rate")?.as_f64()?;
    Some(format!("{backend}|{rate:e}|{mitigation}"))
}

fn find_cell<'a>(table: &'a Json, key: &str) -> Option<&'a Json> {
    table
        .get("cells")?
        .as_arr()?
        .iter()
        .find(|c| cell_key(c).as_deref() == Some(key))
}

fn diff_value(
    ctx: &str,
    field: &str,
    ours: &Json,
    golden: &Json,
    tol: f64,
    out: &mut DiffReport,
) {
    // the golden side defines what must exist: no golden value, nothing to
    // compare — but a golden value our side lost (e.g. a ratio gone null
    // because a paper constant was dropped) is itself a regression
    let Some(b) = golden.get(field).and_then(Json::as_f64) else {
        return;
    };
    let Some(a) = ours.get(field).and_then(Json::as_f64) else {
        out.problems.push(format!(
            "{ctx}: {field} missing from ours (golden has {b})"
        ));
        return;
    };
    out.compared += 1;
    if !close(a, b, tol) {
        out.problems.push(format!(
            "{ctx}: {field} drifted: ours {a} vs golden {b} \
             (Δ {:+.3e}, tol {tol})",
            a - b
        ));
    }
}

/// Does this document speak the report-table protocol (id + rows/cells,
/// possibly under a `tables` wrapper)? Anything else gets the structural
/// walk.
fn is_table_doc(doc: &Json) -> bool {
    doc.get("tables").and_then(Json::as_arr).is_some()
        || (doc.get("id").is_some()
            && (doc.get("rows").is_some() || doc.get("cells").is_some()))
}

/// Strict structural comparison for non-table documents: golden keys must
/// all exist in ours and vice versa, numeric leaves compare within `tol`,
/// all other leaves compare exactly.
fn diff_structural(path: &str, ours: &Json, golden: &Json, tol: f64, out: &mut DiffReport) {
    let at = |key: &str| {
        if path.is_empty() {
            key.to_string()
        } else {
            format!("{path}.{key}")
        }
    };
    match (ours, golden) {
        (Json::Obj(a), Json::Obj(b)) => {
            for (k, gv) in b {
                match a.get(k) {
                    Some(ov) => diff_structural(&at(k), ov, gv, tol, out),
                    None => out.problems.push(format!("{}: missing from ours", at(k))),
                }
            }
            for k in a.keys() {
                if !b.contains_key(k) {
                    out.problems.push(format!("{}: extra key in ours", at(k)));
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                out.problems.push(format!(
                    "{path}: array length {} vs golden {}",
                    a.len(),
                    b.len()
                ));
            }
            for (i, (ov, gv)) in a.iter().zip(b).enumerate() {
                diff_structural(&format!("{path}[{i}]"), ov, gv, tol, out);
            }
        }
        (Json::Num(a), Json::Num(b)) => {
            out.compared += 1;
            if !close(*a, *b, tol) {
                out.problems.push(format!(
                    "{path}: drifted: ours {a} vs golden {b} (Δ {:+.3e}, tol {tol})",
                    a - b
                ));
            }
        }
        (a, b) => {
            out.compared += 1;
            if a != b {
                out.problems.push(format!("{path}: ours {a} vs golden {b}"));
            }
        }
    }
}

/// Compare `ours` against `golden` within relative tolerance `tol`, after
/// deep-removing every key named in `ignore` from both sides.
pub fn diff_json_ignoring(
    ours: &Json,
    golden: &Json,
    tol: f64,
    ignore: &[&str],
) -> DiffReport {
    let (ours, golden) = if ignore.is_empty() {
        (ours.clone(), golden.clone())
    } else {
        (strip_keys(ours, ignore), strip_keys(golden, ignore))
    };
    if !is_table_doc(&golden) {
        let mut out = DiffReport::default();
        diff_structural("", &ours, &golden, tol, &mut out);
        return out;
    }
    diff_json(&ours, &golden, tol)
}

/// Compare `ours` against `golden` within relative tolerance `tol`.
pub fn diff_json(ours: &Json, golden: &Json, tol: f64) -> DiffReport {
    let mut out = DiffReport::default();
    let our_tables = tables_of(ours);

    for gtable in tables_of(golden) {
        let Some(id) = table_id(gtable) else {
            out.problems.push("golden table without an `id` field".into());
            continue;
        };
        let Some(otable) = our_tables.iter().find(|t| table_id(t) == Some(id)) else {
            out.problems.push(format!("table {id}: missing from ours"));
            continue;
        };

        // paper-table rows, matched by label
        if let Some(rows) = gtable.get("rows").and_then(Json::as_arr) {
            for grow in rows {
                let Some(label) = grow.get("label").and_then(Json::as_str) else {
                    continue;
                };
                let Some(orow) = find_row(otable, label) else {
                    out.problems
                        .push(format!("table {id}: row `{label}` missing from ours"));
                    continue;
                };
                let ctx = format!("table {id}, row `{label}`");
                diff_value(&ctx, "ours", orow, grow, tol, &mut out);
                diff_value(&ctx, "ratio", orow, grow, tol, &mut out);
            }
        }

        // campaign cells, matched by (backend, rate, mitigation)
        if let Some(cells) = gtable.get("cells").and_then(Json::as_arr) {
            for gcell in cells {
                let Some(key) = cell_key(gcell) else { continue };
                let Some(ocell) = find_cell(otable, &key) else {
                    out.problems
                        .push(format!("table {id}: cell `{key}` missing from ours"));
                    continue;
                };
                if let Some(obj) = gcell.as_obj() {
                    for (field, v) in obj {
                        if v.as_f64().is_some() && field.as_str() != "rate" {
                            diff_value(
                                &format!("table {id}, cell `{key}`"),
                                field,
                                ocell,
                                gcell,
                                tol,
                                &mut out,
                            );
                        }
                    }
                }
            }
        }
    }
    out
}

/// File-based front-end for the CLI. `ignore` lists object keys to
/// deep-strip from both documents before comparing (`--ignore-keys`).
pub fn diff_files(
    ours_path: &str,
    golden_path: &str,
    tol: f64,
    ignore: &[&str],
) -> Result<DiffReport> {
    let read = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read `{path}`: {e}")))?;
        Json::parse(&text)
    };
    Ok(diff_json_ignoring(
        &read(ours_path)?,
        &read(golden_path)?,
        tol,
        ignore,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::{set_to_json, PaperTable};

    fn sample() -> Json {
        set_to_json(&[
            PaperTable::new("T1", "throughput", "kQ/s")
                .row("fixed", 2343.75, Some(2340.0))
                .row("float", 144.2, None),
            PaperTable::new("H1", "headline", "×").row("speedup", 91.8, Some(95.0)),
        ])
    }

    #[test]
    fn identical_documents_diff_clean() {
        let d = diff_json(&sample(), &sample(), 0.01);
        assert!(d.ok(), "{:?}", d.problems);
        // ours + ratio per paper row, ours + null-ratio skip per bare row
        assert!(d.compared >= 4, "{}", d.compared);
    }

    #[test]
    fn injected_ratio_regression_is_flagged() {
        let golden = sample();
        let drifted = set_to_json(&[
            PaperTable::new("T1", "throughput", "kQ/s")
                .row("fixed", 2343.75 * 1.2, Some(2340.0)) // +20% drift
                .row("float", 144.2, None),
            PaperTable::new("H1", "headline", "×").row("speedup", 91.8, Some(95.0)),
        ]);
        let d = diff_json(&drifted, &golden, 0.05);
        assert!(!d.ok());
        assert!(
            d.problems.iter().any(|p| p.contains("T1") && p.contains("fixed")),
            "{:?}",
            d.problems
        );
        // within-tolerance drift passes
        let ok = diff_json(&drifted, &golden, 0.25);
        assert!(ok.ok(), "{:?}", ok.problems);
    }

    #[test]
    fn missing_tables_and_rows_are_flagged() {
        let golden = sample();
        let partial = set_to_json(&[
            PaperTable::new("T1", "throughput", "kQ/s").row("fixed", 2343.75, Some(2340.0)),
        ]);
        let d = diff_json(&partial, &golden, 0.05);
        assert_eq!(
            d.problems
                .iter()
                .filter(|p| p.contains("missing"))
                .count(),
            2, // row `float` + table H1
            "{:?}",
            d.problems
        );
        // extra ours-side tables are fine
        let d2 = diff_json(&sample(), &partial, 0.05);
        assert!(d2.ok(), "{:?}", d2.problems);
    }

    #[test]
    fn losing_a_golden_numeric_field_is_flagged() {
        // ours dropped the paper constant, so its ratio went null while the
        // golden still carries one — that is a regression, not a skip
        let golden =
            set_to_json(&[PaperTable::new("T1", "t", "u").row("fixed", 2343.75, Some(2340.0))]);
        let ours = set_to_json(&[PaperTable::new("T1", "t", "u").row("fixed", 2343.75, None)]);
        let d = diff_json(&ours, &golden, 0.05);
        assert!(!d.ok());
        assert!(
            d.problems.iter().any(|p| p.contains("ratio missing")),
            "{:?}",
            d.problems
        );
    }

    #[test]
    fn campaign_cells_are_matched_by_key() {
        let mk = |degradation: f64| {
            Json::obj(vec![
                ("id", Json::Str("R2".into())),
                (
                    "cells",
                    Json::Arr(vec![Json::obj(vec![
                        ("backend", Json::Str("cpu".into())),
                        ("rate", Json::Num(1e-4)),
                        ("mitigation", Json::Str("tmr".into())),
                        ("degradation", Json::Num(degradation)),
                    ])]),
                ),
            ])
        };
        let d = diff_json(&mk(0.02), &mk(0.02), 0.01);
        assert!(d.ok());
        assert_eq!(d.compared, 1);
        let d = diff_json(&mk(5.0), &mk(0.02), 0.01);
        assert!(!d.ok());
    }

    #[test]
    fn single_table_documents_work_without_a_wrapper() {
        let t = PaperTable::new("V1", "validate", "max |Δ|").row("cfg", 1e-6, None);
        let d = diff_json(&t.to_json(), &t.to_json(), 0.01);
        assert!(d.ok());
        assert_eq!(d.compared, 1);
    }

    #[test]
    fn structural_diff_with_ignore_keys_compares_manifests() {
        let mk = |run_id: &str, wall: f64, seed: f64| {
            Json::obj(vec![
                ("run_id", Json::Str(run_id.into())),
                ("seed", Json::Num(seed)),
                (
                    "durations",
                    Json::obj(vec![("wall_seconds", Json::Num(wall))]),
                ),
                ("report_sha256", Json::Str("abc".into())),
            ])
        };
        // same run modulo run_id/durations: clean only when ignored
        let a = mk("run-1", 0.5, 7.0);
        let b = mk("run-2", 9.0, 7.0);
        assert!(!diff_json_ignoring(&a, &b, 0.0, &[]).ok());
        let d = diff_json_ignoring(&a, &b, 0.0, &["run_id", "durations"]);
        assert!(d.ok(), "{:?}", d.problems);
        // a real divergence still surfaces under the ignore set
        let c = mk("run-3", 0.5, 8.0);
        let d = diff_json_ignoring(&a, &c, 0.0, &["run_id", "durations"]);
        assert!(!d.ok());
        assert!(d.problems.iter().any(|p| p.contains("seed")), "{:?}", d.problems);
    }

    #[test]
    fn structural_diff_flags_shape_mismatches() {
        let a = Json::obj(vec![
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("extra", Json::Null),
        ]);
        let b = Json::obj(vec![
            ("xs", Json::Arr(vec![Json::Num(1.0)])),
            ("gone", Json::Bool(true)),
        ]);
        let d = diff_json_ignoring(&a, &b, 0.01, &[]);
        assert!(d.problems.iter().any(|p| p.contains("array length")), "{:?}", d.problems);
        assert!(d.problems.iter().any(|p| p.contains("gone") && p.contains("missing")));
        assert!(d.problems.iter().any(|p| p.contains("extra key")));
    }

    #[test]
    fn ignore_keys_leaves_table_docs_on_the_table_path() {
        // a stripped table document still diffs by id/label, not
        // structurally — extra ours-side rows stay permitted
        let golden = set_to_json(&[
            PaperTable::new("T1", "t", "u").row("fixed", 1.0, None),
        ]);
        let ours = set_to_json(&[
            PaperTable::new("T1", "t", "u").row("fixed", 1.0, None).row("more", 2.0, None),
        ]);
        let d = diff_json_ignoring(&ours, &golden, 0.01, &["notes"]);
        assert!(d.ok(), "{:?}", d.problems);
    }

    #[test]
    fn render_summarizes() {
        let d = diff_json(&sample(), &sample(), 0.05);
        let s = d.render(0.05);
        assert!(s.contains("OK"), "{s}");
    }
}
