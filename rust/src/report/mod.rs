//! Paper-table regeneration (Tables 1–8, headline claims, ablations).
//!
//! Each generator returns a [`PaperTable`] carrying our value, the paper's
//! published value and their ratio, so every claim is checkable at a
//! glance. `qfpga report` prints them; `cargo bench --bench paper_tables`
//! regenerates the measured rows; EXPERIMENTS.md records the outcome.

pub mod format;
pub mod tables;

pub use format::{PaperTable, TableRow};
pub use tables::{
    ablation_lut_rom, ablation_pipelining, ablation_wordlen, energy_table, headline,
    resilience_overhead, table1, table2, table_batch, table_completion, table_power,
    CompletionInputs,
};
