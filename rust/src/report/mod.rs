//! Paper-table regeneration (Tables 1–8, headline claims, ablations) and
//! the typed report surface.
//!
//! Each generator returns a [`PaperTable`] carrying our value, the paper's
//! published value and their ratio, so every claim is checkable at a
//! glance. `qfpga report` prints them; `cargo bench --bench paper_tables`
//! regenerates the measured rows; EXPERIMENTS.md records the outcome.
//!
//! Every report in the repo — paper tables, resilience campaigns, latency
//! sweeps, experiment outcomes — implements the [`Report`] trait, so every
//! `qfpga` subcommand can honor `--json FILE` with the same stable schema
//! and `qfpga diff` ([`diff::diff_json`]) can gate paper-ratio drift in CI.

pub mod diff;
pub mod format;
pub mod tables;

pub use diff::{diff_files, diff_json, diff_json_ignoring, DiffReport};
pub use format::{set_to_json, PaperTable, TableRow};
pub use tables::{
    ablation_lut_rom, ablation_pipelining, ablation_wordlen, all_tables, energy_table, headline,
    resilience_overhead, table1, table2, table_batch, table_completion, table_power,
    CompletionInputs,
};

use crate::util::Json;

/// A renderable, serializable experiment artifact. `render()` is the
/// human-facing text every subcommand prints; `to_json()` is the stable
/// machine-readable twin `--json FILE` writes and `qfpga diff` compares.
pub trait Report {
    /// Stable identifier (e.g. `"T1"`, `"R2"`, `"S1"`), used by the diff
    /// tool to pair tables across files.
    fn id(&self) -> &str;

    /// Plain-text rendering.
    fn render(&self) -> String;

    /// Machine-readable form. Must parse back ([`Json::parse`]) to the
    /// same value — asserted by `tests/report_json.rs`.
    fn to_json(&self) -> Json;
}
