//! Minimal JSON: enough to read `artifacts/manifest.json` and write
//! telemetry/result files. Supports the full JSON grammar except for
//! `\u` surrogate pairs (accepted, replaced with U+FFFD).

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --------------------------------------------------------------- access

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required typed getters (error messages carry the key).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Artifact(format!("missing field `{key}`")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Artifact(format!("field `{key}` not a string")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Artifact(format!("field `{key}` not a usize")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Artifact(format!("field `{key}` not a number")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| Error::Artifact(format!("field `{key}` not an array")))
    }

    // ---------------------------------------------------------------- build

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Artifact(format!("json parse error at byte {}: {msg}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected `{}`", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence starting at c
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.req_arr("a").unwrap().len(), 3);
        assert_eq!(v.req_arr("a").unwrap()[2].req_str("b").unwrap(), "c");
        assert!(v.get("d").unwrap().is_null());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"arr":[1,2.5,true,null],"name":"q \"x\"","nested":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""café — ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café — ✓");
    }

    #[test]
    fn typed_getters_error_messages() {
        let v = Json::parse(r#"{"n": "not a number"}"#).unwrap();
        let err = v.req_usize("n").unwrap_err().to_string();
        assert!(err.contains("`n`"), "{err}");
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert_eq!(m.req_usize("version").unwrap(), 1);
            assert!(m.req("artifacts").unwrap().as_obj().unwrap().len() >= 24);
        }
    }
}
