//! Zero-dependency utility substrates.
//!
//! The deployment target (radiation-hardened flight software) motivates a
//! minimal dependency footprint, so the pieces usually pulled from crates.io
//! are built in-repo: a seedable PRNG ([`rng`]), a small JSON
//! parser/writer for the artifact manifest ([`json`]), and a tiny CLI
//! argument parser ([`cli`]).

pub mod cli;
pub mod json;
pub mod rng;
pub mod sha256;
pub mod shutdown;

pub use json::Json;
pub use rng::Rng;
pub use sha256::sha256_hex;
