//! Tiny CLI argument parser: `--key value`, `--flag`, positionals.
//!
//! Intentionally minimal (flight-software style): no derive magic, explicit
//! lookups, helpful errors.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` separator: rest is positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        Error::Config(format!("option --{name} requires a value"))
                    })?;
                    out.options.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option lookup with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("option --{name}: cannot parse `{v}`"))
            }),
        }
    }

    /// Required option.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::Config(format!("missing required option --{name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags).unwrap()
    }

    #[test]
    fn options_flags_positionals() {
        let a = parse("train --env simple --steps 100 --verbose file.txt", &["verbose"]);
        assert_eq!(a.positional(), ["train", "file.txt"]);
        assert_eq!(a.get("env"), Some("simple"));
        assert_eq!(a.get_parse("steps", 0usize).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--env=complex --seed=7", &[]);
        assert_eq!(a.get("env"), Some("complex"));
        assert_eq!(a.get_parse("seed", 0u64).unwrap(), 7);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(["--steps".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse("--steps banana", &[]);
        assert!(a.get_parse("steps", 0usize).is_err());
    }

    #[test]
    fn double_dash_separator() {
        let a = parse("-- --not-an-option", &[]);
        assert_eq!(a.positional(), ["--not-an-option"]);
    }

    #[test]
    fn defaults() {
        let a = parse("", &[]);
        assert_eq!(a.get_or("env", "simple"), "simple");
        assert!(a.require("env").is_err());
    }
}
