//! Cooperative shutdown: one process-global drain flag, settable from a
//! SIGINT/SIGTERM handler or programmatically.
//!
//! Flight software cannot afford to die mid-episode: a signal must turn
//! into "finish the current chunk, write a checkpoint, exit 0". The
//! mechanism here is the smallest one that is async-signal-safe — the
//! handler performs a single atomic store and nothing else; everything
//! that actually drains (the fleet worker pool, the scenario campaign
//! loop, the `qfpga serve` accept loop) polls [`requested`] at its own
//! safe points.
//!
//! The crate is zero-dependency, so the handler is registered through the
//! raw libc `signal(2)` entry point instead of a signal crate. On glibc,
//! `signal()` installs BSD semantics (`SA_RESTART`), which means blocking
//! syscalls are *restarted* after the handler runs — pollers must not
//! rely on `EINTR` to observe the flag. Every drain loop in this repo
//! polls explicitly (nonblocking accept + sleep, chunked episode runs)
//! for exactly that reason.

use std::sync::atomic::{AtomicBool, Ordering};

/// Set by [`on_signal`]/[`request`], observed by every drain loop.
static REQUESTED: AtomicBool = AtomicBool::new(false);

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

extern "C" {
    /// `signal(2)` — the only libc surface this module touches.
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

/// The installed handler: one atomic store, nothing else (the only
/// operation that is unconditionally async-signal-safe).
extern "C" fn on_signal(_signum: i32) {
    REQUESTED.store(true, Ordering::SeqCst);
}

/// Install the SIGINT/SIGTERM handler. Idempotent; safe to call from any
/// subcommand that wants drain-on-signal semantics.
pub fn install() {
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

/// Request a drain programmatically (the daemon's `shutdown` protocol
/// verb, tests).
pub fn request() {
    REQUESTED.store(true, Ordering::SeqCst);
}

/// Has a drain been requested (by signal or [`request`])?
pub fn requested() -> bool {
    REQUESTED.load(Ordering::SeqCst)
}

/// Clear the flag. Test-harness plumbing: the flag is process-global and
/// `cargo test` runs many tests in one process.
pub fn reset() {
    REQUESTED.store(false, Ordering::SeqCst);
}

/// Serializes tests that touch the process-global flag (`cargo test` runs
/// the whole lib suite in one process; a concurrent reader would observe
/// another test's transient `request`).
#[cfg(test)]
pub(crate) static TEST_FLAG_GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    extern "C" {
        fn raise(signum: i32) -> i32;
    }

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        TEST_FLAG_GUARD.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn request_and_reset_toggle_the_flag() {
        let _guard = guard();
        reset();
        assert!(!requested());
        request();
        assert!(requested());
        reset();
        assert!(!requested());
    }

    #[test]
    fn a_real_sigterm_sets_the_flag_once_installed() {
        // `install` replaces the default (terminating) disposition, so
        // raising SIGTERM here is safe: the process survives and the
        // handler's store becomes observable.
        let _guard = guard();
        install();
        reset();
        unsafe { raise(SIGTERM) };
        assert!(requested());
        reset();
    }
}
