//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component (terrain generation, ε-greedy exploration,
//! weight init, workload generators) draws from this generator, so entire
//! experiments replay bit-identically from a seed — a hard requirement for
//! the paper-table benches and for debugging learning runs.
//!
//! Algorithms: Blackman & Vigna, <https://prng.di.unimi.it/> (public domain).

/// xoshiro256++ generator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-agent / per-episode RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seeded(self.next_u64() ^ tag.rotate_left(17))
    }

    /// The full generator state (mission checkpointing). Restoring via
    /// [`Rng::from_state`] resumes the stream bit-exactly.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's method; `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`, 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out {
            *v = self.f32_range(lo, hi);
        }
    }

    /// Random f32 vector in `[lo, hi)`.
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::seeded(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn f64_unit_interval_roughly_uniform() {
        let mut rng = Rng::seeded(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn chance_extremes() {
        let mut rng = Rng::seeded(3);
        assert!(!(0..100).any(|_| rng.chance(0.0)));
        assert!((0..100).all(|_| rng.chance(1.0)));
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::seeded(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        // extremely unlikely to collide on first 4 draws
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn range_bounds() {
        let mut rng = Rng::seeded(4);
        for _ in 0..100 {
            let v = rng.range(5, 9);
            assert!((5..9).contains(&v));
        }
    }
}
