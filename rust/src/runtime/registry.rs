//! Per-thread runtime: PJRT client + compiled-executor cache.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use crate::config::{NetConfig, Precision};
use crate::error::{Error, Result};

use super::artifact::{ArtifactKind, Manifest};
use super::executor::Executor;

/// A PJRT CPU client plus the manifest and a lazy compile cache.
///
/// Not `Send`: PJRT client handles have thread affinity in the `xla` crate.
/// Workers each build their own `Runtime` (compilation of these small
/// modules takes milliseconds; see the `substrates` bench).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executor>>>,
}

impl Runtime {
    /// Create a runtime over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Xla(format!("PjRtClient::cpu: {e}")))?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Create a runtime over the default artifact directory.
    pub fn from_default_dir() -> Result<Runtime> {
        Runtime::new(&super::default_artifact_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of executors compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Get (compile on first use) the executor for an artifact name.
    pub fn executor(&self, name: &str) -> Result<Rc<Executor>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact `{name}`")))?
            .clone();
        let exe = Rc::new(Executor::compile(&self.client, meta)?);
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Get the executor for a configuration.
    pub fn select(
        &self,
        net: &NetConfig,
        prec: Precision,
        kind: ArtifactKind,
    ) -> Result<Rc<Executor>> {
        self.executor(&Manifest::artifact_name(net, prec, kind))
    }

    /// Eagerly compile every artifact (deployment warm-up).
    pub fn warm_up(&self) -> Result<usize> {
        let names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        for name in &names {
            self.executor(name)?;
        }
        Ok(names.len())
    }
}
