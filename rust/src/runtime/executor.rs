//! One compiled artifact: shape-checked execution + typed call helpers.

use crate::config::NetConfig;
use crate::error::{Error, Result};
use crate::nn::params::QNetParams;
use crate::nn::qupdate::QUpdateOutput;

use super::artifact::{ArtifactKind, ArtifactMeta, DType};

/// A borrowed input tensor.
#[derive(Debug, Clone, Copy)]
pub enum TensorValue<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl TensorValue<'_> {
    fn len(&self) -> usize {
        match self {
            TensorValue::F32(s) => s.len(),
            TensorValue::I32(s) => s.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            TensorValue::F32(_) => DType::F32,
            TensorValue::I32(_) => DType::I32,
        }
    }
}

/// A compiled, ready-to-execute artifact. Not `Send` (PJRT client affinity);
/// create one per worker thread via [`super::Runtime`].
pub struct Executor {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executor {
    /// Load the HLO text, compile on the given client.
    pub fn compile(client: &xla::PjRtClient, meta: ArtifactMeta) -> Result<Executor> {
        let path = meta.file.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| Error::Artifact(format!("{}: {e}", meta.name)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Xla(format!("compile {}: {e}", meta.name)))?;
        Ok(Executor { meta, exe })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Execute with shape/dtype validation. Returns one `Vec<f32>` per
    /// declared output (all our artifacts produce f32 outputs).
    pub fn run_raw(&self, inputs: &[TensorValue]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(Error::interface(format!(
                "{}: got {} inputs, artifact declares {}",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (value, spec) in inputs.iter().zip(&self.meta.inputs) {
            if value.len() != spec.elements() {
                return Err(Error::interface(format!(
                    "{}: input `{}` has {} elements, expected {} (shape {:?})",
                    self.meta.name,
                    spec.name,
                    value.len(),
                    spec.elements(),
                    spec.shape
                )));
            }
            if value.dtype() != spec.dtype {
                return Err(Error::interface(format!(
                    "{}: input `{}` dtype mismatch",
                    self.meta.name, spec.name
                )));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match *value {
                TensorValue::F32(s) => xla::Literal::vec1(s),
                TensorValue::I32(s) => xla::Literal::vec1(s),
            };
            literals.push(lit.reshape(&dims)?);
        }

        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // lowered with return_tuple=True: always a tuple, even single results
        let parts = tuple.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            return Err(Error::interface(format!(
                "{}: got {} outputs, artifact declares {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            )));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.iter().zip(&self.meta.outputs) {
            let v = lit.to_vec::<f32>()?;
            if v.len() != spec.elements() {
                return Err(Error::interface(format!(
                    "{}: output `{}` has {} elements, expected {}",
                    self.meta.name,
                    spec.name,
                    v.len(),
                    spec.elements()
                )));
            }
            out.push(v);
        }
        Ok(out)
    }

    fn check_kind(&self, kind: ArtifactKind) -> Result<()> {
        if self.meta.kind != kind {
            return Err(Error::interface(format!(
                "{} is a {:?} artifact, not {kind:?}",
                self.meta.name, self.meta.kind
            )));
        }
        Ok(())
    }

    fn net(&self) -> &NetConfig {
        &self.meta.net
    }

    /// Forward artifact: Q-values for all A actions.
    pub fn run_forward(&self, params: &QNetParams, sa: &[f32]) -> Result<Vec<f32>> {
        self.check_kind(ArtifactKind::Forward)?;
        let tensors = params.to_tensors();
        let mut inputs: Vec<TensorValue> = tensors.iter().map(|t| TensorValue::F32(t)).collect();
        inputs.push(TensorValue::F32(sa));
        let mut out = self.run_raw(&inputs)?;
        Ok(out.remove(0))
    }

    /// Q-update artifact: one full update. Returns the new parameters and
    /// the diagnostic vectors.
    pub fn run_qupdate(
        &self,
        params: &QNetParams,
        sa_cur: &[f32],
        sa_next: &[f32],
        action: usize,
        reward: f32,
    ) -> Result<QUpdateOutput> {
        self.check_kind(ArtifactKind::QUpdate)?;
        if action >= self.net().a {
            return Err(Error::Env(format!("action {action} out of range")));
        }
        let tensors = params.to_tensors();
        let action_buf = [action as i32];
        let reward_buf = [reward];
        let mut inputs: Vec<TensorValue> = tensors.iter().map(|t| TensorValue::F32(t)).collect();
        inputs.push(TensorValue::F32(sa_cur));
        inputs.push(TensorValue::F32(sa_next));
        inputs.push(TensorValue::I32(&action_buf));
        inputs.push(TensorValue::F32(&reward_buf));

        let out = self.run_raw(&inputs)?;
        let n = self.meta.n_param_tensors();
        let new_params = QNetParams::from_tensors(self.net(), &out[..n])?;
        Ok(QUpdateOutput {
            params: new_params,
            q_cur: out[n].clone(),
            q_next: out[n + 1].clone(),
            q_err: out[n + 2][0],
        })
    }

    /// Train-batch artifact: `batch` chained updates in one XLA call.
    /// Returns the new parameters and the per-step Q-errors.
    pub fn run_train_batch(
        &self,
        params: &QNetParams,
        sa_cur: &[f32],
        sa_next: &[f32],
        actions: &[i32],
        rewards: &[f32],
    ) -> Result<(QNetParams, Vec<f32>)> {
        self.check_kind(ArtifactKind::TrainBatch)?;
        let b = self.meta.batch;
        if actions.len() != b || rewards.len() != b {
            return Err(Error::interface(format!(
                "train_batch expects exactly {b} transitions"
            )));
        }
        let tensors = params.to_tensors();
        let mut inputs: Vec<TensorValue> = tensors.iter().map(|t| TensorValue::F32(t)).collect();
        inputs.push(TensorValue::F32(sa_cur));
        inputs.push(TensorValue::F32(sa_next));
        inputs.push(TensorValue::I32(actions));
        inputs.push(TensorValue::F32(rewards));

        let out = self.run_raw(&inputs)?;
        let n = self.meta.n_param_tensors();
        let new_params = QNetParams::from_tensors(self.net(), &out[..n])?;
        Ok((new_params, out[n].clone()))
    }
}
