//! XLA/PJRT runtime — loads and executes the AOT artifacts.
//!
//! The deployment path of the three-layer architecture: python lowers the
//! Pallas/JAX graphs to HLO **text** once (`make artifacts`); this module
//! loads the text with `HloModuleProto::from_text_file`, compiles it on the
//! PJRT CPU client, and executes it from the rust hot path. Python never
//! runs at request time.
//!
//! * [`artifact`] — `artifacts/manifest.json` model: shapes, dtypes,
//!   argument order, baked hyper-parameters (the rust↔python contract).
//! * [`executor`] — one compiled artifact + typed call helpers
//!   (`run_forward`, `run_qupdate`, `run_train_batch`).
//! * [`registry`] — a per-thread runtime: PJRT client + lazily compiled
//!   executor cache, keyed by artifact name.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so
//! a [`registry::Runtime`] must stay on the thread that created it. The
//! coordinator gives each worker its own `Runtime` (CPU clients are cheap);
//! see `coordinator::backend`.

pub mod artifact;
pub mod executor;
pub mod registry;

pub use artifact::{ArtifactKind, ArtifactMeta, DType, Manifest, TensorSpec};
pub use executor::{Executor, TensorValue};
pub use registry::Runtime;

/// Default artifact directory, relative to the crate root.
pub fn default_artifact_dir() -> std::path::PathBuf {
    // honor $QFPGA_ARTIFACTS when set (tests, deployments)
    if let Ok(dir) = std::env::var("QFPGA_ARTIFACTS") {
        return dir.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
