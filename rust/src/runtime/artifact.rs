//! Artifact manifest model — the machine-readable rust↔python contract.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing every
//! lowered HLO module: argument order, shapes, dtypes, the baked
//! hyper-parameters, fixed-point format and sigmoid-ROM geometry. This
//! module parses and validates it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::{Arch, EnvKind, Hyper, NetConfig, Precision};
use crate::error::{Error, Result};
use crate::util::Json;

/// Tensor element type used by the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => Err(Error::Artifact(format!("unsupported dtype `{other}`"))),
        }
    }
}

/// One input/output tensor declaration.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Artifact("bad shape entry".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            shape,
            dtype: DType::parse(j.req_str("dtype")?)?,
        })
    }
}

/// The three graph kinds emitted per configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Action-selection path: Q-values for all A actions.
    Forward,
    /// One full Q-update.
    QUpdate,
    /// `batch` scan-chained Q-updates in one call.
    TrainBatch,
}

impl ArtifactKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ArtifactKind::Forward => "forward",
            ArtifactKind::QUpdate => "qupdate",
            ArtifactKind::TrainBatch => "train_batch",
        }
    }

    fn parse(s: &str) -> Result<ArtifactKind> {
        match s {
            "forward" => Ok(ArtifactKind::Forward),
            "qupdate" => Ok(ArtifactKind::QUpdate),
            "train_batch" => Ok(ArtifactKind::TrainBatch),
            other => Err(Error::Artifact(format!("unknown kind `{other}`"))),
        }
    }
}

/// Everything the runtime needs to know about one artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub net: NetConfig,
    pub precision: Precision,
    pub batch: usize,
    pub hyper: Hyper,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactMeta {
    /// Number of parameter tensors at the head of the input list.
    pub fn n_param_tensors(&self) -> usize {
        match self.net.arch {
            Arch::Perceptron => 2,
            Arch::Mlp => 4,
        }
    }

    fn parse(name: &str, j: &Json, dir: &Path) -> Result<ArtifactMeta> {
        let arch: Arch = j.req_str("arch")?.parse()?;
        let env: EnvKind = j.req_str("env")?.parse()?;
        let net = NetConfig::new(arch, env);
        // cross-check declared dims against the canonical config
        let (d, h, a) = (
            j.req_usize("d")?,
            j.req_usize("h")?,
            j.req_usize("a")?,
        );
        if (net.d, net.h, net.a) != (d, h, a) {
            return Err(Error::Artifact(format!(
                "{name}: manifest dims ({d},{h},{a}) != canonical {:?}",
                (net.d, net.h, net.a)
            )));
        }
        let hyper_j = j.req("hyper")?;
        let hyper = Hyper {
            alpha: hyper_j.req_f64("alpha")? as f32,
            gamma: hyper_j.req_f64("gamma")? as f32,
            lr: hyper_j.req_f64("lr")? as f32,
        };
        let inputs = j
            .req_arr("inputs")?
            .iter()
            .map(TensorSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .req_arr("outputs")?
            .iter()
            .map(TensorSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactMeta {
            name: name.to_string(),
            file: dir.join(j.req_str("file")?),
            kind: ArtifactKind::parse(j.req_str("kind")?)?,
            net,
            precision: j.req_str("precision")?.parse()?,
            batch: j.req_usize("batch")?,
            hyper,
            inputs,
            outputs,
        })
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let version = j.req_usize("version")?;
        if version != 1 {
            return Err(Error::Artifact(format!("unsupported manifest version {version}")));
        }
        let mut artifacts = BTreeMap::new();
        let obj = j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("`artifacts` not an object".into()))?;
        for (name, entry) in obj {
            let meta = ArtifactMeta::parse(name, entry, dir)?;
            if !meta.file.exists() {
                return Err(Error::Artifact(format!(
                    "{name}: missing HLO file {}",
                    meta.file.display()
                )));
            }
            artifacts.insert(name.clone(), meta);
        }
        if artifacts.is_empty() {
            return Err(Error::Artifact("manifest lists no artifacts".into()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Canonical artifact name for a configuration.
    pub fn artifact_name(net: &NetConfig, prec: Precision, kind: ArtifactKind) -> String {
        format!("{}_{}_{}", net.name(), prec.as_str(), kind.as_str())
    }

    /// Look up by configuration.
    pub fn select(
        &self,
        net: &NetConfig,
        prec: Precision,
        kind: ArtifactKind,
    ) -> Result<&ArtifactMeta> {
        let name = Self::artifact_name(net, prec, kind);
        self.artifacts
            .get(&name)
            .ok_or_else(|| Error::Artifact(format!("no artifact `{name}` in manifest")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    fn manifest() -> Option<Manifest> {
        let dir = default_artifact_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else { return };
        assert!(m.artifacts.len() >= 24, "{}", m.artifacts.len());
    }

    #[test]
    fn selects_all_paper_configs() {
        let Some(m) = manifest() else { return };
        for net in NetConfig::all() {
            for prec in [Precision::Fixed, Precision::Float] {
                for kind in [ArtifactKind::Forward, ArtifactKind::QUpdate, ArtifactKind::TrainBatch]
                {
                    let meta = m.select(&net, prec, kind).unwrap();
                    assert_eq!(meta.kind, kind);
                    assert_eq!(meta.net, net);
                    // params head the input list
                    assert!(meta.inputs.len() > meta.n_param_tensors());
                }
            }
        }
    }

    #[test]
    fn qupdate_interface_shapes() {
        let Some(m) = manifest() else { return };
        let net = NetConfig::new(Arch::Mlp, EnvKind::Simple);
        let meta = m.select(&net, Precision::Float, ArtifactKind::QUpdate).unwrap();
        let names: Vec<&str> = meta.inputs.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["w1", "b1", "w2", "b2", "sa_cur", "sa_next", "action", "reward"]);
        assert_eq!(meta.inputs[4].shape, vec![net.a, net.d]);
        assert_eq!(meta.inputs[6].dtype, DType::I32);
        let out_names: Vec<&str> = meta.outputs.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(out_names, ["w1", "b1", "w2", "b2", "q_cur", "q_next", "q_err"]);
    }

    #[test]
    fn missing_dir_is_clear_error() {
        let err = Manifest::load(Path::new("/nonexistent/path")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn artifact_names_are_canonical() {
        let net = NetConfig::new(Arch::Perceptron, EnvKind::Complex);
        assert_eq!(
            Manifest::artifact_name(&net, Precision::Fixed, ArtifactKind::TrainBatch),
            "perceptron_complex_fixed_train_batch"
        );
    }
}
