//! Action-selection policies over Q-values (paper Eq. 2: the action policy
//! picks the argmax; exploration policies wrap it).

use crate::util::Rng;

/// Exploration policy.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// Always the argmax (Eq. 2).
    Greedy,
    /// With probability ε explore uniformly; ε decays multiplicatively per
    /// episode to `min`.
    EpsilonGreedy { eps: f32, decay: f32, min: f32 },
    /// Boltzmann exploration with temperature τ.
    Softmax { temp: f32 },
}

impl Policy {
    /// Standard training policy: ε 0.3 → 0.02, decay 0.995.
    pub fn default_training() -> Policy {
        Policy::EpsilonGreedy { eps: 0.3, decay: 0.995, min: 0.02 }
    }

    /// Pick an action given Q-values.
    pub fn select(&self, q: &[f32], rng: &mut Rng) -> usize {
        debug_assert!(!q.is_empty());
        match self {
            Policy::Greedy => argmax(q),
            Policy::EpsilonGreedy { eps, .. } => {
                if rng.f32() < *eps {
                    rng.below(q.len())
                } else {
                    argmax(q)
                }
            }
            Policy::Softmax { temp } => {
                let t = temp.max(1e-6);
                let m = q.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f32> = q.iter().map(|&v| ((v - m) / t).exp()).collect();
                let total: f32 = weights.iter().sum();
                let mut x = rng.f32() * total;
                for (i, w) in weights.iter().enumerate() {
                    x -= w;
                    if x <= 0.0 {
                        return i;
                    }
                }
                q.len() - 1
            }
        }
    }

    /// Per-episode decay (ε-greedy only).
    pub fn end_episode(&mut self) {
        if let Policy::EpsilonGreedy { eps, decay, min } = self {
            *eps = (*eps * *decay).max(*min);
        }
    }

    /// Restore the exploration rate (mission checkpoint resume). A no-op
    /// for policies without a decaying ε.
    pub fn set_epsilon(&mut self, e: f32) {
        if let Policy::EpsilonGreedy { eps, .. } = self {
            *eps = e;
        }
    }

    /// Current exploration rate (for telemetry).
    pub fn epsilon(&self) -> f32 {
        match self {
            Policy::Greedy => 0.0,
            Policy::EpsilonGreedy { eps, .. } => *eps,
            Policy::Softmax { temp } => *temp,
        }
    }
}

/// First-max argmax (matches the fixed-datapath comparator chain).
pub fn argmax(q: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in q.iter().enumerate() {
        if *v > q[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_takes_argmax() {
        let mut rng = Rng::seeded(1);
        let q = [0.1, 0.9, 0.5];
        for _ in 0..10 {
            assert_eq!(Policy::Greedy.select(&q, &mut rng), 1);
        }
    }

    #[test]
    fn epsilon_explores_and_decays() {
        let mut rng = Rng::seeded(2);
        let mut p = Policy::EpsilonGreedy { eps: 1.0, decay: 0.5, min: 0.1 };
        let q = [1.0, 0.0, 0.0, 0.0];
        let picks: Vec<usize> = (0..200).map(|_| p.select(&q, &mut rng)).collect();
        // ε = 1: uniform → all arms visited
        for a in 0..4 {
            assert!(picks.contains(&a), "arm {a} never explored");
        }
        for _ in 0..10 {
            p.end_episode();
        }
        assert_eq!(p.epsilon(), 0.1); // clamped at min
    }

    #[test]
    fn epsilon_zero_is_greedy() {
        let mut rng = Rng::seeded(3);
        let p = Policy::EpsilonGreedy { eps: 0.0, decay: 1.0, min: 0.0 };
        let q = [0.0, 0.0, 0.7];
        for _ in 0..50 {
            assert_eq!(p.select(&q, &mut rng), 2);
        }
    }

    #[test]
    fn softmax_prefers_higher_q() {
        let mut rng = Rng::seeded(4);
        let p = Policy::Softmax { temp: 0.1 };
        let q = [0.0, 1.0];
        let n1 = (0..1000).filter(|_| p.select(&q, &mut rng) == 1).count();
        assert!(n1 > 950, "{n1}");
    }

    #[test]
    fn softmax_high_temp_is_near_uniform() {
        let mut rng = Rng::seeded(5);
        let p = Policy::Softmax { temp: 100.0 };
        let q = [0.0, 1.0];
        let n1 = (0..2000).filter(|_| p.select(&q, &mut rng) == 1).count();
        assert!((800..1200).contains(&n1), "{n1}");
    }

    #[test]
    fn argmax_first_wins_ties() {
        assert_eq!(argmax(&[0.5, 0.5, 0.1]), 0);
    }
}
